"""Paper Figs 2 & 3 — per-system iteration counts and residual traces.

Fig 2 (right): CG vs def-CG(8,12) iterations per Newton system at tol
1e-5 — def-CG should sit ~25% below CG after the first system, with the
gap stagnating late (the paper's observed recycling limit).
Fig 3: relative-residual traces at tol 1e-8 — def-CG's *slope* must be
steeper (rate effect, P3), not just its starting point lower.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, gpc_problem, log
from repro.core import RecycleManager
from repro.gp import laplace_gpc


def run(n=None):
    x, y, kernel = gpc_problem(n)
    kd = kernel.gram(x)

    cg_res = laplace_gpc(
        x, y, kernel, solver="cg", solver_tol=1e-5, newton_tol=1.0,
        k_dense=kd, dense_matvec=True,
    )
    def_res = laplace_gpc(
        x, y, kernel, solver="defcg",
        recycle=RecycleManager(k=8, ell=12),
        solver_tol=1e-5, newton_tol=1.0, k_dense=kd, dense_matvec=True,
    )
    log("[fig2] iters/system  CG   : " + str(cg_res.trace.solver_iterations))
    log("[fig2] iters/system  defCG: " + str(def_res.trace.solver_iterations))
    for i, (a, b) in enumerate(
        zip(cg_res.trace.solver_iterations, def_res.trace.solver_iterations)
    ):
        emit(f"fig2/system{i+1}", 0.0, f"cg_iters={a};defcg_iters={b}")

    # Fig 3: tight-tolerance traces with slope comparison.
    cg8 = laplace_gpc(
        x, y, kernel, solver="cg", solver_tol=1e-8, newton_tol=1.0,
        k_dense=kd, dense_matvec=True, record_residuals=True,
        solver_maxiter=800,
    )
    def8 = laplace_gpc(
        x, y, kernel, solver="defcg",
        recycle=RecycleManager(k=8, ell=12, tol=1e-8, maxiter=800),
        solver_tol=1e-8, newton_tol=1.0, k_dense=kd, dense_matvec=True,
        record_residuals=True, solver_maxiter=800,
    )

    def slope(trace):
        r = np.asarray(trace)
        r = r[np.isfinite(r)]
        r = r[r > 0]
        if len(r) < 3:
            return 0.0
        return (np.log10(r[-1]) - np.log10(r[0])) / (len(r) - 1)

    slopes_cg = [slope(t) for t in cg8.trace.residual_traces[1:]]
    slopes_def = [slope(t) for t in def8.trace.residual_traces[1:]]
    mean_cg = float(np.mean(slopes_cg)) if slopes_cg else 0.0
    mean_def = float(np.mean(slopes_def)) if slopes_def else 0.0
    log(f"[fig3] mean log10-residual slope/iter: CG {mean_cg:.3f}  "
        f"defCG {mean_def:.3f} (steeper=better, P3 pass={mean_def < mean_cg})")
    emit("fig3/slopes", 0.0,
         f"cg={mean_cg:.4f};defcg={mean_def:.4f};P3_pass={mean_def < mean_cg}")
    return mean_def < mean_cg


if __name__ == "__main__":
    run()
