"""Least-squares engine benchmarks: recycled vs cold LSMR + fused kernel.

The acceptance number for the method axis (DESIGN.md §12): on a
sequence of ill-conditioned drifting ridge problems, deflated
warm-started LSMR (``deflsmr``, exact NW refresh — overhead CHARGED)
must beat cold LSMR on total A/Aᵀ products.  The regime matters and is
reported honestly: spectra with a slow singular tail (logspace decay)
are where deflation pays; flat Gaussian spectra tie, and the bench
records that null result too so the win is never oversold.

Also times the fused ``lsmr_update`` three-vector recurrence across the
impl contract (chunked is the deployable CPU path; reference is the
jnp oracle).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, log, timed
from repro.core import DenseMatrixOperator, lsmr, solve_sequence_lsmr_jit
from repro.kernels import ops


def _drifting_lsq(num, m, n, decay, drift, seed=0):
    """Rectangular sequence A_i = A_{i-1} + drift·‖A‖·G/√(mn), singular
    values of A_0 set by ``decay`` ('logspace' tail or 'flat')."""
    rng = np.random.default_rng(seed)
    U, _ = np.linalg.qr(rng.standard_normal((m, m)))
    V, _ = np.linalg.qr(rng.standard_normal((n, n)))
    if decay == "logspace":
        s = np.logspace(0, -3, n)
    else:
        s = np.abs(rng.standard_normal(n)) + 0.5
    base = U[:, :n] @ np.diag(s) @ V.T
    mats, bs = [], []
    for _ in range(num):
        mats.append(jnp.asarray(base))
        bs.append(jnp.asarray(rng.standard_normal(m)))
        base = base + drift * np.linalg.norm(base) / np.sqrt(m * n) * (
            rng.standard_normal((m, n))
        )
    return jnp.stack(mats), jnp.stack(bs)


def _cold_totals(mats, bs, damp, tol, maxiter):
    iters = mv = 0
    for i in range(mats.shape[0]):
        r = lsmr(DenseMatrixOperator(mats[i]), bs[i], damp=damp, tol=tol,
                 maxiter=maxiter)
        iters += int(r.info.iterations)
        mv += int(r.info.matvecs)
    return iters, mv


def _recycled_totals(mats, bs, damp, tol, maxiter, k, ell):
    seq = solve_sequence_lsmr_jit(
        mats, bs, k=k, ell=ell, damp=damp,
        make_operator=DenseMatrixOperator, tol=tol, maxiter=maxiter,
        refresh_aw="exact",
    )
    if not bool(np.all(np.asarray(seq.info.converged))):
        raise RuntimeError("recycled LSMR failed to converge in bench")
    return (
        int(np.sum(np.asarray(seq.info.iterations))),
        int(np.sum(np.asarray(seq.info.matvecs))),
    )


def run(num=12, m=180, n=120, k=8, ell=48, damp=1e-4, tol=1e-8,
        maxiter=600):
    # -- the win regime: slow singular tail, slow drift ------------------
    for decay in ("logspace", "flat"):
        mats, bs = _drifting_lsq(num, m, n, decay=decay, drift=0.02)
        ci, cmv = _cold_totals(mats, bs, damp, tol, maxiter)
        ri, rmv = _recycled_totals(mats, bs, damp, tol, maxiter, k, ell)
        save = 100.0 * (cmv - rmv) / cmv
        log(f"[lsq] {decay}: cold {ci} iters / {cmv} matvecs — "
            f"deflsmr(k={k}, exact refresh) {ri} iters / {rmv} matvecs "
            f"({save:+.1f}% products)")
        emit(f"lsq/{decay}_cold_matvecs", float(cmv),
             f"iters={ci}")
        emit(f"lsq/{decay}_recycled_matvecs", float(rmv),
             f"iters={ri};saved_pct={save:.1f}")

    # -- timed sequence throughput (the scan itself) ---------------------
    mats, bs = _drifting_lsq(num, m, n, decay="logspace", drift=0.02)
    _, t_seq = timed(
        lambda: solve_sequence_lsmr_jit(
            mats, bs, k=k, ell=ell, damp=damp,
            make_operator=DenseMatrixOperator, tol=tol, maxiter=maxiter,
            refresh_aw="exact",
        ),
        warmup=1, repeats=3,
    )
    emit("lsq/deflsmr_sequence", t_seq * 1e6 / num,
         f"us_per_system;num={num};m={m};n={n}")

    # -- fused lsmr_update microbench ------------------------------------
    nn = 1 << 20
    rng = np.random.default_rng(1)
    x, hbar, h, v = (
        jnp.asarray(rng.standard_normal(nn), jnp.float32) for _ in range(4)
    )
    c = (0.37, -1.21, 0.83)
    _, t_ref = timed(
        lambda: ops.lsmr_update(x, hbar, h, v, *c, impl="reference"),
        warmup=1, repeats=10,
    )
    _, t_chunk = timed(
        lambda: ops.lsmr_update(x, hbar, h, v, *c, impl="chunked",
                                block=65536),
        warmup=1, repeats=10,
    )
    bytes_moved = 7 * nn * 4  # 4 reads + 3 writes of f32
    log(f"[lsq] lsmr_update n={nn}: reference {t_ref*1e6:.0f}us "
        f"chunked {t_chunk*1e6:.0f}us "
        f"({bytes_moved/t_chunk/1e9:.1f} GB/s)")
    emit("lsq/lsmr_update_reference", t_ref * 1e6,
         f"gbps={bytes_moved/t_ref/1e9:.1f}")
    emit("lsq/lsmr_update_chunked", t_chunk * 1e6,
         f"gbps={bytes_moved/t_chunk/1e9:.1f}")


if __name__ == "__main__":
    run()
