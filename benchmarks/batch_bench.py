"""Multi-tenant batched-solve benchmarks: solve_batch vs a sequential loop.

The serving question behind ISSUE 3's tentpole: B users each bring a
GP-classification Newton system over the SAME kernel (per-tenant ``H½``
and rhs — one dataset, many posteriors).  ``solve_batch`` vmaps the flat
def-CG engine over the tenant axis, so all B solves share one XLA
computation (one dispatch, batched GEMMs, per-tenant convergence masks);
the baseline issues B sequential ``solve_jit`` calls (one compiled
program too, but B dispatches and no cross-tenant batching).  Emits
``batch/solve_batch_B{1,8,64}`` with per-tenant µs and the loop speedup.

History: before the all-tenants-converged early exit (ISSUE 5 — the
recording scan's matvec gate now reduces ``active`` across the vmap
axis), the vmapped path lost to the loop at every B on the 1-core CPU
box (0.46–0.95×): under ``vmap`` the per-lane gate lowered to a
``select`` and every tenant paid all ℓ recording-window matvecs even
after the whole batch converged.  With the cross-tenant gate the
batched path wins at B ≥ 8 on the same box (1.46×/2.08× recorded at
B=8/64); the remaining B=1 gap (masked while-loop overhead) stays a
ROADMAP item, and the full (n, B) GEMM win is still the TPU story.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, gpc_problem, log, timed
from repro.core import KernelSystemOperator, SolveSpec, solve_batch_jit, solve_jit


_KMAT_CACHE: dict = {}


def _tenants(B: int, n=None, seed=0):
    """B tenants: shared RBF Gram matrix, per-tenant H½ and rhs.

    K is materialized once (the paper's own setup — one kernel per
    hyperparameter setting serves every tenant), so the per-matvec cost
    is identical for the batched and sequential paths and the benchmark
    isolates the BATCHING effect: one XLA dispatch and one (n, B) GEMM
    per iteration vs B dispatches of (n,) GEMVs.
    """
    x, _, kernel = gpc_problem(n, seed=seed)
    n = x.shape[0]
    if n not in _KMAT_CACHE:
        _KMAT_CACHE[n] = jnp.asarray(kernel.gram(x))
    kmat = _KMAT_CACHE[n]
    k_mv = lambda v: kmat @ v  # noqa: E731 — stable closure for jit caching
    rng = np.random.default_rng(seed + 1)
    fs = jnp.asarray(rng.standard_normal((B, n)) * 0.5)
    pis = jax.nn.sigmoid(fs)
    sqrt_hs = jnp.sqrt(pis * (1.0 - pis))
    bs = jnp.asarray(rng.standard_normal((B, n)))
    return KernelSystemOperator(k_mv, sqrt_hs), bs, n


def batch_bench(sizes=(1, 8, 64), tol=1e-5, maxiter=200):
    spec = SolveSpec(k=8, ell=12, tol=tol, maxiter=maxiter)
    ok = True
    for B in sizes:
        ops_stacked, bs, n = _tenants(B)

        def run_batch():
            return solve_batch_jit(ops_stacked, bs, spec)

        extra_reps = 1 if B >= 32 else 2
        batch, t_batch = timed(run_batch, warmup=1, repeats=1)
        for _ in range(extra_reps):
            _, ti = timed(run_batch, repeats=1)
            t_batch = min(t_batch, ti)

        k_mv = ops_stacked.kernel_matvec

        def run_loop():
            outs = []
            for i in range(B):
                a_i = KernelSystemOperator(k_mv, ops_stacked.sqrt_h[i])
                outs.append(solve_jit(a_i, bs[i], spec))
            jax.block_until_ready(outs[-1].x)
            return outs

        loop, t_loop = timed(run_loop, warmup=1, repeats=1)
        for _ in range(extra_reps):
            _, ti = timed(run_loop, repeats=1)
            t_loop = min(t_loop, ti)

        # Parity while we are here: batched answers track the sequential
        # ones.  The batched matvec is an (n, B) GEMM whose reduction
        # order differs from B GEMVs, so iteration counts drift by a few
        # at large B (±3 observed over ~40-iteration solves) — the
        # contract is that every tenant still converges to tolerance.
        iters_b = np.asarray(batch.info.iterations)
        iters_l = np.asarray([int(r.info.iterations) for r in loop])
        ok = ok and bool(np.max(np.abs(iters_b - iters_l)) <= 4)
        ok = ok and bool(np.asarray(batch.info.converged).all())

        us_b = t_batch * 1e6 / B
        us_l = t_loop * 1e6 / B
        log(
            f"[batch] B={B:3d} n={n}: solve_batch {us_b:.0f} us/tenant "
            f"| sequential loop {us_l:.0f} us/tenant "
            f"({us_l / us_b:.2f}x) iters={iters_b.tolist()[:4]}…"
        )
        emit(
            f"batch/solve_batch_B{B}",
            us_b,
            f"n={n};loop_us={us_l:.0f};speedup={us_l / us_b:.2f};"
            f"max_iter_drift={int(np.max(np.abs(iters_b - iters_l)))}",
        )
    emit("batch/validation", 0.0, f"parity_and_convergence={ok}")
    return ok


def b1_fence_bench(tol=1e-5, maxiter=200):
    """The solve_batch B=1 regression: profiled, then fenced (ISSUE 8).

    Profile: at B=1 the vmapped engine still pays the masked-lowering
    tax — the recording scan's per-lane freezing masks lower to
    ``select`` chains and the batch-axis psum gate adds loop plumbing
    that a plain ``solve`` never builds.  We count ``select``/``while``
    ops in the two lowerings (emitted as ``batch/B1_lowering``) and time
    both paths; the recorded ~0.8× is lowering overhead, not extra
    matvecs (iteration counts are identical).

    Fence: the serving layer never takes that path — when exactly one
    pool slot is active, :class:`repro.serve.SolveService` gathers the
    slot and dispatches through plain ``solve_jit`` (emitted here as
    ``batch/B1_pool_dispatch``: the same single-tenant work at loop
    parity by construction, so the before/after pair lives in this
    section).
    """
    spec = SolveSpec(k=8, ell=12, tol=tol, maxiter=maxiter)
    ops_stacked, bs, n = _tenants(1)

    def run_batch():
        return solve_batch_jit(ops_stacked, bs, spec)

    batch, t_batch = timed(run_batch, warmup=1, repeats=3)

    a0 = KernelSystemOperator(ops_stacked.kernel_matvec, ops_stacked.sqrt_h[0])

    def run_single():
        return solve_jit(a0, bs[0], spec)

    single, t_single = timed(run_single, warmup=1, repeats=3)

    same_iters = int(batch.info.iterations[0]) == int(single.info.iterations)
    txt_b = solve_batch_jit.lower(ops_stacked, bs, spec).as_text()
    txt_s = solve_jit.lower(a0, bs[0], spec).as_text()
    sel_b, sel_s = txt_b.count("select("), txt_s.count("select(")
    whl_b, whl_s = txt_b.count("while("), txt_s.count("while(")

    us_b, us_s = t_batch * 1e6, t_single * 1e6
    log(
        f"[batch] B=1 fence n={n}: solve_batch {us_b:.0f} us | plain solve "
        f"(pool single-dispatch) {us_s:.0f} us ({us_b / us_s:.2f}x saved) "
        f"| lowering selects {sel_b} vs {sel_s}, whiles {whl_b} vs {whl_s} "
        f"| same_iters={same_iters}"
    )
    emit(
        "batch/B1_pool_dispatch",
        us_s,
        f"n={n};batch_us={us_b:.0f};batch_over_single="
        f"{us_b / us_s:.2f};same_iters={same_iters}",
    )
    emit(
        "batch/B1_lowering",
        0.0,
        f"selects_batched={sel_b};selects_single={sel_s};"
        f"whiles_batched={whl_b};whiles_single={whl_s}",
    )
    return same_iters


def run():
    ok = batch_bench()
    return b1_fence_bench() and ok


if __name__ == "__main__":
    run()
