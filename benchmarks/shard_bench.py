"""Sharded-engine benchmarks: per-iteration cost, collective counts,
and the n = 1e5 sharded RBF matvec.

Device counts {1, 4, 8} come from ``xla_force_host_platform_device_count``
(set at the top of run.py before jax initializes), so on this box the
"devices" are host threads — the numbers to watch are the per-iteration
TIME TREND and the per-while-body COLLECTIVE COUNTS (the one-all-reduce
contract, DESIGN.md §5), not absolute multi-device speedup.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, log, timed

_N = 4096  # divisible by every benched device count
_MAXITER = 40


def _dense_system(n=_N, seed=0):
    from repro.core.operators import DenseMatrixOperator

    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    eigs = np.logspace(0, 2, n)
    A = DenseMatrixOperator(mat=jnp.asarray((q * eigs) @ q.T))
    b = jnp.asarray(rng.standard_normal(n))
    return A, b


def _bench_defcg_per_iteration():
    from repro.core import sharded
    from repro.core.api import SolveSpec
    from repro.core.recycle import RecycleState
    from repro.launch import hlo_stats
    from repro.launch.mesh import make_solve_mesh

    A, b = _dense_system()
    # tol=0 never converges: every run spends exactly _MAXITER
    # iterations, so us/iter is a clean division.
    spec = SolveSpec(
        method="defcg", k=8, ell=12, tol=0.0, atol=0.0, maxiter=_MAXITER
    )
    st = RecycleState.zeros(8, _N, jnp.float64)

    n_avail = jax.device_count()
    for nd in (1, 4, 8):
        if nd > n_avail:
            log(f"shard/defcg d{nd}: skipped ({n_avail} devices)")
            continue
        mesh = make_solve_mesh(nd)
        res, dt = timed(
            lambda: sharded.solve_sharded(A, b, spec, st, mesh=mesh),
            warmup=1,
            repeats=3,
        )
        iters = int(res.info.iterations)
        # Pin the communication contract alongside the timing: every
        # while body (recording scan + while phase) of the compiled
        # sharded def-CG must hold exactly ONE all-reduce.
        hlo = (
            sharded.lower_sharded(A, b, spec, st, mesh=mesh)
            .compile()
            .as_text()
        )
        per_body = hlo_stats.while_body_collectives(hlo)
        ars = sorted(c.get("all-reduce", 0) for c in per_body.values())
        emit(
            f"shard/defcg_iter_d{nd}",
            dt / iters * 1e6,
            f"n={_N};iters={iters};allreduce_per_body="
            + ",".join(map(str, ars)),
        )
        log(
            f"shard/defcg d{nd}: {dt / iters * 1e6:8.1f} us/iter  "
            f"while-body all-reduce counts {ars}"
        )


def _bench_rbf_matvec_1e5():
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import sharded
    from repro.core.operators import RBFKernelSystemOperator
    from repro.launch.mesh import make_solve_mesh
    from jax.experimental.shard_map import shard_map

    n = 100_000
    if jax.device_count() < 8:
        log("shard/rbf_matvec_1e5: skipped (<8 devices)")
        return
    mesh = make_solve_mesh(8)
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.standard_normal((n, 2)), jnp.float32)
    sqrt_h = jnp.asarray(0.5 + rng.random(n), jnp.float32)
    v = jnp.asarray(rng.standard_normal(n), jnp.float32)
    A = RBFKernelSystemOperator(
        x=X, sqrt_h=sqrt_h, theta=1.0, lengthscale=2.0,
        impl="chunked", block=512,
    )
    kind, aux, leaves, leaf_specs = sharded._plan_operator(
        A, need_adjoint=False
    )

    def one_matvec(leaves, v_loc):
        apply, _, _ = sharded._make_applies(kind, aux, leaves)
        return apply(v_loc)

    fn = jax.jit(
        shard_map(
            one_matvec,
            mesh=mesh,
            in_specs=(leaf_specs, P("solve")),
            out_specs=P("solve"),
            check_rep=False,
        )
    )
    v_sh = jax.device_put(v, NamedSharding(mesh, P("solve")))
    out, dt = timed(fn, leaves, v_sh, warmup=0, repeats=1)
    assert bool(jnp.all(jnp.isfinite(out)))
    emit(
        "shard/rbf_matvec_1e5",
        dt * 1e6,
        f"n={n};d=2;f32;8shards;K_never_materialized",
    )
    log(f"shard/rbf matvec n=1e5 (8 shards, f32): {dt:8.2f} s")


def run():
    _bench_defcg_per_iteration()
    _bench_rbf_matvec_1e5()
