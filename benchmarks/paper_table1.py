"""Paper Table 1 — Cholesky vs CG vs def-CG(8,12) over the Newton sequence.

Reports, per Newton iteration: log p(y|f), relative error δ vs the
Cholesky (exact) column, and cumulative solver time — the paper's exact
columns, at a CPU-feasible n (paper: 36 551; here REPRO_BENCH_N).
Validation criteria (EXPERIMENTS.md §Paper-validation P1/P2):
  * all three solvers agree on log p(y|f) to ~solver tolerance;
  * def-CG uses fewer iterations than CG from the 2nd system on;
  * both iterative solvers beat cumulative Cholesky time.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit, gpc_problem, log
from repro.core import RecycleManager
from repro.gp import laplace_gpc


def run(n=None):
    x, y, kernel = gpc_problem(n)
    n = x.shape[0]
    kd = kernel.gram(x)
    jax.block_until_ready(kd)
    log(f"[table1] n={n}, dense K materialized (paper setup)")

    results = {}
    for solver in ("cholesky", "cg", "defcg"):
        recycle = (
            RecycleManager(k=8, ell=12, refresh_aw="exact")
            if solver == "defcg" else None
        )
        t0 = time.perf_counter()
        res = laplace_gpc(
            x, y, kernel,
            solver=solver, recycle=recycle,
            solver_tol=1e-5, newton_tol=1.0,
            k_dense=kd, dense_matvec=True,
        )
        wall = time.perf_counter() - t0
        results[solver] = (res, wall)
        log(f"[table1] {solver}: newtons={len(res.trace.logp)} "
            f"logp={res.logp:.3f} solver_time={res.trace.cumulative_time[-1]:.2f}s")

    chol, cgr, defr = (results[s][0] for s in ("cholesky", "cg", "defcg"))
    log("\nit |  chol logp  t[s] |    cg logp     δ      iters  t[s] |"
        "   defcg logp    δ      iters  t[s]")
    rows = max(len(chol.trace.logp), len(cgr.trace.logp), len(defr.trace.logp))
    for i in range(rows):
        def cell(res, want_iters):
            if i >= len(res.trace.logp):
                return "", "", "", ""
            lp = res.trace.logp[i]
            delta = abs(lp - chol.trace.logp[min(i, len(chol.trace.logp) - 1)]) / abs(
                chol.trace.logp[min(i, len(chol.trace.logp) - 1)]
            )
            iters = res.trace.solver_iterations[i] if want_iters else ""
            return lp, delta, iters, res.trace.cumulative_time[i]

        lp_c, _, _, t_c = cell(chol, False)
        lp_g, d_g, it_g, t_g = cell(cgr, True)
        lp_d, d_d, it_d, t_d = cell(defr, True)
        log(f"{i+1:2d} | {lp_c:11.3f} {t_c:5.1f} | {lp_g:11.3f} {d_g:.2e} "
            f"{it_g:5} {t_g:5.1f} | {lp_d:11.3f} {d_d:.2e} {it_d:5} {t_d:5.1f}")

    # CSV + validation
    cg_iters = sum(cgr.trace.solver_iterations[1:])
    def_iters = sum(defr.trace.solver_iterations[1:])
    saving = 1.0 - def_iters / max(cg_iters, 1)
    emit("table1/cholesky_total", results["cholesky"][0].trace.cumulative_time[-1] * 1e6,
         f"newtons={len(chol.trace.logp)}")
    emit("table1/cg_total", cgr.trace.cumulative_time[-1] * 1e6,
         f"iters={sum(cgr.trace.solver_iterations)}")
    emit("table1/defcg_total", defr.trace.cumulative_time[-1] * 1e6,
         f"iters={sum(defr.trace.solver_iterations)};iter_saving={saving:.1%}")
    agreement = max(
        abs(cgr.logp - chol.logp) / abs(chol.logp),
        abs(defr.logp - chol.logp) / abs(chol.logp),
    )
    emit("table1/validation", 0.0,
         f"agreement={agreement:.2e};P2_saving={saving:.1%};"
         f"P2_pass={saving > 0.15}")
    return saving


if __name__ == "__main__":
    run()
