"""Kernel micro-benchmarks: fused RBF matvec and attention impls.

On this CPU container the *chunked* implementations are the deployable
path and the Pallas kernels run in interpret mode (correctness only — its
timing is not meaningful).  We benchmark chunked vs reference
(materialize-K) to show the fusion trade: the fused path trades O(n²)
memory for recomputed distances, and multi-RHS amortization (the A·W
refresh) is measured directly.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, log, timed
from repro.kernels import ops


def run(n=2048, d=784):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((n, 1)), jnp.float32)
    v8 = jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)

    _, t_ref = timed(
        lambda: ops.rbf_matvec(x, v1, 2.0, 3.0, impl="reference"),
        warmup=1, repeats=3,
    )
    _, t_chunk = timed(
        lambda: ops.rbf_matvec(x, v1, 2.0, 3.0, impl="chunked", block=512),
        warmup=1, repeats=3,
    )
    _, t_chunk8 = timed(
        lambda: ops.rbf_matvec(x, v8, 2.0, 3.0, impl="chunked", block=512),
        warmup=1, repeats=3,
    )
    flops = 2.0 * n * n * d
    log(f"[kern] rbf n={n} d={d}: reference {t_ref*1e3:.1f}ms "
        f"chunked {t_chunk*1e3:.1f}ms  8-rhs {t_chunk8*1e3:.1f}ms "
        f"(amortization x{8*t_chunk/t_chunk8:.1f})")
    emit("kernel/rbf_reference", t_ref * 1e6, f"gflops={flops/t_ref/1e9:.1f}")
    emit("kernel/rbf_chunked", t_chunk * 1e6, f"gflops={flops/t_chunk/1e9:.1f}")
    emit("kernel/rbf_chunked_8rhs", t_chunk8 * 1e6,
         f"amortization={8*t_chunk/t_chunk8:.2f}")

    # attention: chunked (linear memory) vs reference at prefill shape
    b, h, hkv, s, dh = 1, 8, 2, 2048, 64
    q = jnp.asarray(rng.standard_normal((b, h, s, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, dh)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((b, hkv, s, dh)), jnp.float32)
    _, t_aref = timed(
        lambda: ops.attention(q, k, vv, causal=True, impl="reference"),
        warmup=1, repeats=3,
    )
    _, t_achk = timed(
        lambda: ops.attention(q, k, vv, causal=True, impl="chunked",
                              block_q=256, block_k=512),
        warmup=1, repeats=3,
    )
    log(f"[kern] attention s={s}: reference {t_aref*1e3:.1f}ms "
        f"chunked {t_achk*1e3:.1f}ms")
    emit("kernel/attn_reference", t_aref * 1e6, "")
    emit("kernel/attn_chunked", t_achk * 1e6, "")


if __name__ == "__main__":
    run()
