"""Shared benchmark scaffolding.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
contract) plus a human-readable table to stderr.  GP problem sizes default
to CPU-feasible values; set ``REPRO_BENCH_N`` to scale up.
"""

from __future__ import annotations

import os
import sys
import time

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

BENCH_N = int(os.environ.get("REPRO_BENCH_N", "1200"))


def log(msg: str):
    print(msg, file=sys.stderr, flush=True)


# Every emit() is also recorded here so run.py can dump a machine-readable
# BENCH_solvers.json next to the CSV stream (perf-trajectory tracking).
RESULTS: list = []  # (name, us_per_call, derived)


def emit(name: str, us_per_call: float, derived: str = ""):
    RESULTS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timed(fn, *args, warmup: int = 0, repeats: int = 1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args)
        jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt


def gpc_problem(n: int = None, seed: int = 0, theta: float = 3.0,
                lengthscale: float = 3.0, noise: float = 0.10):
    """The paper's task at CPU scale: synthetic 3-vs-5, RBF kernel."""
    from repro.data import make_infinite_digits
    from repro.gp import RBFKernel

    n = n or BENCH_N
    x, y = make_infinite_digits(n, seed=seed, noise=noise)
    x = jnp.asarray(x, jnp.float64)
    y = jnp.asarray(y, jnp.float64)
    kernel = RBFKernel(theta=theta, lengthscale=lengthscale)
    return x, y, kernel
