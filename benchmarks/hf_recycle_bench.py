"""Hessian-free LM training with recycled def-CG vs plain CG.

The paper's technique at (mini) LM scale: a reduced-config transformer
trained by Gauss-Newton steps; the inner solver either recycles its
deflation basis across steps (def-CG) or starts cold (CG).  Reported:
cumulative CG iterations and loss trajectory — recycling should need
fewer iterations at matched tolerance once the GGN sequence settles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, log
from repro import models
from repro.configs import get_smoke_config
from repro.data import TokenPipeline
from repro.models.layers import lm_head_weights
from repro.optim import HFConfig, hf_init, hf_step, softmax_xent_hvp


def run(arch="qwen1.5-0.5b", steps=8):
    cfg = get_smoke_config(arch)
    params = models.init(jax.random.PRNGKey(0), cfg)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=4, seq_len=32)

    def model_fn(p, batch):
        hidden, _ = models.forward_hidden(p, batch, cfg)
        return hidden @ lm_head_weights(p["embed"], cfg)

    def loss_fn(logits, batch):
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll)

    results = {}
    for recycle in (True, False):
        # tol tight enough that systems need ≫ ell iterations — recycling
        # pays when solves are long (the paper's overhead argument, §2.2).
        # (The recycle path no longer floors solves at ell iterations:
        # partially filled windows extract through the validity mask.)
        hcfg = HFConfig(
            k=4, ell=8, cg_tol=1e-5, cg_maxiter=120,
            init_damping=1.0, recycle=recycle,
        )
        p = jax.tree_util.tree_map(lambda x: x, params)
        st = hf_init(p, hcfg, jax.random.PRNGKey(1))
        iters, losses = [], []
        step_jit = jax.jit(
            lambda pp, ss, bb: hf_step(
                pp, ss, bb, model_fn=model_fn, loss_fn=loss_fn,
                loss_hvp=softmax_xent_hvp, cfg=hcfg,
            )
        )
        for i in range(steps):
            batch = {
                k: jnp.asarray(v) for k, v in pipe.make_batch(i).items()
            }
            p, st, m = step_jit(p, st, batch)
            iters.append(int(m["cg_iterations"]))
            losses.append(float(m["loss"]))
        results[recycle] = (iters, losses)
        tag = "recycled" if recycle else "cold"
        log(f"[hf] {tag:9s} cg-iters/step: {iters}  "
            f"loss {losses[0]:.3f}->{losses[-1]:.3f}")

    rec_it = sum(results[True][0][2:])
    cold_it = sum(results[False][0][2:])
    emit("hf/recycled_iters", 0.0, f"total={rec_it}")
    emit("hf/cold_iters", 0.0, f"total={cold_it}")
    emit("hf/validation", 0.0,
         f"recycled<=cold={rec_it <= cold_it};"
         f"loss_drop={results[True][1][0] - results[True][1][-1]:.3f}")
    return rec_it, cold_it


if __name__ == "__main__":
    run()
