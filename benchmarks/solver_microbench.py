"""Controlled-spectrum solver microbenchmarks (paper §2.1 claims, P5).

A synthetic SPD matrix with k large outlier eigenvalues: deflating them
must reduce the iteration count to ≈ what κ_eff = λ_{n−k}/λ_1 predicts
(CG iterations ∝ √κ), both with *exact* eigenvectors and with the
harmonic-Ritz vectors recycled from a previous solve.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, log, timed
from repro.core import RecycleManager, cg, defcg, from_callable, from_matrix
from repro.core import pytree as pt
from repro.core.solvers import defcg_jit


def iteration_bench(n=4096, k=8, ell=16, iters=64):
    """Wall-clock µs per def-CG(k, ell) iteration at fixed iteration count.

    The operator is a diagonal matvec — one cheap HBM pass — so this
    isolates the *non-matvec* per-iteration vector work the fused flat
    engine targets (the memory-bound regime of the paper: deflation GEMVs,
    AXPYs, reductions, and the (P, AP) recording).  ``tol=0`` +
    ``min_iters`` pins the loop at exactly ``iters`` iterations.
    """
    rng = np.random.default_rng(0)
    d = jnp.asarray(np.linspace(1.0, 100.0, n))
    A = from_callable(lambda v: d * v)
    b = jnp.asarray(rng.standard_normal(n))
    from repro.core import random_orthonormal_basis

    W = random_orthonormal_basis(jax.random.PRNGKey(0), b, k)
    AW = pt.basis_map_vectors(A, W)

    def run():
        return defcg_jit(
            A, b, None, W=W, AW=AW, ell=ell,
            tol=0.0, maxiter=iters, min_iters=iters,
        )

    # min over repeats: the robust estimator on a noisy shared box.
    res, t = timed(run, warmup=2, repeats=1)
    for _ in range(6):
        _, ti = timed(run, repeats=1)
        t = min(t, ti)
    us_per_iter = t * 1e6 / iters
    log(f"[micro] def-CG({k},{ell}) n={n}: {us_per_iter:.2f} us/iter "
        f"({int(res.info.iterations)} iters)")
    emit(f"micro/defcg_iter_n{n}", us_per_iter,
         f"k={k};ell={ell};iters={iters};per_iteration=True")
    return us_per_iter


def run(n=384, k=8):
    rng = np.random.default_rng(0)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    # outliers at 1e3–1e5: resolvable by ~2k Lanczos steps (the regime the
    # paper targets; ℓ must be able to *find* the outliers — see DESIGN §8)
    eigs = np.concatenate(
        [np.linspace(1.0, 10.0, n - k), np.logspace(3, 5, k)]
    )
    A = jnp.asarray((q * eigs) @ q.T)
    b = jnp.asarray(rng.standard_normal(n))
    kappa_full = eigs[-1] / eigs[0]
    kappa_eff = eigs[n - k - 1] / eigs[0]

    plain, t_plain = timed(
        lambda: cg(from_matrix(A), b, tol=1e-10, maxiter=20000), warmup=1
    )
    W_exact = pt.basis_from_vectors(
        [jnp.asarray(q[:, n - k + i]) for i in range(k)]
    )
    exact, t_exact = timed(
        lambda: defcg(from_matrix(A), b, W=W_exact, tol=1e-10, maxiter=20000),
        warmup=1,
    )

    # Recycled: solve once recording, extract Ritz, solve a fresh RHS.
    mgr = RecycleManager(k=k, ell=3 * k, tol=1e-10, maxiter=20000)
    mgr.solve(from_matrix(A), b)
    b2 = jnp.asarray(rng.standard_normal(n))
    rec = mgr.solve(from_matrix(A), b2, reuse_aw=True)
    fresh2 = cg(from_matrix(A), b2, tol=1e-10, maxiter=20000)

    it_p, it_e = int(plain.info.iterations), int(exact.info.iterations)
    it_r, it_f = int(rec.info.iterations), int(fresh2.info.iterations)
    # Classical CG bound: iters ≲ ½·√κ·ln(2/ε).  P5 = the *deflated* count
    # obeys the κ_eff bound (§2.1's prediction), with 1.3× numerics slack.
    bound_eff = 0.5 * np.sqrt(kappa_eff) * np.log(2.0 / 1e-10)
    p5 = it_e <= 1.3 * bound_eff
    log(f"[micro] κ={kappa_full:.1e} κ_eff={kappa_eff:.1e} "
        f"(κ_eff bound: ≤{bound_eff:.0f} its)")
    log(f"[micro] CG {it_p} its | def-CG exact-W {it_e} its "
        f"| def-CG ritz-W {it_r} its (fresh CG on same rhs: {it_f})")
    emit("micro/cg", t_plain * 1e6, f"iters={it_p}")
    emit("micro/defcg_exactW", t_exact * 1e6,
         f"iters={it_e};kappa_eff_bound={bound_eff:.0f};P5_pass={p5}")
    emit("micro/defcg_ritzW", 0.0,
         f"iters={it_r};vs_fresh={it_f};pass={it_r < it_f}")
    iteration_bench()
    return p5 and it_r < it_f


if __name__ == "__main__":
    run()
