"""Benchmark harness — one entry per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV to stdout (human logs on stderr).
Sections:
  table1   — paper Table 1 (Cholesky/CG/def-CG Newton trace)
  fig2/3   — paper Fig 2 (iterations/system) + Fig 3 (residual slopes)
  fig4     — paper Fig 4 (inducing-point cost/precision)
  micro    — controlled-spectrum κ_eff validation (paper §2.1)
  hf       — Hessian-free recycling at mini-LM scale
  kernel   — fused-kernel micro-benchmarks
  roofline — dry-run derived roofline table (if artifacts exist)
"""

from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    from benchmarks.common import emit, log

    sections = []

    def section(name, fn):
        log(f"\n===== {name} =====")
        try:
            fn()
            sections.append((name, "ok"))
        except Exception as exc:  # noqa: BLE001
            traceback.print_exc()
            emit(f"{name}/FAILED", 0.0, repr(exc)[:80])
            sections.append((name, f"FAILED: {exc!r}"))

    from benchmarks import (
        hf_recycle_bench,
        kernel_bench,
        paper_fig4,
        paper_fig23,
        paper_table1,
        solver_microbench,
    )

    section("table1", paper_table1.run)
    section("fig2+3", paper_fig23.run)
    section("fig4", paper_fig4.run)
    section("micro", solver_microbench.run)
    section("hf", hf_recycle_bench.run)
    section("kernel", kernel_bench.run)

    art = os.path.join(os.path.dirname(__file__), "../artifacts/dryrun")
    if os.path.isdir(art) and os.listdir(art):
        def roofline_section():
            from repro.launch import roofline

            table = roofline.table(art, mesh="single")
            log(table)
            n_rows = table.count("\n") - 1
            emit("roofline/cells", 0.0, f"rows={n_rows}")

        section("roofline", roofline_section)

    log("\n===== summary =====")
    for name, status in sections:
        log(f"{name:10s} {status}")
    if any(s != "ok" for _, s in sections):
        sys.exit(1)


if __name__ == "__main__":
    main()
