"""Benchmark harness — one entry per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV to stdout (human logs on stderr)
and writes ``BENCH_solvers.json`` next to this file (repo root parent):
``{"sections": {section: {bench_name: us_per_call}}, "derived": {...}}`` —
the machine-readable perf trajectory, one snapshot per run.
Sections:
  table1   — paper Table 1 (Cholesky/CG/def-CG Newton trace)
  fig2/3   — paper Fig 2 (iterations/system) + Fig 3 (residual slopes)
  fig4     — paper Fig 4 (inducing-point cost/precision)
  micro    — controlled-spectrum κ_eff validation (paper §2.1)
  seq      — sequence engine: extraction+refresh overhead, device scan,
             and the recycle-strategy matrix (iterations × matvecs for
             harmonic/windowed/mgeometry on a drifting GP Newton sequence)
  seq/chaos— fault-tolerance cost: clean-path ladder overhead (must be
             iterate-identical), recovery price under an injected NaN
             system, and the chunked checkpoint driver's overhead
  batch    — multi-tenant solve_batch vs sequential loop (B ∈ {1, 8, 64})
             + the B=1 lowering profile and pool-dispatch fence
  serve    — the repro.serve slot pool: throughput + occupancy vs a naive
             per-tenant loop at B ∈ {8, 64} under Poisson arrivals
  hf       — Hessian-free recycling at mini-LM scale
  lsq      — least-squares axis: recycled vs cold LSMR total A/Aᵀ
             products (win regime AND the flat-spectrum null result)
             + the fused lsmr_update recurrence
  kernel   — fused-kernel micro-benchmarks
  shard    — device-mesh solver: per-iteration def-CG cost at device
             counts {1, 4, 8}, the one-all-reduce-per-while-body pin
             counted from compiled HLO, and a sharded fused RBF matvec
             at n = 1e5 (K never materialized)
  roofline — dry-run derived roofline table (if artifacts exist)
"""

from __future__ import annotations

import json
import os
import sys
import time
import traceback

# The shard section benches mesh sizes up to 8; force 8 host devices
# BEFORE anything imports jax (benchmarks.common does, inside main()).
if "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()


def main() -> None:
    from benchmarks import common
    from benchmarks.common import emit, log

    sections = []
    section_results: dict = {}

    def section(name, fn):
        log(f"\n===== {name} =====")
        mark = len(common.RESULTS)
        try:
            fn()
            sections.append((name, "ok"))
        except Exception as exc:  # noqa: BLE001
            traceback.print_exc()
            emit(f"{name}/FAILED", 0.0, repr(exc)[:80])
            sections.append((name, f"FAILED: {exc!r}"))
        section_results[name] = common.RESULTS[mark:]

    from benchmarks import (
        batch_bench,
        chaos_bench,
        hf_recycle_bench,
        kernel_bench,
        lsq_bench,
        paper_fig4,
        paper_fig23,
        paper_table1,
        seq_bench,
        serve_bench,
        shard_bench,
        solver_microbench,
    )

    section("table1", paper_table1.run)
    section("fig2+3", paper_fig23.run)
    section("fig4", paper_fig4.run)
    section("micro", solver_microbench.run)
    section("seq", seq_bench.run)
    section("seq/chaos", chaos_bench.run)
    section("batch", batch_bench.run)
    section("serve", serve_bench.run)
    section("hf", hf_recycle_bench.run)
    section("lsq", lsq_bench.run)
    section("kernel", kernel_bench.run)
    section("shard", shard_bench.run)

    art = os.path.join(os.path.dirname(__file__), "../artifacts/dryrun")
    if os.path.isdir(art) and os.listdir(art):
        def roofline_section():
            from repro.launch import roofline

            table = roofline.table(art, mesh="single")
            log(table)
            n_rows = table.count("\n") - 1
            emit("roofline/cells", 0.0, f"rows={n_rows}")

        section("roofline", roofline_section)

    payload = {
        "schema": "bench_solvers/v1",
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "bench_n": common.BENCH_N,
        "status": dict(sections),
        "sections": {
            name: {r[0]: r[1] for r in rows}
            for name, rows in section_results.items()
        },
        "derived": {
            r[0]: r[2]
            for rows in section_results.values()
            for r in rows
            if r[2]
        },
    }
    json_path = os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_solvers.json"
    )
    with open(json_path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
    log(f"\nwrote {os.path.normpath(json_path)}")

    log("\n===== summary =====")
    for name, status in sections:
        log(f"{name:10s} {status}")
    if any(s != "ok" for _, s in sections):
        sys.exit(1)


if __name__ == "__main__":
    main()
