"""Chaos benchmarks: what fault tolerance costs (ISSUE 6 acceptance).

Three numbers on one drifting GP Newton sequence:

* ``seq/chaos_clean_overhead`` — the armed recovery ladder vs the same
  scan with recovery disarmed, on a HEALTHY sequence.  The ladder is a
  zero-iteration ``lax.while_loop`` on the clean path, so per-system
  iteration counts must be IDENTICAL (recorded in ``derived``) and the
  wall-clock delta is dispatch noise.
* ``seq/chaos_recovery`` — the same sequence with one persistently
  NaN-poisoned system: the honest price of detection + the full ladder
  climb + retirement, as extra matvecs and extra wall-clock over clean.
* ``seq/chaos_checkpoint_overhead`` — the crash-resumable chunked driver
  (checkpoint every 2 systems, blocking saves) vs the single
  uninterrupted scan.
"""

from __future__ import annotations

import shutil
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, gpc_problem, log, timed
from repro.checkpoint import CheckpointManager
from repro.core import (
    FaultInjectingOperator,
    KernelSystemOperator,
    SolveSpec,
    SolveStatus,
    solve_sequence,
)


def _newton_trace(num_systems=4, seed=0):
    """Drifting H½ Newton-style systems over the chunked Gram matvec."""
    x, _, kernel = gpc_problem(None, seed=seed)
    n = x.shape[0]
    k_mv = kernel.matvec_fn(x, impl="chunked", block=256)
    rng = np.random.default_rng(seed + 1)
    fs = jnp.asarray(rng.standard_normal((num_systems, n)) * 0.5)
    pis = jax.nn.sigmoid(fs)
    sqrt_hs = jnp.sqrt(pis * (1.0 - pis))
    bs = jnp.asarray(rng.standard_normal((num_systems, n)))
    return KernelSystemOperator(k_mv, sqrt_hs), bs, n


def run(num_systems=4, k=8, ell=12, tol=1e-5, maxiter=400):
    ops, bs, n = _newton_trace(num_systems)
    spec = SolveSpec(k=k, ell=ell, tol=tol, maxiter=maxiter)

    def run_clean(armed=True):
        return solve_sequence(ops, bs, spec, divergence_fallback=armed)

    clean, t_clean = timed(run_clean, warmup=1, repeats=3)
    disarmed, t_disarmed = timed(run_clean, False, warmup=1, repeats=3)
    it_armed = [int(v) for v in np.asarray(clean.info.iterations)]
    it_off = [int(v) for v in np.asarray(disarmed.info.iterations)]
    mv_clean = int(np.asarray(clean.info.matvecs).sum())
    unchanged = it_armed == it_off and bool(
        (np.asarray(clean.report.rung) == 0).all()
    )
    us_clean = t_clean * 1e6 / num_systems
    us_off = t_disarmed * 1e6 / num_systems
    log(f"[chaos] clean n={n}: armed {us_clean:.0f} us/system vs disarmed "
        f"{us_off:.0f} (iters unchanged={unchanged}, {it_armed})")
    emit("seq/chaos_clean_overhead", us_clean - us_off,
         f"n={n};iters_unchanged={unchanged};"
         f"iters={'/'.join(map(str, it_armed))};"
         f"armed_us={us_clean:.0f};disarmed_us={us_off:.0f}")

    # One persistently-broken system mid-trace: detection + full ladder
    # + retirement, honestly charged.
    poison = jnp.zeros(num_systems, bs.dtype).at[1].set(jnp.nan)
    faulty_ops = FaultInjectingOperator(ops, poison)

    def run_faulty():
        return solve_sequence(faulty_ops, bs, spec)

    chaos, t_chaos = timed(run_faulty, warmup=1, repeats=3)
    status = [SolveStatus.describe(s) for s in np.asarray(chaos.report.status)]
    rungs = [int(v) for v in np.asarray(chaos.report.rung)]
    mv_chaos = int(np.asarray(chaos.info.matvecs).sum())
    finite = bool(jnp.all(jnp.isfinite(chaos.x)))
    healthy_ok = bool(
        np.asarray(chaos.info.converged)[
            [i for i in range(num_systems) if i != 1]
        ].all()
    )
    us_chaos = t_chaos * 1e6 / num_systems
    log(f"[chaos] poisoned system 1: statuses {status} rungs {rungs}; "
        f"matvecs {mv_clean} -> {mv_chaos} (+{mv_chaos - mv_clean} "
        f"recovery); finite={finite} neighbors_converged={healthy_ok}")
    emit("seq/chaos_recovery", us_chaos - us_clean,
         f"n={n};extra_matvecs={mv_chaos - mv_clean};"
         f"rungs={'/'.join(map(str, rungs))};finite={finite};"
         f"neighbors_converged={healthy_ok}")

    # Crash-resumable chunked driver vs the uninterrupted scan.
    ckpt_dir = tempfile.mkdtemp(prefix="chaos_ckpt_")
    try:
        def run_chunked():
            return solve_sequence(
                ops, bs, spec,
                checkpoint=CheckpointManager(ckpt_dir),
                checkpoint_every=2,
            )

        chunked, t_chunk = timed(run_chunked, warmup=1, repeats=3)
        parity = it_armed == [
            int(v) for v in np.asarray(chunked.info.iterations)
        ]
        us_chunk = t_chunk * 1e6 / num_systems
        log(f"[chaos] chunked+checkpointed {us_chunk:.0f} us/system vs "
            f"scan {us_clean:.0f} (iterate parity={parity})")
        emit("seq/chaos_checkpoint_overhead", us_chunk - us_clean,
             f"n={n};chunk=2;parity={parity};chunked_us={us_chunk:.0f};"
             f"scan_us={us_clean:.0f}")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)

    return unchanged and finite and healthy_ok


if __name__ == "__main__":
    run()
