"""Sequence-engine benchmarks: cross-system extraction+refresh overhead.

The paper's outer loop pays, per system, (a) the harmonic-Ritz extraction
and (b) the ``A⁽ⁱ⁺¹⁾W`` refresh.  PR-1 left both on the eager path: a
host sync on the stored count (``int(rec.stored)`` + static slicing), a
pytree extraction with three separate gram GEMMs, and k *sequential*
(vmapped) matvecs for the refresh.  The sequence engine replaces them
with a masked flat extraction over ONE stacked gram GEMM and a single
multi-RHS operator application (`seq/recycle_refresh`), and scans whole
sequences device-resident (`seq/solve_sequence` vs the host-driven
RecycleManager loop on identical systems).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, gpc_problem, log, timed
from repro.core import KernelSystemOperator, RecycleManager
from repro.core import pytree as pt
from repro.core.recycle import (
    _extract_next_basis_jit,
    harmonic_ritz_jit,
    solve_sequence_jit,
)
from repro.core.solvers import defcg


def _newton_system(n=None, seed=0):
    """A = I + H½KH½ over the fused (chunked on CPU) Gram matvec."""
    x, _, kernel = gpc_problem(n, seed=seed)
    k_mv = kernel.matvec_fn(x, impl="chunked", block=256)
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.standard_normal(x.shape[0]) * 0.5)
    pi = jax.nn.sigmoid(f)
    sqrt_h = jnp.sqrt(pi * (1.0 - pi))
    return KernelSystemOperator(k_mv, sqrt_h), k_mv, x.shape[0]


def refresh_extract_bench(k=8, ell=12):
    """µs per system of the extraction+refresh bookkeeping, old vs new.

    A drifting sequence fills the recording window to *varying* stored
    counts.  The PR-1 path host-syncs on ``int(rec.stored)`` and
    static-slices, so ``harmonic_ritz_jit`` RE-COMPILES for every distinct
    count the sequence produces (plus pays the sync and three separate
    gram GEMMs when warm); the masked flat path compiles ONCE and keeps
    the count on device.  Both paths are warmed on the first system only
    — exactly what a real sequence can do — then swept over 8 systems
    with realistic varying fills.  (The k-matvec vs one-multi-RHS refresh
    half of the overhead is a kernel-level effect quantified by
    ``kernel/rbf_chunked_8rhs``; on CPU XLA batches the vmapped matvecs,
    on TPU the vmapped Pallas kernel re-forms each K-tile k times.)
    """
    a_op, _, n = _newton_system()
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.standard_normal(n))
    res = defcg(a_op, b, tol=1e-5, maxiter=400, ell=ell)
    P, AP = res.recycle.P, res.recycle.AP
    W0 = pt.basis_slice(P, k)  # any full-rank k-basis; shape is what matters
    AW0 = pt.basis_slice(AP, k)
    # window fills of a drifting sequence (first value = warmup system)
    fills = [ell, ell - 3, ell - 1, ell - 5, ell - 2, ell - 4, ell, ell - 6]

    def old_extract(stored):
        # PR-1 RecycleManager._refresh, faithfully: host round-trip on the
        # stored count, static slice (one XLA program per distinct count),
        # pytree extraction with three separate gram GEMMs.
        m = int(stored)
        Z = pt.basis_concat(W0, pt.basis_slice(P, m))
        AZ = pt.basis_concat(AW0, pt.basis_slice(AP, m))
        return harmonic_ritz_jit(Z, AZ, k)

    def new_extract(stored):
        # Masked flat extraction: stored stays a device scalar, one
        # stacked gram GEMM, one compiled program for every fill.
        return _extract_next_basis_jit(W0, AW0, P, AP, stored, k)

    def sweep(fn):
        out = None
        for m in fills[1:]:
            out = fn(jnp.int32(m))
        return out

    # Warm each path on the first system's fill only.
    jax.block_until_ready(old_extract(jnp.int32(fills[0]))[0])
    jax.block_until_ready(new_extract(jnp.int32(fills[0]))[0])
    _, t_old = timed(sweep, old_extract, repeats=1)
    _, t_new = timed(sweep, new_extract, repeats=1)
    us_old = t_old * 1e6 / (len(fills) - 1)
    us_new = t_new * 1e6 / (len(fills) - 1)

    # Steady state (every shape already compiled): the residual sync +
    # three-GEMM dispatch cost of the old path.
    _, t_old_w = timed(sweep, old_extract, warmup=1, repeats=3)
    _, t_new_w = timed(sweep, new_extract, warmup=1, repeats=3)
    us_old_w = t_old_w * 1e6 / (len(fills) - 1)
    us_new_w = t_new_w * 1e6 / (len(fills) - 1)

    log(f"[seq] extraction/system n={n} k={k} ell={ell}: "
        f"{us_old:.0f} -> {us_new:.0f} us over varying fills "
        f"({us_old / us_new:.1f}x; steady-state {us_old_w:.0f} -> "
        f"{us_new_w:.0f} us, {us_old_w / us_new_w:.2f}x)")
    emit("seq/recycle_refresh", us_new,
         f"n={n};k={k};ell={ell};baseline_us={us_old:.0f};"
         f"speedup={us_old / us_new:.1f};"
         f"steady_us={us_new_w:.0f};steady_baseline_us={us_old_w:.0f}")
    return us_old, us_new


def sequence_bench(num_systems=4, k=8, ell=12, tol=1e-5, maxiter=400):
    """Whole-sequence wall-clock: device-resident scan vs host-driven loop
    on an identical drifting Newton sequence (per-system µs).

    Compile and steady state are measured SEPARATELY for both paths: the
    first call of each includes trace+compile (the scan traces one big
    XLA program; the manager traces several smaller ones), and folding
    that one-off cost into a per-system number made the derived
    scan-vs-manager "speedup" depend on how many sequences the process
    would go on to solve.  ``*_cold_us`` is the first-call total;
    the headline numbers are steady-state min-of-3.
    """
    a_op, k_mv, n = _newton_system()
    rng = np.random.default_rng(2)
    fs = jnp.asarray(rng.standard_normal((num_systems, n)) * 0.5)
    pis = jax.nn.sigmoid(fs)
    sqrt_hs = jnp.sqrt(pis * (1.0 - pis))  # drifting H½ across systems
    bs = jnp.asarray(rng.standard_normal((num_systems, n)))
    ops_stacked = KernelSystemOperator(k_mv, sqrt_hs)

    def run_seq():
        return solve_sequence_jit(
            ops_stacked, bs, k=k, ell=ell, tol=tol, maxiter=maxiter
        )

    # Cold = trace + compile + run; steady = min over warm re-runs.
    seq, t_seq_cold = timed(run_seq, repeats=1)
    _, t_seq = timed(run_seq, repeats=1)
    for _ in range(2):
        _, ti = timed(run_seq, repeats=1)
        t_seq = min(t_seq, ti)

    def run_mgr():
        mgr = RecycleManager(k=k, ell=ell, tol=tol, maxiter=maxiter)
        results = []
        for i in range(num_systems):
            a_i = KernelSystemOperator(k_mv, sqrt_hs[i])
            results.append(mgr.solve(a_i, bs[i]))
        return results

    mgr_res, t_mgr_cold = timed(run_mgr, repeats=1)
    _, t_mgr = timed(run_mgr, repeats=1)
    for _ in range(2):
        _, ti = timed(run_mgr, repeats=1)
        t_mgr = min(t_mgr, ti)

    seq_iters = [int(v) for v in np.asarray(seq.info.iterations)]
    mgr_iters = [int(r.info.iterations) for r in mgr_res]
    us_seq = t_seq * 1e6 / num_systems
    us_mgr = t_mgr * 1e6 / num_systems
    log(f"[seq] {num_systems} systems n={n}: scan {us_seq:.0f} us/system "
        f"(cold total {t_seq_cold:.2f} s, iters {seq_iters}) | manager "
        f"loop {us_mgr:.0f} us/system (cold total {t_mgr_cold:.2f} s, "
        f"iters {mgr_iters})")
    emit("seq/solve_sequence", us_seq,
         f"systems={num_systems};iters={'/'.join(map(str, seq_iters))};"
         f"manager_us={us_mgr:.0f};scan_cold_us={t_seq_cold * 1e6:.0f};"
         f"manager_cold_us={t_mgr_cold * 1e6:.0f}")
    # Recycling sanity on the device path: later systems not slower.
    ok = seq_iters[-1] <= seq_iters[0]
    emit("seq/validation", 0.0,
         f"iters_nonincreasing={ok};"
         f"matvecs={'/'.join(map(str, np.asarray(seq.info.matvecs)))}")
    return ok


def strategy_matrix_bench(num_systems=6, k=8, ell=12, tol=1e-5,
                          maxiter=2000, n=None):
    """Iterations × matvecs for every recycle strategy on one drifting GP
    Newton sequence (ISSUE 5's scenario-diversity matrix).

    The sequence is a GENUINE Newton trace (per-iteration H½ from exact
    inner solves), so the drift profile is the paper's: large early
    moves, shrinking as Newton converges.  Expected shape of the matrix:

    * ``harmonic``  — matvecs = iters + 1 + k (the k-matvec exact
      refresh every system);
    * ``windowed``  — matvecs = iters + 2 (+k only where the drift guard
      bought a refresh; on fast-moving early systems it should, on a
      converged tail it should not);
    * ``mgeometry`` — harmonic accounting under a Jacobi preconditioner,
      extraction in the effective M⁻¹A geometry.
    """
    from repro.core import SolveSpec, jacobi, solve_sequence
    from repro.core.strategies import MGeometryHarmonic, WindowedRecombine

    x, y, kernel = gpc_problem(n)
    n = x.shape[0]
    k_mv = kernel.matvec_fn(x, impl="chunked", block=256)

    # Genuine Newton sequence: exact (CG at tight tol) inner solves.
    from repro.core import cg as core_cg
    from repro.gp.laplace import logistic_quantities

    f = jnp.zeros(n, x.dtype)
    shs, bs = [], []
    for _ in range(num_systems):
        _, grad, hdiag = logistic_quantities(f, y)
        sh = jnp.sqrt(hdiag)
        bg = hdiag * f + grad
        b = sh * k_mv(bg)
        shs.append(sh)
        bs.append(b)
        a_i = KernelSystemOperator(k_mv, sh)
        xsol = core_cg(a_i, b, tol=1e-10, maxiter=20 * n).x
        f = k_mv(bg - sh * xsol)
    sqrt_hs = jnp.stack(shs)
    bs2 = jnp.stack(bs)
    ops_stacked = KernelSystemOperator(k_mv, sqrt_hs)
    theta2 = kernel.theta**2  # k(x, x) for the RBF diagonal

    cases = [
        ("harmonic", SolveSpec(k=k, ell=ell, tol=tol, maxiter=maxiter),
         None),
        ("windowed",
         SolveSpec(k=k, ell=ell, tol=tol, maxiter=maxiter,
                   strategy=WindowedRecombine()),
         None),
        ("mgeometry",
         SolveSpec(k=k, ell=ell, tol=tol, maxiter=maxiter,
                   precond="jacobi", strategy=MGeometryHarmonic()),
         lambda op: jacobi(1.0 + op.sqrt_h**2 * theta2)),
    ]
    totals = {}
    for name, spec, make_prec in cases:
        def run_case(spec=spec, make_prec=make_prec):
            return solve_sequence(
                ops_stacked, bs2, spec, make_preconditioner=make_prec
            )

        seq, t = timed(run_case, warmup=1, repeats=1)
        iters = [int(v) for v in np.asarray(seq.info.iterations)]
        mvs = [int(v) for v in np.asarray(seq.info.matvecs)]
        totals[name] = sum(mvs)
        us = t * 1e6 / num_systems
        log(f"[seq] strategy {name:9s}: iters {iters} matvecs {mvs} "
            f"({us:.0f} us/system)")
        emit(f"seq/strategy_matrix_{name}", us,
             f"n={n};systems={num_systems};"
             f"iters={'/'.join(map(str, iters))};"
             f"matvecs={'/'.join(map(str, mvs))};total_matvecs={sum(mvs)}")
    return totals


def run():
    us_old, us_new = refresh_extract_bench()
    ok = sequence_bench()
    strategy_matrix_bench()
    return ok and us_new < us_old


if __name__ == "__main__":
    run()
