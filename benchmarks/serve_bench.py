"""Serving-layer benchmarks: pool throughput + occupancy vs a naive loop.

The ISSUE 8 acceptance scenario: T tenants arrive over a Poisson process,
each bringing a short drifting GP Newton sequence over ONE shared kernel
(the paper's multi-posterior shape, same as ``batch_bench``).  The pool
(:class:`repro.serve.SolveService`, B slots) serves all resident tenants'
next systems with one slot-masked batched step per tick; the baseline
serves every tenant's whole sequence with sequential ``solve_jit`` calls
(per-tenant recycling, B dispatches — exactly what a no-serving-layer
deployment would do).

Emits ``serve/pool_B{8,64}`` with per-system µs, loop comparison,
throughput, and the pool's own occupancy/eviction telemetry (the
``metrics.py`` snapshot is the source — the bench records it rather than
re-deriving).  Both paths are run once untimed first so compile time is
excluded (the pool reuses ONE compiled batched step across ticks — that
is the point).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, gpc_problem, log, timed
from repro.core import KernelSystemOperator, SolveSpec, solve_jit
from repro.serve import SolveService

_KMAT_CACHE: dict = {}


def _shared_kernel(n=None, seed=0):
    x, _, kernel = gpc_problem(n, seed=seed)
    n = x.shape[0]
    if n not in _KMAT_CACHE:
        kmat = jnp.asarray(kernel.gram(x))
        # ONE stable closure per n: the operator's aux data keys the jit
        # cache, so every tenant/run must share this function object.
        _KMAT_CACHE[n] = (kmat, lambda v: _KMAT_CACHE[n][0] @ v)
    return _KMAT_CACHE[n][1], n


def _tenant_traffic(T, num_systems, n, k_mv, seed=0, drift=0.15):
    """Per-tenant drifting Newton sequences + Poisson arrival schedule."""
    rng = np.random.default_rng(seed)
    ops, rhs = {}, {}
    for t in range(T):
        f = rng.standard_normal(n) * 0.5
        systems, bs = [], []
        for _ in range(num_systems):
            pi = 1.0 / (1.0 + np.exp(-f))
            systems.append(
                KernelSystemOperator(k_mv, jnp.asarray(np.sqrt(pi * (1 - pi))))
            )
            bs.append(jnp.asarray(rng.standard_normal(n)))
            f = f + drift * rng.standard_normal(n)  # posterior drifts
        ops[f"t{t}"], rhs[f"t{t}"] = systems, bs
    # Poisson arrivals: ~T/2 tenants per tick until everyone has arrived
    # (ramp-up ticks run the pool below capacity, so arrival density is
    # part of the measured story — occupancy is emitted alongside).
    arrivals, remaining = [], [f"t{t}" for t in range(T)]
    while remaining:
        batch = min(int(rng.poisson(max(T / 2, 1))), len(remaining))
        if batch == 0 and not arrivals:
            batch = 1  # never start with an empty tick
        arrivals.append(remaining[:batch])
        remaining = remaining[batch:]
    return ops, rhs, arrivals


def _run_pool(spec, B, ops, rhs, arrivals):
    svc = SolveService(spec, slots=B)
    tickets = []
    for arriving in arrivals:
        for t in arriving:
            s = svc.session(t)
            for A, b in zip(ops[t], rhs[t]):
                tickets.append(s.submit(A, b))
        svc.tick()
    svc.run_until_idle()
    results = [svc.result(tk, drive=False) for tk in tickets]
    jax.block_until_ready(results[-1].x)
    return svc, results


def _run_loop(spec, ops, rhs):
    outs = []
    for t in ops:
        state = None
        for A, b in zip(ops[t], rhs[t]):
            r = solve_jit(A, b, spec, state)
            state = r.state
            outs.append(r)
    jax.block_until_ready(outs[-1].x)
    return outs


def serve_bench(sizes=(8, 64), tol=1e-5, maxiter=200):
    k_mv, n = _shared_kernel()
    spec = SolveSpec(k=8, ell=12, tol=tol, maxiter=maxiter)
    ok = True
    for B in sizes:
        # Sequences long enough that the full-occupancy steady state
        # dominates the arrival ramp (short sequences would measure the
        # ramp, where a half-empty batched step loses by construction).
        num_systems = 6 if B <= 8 else 3
        ops, rhs, arrivals = _tenant_traffic(B, num_systems, n, k_mv, seed=B)
        total = B * num_systems

        svc, t_pool = timed(
            lambda: _run_pool(spec, B, ops, rhs, arrivals), warmup=1
        )
        _, t_loop = timed(lambda: _run_loop(spec, ops, rhs), warmup=1)

        svc_obj, results = svc
        all_converged = all(r.converged for r in results)
        ok = ok and all_converged
        snap = svc_obj.metrics_snapshot()["pool"]
        us_pool = t_pool * 1e6 / total
        us_loop = t_loop * 1e6 / total
        thr = total / t_pool
        log(
            f"[serve] B={B:3d} n={n} T={B}x{num_systems}: pool "
            f"{us_pool:.0f} us/system ({thr:.1f} sys/s) | loop "
            f"{us_loop:.0f} us/system ({us_loop / us_pool:.2f}x) | "
            f"occupancy={snap['mean_serving_occupancy']:.2f} "
            f"ticks={snap['ticks']} evictions={snap['evictions']} "
            f"converged={all_converged}"
        )
        emit(
            f"serve/pool_B{B}",
            us_pool,
            f"n={n};loop_us={us_loop:.0f};speedup={us_loop / us_pool:.2f};"
            f"throughput_per_s={thr:.1f};"
            f"occupancy={snap['mean_serving_occupancy']:.2f};"
            f"ticks={snap['ticks']};batched_steps={snap['batched_steps']};"
            f"single_steps={snap['single_steps']};"
            f"evictions={snap['evictions']};converged={all_converged}",
        )
    emit("serve/validation", 0.0, f"all_converged={ok}")
    return ok


def run():
    return serve_bench()


if __name__ == "__main__":
    run()
