"""Paper Fig 4 — cost/precision: iterative solvers vs inducing subsets.

Subsets of m ∈ {n/16, n/8, n/4, n/2} data points (the a-priori low-rank
route) against full-data CG / def-CG, measured as relative error of
log p(y|f) vs the exact Cholesky solution over the full training set.
Expected picture (P4): subsets are fast but plateau at a finite error;
the iterative solvers land ~machine-precision at a cost comparable to the
25–50% subsets.
"""

from __future__ import annotations

import time

import jax

from benchmarks.common import emit, gpc_problem, log
from repro.core import RecycleManager
from repro.gp import laplace_gpc, subset_gpc


def run(n=None):
    x, y, kernel = gpc_problem(n)
    n = x.shape[0]
    kd = kernel.gram(x)

    exact = laplace_gpc(
        x, y, kernel, solver="cholesky", newton_tol=1e-3,
        k_dense=kd, dense_matvec=True,
    )
    log(f"[fig4] exact logp={exact.logp:.4f}")

    rows = []
    for m in (n // 16, n // 8, n // 4, n // 2):
        sub = subset_gpc(x, y, kernel, m, key=jax.random.PRNGKey(m))
        rel = abs(sub.logp_full - exact.logp) / abs(exact.logp)
        rows.append(("subset_m=%d" % m, sub.seconds, rel))

    for solver in ("cg", "defcg"):
        recycle = RecycleManager(k=8, ell=12) if solver == "defcg" else None
        t0 = time.perf_counter()
        res = laplace_gpc(
            x, y, kernel, solver=solver, recycle=recycle,
            solver_tol=1e-8, newton_tol=1e-3, k_dense=kd, dense_matvec=True,
        )
        rows.append((solver, time.perf_counter() - t0,
                     abs(res.logp - exact.logp) / abs(exact.logp)))

    log(f"{'method':>16s} {'time[s]':>8s} {'rel err':>10s}")
    for name, t, rel in rows:
        log(f"{name:>16s} {t:8.2f} {rel:10.2e}")
        emit(f"fig4/{name}", t * 1e6, f"rel_err={rel:.3e}")

    # P4: iterative error orders of magnitude below the best subset.
    best_subset = min(rel for name, _, rel in rows if name.startswith("subset"))
    it_err = max(rel for name, _, rel in rows if not name.startswith("subset"))
    gap = best_subset / max(it_err, 1e-16)
    log(f"[fig4] precision gap iterative vs best subset: {gap:.1e}x "
        f"(P4 pass={gap > 1e2})")
    emit("fig4/validation", 0.0, f"precision_gap={gap:.2e};P4_pass={gap > 1e2}")
    return gap


if __name__ == "__main__":
    run()
