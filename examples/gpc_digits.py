"""End-to-end paper reproduction driver: GP classification, Laplace mode.

Runs the paper's §3 experiment (Table 1 columns) on the synthetic
infinite-digits 3-vs-5 task: the Newton loop solves Eq. (10) per iteration
with Cholesky (exact), CG, and def-CG(8,12) with harmonic-Ritz recycling,
reporting log p(y|f), relative error and cumulative solver time.

    PYTHONPATH=src python examples/gpc_digits.py --n 1000
"""

import argparse

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from repro.core import RecycleManager  # noqa: E402
from repro.data import make_infinite_digits  # noqa: E402
from repro.gp import RBFKernel, laplace_gpc  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=800)
    ap.add_argument("--theta", type=float, default=3.0)
    ap.add_argument("--lengthscale", type=float, default=3.0)
    ap.add_argument("--tol", type=float, default=1e-5)
    args = ap.parse_args()

    x, y = make_infinite_digits(args.n, seed=0, noise=0.10)
    x, y = jnp.asarray(x, jnp.float64), jnp.asarray(y, jnp.float64)
    kernel = RBFKernel(theta=args.theta, lengthscale=args.lengthscale)
    kd = kernel.gram(x)

    runs = {}
    for solver in ("cholesky", "cg", "defcg"):
        recycle = RecycleManager(k=8, ell=12) if solver == "defcg" else None
        runs[solver] = laplace_gpc(
            x, y, kernel, solver=solver, recycle=recycle,
            solver_tol=args.tol, newton_tol=1.0,
            k_dense=kd, dense_matvec=True,
        )
        r = runs[solver]
        print(f"{solver:9s} newtons={len(r.trace.logp)} "
              f"logp={r.logp:10.3f} "
              f"solver_time={r.trace.cumulative_time[-1]:6.2f}s "
              f"iters={r.trace.solver_iterations}")

    chol = runs["cholesky"]
    acc = float(jnp.mean(jnp.sign(chol.f) == y))
    cg_it = sum(runs["cg"].trace.solver_iterations[1:])
    def_it = sum(runs["defcg"].trace.solver_iterations[1:])
    print(f"\ntrain accuracy (exact mode): {acc:.3f}")
    print(f"def-CG iteration saving after system 1: {1 - def_it/cg_it:.0%} "
          f"(paper: ~25%)")


if __name__ == "__main__":
    main()
