"""Hessian-free LM training with recycled def-CG — the paper at LM scale.

Trains a reduced transformer by Gauss-Newton steps whose inner SPD solves
recycle their deflation subspace across the step sequence (def-CG), vs the
cold-CG baseline.  Prints per-step CG iterations and loss.

    PYTHONPATH=src python examples/hessian_free_lm.py --steps 10
"""

import argparse

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import get_smoke_config
from repro.data import TokenPipeline
from repro.models.layers import lm_head_weights
from repro.optim import HFConfig, hf_init, hf_step, softmax_xent_hvp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--no-recycle", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = models.init(jax.random.PRNGKey(0), cfg)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=4, seq_len=32)

    def model_fn(p, batch):
        hidden, _ = models.forward_hidden(p, batch, cfg)
        return hidden @ lm_head_weights(p["embed"], cfg)

    def loss_fn(logits, batch):
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(lse - ll)

    hcfg = HFConfig(
        k=4, ell=8, cg_tol=1e-3, cg_maxiter=50,
        init_damping=10.0, recycle=not args.no_recycle,
    )
    state = hf_init(params, hcfg, jax.random.PRNGKey(1))
    step = jax.jit(
        lambda p, s, b: hf_step(
            p, s, b, model_fn=model_fn, loss_fn=loss_fn,
            loss_hvp=softmax_xent_hvp, cfg=hcfg,
        )
    )
    mode = "cold CG" if args.no_recycle else "recycled def-CG"
    print(f"arch={cfg.name} optimizer=Hessian-free ({mode})")
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.make_batch(i).items()}
        params, state, m = step(params, state, batch)
        print(
            f"step {i:3d} loss {float(m['loss']):.4f} "
            f"cg_iters {int(m['cg_iterations']):3d} "
            f"damping {float(m['damping']):.2e} "
            f"accepted {bool(m['accepted'])}"
        )


if __name__ == "__main__":
    main()
