"""Quickstart: Krylov subspace recycling on a sequence of SPD systems.

The paper in 40 lines: solve A⁽ⁱ⁾x = b⁽ⁱ⁾ for a slowly drifting SPD
family; def-CG(k, ell) recycles harmonic-Ritz vectors between systems and
needs fewer iterations than cold CG from system 2 on.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import RecycleManager, cg, from_matrix  # noqa: E402

rng = np.random.default_rng(0)
n, k, ell = 256, 8, 12

# An SPD family with 8 large outlier eigenvalues that drift slowly —
# the situation of a Newton/Gauss-Newton outer loop near convergence.
q, _ = np.linalg.qr(rng.standard_normal((n, n)))
eigs = np.concatenate([np.linspace(1, 8, n - k), np.logspace(3, 5, k)])
base = (q * eigs) @ q.T

mgr = RecycleManager(k=k, ell=ell, tol=1e-8, maxiter=5000)
x_warm = None
print(f"{'system':>6} {'cold CG':>8} {'def-CG':>7} {'saving':>7}")
for i in range(6):
    drift = rng.standard_normal((n, n)) * 0.02
    a_i = jnp.asarray(base + drift @ drift.T)
    b_i = jnp.asarray(rng.standard_normal(n))

    cold = cg(from_matrix(a_i), b_i, tol=1e-8, maxiter=5000)
    res = mgr.solve(from_matrix(a_i), b_i, x0=x_warm)
    x_warm = res.x

    ci, di = int(cold.info.iterations), int(res.info.iterations)
    print(f"{i + 1:>6} {ci:>8} {di:>7} {1 - di / ci:>6.0%}")

    # both solve the same system
    np.testing.assert_allclose(
        np.asarray(a_i @ res.x), np.asarray(b_i),
        atol=1e-6 * float(jnp.linalg.norm(b_i)),
    )

print("\nRitz values tracked by the recycled basis (≈ outlier eigenvalues):")
print(np.sort(np.asarray(mgr.theta))[::-1].round(1))
print("true outliers:", np.sort(eigs[-k:])[::-1].round(1))
