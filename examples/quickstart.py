"""Quickstart: one front door for every solve — SolveSpec + RecycleState.

The paper in ~60 lines, on its own workload: GP classification by
Laplace/Newton, where every Newton iteration is an SPD system
``A⁽ⁱ⁾x = b⁽ⁱ⁾`` drifting slowly with the posterior.  One ``SolveSpec``
configures everything; ``repro.core.solve`` carries a ``RecycleState``
(harmonic-Ritz deflation basis) across systems; composing a Nyström
preconditioner (one sketch of the INVARIANT kernel K, re-bound to each
system's H½ by a rank-r Woodbury solve) cuts iterations further; and
``solve_batch`` serves many tenants' systems in one compiled program.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (  # noqa: E402
    KernelSystemOperator,
    SolveSpec,
    solve_batch,
)
from repro.data import make_infinite_digits  # noqa: E402
from repro.gp import RBFKernel, laplace_gpc  # noqa: E402

# The paper's task at small scale: synthetic 3-vs-5 digits, RBF kernel.
n = 220
x, y = make_infinite_digits(n, seed=7)
x, y = jnp.asarray(x, jnp.float64), jnp.asarray(y, jnp.float64)
kernel = RBFKernel(theta=30.0, lengthscale=32.0)

# ONE spec is the whole solver configuration: def-CG(k, ell) with
# harmonic-Ritz recycling, tolerances, and the preconditioner strategy.
spec = SolveSpec(method="defcg", k=8, ell=12, tol=1e-8, maxiter=2000)

plain = laplace_gpc(x, y, kernel, spec=spec, newton_tol=1e-3)
nys = laplace_gpc(
    x, y, kernel,
    spec=dataclasses.replace(spec, precond="nystrom", precond_rank=40),
    precond_key=jax.random.PRNGKey(0),
    newton_tol=1e-3,
)

print("GP-classification Newton sequence (def-CG iterations per system):")
print(f"{'system':>7} {'recycled':>9} {'+nystrom':>9}")
for i, (a, b) in enumerate(
    zip(plain.trace.solver_iterations, nys.trace.solver_iterations)
):
    print(f"{i + 1:>7} {a:>9} {b:>9}")
print(
    f"log p(y|f): {plain.logp:.4f} (recycled) vs {nys.logp:.4f} "
    f"(preconditioned) — same mode, "
    f"{sum(nys.trace.solver_matvecs)}/{sum(plain.trace.solver_matvecs)} "
    "total matvecs (sketch included)"
)

# --- solve_batch: many tenants, one compiled program --------------------
# B tenants share the kernel (one dataset) but each has its own Newton
# state H½ and right-hand side — e.g. B users' posteriors served at once.
B = 4
rng = np.random.default_rng(0)
kd = kernel.gram(x)
k_mv = lambda v: kd @ v  # noqa: E731
fs = jnp.asarray(rng.standard_normal((B, n)) * 0.5)
pis = jax.nn.sigmoid(fs)
tenants = KernelSystemOperator(k_mv, jnp.sqrt(pis * (1.0 - pis)))
bs = jnp.asarray(rng.standard_normal((B, n)))

batch = solve_batch(tenants, bs, spec)
print(f"\nsolve_batch over {B} tenants (one XLA computation):")
print("  per-tenant iterations:", np.asarray(batch.info.iterations).tolist())
print("  per-tenant converged: ", np.asarray(batch.info.converged).tolist())
assert bool(np.asarray(batch.info.converged).all())

# The returned per-tenant RecycleState warm-starts the next round.
bs2 = jnp.asarray(rng.standard_normal((B, n)))
batch2 = solve_batch(tenants, bs2, spec, batch.state)
print("  next round (recycled): ", np.asarray(batch2.info.iterations).tolist())
assert np.asarray(batch2.info.iterations).mean() < np.asarray(
    batch.info.iterations
).mean()
