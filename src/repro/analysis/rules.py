"""AST lint rules encoding the repo's trace-discipline invariants.

Each rule is a small object with a ``name`` and a
``check(tree, src, relpath, ctx) -> [Violation]`` method; the engine
(``repro.analysis.engine``) runs every rule over every scanned file and
applies suppressions/baselining.  Rules are *static over-approximations*
— when a rule cannot prove a pattern safe it flags it, and the author
answers with an inline ``# repro-lint: disable=rule — why`` that
documents the intent.  The catalogue (see DESIGN.md §10):

``host-sync-in-trace``
    ``int()``/``float()``/``bool()``/``.item()``/``np.*`` reachable from
    jit'd or scanned functions in the traced packages (``core/``,
    ``kernels/``).  Each such call blocks on device→host transfer and —
    when the value feeds Python control flow — bakes it into the trace,
    recompiling per distinct value (the PR 2 ``int(stored)`` bug class).

``kernel-contract``
    Every public op in ``kernels/ops.py`` taking an ``impl`` keyword
    must dispatch all four backends (pallas/interpret/reference/
    chunked), reference a ``ref.py`` oracle, and be exercised by name
    somewhere under ``tests/``.

``pytree-schema``
    Registered pytree classes must define their flatten/unflatten pair,
    and keyed registrations must use literal key names — dynamic keys
    break the name-matched checkpoint restore (the PR 4 leaf-rename
    break class).

``static-spec-frozen``
    Dataclasses used as static jit arguments (``*Spec``/``*Strategy`` or
    ``_register_strategy``-decorated) must be ``frozen=True`` (hashable)
    and must not declare array-typed fields (a leaf in a static arg
    retraces per value — or is simply unhashable).

``cond-batched-pred``
    A ``lax.cond`` whose predicate is traced data without an axis-name
    reduction (``lax.psum``/``pmax``/…) lowers to a per-lane ``select``
    under ``vmap`` — both branches execute for every lane (the PR 4
    solve_batch early-exit regression class).  The rule cannot see
    vmap-ness across call boundaries, so it flags every un-reduced
    traced predicate in the traced packages; genuinely unbatched sites
    carry a suppression explaining why.

``bare-except`` / ``swallowed-thread-exc``
    ``except:`` catches ``KeyboardInterrupt``/``SystemExit``; an
    exception handler inside a ``threading.Thread`` target that neither
    re-raises nor stores the caught exception dies silently with the
    thread (the PR 6 async-checkpoint bug class).
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.engine import LintConfig, Violation

# jax entry points whose function-valued arguments run under trace.
_TRACE_ENTRY_NAMES = {
    "jit",
    "vmap",
    "pmap",
    "scan",
    "while_loop",
    "fori_loop",
    "cond",
    "switch",
    "checkpoint",
    "remat",
    "shard_map",
    "grad",
    "value_and_grad",
    "custom_jvp",
    "custom_vjp",
    "associative_scan",
    "map",
}

# Axis-name collectives that turn a per-lane predicate into an unbatched
# cross-lane one (safe under vmap).
_AXIS_REDUCTIONS = {
    "psum",
    "pmax",
    "pmin",
    "pmean",
    "all_gather",
    "all_to_all",
    "axis_index",
    "psum_scatter",
}

# Host-returning builtins flagged inside traced code.
_HOST_CASTS = {"int", "float", "bool"}
_HOST_METHODS = {"item", "tolist", "block_until_ready"}


@dataclasses.dataclass
class RuleContext:
    """Per-file context handed to each rule by the engine."""

    config: LintConfig
    abspath: str
    src_lines: List[str]

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.src_lines):
            return self.src_lines[lineno - 1]
        return ""

    def make(self, rule: str, node: ast.AST, message: str,
             relpath: str) -> Violation:
        line = getattr(node, "lineno", 0)
        col = getattr(node, "col_offset", 0)
        return Violation(
            rule=rule,
            path=relpath,
            line=line,
            col=col,
            message=message,
            source=self.line_text(line).strip(),
        )


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------


def _attr_name(node: ast.AST) -> Optional[str]:
    """Terminal name of a Name/Attribute chain (``jax.lax.cond`` → ``cond``)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _parent_map(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def _enclosing_function(
    node: ast.AST, parents: Dict[ast.AST, ast.AST]
) -> Optional[ast.AST]:
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parents.get(cur)
    return None


def _in_traced_package(relpath: str, config: LintConfig) -> bool:
    parts = relpath.split("/")
    if not any(p in config.traced_packages for p in parts[:-1]):
        return False
    return not any(a.rstrip("/") in relpath for a in
                   config.host_side_allowlist)


def _is_jit_decorator(dec: ast.AST) -> bool:
    """jit / jax.jit / jax.jit(...) / partial(jax.jit, ...) / checkpoint."""
    target = dec.func if isinstance(dec, ast.Call) else dec
    name = _attr_name(target)
    if name in ("jit", "filter_jit", "checkpoint", "remat"):
        return True
    if name == "partial" and isinstance(dec, ast.Call) and dec.args:
        return _attr_name(dec.args[0]) == "jit"
    return False


def _traced_functions(tree: ast.AST) -> Set[ast.AST]:
    """Over-approximate the set of function defs whose bodies run under
    trace: jit-decorated roots, functions handed to jax transforms, and
    everything they reference by name (transitively).

    Reference propagation is by *name* (bare loads and ``.attr(...)``
    calls) against locally-defined functions — deliberately coarse; a
    false positive costs one documented suppression, a false negative
    hides a retrace bug.
    """
    all_funcs = [
        n
        for n in ast.walk(tree)
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    ]
    by_name: Dict[str, List[ast.AST]] = {}
    for f in all_funcs:
        by_name.setdefault(f.name, []).append(f)

    roots: Set[ast.AST] = set()
    for f in all_funcs:
        if any(_is_jit_decorator(d) for d in f.decorator_list):
            roots.add(f)
    # Functions passed (positionally or by keyword) to a transform call
    # anywhere in the module, e.g. ``solve_jit = jax.jit(solve, ...)`` or
    # ``lax.scan(step, ...)``.
    for call in ast.walk(tree):
        if not isinstance(call, ast.Call):
            continue
        if _attr_name(call.func) not in _TRACE_ENTRY_NAMES:
            continue
        cands = list(call.args) + [kw.value for kw in call.keywords]
        for arg in cands:
            if isinstance(arg, ast.Name) and arg.id in by_name:
                roots.update(by_name[arg.id])

    # Propagate through referenced local names.
    traced: Set[ast.AST] = set()
    work = list(roots)
    while work:
        f = work.pop()
        if f in traced:
            continue
        traced.add(f)
        for node in ast.walk(f):
            name = None
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                name = node.id
            elif isinstance(node, ast.Call):
                name = _attr_name(node.func)
            if name and name in by_name:
                for g in by_name[name]:
                    if g not in traced:
                        work.append(g)
    return traced


# ---------------------------------------------------------------------------
# host-sync-in-trace
# ---------------------------------------------------------------------------


class HostSyncInTrace:
    name = "host-sync-in-trace"

    @staticmethod
    def _static_cast_arg(arg: ast.AST) -> bool:
        """Casts the rule can prove host-static: constants, ``len()``,
        and shape/dtype metadata (``x.shape[0]``, ``x.ndim``)."""
        if isinstance(arg, ast.Constant):
            return True
        if isinstance(arg, ast.Call) and _attr_name(arg.func) == "len":
            return True
        node = arg
        if isinstance(node, ast.Subscript):
            node = node.value
        if isinstance(node, ast.Attribute) and node.attr in (
            "shape", "ndim", "size", "dtype",
        ):
            return True
        return False

    def check(self, tree, src, relpath, ctx) -> List[Violation]:
        if not _in_traced_package(relpath, ctx.config):
            return []
        out: List[Violation] = []
        seen: Set[Tuple[int, int]] = set()

        def flag(node, msg):
            key = (node.lineno, node.col_offset)
            if key not in seen:
                seen.add(key)
                out.append(ctx.make(self.name, node, msg, relpath))

        for fn in _traced_functions(tree):
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    fname = _attr_name(node.func)
                    if (
                        isinstance(node.func, ast.Name)
                        and fname in _HOST_CASTS
                        and node.args
                        and not self._static_cast_arg(node.args[0])
                    ):
                        flag(
                            node,
                            f"`{fname}()` on traced data forces a host "
                            "sync and bakes the value into the trace "
                            "(retraces per distinct value); use jnp "
                            "ops, or suppress if the argument is a "
                            "static Python scalar",
                        )
                    elif (
                        isinstance(node.func, ast.Attribute)
                        and node.func.attr in _HOST_METHODS
                    ):
                        flag(
                            node,
                            f"`.{node.func.attr}()` forces a device→"
                            "host transfer inside traced code",
                        )
                elif isinstance(node, ast.Attribute):
                    if (
                        isinstance(node.value, ast.Name)
                        and node.value.id in ("np", "numpy")
                    ):
                        flag(
                            node,
                            f"`{node.value.id}.{node.attr}` is host-side "
                            "numpy inside traced code; use jnp (or "
                            "io_callback for intentional host hops)",
                        )
        return out


# ---------------------------------------------------------------------------
# kernel-contract
# ---------------------------------------------------------------------------


class KernelContract:
    name = "kernel-contract"

    @staticmethod
    def _ref_defs(ops_abspath: str, ref_module: str) -> Set[str]:
        ref_path = os.path.join(os.path.dirname(ops_abspath),
                                ref_module + ".py")
        if not os.path.exists(ref_path):
            return set()
        with open(ref_path) as f:
            try:
                ref_tree = ast.parse(f.read())
            except SyntaxError:
                return set()
        return {
            n.name
            for n in ref_tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }

    @staticmethod
    def _tests_corpus(ops_abspath: str, tests_dir: str) -> str:
        """Concatenated text of tests/*.py, found by walking up from the
        ops module (returns "" when no tests directory exists — fixture
        trees in unit tests)."""
        cur = os.path.dirname(ops_abspath)
        for _ in range(8):
            cand = os.path.join(cur, tests_dir)
            if os.path.isdir(cand):
                chunks = []
                for name in sorted(os.listdir(cand)):
                    if name.endswith(".py"):
                        with open(os.path.join(cand, name)) as f:
                            chunks.append(f.read())
                return "\n".join(chunks)
            nxt = os.path.dirname(cur)
            if nxt == cur:
                break
            cur = nxt
        return ""

    def check(self, tree, src, relpath, ctx) -> List[Violation]:
        cfg = ctx.config
        if not relpath.endswith(cfg.ops_module):
            return []
        out: List[Violation] = []
        ref_defs = self._ref_defs(ctx.abspath, cfg.ref_module_name)
        tests_text = self._tests_corpus(ctx.abspath, cfg.tests_dir_name)
        for fn in tree.body:
            if not isinstance(fn, ast.FunctionDef):
                continue
            if fn.name.startswith("_"):
                continue
            kwonly = {a.arg for a in fn.args.kwonlyargs}
            if "impl" not in kwonly:
                continue  # not under the contract (e.g. decode steps)
            strings = {
                n.value
                for n in ast.walk(fn)
                if isinstance(n, ast.Constant) and isinstance(n.value, str)
            }
            missing = [i for i in cfg.kernel_impls if i not in strings]
            if missing:
                out.append(ctx.make(
                    self.name, fn,
                    f"op `{fn.name}` does not dispatch impl(s) "
                    f"{missing}: the contract requires all of "
                    f"{list(cfg.kernel_impls)}",
                    relpath,
                ))
            orefs = {
                n.attr
                for n in ast.walk(fn)
                if isinstance(n, ast.Attribute)
                and isinstance(n.value, ast.Name)
                and n.value.id == cfg.ref_module_name
            }
            if not orefs:
                out.append(ctx.make(
                    self.name, fn,
                    f"op `{fn.name}` never references a "
                    f"`{cfg.ref_module_name}.*` oracle",
                    relpath,
                ))
            else:
                absent = sorted(o for o in orefs if o not in ref_defs)
                if absent:
                    out.append(ctx.make(
                        self.name, fn,
                        f"op `{fn.name}` references oracle(s) {absent} "
                        f"not defined in {cfg.ref_module_name}.py",
                        relpath,
                    ))
            if tests_text and not re.search(
                rf"\b{re.escape(fn.name)}\b", tests_text
            ):
                out.append(ctx.make(
                    self.name, fn,
                    f"op `{fn.name}` has no parity test mentioning it "
                    f"under {cfg.tests_dir_name}/",
                    relpath,
                ))
        return out


# ---------------------------------------------------------------------------
# pytree-schema
# ---------------------------------------------------------------------------

_PYTREE_DECORATORS = {
    "register_pytree_node_class": ("tree_flatten", "tree_unflatten"),
    "register_pytree_with_keys_class": (
        "tree_flatten_with_keys", "tree_unflatten",
    ),
}
_KEY_CTORS = {"GetAttrKey", "DictKey", "SequenceKey", "FlattenedIndexKey"}


class PytreeSchema:
    name = "pytree-schema"

    def check(self, tree, src, relpath, ctx) -> List[Violation]:
        out: List[Violation] = []
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            reg = None
            for dec in cls.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                dn = _attr_name(target)
                if dn in _PYTREE_DECORATORS:
                    reg = dn
            if reg is None:
                continue
            methods = {
                n.name for n in cls.body if isinstance(n, ast.FunctionDef)
            }
            for required in _PYTREE_DECORATORS[reg]:
                if required not in methods:
                    out.append(ctx.make(
                        self.name, cls,
                        f"pytree class `{cls.name}` ({reg}) is missing "
                        f"`{required}`",
                        relpath,
                    ))
            if reg == "register_pytree_with_keys_class":
                for node in ast.walk(cls):
                    if (
                        isinstance(node, ast.Call)
                        and _attr_name(node.func) in _KEY_CTORS
                        and node.args
                        and not isinstance(node.args[0], ast.Constant)
                    ):
                        out.append(ctx.make(
                            self.name, node,
                            f"`{cls.name}` builds a pytree key from a "
                            "non-literal: keys must be stable string "
                            "constants or checkpoint name-matching "
                            "breaks silently",
                            relpath,
                        ))
        return out


# ---------------------------------------------------------------------------
# static-spec-frozen
# ---------------------------------------------------------------------------


class StaticSpecFrozen:
    name = "static-spec-frozen"

    @staticmethod
    def _dataclass_dec(cls: ast.ClassDef) -> Optional[ast.AST]:
        for dec in cls.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if _attr_name(target) == "dataclass":
                return dec
        return None

    def check(self, tree, src, relpath, ctx) -> List[Violation]:
        cfg = ctx.config
        pat = re.compile(cfg.static_spec_pattern)
        out: List[Violation] = []
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            dec_names = {
                _attr_name(d.func if isinstance(d, ast.Call) else d)
                for d in cls.decorator_list
            }
            is_spec = bool(pat.match(cls.name)) or bool(
                dec_names & set(cfg.static_spec_decorators)
            )
            dc = self._dataclass_dec(cls)
            if not is_spec or dc is None:
                continue
            frozen = False
            if isinstance(dc, ast.Call):
                for kw in dc.keywords:
                    if (
                        kw.arg == "frozen"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True
                    ):
                        frozen = True
            if not frozen:
                out.append(ctx.make(
                    self.name, cls,
                    f"static-spec dataclass `{cls.name}` must be "
                    "@dataclass(frozen=True): static jit args are "
                    "hashed, and mutation after first use silently "
                    "desyncs the compile cache",
                    relpath,
                ))
            for stmt in cls.body:
                if not isinstance(stmt, ast.AnnAssign):
                    continue
                try:
                    ann = ast.unparse(stmt.annotation)
                except Exception:
                    continue
                if re.search(r"\b(jax\.)?Array\b|\bndarray\b|jnp\.", ann):
                    out.append(ctx.make(
                        self.name, stmt,
                        f"`{cls.name}.{ast.unparse(stmt.target)}` is "
                        f"array-typed ({ann}): static jit args must be "
                        "leaf-less (arrays are unhashable and would "
                        "retrace per value) — carry arrays in the "
                        "pytree side (e.g. RecycleState)",
                        relpath,
                    ))
        return out


# ---------------------------------------------------------------------------
# cond-batched-pred
# ---------------------------------------------------------------------------


class CondBatchedPred:
    name = "cond-batched-pred"

    @staticmethod
    def _has_reduction(node: ast.AST) -> bool:
        for n in ast.walk(node):
            if isinstance(n, ast.Call) and _attr_name(n.func) in (
                _AXIS_REDUCTIONS
            ):
                return True
        return False

    def _pred_is_reduced(
        self,
        pred: ast.AST,
        fn: Optional[ast.AST],
    ) -> bool:
        """True when the predicate — or any assignment in its intra-
        function dataflow chain — applies an axis-name collective."""
        if self._has_reduction(pred):
            return True
        names = {
            n.id
            for n in ast.walk(pred)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
        }
        if not names or fn is None:
            return bool(names) is False  # constant predicate: fine
        assigns: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    for t in ast.walk(tgt):
                        if isinstance(t, ast.Name):
                            assigns.setdefault(t.id, []).append(node.value)
            elif isinstance(node, ast.AugAssign) and isinstance(
                node.target, ast.Name
            ):
                assigns.setdefault(node.target.id, []).append(node.value)
        seen: Set[str] = set()
        work = list(names)
        while work:
            nm = work.pop()
            if nm in seen:
                continue
            seen.add(nm)
            for rhs in assigns.get(nm, ()):
                if self._has_reduction(rhs):
                    return True
                for n in ast.walk(rhs):
                    if isinstance(n, ast.Name) and n.id not in seen:
                        work.append(n.id)
        return False

    def check(self, tree, src, relpath, ctx) -> List[Violation]:
        if not _in_traced_package(relpath, ctx.config):
            return []
        parents = _parent_map(tree)
        out: List[Violation] = []
        for call in ast.walk(tree):
            if not isinstance(call, ast.Call):
                continue
            func = call.func
            if not (
                isinstance(func, ast.Attribute)
                and func.attr == "cond"
                and _attr_name(func.value) == "lax"
            ):
                continue
            if not call.args:
                continue
            pred = call.args[0]
            if isinstance(pred, ast.Constant):
                continue
            fn = _enclosing_function(call, parents)
            if not self._pred_is_reduced(pred, fn):
                out.append(ctx.make(
                    self.name, call,
                    "`lax.cond` predicate has no axis-name reduction: "
                    "under vmap it lowers to a per-lane `select` and "
                    "BOTH branches run for every lane — reduce with "
                    "`lax.psum(pred, axis) > 0` (see solve_batch), or "
                    "suppress if this site can never be vmapped",
                    relpath,
                ))
        return out


# ---------------------------------------------------------------------------
# bare-except / swallowed-thread-exc
# ---------------------------------------------------------------------------


class BareExcept:
    name = "bare-except"

    def check(self, tree, src, relpath, ctx) -> List[Violation]:
        out = []
        for node in ast.walk(tree):
            if isinstance(node, ast.ExceptHandler) and node.type is None:
                out.append(ctx.make(
                    self.name, node,
                    "bare `except:` also catches KeyboardInterrupt/"
                    "SystemExit; catch Exception (or narrower)",
                    relpath,
                ))
        return out


class SwallowedThreadExc:
    name = "swallowed-thread-exc"

    @staticmethod
    def _handler_propagates(handler: ast.ExceptHandler) -> bool:
        """A handler is fine if it re-raises or stores/uses the caught
        exception (``self._err = exc`` keeps it observable)."""
        for n in ast.walk(handler):
            if isinstance(n, ast.Raise):
                return True
            if (
                handler.name
                and isinstance(n, ast.Name)
                and n.id == handler.name
                and isinstance(n.ctx, ast.Load)
            ):
                return True
        return False

    def check(self, tree, src, relpath, ctx) -> List[Violation]:
        funcs = {
            n.name: n
            for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        }
        targets: Set[str] = set()
        for call in ast.walk(tree):
            if not isinstance(call, ast.Call):
                continue
            if _attr_name(call.func) != "Thread":
                continue
            for kw in call.keywords:
                if kw.arg == "target" and isinstance(kw.value, ast.Name):
                    targets.add(kw.value.id)
        out = []
        for name in sorted(targets):
            fn = funcs.get(name)
            if fn is None:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.ExceptHandler):
                    if not self._handler_propagates(node):
                        out.append(ctx.make(
                            self.name, node,
                            f"thread target `{name}` swallows the "
                            "exception: a dead worker looks like a "
                            "successful one — store it for the joiner "
                            "to re-raise (see checkpoint.manager) or "
                            "re-raise",
                            relpath,
                        ))
        return out


ALL_RULES = [
    HostSyncInTrace(),
    KernelContract(),
    PytreeSchema(),
    StaticSpecFrozen(),
    CondBatchedPred(),
    BareExcept(),
    SwallowedThreadExc(),
]

RULE_NAMES = [r.name for r in ALL_RULES]
