"""repro.analysis — the repo's static analyzer + executable trace-audit gate.

Every hard bug class fixed in PRs 2–6 is statically (or cheaply
dynamically) detectable: host syncs hiding in traced code, ``lax.cond``
predicates that silently become per-lane ``select`` under vmap, pytree
leaf renames that orphan checkpoints, exceptions swallowed in daemon
threads.  This package turns those reviewer-head invariants into a
checked-in gate:

* AST lint rules (``repro.analysis.rules``) with inline suppressions
  (``# repro-lint: disable=rule — why``) and a grandfathering baseline
  (``analysis/baseline.json`` at the repo root).
* An executable schema check (``repro.analysis.schema``) pinning the
  ``RecycleState``/``SolveSpec``/``SolveReport`` leaf-and-field
  manifests against ``schema_manifest.json``.
* A trace audit (``repro.analysis.trace_audit``) that jits the three
  front doors under ``jax.check_tracer_leaks``, asserts compile budgets,
  and greps the lowered jaxprs for forbidden host callbacks.

CLI::

    python -m repro.analysis src/              # AST rules only (fast)
    python -m repro.analysis --all src/        # + schema + trace audit
    python -m repro.analysis --update-baseline src/
    python -m repro.analysis --update-schema

Exit code 0 iff no *new* violations (suppressed and baselined findings
are reported but do not fail).  See DESIGN.md §10 for the rule
catalogue and the policy on suppressions vs baseline entries.
"""

from repro.analysis.engine import (
    LintConfig,
    LintResult,
    Violation,
    load_baseline,
    run_lint,
    write_baseline,
)
from repro.analysis.rules import ALL_RULES, RULE_NAMES

__all__ = [
    "ALL_RULES",
    "LintConfig",
    "LintResult",
    "RULE_NAMES",
    "Violation",
    "load_baseline",
    "run_lint",
    "write_baseline",
]
