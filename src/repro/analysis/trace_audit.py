"""Executable trace audit: DESIGN.md §6's compile-time discipline as a gate.

Three properties of the solver front doors (``solve`` /
``solve_sequence`` / ``solve_batch``) are load-bearing for serving and
cannot be checked statically, so this module *runs* them (tiny problems,
n≈24, a few iterations) and turns every breach into a ``trace-audit``
:class:`~repro.analysis.engine.Violation`:

1. **No tracer leaks.** Every audit runs under
   ``jax.check_tracer_leaks()`` — a traced value escaping into Python
   state raises instead of silently capturing a stale tracer.

2. **Retrace budgets.** A spec-identical repeat call (same shapes,
   dtypes, static spec — new values) must hit the jit cache: ≤1 trace
   for ``solve``/``solve_sequence``/``solve_batch``, measured on fresh
   ``jax.jit`` wrappers via ``_cache_size()``.  The chunked
   (checkpointed) ``solve_sequence`` is a host loop over eager engine
   scans; its budget is ≤2 ``scan`` compilations per run shape (the
   full-chunk program + one trailing partial chunk, the PR 6 claim) and
   **zero** new XLA compilations on an identical re-run, measured by
   capturing ``jax.log_compiles()`` output.

3. **No forbidden host primitives.** The lowered jaxpr of each clean
   path must not contain ``io_callback`` / ``pure_callback`` /
   ``debug_callback`` — a host callback in the hot loop serializes the
   device stream every iteration.  (Intentional host hops — fault
   instrumentation, checkpointing — live OUTSIDE these clean paths.)

Budget: the whole audit is a handful of n=24 CPU solves — seconds, not
minutes — so CI runs it on every push (the ``lint`` job).
"""

from __future__ import annotations

import contextlib
import logging
import re
import tempfile
from typing import Iterator, List, Tuple

from repro.analysis.engine import Violation

FORBIDDEN_PRIMITIVES = ("io_callback", "pure_callback", "debug_callback")


def fresh_jit(fn, **jit_kwargs):
    """``jax.jit`` with a PRIVATE trace cache.

    jit's tracing cache is keyed on the underlying function object and
    shared across every wrapper of it — ``jax.jit(api.solve)._cache_size()``
    counts traces from *all* callers of ``solve`` in the process,
    including module-level ``solve_jit`` and other tests.  A fresh
    forwarding wrapper (``functools.wraps`` preserves the signature, so
    ``static_argnames`` still resolves) isolates the measurement.
    """
    import functools

    import jax

    @functools.wraps(fn)
    def isolated(*args, **kwargs):
        return fn(*args, **kwargs)

    return jax.jit(isolated, **jit_kwargs)

# One record per actual XLA compilation (jax._src.interpreters.pxla).
_COMPILE_RE = re.compile(r"^Compiling ([\w<>\[\]\.-]+) with global shapes")


def _violation(message: str, source: str = "") -> Violation:
    return Violation(
        rule="trace-audit", path="trace_audit", line=0, col=0,
        message=message, source=source,
    )


# ---------------------------------------------------------------------------
# compile-event capture
# ---------------------------------------------------------------------------


class _CompileCapture(logging.Handler):
    """Collects the names of XLA compilations logged by
    ``jax.log_compiles()``."""

    def __init__(self):
        super().__init__(level=logging.WARNING)
        self.names: List[str] = []

    def emit(self, record):
        m = _COMPILE_RE.match(record.getMessage())
        if m:
            self.names.append(m.group(1))


@contextlib.contextmanager
def count_compiles() -> Iterator[_CompileCapture]:
    """Context manager yielding a live list of XLA compile events."""
    import jax

    cap = _CompileCapture()
    logger = logging.getLogger("jax")
    old_level = logger.level
    logger.addHandler(cap)
    if not logger.isEnabledFor(logging.WARNING):
        logger.setLevel(logging.WARNING)
    try:
        with jax.log_compiles():
            yield cap
    finally:
        logger.removeHandler(cap)
        logger.setLevel(old_level)


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------


def _subjaxprs(value):
    import jax.core as jcore

    if isinstance(value, jcore.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jcore.Jaxpr):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _subjaxprs(v)


def iter_primitives(jaxpr) -> Iterator[str]:
    """Every primitive name in ``jaxpr``, recursing into sub-jaxprs
    (scan/while/cond bodies, pjit calls)."""
    for eqn in jaxpr.eqns:
        yield eqn.primitive.name
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_primitives(sub)


def find_forbidden(closed_jaxpr) -> List[str]:
    hits = [
        p
        for p in iter_primitives(closed_jaxpr.jaxpr)
        if any(p.startswith(f) for f in FORBIDDEN_PRIMITIVES)
    ]
    return sorted(set(hits))


# ---------------------------------------------------------------------------
# tiny audit problems (pure jnp; deterministic)
# ---------------------------------------------------------------------------


def _audit_problem(n: int = 24, num: int = 5, seed: int = 0):
    """A short drifting SPD sequence — small enough that the full audit
    is a few seconds of CPU."""
    import jax
    import jax.numpy as jnp

    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (n, n)) / jnp.sqrt(n)
    base = q @ q.T + jnp.eye(n)
    shifts = 0.05 * jnp.arange(num, dtype=base.dtype)
    mats = base[None] + shifts[:, None, None] * jnp.eye(n)[None]
    bs = jax.random.normal(jax.random.fold_in(key, 1), (num, n))
    return mats, bs


def _audit_spec():
    from repro.core import SolveSpec

    return SolveSpec(k=3, ell=4, tol=1e-6, maxiter=40)


# ---------------------------------------------------------------------------
# the three audits
# ---------------------------------------------------------------------------


def audit_forbidden_primitives() -> List[Violation]:
    """Lower each front door's clean path and scan the jaxpr."""
    import jax

    from repro.core import RecycleState, from_matrix
    from repro.core import api as api_mod

    spec = _audit_spec()
    mats, bs = _audit_problem()
    n = bs.shape[-1]
    state0 = RecycleState.zeros(spec.k, n, bs.dtype)
    out: List[Violation] = []

    def check(name, fn, *args):
        with jax.check_tracer_leaks():
            jaxpr = jax.make_jaxpr(fn)(*args)
        hits = find_forbidden(jaxpr)
        if hits:
            out.append(_violation(
                f"front door `{name}` lowers forbidden host "
                f"primitive(s) {hits}: a host callback in the hot loop "
                "serializes the device stream",
                source=name,
            ))

    check(
        "solve",
        lambda A, b, st: api_mod.solve(from_matrix(A), b, spec, st),
        mats[0], bs[0], state0,
    )
    check(
        "solve_sequence",
        lambda ms, vs, st: api_mod.solve_sequence(
            ms, vs, spec, st, make_operator=from_matrix
        ),
        mats, bs, state0,
    )
    import jax.numpy as jnp

    bstate = jax.tree_util.tree_map(
        lambda l: jnp.stack([l, l]), state0
    )
    check(
        "solve_batch",
        lambda ms, vs, st: api_mod.solve_batch(
            ms, vs, spec, st, make_operator=from_matrix
        ),
        mats[:2], bs[:2], bstate,
    )
    return out


def audit_retrace_budgets() -> List[Violation]:
    """Spec-identical repeats must not retrace (≤1 cached trace each)."""
    import jax

    from repro.core import RecycleState, from_matrix
    from repro.core import api as api_mod

    spec = _audit_spec()
    mats, bs = _audit_problem()
    n = bs.shape[-1]
    state0 = RecycleState.zeros(spec.k, n, bs.dtype)
    out: List[Violation] = []

    # NOTE: no `jax.check_tracer_leaks()` here — leak checking re-traces
    # every call (it disables the jit cache), which would make any
    # compile-count measurement meaningless.  Leak checking runs in
    # audit_forbidden_primitives, where only the lowering matters.
    def budget(name, fn, budget_traces, calls, **kwargs):
        for args in calls:
            fn(*args, **kwargs)
        traces = fn._cache_size()
        if traces > budget_traces:
            out.append(_violation(
                f"`{name}` traced {traces}× across spec-identical calls "
                f"(budget {budget_traces}): something in the call "
                "signature is not cache-stable",
                source=name,
            ))

    solve_f = fresh_jit(
        api_mod.solve,
        static_argnames=("spec", "record_residuals", "batch_axis"),
    )
    budget(
        "solve", solve_f, 1,
        [
            (from_matrix(mats[0]), bs[0], spec, state0),
            (from_matrix(mats[1]), bs[1], spec, state0),
        ],
    )

    seq_f = jax.jit(
        lambda ms, vs, st: api_mod.solve_sequence(
            ms, vs, spec, st, make_operator=from_matrix
        )
    )
    budget(
        "solve_sequence", seq_f, 1,
        [(mats, bs, state0), (mats + 0.01, bs + 1.0, state0)],
    )

    import jax.numpy as jnp

    bstate = jax.tree_util.tree_map(lambda l: jnp.stack([l, l]), state0)
    batch_f = fresh_jit(
        api_mod.solve_batch,
        static_argnames=(
            "spec", "make_operator", "make_preconditioner",
            "sequence", "carry_x",
        ),
    )
    budget(
        "solve_batch", batch_f, 1,
        [
            (mats[:2], bs[:2], spec, bstate),
            (mats[1:3], bs[1:3], spec, bstate),
        ],
        make_operator=from_matrix,
    )
    return out


def audit_chunked_sequence() -> List[Violation]:
    """The chunked (crash-resumable) ``solve_sequence`` budget:

    * ≤2 ``scan`` compilations on a cold run (full chunk + trailing
      partial chunk — the PR 6 claim), and
    * ZERO new XLA compilations on a spec/shape-identical re-run.
    """
    import jax

    from repro.checkpoint import CheckpointManager
    from repro.core import from_matrix
    from repro.core import api as api_mod

    spec = _audit_spec()
    mats, bs = _audit_problem(num=5)
    out: List[Violation] = []

    def run(directory):
        return api_mod.solve_sequence(
            mats, bs, spec, None,
            make_operator=from_matrix,
            checkpoint=CheckpointManager(directory),
            checkpoint_every=2,
        )

    # No leak-check context here either (it would defeat the caches this
    # audit exists to measure) — see audit_retrace_budgets.
    with tempfile.TemporaryDirectory() as d1:
        with count_compiles() as cold:
            run(d1)
    # The chunk engine is one module-level jit (`_solve_sequence_spec`);
    # count its compilations plus any bare eager scans that leak out.
    scans = [
        n for n in cold.names if n == "scan" or "solve_sequence" in n
    ]
    if len(scans) > 2:
        out.append(_violation(
            f"chunked solve_sequence compiled {len(scans)} scan "
            "programs on a cold run (budget 2: full chunk + "
            "trailing partial)",
            source="solve_sequence[chunked] cold",
        ))
    with tempfile.TemporaryDirectory() as d2:
        with count_compiles() as warm:
            run(d2)
    if warm.names:
        out.append(_violation(
            f"chunked solve_sequence re-run recompiled "
            f"{len(warm.names)} program(s) ({sorted(set(warm.names))}) "
            "despite identical spec/shapes: the host loop is "
            "breaking XLA's eager cache",
            source="solve_sequence[chunked] warm",
        ))
    return out


def run_trace_audit() -> Tuple[List[Violation], List[str]]:
    """Run all three audits; returns (violations, progress lines)."""
    lines = []
    out: List[Violation] = []
    for name, fn in (
        ("forbidden-primitives", audit_forbidden_primitives),
        ("retrace-budgets", audit_retrace_budgets),
        ("chunked-sequence", audit_chunked_sequence),
    ):
        vs = fn()
        lines.append(
            f"trace-audit/{name}: {'OK' if not vs else f'{len(vs)} violation(s)'}"
        )
        out.extend(vs)
    return out, lines
