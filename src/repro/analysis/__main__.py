"""CLI: ``python -m repro.analysis [paths...]``.

Runs the AST rules (always), plus the schema manifest check and the
executable trace audit with ``--all`` (what CI's ``lint`` job runs).
Exit code 0 iff no NEW violations — inline-suppressed and baselined
findings are summarized but do not fail the gate.
"""

from __future__ import annotations

import argparse
import os
import sys

from repro.analysis import engine

DEFAULT_BASELINE = os.path.join("analysis", "baseline.json")


def _find_baseline(explicit: str | None) -> str | None:
    """``--baseline`` wins; otherwise walk up from CWD for the repo's
    ``analysis/baseline.json`` (so the CLI works from subdirectories)."""
    if explicit is not None:
        return explicit
    cur = os.getcwd()
    for _ in range(8):
        cand = os.path.join(cur, DEFAULT_BASELINE)
        if os.path.exists(cand):
            return cand
        nxt = os.path.dirname(cur)
        if nxt == cur:
            break
        cur = nxt
    return None


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro static analyzer + trace-audit gate "
                    "(DESIGN.md §10)",
    )
    parser.add_argument(
        "paths", nargs="*", default=["src"],
        help="files/directories to lint (default: src)",
    )
    parser.add_argument(
        "--all", action="store_true",
        help="also run the schema manifest check and the executable "
             "trace audit (imports jax and runs tiny solves)",
    )
    parser.add_argument(
        "--trace-audit", action="store_true",
        help="run only the executable trace audit (no AST lint)",
    )
    parser.add_argument(
        "--baseline", default=None,
        help=f"baseline file (default: nearest {DEFAULT_BASELINE})",
    )
    parser.add_argument(
        "--update-baseline", action="store_true",
        help="rewrite the baseline to grandfather every current finding "
             "(use sparingly: prefer fixing or suppressing inline)",
    )
    parser.add_argument(
        "--update-schema", action="store_true",
        help="regenerate schema_manifest.json from the live classes "
             "(after bumping checkpoint SCHEMA_VERSION)",
    )
    parser.add_argument(
        "-q", "--quiet", action="store_true",
        help="print only the final summary line",
    )
    args = parser.parse_args(argv)

    if args.update_schema:
        from repro.analysis import schema

        path = schema.write_manifest()
        print(f"wrote {path}")
        if not (args.all or args.trace_audit or args.update_baseline):
            return 0

    violations: list = []
    lines: list = []

    if not args.trace_audit:
        paths = [p for p in (args.paths or ["src"])]
        baseline_path = _find_baseline(args.baseline)
        baseline = engine.load_baseline(baseline_path)
        result = engine.run_lint(paths, baseline=baseline)
        if args.update_baseline:
            target = baseline_path or DEFAULT_BASELINE
            os.makedirs(os.path.dirname(target) or ".", exist_ok=True)
            engine.write_baseline(
                target, result.violations + result.baselined
            )
            print(
                f"baselined {len(result.violations + result.baselined)} "
                f"finding(s) into {target}"
            )
            return 0
        violations.extend(result.violations)
        lines.append(
            f"lint: {result.files_scanned} file(s), "
            f"{len(result.violations)} new violation(s), "
            f"{len(result.baselined)} baselined, "
            f"{result.suppressed} suppressed"
        )
        if not args.quiet:
            for v in result.baselined:
                print(f"baselined: {v.format()}")

    if args.all or args.trace_audit:
        from repro.analysis import schema, trace_audit

        schema_vs = schema.check_manifest()
        violations.extend(schema_vs)
        lines.append(
            f"schema: {'OK' if not schema_vs else f'{len(schema_vs)} mismatch(es)'}"
        )
        audit_vs, audit_lines = trace_audit.run_trace_audit()
        violations.extend(audit_vs)
        lines.extend(audit_lines)

    for v in violations:
        print(v.format())
    for line in lines:
        print(line)
    if violations:
        print(
            f"FAILED: {len(violations)} new violation(s) — fix, or "
            "suppress inline with `# repro-lint: disable=<rule> — why`"
        )
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
