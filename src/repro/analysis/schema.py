"""Executable schema check: the live pytree/field manifest vs the
checked-in one.

The PR 4 checkpoint break — renaming a ``RecycleState`` leaf silently
orphaned every existing checkpoint, because restore matches leaves *by
name* — is exactly the class of regression an AST rule cannot catch (the
rename is perfectly well-formed code).  So the schema half of the
``pytree-schema`` gate is executable: :func:`compute_manifest` imports
the real classes and derives the structure a checkpoint (and a jit
cache key) actually depends on:

* ``RecycleState``: the keyed-flatten leaf names, in flatten order, with
  rank and dtype of the canonical cold template — the checkpoint
  restore contract.
* ``SolveSpec``: field names + reprs of defaults — the static jit cache
  key (a changed default silently changes what "default spec" means for
  every caller).
* ``SolveReport``: the NamedTuple field order — positional destructuring
  of reports is everywhere in tests and serving code.

:func:`check_manifest` diffs that against ``schema_manifest.json``.  A
mismatch is not (necessarily) a bug — it is an *unacknowledged contract
change*.  To acknowledge one: bump ``SCHEMA_VERSION`` in
``repro/checkpoint/manager.py`` (teach ``restore_pytree`` to migrate old
leaves), then regenerate the manifest with
``python -m repro.analysis --update-schema``.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import List

from repro.analysis.engine import Violation

MANIFEST_BASENAME = "schema_manifest.json"


def default_manifest_path() -> str:
    return os.path.join(os.path.dirname(__file__), MANIFEST_BASENAME)


def compute_manifest() -> dict:
    """Derive the live schema from the imported classes (small template
    instances; no solves run)."""
    import jax

    from repro.checkpoint import manager as ckpt_manager
    from repro.core import RecycleState, SolveReport, SolveSpec

    template = RecycleState.zeros(k=2, n=4)
    leaves_with_keys, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in leaves_with_keys:
        # One GetAttrKey per leaf for a flat keyed dataclass; join defensively
        # so nested future leaves still get a stable dotted name.
        name = ".".join(
            getattr(k, "name", getattr(k, "key", str(k))) for k in path
        )
        leaves.append({
            "key": name,
            "ndim": int(getattr(leaf, "ndim", 0)),
            "dtype": str(getattr(leaf, "dtype", type(leaf).__name__)),
        })

    spec_fields = [
        {"name": f.name, "default": _default_repr(f)}
        for f in dataclasses.fields(SolveSpec)
    ]

    return {
        "_comment": (
            "Checked-in leaf/field schema for the solver stack's public "
            "carries.  If `python -m repro.analysis` reports a mismatch "
            "here, you changed a checkpoint/jit contract: bump "
            "SCHEMA_VERSION in repro/checkpoint/manager.py, add a "
            "restore migration, then regenerate with "
            "`python -m repro.analysis --update-schema`."
        ),
        "checkpoint_schema_version": int(ckpt_manager.SCHEMA_VERSION),
        "RecycleState": {
            "kind": "register_pytree_with_keys_class",
            "leaves": leaves,
            "num_leaves": treedef.num_leaves,
        },
        "SolveSpec": {
            "kind": "frozen_dataclass(static-jit-arg)",
            "fields": spec_fields,
        },
        "SolveReport": {
            "kind": "NamedTuple",
            "fields": list(SolveReport._fields),
        },
    }


def _default_repr(f: "dataclasses.Field") -> str:
    if f.default is not dataclasses.MISSING:
        return repr(f.default)
    if f.default_factory is not dataclasses.MISSING:  # type: ignore[misc]
        return repr(f.default_factory())
    return "<required>"


def write_manifest(path: str | None = None) -> str:
    path = path or default_manifest_path()
    with open(path, "w") as f:
        json.dump(compute_manifest(), f, indent=2)
        f.write("\n")
    return path


def check_manifest(path: str | None = None) -> List[Violation]:
    """Diff the live schema against the checked-in manifest; every
    difference becomes one ``pytree-schema`` violation."""
    path = path or default_manifest_path()
    rel = os.path.basename(path)
    if not os.path.exists(path):
        return [Violation(
            rule="pytree-schema", path=rel, line=0, col=0,
            message=f"schema manifest missing at {path}; generate it "
                    "with `python -m repro.analysis --update-schema`",
        )]
    with open(path) as f:
        stored = json.load(f)
    live = compute_manifest()
    out: List[Violation] = []

    def diff(key: str, stored_v, live_v, hint: str):
        if stored_v != live_v:
            out.append(Violation(
                rule="pytree-schema", path=rel, line=0, col=0,
                message=(
                    f"{key} changed: manifest has {stored_v!r}, live code "
                    f"has {live_v!r} — {hint}"
                ),
                source=key,
            ))

    diff(
        "checkpoint_schema_version",
        stored.get("checkpoint_schema_version"),
        live["checkpoint_schema_version"],
        "keep manager.SCHEMA_VERSION and the manifest in lockstep",
    )
    diff(
        "RecycleState.leaves",
        (stored.get("RecycleState") or {}).get("leaves"),
        live["RecycleState"]["leaves"],
        "renamed/retyped leaves orphan every existing checkpoint "
        "(restore matches BY NAME); bump SCHEMA_VERSION + migrate",
    )
    diff(
        "SolveSpec.fields",
        (stored.get("SolveSpec") or {}).get("fields"),
        live["SolveSpec"]["fields"],
        "SolveSpec is the static jit cache key; changed fields/defaults "
        "change every caller's default behavior",
    )
    diff(
        "SolveReport.fields",
        (stored.get("SolveReport") or {}).get("fields"),
        live["SolveReport"]["fields"],
        "SolveReport is destructured positionally; field order is API",
    )
    return out
