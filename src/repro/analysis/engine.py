"""Lint engine: file walking, suppressions, baselining, reporting.

The analyzer has two kinds of checks (see ``repro.analysis``):

* **AST rules** (``repro.analysis.rules``) run here, file by file.  A
  rule is a pure function ``check(tree, src, relpath, ctx) ->
  [Violation]``; the engine owns everything around it — which files are
  scanned, which findings are suppressed inline, which are grandfathered
  in the baseline, and how the result is rendered/exit-coded.
* **Executable checks** (``repro.analysis.schema`` — the live pytree
  manifest; ``repro.analysis.trace_audit`` — compile-count and jaxpr
  audits) import the package under test and report through the same
  :class:`Violation` shape so one CLI aggregates both.

Suppression policy (DESIGN.md §10): a finding is silenced by

    ``# repro-lint: disable=rule-name — <one-line justification>``

on the offending line or the line directly above it.  Several rules may
be listed comma-separated; ``disable-file=rule-name`` anywhere in the
file silences the rule for the whole file.  Suppressions are the
*documented-intent* channel — every one should say why the flagged
pattern is safe.  The baseline file is the *grandfathering* channel for
pre-existing debt: violations whose fingerprint appears in it are
reported as baselined (not failures), so the gate only fails on NEW
violations.  Fingerprints hash the rule, the file path, and the source
line *text* (not the line number), so unrelated edits above a
grandfathered finding do not un-baseline it.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
from typing import Iterable, List, Optional, Sequence, Tuple

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(disable|disable-file)\s*=\s*([A-Za-z0-9_\-, ]+)"
)


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: a rule, a location, and an explanation."""

    rule: str
    path: str  # posix-style path, relative to the scan root when possible
    line: int  # 1-based; 0 for file-level / runtime findings
    col: int
    message: str
    source: str = ""  # the stripped offending source line ("" for runtime)

    def fingerprint(self) -> str:
        """Stable identity for baselining: rule + path + line TEXT.

        Line numbers drift under unrelated edits; the source text of the
        offending line (plus an occurrence-independent rule/path key)
        survives them.
        """
        key = f"{self.rule}|{self.path}|{self.source.strip()}"
        return hashlib.sha256(key.encode()).hexdigest()[:16]

    def format(self) -> str:
        loc = f"{self.path}:{self.line}:{self.col}"
        return f"{loc}: [{self.rule}] {self.message}"


@dataclasses.dataclass(frozen=True)
class LintConfig:
    """Repo-invariant analyzer configuration (defaults fit this repo).

    ``traced_packages`` scope the ``host-sync-in-trace`` rule: only files
    whose path contains one of these directory names hold traced solver
    code.  ``host_side_allowlist`` carves out files inside those packages
    that are *genuinely* host-side (checkpoint IO, fault-injection
    instrumentation built on ``io_callback``).
    """

    traced_packages: Tuple[str, ...] = ("core", "kernels")
    host_side_allowlist: Tuple[str, ...] = (
        "checkpoint/",
        "faults.py",  # io_callback-based chaos instrumentation (host-counted)
        "tpu_compat.py",
    )
    ops_module: str = "kernels/ops.py"
    ref_module_name: str = "ref"
    tests_dir_name: str = "tests"
    kernel_impls: Tuple[str, ...] = (
        "pallas",
        "interpret",
        "reference",
        "chunked",
    )
    # Dataclasses matching this name pattern — or carrying one of the
    # registration decorators below — are jit-STATIC config: they must be
    # frozen (hashable) and hold no array leaves.
    static_spec_pattern: str = r".*(Spec|Strategy)$"
    static_spec_decorators: Tuple[str, ...] = ("_register_strategy",)


@dataclasses.dataclass
class FileSuppressions:
    """Parsed ``# repro-lint:`` directives of one file."""

    by_line: dict  # line number -> set of rule names (or {"all"})
    file_level: set  # rule names silenced for the whole file

    def matches(self, rule: str, line: int) -> bool:
        if rule in self.file_level or "all" in self.file_level:
            return True
        for ln in (line, line - 1):
            rules = self.by_line.get(ln)
            if rules and (rule in rules or "all" in rules):
                return True
        return False


def parse_suppressions(src: str) -> FileSuppressions:
    by_line: dict = {}
    file_level: set = set()
    lines = src.splitlines()
    for i, text in enumerate(lines, start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        kind, names = m.groups()
        # The rule list ends at the first token that is not a rule name —
        # trailing justifications ("— static python int") are free-form.
        rules = {r.strip() for r in names.split(",") if r.strip()}
        if kind == "disable-file":
            file_level |= rules
            continue
        by_line.setdefault(i, set()).update(rules)
        # A directive opening a comment block covers the whole block plus
        # the first code line after it, so multi-line justifications work:
        #     # repro-lint: disable=rule — because
        #     # ...continued rationale...
        #     offending_statement()
        j = i
        while j < len(lines) and lines[j].lstrip().startswith("#"):
            j += 1
            by_line.setdefault(j, set()).update(rules)
    return FileSuppressions(by_line=by_line, file_level=file_level)


@dataclasses.dataclass
class LintResult:
    violations: List[Violation]  # new findings (fail the gate)
    baselined: List[Violation]  # grandfathered findings (reported, pass)
    suppressed: int  # count of inline-suppressed findings
    files_scanned: int = 0

    @property
    def ok(self) -> bool:
        return not self.violations


def iter_python_files(paths: Sequence[str]) -> Iterable[Tuple[str, str]]:
    """Yield ``(abspath, relpath)`` for every ``.py`` under ``paths``.

    ``relpath`` is posix-style and relative to the scanned root (or to
    the file's directory for a single-file path), so fingerprints are
    machine-independent.
    """
    for root in paths:
        root = os.path.abspath(root)
        if os.path.isfile(root):
            if root.endswith(".py"):
                yield root, os.path.basename(root)
            continue
        base = os.path.dirname(root)
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(
                d
                for d in dirnames
                if d not in ("__pycache__", ".git", ".tmp")
            )
            for name in sorted(filenames):
                if not name.endswith(".py"):
                    continue
                ap = os.path.join(dirpath, name)
                rp = os.path.relpath(ap, base).replace(os.sep, "/")
                yield ap, rp


def load_baseline(path: Optional[str]) -> set:
    """Fingerprints grandfathered by ``baseline.json`` (empty if absent)."""
    if path is None or not os.path.exists(path):
        return set()
    with open(path) as f:
        data = json.load(f)
    return {entry["fingerprint"] for entry in data.get("violations", [])}


def write_baseline(path: str, violations: Sequence[Violation]) -> None:
    data = {
        "_comment": (
            "Grandfathered repro-lint findings: pre-existing violations "
            "the gate tolerates.  New code must not add entries here — "
            "fix the finding or suppress it inline with a justification "
            "(# repro-lint: disable=rule — why)."
        ),
        "violations": [
            {
                "rule": v.rule,
                "path": v.path,
                "fingerprint": v.fingerprint(),
                "message": v.message,
            }
            for v in sorted(violations, key=lambda v: (v.path, v.line))
        ],
    }
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")


def run_lint(
    paths: Sequence[str],
    config: Optional[LintConfig] = None,
    baseline: Optional[set] = None,
) -> LintResult:
    """Run every AST rule over ``paths`` and split the findings three ways:
    new violations, baselined (grandfathered), and inline-suppressed."""
    import ast

    from repro.analysis import rules as rules_mod

    config = config or LintConfig()
    baseline = baseline or set()
    new: List[Violation] = []
    old: List[Violation] = []
    suppressed = 0
    nfiles = 0
    for abspath, relpath in iter_python_files(paths):
        nfiles += 1
        with open(abspath) as f:
            src = f.read()
        try:
            tree = ast.parse(src, filename=relpath)
        except SyntaxError as exc:
            new.append(
                Violation(
                    rule="parse-error",
                    path=relpath,
                    line=exc.lineno or 0,
                    col=exc.offset or 0,
                    message=f"file does not parse: {exc.msg}",
                )
            )
            continue
        sup = parse_suppressions(src)
        ctx = rules_mod.RuleContext(
            config=config, abspath=abspath, src_lines=src.splitlines()
        )
        for rule in rules_mod.ALL_RULES:
            for v in rule.check(tree, src, relpath, ctx):
                if sup.matches(v.rule, v.line):
                    suppressed += 1
                elif v.fingerprint() in baseline:
                    old.append(v)
                else:
                    new.append(v)
    return LintResult(
        violations=new, baselined=old, suppressed=suppressed,
        files_scanned=nfiles,
    )
