"""Synthetic "infinite digits" generator — stand-in for infinite MNIST.

The paper's dataset (Loosli et al.'s infinite-MNIST 3-vs-5 task) is built
by applying random deformations to MNIST digits; MNIST itself is not
redistributable inside this offline container, so we generate the digits
procedurally: each class is a parametric stroke skeleton ("3" = two
right-bulging arcs, "5" = bar + stem + bowl), rasterized to 28×28 with a
Gaussian pen, under a random affine jitter (rotation/scale/shear/shift)
plus pixel noise — the same "infinite transformations of a prototype"
recipe, with the same binary-classification difficulty knobs.

Fully deterministic given the seed; pure numpy (data pipeline, not jitted).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

IMG = 28


def _stroke_points_three(n_pts: int) -> np.ndarray:
    """Digit '3': two arcs bulging right, in unit coords (x right, y down)."""
    t1 = np.linspace(-0.5 * np.pi, 0.5 * np.pi, n_pts // 2)
    upper = np.stack(
        [0.42 + 0.18 * np.cos(t1), 0.32 + 0.14 * np.sin(t1)], axis=1
    )
    t2 = np.linspace(-0.5 * np.pi, 0.5 * np.pi, n_pts - n_pts // 2)
    lower = np.stack(
        [0.42 + 0.20 * np.cos(t2), 0.64 + 0.16 * np.sin(t2)], axis=1
    )
    return np.concatenate([upper, lower], axis=0)


def _stroke_points_five(n_pts: int) -> np.ndarray:
    """Digit '5': top bar, left stem, lower-right bowl."""
    n1, n2 = n_pts // 4, n_pts // 4
    n3 = n_pts - n1 - n2
    bar = np.stack(
        [np.linspace(0.30, 0.66, n1), np.full(n1, 0.20)], axis=1
    )
    stem = np.stack(
        [np.full(n2, 0.30), np.linspace(0.20, 0.46, n2)], axis=1
    )
    t = np.linspace(-0.75 * np.pi, 0.6 * np.pi, n3)
    bowl = np.stack(
        [0.42 + 0.20 * np.cos(t), 0.62 + 0.18 * np.sin(t)], axis=1
    )
    return np.concatenate([bar, stem, bowl], axis=0)


def _rasterize(points: np.ndarray, sigma: float = 0.95) -> np.ndarray:
    """Splat stroke points onto the 28×28 grid with a Gaussian pen."""
    px = points[:, 0] * IMG
    py = points[:, 1] * IMG
    gx = np.arange(IMG) + 0.5
    d2x = (gx[None, :] - px[:, None]) ** 2  # (m, 28)
    d2y = (gx[None, :] - py[:, None]) ** 2
    img = np.einsum(
        "my,mx->yx",
        np.exp(-0.5 * d2y / sigma**2),
        np.exp(-0.5 * d2x / sigma**2),
    )
    peak = img.max()
    return img / peak if peak > 0 else img


def make_infinite_digits(
    n: int,
    seed: int = 0,
    *,
    noise: float = 0.06,
    n_stroke_points: int = 120,
) -> Tuple[np.ndarray, np.ndarray]:
    """Generate n samples of the 3-vs-5 task.

    Returns:
      x: (n, 784) float32 in [0, 1]
      y: (n,) float32 in {−1, +1}   (+1 ≙ "3", −1 ≙ "5")
    """
    rng = np.random.default_rng(seed)
    protos = {
        +1: _stroke_points_three(n_stroke_points),
        -1: _stroke_points_five(n_stroke_points),
    }
    xs = np.empty((n, IMG * IMG), np.float32)
    ys = np.empty((n,), np.float32)
    labels = rng.permuted(np.repeat([1.0, -1.0], [n - n // 2, n // 2]))
    for i in range(n):
        label = labels[i]
        pts = protos[int(label)].copy()
        # Random affine jitter around the glyph center.
        ang = rng.uniform(-0.26, 0.26)  # ±15°
        scale = rng.uniform(0.85, 1.15)
        shear = rng.uniform(-0.15, 0.15)
        rot = np.array(
            [[np.cos(ang), -np.sin(ang)], [np.sin(ang), np.cos(ang)]]
        )
        shr = np.array([[1.0, shear], [0.0, 1.0]])
        center = np.array([0.45, 0.48])
        pts = (pts - center) @ (rot @ shr).T * scale + center
        pts += rng.uniform(-2.0 / IMG, 2.0 / IMG, size=2)

        img = _rasterize(pts)
        img += rng.normal(0.0, noise, img.shape)
        xs[i] = np.clip(img, 0.0, 1.0).ravel()
        ys[i] = label
    return xs, ys
