"""repro.data — data pipelines (synthetic, deterministic, shard-aware)."""

from repro.data.digits import make_infinite_digits
from repro.data.tokens import TokenPipeline, batch_sharding

__all__ = ["make_infinite_digits", "TokenPipeline", "batch_sharding"]
