"""Synthetic token pipeline: deterministic, learnable, shard-aware.

The stream is an order-2 additive-congruential process with zipfian noise:
``t_{i+1} = (a·t_i + b·t_{i-1} + ξ) mod V`` — enough structure that a
model's loss drops measurably within a few hundred steps (the end-to-end
training driver's success signal), fully deterministic given (seed, step),
and generated on the fly (no storage, no host I/O bottleneck: the
generator is pure numpy and can run ahead of the device on a background
thread if needed).

``make_batch(step)`` is content-addressed by step — after a restart the
pipeline resumes mid-stream exactly (fault-tolerance requirement: data
order survives preemption without persisted reader state).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

try:  # jax optional: the generator itself is pure numpy
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
except Exception:  # pragma: no cover
    jax = None


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0

    def make_batch(self, step: int) -> dict:
        """Batch for a given global step (deterministic, restartable)."""
        rng = np.random.default_rng((self.seed << 20) ^ step)
        v = self.vocab_size
        a = 31 + (step % 7)
        b = 17
        t = np.empty((self.batch, self.seq_len + 1), np.int32)
        t[:, 0] = rng.integers(0, v, self.batch)
        t[:, 1] = rng.integers(0, v, self.batch)
        noise = (rng.zipf(2.0, (self.batch, self.seq_len + 1)) - 1) % v
        for i in range(2, self.seq_len + 1):
            t[:, i] = (a * t[:, i - 1] + b * t[:, i - 2] + noise[:, i]) % v
        return {"tokens": t[:, :-1], "labels": t[:, 1:].astype(np.int32)}

    def iterate(
        self, start_step: int = 0, sharding: Optional["NamedSharding"] = None
    ) -> Iterator[dict]:
        step = start_step
        while True:
            batch = self.make_batch(step)
            if sharding is not None and jax is not None:
                batch = {
                    k: jax.device_put(val, sharding)
                    for k, val in batch.items()
                }
            yield batch
            step += 1


def batch_sharding(mesh, batch_axes=("data",)):
    """NamedSharding for (B, S) int batches: batch over the DP axes."""
    return NamedSharding(mesh, P(batch_axes, None))
