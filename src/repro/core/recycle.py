"""Krylov subspace recycling: harmonic-Ritz extraction + cross-system state.

This is the paper's §2.3.  After def-CG solves system ``i`` we have

    Z  = [W, P_ell]        (k + ell stacked vectors)
    AZ = [AW, AP_ell]

and the harmonic projection (Morgan 1995) asks for ``(θ, u)`` with

    (AZ)ᵀ (AZ u − θ Z u) = 0    ⇔    G u = θ F u,
    G = (AZ)ᵀ(AZ)  (SPD),   F = (AZ)ᵀ Z = ZᵀAZ  (symmetric for A = Aᵀ).

We reduce the generalized problem with a Cholesky of ``G``:

    G = LLᵀ,  w = Lᵀu :   (L⁻¹ F L⁻ᵀ) w = (1/θ) w,

a small ``(k+ell)²`` symmetric eigenproblem solved identically (replicated)
on every device — far cheaper than any distributed scheme at these sizes.
The k selected Ritz vectors ``W' = Z U`` (and ``A W' = AZ · U``, free) are
the recycled deflation space for the *next* system in the sequence.

Column equilibration: the generalized eigenproblem is invariant under
column scaling ``Z → Z D`` (``G → DGD``, ``F → DFD``, ``θ`` unchanged), so
we equilibrate to unit ``‖Z_i‖`` / unit ``‖AZ_i‖`` before factoring — this
keeps the reduction well-posed even when late CG directions have tiny
norms.

Two implementations share the same math:

* :func:`harmonic_ritz` — the pytree-native original (stacked pytree
  bases, static sizes).  Kept as the semantic oracle.
* :func:`harmonic_ritz_flat` — the device-resident engine: flat ``(m, n)``
  bases, ONE tall-skinny GEMM for all three grams
  (``kernels.ops.self_gram`` over ``S = [Z; AZ]``), and a traced validity
  mask instead of dynamic slicing, so a *dynamic* stored count needs no
  host round-trip.  :func:`solve_sequence` scans it across a whole
  sequence of systems without leaving the device.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import operators as ops_mod
from repro.core import pytree as pt
from repro.core.solvers import (
    DEFAULT_WAW_JITTER,
    CGResult,
    SolveInfo,
    _flat_operator,
    defcg,
    defcg_jit,
)
from repro.core.strategies import (
    HarmonicRitz,
    RecycleStrategy,
    _select_positive_ritz,
    extract_next_basis_core,
    harmonic_ritz_flat_core,
)

Pytree = Any


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class RecycleState:
    """First-class recycled-subspace state — the carry of every solve path.

    Replaces the bare ``(W, AW)`` pairs previously threaded through
    ``RecycleManager``, ``recycled_solve_jit``, ``hf_step`` and
    ``solve_sequence``'s scan carry.  A registered pytree node (with
    stable key names, so it round-trips through ``repro.checkpoint``
    by leaf path), it vmaps over a leading tenant axis (``solve_batch``)
    and shards like the solution vector under pjit.

    Attributes:
      W: flat ``(k, n)`` recycled basis rows.  Zero rows are empty slots
        (cold bootstrap / clamped extraction) — def-CG deflates them as
        exact no-ops, so an all-zero state is a valid "no recycling yet".
      AW: ``(k, n)`` A-products of ``W`` under the operator that produced
        them (stale until the next refresh).
      theta: ``(k,)`` harmonic Ritz values (0 = clamped slot).
      systems_solved: int32 scalar — how many solves fed this state.
      drift: scalar — the recycle strategy's carried drift measurement
        (the ``‖AW − A·W‖`` proxy read off the last extraction gram; see
        :class:`repro.core.strategies.WindowedRecombine`).  0 for
        strategies that do not guard and for cold states.
    """

    W: jnp.ndarray
    AW: jnp.ndarray
    theta: jnp.ndarray
    systems_solved: jnp.ndarray
    drift: jnp.ndarray = dataclasses.field(
        default_factory=lambda: jnp.float32(0.0)
    )

    @classmethod
    def zeros(cls, k: int, n: int, dtype=jnp.float32) -> "RecycleState":
        """A cold (empty) state: the first solve runs plain CG + record."""
        return cls(
            W=jnp.zeros((k, n), dtype),
            AW=jnp.zeros((k, n), dtype),
            theta=jnp.zeros((k,), dtype),
            systems_solved=jnp.int32(0),
            drift=jnp.zeros((), dtype),
        )

    def tree_flatten_with_keys(self):
        ga = jax.tree_util.GetAttrKey
        return (
            (
                (ga("W"), self.W),
                (ga("AW"), self.AW),
                (ga("theta"), self.theta),
                (ga("systems_solved"), self.systems_solved),
                (ga("drift"), self.drift),
            ),
            None,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


def harmonic_ritz(
    Z: Pytree,
    AZ: Pytree,
    k: int,
    *,
    select: str = "largest",
    jitter: float = 1e-10,
) -> Tuple[Pytree, Pytree, jnp.ndarray]:
    """Extract ``k`` harmonic Ritz pairs from the basis ``Z`` (see module doc).

    Args:
      Z, AZ: stacked bases of m ≥ k vectors and their A-products.
      k: number of Ritz vectors to keep.
      select: ``"largest"`` (deflate the top of the spectrum — the right
        choice for the paper's ``A = I + H½KH½`` whose spectrum clusters at
        1 with large outliers) or ``"smallest"``.
      jitter: relative diagonal regularization for the Cholesky of G.

    Returns:
      ``(W, AW, theta)`` — the recycled basis, its A-products, and the k
      harmonic Ritz values (approximate eigenvalues of A).  If fewer than
      ``k`` positive Ritz pairs survive the rank filter, the trailing
      slots are exact zeros (θ = 0).
    """
    m = pt.basis_size(Z)
    if k > m:
        raise ValueError(f"cannot extract k={k} Ritz vectors from m={m} basis")

    # Normalize columns BEFORE forming the grams: late CG directions are
    # orders of magnitude smaller than early ones, and computing ZᵀAZ at
    # mixed scales loses the small columns' entries to rounding (observed:
    # negative "Ritz values" from an SPD operator).  Column scaling is an
    # exact invariance of the generalized problem, so this is free.
    zn = jnp.sqrt(jnp.maximum(jnp.diag(pt.gram(Z, Z)), 1e-300))
    Z = pt.basis_scale_columns(Z, 1.0 / zn)
    AZ = pt.basis_scale_columns(AZ, 1.0 / zn)

    G = pt.gram(AZ, AZ)
    F = pt.gram(AZ, Z)
    F = 0.5 * (F + F.T)

    # Second-stage equilibration on ‖AZ_i‖.
    d = jnp.where(jnp.diag(G) > 0, jnp.diag(G), 1.0) ** -0.5
    G = G * d[:, None] * d[None, :]
    F = F * d[:, None] * d[None, :]

    # Rank-revealing reduction of the generalized problem: eigendecompose
    # G and *project out* its near-null directions (near-dependent Krylov
    # columns otherwise surface as spurious huge Ritz values; observed on
    # long recording windows).  Projected directions get ζ = 0 exactly and
    # the positivity filter below excludes them — shapes stay static.
    lam, qg = jnp.linalg.eigh(G)  # ascending
    eps = jnp.finfo(G.dtype).eps
    rcond = jnp.maximum(jnp.asarray(jitter, G.dtype), 100.0 * eps) * m
    good = lam > rcond * lam[-1]
    s = jnp.where(good, 1.0 / jnp.sqrt(jnp.maximum(lam, 1e-300)), 0.0)
    M = s[:, None] * (qg.T @ F @ qg) * s[None, :]
    M = 0.5 * (M + M.T)
    zeta, Wm = jnp.linalg.eigh(M)  # ascending ζ = 1/θ

    w_sel, theta, slot_ok = _select_positive_ritz(zeta, Wm, k, select)

    # u = D · Qg S w  (undo reduction and equilibration).
    u = qg @ (s[:, None] * w_sel)
    u = u * d[:, None]

    W = pt.basis_matmul(Z, u)
    AW = pt.basis_matmul(AZ, u)

    # Normalize the recycled vectors to unit 2-norm (pure conditioning);
    # clamped slots stay exactly zero.
    col_norms = jnp.sqrt(
        jnp.maximum(jnp.diag(pt.gram(W, W)), jnp.finfo(u.dtype).tiny)
    )
    col_scale = jnp.where(slot_ok, 1.0 / col_norms, 0.0)
    W = pt.basis_scale_columns(W, col_scale)
    AW = pt.basis_scale_columns(AW, col_scale)
    return W, AW, theta


harmonic_ritz_jit = jax.jit(
    harmonic_ritz, static_argnames=("k", "select", "jitter")
)


def harmonic_ritz_flat(
    Z: jnp.ndarray,
    AZ: jnp.ndarray,
    k: int,
    *,
    valid: Optional[jnp.ndarray] = None,
    select: str = "largest",
    jitter: float = 1e-10,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Device-resident harmonic Ritz over flat ``(m, n)`` row-stacked bases.

    The sequence-engine twin of :func:`harmonic_ritz`:

    * ``valid`` is an optional *traced* ``(m,)`` bool mask — rows whose
      slot is invalid (unfilled recording window, clamped basis columns)
      are zeroed and flow through the rank filter as exact nulls, so a
      dynamic stored count costs no host round-trip and no dynamic shapes;
    * the three gram passes (``ZZᵀ`` for column norms, ``G``, ``F``)
      collapse into ONE tall-skinny GEMM over ``S = [Z; AZ]``
      (:func:`repro.kernels.ops.self_gram`) — its quadrants are sliced on
      device.  Column equilibration is applied to the *gram entries*
      (exact invariance), not the O(m·n) basis data.

    Returns ``(W, AW, theta)`` of shapes ``(k, n), (k, n), (k,)``; slots
    past the surviving positive-Ritz count are exact zeros — downstream
    def-CG treats a zero column as a no-op deflation direction (see the
    jitter floor in ``solvers.defcg``).

    The math lives in :func:`repro.core.strategies.harmonic_ritz_flat_core`
    (this wrapper keeps the historical 3-tuple signature), which also
    serves the strategy layer's M-geometry extraction and drift proxy.
    """
    W, AW, theta, _ = harmonic_ritz_flat_core(
        Z, AZ, k, valid=valid, select=select, jitter=jitter
    )
    return W, AW, theta


def _extract_next_basis(
    w_flat: Optional[jnp.ndarray],
    aw_flat: Optional[jnp.ndarray],
    p_flat: jnp.ndarray,
    ap_flat: jnp.ndarray,
    stored,
    k: int,
    *,
    select: str = "largest",
    jitter: float = 1e-10,
):
    """One cross-system extraction on the flat engine (3-tuple wrapper
    over :func:`repro.core.strategies.extract_next_basis_core` — the
    strategy layer's shared masked extraction)."""
    W, AW, theta, _ = extract_next_basis_core(
        w_flat, aw_flat, p_flat, ap_flat, stored, k,
        select=select, jitter=jitter,
    )
    return W, AW, theta


def _apply_basis_flat(A, unravel, w_flat: jnp.ndarray) -> jnp.ndarray:
    """``A @ W`` for a flat ``(k, n)`` basis — one multi-RHS application
    through the operator's pytree coordinates."""
    basis = pt.unravel_basis(w_flat, unravel)
    return pt.ravel_basis(ops_mod.apply_to_basis(A, basis))


# Highest rung the recovery ladder can climb (see ``_one_recycled_solve``).
MAX_RECOVERY_RUNGS = 3


def _one_recycled_solve(
    A,
    b: Pytree,
    x0: Optional[Pytree],
    w: jnp.ndarray,
    aw_carry: jnp.ndarray,
    drift: jnp.ndarray,
    unravel,
    *,
    k: int,
    ell: int,
    tol: float,
    atol: float,
    maxiter: int,
    select: str,
    waw_jitter: float,
    refresh_aw: str,
    strategy: RecycleStrategy,
    M=None,
    record_residuals: bool = False,
    batch_axis: Optional[str] = None,
    recovery_rungs: int = 0,
    recovery_shift: float = 1e-6,
    stagnation_window: int = 0,
):
    """ONE system of the recycled def-CG step, on flat state.

    The single source of truth for per-system semantics — shared by the
    front-door :func:`repro.core.solve` and by :func:`solve_sequence`'s
    scan body, so the single-system and scan paths cannot drift apart.
    Both halves of the per-system policy are owned by the ``strategy``
    object (:mod:`repro.core.strategies`):

    * ``strategy.prepare`` decides which ``AW`` deflates this system and
      what it costs (exact k-matvec refresh / guarded stale / pure
      stale), reading the carried ``drift`` measurement;
    * ``strategy.transition`` consumes the recorded window — the
      ``(P, AP, α, β, stored)`` handoff from the solver's scan phase —
      and emits the next ``(W, AW, θ, drift)``.

    ``recovery_rungs > 0`` arms the escalating recovery ladder (the
    generalization of the old one-shot ``divergence_fallback``).  When
    the attempt ends broken (``info.breakdown``) or unconverged with a
    carried basis, a ``lax.while_loop`` climbs up to
    :data:`MAX_RECOVERY_RUNGS` re-solve rungs:

    1. **refresh-AW-and-redo** — keep ``W``, recompute ``AW = A·W``
       exactly (k matvecs, charged) and re-solve: repairs stale/poisoned
       basis *products* and transient matvec faults without discarding
       the subspace;
    2. **drop the basis** — re-solve with a zeroed ``W`` (the
       cold-bootstrap path: exact no-op deflation plus recording, so the
       extraction re-seeds the sequence);
    3. **escalated plain CG** — zero basis, preconditioner disabled, and
       the operator shifted to ``A + σI`` (σ = ``recovery_shift``): the
       last resort against a (numerically) indefinite or singular
       operator, trading a σ-sized bias for a finite answer.

    The loop traces ONE extra solver instance regardless of rung count
    (rung identity is a traced index: the shift is ``σ·𝟙[rung = 3]`` and
    the preconditioner is identity-gated), and on a clean solve it runs
    zero iterations — the clean path's iterates and matvec totals are
    untouched.  Every executed attempt's matvecs are charged to the
    reported total; the adopted solution is whichever attempt holds the
    smallest (finite, non-broken) residual, while the basis always comes
    from the last executed rung — a freshly re-seeded space beats
    carrying poison forward.  Rung 3 only fires on an actual breakdown
    (a merely maxiter-bound system is not re-solved against a shifted
    operator), and a basis-less system that fails *without* breakdown
    never enters the ladder (re-running the identical solve cannot
    help).

    Returns ``(x, info, w_next, aw_next, theta, drift_next, rung)``;
    ``theta`` is ``None`` when ``ell == 0`` (nothing recorded — callers
    carry their previous Ritz values, and the drift carry passes through
    unchanged), and ``rung`` is the int32 highest recovery rung executed
    (0 = clean / ladder disarmed).
    """
    m_flat = _flat_operator(M, unravel) if M is not None else None
    aw_used, refresh_matvecs, exact_aw, stale_guard = strategy.prepare(
        lambda ww: _apply_basis_flat(A, unravel, ww),
        w,
        aw_carry,
        drift,
        k=k,
        refresh_aw=refresh_aw,
        tol=tol,
        batch_axis=batch_axis,
    )
    result = defcg(
        A,
        b,
        x0,
        W=w,
        AW=aw_used,
        ell=ell,
        tol=tol,
        atol=atol,
        maxiter=maxiter,
        record_residuals=record_residuals,
        waw_jitter=waw_jitter,
        exact_aw=exact_aw,
        flat_recycle=True,
        M=M,
        batch_axis=batch_axis,
        stale_guard=stale_guard,
        stagnation_window=stagnation_window,
    )
    if result.recycle is not None and result.recycle.aw_used is not None:
        # The in-solve drift guard may have replaced the stale AW with a
        # fresh A·W — the transition must recombine what was USED.
        aw_used = result.recycle.aw_used
    info = result.info
    # The multi-RHS refresh is one fused pass but (when the strategy
    # spent it) k matvecs of operator work — the §2.2 overhead term,
    # reported honestly: zero on cold bootstraps and un-triggered guards.
    info = info._replace(
        matvecs=info.matvecs + refresh_matvecs.astype(info.matvecs.dtype)
    )
    if ell > 0:
        w_next, aw_next, theta, drift_next = strategy.transition(
            w,
            aw_used,
            result.recycle,
            k=k,
            select=select,
            m_apply=m_flat,
        )
    else:
        w_next, aw_next, theta, drift_next = w, aw_used, None, drift

    rung0 = jnp.int32(0)
    if recovery_rungs <= 0:
        return (
            result.x, info, w_next, aw_next, theta, drift_next, rung0,
        )

    # repro-lint: disable=host-sync-in-trace — recovery_rungs is static
    # Python config (jit-static via SolveSpec), not traced data.
    rungs = min(int(recovery_rungs), MAX_RECOVERY_RUNGS)
    had_basis = jnp.any(w != 0)
    zero_dtype = w.dtype

    def _eligible(i, info_c):
        """Per-lane: does rung ``i`` apply to this (still-bad) solve?"""
        bad_c = info_c.breakdown | jnp.logical_not(info_c.converged)
        return (
            bad_c
            & (had_basis | info_c.breakdown)
            & ((i < MAX_RECOVERY_RUNGS) | info_c.breakdown)
        )

    def ladder_cond(st):
        i, _, info_c, *_ = st
        elig = _eligible(i, info_c)
        if batch_axis is not None:
            # Under vmap a batched predicate would kill the loop — the
            # cross-lane any() is unbatched, and lanes mask per-slot
            # adoption in the body (a broken tenant is retired into its
            # own failure status without dragging the healthy lanes).
            elig = jax.lax.psum(elig.astype(jnp.int32), batch_axis) > 0
        return (i <= rungs) & elig

    def ladder_body(st):
        i, x_c, info_c, w_c, aw_c, th_c, d_c, rung_c = st
        is1 = i == jnp.int32(1)
        # Rung identity is traced, so every rung shares this ONE solver
        # instance: rung 1 keeps W with a freshly refreshed AW; rungs 2–3
        # zero the basis; rung 3 additionally shifts the operator and
        # gates the preconditioner to identity.
        w_att = jnp.where(is1, w, jnp.zeros_like(w))
        refresh_pred = is1 & had_basis
        if batch_axis is not None:
            refresh_pred = (
                jax.lax.psum(refresh_pred.astype(jnp.int32), batch_axis) > 0
            )
        aw_att = jax.lax.cond(
            refresh_pred,
            lambda _: _apply_basis_flat(A, unravel, w),
            lambda _: jnp.zeros_like(aw_carry),
            None,
        )
        aw_att = jnp.where(is1, aw_att, jnp.zeros_like(aw_att))
        refresh_charge = jnp.where(is1 & had_basis, k, 0).astype(jnp.int32)

        sigma = jnp.where(
            i >= MAX_RECOVERY_RUNGS, recovery_shift, 0.0
        ).astype(zero_dtype)

        def A_rec(v):
            return jax.tree_util.tree_map(
                lambda a_, v_: a_ + sigma * v_, A(v), v
            )

        M_rec = None
        if M is not None:
            use_m = i < MAX_RECOVERY_RUNGS

            def M_rec(v):  # noqa: F811 — identity-gated preconditioner
                return jax.tree_util.tree_map(
                    lambda m_, v_: jnp.where(use_m, m_, v_), M(v), v
                )

        res = defcg(
            A_rec,
            b,
            x0,
            W=w_att,
            AW=aw_att,
            ell=ell,
            tol=tol,
            atol=atol,
            maxiter=maxiter,
            record_residuals=record_residuals,
            waw_jitter=waw_jitter,
            exact_aw=True,
            flat_recycle=True,
            M=M_rec,
            batch_axis=batch_axis,
            stale_guard=None,
            stagnation_window=stagnation_window,
        )
        i2 = res.info
        if ell > 0:
            w2, aw2, th2, d2 = strategy.transition(
                w_att,
                aw_att,
                res.recycle,
                k=k,
                select=select,
                m_apply=m_flat,
            )
        else:
            w2, aw2, th2, d2 = w_att, aw_att, None, d_c

        elig = _eligible(i, info_c)
        # Keep whichever attempt holds the better residual (a broken or
        # non-finite incumbent loses naturally), but always carry the
        # rung's freshly extracted basis and the honest matvec total.
        warm_ok = jnp.isfinite(info_c.residual_norm) & (
            ~info_c.breakdown
        )
        take_x = elig & (
            (~warm_ok) | (i2.residual_norm < info_c.residual_norm)
        )
        selx = lambda a, b_: jnp.where(take_x, a, b_)  # noqa: E731
        sel = lambda a, b_: jnp.where(elig, a, b_)  # noqa: E731
        x_n = selx(pt.ravel(res.x), x_c)
        info_n = SolveInfo(
            iterations=selx(i2.iterations, info_c.iterations),
            converged=selx(i2.converged, info_c.converged),
            residual_norm=selx(i2.residual_norm, info_c.residual_norm),
            matvecs=sel(
                i2.matvecs + info_c.matvecs + refresh_charge,
                info_c.matvecs,
            ),
            residual_norms=(
                None
                if i2.residual_norms is None
                else selx(i2.residual_norms, info_c.residual_norms)
            ),
            breakdown=selx(i2.breakdown, info_c.breakdown),
            status=selx(i2.status, info_c.status),
            guard_fired=info_c.guard_fired,
        )
        th_n = None if th2 is None else sel(th2, th_c)
        return (
            i + 1,
            x_n,
            info_n,
            sel(w2, w_c),
            sel(aw2, aw_c),
            th_n,
            sel(d2, d_c),
            jnp.where(elig, i, rung_c).astype(jnp.int32),
        )

    st = (
        jnp.int32(1),
        pt.ravel(result.x),
        info,
        w_next,
        aw_next,
        theta,
        drift_next,
        rung0,
    )
    _, x_fin, info_fin, w_fin, aw_fin, th_fin, d_fin, rung_fin = (
        jax.lax.while_loop(ladder_cond, ladder_body, st)
    )
    # Terminal retirement: a solve that is STILL broken after the whole
    # ladder (a persistently-corrupted operator) must neither return
    # non-finite coordinates nor hand a poisoned subspace to the next
    # system/tenant.  The solution falls back to the finite warm start
    # (or zeros) and the carried state is zeroed — the sequence
    # re-bootstraps cold from the next system on.  Status/residual stay
    # honest: the report still says BREAKDOWN_*.
    x_safe = (
        jnp.zeros_like(x_fin)
        if x0 is None
        else pt.ravel(x0).astype(x_fin.dtype)
    )
    x_safe = jnp.where(jnp.isfinite(x_safe), x_safe, 0.0)
    x_fin = jnp.where(jnp.all(jnp.isfinite(x_fin)), x_fin, x_safe)
    retire = (
        info_fin.breakdown
        | ~jnp.all(jnp.isfinite(w_fin))
        | ~jnp.all(jnp.isfinite(aw_fin))
    )
    w_fin = jnp.where(retire, 0.0, w_fin)
    aw_fin = jnp.where(retire, 0.0, aw_fin)
    if th_fin is not None:
        th_fin = jnp.where(retire, 0.0, th_fin)
    d_fin = jnp.where(retire, jnp.zeros_like(d_fin), d_fin)
    return (
        unravel(x_fin), info_fin, w_fin, aw_fin, th_fin, d_fin, rung_fin,
    )


# ---------------------------------------------------------------------------
# The device-resident sequence engine
# ---------------------------------------------------------------------------


class SequenceResult(NamedTuple):
    """Stacked outputs of :func:`solve_sequence` (leading axis = system)."""

    x: Pytree  # per-system solutions
    info: SolveInfo  # per-system diagnostics (all fields stacked)
    theta: jnp.ndarray  # (num_systems, k) harmonic Ritz values
    W: jnp.ndarray  # final recycled basis, flat (k, n)
    AW: jnp.ndarray  # its A-products under the last refresh
    drift: Optional[jnp.ndarray] = None  # final strategy drift carry
    rung: Optional[jnp.ndarray] = None  # (num_systems,) recovery rung taken


def solve_sequence(
    systems: Any,
    b_seq: Pytree,
    W0: Optional[jnp.ndarray] = None,
    AW0: Optional[jnp.ndarray] = None,
    *,
    k: int,
    ell: int,
    make_operator: Optional[Callable[[Any], Any]] = None,
    make_preconditioner: Optional[Callable[[Any], Any]] = None,
    tol: float = 1e-5,
    atol: float = 0.0,
    maxiter: int = 1000,
    select: str = "largest",
    waw_jitter: float = DEFAULT_WAW_JITTER,
    refresh_aw: str = "exact",
    carry_x: bool = False,
    strategy: Optional[RecycleStrategy] = None,
    drift0: Optional[jnp.ndarray] = None,
    divergence_fallback: bool = True,
    batch_axis: Optional[str] = None,
    recovery_rungs: Optional[int] = None,
    recovery_shift: float = 1e-6,
    stagnation_window: int = 0,
    x_prev0: Optional[jnp.ndarray] = None,
) -> SequenceResult:
    """Solve a whole sequence of related SPD systems on-device.

    This is the paper's outer loop (§2.3, Fig. 1–2) as a single
    ``lax.scan``: the recycled basis ``(W, AW)`` and (optionally) the
    warm-start solution are carried as flat device arrays across systems,
    every solve runs the flat def-CG engine, the basis refresh is ONE
    multi-RHS operator application, and the harmonic-Ritz extraction is
    the masked flat form — zero host syncs between systems, so the whole
    sequence jits (and pjit-shards) as one XLA computation.

    Args:
      systems: a pytree of per-system operator data with a leading
        system axis on every leaf — either a stacked operator pytree
        (e.g. a ``KernelSystemOperator`` whose ``sqrt_h`` is ``(N, n)``)
        consumed directly, or raw data mapped through ``make_operator``.
      b_seq: stacked right-hand sides (leading system axis on each leaf).
      W0, AW0: optional initial flat ``(k, n)`` recycled basis and its
        A-products.  ``None`` bootstraps from zeros: system 1 then runs
        an exact no-op deflation (plain CG + recording), exactly how a
        sequence starts cold.
      make_operator: maps one system slice to an SPD operator
        (``None`` → the slice *is* the operator).  Must be a stable
        callable for jit caching.
      make_preconditioner: optional stable callable mapping the per-system
        operator to an SPD preconditioner apply ``M`` (``None`` → no
        preconditioning).  Every solve in the scan then runs the
        split-preconditioned def-CG (see :func:`repro.core.solvers.defcg`)
        — deflation and preconditioning compose.
      refresh_aw: ``"exact"`` — recompute ``A⁽ⁱ⁾W`` per system with one
        multi-RHS pass (k matvecs of accounted cost); ``"stale"`` — reuse
        the extraction's ``AW`` (zero matvecs, approximate deflation, the
        paper's cheap mode; def-CG spends one true matvec re-deriving r₀).
        Stale deflation is exact for an unchanged operator (multiple RHS)
        but can destabilize the conjugacy recurrence under drift —
        ``divergence_fallback`` (below) catches that on-device, and the
        :class:`repro.core.strategies.WindowedRecombine` strategy is the
        *guarded* form of this mode (prefer it over a bare
        ``refresh_aw="stale"`` for drifting sequences).
      carry_x: warm-start each system with the previous solution
        (Alg. 1's ``x_{-1}``).
      strategy: the :class:`repro.core.strategies.RecycleStrategy` owning
        the per-system refresh policy and end-of-solve transition
        (``None`` → :class:`repro.core.strategies.HarmonicRitz`, the
        incumbent behavior).  The strategy's drift measurement rides in
        the scan carry — still zero host syncs.
      drift0: initial drift carry (a previous ``SequenceResult.drift`` /
        ``RecycleState.drift``; ``None`` → 0).
      divergence_fallback: legacy switch for the per-system recovery
        ladder: ``True`` (default) arms the full ladder
        (``recovery_rungs=3``), ``False`` disarms it entirely.
        Superseded by ``recovery_rungs`` (which wins when given).
      batch_axis: vmap axis name for the all-tenants-converged matvec
        gate (see :func:`repro.core.solvers.defcg`); ``solve_batch``
        sets it.
      recovery_rungs: explicit rung count for the escalating recovery
        ladder each system of the scan runs on breakdown/non-convergence
        — see :func:`_one_recycled_solve` for the rung semantics
        (refresh-AW-and-redo → drop basis → shifted plain CG).  A failed
        attempt's matvecs are folded into the reported totals and the
        sequence continues from the rung's freshly extracted basis.
        ``None`` defers to ``divergence_fallback``.
      recovery_shift: σ of the rung-3 ``A + σI`` shift.
      stagnation_window: per-solve stalled-residual detector window
        (see :func:`repro.core.solvers.defcg`); 0 disables.
      x_prev0: initial flat ``(n,)`` warm-start carry for ``carry_x``
        mode — lets a chunked/resumed driver continue a sequence exactly
        where a previous call stopped (``None`` → zeros, the cold
        start).

    Returns:
      :class:`SequenceResult` with per-system solutions/diagnostics and
      the final basis, ready to seed the next call.  Its ``rung`` field
      records the per-system recovery rung taken (0 = clean).
    """
    if refresh_aw not in ("exact", "stale"):
        raise ValueError(f"unknown refresh_aw={refresh_aw!r}")
    if refresh_aw == "stale" and W0 is not None and AW0 is None:
        # A zero AW against a real W makes the deflated initial guess
        # garbage while the residual still converges — a silently wrong
        # "solution".  Stale mode never recomputes AW, so it must be fed.
        raise ValueError("refresh_aw='stale' with W0 requires AW0")
    strategy = HarmonicRitz() if strategy is None else strategy
    make_op = make_operator if make_operator is not None else (lambda s: s)

    b0 = jax.tree_util.tree_map(lambda l: l[0], b_seq)
    b0_flat, unravel = pt.ravel_vector(b0)
    n = b0_flat.shape[0]
    dtype = b0_flat.dtype

    w_init = jnp.zeros((k, n), dtype) if W0 is None else W0.astype(dtype)
    aw_init = (
        jnp.zeros((k, n), dtype)
        if (AW0 is None or W0 is None)
        else AW0.astype(dtype)
    )
    x_init = (
        jnp.zeros((n,), dtype) if x_prev0 is None else x_prev0.astype(dtype)
    )
    drift_init = (
        jnp.zeros((), dtype) if drift0 is None else drift0.astype(dtype)
    )
    if recovery_rungs is None:
        recovery_rungs = MAX_RECOVERY_RUNGS if divergence_fallback else 0

    def body(carry, xs):
        w, aw, drift, x_prev = carry
        sys_i, b = xs
        A = make_op(sys_i)
        x0 = unravel(x_prev) if carry_x else None
        M = (
            make_preconditioner(A)
            if make_preconditioner is not None
            else None
        )
        # Per-system semantics (refresh, accounting, extraction, and the
        # recovery ladder) live in ONE place, shared with the
        # single-system front door.
        x_out, info, w2, aw2, theta, drift2, rung = _one_recycled_solve(
            A,
            b,
            x0,
            w,
            aw,
            drift,
            unravel=unravel,
            k=k,
            ell=ell,
            tol=tol,
            atol=atol,
            maxiter=maxiter,
            select=select,
            waw_jitter=waw_jitter,
            refresh_aw=refresh_aw,
            strategy=strategy,
            M=M,
            batch_axis=batch_axis,
            recovery_rungs=recovery_rungs,
            recovery_shift=recovery_shift,
            stagnation_window=stagnation_window,
        )
        x_flat = pt.ravel(x_out)
        return (w2, aw2, drift2, x_flat), (x_out, info, theta, rung)

    (w_fin, aw_fin, drift_fin, _), (xs_out, infos, thetas, rungs) = (
        jax.lax.scan(
            body, (w_init, aw_init, drift_init, x_init), (systems, b_seq)
        )
    )
    return SequenceResult(
        x=xs_out, info=infos, theta=thetas, W=w_fin, AW=aw_fin,
        drift=drift_fin, rung=rungs,
    )


solve_sequence_jit = jax.jit(
    solve_sequence,
    static_argnames=(
        "k",
        "ell",
        "make_operator",
        "make_preconditioner",
        "tol",
        "atol",
        "maxiter",
        "select",
        "waw_jitter",
        "refresh_aw",
        "carry_x",
        "strategy",
        "divergence_fallback",
        "batch_axis",
        "recovery_rungs",
        "recovery_shift",
        "stagnation_window",
    ),
)


def _apply_basis_maybe_jit(A, W):
    """One multi-RHS ``A @ W`` — jitted when A is a pytree node
    (stable-closure operators hit the jit cache), eager otherwise."""
    try:
        return _apply_basis_jitted(A, W)
    except TypeError:  # A is a bare callable, not a registered pytree node
        return ops_mod.apply_to_basis(A, W)


@jax.jit
def _apply_basis_jitted(A, W):
    return ops_mod.apply_to_basis(A, W)


@dataclasses.dataclass
class RecycleManager:
    """Carries the recycled subspace across a *sequence* of SPD systems.

    This object is the host-driven convenience wrapper over the sequence
    engine: call :meth:`solve` once per system ``A⁽ⁱ⁾ x = b⁽ⁱ⁾``; it runs
    ``def-CG(k, ell)`` with the current recycled basis (plain CG +
    recording for the first system), then refreshes the basis by the flat
    masked harmonic-Ritz extraction — the stored count stays a device
    scalar (no host round-trip), and the ``AW`` refresh is one multi-RHS
    operator application.  Fully-jitted outer loops should scan
    :func:`solve_sequence` instead (one XLA computation, zero host
    involvement between systems); the manager adds host-side resilience
    (breakdown fallback) on the same primitives.

    ``refresh_aw`` controls how ``A⁽ⁱ⁺¹⁾W`` is obtained:

    * ``"exact"`` — recompute with one multi-RHS pass (k matvecs of
      operator work — the O(k n²) overhead the paper accounts for in
      §2.2).  Deflation identities hold exactly.
    * ``"stale"`` — reuse ``A⁽ⁱ⁾W = AZ·U`` from the extraction (zero
      matvecs; this matches the paper's ``O(n²(ℓ+1)k)`` cost accounting
      for obtaining *both* W and AW from stored quantities).  The
      deflation projector is then approximate, and with operator drift
      the error compounds through the direction recurrence: ``Wᵀr = 0``
      is no longer maintained, the CG step scalars lose their line-search
      property, and the solve can *diverge* outright (observed; the
      extreme form of the Fig. 2 stagnation).  The breakdown fallback
      below catches exactly this — it re-solves clean and, since the
      accounting fix, reports the true total cost including the failed
      attempt.  Stale mode is exact (and safe) when the operator is
      unchanged between systems — the multiple-RHS setting.  The
      ``strategy`` field generalizes this switch:
      :class:`repro.core.strategies.WindowedRecombine` is the guarded
      stale mode (drift measured for free, refresh only when needed).

    ``reuse_aw=True`` on a call additionally declares the operator
    unchanged since the previous solve (multiple RHS against one matrix).

    ``strategy`` selects the :class:`repro.core.strategies.RecycleStrategy`
    owning the refresh decision (its host-side
    ``manager_wants_refresh`` mirror) and the end-of-solve transition;
    the strategy's drift measurement is carried in ``state.drift``.

    The manager carries a :class:`RecycleState` (flat ``(k, n)`` device
    arrays): it shards like the solution vector, persists on-device across
    systems, and is checkpointable (``repro.checkpoint`` saves it with the
    train state).  ``W``/``AW``/``theta`` remain readable as properties.
    """

    k: int
    ell: int
    select: str = "largest"
    tol: float = 1e-5
    maxiter: int = 1000
    waw_jitter: float = DEFAULT_WAW_JITTER
    refresh_aw: str = "exact"  # "exact" | "stale" (see class docstring)
    strategy: RecycleStrategy = HarmonicRitz()
    use_jit: bool = True
    state: Optional[RecycleState] = None
    systems_solved: int = 0
    _has_aw: bool = False  # state.AW holds real A-products (not placeholder)

    @property
    def W(self) -> Optional[jnp.ndarray]:
        """Flat ``(m, n)`` recycled basis rows, or None before bootstrap."""
        return None if self.state is None else self.state.W

    @property
    def AW(self) -> Optional[jnp.ndarray]:
        """A-products of ``W`` (None when seeded without them)."""
        if self.state is None or not self._has_aw:
            return None
        return self.state.AW

    @property
    def theta(self) -> Optional[jnp.ndarray]:
        return None if self.state is None else self.state.theta

    def seed(self, W: Pytree, AW: Optional[Pytree] = None) -> None:
        """Seed the recycle space a priori (e.g. Nyström vectors — the
        paper's §1.1 'guessed projective space as first initialization').

        ``W`` is a stacked basis (pytree or flat ``(m, n)``) of at most
        ``self.k`` vectors; shape/k-consistency is validated HERE, with a
        host-side error, instead of surfacing as an XLA shape failure in
        the middle of the next solve.
        """
        w_flat = pt.ravel_basis(W)
        m = w_flat.shape[0]
        if not 1 <= m <= self.k:
            raise ValueError(
                f"seed basis has {m} vectors; RecycleManager(k={self.k}) "
                f"can carry between 1 and {self.k}"
            )
        aw_flat = None
        if AW is not None:
            if jax.tree_util.tree_structure(
                AW
            ) != jax.tree_util.tree_structure(W):
                raise ValueError(
                    "seed AW must have the same pytree structure as W, got "
                    f"{jax.tree_util.tree_structure(AW)} vs "
                    f"{jax.tree_util.tree_structure(W)}"
                )
            aw_flat = pt.ravel_basis(AW)
            if aw_flat.shape != w_flat.shape:
                raise ValueError(
                    f"seed AW shape {aw_flat.shape} does not match W "
                    f"shape {w_flat.shape}"
                )
        self.state = RecycleState(
            W=w_flat,
            AW=jnp.zeros_like(w_flat) if aw_flat is None else aw_flat,
            theta=jnp.zeros((m,), w_flat.dtype),
            systems_solved=jnp.int32(self.systems_solved),
            drift=jnp.zeros((), w_flat.dtype),
        )
        self._has_aw = aw_flat is not None

    def solve(
        self,
        A,
        b: Pytree,
        x0: Optional[Pytree] = None,
        *,
        reuse_aw: bool = False,
        tol: Optional[float] = None,
        maxiter: Optional[int] = None,
        record_residuals: bool = False,
        M=None,
    ) -> CGResult:
        tol = self.tol if tol is None else tol
        maxiter = self.maxiter if maxiter is None else maxiter
        if self.strategy.needs_preconditioner and M is None:
            # Without M the M-geometry transition would silently degrade
            # to the Euclidean extraction — the SolveSpec path rejects
            # this combination too (spec validation).
            raise ValueError(
                f"strategy={type(self.strategy).__name__} extracts in the "
                "preconditioner's geometry — pass M to every solve()"
            )

        w_flat = self.state.W if self.state is not None else None
        aw_flat = self.AW  # None when seeded without A-products
        # A basis with no A-products at all (seed() without AW) must be
        # refreshed even under reuse_aw — there is nothing to reuse.
        # Otherwise the refresh decision belongs to the strategy (exact
        # policy / drift guard / pure stale) — the host-side mirror of
        # ``strategy.prepare`` on the device paths.
        drift = (
            self.state.drift if self.state is not None else jnp.float32(0.0)
        )
        needs_fresh = w_flat is not None and (
            aw_flat is None
            or (
                not reuse_aw
                and self.strategy.manager_wants_refresh(
                    self.refresh_aw, drift, tol
                )
            )
        )
        if needs_fresh:
            _, unravel = pt.ravel_vector(b)
            basis = pt.unravel_basis(w_flat, unravel)
            aw = (
                _apply_basis_maybe_jit(A, basis)
                if self.use_jit
                else ops_mod.apply_to_basis(A, basis)
            )
            aw_flat = pt.ravel_basis(aw)

        solve_fn = defcg_jit if self.use_jit else defcg
        exact_aw = needs_fresh or reuse_aw or w_flat is None
        result = solve_fn(
            A,
            b,
            x0,
            W=w_flat,
            AW=aw_flat,
            ell=self.ell,
            tol=tol,
            maxiter=maxiter,
            record_residuals=record_residuals,
            waw_jitter=self.waw_jitter,
            exact_aw=exact_aw,
            flat_recycle=True,  # _refresh consumes (P, AP) flat
            M=M,
            # A stale solve gets the strategy's in-solve drift guard —
            # the same layer-2 protection the device paths arm through
            # strategy.prepare (its k-matvec refresh is charged by defcg).
            stale_guard=(
                None if exact_aw else self.strategy.in_solve_guard(tol)
            ),
        )
        if result.recycle is not None and result.recycle.aw_used is not None:
            # The in-solve guard may have refreshed — extract from what
            # the solve actually deflated with.
            aw_flat = result.recycle.aw_used
        # Charge what the refresh actually computed: a seeded basis may
        # hold fewer than self.k vectors.
        refresh_cost = w_flat.shape[0] if needs_fresh else 0

        if w_flat is not None and (
            bool(result.info.breakdown) or not bool(result.info.converged)
        ):
            # Resilience: a stale/ill-conditioned basis can poison the
            # conjugacy recurrences.  Drop it and re-solve clean — the
            # sequence continues with a freshly bootstrapped space.  The
            # failed attempt's matvecs (and the refresh spent on the
            # discarded basis) were still paid — fold them into the
            # reported total rather than silently dropping them.
            failed_matvecs = result.info.matvecs
            self.state = None
            self._has_aw = False
            w_flat = aw_flat = None
            result = solve_fn(
                A, b, x0,
                ell=self.ell, tol=tol, maxiter=maxiter,
                record_residuals=record_residuals,
                flat_recycle=True,
                M=M,
            )
            result = result._replace(
                info=result.info._replace(
                    matvecs=result.info.matvecs
                    + failed_matvecs
                    + refresh_cost
                )
            )
        elif refresh_cost:
            result = result._replace(
                info=result.info._replace(
                    matvecs=result.info.matvecs + refresh_cost
                )
            )
        self.systems_solved += 1
        self._refresh(result, w_flat, aw_flat, b=b, M=M)
        return result

    # -- internal ----------------------------------------------------------
    def _refresh(
        self,
        result: CGResult,
        w_flat: Optional[jnp.ndarray],
        aw_flat: Optional[jnp.ndarray],
        *,
        b: Pytree,
        M=None,
    ) -> None:
        rec = result.recycle
        if rec is None:
            return
        if int(rec.stored) == 0:
            # Nothing recorded (0-iteration solve: x0 was already exact) —
            # keep the current basis as-is.  In particular a None state
            # must stay None, not become a phantom zero basis that every
            # later solve "refreshes" for k wasted matvecs.  This scalar
            # read costs nothing extra: solve() already synced on
            # result.info.converged, so the value is sitting on the host
            # side of a completed computation — unlike the old path, it
            # gates no shapes and triggers no per-count recompiles.
            return
        # Strategy-owned transition on the flat masked extraction: the
        # dynamic stored count feeds the jitted extraction as a device
        # scalar (the pre-flat-engine path static-sliced on it,
        # recompiling for every distinct count).
        P, AP = rec.P, rec.AP  # already flat (flat_recycle=True)
        k = min(self.k, P.shape[0] + (0 if w_flat is None else w_flat.shape[0]))
        if self.strategy.needs_preconditioner and M is not None:
            # M-geometry needs the flat M⁻¹ apply — a per-call closure,
            # so this path runs eagerly (the front doors jit it whole).
            _, unravel = pt.ravel_vector(b)
            W_new, AW_new, theta, drift = self.strategy.transition(
                w_flat, aw_flat, rec, k=k, select=self.select,
                m_apply=_flat_operator(M, unravel),
            )
        elif self.use_jit:
            W_new, AW_new, theta, drift = _strategy_transition_jit(
                self.strategy, w_flat, aw_flat, rec, k, self.select
            )
        else:
            W_new, AW_new, theta, drift = self.strategy.transition(
                w_flat, aw_flat, rec, k=k, select=self.select
            )
        self.state = RecycleState(
            W=W_new,
            AW=AW_new,
            theta=theta,
            systems_solved=jnp.int32(self.systems_solved),
            drift=drift,
        )
        self._has_aw = True


_extract_next_basis_jit = jax.jit(
    _extract_next_basis, static_argnames=("k", "select", "jitter")
)


@functools.partial(
    jax.jit, static_argnames=("strategy", "k", "select")
)
def _strategy_transition_jit(strategy, w_flat, aw_flat, window, k, select):
    """Jitted strategy transition for the host-driven manager (strategies
    are hashable static config; the window rides in as a traced pytree)."""
    return strategy.transition(w_flat, aw_flat, window, k=k, select=select)


def recycled_solve_jit(
    A,
    b: Pytree,
    x0: Pytree,
    W: Pytree,
    *,
    k: int,
    ell: int,
    tol: float,
    maxiter: int,
    select: str = "largest",
) -> Tuple[Pytree, Pytree, CGResult]:
    """Single-shot, fully traceable solve+extract for jitted outer loops.

    One step of the sequence engine for callers that carry ``W`` in their
    own state (the Hessian-free optimizer): one multi-RHS ``AW`` refresh,
    a flat def-CG solve, and the masked flat extraction.  The recording
    window no longer needs a ``min_iters`` floor — a partially filled
    window extracts through the validity mask, so early-converging solves
    stop early instead of burning ``ell`` matvecs to fill buffers.

    Callers bootstrap with a random orthonormal basis, which is a valid
    (merely unhelpful) deflation space.  Returns ``(W_next, x, result)``.
    """
    AW = ops_mod.apply_to_basis(A, W)
    result = defcg(
        A,
        b,
        x0,
        W=W,
        AW=AW,
        ell=ell,
        tol=tol,
        maxiter=maxiter,
        flat_recycle=True,
    )
    _, unravel = pt.ravel_vector(b)
    w_flat = pt.ravel_basis(W)
    aw_flat = pt.ravel_basis(AW)
    W_next, _, _ = _extract_next_basis(
        w_flat,
        aw_flat,
        result.recycle.P,
        result.recycle.AP,
        result.recycle.stored,
        k,
        select=select,
    )
    result = result._replace(
        info=result.info._replace(
            matvecs=result.info.matvecs + pt.basis_size(W)
        )
    )
    return pt.unravel_basis(W_next, unravel), result.x, result


def random_orthonormal_basis(key, template: Pytree, k: int) -> Pytree:
    """k orthonormal random vectors shaped like ``template`` (bootstrap W)."""
    vs = []
    for i in range(k):
        key, sub = jax.random.split(key)
        v = pt.tree_random_like(sub, template)
        for u in vs:
            v = pt.tree_axpy(-pt.tree_dot(u, v), u, v)
        v = pt.tree_scale(1.0 / pt.tree_norm(v), v)
        vs.append(v)
    return pt.basis_from_vectors(vs)
