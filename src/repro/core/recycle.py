"""Krylov subspace recycling: harmonic-Ritz extraction + cross-system state.

This is the paper's §2.3.  After def-CG solves system ``i`` we have

    Z  = [W, P_ell]        (k + ell stacked vectors)
    AZ = [AW, AP_ell]

and the harmonic projection (Morgan 1995) asks for ``(θ, u)`` with

    (AZ)ᵀ (AZ u − θ Z u) = 0    ⇔    G u = θ F u,
    G = (AZ)ᵀ(AZ)  (SPD),   F = (AZ)ᵀ Z = ZᵀAZ  (symmetric for A = Aᵀ).

We reduce the generalized problem with a Cholesky of ``G``:

    G = LLᵀ,  w = Lᵀu :   (L⁻¹ F L⁻ᵀ) w = (1/θ) w,

a small ``(k+ell)²`` symmetric eigenproblem solved identically (replicated)
on every device — far cheaper than any distributed scheme at these sizes.
The k selected Ritz vectors ``W' = Z U`` (and ``A W' = AZ · U``, free) are
the recycled deflation space for the *next* system in the sequence.

Column equilibration: the generalized eigenproblem is invariant under
column scaling ``Z → Z D`` (``G → DGD``, ``F → DFD``, ``θ`` unchanged), so
we scale every column to unit ``‖AZ_i‖`` before factoring — this keeps the
Cholesky well-posed even when late CG directions have tiny norms.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import pytree as pt
from repro.core.solvers import CGResult, defcg, defcg_jit

Pytree = Any


def harmonic_ritz(
    Z: Pytree,
    AZ: Pytree,
    k: int,
    *,
    select: str = "largest",
    jitter: float = 1e-10,
) -> Tuple[Pytree, Pytree, jnp.ndarray]:
    """Extract ``k`` harmonic Ritz pairs from the basis ``Z`` (see module doc).

    Args:
      Z, AZ: stacked bases of m ≥ k vectors and their A-products.
      k: number of Ritz vectors to keep.
      select: ``"largest"`` (deflate the top of the spectrum — the right
        choice for the paper's ``A = I + H½KH½`` whose spectrum clusters at
        1 with large outliers) or ``"smallest"``.
      jitter: relative diagonal regularization for the Cholesky of G.

    Returns:
      ``(W, AW, theta)`` — the recycled basis, its A-products, and the k
      harmonic Ritz values (approximate eigenvalues of A).
    """
    m = pt.basis_size(Z)
    if k > m:
        raise ValueError(f"cannot extract k={k} Ritz vectors from m={m} basis")

    # Normalize columns BEFORE forming the grams: late CG directions are
    # orders of magnitude smaller than early ones, and computing ZᵀAZ at
    # mixed scales loses the small columns' entries to rounding (observed:
    # negative "Ritz values" from an SPD operator).  Column scaling is an
    # exact invariance of the generalized problem, so this is free.
    zn = jnp.sqrt(jnp.maximum(jnp.diag(pt.gram(Z, Z)), 1e-300))
    Z = pt.basis_scale_columns(Z, 1.0 / zn)
    AZ = pt.basis_scale_columns(AZ, 1.0 / zn)

    G = pt.gram(AZ, AZ)
    F = pt.gram(AZ, Z)
    F = 0.5 * (F + F.T)

    # Second-stage equilibration on ‖AZ_i‖.
    d = jnp.where(jnp.diag(G) > 0, jnp.diag(G), 1.0) ** -0.5
    G = G * d[:, None] * d[None, :]
    F = F * d[:, None] * d[None, :]

    # Rank-revealing reduction of the generalized problem: eigendecompose
    # G and *project out* its near-null directions (near-dependent Krylov
    # columns otherwise surface as spurious huge Ritz values; observed on
    # long recording windows).  Projected directions get ζ = 0 exactly and
    # the positivity filter below excludes them — shapes stay static.
    lam, qg = jnp.linalg.eigh(G)  # ascending
    eps = jnp.finfo(G.dtype).eps
    rcond = jnp.maximum(jnp.asarray(jitter, G.dtype), 100.0 * eps) * m
    good = lam > rcond * lam[-1]
    s = jnp.where(good, 1.0 / jnp.sqrt(jnp.maximum(lam, 1e-300)), 0.0)
    M = s[:, None] * (qg.T @ F @ qg) * s[None, :]
    M = 0.5 * (M + M.T)
    zeta, Wm = jnp.linalg.eigh(M)  # ascending ζ = 1/θ

    # ζ ≤ 0 can only arise from rounding (A SPD ⇒ θ > 0) — never select it.
    tiny = jnp.asarray(1e-300, zeta.dtype)
    if select == "largest":
        zeta_key = jnp.where(zeta > 0, zeta, jnp.inf)
        order = jnp.argsort(zeta_key)[:k]  # smallest positive ζ → largest θ
    elif select == "smallest":
        zeta_key = jnp.where(zeta > 0, zeta, -jnp.inf)
        order = jnp.argsort(zeta_key)[::-1][:k]
    else:
        raise ValueError(f"unknown select={select!r}")

    w_sel = Wm[:, order]  # (m, k)
    zeta_sel = zeta[order]
    theta = 1.0 / jnp.where(jnp.abs(zeta_sel) > 1e-300, zeta_sel, 1e-300)

    # u = D · Qg S w  (undo reduction and equilibration).
    u = qg @ (s[:, None] * w_sel)
    u = u * d[:, None]

    W = pt.basis_matmul(Z, u)
    AW = pt.basis_matmul(AZ, u)

    # Normalize the recycled vectors to unit 2-norm (pure conditioning).
    col_norms = jnp.sqrt(
        jnp.maximum(jnp.diag(pt.gram(W, W)), jnp.finfo(u.dtype).tiny)
    )
    W = pt.basis_scale_columns(W, 1.0 / col_norms)
    AW = pt.basis_scale_columns(AW, 1.0 / col_norms)
    return W, AW, theta


harmonic_ritz_jit = jax.jit(
    harmonic_ritz, static_argnames=("k", "select", "jitter")
)


def _basis_map_maybe_jit(A, W):
    """``A @ w_i`` for every basis vector — jitted when A is a pytree node
    (stable-closure operators hit the jit cache), eager otherwise."""
    try:
        return _basis_map_jitted(A, W)
    except TypeError:  # A is a bare callable, not a registered pytree node
        return pt.basis_map_vectors(A, W)


@jax.jit
def _basis_map_jitted(A, W):
    return pt.basis_map_vectors(A, W)


@dataclasses.dataclass
class RecycleManager:
    """Carries the recycled subspace across a *sequence* of SPD systems.

    This object is the paper's outer-loop state: call :meth:`solve` once per
    system ``A⁽ⁱ⁾ x = b⁽ⁱ⁾``; it runs ``def-CG(k, ell)`` with the current
    recycled basis (plain CG + recording for the first system), then
    refreshes the basis by harmonic-Ritz extraction.

    ``refresh_aw`` controls how ``A⁽ⁱ⁺¹⁾W`` is obtained:

    * ``"exact"`` — recompute with k fresh matvecs (the O(k n²) overhead the
      paper accounts for in §2.2).  Deflation identities hold exactly.
    * ``"stale"`` — reuse ``A⁽ⁱ⁾W = AZ·U`` from the extraction (zero
      matvecs; this matches the paper's ``O(n²(ℓ+1)k)`` cost accounting for
      obtaining *both* W and AW from stored quantities).  The deflation
      projector is then approximate — CG's own residual recurrence stays
      exact, so the solution is still correct; only the deflation
      *effectiveness* degrades with the drift ‖A⁽ⁱ⁺¹⁾ − A⁽ⁱ⁾‖, which is
      precisely the stagnation the paper observes in Fig. 2.

    ``reuse_aw=True`` on a call additionally declares the operator unchanged
    since the previous solve (multiple RHS against one matrix).

    The manager state (W, AW) is an ordinary pytree of device arrays: it
    shards like the solution vector, persists on-device across systems, and
    is checkpointable (``repro.checkpoint`` saves it with the train state).
    """

    k: int
    ell: int
    select: str = "largest"
    tol: float = 1e-5
    maxiter: int = 1000
    waw_jitter: float = 1e-12
    refresh_aw: str = "exact"  # "exact" | "stale" (see class docstring)
    use_jit: bool = True
    W: Optional[Pytree] = None
    AW: Optional[Pytree] = None
    theta: Optional[jnp.ndarray] = None
    systems_solved: int = 0

    def seed(self, W: Pytree, AW: Optional[Pytree] = None) -> None:
        """Seed the recycle space a priori (e.g. Nyström vectors — the
        paper's §1.1 'guessed projective space as first initialization')."""
        self.W = W
        self.AW = AW

    def solve(
        self,
        A,
        b: Pytree,
        x0: Optional[Pytree] = None,
        *,
        reuse_aw: bool = False,
        tol: Optional[float] = None,
        maxiter: Optional[int] = None,
        record_residuals: bool = False,
    ) -> CGResult:
        tol = self.tol if tol is None else tol
        maxiter = self.maxiter if maxiter is None else maxiter

        AW = self.AW
        needs_fresh = (
            self.W is not None
            and not reuse_aw
            and (AW is None or self.refresh_aw == "exact")
        )
        if needs_fresh:
            AW = (
                _basis_map_maybe_jit(A, self.W)
                if self.use_jit
                else pt.basis_map_vectors(A, self.W)
            )

        solve_fn = defcg_jit if self.use_jit else defcg
        result = solve_fn(
            A,
            b,
            x0,
            W=self.W,
            AW=AW,
            ell=self.ell,
            tol=tol,
            maxiter=maxiter,
            record_residuals=record_residuals,
            waw_jitter=self.waw_jitter,
            exact_aw=needs_fresh or reuse_aw or self.W is None,
        )
        refresh_cost = self.k if needs_fresh else 0

        if self.W is not None and (
            bool(result.info.breakdown) or not bool(result.info.converged)
        ):
            # Resilience: a stale/ill-conditioned basis can poison the
            # conjugacy recurrences.  Drop it and re-solve clean — the
            # sequence continues with a freshly bootstrapped space.
            self.W = self.AW = self.theta = None
            result = solve_fn(
                A, b, x0,
                ell=self.ell, tol=tol, maxiter=maxiter,
                record_residuals=record_residuals,
            )

        if refresh_cost:
            result = result._replace(
                info=result.info._replace(
                    matvecs=result.info.matvecs + refresh_cost
                )
            )
        self.systems_solved += 1
        self._refresh(result, AW)  # AW unused by _refresh when self.W is None
        return result

    # -- internal ----------------------------------------------------------
    def _refresh(self, result: CGResult, AW: Optional[Pytree]) -> None:
        rec = result.recycle
        if rec is None:
            return
        stored = int(rec.stored)  # host sync between systems — cheap
        if stored == 0:
            return
        P = pt.basis_slice(rec.P, stored)
        AP = pt.basis_slice(rec.AP, stored)
        if self.W is not None:
            Z = pt.basis_concat(self.W, P)
            AZ = pt.basis_concat(AW, AP)
        else:
            Z, AZ = P, AP
        k = min(self.k, pt.basis_size(Z))
        extract = harmonic_ritz_jit if self.use_jit else harmonic_ritz
        self.W, self.AW, self.theta = extract(Z, AZ, k, select=self.select)


def recycled_solve_jit(
    A,
    b: Pytree,
    x0: Pytree,
    W: Pytree,
    *,
    k: int,
    ell: int,
    tol: float,
    maxiter: int,
    select: str = "largest",
) -> Tuple[Pytree, Pytree, CGResult]:
    """Single-shot, fully traceable solve+extract for jitted outer loops.

    Unlike :class:`RecycleManager` (host-driven, dynamic stored count), this
    variant is shape-static so it can live *inside* a pjit-ed Hessian-free
    train step: it forces ``min_iters=ell`` (all buffers valid) and always
    deflates with the provided basis ``W`` — callers bootstrap with a random
    orthonormal basis, which is a valid (merely unhelpful) deflation space.

    Returns ``(W_next, x, result)``.
    """
    AW = pt.basis_map_vectors(A, W)
    result = defcg(
        A,
        b,
        x0,
        W=W,
        AW=AW,
        ell=ell,
        tol=tol,
        maxiter=maxiter,
        min_iters=ell,
        waw_jitter=1e-10,
    )
    Z = pt.basis_concat(W, result.recycle.P)
    AZ = pt.basis_concat(AW, result.recycle.AP)
    W_next, _, _ = harmonic_ritz(Z, AZ, k, select=select)
    return W_next, result.x, result


def random_orthonormal_basis(key, template: Pytree, k: int) -> Pytree:
    """k orthonormal random vectors shaped like ``template`` (bootstrap W)."""
    vs = []
    for i in range(k):
        key, sub = jax.random.split(key)
        v = pt.tree_random_like(sub, template)
        for u in vs:
            v = pt.tree_axpy(-pt.tree_dot(u, v), u, v)
        v = pt.tree_scale(1.0 / pt.tree_norm(v), v)
        vs.append(v)
    return pt.basis_from_vectors(vs)
