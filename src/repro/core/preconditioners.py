"""Preconditioners and a-priori low-rank subspaces.

The paper contrasts *recycled* subspaces against the ML-standard *a-priori*
low-rank approximations (Nyström / inducing points, §1.1) and notes the
latter can seed the former.  This module provides:

* :func:`jacobi` — diagonal preconditioning (given the diagonal);
* :func:`randomized_nystrom` — a randomized Nyström low-rank eigensketch of
  a matrix-free SPD operator (sketch → QR → Rayleigh–Ritz), usable both as
  (a) a preconditioner ``M⁻¹ = U (Λ+σ)⁻¹ Uᵀ + (I − UUᵀ)/σ_scale`` and
  (b) an initial deflation basis for :class:`repro.core.recycle.RecycleManager`
      (``seed="nystrom"`` — the paper's 'missing link' initialization).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve

from repro.core import pytree as pt

Pytree = Any


# Preconditioners are *registered pytree nodes* (data in children, no
# closures), so the jitted solver entry points treat ``M`` as a traced
# argument: a Newton loop that rebuilds its preconditioner every system
# (new diag, new sketch) reuses one compiled solve instead of recompiling.
# ``eq=False`` keeps instances hashable (identity) for any caller that
# still routes them through a static argument.


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(eq=False)
class JacobiPreconditioner:
    """``M⁻¹ r = r / diag`` (elementwise, pytree-wise)."""

    diag: Pytree

    def __call__(self, r: Pytree) -> Pytree:
        return jax.tree_util.tree_map(lambda rl, dl: rl / dl, r, self.diag)

    def tree_flatten(self):
        return (self.diag,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(eq=False)
class NystromPreconditioner:
    """``M⁻¹`` from a rank-r Nyström eigensketch ``(U, Λ)`` of ``A``:

        M⁻¹ r = r + U ((λ_min+σ)/(Λ+σ) − 1) Uᵀ r

    (Frangella et al. form; the unsketched bulk is treated as
    ≈ (λ_min+σ) I).  ``U`` is a stacked basis (leading axis = rank) in
    descending eigenvalue order, as :func:`randomized_nystrom` returns.
    """

    U: Pytree
    lam: jnp.ndarray
    sigma: jnp.ndarray

    def __call__(self, r: Pytree) -> Pytree:
        lam_min = self.lam[-1]
        c = pt.basis_dot(self.U, r)
        scale = (lam_min + self.sigma) / (self.lam + self.sigma) - 1.0
        return pt.tree_add(r, pt.basis_combine(self.U, scale * c))

    def tree_flatten(self):
        return (self.U, self.lam, self.sigma), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(eq=False)
class WoodburyKernelPreconditioner:
    """``M⁻¹`` for the Newton-system family ``A_i = I + H½ᵢ K H½ᵢ``.

    The right way to Nyström-precondition a *sequence* whose drift lives
    entirely in ``H``: sketch the INVARIANT ``K ≈ U Λ Uᵀ`` once (per
    hyperparameter setting — it amortizes across every Newton iteration
    and every tenant), then per system take

        M = I + H½ U Λ Uᵀ H½,
        M⁻¹ r = r − H½ U C⁻¹ Uᵀ H½ r,   C = Λ⁻¹ + Uᵀ H U   (Woodbury)

    so the preconditioner tracks the drifting ``H`` exactly at the cost
    of one r×r Cholesky per system (O(r²n) build, O(rn) apply — no
    operator matvecs at all).  Built by
    :func:`kernel_nystrom_preconditioner`; a sketch of ``A_i`` itself
    (:class:`NystromPreconditioner`) goes stale as ``H`` moves.
    """

    sqrt_h: jnp.ndarray  # (n,)
    U: jnp.ndarray  # (r, n) row-stacked sketch basis of K
    chol_c: jnp.ndarray  # Cholesky factor of C = Λ⁻¹ + UᵀHU
    lower: bool = dataclasses.field(default=False)

    def __call__(self, r: jnp.ndarray) -> jnp.ndarray:
        t = self.U @ (self.sqrt_h * r)
        s = cho_solve((self.chol_c, self.lower), t)
        return r - self.sqrt_h * (s @ self.U)

    def tree_flatten(self):
        return (self.sqrt_h, self.U, self.chol_c), (self.lower,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, lower=aux[0])


def kernel_nystrom_preconditioner(
    U: jnp.ndarray, lam: jnp.ndarray, sqrt_h: jnp.ndarray
) -> WoodburyKernelPreconditioner:
    """Bind a (once-per-hyperparameter) Nyström sketch of ``K`` to one
    system's ``H½`` — see :class:`WoodburyKernelPreconditioner`.

    ``(U, lam)`` come from :func:`randomized_nystrom` of the *kernel*
    operator ``v ↦ K v`` (NOT of ``A``); ``U`` is ``(r, n)`` row-stacked.
    Non-positive Ritz values (rank-deficient sketch tails) are clipped
    out — their ``Λ⁻¹`` diverges, which Woodbury turns into an exact
    no-op for that direction.
    """
    U = pt.ravel_basis(U) if not isinstance(U, jnp.ndarray) or U.ndim != 2 else U
    lam_floor = 1e-12 * jnp.maximum(jnp.max(lam), 1.0)
    lam_safe = jnp.maximum(lam, lam_floor)
    uhu = (U * (sqrt_h * sqrt_h)[None, :]) @ U.T
    C = jnp.diag(1.0 / lam_safe) + uhu
    C = 0.5 * (C + C.T)
    chol, lower = cho_factor(C)
    return WoodburyKernelPreconditioner(sqrt_h, U, chol, lower=bool(lower))


def jacobi(diag: Pytree) -> JacobiPreconditioner:
    """``M⁻¹ r = r / diag`` (elementwise, pytree-wise)."""
    return JacobiPreconditioner(diag)


def randomized_nystrom(
    A,
    template: Pytree,
    rank: int,
    key,
    *,
    oversample: int = 8,
) -> Tuple[Pytree, jnp.ndarray]:
    """Randomized Nyström/Rayleigh–Ritz eigensketch of an SPD operator.

    Sketch ``Y = A Ω`` with ``rank+oversample`` Gaussian probes, orthonormalize
    (modified Gram–Schmidt over pytrees), Rayleigh–Ritz on ``QᵀAQ``, keep the
    top ``rank`` pairs.  Costs ``rank+oversample`` matvecs — this is exactly
    the "a-priori subspace, chosen before the solve" cost profile of
    spectral methods the paper compares against.

    Returns ``(U, lam)``: a stacked basis of ``rank`` approximate
    eigenvectors (descending eigenvalue order) and their Ritz values.
    """
    m = rank + oversample
    probes = []
    for _ in range(m):
        key, sub = jax.random.split(key)
        probes.append(pt.tree_random_like(sub, template))

    # Y = A Ω, then modified Gram–Schmidt.
    ys = [A(p) for p in probes]
    qs: list = []
    for y in ys:
        for q in qs:
            y = pt.tree_axpy(-pt.tree_dot(q, y), q, y)
        nrm = pt.tree_norm(y)
        y = jax.tree_util.tree_map(lambda l: l / jnp.maximum(nrm, 1e-30), y)
        qs.append(y)
    Q = pt.basis_from_vectors(qs)

    AQ = pt.basis_map_vectors(A, Q)
    T = pt.gram(Q, AQ)
    T = 0.5 * (T + T.T)
    lam, V = jnp.linalg.eigh(T)  # ascending
    order = jnp.argsort(lam)[::-1][:rank]
    U = pt.basis_matmul(Q, V[:, order])
    return U, lam[order]


def nystrom_preconditioner(
    U: Pytree, lam: jnp.ndarray, sigma: float
) -> NystromPreconditioner:
    """``M⁻¹`` from a Nyström sketch, for ``A ≈ U Λ Uᵀ + σ-bulk``:

        M⁻¹ r = U ((λ_min+σ)/(Λ+σ) − 1) Uᵀ r + r

    scaled so the unsketched bulk is treated as ≈ (λ_min+σ) I.  Standard
    randomized-Nyström PCG preconditioner (Frangella et al. form).
    """
    return NystromPreconditioner(U, lam, jnp.asarray(sigma, lam.dtype))
