"""Preconditioners and a-priori low-rank subspaces.

The paper contrasts *recycled* subspaces against the ML-standard *a-priori*
low-rank approximations (Nyström / inducing points, §1.1) and notes the
latter can seed the former.  This module provides:

* :func:`jacobi` — diagonal preconditioning (given the diagonal);
* :func:`randomized_nystrom` — a randomized Nyström low-rank eigensketch of
  a matrix-free SPD operator (sketch → QR → Rayleigh–Ritz), usable both as
  (a) a preconditioner ``M⁻¹ = U (Λ+σ)⁻¹ Uᵀ + (I − UUᵀ)/σ_scale`` and
  (b) an initial deflation basis for :class:`repro.core.recycle.RecycleManager`
      (``seed="nystrom"`` — the paper's 'missing link' initialization).
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core import pytree as pt

Pytree = Any


def jacobi(diag: Pytree) -> Callable[[Pytree], Pytree]:
    """``M⁻¹ r = r / diag`` (elementwise, pytree-wise)."""

    def apply(r):
        return jax.tree_util.tree_map(lambda rl, dl: rl / dl, r, diag)

    return apply


def randomized_nystrom(
    A,
    template: Pytree,
    rank: int,
    key,
    *,
    oversample: int = 8,
) -> Tuple[Pytree, jnp.ndarray]:
    """Randomized Nyström/Rayleigh–Ritz eigensketch of an SPD operator.

    Sketch ``Y = A Ω`` with ``rank+oversample`` Gaussian probes, orthonormalize
    (modified Gram–Schmidt over pytrees), Rayleigh–Ritz on ``QᵀAQ``, keep the
    top ``rank`` pairs.  Costs ``rank+oversample`` matvecs — this is exactly
    the "a-priori subspace, chosen before the solve" cost profile of
    spectral methods the paper compares against.

    Returns ``(U, lam)``: a stacked basis of ``rank`` approximate
    eigenvectors (descending eigenvalue order) and their Ritz values.
    """
    m = rank + oversample
    probes = []
    for _ in range(m):
        key, sub = jax.random.split(key)
        probes.append(pt.tree_random_like(sub, template))

    # Y = A Ω, then modified Gram–Schmidt.
    ys = [A(p) for p in probes]
    qs: list = []
    for y in ys:
        for q in qs:
            y = pt.tree_axpy(-pt.tree_dot(q, y), q, y)
        nrm = pt.tree_norm(y)
        y = jax.tree_util.tree_map(lambda l: l / jnp.maximum(nrm, 1e-30), y)
        qs.append(y)
    Q = pt.basis_from_vectors(qs)

    AQ = pt.basis_map_vectors(A, Q)
    T = pt.gram(Q, AQ)
    T = 0.5 * (T + T.T)
    lam, V = jnp.linalg.eigh(T)  # ascending
    order = jnp.argsort(lam)[::-1][:rank]
    U = pt.basis_matmul(Q, V[:, order])
    return U, lam[order]


def nystrom_preconditioner(
    U: Pytree, lam: jnp.ndarray, sigma: float
) -> Callable[[Pytree], Pytree]:
    """``M⁻¹`` from a Nyström sketch, for ``A ≈ U Λ Uᵀ + σ-bulk``:

        M⁻¹ r = U ((λ_min+σ)/(Λ+σ) − 1) Uᵀ r + r

    scaled so the unsketched bulk is treated as ≈ (λ_min+σ) I.  Standard
    randomized-Nyström PCG preconditioner (Frangella et al. form).
    """
    lam_min = lam[-1]

    def apply(r):
        c = pt.basis_dot(U, r)
        scale = (lam_min + sigma) / (lam + sigma) - 1.0
        return pt.tree_add(r, pt.basis_combine(U, scale * c))

    return apply
