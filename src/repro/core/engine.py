"""Method-agnostic Krylov iteration harness (DESIGN.md §12).

Every iterative method in this repo — CG, def-CG, and now LSMR — shares
the same loop *scaffolding*: tolerance resolution, typed breakdown
classification with a sticky ``fail`` code, optional stalled-residual
detection, an optional residual-norm trace, honest matvec accounting,
the vmap-aware matvec gate, and the two-phase iteration shape (a
fixed-length masked recording ``lax.scan`` whose stacked outputs are the
recycling window, followed by a buffer-free ``lax.while_loop``).  Before
this module existed all of it lived inside ``core/solvers.py`` and any
second method would have had to copy-paste ~800 lines of it.

The contract a method implements:

* **state** — a flat tuple of traced values, opaque to the harness.
* ``active_fn(state) -> bool`` — whether the next step should run (the
  harness uses it as the while-loop condition AND to freeze scan steps
  after convergence).
* ``step(state, active, gate_matvec) -> (state, emit)`` — one iteration.
  ``active=False`` must freeze the state (masked no-op); ``gate_matvec``
  tells the step it is running inside the fixed-length recording scan,
  where the operator application should hide behind
  :func:`gated_matvec` so converged solves stop paying for it.  ``emit``
  is the per-step recycling record (rows of the window); the harness
  zero-masks it on frozen steps.

:func:`run_recording_loop` drives the two phases;
the classification/status/stagnation helpers are shared verbatim by the
method step functions.  Everything here is shape-static, jit-compatible
and vmap-safe — the harness adds no host syncs of its own.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import pytree as pt

Pytree = Any

# Stagnation test: a new best residual must beat the previous best by at
# least this factor to count as progress.  CG on a hard-but-healthy system
# keeps shaving the residual (1% over `stagnation_window` iterations is a
# very low bar); a solve that is looping on a poisoned recurrence does not.
STAGNATION_RTOL = 0.99


class SolveStatus:
    """Enumerated terminal status of an iterative solve.

    Plain int32 codes (not a Python enum) so they live inside jitted loop
    state and ``jnp.where`` selections.  ``0``/``1`` are the healthy exits;
    anything ``>= BREAKDOWN_NONFINITE`` means the iteration was cut short
    by a detected numerical failure and the recovery ladder
    (``repro.core.recycle``) may have re-solved.
    """

    CONVERGED = 0  # ‖r‖ ≤ max(tol·‖b‖, atol)
    MAXITER = 1  # iteration budget exhausted, no breakdown detected
    BREAKDOWN_NONFINITE = 2  # NaN/Inf in pᵀAp or ‖r‖ (poisoned matvec/basis)
    BREAKDOWN_INDEFINITE = 3  # pᵀAp ≤ 0: operator not SPD along p
    STAGNATED = 4  # residual stalled for `stagnation_window` iters, or diverged

    _NAMES = {
        0: "CONVERGED",
        1: "MAXITER",
        2: "BREAKDOWN_NONFINITE",
        3: "BREAKDOWN_INDEFINITE",
        4: "STAGNATED",
    }

    @classmethod
    def describe(cls, code) -> str:
        """Host-side pretty-printer for a (concrete) status code."""
        return cls._NAMES.get(int(code), f"UNKNOWN({int(code)})")


def classify_breakdown(d, rnorm, diverged_at):
    """Fold breakdown detection into the pᵀAp reduction already computed.

    Returns ``(bad, code)``: ``bad`` flags this iteration as broken and
    ``code`` is the int32 :class:`SolveStatus` cause (0 when healthy).
    Explosive residual growth (past the ``diverged_at`` ceiling) is
    classed as STAGNATED — "stopped converging" covers both stalling and
    running away; the non-finite/indefinite codes are reserved for
    detections at the reduction itself.
    """
    nonfinite = ~jnp.isfinite(d)
    indefinite = (~nonfinite) & (d <= 0.0)
    diverging = rnorm > diverged_at
    bad = nonfinite | indefinite | diverging
    code = jnp.where(
        nonfinite,
        SolveStatus.BREAKDOWN_NONFINITE,
        jnp.where(
            indefinite,
            SolveStatus.BREAKDOWN_INDEFINITE,
            SolveStatus.STAGNATED,
        ),
    )
    return bad, jnp.where(bad, code, 0).astype(jnp.int32)


def exit_status(converged, fail):
    return jnp.where(
        converged,
        SolveStatus.CONVERGED,
        jnp.where(fail > 0, fail, SolveStatus.MAXITER),
    ).astype(jnp.int32)


class SolveInfo(NamedTuple):
    """Diagnostics of an iterative solve (all traced values)."""

    iterations: jax.Array  # int32: iterations executed
    converged: jax.Array  # bool
    residual_norm: jax.Array  # final ‖r‖ (method's convergence quantity)
    matvecs: jax.Array  # total operator applications (A and Aᵀ both count)
    residual_norms: Optional[jax.Array] = None  # (maxiter+1,) trace or None
    breakdown: jax.Array | bool = False  # any in-loop breakdown detected
    status: jax.Array | int = 0  # int32 SolveStatus code of the terminal exit
    guard_fired: jax.Array | bool = False  # in-solve stale_guard refreshed AW


def tolerances(b, tol, atol):
    bnorm = pt.tree_norm(b)
    return jnp.maximum(tol * bnorm, atol), bnorm


def flat_operator(op, unravel):
    """Lift a pytree matvec/preconditioner to flat ``(n,)`` vectors."""

    def mv(v_flat):
        return pt.ravel(op(unravel(v_flat)))

    return mv


def initial_fail(rnorm0):
    """Sticky-fail seed: a non-finite initial residual (poisoned x0 /
    operator / basis) never enters the loop — flag it so the exit status
    reads BREAKDOWN_NONFINITE rather than a 0-iteration MAXITER."""
    return jnp.where(
        jnp.isfinite(rnorm0), 0, SolveStatus.BREAKDOWN_NONFINITE
    ).astype(jnp.int32)


def trace_init(rnorm0, maxiter: int, record: bool):
    """NaN-tailed residual trace, slot 0 pre-filled; ``None`` when off."""
    if not record:
        return None
    trace0 = jnp.full((maxiter + 1,), jnp.nan, dtype=rnorm0.dtype)
    return trace0.at[0].set(rnorm0)


def stagnation_init(rnorm0, window: int):
    """Stall-detector state ``(best, stall)`` — ``None`` when disarmed,
    so the clean path carries no extra loop state."""
    return (rnorm0, jnp.int32(0)) if window > 0 else None


def stagnation_update(stag, rnorm_new, fail, active, window: int):
    """One stall-detector step.  Returns ``(stag, fail)`` with STAGNATED
    latched into the sticky ``fail`` when the best residual has not
    improved by 1% for ``window`` consecutive active iterations."""
    best, stall = stag
    improved = rnorm_new < STAGNATION_RTOL * best
    stall_new = jnp.where(improved, 0, stall + 1).astype(jnp.int32)
    fail = jnp.where(
        (fail == 0) & active & (stall_new >= window),
        SolveStatus.STAGNATED,
        fail,
    ).astype(jnp.int32)
    stag = (
        jnp.where(active, jnp.minimum(best, rnorm_new), best),
        jnp.where(active, stall_new, stall),
    )
    return stag, fail


def psum_merged(parts, axis_name: str):
    """Batch several small reductions into ONE ``psum`` collective.

    ``parts`` is a sequence of per-shard partial reductions (scalars or
    1-D arrays, e.g. ``[pᵀap, rᵀap, apᵀap, AW@ap]``); they are packed
    into one flat vector, reduced with a single ``lax.psum`` over
    ``axis_name``, and unpacked to the original shapes.  This is the
    sharded engine's one-all-reduce-per-iteration contract (DESIGN.md
    §5): every scalar reduction of an iteration must ride this ONE
    collective — the HLO collective-counting pass
    (:func:`repro.launch.hlo_stats.while_body_collectives`) pins it.
    """
    flats = [jnp.ravel(jnp.asarray(p)) for p in parts]
    packed = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
    red = jax.lax.psum(packed, axis_name)
    out, off = [], 0
    for p, f in zip(parts, flats):
        out.append(jnp.reshape(red[off : off + f.shape[0]], jnp.shape(p)))
        off += f.shape[0]
    return out


def gated_matvec(
    apply, v, active, batch_axis: Optional[str], out_like=None
):
    """The recording scan's matvec gate: skip the operator outright once
    the solve has converged.

    Under ``vmap`` a per-lane ``lax.cond`` lowers to a ``select`` (both
    branches execute for every lane), so when ``batch_axis`` names the
    tenant axis the gate reduces ``active`` across it — the cross-tenant
    ``any(active)`` is unbatched, the ``cond`` survives batching, and the
    operator is skipped once EVERY lane is frozen.

    ``out_like`` shapes the skipped branch's zeros for RECTANGULAR
    operators (LSMR's ``A``/``Aᵀ`` map between different spaces); the
    default ``None`` keeps the square contract — zeros shaped like the
    input.
    """
    if batch_axis is None:
        run_mv = active
    else:
        run_mv = jax.lax.psum(active.astype(jnp.int32), batch_axis) > 0
    if out_like is None:
        return jax.lax.cond(run_mv, apply, jnp.zeros_like, v)
    return jax.lax.cond(
        run_mv, apply, lambda _: jnp.zeros_like(out_like), v
    )


def run_recording_loop(
    step: Callable,
    active_fn: Callable,
    state: Tuple,
    *,
    ell: int = 0,
):
    """Drive a method's iteration: recording scan, then plain while-loop.

    Phase 1 (``ell > 0``): exactly ``ell`` ``lax.scan`` steps whose
    stacked ``emit`` outputs are the recycling window — each row is
    written once by the scan, so no ``(ell, n)`` buffer rides through
    loop state (XLA copies loop-carried buffers on masked dynamic row
    writes; scan outputs it writes in place).  Steps after convergence
    are frozen: ``active_fn`` gates the step, the step's matvec hides
    behind :func:`gated_matvec`, and the emitted rows are zero-masked —
    the two-phase split is semantically identical to one guarded loop.

    Phase 2: a buffer-free ``lax.while_loop`` for the remaining
    iterations (``active=True``, matvec ungated).

    Returns ``(final_state, rows)`` where ``rows`` is the stacked emit
    pytree (``None`` when ``ell == 0``).
    """
    rows = None
    if ell > 0:

        def scan_body(state, _):
            active = active_fn(state)
            state, emit = step(state, active, True)
            emit = jax.tree_util.tree_map(
                lambda e: jnp.where(active, e, jnp.zeros_like(e)), emit
            )
            return state, emit

        state, rows = jax.lax.scan(scan_body, state, None, length=ell)

    def cond(state):
        return active_fn(state)

    def body(state):
        return step(state, jnp.bool_(True), False)[0]

    state = jax.lax.while_loop(cond, body, state)
    return state, rows
