"""Fault injection for the solve runtime — proof the ladder works.

The robustness layer (breakdown detection in :mod:`repro.core.solvers`,
the recovery ladder in :mod:`repro.core.recycle`, crash-resumable
sequences in :mod:`repro.core.api`) is only trustworthy if it is
exercised against *actual* faults.  This module supplies the chaos:

* :class:`FaultInjectingOperator` — a registered-pytree wrapper around
  any operator that corrupts its matvec output on demand:

  - ``poison`` (traced): an additive scalar folded into every matvec
    result.  ``nan``/``inf`` model hard numerical corruption (a bad
    reduction, a poisoned kernel tile); a small finite value models a
    bounded perturbation (lossy interconnect, non-deterministic
    accumulation).  Because it is a *traced leaf*, a per-system
    ``(N,)`` poison array scans through the sequence engine — "system i
    of the trace is broken" is just ``poison.at[i].set(nan)`` — and a
    per-tenant array vmaps through :func:`repro.core.solve_batch`.
  - ``at_matvec`` (static): corrupt exactly the ``t``-th *executed*
    matvec, counted on the host through ``io_callback`` — "the solve
    breaks mid-iteration at step t".  Host-counted, so keep it out of
    ``vmap``/multi-device code; it exists for single-solve chaos tests.

* :func:`truncate_latest_checkpoint` — damage the newest checkpoint on
  disk the way a crash mid-write would (manifest present, arrays
  unreadable), to prove ``restore_latest`` falls back and reports the
  skip.

Nothing here is imported by the solver hot path; it is test/benchmark
instrumentation that happens to live next to the code it attacks.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import io_callback

from repro.core import pytree as pt

Pytree = Any


class _HostCounter:
    """Mutable host-side executed-matvec counter.

    Lives in the operator's pytree *aux data*, so it must be hashable
    with identity semantics (jit retraces when the counter object —
    not its value — changes).
    """

    __slots__ = ("count",)

    def __init__(self):
        self.count = 0

    def tick(self) -> np.int32:
        self.count += 1
        return np.int32(self.count)

    def reset(self):
        self.count = 0


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FaultInjectingOperator:
    """Wrap any operator ``A`` and corrupt selected matvec outputs.

    Attributes:
      base: the wrapped operator (any callable pytree; its traced leaves
        remain traced through this wrapper).
      poison: traced additive scalar applied to EVERY matvec result.
        ``0.0`` is a bit-exact no-op on the output values (``out + 0``),
        ``nan``/``inf`` is hard corruption, small finite values are
        bounded perturbations.  May be a per-system/per-tenant array
        upstream, sliced to a scalar by scan/vmap by the time it
        reaches this operator.
      at_matvec: 0-based index of the single executed matvec to poison
        with NaN, counted host-side across ALL applications of this
        operator instance (including basis refreshes).  ``None``
        disables the counter entirely — the operator stays pure and
        vmap/scan-safe.
      counter: the host counter backing ``at_matvec`` (auto-created).
        Call :meth:`reset` between solves to re-arm.
    """

    base: Any
    poison: jnp.ndarray = 0.0
    at_matvec: Optional[int] = None
    counter: Optional[_HostCounter] = None

    def __post_init__(self):
        if self.at_matvec is not None and self.counter is None:
            object.__setattr__(self, "counter", _HostCounter())

    def reset(self):
        """Re-arm the ``at_matvec`` trigger (no-op without one)."""
        if self.counter is not None:
            self.counter.reset()

    @property
    def executed_matvecs(self) -> int:
        """Host-observed matvec count (0 without an ``at_matvec`` trigger)."""
        return self.counter.count if self.counter is not None else 0

    def __call__(self, v: Pytree) -> Pytree:
        out = self.base(v)
        flat, unravel = pt.ravel_vector(out)
        bad = jnp.asarray(self.poison, flat.dtype)
        if self.at_matvec is not None:
            t = io_callback(
                self.counter.tick,
                jax.ShapeDtypeStruct((), np.int32),
                ordered=False,
            )
            hit = (t - 1) == self.at_matvec
            bad = bad + jnp.where(hit, jnp.asarray(jnp.nan, flat.dtype), 0)
        return unravel(flat + bad)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.base, self.poison), (self.at_matvec, self.counter)

    @classmethod
    def tree_unflatten(cls, aux, children):
        base, poison = children
        at_matvec, counter = aux
        return cls(base, poison, at_matvec, counter)


def truncate_latest_checkpoint(directory: str) -> Optional[int]:
    """Damage the newest checkpoint like a crash mid-write would.

    Replaces its ``arrays.npz`` with garbage bytes while leaving the
    manifest intact — the checkpoint directory looks committed but its
    payload is unreadable, exactly the state a host death between the
    array write and the atomic rename cannot produce but a torn disk
    can.  Returns the damaged step number, or ``None`` if the directory
    holds no checkpoints.
    """
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    if not steps:
        return None
    step = max(steps)
    payload = os.path.join(directory, f"step_{step:08d}", "arrays.npz")
    with open(payload, "wb") as f:
        f.write(b"not an npz: torn write")
    return step
