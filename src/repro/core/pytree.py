"""Vector-space operations over arbitrary pytrees.

Every iterative solver in :mod:`repro.core` treats "a vector" as an
arbitrary pytree of arrays (a flat ``(n,)`` array, a dict of model
parameters, ...).  This module provides the small linear-algebra
vocabulary the solvers need — inner products, AXPYs, and *stacked bases*.

A **basis** is a pytree with the same structure as a vector but where every
leaf carries one extra *leading* axis of size ``m``: it represents ``m``
stacked vectors (e.g. the deflation space ``W`` of def-CG).  Basis
operations (``basis_dot``, ``basis_combine``, ``gram``) are the tall-skinny
GEMMs of subspace recycling; under pjit they lower to per-shard contractions
plus a single all-reduce, which is exactly the collective profile we want on
a TPU mesh.

All functions are pure and jit-compatible.
"""

from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.flatten_util
import jax.numpy as jnp

Pytree = Any


# ---------------------------------------------------------------------------
# Elementary vector-space ops
# ---------------------------------------------------------------------------


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(alpha, a: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda x: alpha * x, a)


def tree_axpy(alpha, x: Pytree, y: Pytree) -> Pytree:
    """``y + alpha * x`` (the BLAS axpy, pytree-wise)."""
    return jax.tree_util.tree_map(lambda xl, yl: yl + alpha * xl, x, y)


def tree_zeros_like(a: Pytree) -> Pytree:
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_dot(a: Pytree, b: Pytree):
    """Global inner product ``<a, b>`` reduced over every leaf.

    Accumulates in at least float32 regardless of the storage dtype so that
    bf16 solver states do not destroy CG's scalar recurrences.
    """
    leaves = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(
            lambda x, y: jnp.sum(
                x.astype(_acc_dtype(x.dtype)) * y.astype(_acc_dtype(y.dtype))
            ),
            a,
            b,
        )
    )
    return functools.reduce(jnp.add, leaves)


def tree_norm(a: Pytree):
    return jnp.sqrt(tree_dot(a, a))


def tree_random_like(key, a: Pytree, dtype=None) -> Pytree:
    """Standard-normal pytree with the structure/shapes of ``a``."""
    leaves, treedef = jax.tree_util.tree_flatten(a)
    keys = jax.random.split(key, len(leaves))
    new = [
        jax.random.normal(k, l.shape, dtype or l.dtype)
        for k, l in zip(keys, leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, new)


def _acc_dtype(dtype):
    """Accumulation dtype: keep f64 as f64, promote everything real to f32+."""
    if dtype == jnp.float64:
        return jnp.float64
    return jnp.promote_types(dtype, jnp.float32)


# ---------------------------------------------------------------------------
# Flat-vector packing (the solver fast path)
# ---------------------------------------------------------------------------
#
# The CG/def-CG inner loop runs on *contiguous* ``(n,)`` arrays: a solve
# flattens its pytree once at entry, iterates on flat state (one fused HBM
# pass instead of a tree_map per op — DESIGN.md §8), and unflattens once at
# exit.  Bases flatten to 2-D ``(m, n)`` arrays whose column order matches
# :func:`ravel_vector`, so flat GEMVs agree with ``basis_dot`` et al.


def ravel_vector(tree: Pytree):
    """Flatten a pytree vector to ``(flat, unravel)``.

    ``flat`` is a contiguous ``(n,)`` array (leaves concatenated in
    ``tree_leaves`` order, mixed dtypes promoted); ``unravel`` maps a flat
    array back to the original structure.  For an already-flat ``(n,)``
    array this is the identity (no copy after XLA fusion).
    """
    return jax.flatten_util.ravel_pytree(tree)


def ravel(tree: Pytree) -> jnp.ndarray:
    """Just the flat ``(n,)`` array of :func:`ravel_vector`."""
    return jax.flatten_util.ravel_pytree(tree)[0]


def ravel_basis(basis: Pytree) -> jnp.ndarray:
    """Flatten a stacked basis to a 2-D ``(m, n)`` array.

    Row ``i`` equals ``ravel(basis_vector(basis, i))`` — column order (and
    dtype promotion) match :func:`ravel_vector`, so ``flat_basis @ flat_v``
    computes the same inner products as :func:`basis_dot`.
    """
    leaves = jax.tree_util.tree_leaves(basis)
    m = leaves[0].shape[0]
    dtype = functools.reduce(
        jnp.promote_types, [l.dtype for l in leaves[1:]], leaves[0].dtype
    )
    return jnp.concatenate(
        [l.reshape(m, -1).astype(dtype) for l in leaves], axis=1
    )


def unravel_basis(flat: jnp.ndarray, unravel) -> Pytree:
    """Inverse of :func:`ravel_basis` given a vector ``unravel`` (vmapped)."""
    return jax.vmap(unravel)(flat)


# ---------------------------------------------------------------------------
# Stacked bases
# ---------------------------------------------------------------------------


def basis_from_vectors(vectors: Sequence[Pytree]) -> Pytree:
    """Stack a list of vectors into a basis (new leading axis)."""
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls, axis=0), *vectors)


def basis_size(basis: Pytree) -> int:
    """Number of stacked vectors ``m`` (static)."""
    leaf = jax.tree_util.tree_leaves(basis)[0]
    return leaf.shape[0]


def basis_vector(basis: Pytree, i) -> Pytree:
    """Extract vector ``i`` from a basis."""
    return jax.tree_util.tree_map(
        lambda l: jax.lax.dynamic_index_in_dim(l, i, axis=0, keepdims=False),
        basis,
    )


def basis_dot(basis: Pytree, v: Pytree) -> jnp.ndarray:
    """``Bᵀ v`` — shape ``(m,)``.  One tall-skinny GEMV per leaf + reduce."""

    def leaf_dot(bl, vl):
        m = bl.shape[0]
        return (
            bl.reshape(m, -1).astype(_acc_dtype(bl.dtype))
            @ vl.reshape(-1).astype(_acc_dtype(vl.dtype))
        )

    leaves = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(leaf_dot, basis, v)
    )
    return functools.reduce(jnp.add, leaves)


def basis_combine(basis: Pytree, coef: jnp.ndarray) -> Pytree:
    """``B coef`` — linear combination of the stacked vectors, shape of one vector."""

    def leaf_comb(bl):
        m = bl.shape[0]
        flat = coef.astype(_acc_dtype(bl.dtype)) @ bl.reshape(m, -1).astype(
            _acc_dtype(bl.dtype)
        )
        return flat.reshape(bl.shape[1:]).astype(bl.dtype)

    return jax.tree_util.tree_map(leaf_comb, basis)


def basis_matmul(basis: Pytree, mat: jnp.ndarray) -> Pytree:
    """``B @ mat`` for ``mat`` of shape ``(m, j)`` — returns a ``j``-vector basis."""

    def leaf_mm(bl):
        m = bl.shape[0]
        flat = mat.T.astype(_acc_dtype(bl.dtype)) @ bl.reshape(m, -1).astype(
            _acc_dtype(bl.dtype)
        )
        return flat.reshape((mat.shape[1],) + bl.shape[1:]).astype(bl.dtype)

    return jax.tree_util.tree_map(leaf_mm, basis)


def gram(a: Pytree, b: Pytree) -> jnp.ndarray:
    """``Aᵀ B`` for two bases — the small ``(ma, mb)`` Gram matrix."""

    def leaf_gram(al, bl):
        ma, mb = al.shape[0], bl.shape[0]
        return al.reshape(ma, -1).astype(_acc_dtype(al.dtype)) @ bl.reshape(
            mb, -1
        ).astype(_acc_dtype(bl.dtype)).T

    leaves = jax.tree_util.tree_leaves(jax.tree_util.tree_map(leaf_gram, a, b))
    return functools.reduce(jnp.add, leaves)


def basis_concat(a: Pytree, b: Pytree) -> Pytree:
    """Concatenate two bases along the stacking axis: ``[A, B]``."""
    return jax.tree_util.tree_map(
        lambda al, bl: jnp.concatenate([al, bl], axis=0), a, b
    )


def basis_zeros(template: Pytree, m: int) -> Pytree:
    """An all-zero basis of ``m`` vectors shaped like ``template``."""
    return jax.tree_util.tree_map(
        lambda l: jnp.zeros((m,) + l.shape, l.dtype), template
    )


def basis_set(basis: Pytree, v: Pytree, i) -> Pytree:
    """Functionally set stacked vector ``i`` to ``v`` (dynamic index ok)."""
    return jax.tree_util.tree_map(
        lambda bl, vl: jax.lax.dynamic_update_index_in_dim(
            bl, vl.astype(bl.dtype), i, axis=0
        ),
        basis,
        v,
    )


def basis_slice(basis: Pytree, m: int) -> Pytree:
    """First ``m`` vectors of a basis (static ``m``)."""
    return jax.tree_util.tree_map(lambda l: l[:m], basis)


def basis_scale_columns(basis: Pytree, scales: jnp.ndarray) -> Pytree:
    """Scale stacked vector ``i`` by ``scales[i]``."""

    def leaf(bl):
        shape = (bl.shape[0],) + (1,) * (bl.ndim - 1)
        return bl * scales.reshape(shape).astype(bl.dtype)

    return jax.tree_util.tree_map(leaf, basis)


def basis_map_vectors(fn, basis: Pytree) -> Pytree:
    """Apply a vector->vector function across the stacking axis (vmapped)."""
    return jax.vmap(fn)(basis)
