"""Recycled LSMR: regularized least-squares on the method-agnostic engine.

This opens the repo's second method axis (DESIGN.md §12): where CG /
def-CG solve SPD systems ``A x = b``, LSMR (Fong & Saunders 2011) solves
the regularized least-squares problem

    min_x ‖A x − b‖² + λ‖x‖²,        A: (m, n) rectangular,

via Golub–Kahan bidiagonalization of the *augmented* operator

    Â = [A; √λ·I],   b̂ = [b; 0],

which is mathematically LSQR/LSMR on the damped problem but — unlike the
textbook ``damp`` recurrences — stays exact under a **warm start**: the
initial residual ``r̂₀ = [b − A x₀; −√λ x₀]`` is carried as an explicit
``(u_m, u_n)`` block pair, so a recycled sequence converges to the TRUE
ridge solution, not the proximal one.  ``λ = 0`` statically drops the
bottom block (no dead state rides the loop).

The iteration is seated on :mod:`repro.core.engine` exactly like def-CG:
LSMR supplies only its ``step``/``state`` contract; the harness owns
tolerance logic, the sticky ``fail`` code, the stagnation detector, the
recording scan + while-loop split and the vmap-aware matvec gate.  The
three vector recurrences of an iteration (``hbar``/``x``/``h``) lower to
ONE fused pass (:func:`repro.kernels.ops.lsmr_update`).

Recycling (the paper's §2.3 transplanted to least-squares) happens in
the **normal-equations geometry**: LSMR is MINRES on
``N dx = Âᵀ r̂₀`` with ``N = AᵀA + λI`` (SPD), so a deflation basis
``W`` with products ``NW = N·W`` plays exactly the role ``(W, AW)``
plays for def-CG:

* warm start   ``x₀' = x_prev + W (WᵀNW)⁻¹ Wᵀ s₀``, ``s₀ = Âᵀ r̂(x_prev)``,
  which zeroes the W-component of the normal residual;
* per-iteration right-projection ``Q v = v − W (WᵀNW)⁻¹ (NW)ᵀ v`` — the
  bidiagonalization runs on ``Â·Q`` (adjoint ``Qᵀ·Âᵀ``), keeping the
  Krylov space N-orthogonal to ``W`` at the cost of two k×n GEMVs per
  operator application and ZERO extra A/Aᵀ products;
* window recording: the recurrence already holds ``g_j = B̂ᵀu_j``, so
  ``N̂ v_j = α_j g_j + β_{j+1} g_{j+1}`` is free — the ``(v_j, N̂v_j)``
  rows feed the SAME masked harmonic-Ritz extraction
  (:func:`repro.core.strategies.extract_next_basis_core`) def-CG uses,
  with ``(Z, AZ) = ([W; V], [NW; N̂V])``.  (For a deflated solve the
  recorded products are of the *deflated* normal operator — approximate
  in the same sense as the repo's stale-``AW`` mode; the per-system
  ``refresh_aw="exact"`` pass re-derives true ``NW`` products.)

Matvec accounting counts ``A`` and ``Aᵀ`` applications each as 1 (the
λ-block and all projections are free): init costs 1 Aᵀ (+1 A with a
warm start), every iteration exactly 2.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve

from repro.core import engine
from repro.core import operators as ops_mod
from repro.core import pytree as pt
from repro.core.solvers import (
    DEFAULT_WAW_JITTER,
    CGResult,
    RecycleData,
    SolveInfo,
    SolveStatus,
)
from repro.core.strategies import extract_next_basis_core
from repro.kernels import ops as kops

Pytree = Any


def _sym_ortho(a, b):
    """Stable Givens pair ``(c, s, r)`` with ``r = √(a² + b²)``.

    The degenerate ``r = 0`` case returns ``(0, 0, 0)`` — it only arises
    at exact termination (``α = β = 0``), which the step latches as
    converged, so the zeros never propagate.
    """
    r = jnp.sqrt(a * a + b * b)
    safe = jnp.where(r == 0.0, 1.0, r)
    return a / safe, b / safe, r


def _domain_template(A, b: Pytree):
    """The x-space pytree structure of ``A``, discovered at zero cost.

    Rectangular operators map x-space to b-space, so ``b`` alone does not
    determine the solution structure; one ``eval_shape`` of the adjoint
    (no FLOPs, no device work) does.
    """
    probe = jax.eval_shape(ops_mod.adjoint_matvec(A), b)
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), probe
    )


def _factor_wnw(w_flat, nw_flat, k: int, jitter: float):
    """Cholesky of ``WᵀNW`` — same regularization policy as def-CG's
    ``WᵀAW`` factor: relative diagonal jitter, plus UNconditional
    regularization of exactly-zero columns (clamped extraction slots /
    cold states deflate as exact no-ops; see ``solvers._factor_waw``)."""
    wnw = w_flat @ nw_flat.T
    wnw = 0.5 * (wnw + wnw.T)
    dj = jnp.diag(wnw)
    tr = jnp.sum(dj)
    if jitter:
        scale = jnp.where(tr > 0, tr / k, 1.0)
        wnw = wnw + jitter * scale * jnp.eye(k, dtype=wnw.dtype)
    wnw = wnw + jnp.diag(
        jnp.where(dj == 0.0, jnp.maximum(tr / k, 1.0), 0.0)
    )
    return cho_factor(wnw)


def lsmr(
    A,
    b: Pytree,
    x0: Optional[Pytree] = None,
    W: Optional[jnp.ndarray] = None,
    NW: Optional[jnp.ndarray] = None,
    *,
    damp: float = 0.0,
    ell: int = 0,
    tol: float = 1e-6,
    atol: float = 0.0,
    maxiter: int = 1000,
    min_iters: int = 0,
    record_residuals: bool = False,
    waw_jitter: float = DEFAULT_WAW_JITTER,
    flat_recycle: bool = False,
    batch_axis: Optional[str] = None,
    stagnation_window: int = 0,
) -> CGResult:
    """(Deflated) LSMR for ``min ‖Ax − b‖² + damp·‖x‖²``.

    Args:
      A: rectangular operator.  Its adjoint resolves through
         :func:`repro.core.operators.adjoint_matvec` — an ``rmatvec``
         (:class:`LinearOperator`, :class:`DenseMatrixOperator`,
         :class:`GaussNewtonOperator`) when present, else the operator's
         own matvec (this repo's symmetric-by-contract default).
      b: right-hand side (range-space pytree; its structure may differ
         from the solution's — the domain structure is discovered from
         the adjoint).
      x0: warm start.  Handled EXACTLY (explicit augmented residual
         blocks), so warm-started ridge solves converge to the same
         minimizer as cold ones.
      W, NW: optional flat ``(k, n)`` deflation basis and its
         normal-operator products ``(AᵀA + damp·I)·W`` — the deflated
         method (``SolveSpec.method="deflsmr"``).  Zero rows deflate as
         exact no-ops, so a cold state is valid.
      damp: the ridge shift λ ≥ 0 (static; selects the augmented-block
         code path at trace time).
      ell: number of leading ``(v, N̂v)`` pairs to record for the
         harmonic-Ritz extraction — zero extra matvecs, same contract as
         def-CG's ``(P, AP)`` window.
      tol, atol: convergence is declared on the normal residual
         ``‖Âᵀr̂‖ ≤ max(tol·‖Âᵀr̂₀‖, atol)`` — the quantity LSMR
         monotonically decreases, reported as ``info.residual_norm``.
      min_iters, record_residuals, waw_jitter, flat_recycle, batch_axis,
      stagnation_window: as in :func:`repro.core.solvers.defcg`.

    Returns ``CGResult``; ``recycle.P``/``recycle.AP`` hold the
    ``(v, N̂v)`` window (``alpha``/``beta`` are None — LSMR's extraction
    needs no recurrence coefficients).
    """
    if damp < 0.0:
        raise ValueError(f"damp must be >= 0, got {damp}")
    has_shift = damp > 0.0
    sqrt_damp = float(damp) ** 0.5  # repro-lint: disable=host-sync-in-trace — damp is a static Python scalar (lsmr_jit static argname)

    b_flat, unravel_b = pt.ravel_vector(b)
    if x0 is not None:
        x_flat, unravel_x = pt.ravel_vector(x0)
    else:
        x_flat, unravel_x = pt.ravel_vector(_domain_template(A, b))

    A_flat = engine.flat_operator(A, unravel_x)
    At_flat = engine.flat_operator(
        ops_mod.adjoint_matvec(A), unravel_b
    )

    deflating = W is not None
    if deflating:
        k = W.shape[0]
        nw_flat = NW if NW is not None else jnp.zeros_like(W)
        wnw_cho = _factor_wnw(W, nw_flat, k, waw_jitter)
        winv = cho_solve(wnw_cho, jnp.eye(k, dtype=W.dtype))

        def q_apply(vv):
            # Right projection: N-orthogonalize against W.
            return vv - (winv @ (nw_flat @ vv)) @ W

        def qt_apply(gg):
            # Its transpose, applied to adjoint products.
            return gg - (winv @ (W @ gg)) @ nw_flat
    else:
        q_apply = qt_apply = lambda z: z  # noqa: E731

    # -- initial augmented residual r̂₀ = [b − A x₀; −√λ x₀] --------------
    init_mv = jnp.int32(1)  # the Âᵀu₁ below
    if x0 is not None:
        r_m = b_flat - A_flat(x_flat)
        init_mv = init_mv + 1
    else:
        r_m = b_flat
    u_n0 = -sqrt_damp * x_flat if has_shift else None

    beta_sq = jnp.vdot(r_m, r_m)
    if has_shift:
        beta_sq = beta_sq + jnp.vdot(u_n0, u_n0)
    beta1 = jnp.sqrt(beta_sq)
    safe_b = jnp.where(beta1 == 0.0, 1.0, beta1)
    u_m0 = r_m / safe_b
    u_n0 = (u_n0 / safe_b) if has_shift else None

    g0 = At_flat(u_m0)
    if has_shift:
        g0 = g0 + sqrt_damp * u_n0
    g0 = qt_apply(g0)
    alpha1 = jnp.sqrt(jnp.vdot(g0, g0))
    safe_a = jnp.where(alpha1 == 0.0, 1.0, alpha1)
    v0 = g0 / safe_a

    normar0 = alpha1 * beta1
    threshold = jnp.maximum(tol * normar0, atol)
    diverged_at = 1e8 * normar0
    trace0 = engine.trace_init(normar0, maxiter, record_residuals)
    fail0 = engine.initial_fail(normar0)
    stag0 = engine.stagnation_init(normar0, stagnation_window)
    one = jnp.ones((), b_flat.dtype)

    def active_fn(state):
        j, zetabar, fail = state[0], state[7], state[16]
        keep_going = (jnp.abs(zetabar) > threshold) | (j < min_iters)
        return (j < maxiter) & keep_going & (fail == 0)

    def step(state, active, gate_matvec):
        """One LSMR iteration; ``active=False`` freezes the state.

        Same freezing policy as def-CG's step: only the two operator
        applications hide behind the harness's ``cond`` gate — the cheap
        vector passes run as masked no-ops.
        """
        (j, x, u_m, u_n, v, g, alpha, zetabar, alphabar, rho, rhobar,
         cbar, sbar, h, hbar, trace, fail, stag) = state
        v_in = v

        # -- bidiagonalization: β u⁺ = Â(Qv) − α u ----------------------
        qv = q_apply(v)
        if gate_matvec:
            av = engine.gated_matvec(
                A_flat, qv, active, batch_axis, out_like=u_m
            )
        else:
            av = A_flat(qv)
        u_m_new = av - alpha * u_m
        beta_sq_ = jnp.vdot(u_m_new, u_m_new)
        if has_shift:
            u_n_new = sqrt_damp * qv - alpha * u_n
            beta_sq_ = beta_sq_ + jnp.vdot(u_n_new, u_n_new)
        beta_new = jnp.sqrt(beta_sq_)
        sb = jnp.where(beta_new == 0.0, 1.0, beta_new)
        u_m_new = u_m_new / sb
        if has_shift:
            u_n_new = u_n_new / sb

        # -- α v⁺ = Qᵀ(Âᵀu⁺) − β v --------------------------------------
        if gate_matvec:
            atu = engine.gated_matvec(
                At_flat, u_m_new, active, batch_axis, out_like=v
            )
        else:
            atu = At_flat(u_m_new)
        g_new = atu + sqrt_damp * u_n_new if has_shift else atu
        g_new = qt_apply(g_new)
        # The window row, free from recurrence quantities:
        #   N̂ v_j = B̂ᵀB̂ v_j = α_j·B̂ᵀu_j + β_{j+1}·B̂ᵀu_{j+1}.
        nv = alpha * g + beta_new * g_new
        w_vec = g_new - beta_new * v
        alpha_new = jnp.sqrt(jnp.vdot(w_vec, w_vec))
        sa = jnp.where(alpha_new == 0.0, 1.0, alpha_new)
        v_new = w_vec / sa

        # -- the two Givens rotations (Fong & Saunders 2011, §2.2; the
        # λ-rotation is statically absent — λ lives in Â itself) --------
        rho_old, rhobar_old = rho, rhobar
        c, s, rho_new = _sym_ortho(alphabar, beta_new)
        thetanew = s * alpha_new
        alphabar_new = c * alpha_new
        thetabar = sbar * rho_new
        cbar_new, sbar_new, rhobar_new = _sym_ortho(
            cbar * rho_new, thetanew
        )
        zeta = cbar_new * zetabar
        zetabar_new = -sbar_new * zetabar

        # -- fused vector triple: hbar/x/h in one pass ------------------
        sr = jnp.where(rho_new == 0.0, 1.0, rho_new)
        srb = jnp.where(rhobar_new == 0.0, 1.0, rhobar_new)
        c0 = thetabar * rho_new / (rho_old * rhobar_old)
        c1 = zeta / (sr * srb)
        c2 = thetanew / sr
        x_new, hbar_new, h_new = kops.lsmr_update(
            x, hbar, h, v_new, c0, c1, c2
        )

        # Exact termination: a zero β or α means Âᵀr̂ has been driven to
        # (numerical) zero — latch the convergence quantity there.
        exact = (beta_new == 0.0) | (alpha_new == 0.0)
        zetabar_new = jnp.where(exact, 0.0, zetabar_new)
        normar_new = jnp.abs(zetabar_new)

        fail = jnp.where(
            (fail == 0) & active & (~jnp.isfinite(normar_new)),
            SolveStatus.BREAKDOWN_NONFINITE,
            fail,
        ).astype(jnp.int32)
        fail = jnp.where(
            (fail == 0) & active & (normar_new > diverged_at),
            SolveStatus.STAGNATED,
            fail,
        ).astype(jnp.int32)
        if stag is not None:
            stag, fail = engine.stagnation_update(
                stag, normar_new, fail, active, stagnation_window
            )
        if trace is not None:
            old = trace[j + 1]
            trace = trace.at[j + 1].set(
                jnp.where(active, normar_new, old)
            )

        sel = lambda new, cur: jnp.where(active, new, cur)  # noqa: E731
        state_new = (
            j + active.astype(j.dtype),
            sel(x_new, x),
            sel(u_m_new, u_m),
            sel(u_n_new, u_n) if has_shift else None,
            sel(v_new, v),
            sel(g_new, g),
            sel(alpha_new, alpha),
            sel(zetabar_new, zetabar),
            sel(alphabar_new, alphabar),
            sel(rho_new, rho),
            sel(rhobar_new, rhobar),
            sel(cbar_new, cbar),
            sel(sbar_new, sbar),
            sel(h_new, h),
            sel(hbar_new, hbar),
            trace,
            fail,
            stag,
        )
        return state_new, (v_in, nv)

    state = (
        jnp.int32(0), x_flat, u_m0, u_n0, v0, g0, alpha1,
        normar0, alpha1, one, one, one, jnp.zeros((), b_flat.dtype),
        v0, jnp.zeros_like(v0), trace0, fail0, stag0,
    )
    state, rows = engine.run_recording_loop(
        step, active_fn, state, ell=ell
    )
    j, x = state[0], state[1]
    zetabar, trace, fail = state[7], state[15], state[16]
    normar = jnp.abs(zetabar)

    if deflating:
        # The Krylov correction lives in the Q-subspace: one exit-time
        # projection of the accumulated update (two k×n GEMVs, once).
        x = x_flat + q_apply(x - x_flat)

    converged = normar <= threshold
    info = SolveInfo(
        iterations=j,
        converged=converged,
        residual_norm=normar,
        matvecs=init_mv + 2 * j,
        residual_norms=trace,
        breakdown=fail > 0,
        status=engine.exit_status(converged, fail),
    )
    recycle = None
    if ell > 0:
        v_rows, nv_rows = rows
        if flat_recycle:
            recycle = RecycleData(
                P=v_rows, AP=nv_rows, stored=jnp.minimum(j, ell),
            )
        else:
            recycle = RecycleData(
                P=pt.unravel_basis(v_rows, unravel_x),
                AP=pt.unravel_basis(nv_rows, unravel_x),
                stored=jnp.minimum(j, ell),
            )
    return CGResult(x=unravel_x(x), info=info, recycle=recycle)


lsmr_jit = jax.jit(
    lsmr,
    static_argnames=(
        "damp",
        "ell",
        "tol",
        "atol",
        "maxiter",
        "min_iters",
        "record_residuals",
        "waw_jitter",
        "flat_recycle",
        "batch_axis",
        "stagnation_window",
    ),
)


# ---------------------------------------------------------------------------
# Recycled least-squares sequences
# ---------------------------------------------------------------------------


def _normal_basis_flat(A, unravel_x, w_flat, damp: float):
    """``(AᵀA + damp·I) @ W`` for a flat ``(k, n)`` basis — one multi-RHS
    forward pass and one adjoint pass (2k accounted matvecs)."""
    basis = pt.unravel_basis(w_flat, unravel_x)
    aw = ops_mod.apply_to_basis(A, basis)
    nw = pt.ravel_basis(
        ops_mod.apply_to_basis(ops_mod.adjoint_matvec(A), aw)
    )
    if damp > 0.0:
        nw = nw + damp * w_flat
    return nw


def _one_recycled_lsmr(
    A,
    b: Pytree,
    x0: Optional[Pytree],
    w: jnp.ndarray,
    nw_carry: jnp.ndarray,
    unravel_x,
    *,
    k: int,
    ell: int,
    damp: float,
    tol: float,
    atol: float,
    maxiter: int,
    select: str,
    waw_jitter: float,
    refresh_aw: str,
    record_residuals: bool = False,
    batch_axis: Optional[str] = None,
    stagnation_window: int = 0,
):
    """ONE system of the recycled LSMR step, on flat state.

    The least-squares mirror of ``recycle._one_recycled_solve`` and the
    single source of per-system semantics shared by the front-door
    :func:`repro.core.solve` and :func:`solve_sequence_lsmr`'s scan body:

    1. per-system basis refresh: ``refresh_aw="exact"`` re-derives
       ``NW = (AᵀA + λI)W`` under THIS system's operator (2k accounted
       matvecs); ``"stale"`` reuses the carried products (zero matvecs,
       approximate deflation — the paper's cheap mode);
    2. deflated warm start ``x₀' = x_prev + W (WᵀNW)⁻¹ Wᵀ s₀`` with
       ``s₀ = Âᵀr̂(x_prev)`` (2 matvecs; exact no-op on a cold basis);
    3. the deflated solve (:func:`lsmr` with the N-orthogonal
       projection);
    4. extraction: the recorded ``(v, N̂v)`` window and the carried
       ``(W, NW)`` stack through the SAME masked harmonic-Ritz core
       def-CG uses — zero extra matvecs.

    A broken or non-finite outcome retires the basis (zeroed carry, the
    sequence re-bootstraps cold) and falls the solution back to the
    finite warm start — same terminal policy as the def-CG ladder's
    last resort, without the ladder (LSMR has no SPD breakdown modes;
    nonfinite input is the realistic failure here).

    Returns ``(x, info, w_next, nw_next, theta, rung)`` with ``theta``
    None when ``ell == 0`` and ``rung`` always 0 (kept for carry-shape
    parity with the def-CG path).
    """
    b_flat, _ = pt.ravel_vector(b)
    A_flat = engine.flat_operator(A, unravel_x)
    At_flat = engine.flat_operator(
        ops_mod.adjoint_matvec(A), pt.ravel_vector(b)[1]
    )

    refresh_charge = jnp.int32(0)
    if refresh_aw == "exact":
        nw_used = _normal_basis_flat(A, unravel_x, w, damp)
        refresh_charge = refresh_charge + 2 * k
    else:
        nw_used = nw_carry

    # Deflated warm start in x-space (s₀ = Aᵀ(b − A x_prev) − λ x_prev).
    x_prev = (
        jnp.zeros((w.shape[1],), b_flat.dtype)
        if x0 is None
        else pt.ravel(x0)
    )
    r_m = b_flat - A_flat(x_prev)
    s0 = At_flat(r_m)
    if damp > 0.0:
        s0 = s0 - damp * x_prev
    wnw_cho = _factor_wnw(w, nw_used, k, waw_jitter)
    cvec = cho_solve(wnw_cho, w @ s0)
    x0p = x_prev + cvec @ w
    guess_charge = jnp.int32(2)

    result = lsmr(
        A,
        b,
        unravel_x(x0p),
        W=w,
        NW=nw_used,
        damp=damp,
        ell=ell,
        tol=tol,
        atol=atol,
        maxiter=maxiter,
        record_residuals=record_residuals,
        waw_jitter=waw_jitter,
        flat_recycle=True,
        batch_axis=batch_axis,
        stagnation_window=stagnation_window,
    )
    info = result.info
    info = info._replace(
        matvecs=info.matvecs + refresh_charge + guess_charge
    )

    if ell > 0:
        w2, nw2, theta, _ = extract_next_basis_core(
            w, nw_used, result.recycle.P, result.recycle.AP,
            result.recycle.stored, k, select=select,
        )
    else:
        w2, nw2, theta = w, nw_used, None

    # Terminal retirement: never hand a poisoned basis (or non-finite
    # coordinates) to the next system.
    x_flat = pt.ravel(result.x)
    x_safe = jnp.where(jnp.isfinite(x_prev), x_prev, 0.0)
    x_flat = jnp.where(jnp.all(jnp.isfinite(x_flat)), x_flat, x_safe)
    retire = (
        info.breakdown
        | ~jnp.all(jnp.isfinite(w2))
        | ~jnp.all(jnp.isfinite(nw2))
    )
    w2 = jnp.where(retire, 0.0, w2)
    nw2 = jnp.where(retire, 0.0, nw2)
    if theta is not None:
        theta = jnp.where(retire, 0.0, theta)
    return (
        unravel_x(x_flat), info, w2, nw2, theta, jnp.int32(0),
    )


def solve_sequence_lsmr(
    systems: Any,
    b_seq: Pytree,
    W0: Optional[jnp.ndarray] = None,
    NW0: Optional[jnp.ndarray] = None,
    *,
    k: int,
    ell: int,
    damp: float = 0.0,
    make_operator: Optional[Callable[[Any], Any]] = None,
    tol: float = 1e-6,
    atol: float = 0.0,
    maxiter: int = 1000,
    select: str = "largest",
    waw_jitter: float = DEFAULT_WAW_JITTER,
    refresh_aw: str = "exact",
    carry_x: bool = False,
    batch_axis: Optional[str] = None,
    stagnation_window: int = 0,
    x_prev0: Optional[jnp.ndarray] = None,
):
    """Recycled LSMR across a sequence of least-squares problems.

    The least-squares twin of :func:`repro.core.recycle.solve_sequence`:
    one ``lax.scan`` carrying the flat ``(W, NW)`` recycled basis (and
    optionally the warm-start solution) across systems — zero host syncs,
    the whole sequence jits as one XLA computation.  Returns the same
    :class:`repro.core.recycle.SequenceResult` shape, with the ``AW``
    slot holding the normal-operator products ``NW``.
    """
    from repro.core.recycle import SequenceResult

    if refresh_aw not in ("exact", "stale"):
        raise ValueError(f"unknown refresh_aw={refresh_aw!r}")
    make_op = make_operator if make_operator is not None else (lambda s: s)

    b0 = jax.tree_util.tree_map(lambda l: l[0], b_seq)
    A0 = make_op(jax.tree_util.tree_map(lambda l: l[0], systems))
    x_tmpl = _domain_template(A0, b0)
    x0_flat, unravel_x = pt.ravel_vector(x_tmpl)
    n = x0_flat.shape[0]
    dtype = x0_flat.dtype

    w_init = jnp.zeros((k, n), dtype) if W0 is None else W0.astype(dtype)
    nw_init = (
        jnp.zeros((k, n), dtype)
        if (NW0 is None or W0 is None)
        else NW0.astype(dtype)
    )
    x_init = (
        jnp.zeros((n,), dtype) if x_prev0 is None else x_prev0.astype(dtype)
    )

    def body(carry, xs):
        w, nw, x_prev = carry
        sys_i, b = xs
        A = make_op(sys_i)
        x0 = unravel_x(x_prev) if carry_x else None
        x_out, info, w2, nw2, theta, rung = _one_recycled_lsmr(
            A,
            b,
            x0,
            w,
            nw,
            unravel_x=unravel_x,
            k=k,
            ell=ell,
            damp=damp,
            tol=tol,
            atol=atol,
            maxiter=maxiter,
            select=select,
            waw_jitter=waw_jitter,
            refresh_aw=refresh_aw,
            batch_axis=batch_axis,
            stagnation_window=stagnation_window,
        )
        return (w2, nw2, pt.ravel(x_out)), (x_out, info, theta, rung)

    (w_fin, nw_fin, _), (xs_out, infos, thetas, rungs) = jax.lax.scan(
        body, (w_init, nw_init, x_init), (systems, b_seq)
    )
    return SequenceResult(
        x=xs_out, info=infos, theta=thetas, W=w_fin, AW=nw_fin,
        drift=jnp.zeros((), dtype), rung=rungs,
    )


solve_sequence_lsmr_jit = jax.jit(
    solve_sequence_lsmr,
    static_argnames=(
        "k",
        "ell",
        "damp",
        "make_operator",
        "tol",
        "atol",
        "maxiter",
        "select",
        "waw_jitter",
        "refresh_aw",
        "carry_x",
        "batch_axis",
        "stagnation_window",
    ),
)
