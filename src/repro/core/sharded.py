"""SPMD sharding of the flat Krylov engine (DESIGN.md §5).

This module makes the flat engine *n-parallel*: every length-n vector of
an iteration (``x, r, p, z``), the ``(k, n)`` recycled-basis leaves of
:class:`repro.core.recycle.RecycleState`, and the recorded ``(ell, n)``
window rows are sharded along the coordinate dimension over a 1-D
``"solve"`` mesh axis, and the def-CG / CG / LSMR loop harnesses run
under :func:`jax.experimental.shard_map.shard_map` with the fused kernel
ops (:mod:`repro.kernels.ops`) applied per-shard.

The communication contract is ONE collective per def-CG iteration: all
scalar reductions of a step — ``pᵀAp``, ``rᵀAp``, ``ApᵀAp``, the
deflation GEMVs ``(AW)ᵀAp`` / ``(AW)ᵀr``, and a FRESH ``‖r‖²`` of the
incoming residual — are packed into a single
:func:`repro.core.engine.psum_merged` all-reduce.  The post-update
quantities then follow from one-step recurrences

    ‖r₊‖² = ‖r‖² − 2α·rᵀAp + α²·ApᵀAp,
    (AW)ᵀr₊ = (AW)ᵀr − α·(AW)ᵀAp,

used ONLY for β, μ and the stopping test; α is always formed from the
freshly-reduced ``‖r‖²`` of the actual residual vector, so recurrence
rounding does NOT accumulate across iterations (a fully-carried ``‖r‖²``
decouples from the true residual near convergence and diverges — the
one-step form differs from the unsharded fresh reductions only in
floating-point association; parity is ~1e-13 relative in f64, pinned at
1e-10 by the test suite).  LSMR inherently
needs two all-reduces per iteration (``β = ‖u₊‖`` must normalize ``u``
before ``Âᵀu`` can be formed).  The per-while-body collective counts are
pinned from compiled HLO by
:func:`repro.launch.hlo_stats.while_body_collectives`.

Operator side: a matvec under the mesh costs one ``all_gather`` of the
direction vector plus the one merged all-reduce.  Two operator kinds are
sharded natively:

* :class:`repro.core.operators.DenseMatrixOperator` — the matrix is
  row-sharded ``P("solve", None)``; each shard contracts its row block
  against the gathered vector.
* :class:`repro.core.operators.RBFKernelSystemOperator` — the data
  ``X`` is row-sharded; the full ``X`` is all-gathered ONCE per solve
  (hoisted out of the while loop as a constant) and each shard forms its
  local K-tile rows on the fly via
  :func:`repro.kernels.ops.rbf_matvec_rect` — ``K`` is never
  materialized, which is what lets n = 10⁵–10⁶ GP solves run at all.

Differences from the unsharded front door (documented, tested):

* No recovery ladder (``spec.recovery_rungs`` is ignored): a broken
  solve retires the basis (zeroed carry) and falls the solution back to
  the finite warm start — the same terminal policy as the recycled-LSMR
  path.  Clean solves are identical either way (the ladder runs zero
  iterations on them).
* ``method="deflsmr"``, preconditioners, and ``batch_axis`` are not
  supported (NotImplementedError / ValueError at the front door).
* Only the :class:`HarmonicRitz` strategy (the default) is accepted.

Everything else — tolerances, breakdown classification, stagnation,
matvec accounting, the recording-scan/while-loop split, the extraction —
reuses the engine and strategy cores verbatim, with
``psum_axis="solve"`` threaded where an n-reduction hides.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.scipy.linalg import cho_factor, cho_solve
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import engine
from repro.core import operators as ops_mod
from repro.core import pytree as pt
from repro.core.engine import SolveInfo, SolveStatus
from repro.core.recycle import RecycleState
from repro.core.strategies import HarmonicRitz, extract_next_basis_core
from repro.kernels import ops as kops

Pytree = Any

# The 1-D mesh axis every length-n dimension shards over (see
# repro.launch.mesh.make_solve_mesh).
SOLVE_AXIS = "solve"

_SHARDED_METHODS = ("cg", "defcg", "lsmr")


# ---------------------------------------------------------------------------
# Sharding rules — the PartitionSpec vocabulary of the solve state
# ---------------------------------------------------------------------------


def vector_spec() -> P:
    """Length-n solve vectors (x, r, p, b): sharded along n."""
    return P(SOLVE_AXIS)


def basis_spec() -> P:
    """``(k, n)`` basis stacks (W, AW) and ``(ell, n)`` window rows:
    replicated over rows, sharded along the n columns."""
    return P(None, SOLVE_AXIS)


def recycle_state_specs() -> RecycleState:
    """A :class:`RecycleState`-shaped pytree of PartitionSpecs — the
    sharding rule for carrying recycle state on the solve mesh (W/AW
    column-sharded, the k-sized/scalar leaves replicated)."""
    return RecycleState(
        W=basis_spec(),
        AW=basis_spec(),
        theta=P(),
        systems_solved=P(),
        drift=P(),
    )


def shard_recycle_state(state: RecycleState, mesh: Mesh) -> RecycleState:
    """Place a ``RecycleState`` on ``mesh`` per :func:`recycle_state_specs`.

    Explicit per-leaf placement — PartitionSpec subclasses tuple, so a
    tree_map pairing leaves with specs would descend into the specs.
    """
    s = recycle_state_specs()

    def put(leaf, spec):
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return RecycleState(
        W=put(state.W, s.W),
        AW=put(state.AW, s.AW),
        theta=put(state.theta, s.theta),
        systems_solved=put(state.systems_solved, s.systems_solved),
        drift=put(state.drift, s.drift),
    )


def _commit(mesh: Mesh, x, spec: P):
    """Place one traced input on ``mesh`` under ``spec`` before the
    jitted shard_map call.  A no-op for well-placed arrays; for arrays
    committed to different devices (a ``RecycleState`` carried from a
    solve on another mesh size, say) it is the reshard that makes them
    legal inputs instead of a cross-device jit error."""
    return jax.device_put(x, NamedSharding(mesh, spec))


def _commit_tree(mesh: Mesh, tree, spec_tree):
    """:func:`_commit` over an operator-leaves pytree paired with its
    spec pytree.  Flatten-up-to keeps each PartitionSpec whole at the
    leaf positions (a naive two-tree map could descend into the specs —
    PartitionSpec subclasses tuple)."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    specs = treedef.flatten_up_to(spec_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [_commit(mesh, x, s) for x, s in zip(flat, specs)]
    )


# ---------------------------------------------------------------------------
# Operator planning — which leaves shard, and how the shard applies them
# ---------------------------------------------------------------------------


def _plan_operator(A, *, need_adjoint: bool):
    """Host-side classification of an operator for the solve mesh.

    Returns ``(kind, aux, leaves, leaf_specs)``: ``leaves`` are the
    traced arrays handed through ``shard_map`` under ``leaf_specs``;
    ``kind``/``aux`` are static and select the per-shard apply built by
    :func:`_make_applies`.
    """
    if isinstance(A, ops_mod.RBFKernelSystemOperator):
        aux = (float(A.theta), float(A.lengthscale), int(A.block), A.impl)
        return ("rbf", aux, (A.x, A.sqrt_h), (P(SOLVE_AXIS, None), P(SOLVE_AXIS)))
    mat = getattr(A, "mat", None)
    if mat is not None:
        leaves = (mat,)
        specs = (P(SOLVE_AXIS, None),)
        if need_adjoint:
            # LSMR contracts with Aᵀ too: ship the transpose as its own
            # row-sharded leaf so the adjoint matvec is also a local
            # row-block GEMV (transposing the sharded leaf in-loop would
            # re-lay the matrix out every iteration).
            leaves = (mat, jnp.swapaxes(mat, -2, -1))
            specs = (P(SOLVE_AXIS, None), P(SOLVE_AXIS, None))
        return ("dense", (), leaves, specs)
    raise TypeError(
        "solve(..., mesh=...) shards the operator's data leaves along n; "
        "that needs a DenseMatrixOperator (row-sharded matrix) or an "
        f"RBFKernelSystemOperator (row-sharded data) — got {type(A).__name__}. "
        "Unsharded callers: drop the mesh argument."
    )


def _make_applies(kind: str, aux, leaves):
    """Build the per-shard ``(apply, rapply, basis_apply)`` closures.

    Runs INSIDE the shard_map body: ``leaves`` are local shards.  Each
    matvec all-gathers its input vector once; the RBF operator
    additionally all-gathers the full data ``X`` at closure-build time —
    a loop constant XLA hoists, so it happens once per solve, not per
    iteration.
    """
    ax = SOLVE_AXIS
    if kind == "dense":
        mat_loc = leaves[0]

        def apply(v_loc):
            v_full = jax.lax.all_gather(v_loc, ax, tiled=True)
            return mat_loc @ v_full

        if len(leaves) > 1:
            mat_t_loc = leaves[1]

            def rapply(u_loc):
                u_full = jax.lax.all_gather(u_loc, ax, tiled=True)
                return mat_t_loc @ u_full

        else:
            rapply = apply

        def basis_apply(w_loc):  # (k, n_loc) -> (k, n_loc)
            w_full = jax.lax.all_gather(w_loc, ax, axis=1, tiled=True)
            return w_full @ mat_loc.T

        return apply, rapply, basis_apply

    if kind == "rbf":
        theta, lengthscale, block, impl = aux
        x_loc, sh_loc = leaves
        # Gathered ONCE per solve (closure constant, hoisted out of the
        # while loop) — each shard then owns the rectangular tile
        # (local rows × all columns) of K implicitly.
        x_full = jax.lax.all_gather(x_loc, ax, tiled=True)

        def apply(v_loc):
            u_full = jax.lax.all_gather(sh_loc * v_loc, ax, tiled=True)
            kv_loc = kops.rbf_matvec_rect(
                x_loc, x_full, u_full, theta, lengthscale,
                impl=impl, block=block,
            )
            return v_loc + sh_loc * kv_loc

        def basis_apply(w_loc):  # (k, n_loc): one fused multi-RHS pass
            u_full = jax.lax.all_gather(
                w_loc * sh_loc[None, :], ax, axis=1, tiled=True
            )
            kv_loc = kops.rbf_matvec_rect(
                x_loc, x_full, u_full.T, theta, lengthscale,
                impl=impl, block=block,
            )
            return w_loc + sh_loc[None, :] * kv_loc.T

        return apply, apply, basis_apply

    raise ValueError(f"unknown operator kind {kind!r}")


# ---------------------------------------------------------------------------
# Sharded method bodies — per-shard views, merged-psum reductions
# ---------------------------------------------------------------------------


def _sharded_cg_body(
    kind, aux, *, tol, atol, maxiter, stagnation_window, record_residuals
):
    """Plain CG on per-shard state: one merged all-reduce per iteration
    (``[pᵀAp, rᵀAp, ApᵀAp, ‖r‖²]``).  α comes from the FRESH ``‖r‖²``
    of the incoming residual; only β and the stopping test ride the
    one-step ``‖r₊‖²`` recurrence, so rounding never accumulates."""
    ax = SOLVE_AXIS

    def body(leaves, b_loc, x0_loc):
        apply, _, _ = _make_applies(kind, aux, leaves)
        r0 = b_loc - apply(x0_loc)
        bsq, rs0 = engine.psum_merged(
            [jnp.vdot(b_loc, b_loc), jnp.vdot(r0, r0)], ax
        )
        bnorm = jnp.sqrt(bsq)
        threshold = jnp.maximum(tol * bnorm, atol)
        rnorm0 = jnp.sqrt(rs0)
        p0 = r0
        trace0 = engine.trace_init(rnorm0, maxiter, record_residuals)
        diverged_at = 1e8 * jnp.maximum(rnorm0, bnorm)

        def active_fn(state):
            j, _, _, _, rnorm, _, fail, _ = state
            return (j < maxiter) & (rnorm > threshold) & (fail == 0)

        def step(state, active, gate_matvec):
            del active, gate_matvec  # ell == 0: while-phase only
            j, x, r, p, rnorm, trace, fail, stag = state
            ap = apply(p)
            d, rap, apap, rs = engine.psum_merged(
                [
                    jnp.vdot(p, ap), jnp.vdot(r, ap),
                    jnp.vdot(ap, ap), jnp.vdot(r, r),
                ],
                ax,
            )
            bad, code = engine.classify_breakdown(d, rnorm, diverged_at)
            fail = jnp.where(fail > 0, fail, code)
            ap = jnp.where(bad, 0.0, ap)
            rap = jnp.where(bad, 0.0, rap)
            apap = jnp.where(bad, 0.0, apap)
            alpha = jnp.where(bad, 0.0, rs / jnp.where(bad, 1.0, d))
            x, r, _, _ = kops.fused_cg_update(x, r, p, ap, alpha)
            # One-step ‖r₊‖² recurrence off the fresh ‖r‖² (clamped: at
            # convergence the cancellation can go eps-negative).
            rs_new = jnp.maximum(
                rs - 2.0 * alpha * rap + alpha * alpha * apap, 0.0
            )
            beta = rs_new / jnp.where(rs == 0.0, 1.0, rs)
            p, _, _ = kops.fused_deflate_direction(r, p, beta)
            rnorm = jnp.sqrt(rs_new)
            fail = jnp.where(
                (fail == 0) & (~jnp.isfinite(rnorm)),
                SolveStatus.BREAKDOWN_NONFINITE,
                fail,
            ).astype(jnp.int32)
            if stag is not None:
                stag, fail = engine.stagnation_update(
                    stag, rnorm, fail, jnp.bool_(True), stagnation_window
                )
            if trace is not None:
                trace = trace.at[j + 1].set(rnorm)
            return (j + 1, x, r, p, rnorm, trace, fail, stag), ()

        fail0 = engine.initial_fail(rnorm0)
        stag0 = engine.stagnation_init(rnorm0, stagnation_window)
        state = (
            jnp.int32(0), x0_loc, r0, p0, rnorm0, trace0, fail0, stag0,
        )
        state, _ = engine.run_recording_loop(step, active_fn, state, ell=0)
        j, x, _, _, rnorm, trace, fail, _ = state
        converged = rnorm <= threshold
        out = {
            "x": x,
            "iterations": j,
            "converged": converged,
            "residual_norm": rnorm,
            "matvecs": j + 1,
            "breakdown": fail > 0,
            "status": engine.exit_status(converged, fail),
        }
        if record_residuals:
            out["trace"] = trace
        return out

    return body


def _sharded_defcg_body(
    kind,
    aux,
    *,
    k,
    ell,
    tol,
    atol,
    maxiter,
    select,
    waw_jitter,
    refresh_aw,
    stagnation_window,
    record_residuals,
):
    """Deflated CG + harmonic-Ritz extraction on per-shard state.

    The iteration's ONE all-reduce merges ``[pᵀAp, rᵀAp, ApᵀAp,
    (AW)ᵀAp, ‖r‖², (AW)ᵀr]`` — fresh reductions of the incoming
    residual plus the Ap products; the post-update ``‖r₊‖²`` /
    ``(AW)ᵀr₊`` that β and μ need come from one-step recurrences off
    those fresh values, so μ and β need no second collective and
    recurrence rounding never accumulates.
    """
    ax = SOLVE_AXIS

    def body(leaves, b_loc, x0_loc, w_loc, aw_carry_loc):
        apply, _, basis_apply = _make_applies(kind, aux, leaves)
        dtype = b_loc.dtype
        matvecs = jnp.int32(0)

        # -- strategy.prepare (HarmonicRitz): exact refresh or stale -----
        if refresh_aw == "stale":
            aw_used = aw_carry_loc
        else:
            has_w = (
                jax.lax.psum(jnp.sum((w_loc != 0).astype(jnp.int32)), ax) > 0
            )
            aw_used = jax.lax.cond(
                has_w, basis_apply, lambda ww: jnp.zeros_like(ww), w_loc
            )
            matvecs = matvecs + k * has_w.astype(jnp.int32)

        # -- setup: WᵀAW factor + deflated initial guess -----------------
        r_init = b_loc - apply(x0_loc)
        matvecs = matvecs + 1
        waw, bsq, wr = engine.psum_merged(
            [w_loc @ aw_used.T, jnp.vdot(b_loc, b_loc), w_loc @ r_init], ax
        )
        bnorm = jnp.sqrt(bsq)
        threshold = jnp.maximum(tol * bnorm, atol)

        # Same regularization policy as solvers._factor_waw.
        waw = 0.5 * (waw + waw.T)
        dj = jnp.diag(waw)
        tr = jnp.sum(dj)
        if waw_jitter:
            scale = jnp.where(tr > 0, tr / k, 1.0)
            waw = waw + waw_jitter * scale * jnp.eye(k, dtype=waw.dtype)
        waw = waw + jnp.diag(
            jnp.where(dj == 0.0, jnp.maximum(tr / k, 1.0), 0.0)
        )
        waw_cho = cho_factor(waw)

        c = cho_solve(waw_cho, wr)
        x = x0_loc + c @ w_loc
        r = r_init - c @ aw_used
        rs0, awr0 = engine.psum_merged([jnp.vdot(r, r), aw_used @ r], ax)
        mu0 = cho_solve(waw_cho, awr0)
        p0 = r - mu0 @ w_loc
        winv = cho_solve(waw_cho, jnp.eye(k, dtype=aw_used.dtype))
        rnorm0 = jnp.sqrt(rs0)

        trace0 = engine.trace_init(rnorm0, maxiter, record_residuals)
        diverged_at = 1e8 * jnp.maximum(rnorm0, bnorm)

        def active_fn(state):
            j, rnorm, fail = state[0], state[4], state[6]
            return (j < maxiter) & (rnorm > threshold) & (fail == 0)

        def step(state, active, gate_matvec):
            j, x, r, p, rnorm, trace, fail, stag = state
            p_in = p
            if gate_matvec:
                ap = engine.gated_matvec(apply, p, active, None)
            else:
                ap = apply(p)
            rap_l, awap_l = kops.fused_rz_reduce(r, ap, aw_used)
            rs_l, awr_l = kops.fused_rz_reduce(r, r, aw_used)
            d, rap, apap, awap, rs, awr = engine.psum_merged(
                [jnp.vdot(p, ap), rap_l, jnp.vdot(ap, ap), awap_l,
                 rs_l, awr_l],
                ax,
            )
            bad, code = engine.classify_breakdown(d, rnorm, diverged_at)
            fail = jnp.where((fail == 0) & active, code, fail)
            # Sanitize the poisoned reductions too: alpha is zeroed on
            # breakdown, but 0·NaN would still poison the recurrences.
            ap = jnp.where(bad, 0.0, ap)
            rap = jnp.where(bad, 0.0, rap)
            apap = jnp.where(bad, 0.0, apap)
            awap = jnp.where(bad, 0.0, awap)
            alpha = jnp.where(
                bad | (~active), 0.0, rs / jnp.where(bad, 1.0, d)
            )
            x, r, _, _ = kops.fused_cg_update(x, r, p, ap, alpha)
            rs_new = jnp.maximum(
                rs - 2.0 * alpha * rap + alpha * alpha * apap, 0.0
            )
            awr_new = awr - alpha * awap
            mu = winv @ awr_new.astype(winv.dtype)
            beta = rs_new / jnp.where(rs == 0.0, 1.0, rs)
            p_new, _, _ = kops.fused_deflate_direction(
                r, p, beta, w_loc, mu
            )
            p = jnp.where(active & (~bad), p_new, p)
            rnorm_new = jnp.sqrt(rs_new)
            fail = jnp.where(
                (fail == 0) & active & (~jnp.isfinite(rnorm_new)),
                SolveStatus.BREAKDOWN_NONFINITE,
                fail,
            ).astype(jnp.int32)
            rnorm = jnp.where(active, rnorm_new, rnorm)
            if stag is not None:
                stag, fail = engine.stagnation_update(
                    stag, rnorm_new, fail, active, stagnation_window
                )
            if trace is not None:
                old = trace[j + 1]
                trace = trace.at[j + 1].set(jnp.where(active, rnorm, old))
            j = j + active.astype(j.dtype)
            return (j, x, r, p, rnorm, trace, fail, stag), (
                p_in, ap, alpha, beta,
            )

        fail0 = engine.initial_fail(rnorm0)
        stag0 = engine.stagnation_init(rnorm0, stagnation_window)
        state = (
            jnp.int32(0), x, r, p0, rnorm0, trace0, fail0, stag0,
        )
        state, rows = engine.run_recording_loop(
            step, active_fn, state, ell=ell
        )
        j, x = state[0], state[1]
        rnorm, trace, fail = state[4], state[5], state[6]
        converged = rnorm <= threshold
        breakdown = fail > 0

        # -- strategy.transition: sharded harmonic-Ritz extraction -------
        theta = None
        if ell > 0:
            p_rows, ap_rows, _, _ = rows
            w2, aw2, theta, _ = extract_next_basis_core(
                w_loc, aw_used, p_rows, ap_rows, jnp.minimum(j, ell), k,
                select=select, psum_axis=ax,
            )
        else:
            w2, aw2 = w_loc, aw_used

        # -- terminal retirement (the ladder-less safety floor; mirrors
        # lsmr._one_recycled_lsmr): never hand poisoned coordinates or a
        # poisoned basis to the caller / next system.  One merged
        # all-reduce covers both finiteness checks.
        nonfinite_x = jnp.sum((~jnp.isfinite(x)).astype(jnp.int32))
        nonfinite_basis = jnp.sum(
            (~jnp.isfinite(w2)).astype(jnp.int32)
        ) + jnp.sum((~jnp.isfinite(aw2)).astype(jnp.int32))
        nonfinite_x, nonfinite_basis = engine.psum_merged(
            [nonfinite_x, nonfinite_basis], ax
        )
        x_safe = jnp.where(jnp.isfinite(x0_loc), x0_loc, jnp.zeros((), dtype))
        x = jnp.where(nonfinite_x == 0, x, x_safe)
        retire = breakdown | (nonfinite_basis > 0)
        w2 = jnp.where(retire, 0.0, w2)
        aw2 = jnp.where(retire, 0.0, aw2)
        if theta is not None:
            theta = jnp.where(retire, 0.0, theta)

        out = {
            "x": x,
            "iterations": j,
            "converged": converged,
            "residual_norm": rnorm,
            "matvecs": matvecs + j,
            "breakdown": breakdown,
            "status": engine.exit_status(converged, fail),
            "w": w2,
            "aw": aw2,
        }
        if record_residuals:
            out["trace"] = trace
        if ell > 0:
            out["theta"] = theta
        return out

    return body


def _sharded_lsmr_body(
    kind,
    aux,
    *,
    damp,
    tol,
    atol,
    maxiter,
    stagnation_window,
    record_residuals,
    has_x0,
):
    """Plain LSMR on per-shard state — 2 all-reduces per iteration (the
    Golub–Kahan β and α normalizations are serially dependent: ``u₊``
    must be normalized before ``Âᵀu₊`` exists)."""
    ax = SOLVE_AXIS
    has_shift = damp > 0.0
    sqrt_damp = float(damp) ** 0.5

    def body(leaves, b_loc, x0_loc):
        apply, rapply, _ = _make_applies(kind, aux, leaves)

        init_mv = jnp.int32(1)
        if has_x0:
            r_m = b_loc - apply(x0_loc)
            init_mv = init_mv + 1
        else:
            r_m = b_loc
        u_n0 = -sqrt_damp * x0_loc if has_shift else None

        bsum = jnp.vdot(r_m, r_m)
        if has_shift:
            bsum = bsum + jnp.vdot(u_n0, u_n0)
        (beta_sq,) = engine.psum_merged([bsum], ax)
        beta1 = jnp.sqrt(beta_sq)
        safe_b = jnp.where(beta1 == 0.0, 1.0, beta1)
        u_m0 = r_m / safe_b
        u_n0 = (u_n0 / safe_b) if has_shift else None

        g0 = rapply(u_m0)
        if has_shift:
            g0 = g0 + sqrt_damp * u_n0
        (asum,) = engine.psum_merged([jnp.vdot(g0, g0)], ax)
        alpha1 = jnp.sqrt(asum)
        safe_a = jnp.where(alpha1 == 0.0, 1.0, alpha1)
        v0 = g0 / safe_a

        normar0 = alpha1 * beta1
        threshold = jnp.maximum(tol * normar0, atol)
        diverged_at = 1e8 * normar0
        trace0 = engine.trace_init(normar0, maxiter, record_residuals)
        fail0 = engine.initial_fail(normar0)
        stag0 = engine.stagnation_init(normar0, stagnation_window)
        one = jnp.ones((), b_loc.dtype)

        def active_fn(state):
            j, zetabar, fail = state[0], state[7], state[16]
            return (
                (j < maxiter) & (jnp.abs(zetabar) > threshold) & (fail == 0)
            )

        def step(state, active, gate_matvec):
            del active, gate_matvec  # ell == 0: while-phase only
            (j, x, u_m, u_n, v, g, alpha, zetabar, alphabar, rho, rhobar,
             cbar, sbar, h, hbar, trace, fail, stag) = state

            av = apply(v)
            u_m_new = av - alpha * u_m
            bs = jnp.vdot(u_m_new, u_m_new)
            if has_shift:
                u_n_new = sqrt_damp * v - alpha * u_n
                bs = bs + jnp.vdot(u_n_new, u_n_new)
            (beta_sq_,) = engine.psum_merged([bs], ax)
            beta_new = jnp.sqrt(beta_sq_)
            sb = jnp.where(beta_new == 0.0, 1.0, beta_new)
            u_m_new = u_m_new / sb
            if has_shift:
                u_n_new = u_n_new / sb

            atu = rapply(u_m_new)
            g_new = atu + sqrt_damp * u_n_new if has_shift else atu
            w_vec = g_new - beta_new * v
            (as_,) = engine.psum_merged([jnp.vdot(w_vec, w_vec)], ax)
            alpha_new = jnp.sqrt(as_)
            sa = jnp.where(alpha_new == 0.0, 1.0, alpha_new)
            v_new = w_vec / sa

            rho_old, rhobar_old = rho, rhobar
            c, s, rho_new = _sym_ortho(alphabar, beta_new)
            thetanew = s * alpha_new
            alphabar_new = c * alpha_new
            thetabar = sbar * rho_new
            cbar_new, sbar_new, rhobar_new = _sym_ortho(
                cbar * rho_new, thetanew
            )
            zeta = cbar_new * zetabar
            zetabar_new = -sbar_new * zetabar

            sr = jnp.where(rho_new == 0.0, 1.0, rho_new)
            srb = jnp.where(rhobar_new == 0.0, 1.0, rhobar_new)
            c0 = thetabar * rho_new / (rho_old * rhobar_old)
            c1 = zeta / (sr * srb)
            c2 = thetanew / sr
            x_new, hbar_new, h_new = kops.lsmr_update(
                x, hbar, h, v_new, c0, c1, c2
            )

            exact = (beta_new == 0.0) | (alpha_new == 0.0)
            zetabar_new = jnp.where(exact, 0.0, zetabar_new)
            normar_new = jnp.abs(zetabar_new)

            fail = jnp.where(
                (fail == 0) & (~jnp.isfinite(normar_new)),
                SolveStatus.BREAKDOWN_NONFINITE,
                fail,
            ).astype(jnp.int32)
            fail = jnp.where(
                (fail == 0) & (normar_new > diverged_at),
                SolveStatus.STAGNATED,
                fail,
            ).astype(jnp.int32)
            if stag is not None:
                stag, fail = engine.stagnation_update(
                    stag, normar_new, fail, jnp.bool_(True),
                    stagnation_window,
                )
            if trace is not None:
                trace = trace.at[j + 1].set(normar_new)

            state_new = (
                j + 1, x_new, u_m_new,
                u_n_new if has_shift else None,
                v_new, g_new, alpha_new, zetabar_new, alphabar_new,
                rho_new, rhobar_new, cbar_new, sbar_new, h_new, hbar_new,
                trace, fail, stag,
            )
            return state_new, ()

        state = (
            jnp.int32(0), x0_loc, u_m0, u_n0, v0, g0, alpha1,
            normar0, alpha1, one, one, one, jnp.zeros((), b_loc.dtype),
            v0, jnp.zeros_like(v0), trace0, fail0, stag0,
        )
        state, _ = engine.run_recording_loop(step, active_fn, state, ell=0)
        j, x = state[0], state[1]
        zetabar, trace, fail = state[7], state[15], state[16]
        normar = jnp.abs(zetabar)
        converged = normar <= threshold
        out = {
            "x": x,
            "iterations": j,
            "converged": converged,
            "residual_norm": normar,
            "matvecs": init_mv + 2 * j,
            "breakdown": fail > 0,
            "status": engine.exit_status(converged, fail),
        }
        if record_residuals:
            out["trace"] = trace
        return out

    return body


def _sym_ortho(a, b):
    """Stable Givens pair — duplicated from repro.core.lsmr to keep this
    module importable without the (heavier) lsmr module at trace time."""
    r = jnp.sqrt(a * a + b * b)
    safe = jnp.where(r == 0.0, 1.0, r)
    return a / safe, b / safe, r


# ---------------------------------------------------------------------------
# Builder — shard_map + jit, cached per (mesh, operator kind, spec)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=128)
def _build(mesh: Mesh, method: str, kind: str, aux, leaf_specs, statics):
    """Compile-cached sharded solver: ``shard_map`` over ``mesh`` of the
    method body, jitted.  Everything static rides the cache key; the
    returned callable takes only traced arrays."""
    st = dict(statics)
    if method == "cg":
        body = _sharded_cg_body(
            kind, aux,
            tol=st["tol"], atol=st["atol"], maxiter=st["maxiter"],
            stagnation_window=st["stagnation_window"],
            record_residuals=st["record_residuals"],
        )
        in_specs = (leaf_specs, P(SOLVE_AXIS), P(SOLVE_AXIS))
    elif method == "defcg":
        body = _sharded_defcg_body(
            kind, aux,
            k=st["k"], ell=st["ell"], tol=st["tol"], atol=st["atol"],
            maxiter=st["maxiter"], select=st["select"],
            waw_jitter=st["waw_jitter"], refresh_aw=st["refresh_aw"],
            stagnation_window=st["stagnation_window"],
            record_residuals=st["record_residuals"],
        )
        in_specs = (
            leaf_specs, P(SOLVE_AXIS), P(SOLVE_AXIS),
            basis_spec(), basis_spec(),
        )
    elif method == "lsmr":
        body = _sharded_lsmr_body(
            kind, aux,
            damp=st["damp"], tol=st["tol"], atol=st["atol"],
            maxiter=st["maxiter"],
            stagnation_window=st["stagnation_window"],
            record_residuals=st["record_residuals"],
            has_x0=st["has_x0"],
        )
        in_specs = (leaf_specs, P(SOLVE_AXIS), P(SOLVE_AXIS))
    else:
        raise ValueError(f"unknown sharded method {method!r}")

    out_specs = {
        "x": vector_spec(),
        "iterations": P(),
        "converged": P(),
        "residual_norm": P(),
        "matvecs": P(),
        "breakdown": P(),
        "status": P(),
    }
    if st["record_residuals"]:
        out_specs["trace"] = P()
    if method == "defcg":
        out_specs["w"] = basis_spec()
        out_specs["aw"] = basis_spec()
        if st["ell"] > 0:
            out_specs["theta"] = P()

    sharded = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )
    return jax.jit(sharded)


def _divisible(name: str, size: int, n_shards: int) -> None:
    if size % n_shards != 0:
        raise ValueError(
            f"{name} has length {size}, not divisible by the solve mesh's "
            f"{n_shards} shards — pad the problem or resize the mesh "
            "(repro.launch.mesh.make_solve_mesh(n_devices=...))"
        )


def _prepare(A, b, spec, state, *, mesh, x0, record_residuals):
    """Shared host-side setup of :func:`solve_sharded` /
    :func:`lower_sharded`: validation, operator planning, argument
    flattening.  Returns ``(fn, args, assemble)``."""
    from repro.core import api as api_mod

    spec = api_mod.SolveSpec() if spec is None else spec
    if not isinstance(mesh, Mesh) or SOLVE_AXIS not in mesh.axis_names:
        raise ValueError(
            f"mesh must be a jax Mesh with a {SOLVE_AXIS!r} axis — build "
            "one with repro.launch.mesh.make_solve_mesh()"
        )
    if spec.method not in _SHARDED_METHODS:
        raise NotImplementedError(
            f"method={spec.method!r} has no sharded path yet (supported: "
            f"{_SHARDED_METHODS}); drop the mesh argument"
        )
    if spec.precond != "none":
        raise ValueError(
            "the sharded engine has no preconditioner path — use "
            "precond='none' or drop the mesh argument"
        )
    if spec.method == "defcg" and type(spec.strategy) is not HarmonicRitz:
        raise ValueError(
            "the sharded def-CG path extracts through the default "
            f"HarmonicRitz strategy only, got {type(spec.strategy).__name__}"
        )

    n_shards = mesh.shape[SOLVE_AXIS]
    need_adjoint = spec.method == "lsmr"
    kind, aux, leaves, leaf_specs = _plan_operator(
        A, need_adjoint=need_adjoint
    )

    b_flat, _ = pt.ravel_vector(b)
    m = b_flat.shape[0]
    _divisible("b", m, n_shards)

    if spec.method == "lsmr":
        if kind == "dense":
            n = leaves[0].shape[1]
        else:
            n = m  # symmetric-by-contract operators: domain == range
        _divisible("x", n, n_shards)
        has_x0 = x0 is not None
        x0_flat = (
            pt.ravel(x0) if has_x0 else jnp.zeros((n,), b_flat.dtype)
        )
        statics = (
            ("damp", float(spec.lsq_shift)),
            ("tol", float(spec.tol)),
            ("atol", float(spec.atol)),
            ("maxiter", int(spec.maxiter)),
            ("stagnation_window", int(spec.stagnation_window)),
            ("record_residuals", bool(record_residuals)),
            ("has_x0", has_x0),
        )
        fn = _build(mesh, "lsmr", kind, aux, leaf_specs, statics)
        args = (
            _commit_tree(mesh, leaves, leaf_specs),
            _commit(mesh, b_flat, vector_spec()),
            _commit(mesh, x0_flat, vector_spec()),
        )

        def assemble(out):
            info = _info_from(out, record_residuals)
            return api_mod.SolveResult(
                x=out["x"], info=info, state=state,
                report=api_mod._make_report(info, 0),
            )

        return fn, args, assemble

    n = m
    x0_flat = jnp.zeros_like(b_flat) if x0 is None else pt.ravel(x0)

    if spec.method == "cg":
        statics = (
            ("tol", float(spec.tol)),
            ("atol", float(spec.atol)),
            ("maxiter", int(spec.maxiter)),
            ("stagnation_window", int(spec.stagnation_window)),
            ("record_residuals", bool(record_residuals)),
        )
        fn = _build(mesh, "cg", kind, aux, leaf_specs, statics)
        args = (
            _commit_tree(mesh, leaves, leaf_specs),
            _commit(mesh, b_flat, vector_spec()),
            _commit(mesh, x0_flat, vector_spec()),
        )

        def assemble(out):
            info = _info_from(out, record_residuals)
            return api_mod.SolveResult(
                x=out["x"], info=info, state=state,
                report=api_mod._make_report(info, 0),
            )

        return fn, args, assemble

    # -- defcg ----------------------------------------------------------
    state_in = (
        RecycleState.zeros(spec.k, n, b_flat.dtype)
        if state is None
        else state
    )
    if state_in.W.ndim != 2 or state_in.W.shape != (spec.k, n):
        raise ValueError(
            f"state.W has shape {state_in.W.shape}; spec(k={spec.k}) over "
            f"this system needs ({spec.k}, {n}) — state and spec must agree"
        )
    statics = (
        ("k", int(spec.k)),
        ("ell", int(spec.ell)),
        ("tol", float(spec.tol)),
        ("atol", float(spec.atol)),
        ("maxiter", int(spec.maxiter)),
        ("select", spec.select),
        ("waw_jitter", float(spec.waw_jitter)),
        ("refresh_aw", spec.refresh_aw),
        ("stagnation_window", int(spec.stagnation_window)),
        ("record_residuals", bool(record_residuals)),
    )
    fn = _build(mesh, "defcg", kind, aux, leaf_specs, statics)
    args = (
        _commit_tree(mesh, leaves, leaf_specs),
        _commit(mesh, b_flat, vector_spec()),
        _commit(mesh, x0_flat, vector_spec()),
        _commit(mesh, state_in.W, basis_spec()),
        _commit(mesh, state_in.AW, basis_spec()),
    )

    def assemble(out):
        info = _info_from(out, record_residuals)
        new_state = RecycleState(
            W=out["w"],
            AW=out["aw"],
            theta=out["theta"] if spec.ell > 0 else state_in.theta,
            systems_solved=state_in.systems_solved + 1,
            drift=(
                jnp.zeros((), state_in.drift.dtype)
                if spec.ell > 0
                else state_in.drift
            ),
        )
        return api_mod.SolveResult(
            x=out["x"], info=info, state=new_state,
            report=api_mod._make_report(info, 0),
        )

    return fn, args, assemble


def _info_from(out, record_residuals: bool) -> SolveInfo:
    return SolveInfo(
        iterations=out["iterations"],
        converged=out["converged"],
        residual_norm=out["residual_norm"],
        matvecs=out["matvecs"],
        residual_norms=out.get("trace") if record_residuals else None,
        breakdown=out["breakdown"],
        status=out["status"],
    )


def solve_sharded(
    A,
    b: Pytree,
    spec=None,
    state: Optional[RecycleState] = None,
    *,
    mesh: Mesh,
    x0: Optional[Pytree] = None,
    record_residuals: bool = False,
):
    """One solve on the ``"solve"`` mesh — the sharded twin of
    :func:`repro.core.api.solve` (which forwards here when called with
    ``mesh=``).  Same ``SolveResult`` contract; see the module docstring
    for the (small, documented) semantic differences.
    """
    fn, args, assemble = _prepare(
        A, b, spec, state, mesh=mesh, x0=x0,
        record_residuals=record_residuals,
    )
    return assemble(fn(*args))


def lower_sharded(
    A,
    b: Pytree,
    spec=None,
    state: Optional[RecycleState] = None,
    *,
    mesh: Mesh,
    x0: Optional[Pytree] = None,
    record_residuals: bool = False,
):
    """The sharded solve's :class:`jax.stages.Lowered` — for the HLO
    collective-counting gates (``lowered.compile().as_text()`` feeds
    :func:`repro.launch.hlo_stats.while_body_collectives`)."""
    fn, args, _ = _prepare(
        A, b, spec, state, mesh=mesh, x0=x0,
        record_residuals=record_residuals,
    )
    return fn.lower(*args)
