"""Matrix-free symmetric positive-definite linear operators.

The solvers in :mod:`repro.core.solvers` only ever touch ``A`` through
``A @ v`` (a matvec on a pytree).  This module provides the operator
abstraction plus the concrete operators the framework uses:

* :func:`from_matrix` — an explicit dense matrix (tests / small problems);
* :class:`KernelSystemOperator` — the paper's GP-classification Newton
  system ``A = I + H^{1/2} K H^{1/2}`` (Eq. 10), matrix-free over the fused
  Gram-matvec kernel so the ``n x n`` Gram matrix is never materialized;
* :class:`GGNOperator` — damped Gauss-Newton matvec through an arbitrary
  model (``G v = Jᵀ H_L J v + λ v`` via ``jvp``/``vjp``), the Hessian-free
  workhorse that carries the paper's technique to LM-scale training;
* shift/scale/sum composition helpers.

Operators are registered as pytree nodes so they can cross ``jit``
boundaries as arguments.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.flatten_util
import jax.numpy as jnp

from repro.core import pytree as pt

Pytree = Any
Matvec = Callable[[Pytree], Pytree]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class LinearOperator:
    """A linear operator ``v ↦ A v`` — symmetric by default, rectangular
    when an adjoint is supplied.

    Attributes:
      matvec: the matvec closure.  Must be pure and jit-compatible.
      matvec_cost_flops: optional static estimate of flops per matvec,
        used by benchmark accounting (``None`` → unknown).
      matmat: optional multi-RHS closure ``V ↦ A V`` over column-stacked
        ``(n, r)`` arrays (array-vector operators only).  When present,
        :func:`apply_to_basis` refreshes a whole recycled basis in one
        operator application instead of r sequential matvecs.
      rmatvec: optional adjoint closure ``u ↦ Aᵀ u``.  ``None`` declares
        the operator SYMMETRIC (the historical contract of this repo:
        every SPD solve path assumes it), in which case :attr:`T` is the
        operator itself.  Supplying it opens the rectangular / least-
        squares workload: LSMR touches ``A`` only through
        ``matvec``/``rmatvec`` pairs.
    """

    matvec: Matvec
    matvec_cost_flops: Optional[float] = None
    matmat: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None
    rmatvec: Optional[Matvec] = None

    def __call__(self, v: Pytree) -> Pytree:
        return self.matvec(v)

    def __matmul__(self, v: Pytree) -> Pytree:
        return self.matvec(v)

    @property
    def T(self) -> "LinearOperator":
        """The adjoint operator ``u ↦ Aᵀ u``.

        Symmetric operators (``rmatvec is None``) are their own adjoint;
        rectangular ones get a fresh operator with the closures swapped,
        so ``op.T.T`` round-trips.
        """
        if self.rmatvec is None:
            return self
        return LinearOperator(
            self.rmatvec, self.matvec_cost_flops, None, self.matvec
        )

    def basis_matvec(self, basis: Pytree) -> Pytree:
        """``A`` applied to every vector of a stacked basis (leading axis).

        One ``matmat`` call when available (the basis rows become columns),
        else a vmapped matvec sweep.
        """
        if self.matmat is not None:
            return self.matmat(jnp.swapaxes(basis, 0, 1)).swapaxes(0, 1)
        return pt.basis_map_vectors(self.matvec, basis)

    # -- composition ------------------------------------------------------
    def shifted(self, sigma) -> "LinearOperator":
        """``A + sigma I`` (square operators only)."""

        def mv(v, base=self.matvec):
            return pt.tree_axpy(sigma, v, base(v))

        mm = None
        if self.matmat is not None:

            def mm(vs, base=self.matmat):
                return base(vs) + sigma * vs

        rmv = None
        if self.rmatvec is not None:

            def rmv(u, base=self.rmatvec):
                return pt.tree_axpy(sigma, u, base(u))

        return LinearOperator(mv, self.matvec_cost_flops, mm, rmv)

    def scaled(self, c) -> "LinearOperator":
        def mv(v, base=self.matvec):
            return pt.tree_scale(c, base(v))

        mm = None
        if self.matmat is not None:

            def mm(vs, base=self.matmat):
                return c * base(vs)

        rmv = None
        if self.rmatvec is not None:

            def rmv(u, base=self.rmatvec):
                return pt.tree_scale(c, base(u))

        return LinearOperator(mv, self.matvec_cost_flops, mm, rmv)

    def __add__(self, other: "LinearOperator") -> "LinearOperator":
        def mv(v, a=self.matvec, b=other.matvec):
            return pt.tree_add(a(v), b(v))

        cost = None
        if self.matvec_cost_flops is not None and other.matvec_cost_flops is not None:
            cost = self.matvec_cost_flops + other.matvec_cost_flops
        mm = None
        if self.matmat is not None and other.matmat is not None:

            def mm(vs, a=self.matmat, b=other.matmat):
                return a(vs) + b(vs)

        rmv = None
        if self.rmatvec is not None and other.rmatvec is not None:

            def rmv(u, a=self.rmatvec, b=other.rmatvec):
                return pt.tree_add(a(u), b(u))

        return LinearOperator(mv, cost, mm, rmv)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (), (self.matvec, self.matvec_cost_flops, self.matmat, self.rmatvec)

    @classmethod
    def tree_unflatten(cls, aux, children):
        del children
        return cls(*aux)


@jax.tree_util.register_pytree_node_class
class DenseMatrixOperator(LinearOperator):
    """Dense matrix as an operator — with the matrix as a pytree LEAF.

    The base :class:`LinearOperator` flattens with zero children (its
    closures are aux data), which is right for opaque callables but
    wrong for an explicit matrix: aux data is part of the jit cache key,
    so a closure-wrapped matrix retraced ``solve_jit`` for EVERY new
    system (the trace-audit gate's retrace-budget check catches exactly
    this).  Here the matrix is the child — two operators over same-shape
    matrices share one trace, vmap batches over a stacked leading axis,
    and the matrix shards like any other array.

    Rectangular ``(m, n)`` matrices are supported: ``matvec`` maps
    ``(n,) → (m,)`` and :attr:`rmatvec`/:attr:`T` apply ``matᵀ`` —
    which is what the LSMR front door consumes.  Square SPD usage is
    unchanged (the SPD solvers never call ``rmatvec``).
    """

    def __init__(self, mat: jnp.ndarray):
        self.mat = mat
        # Unflatten may pass non-array sentinels (treedef manipulation);
        # the matvec is never called on those, but __init__ must survive.
        shape = getattr(mat, "shape", None)
        m, n = (shape[-2], shape[-1]) if shape and len(shape) >= 2 else (0, 0)

        def mv(v):
            return mat @ v

        def rmv(u):
            return jnp.swapaxes(mat, -2, -1) @ u

        LinearOperator.__init__(
            self, mv, matvec_cost_flops=2.0 * m * n, matmat=mv, rmatvec=rmv
        )

    @property
    def T(self) -> "DenseMatrixOperator":
        return DenseMatrixOperator(jnp.swapaxes(self.mat, -2, -1))

    def tree_flatten(self):
        return (self.mat,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        (mat,) = children
        return cls(mat)


def from_matrix(mat: jnp.ndarray) -> DenseMatrixOperator:
    """Explicit dense SPD matrix as an operator over flat ``(n,)`` vectors.

    The matrix is carried as a traced pytree leaf (see
    :class:`DenseMatrixOperator`): solves over different same-shape
    matrices hit one compiled trace instead of retracing per system.
    """
    return DenseMatrixOperator(mat)


def from_callable(fn: Matvec, cost: Optional[float] = None) -> LinearOperator:
    return LinearOperator(fn, cost)


def apply_to_basis(op, basis: Pytree) -> Pytree:
    """``A @ [w_1 … w_m]`` as ONE multi-RHS operator application.

    The cross-system refresh of the recycled basis (``AW`` for the next
    system's operator) is the paper's §2.2 overhead term: issued as m
    sequential matvecs it costs m operator passes; operators that expose
    ``basis_matvec`` (all the concrete ones here) amortize it into a
    single pass — e.g. the fused RBF Gram kernel forms each K-tile once
    for all m right-hand sides.  Falls back to a vmapped matvec sweep for
    bare callables.
    """
    bm = getattr(op, "basis_matvec", None)
    if bm is not None:
        return bm(basis)
    return pt.basis_map_vectors(op, basis)


# ---------------------------------------------------------------------------
# The paper's Newton-system operator (GP classification, Eq. 10)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class KernelSystemOperator:
    """``A v = v + H^{1/2} · K (H^{1/2} · v)`` — Kuss–Rasmussen restructuring.

    ``kernel_matvec`` computes ``K u`` matrix-free (fused Pallas kernel on
    TPU, chunked-jnp elsewhere) and must also accept column-stacked
    ``(n, r)`` right-hand sides (both the fused kernel and a dense
    ``K @ V`` do); ``sqrt_h`` is the elementwise vector ``H^{1/2}`` (H
    diagonal for logistic likelihood).  Eigenvalues of ``A`` are confined
    to ``[1, n·max(K)/4]`` which is what makes CG and def-CG well behaved
    on this family (paper §3).
    """

    kernel_matvec: Matvec
    sqrt_h: jnp.ndarray
    matvec_cost_flops: Optional[float] = None

    def matvec(self, v):
        return v + self.sqrt_h * self.kernel_matvec(self.sqrt_h * v)

    def basis_matvec(self, basis: jnp.ndarray) -> jnp.ndarray:
        """``A`` on an ``(m, n)`` stacked basis — one fused multi-RHS
        Gram pass (each K-tile formed once for all m vectors)."""
        v = (basis * self.sqrt_h[None, :]).T  # (n, m) column-stacked
        return basis + self.sqrt_h[None, :] * self.kernel_matvec(v).T

    def __call__(self, v):
        return self.matvec(v)

    def __matmul__(self, v):
        return self.matvec(v)

    def tree_flatten(self):
        return (self.sqrt_h,), (self.kernel_matvec, self.matvec_cost_flops)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (sqrt_h,) = children
        kernel_matvec, cost = aux
        return cls(kernel_matvec, sqrt_h, cost)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RBFKernelSystemOperator:
    """The GP Newton operator with its DATA as pytree leaves — shardable.

    Same math as :class:`KernelSystemOperator` specialized to the RBF
    Gram kernel, ``A v = v + H^{1/2} · K(X, X) (H^{1/2} · v)``, but the
    training data ``x`` and the likelihood diagonal ``sqrt_h`` are
    pytree CHILDREN instead of being baked into a matvec closure.  That
    is what makes the operator mesh-shardable (DESIGN.md §5): under the
    sharded engine each device keeps a ROW block of ``x``/``sqrt_h``
    local, the matvec all-gathers the scaled vector once per iteration,
    and the local K-tiles are formed and consumed on the fly
    (:func:`repro.kernels.ops.rbf_matvec_rect`) — n = 10⁵–10⁶ solves
    never materialize the n×n Gram matrix.  On one device it behaves
    exactly like ``KernelSystemOperator`` over the fused/chunked Gram
    matvec (and, being leaf-carrying, same-shape systems share one
    ``solve_jit`` trace, like :class:`DenseMatrixOperator`).

    ``theta``/``lengthscale``/``block``/``impl`` are static aux data —
    hyperparameter *values* bake into the trace; the kernel wrapper
    pre-scales inputs so the Pallas kernel itself never recompiles.
    """

    x: jnp.ndarray  # (n, d) training inputs
    sqrt_h: jnp.ndarray  # (n,) H^{1/2} diagonal
    theta: float = 1.0
    lengthscale: float = 1.0
    block: int = 1024
    impl: str = "auto"

    def kernel_matvec(self, u: jnp.ndarray) -> jnp.ndarray:
        """``K(X, X) @ u`` — (n,) or column-stacked (n, r)."""
        from repro.kernels import ops as kops

        return kops.rbf_matvec(
            self.x, u, self.theta, self.lengthscale,
            impl=self.impl, block=self.block,
        )

    def matvec(self, v: jnp.ndarray) -> jnp.ndarray:
        return v + self.sqrt_h * self.kernel_matvec(self.sqrt_h * v)

    def basis_matvec(self, basis: jnp.ndarray) -> jnp.ndarray:
        v = (basis * self.sqrt_h[None, :]).T  # (n, m) column-stacked
        return basis + self.sqrt_h[None, :] * self.kernel_matvec(v).T

    def __call__(self, v):
        return self.matvec(v)

    def __matmul__(self, v):
        return self.matvec(v)

    def tree_flatten(self):
        return (self.x, self.sqrt_h), (
            self.theta, self.lengthscale, self.block, self.impl,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        x, sqrt_h = children
        theta, lengthscale, block, impl = aux
        return cls(x, sqrt_h, theta, lengthscale, block, impl)


# ---------------------------------------------------------------------------
# Gauss-Newton operator — Hessian-free optimization at LM scale
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GGNOperator:
    """Damped generalized Gauss-Newton matvec ``(Jᵀ H_L J + λ I) v``.

    ``model_fn(params) -> outputs`` is the network up to its final linear
    outputs; ``loss_hvp(outputs, tangent_out) -> tangent_out'`` applies the
    (tiny, typically diagonal or per-token-softmax) loss Hessian.  The GGN
    is SPD for convex losses, which is exactly the setting def-CG needs.

    One matvec = one ``jvp`` + one loss-Hessian apply + one ``vjp`` —
    roughly 3x a forward pass, entirely expressible in XLA so the full
    Hessian-free step (def-CG loop included) jits and shards under pjit.
    """

    model_fn: Callable[[Pytree], Pytree]
    loss_hvp: Callable[[Pytree, Pytree], Pytree]
    params: Pytree
    damping: jnp.ndarray = dataclasses.field(default_factory=lambda: jnp.float32(0.0))
    matvec_cost_flops: Optional[float] = None

    def matvec(self, v: Pytree) -> Pytree:
        outputs, jv = jax.jvp(self.model_fn, (self.params,), (v,))
        hjv = self.loss_hvp(outputs, jv)
        _, vjp_fn = jax.vjp(self.model_fn, self.params)
        (gv,) = vjp_fn(hjv)
        return pt.tree_axpy(self.damping, v, gv)

    def basis_matvec(self, basis: Pytree) -> Pytree:
        """GGN applied to a stacked basis: the model is linearized ONCE
        and the (linear) tangent/cotangent maps are vmapped over the m
        vectors — two forward passes total instead of 2m."""
        outputs, jvp_fn = jax.linearize(self.model_fn, self.params)
        _, vjp_fn = jax.vjp(self.model_fn, self.params)

        def one(v):
            hjv = self.loss_hvp(outputs, jvp_fn(v))
            (gv,) = vjp_fn(hjv)
            return pt.tree_axpy(self.damping, v, gv)

        return jax.vmap(one)(basis)

    def __call__(self, v):
        return self.matvec(v)

    def __matmul__(self, v):
        return self.matvec(v)

    def tree_flatten(self):
        return (self.params, self.damping), (
            self.model_fn,
            self.loss_hvp,
            self.matvec_cost_flops,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        params, damping = children
        model_fn, loss_hvp, cost = aux
        return cls(model_fn, loss_hvp, params, damping, cost)


# ---------------------------------------------------------------------------
# Gauss-Newton Jacobian operator — the rectangular least-squares workhorse
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GaussNewtonOperator:
    """The Jacobian ``J`` of a residual map as a rectangular operator.

    ``residual_fn(params) -> residuals`` is the model's residual map
    (e.g. ``predictions − targets``); the operator exposes the two
    products LSMR consumes:

    * ``matvec(v) = J v`` — one ``jvp`` through the residual map;
    * ``rmatvec(u) = Jᵀ u`` — one ``vjp``.

    Solving ``min ‖J δ + r‖² + λ‖δ‖²`` with :func:`repro.core.lsmr.lsmr`
    is the TRUE Gauss-Newton step — unlike :class:`GGNOperator` (which
    squares ``J`` into ``JᵀH_LJ`` and hands an SPD system to CG), the
    least-squares path never forms the normal-equations operator, so its
    conditioning is κ(J), not κ(J)².  Domain is the params pytree, range
    the residual pytree — both cross the flat engine through their own
    ravel/unravel pair.
    """

    residual_fn: Callable[[Pytree], Pytree]
    params: Pytree
    matvec_cost_flops: Optional[float] = None

    def matvec(self, v: Pytree) -> Pytree:
        return jax.jvp(self.residual_fn, (self.params,), (v,))[1]

    def rmatvec(self, u: Pytree) -> Pytree:
        _, vjp_fn = jax.vjp(self.residual_fn, self.params)
        (jtv,) = vjp_fn(u)
        return jtv

    def residuals(self) -> Pytree:
        """``r(params)`` — the right-hand side is ``−r`` for a GN step."""
        return self.residual_fn(self.params)

    @property
    def T(self) -> LinearOperator:
        return LinearOperator(
            self.rmatvec, self.matvec_cost_flops, None, self.matvec
        )

    def __call__(self, v):
        return self.matvec(v)

    def __matmul__(self, v):
        return self.matvec(v)

    def tree_flatten(self):
        return (self.params,), (self.residual_fn, self.matvec_cost_flops)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (params,) = children
        residual_fn, cost = aux
        return cls(residual_fn, params, cost)


def adjoint_matvec(op) -> Matvec:
    """The ``u ↦ Aᵀ u`` closure of ``op``.

    Operators without an ``rmatvec`` are symmetric by this repo's
    contract (every SPD solve path already relies on it), so their
    adjoint is their own matvec.  This is the single place the LSMR
    engine resolves adjoints through.
    """
    rmv = getattr(op, "rmatvec", None)
    if rmv is not None:
        return rmv
    return op.matvec if hasattr(op, "matvec") else op


def materialize(op, template: Pytree) -> jnp.ndarray:
    """Densify a small operator (tests only): returns the matrix of ``op``
    in the coordinate system of ``template``'s raveled pytree."""
    flat, unravel = jax.flatten_util.ravel_pytree(template)
    n = flat.shape[0]

    def col(i):
        e = unravel(jnp.zeros_like(flat).at[i].set(1.0))
        out, _ = jax.flatten_util.ravel_pytree(op(e))
        return out

    return jax.vmap(col, out_axes=1)(jnp.arange(n))
