"""Iterative SPD solvers: CG, preconditioned CG, and deflated CG.

This file implements the paper's Algorithm 1 (Saad et al.'s deflated
conjugate gradient) as a jit-able, pytree-native, shardable solver:

* vectors are arbitrary pytrees (``repro.core.pytree``) at the API; the
  *inner loop* runs on a contiguous flat ``(n,)`` vector — each solve packs
  its pytree once (``pt.ravel_vector``), iterates on flat state, and
  unpacks once at exit (the flat-engine fast path, DESIGN.md §8);
* ``A`` is any matrix-free operator (``repro.core.operators``);
* the main iteration is driven by the method-agnostic harness
  (:mod:`repro.core.engine`): CG and def-CG supply only their per-method
  ``step``/``state`` contract, while the harness owns tolerance
  resolution, breakdown classification, stagnation tracking, the
  recording scan + while-loop split, and the vmap-aware matvec gate —
  the whole solve lowers to a single XLA computation that pjit can shard
  across a pod;
* the non-matvec vector work of an iteration lowers to two fused passes
  (``repro.kernels.ops.fused_cg_update`` / ``fused_deflate_direction``:
  Pallas kernels on TPU, fused-jnp elsewhere) instead of ~8 separate HBM
  sweeps — in the memory-bound regime the paper targets this, not the
  matvec, is the bottleneck;
* the first ``ell`` search directions and their ``A``-products are recorded
  into fixed-size ring buffers, which is all the harmonic-Ritz recycling
  step (``repro.core.recycle``) needs — zero extra matvecs, exactly the
  "readily available quantities" trick of the paper (§2.3, adapted: we
  store ``P``/``AP`` directly and form ``F``/``G`` by two tall-skinny GEMMs,
  which is MXU-friendly; see DESIGN.md §8).

Deflation (the lines that differ from textbook CG, cf. paper Alg. 1
lines 3 & 11):

    x0  = x_{-1} + W (WᵀAW)⁻¹ Wᵀ r_{-1}          # Wᵀ r0 = 0
    p0  = r0 − W μ0,        WᵀAW μ0 = WᵀA r0
    p_j = β p_{j-1} + r_j − W μ_j,  WᵀAW μ_j = WᵀA r_j

``WᵀA r`` is evaluated as ``(AW)ᵀ r`` (A symmetric) and fused into the
residual-update pass, so the per-iteration deflation overhead is one k×k
triangular solve plus the ``W μ`` combine inside the direction pass —
O(nk) flops and *no* additional collectives beyond the two GEMV psums.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve

from repro.core import engine
from repro.core import operators as ops_mod
from repro.core import pytree as pt
from repro.core.engine import (  # noqa: F401  (re-exported API surface)
    SolveInfo,
    SolveStatus,
)
from repro.kernels import ops as kops

Pytree = Any

# The ONE waw_jitter default, carried by ``repro.core.api.SolveSpec`` and
# referenced (never re-written as a literal) by every solve path.  Keep it
# SMALL: jitter ≳1e-8 reinjects un-deflated W-components each iteration and
# makes def-CG diverge with a well-converged Ritz basis (measured; see the
# ``waw_jitter`` arg of :func:`defcg`).
DEFAULT_WAW_JITTER = 1e-12

# The ONE noise floor for drift-guard thresholds, in units of the working
# dtype's eps: drift measurements (residual differences, gram asymmetry)
# carry rounding-level terms even for an exactly unchanged operator
# (~1e-16 in f64, ~1e-7 in f32), and a threshold below this floor would
# buy k-matvec refreshes on pure noise.  Shared by defcg's in-solve
# guard and every strategy-layer comparison (``repro.core.strategies``).
DRIFT_NOISE_FLOOR_EPS = 500.0

# Backwards-compatible aliases: the loop scaffolding moved to
# repro.core.engine (the method-agnostic harness); these names stay
# importable from here because recycle/api/serve grew up against them.
_STAGNATION_RTOL = engine.STAGNATION_RTOL
_classify_breakdown = engine.classify_breakdown
_exit_status = engine.exit_status
_tolerances = engine.tolerances
_flat_operator = engine.flat_operator


class RecycleData(NamedTuple):
    """Stored Krylov quantities — the solver→strategy window handoff.

    This is the contract between the def-CG scan phase and the
    :mod:`repro.core.strategies` layer: everything a recycle strategy may
    consume at the end-of-solve transition is recorded here, all of it
    "readily available" (paper §2.3) — zero extra matvecs.
    """

    P: Pytree  # basis of ell search directions
    AP: Pytree  # their A-products
    stored: jax.Array  # int32: valid columns (may be < ell on early converge)
    # CG recurrence coefficients of the recorded iterations: ``alpha[j]``
    # is the step size taken along ``P[j]``; ``beta[j]`` the direction
    # coefficient computed at the END of iteration j (it builds p_{j+1}).
    # Rows past ``stored`` are zero.  None when ``ell == 0``.
    alpha: Optional[jax.Array] = None  # (ell,)
    beta: Optional[jax.Array] = None  # (ell,)
    # The (k, n) basis products the solve ACTUALLY deflated with — set
    # only under ``stale_guard`` (flat recycle), where the in-solve guard
    # may have replaced the caller's stale AW with a fresh ``A·W``: the
    # extraction must recombine what was used, not what was passed.
    aw_used: Optional[jax.Array] = None


class CGResult(NamedTuple):
    x: Pytree
    info: SolveInfo
    recycle: Optional[RecycleData] = None


# ---------------------------------------------------------------------------
# Conjugate gradients (the paper's CG baseline)
# ---------------------------------------------------------------------------


def cg(
    A,
    b: Pytree,
    x0: Optional[Pytree] = None,
    *,
    tol: float = 1e-5,
    atol: float = 0.0,
    maxiter: int = 1000,
    M: Optional[Callable[[Pytree], Pytree]] = None,
    record_residuals: bool = False,
    stagnation_window: int = 0,
) -> CGResult:
    """(Preconditioned) conjugate gradients for SPD ``A``.

    ``M`` is an (SPD) preconditioner apply ``r ↦ M⁻¹ r``; ``None`` gives
    plain CG, matching the paper's baseline.

    The loop carries ``rᵀz`` through its state (computed once per
    iteration, not twice), and without a preconditioner the recurrence
    scalar is the ``‖r‖²`` reduction the fused update pass already emits —
    plain CG costs exactly one reduction per iteration beyond ``pᵀAp``.

    Per-iteration breakdown detection rides those same reductions: a
    non-finite or non-positive ``pᵀAp`` and a runaway ``‖r‖`` stop the
    loop with a typed cause in ``info.status`` (:class:`SolveStatus`).
    ``stagnation_window > 0`` additionally declares STAGNATED when the
    best residual fails to improve by 1% over that many consecutive
    iterations (0 — the default — adds no state and no checks).
    """
    b_flat, unravel = pt.ravel_vector(b)
    x_flat = jnp.zeros_like(b_flat) if x0 is None else pt.ravel(x0)
    A_flat = engine.flat_operator(A, unravel)
    precond = engine.flat_operator(M, unravel) if M is not None else None

    r0 = b_flat - A_flat(x_flat)
    z0 = precond(r0) if precond is not None else r0
    p0 = z0
    rz0 = pt.tree_dot(r0, z0)
    rnorm0 = pt.tree_norm(r0)
    threshold, _ = engine.tolerances(b_flat, tol, atol)

    trace0 = engine.trace_init(rnorm0, maxiter, record_residuals)
    diverged_at = 1e8 * jnp.maximum(rnorm0, pt.tree_norm(b_flat))

    def active_fn(state):
        j, _, _, _, _, _, rnorm, _, fail, _ = state
        return (j < maxiter) & (rnorm > threshold) & (fail == 0)

    def step(state, active, gate_matvec):
        # CG never records a window (ell == 0): the harness only runs
        # this in the while phase, so ``active``/``gate_matvec`` carry no
        # information and the body stays the unmasked textbook iteration.
        del active, gate_matvec
        j, x, r, z, p, rz, rnorm, trace, fail, stag = state
        ap = A_flat(p)
        d = pt.tree_dot(p, ap)
        bad, code = engine.classify_breakdown(d, rnorm, diverged_at)
        fail = jnp.where(fail > 0, fail, code)
        # Sanitize a poisoned A·p before it reaches the update pass:
        # alpha is zeroed on breakdown, but 0·NaN would still poison x/r.
        ap = jnp.where(bad, 0.0, ap)
        alpha = jnp.where(bad, 0.0, rz / jnp.where(bad, 1.0, d))
        x, r, rr, _ = kops.fused_cg_update(x, r, p, ap, alpha)
        if precond is not None:
            z = precond(r)
            rz_new = pt.tree_dot(r, z)
        else:
            z = r
            rz_new = rr
        beta = rz_new / jnp.where(rz == 0.0, 1.0, rz)
        p, _, _ = kops.fused_deflate_direction(z, p, beta)
        rnorm = jnp.sqrt(rr)
        fail = jnp.where(
            (fail == 0) & (~jnp.isfinite(rnorm)),
            SolveStatus.BREAKDOWN_NONFINITE,
            fail,
        ).astype(jnp.int32)
        if stag is not None:
            stag, fail = engine.stagnation_update(
                stag, rnorm, fail, jnp.bool_(True), stagnation_window
            )
        if trace is not None:
            trace = trace.at[j + 1].set(rnorm)
        return (j + 1, x, r, z, p, rz_new, rnorm, trace, fail, stag), ()

    fail0 = engine.initial_fail(rnorm0)
    stag0 = engine.stagnation_init(rnorm0, stagnation_window)
    state = (
        jnp.int32(0), x_flat, r0, z0, p0, rz0, rnorm0, trace0, fail0, stag0,
    )
    state, _ = engine.run_recording_loop(step, active_fn, state, ell=0)
    j, x, _, _, _, _, rnorm, trace, fail, _ = state
    converged = rnorm <= threshold
    info = SolveInfo(
        iterations=j,
        converged=converged,
        residual_norm=rnorm,
        matvecs=j + 1,
        residual_norms=trace,
        breakdown=fail > 0,
        status=engine.exit_status(converged, fail),
    )
    return CGResult(x=unravel(x), info=info)


# ---------------------------------------------------------------------------
# Deflated conjugate gradients — paper Algorithm 1
# ---------------------------------------------------------------------------


def deflated_initial_guess(x_prev, r_prev, W, AW, waw_cho):
    """Line 3 of Alg. 1: ``x0 = x_{-1} + W (WᵀAW)⁻¹ Wᵀ r_{-1}``.

    Returns ``(x0, r0)`` with ``r0`` updated via ``AW`` (no extra matvec):
    ``r0 = r_{-1} − AW c``.
    """
    c = cho_solve(waw_cho, pt.basis_dot(W, r_prev))
    x0 = pt.tree_add(x_prev, pt.basis_combine(W, c))
    r0 = pt.tree_sub(r_prev, pt.basis_combine(AW, c))
    return x0, r0


def defcg(
    A,
    b: Pytree,
    x0: Optional[Pytree] = None,
    W: Optional[Pytree] = None,
    AW: Optional[Pytree] = None,
    *,
    ell: int = 0,
    tol: float = 1e-5,
    atol: float = 0.0,
    maxiter: int = 1000,
    min_iters: int = 0,
    record_residuals: bool = False,
    waw_jitter: float = DEFAULT_WAW_JITTER,
    exact_aw: bool = True,
    flat_recycle: bool = False,
    M: Optional[Callable[[Pytree], Pytree]] = None,
    batch_axis: Optional[str] = None,
    stale_guard: Optional[float] = None,
    stagnation_window: int = 0,
) -> CGResult:
    """Deflated CG — ``def-CG(k, ell)`` of the paper (k = basis size of W).

    Args:
      A: SPD operator (callable on pytrees).
      b: right-hand side.
      x0: previous solution / warm start (``x_{-1}`` in Alg. 1).
      W: deflation basis (stacked pytree of k vectors) or None → plain CG
         that *still records* the first ``ell`` directions, which is how the
         first system of a sequence bootstraps recycling (paper Fig. 1).
      AW: ``A @ W``; computed here (k matvecs) when not supplied.
      ell: number of leading (p, Ap) pairs to record for Ritz extraction.
      min_iters: force at least this many iterations (useful to guarantee
         ``ell`` stored columns inside fully-jitted outer loops).
      waw_jitter: relative diagonal jitter for the k×k Cholesky.  Keep
         this SMALL (the :data:`DEFAULT_WAW_JITTER` = 1e-12 shared with
         every other solve path): the jitter perturbs μ = (WᵀAW)⁻¹(AW)ᵀr,
         and the un-deflated W-component it reinjects each iteration
         compounds — with a well-converged Ritz basis and a wide θ spread,
         jitter ≳1e-8 makes def-CG diverge outright (measured).
         Exactly-zero basis columns (clamped extraction slots) are
         regularized away unconditionally regardless of this setting.
      M: optional SPD preconditioner apply ``r ↦ M⁻¹ r``.  Deflation and
         preconditioning compose (the Soodhalter et al. projection
         framework): the iteration is the split-preconditioned def-CG —
         it carries the PCG recurrence scalar ``rᵀz`` (z = M⁻¹r) through
         loop state and deflates in the preconditioned inner product
         (``μ = (WᵀAW)⁻¹ (AW)ᵀ z``), which is exactly plain def-CG on
         ``M^{-1/2} A M^{-1/2}`` with the transformed basis ``M^{1/2}W``
         mapped back (tested to 1e-10 against that reference).  Costs one
         extra fused pass (``kernels.ops.fused_rz_reduce``) plus the M
         apply per iteration; convergence is still tested on the TRUE
         residual ‖r‖.
      exact_aw: declare that ``AW`` is exactly ``A @ W``.  When False (a
         *stale* basis recycled across a drifted operator — the paper's
         cheap mode), the initial residual is recomputed with one true
         matvec instead of the ``r0 = r − AW c`` shortcut, keeping CG's
         convergence target exact while the deflation is approximate.
      stale_guard: in-solve drift guard for the stale mode (requires
         ``exact_aw=False``; ignored otherwise).  The stale setup already
         computes both the shortcut residual ``r_s = r − AW·c`` and the
         true ``r_t = b − A·x₀`` — their difference is exactly
         ``(A·W − AW)·c``, a FREE measurement of how stale the products
         are along the deflated direction, available BEFORE the first
         iteration.  When ``‖r_t − r_s‖ / ‖r_init‖`` exceeds this
         threshold, the setup refreshes ``AW = A·W`` (k matvecs, counted
         in ``info.matvecs``) and redoes the deflated guess under a
         ``lax.cond`` — stale deflation that would destabilize the
         conjugacy recurrence is caught on the system it would break, at
         zero cost when it would not.  (Under ``vmap`` the cond lowers to
         a select, so a batched solve pays the refresh GEMM
         unconditionally — same caveat as the cold-bootstrap refresh.)
      flat_recycle: return the recorded ``(P, AP)`` as raw flat
         ``(ell, n)`` arrays instead of unraveling them to the vector's
         pytree structure — the device-resident sequence engine consumes
         them flat, so the round-trip would be pure waste.
      batch_axis: name of a ``vmap`` axis this solve is lifted over
         (``solve_batch`` passes its tenant axis).  Used for the
         all-tenants-converged early exit: the recording scan runs a
         fixed ``ell`` steps, and under ``vmap`` its per-step
         ``lax.cond`` matvec gate lowers to a ``select`` (both branches
         execute) — so without this, every tenant pays ``ell`` matvecs
         even after the whole batch converged.  With the axis name the
         gate becomes a cross-tenant ``any(active)`` reduction, which is
         unbatched, so the ``cond`` survives ``vmap`` and the operator is
         skipped once EVERY lane is frozen.  ``None`` (default) keeps the
         per-lane gate.
      stagnation_window: > 0 enables the stalled-residual detector: the
         solve is stopped with STAGNATED status when the best ‖r‖ seen
         fails to improve by 1% over this many consecutive iterations.
         The default 0 carries no extra loop state and adds no checks —
         the clean path is bit-identical to a detector-free solve.

    Internals: the whole solve — setup (Wᵀ A W factorization, deflated
    initial guess) and iteration — runs on the flat engine: the vector
    packs to a contiguous ``(n,)`` array and the deflation basis to a 2-D
    ``(k, n)`` array, so ``(AW)ᵀ r`` fuses into the residual-update pass
    and ``W μ`` into the direction pass.  The iteration itself is driven
    by :func:`repro.core.engine.run_recording_loop` — def-CG supplies
    only its ``step``/``active_fn`` pair, the harness owns the
    fixed-length masked recording scan (whose stacked outputs *are* the
    ``(P, AP, α, β)`` record) and the buffer-free ``while_loop`` for the
    remaining iterations.  Steps after convergence inside the scan
    window are frozen — the matvec is skipped via the harness's gated
    ``lax.cond``, the cheap vector passes run as masked no-ops, zero
    rows are recorded — so the two-phase split is semantically identical
    to one guarded loop.

    Returns ``CGResult`` whose ``recycle`` field feeds
    :func:`repro.core.recycle.harmonic_ritz`.
    """
    b_flat, unravel = pt.ravel_vector(b)
    threshold, _ = engine.tolerances(b_flat, tol, atol)
    matvecs = jnp.int32(0)
    guard_fired = jnp.bool_(False)

    A_flat = engine.flat_operator(A, unravel)
    precond = engine.flat_operator(M, unravel) if M is not None else None
    x_flat = (
        jnp.zeros_like(b_flat) if x0 is None else pt.ravel(x0)
    )

    deflating = W is not None
    w_flat = aw_flat = waw_inv = None
    if deflating:
        # Setup runs in flat space as well (not just the loop), so the
        # whole solve is structure-blind: any pytree layout of the same
        # coordinates produces bit-identical iterates.
        k = pt.basis_size(W)
        w_flat = pt.ravel_basis(W)

        def _apply_basis(w_f):
            # One fused multi-RHS operator application (each K-tile /
            # linearization formed once for all k vectors), not k
            # sequential matvecs — same primitive as the refresh paths.
            basis = pt.unravel_basis(w_f, unravel)
            return pt.ravel_basis(ops_mod.apply_to_basis(A, basis))

        if AW is None:
            aw_flat = _apply_basis(w_flat)
            matvecs = matvecs + k
        else:
            aw_flat = pt.ravel_basis(AW)

        def _factor_waw(aw_f):
            waw = pt.gram(w_flat, aw_f)
            waw = 0.5 * (waw + waw.T)
            dj = jnp.diag(waw)
            tr = jnp.sum(dj)
            if waw_jitter:
                scale = jnp.where(tr > 0, tr / k, 1.0)
                waw = waw + waw_jitter * scale * jnp.eye(k, dtype=waw.dtype)
            # Exactly-zero columns (clamped extraction slots — see
            # recycle.harmonic_ritz_flat) are regularized UNconditionally:
            # Wᵀr = 0 there, so any positive diagonal entry yields the
            # same deflation result (c_i = μ_i = 0) while keeping the
            # Cholesky finite.  A no-op when no column is zero, whatever
            # waw_jitter is.
            waw = waw + jnp.diag(
                jnp.where(dj == 0.0, jnp.maximum(tr / k, 1.0), 0.0)
            )
            return cho_factor(waw)

        def _post_guess(aw_f, waw_cho, z_f):
            # Deflation in the preconditioned inner product: μ from (AW)ᵀz.
            mu0 = cho_solve(waw_cho, pt.basis_dot(aw_f, z_f))
            p0 = z_f - pt.basis_combine(w_flat, mu0)
            # In-loop μ solves become one k×k GEMV: (WᵀAW)⁻¹ is formed
            # once from the (jittered, equilibrated) Cholesky —
            # numerically benign at these sizes, and it keeps LAPACK
            # dispatches out of the loop.
            winv = cho_solve(waw_cho, jnp.eye(k, dtype=aw_f.dtype))
            return p0, winv

        waw_cho = _factor_waw(aw_flat)
        x_in = x_flat
        r_init = b_flat - A_flat(x_in)
        matvecs = matvecs + 1
        x_flat, r_flat = deflated_initial_guess(
            x_in, r_init, w_flat, aw_flat, waw_cho
        )
        if not exact_aw:
            r_short = r_flat
            r_flat = b_flat - A_flat(x_flat)
            matvecs = matvecs + 1
            if stale_guard is not None:
                # In-solve drift guard: ‖r_true − r_short‖ = ‖(A·W − AW)c‖
                # measures the staleness of AW along the deflated
                # component — both residuals are already paid for.  Above
                # the threshold, refresh AW = A·W and redo the deflated
                # guess BEFORE iterating (a stale μ-recurrence diverges,
                # it does not merely slow down).
                drift_obs = pt.tree_norm(r_flat - r_short) / jnp.maximum(
                    pt.tree_norm(r_init), jnp.finfo(r_init.dtype).tiny
                )
                # Floor the threshold above the WORKING dtype's rounding
                # noise (the two residuals differ by ~eps-level terms
                # even with an exact AW): without this, f32 solves would
                # re-trigger k-matvec refreshes on pure noise.
                guard_eff = jnp.maximum(
                    jnp.asarray(stale_guard, drift_obs.dtype),
                    DRIFT_NOISE_FLOOR_EPS * jnp.finfo(r_init.dtype).eps,
                )
                refresh = drift_obs > guard_eff

                def _refresh_setup(_):
                    aw_n = _apply_basis(w_flat)
                    cho_n = _factor_waw(aw_n)
                    x_n, r_n = deflated_initial_guess(
                        x_in, r_init, w_flat, aw_n, cho_n
                    )
                    z_n = precond(r_n) if precond is not None else r_n
                    p_n, winv_n = _post_guess(aw_n, cho_n, z_n)
                    return aw_n, x_n, r_n, z_n, p_n, winv_n

                def _keep_setup(_):
                    z_s = precond(r_flat) if precond is not None else r_flat
                    p_s, winv_s = _post_guess(aw_flat, waw_cho, z_s)
                    return aw_flat, x_flat, r_flat, z_s, p_s, winv_s

                aw_flat, x_flat, r_flat, z_flat, p_flat, waw_inv = (
                    # repro-lint: disable=cond-batched-pred — documented
                    # caveat (see docstring): under vmap this lowers to a
                    # select and a batched solve pays the refresh GEMM.
                    jax.lax.cond(refresh, _refresh_setup, _keep_setup, None)
                )
                matvecs = matvecs + k * refresh.astype(matvecs.dtype)
                guard_fired = refresh

        if waw_inv is None:  # exact or unguarded-stale setup
            z_flat = precond(r_flat) if precond is not None else r_flat
            p_flat, waw_inv = _post_guess(aw_flat, waw_cho, z_flat)
    else:
        r_flat = b_flat - A_flat(x_flat)
        matvecs = matvecs + 1
        z_flat = precond(r_flat) if precond is not None else r_flat
        p_flat = z_flat

    rnorm0 = pt.tree_norm(r_flat)
    # The carried recurrence scalar: rᵀz (== ‖r‖² without a preconditioner).
    rs0 = pt.tree_dot(r_flat, z_flat)

    trace0 = engine.trace_init(rnorm0, maxiter, record_residuals)
    diverged_at = 1e8 * jnp.maximum(rnorm0, pt.tree_norm(b_flat))

    def active_fn(state):
        j, rnorm, fail = state[0], state[5], state[7]
        keep_going = (rnorm > threshold) | (j < min_iters)
        return (j < maxiter) & keep_going & (fail == 0)

    def step(state, active, gate_matvec):
        """One def-CG iteration; ``active=False`` freezes the state.

        The recording scan runs a fixed step count, so steps after
        convergence are frozen: the matvec is gated behind the harness's
        ``cond`` (skipping the expensive operator outright), while the
        cheap fused vector passes are masked via ``alpha = 0`` and a
        frozen ``p`` — wrapping the *whole* body in a ``cond`` measured
        slower on active steps (branch-boundary state copies) than
        letting the no-op passes run.
        """
        j, x, r, p, rs, rnorm, trace, fail, stag = state
        p_in = p
        if gate_matvec:
            ap = engine.gated_matvec(A_flat, p, active, batch_axis)
        else:
            ap = A_flat(p)
        d = pt.tree_dot(p, ap)
        bad, code = engine.classify_breakdown(d, rnorm, diverged_at)
        fail = jnp.where((fail == 0) & active, code, fail)
        # Sanitize a poisoned A·p before the fused passes touch it: alpha
        # is zeroed on breakdown, but 0·NaN = NaN would still poison x, r,
        # and (through μ) the next direction — a broken step must leave
        # the last HEALTHY iterate in state for the recovery ladder.
        ap = jnp.where(bad, 0.0, ap)
        alpha = jnp.where(bad | (~active), 0.0, rs / jnp.where(bad, 1.0, d))

        mu = None
        if precond is None:
            # Unpreconditioned: rᵀr IS the recurrence scalar, and the
            # deflation GEMV rides in the update pass.
            if deflating:
                x, r, rs_new, awr = kops.fused_cg_update(
                    x, r, p, ap, alpha, aw_flat
                )
                mu = waw_inv @ awr.astype(waw_inv.dtype)
            else:
                x, r, rs_new, _ = kops.fused_cg_update(x, r, p, ap, alpha)
            rr = rs_new
            zvec = r
        else:
            # Split-preconditioned: z = M⁻¹r only exists after the update,
            # so rᵀz and (AW)ᵀz go in a second fused pass; convergence is
            # still tested on the true residual ‖r‖ from the update pass.
            x, r, rr, _ = kops.fused_cg_update(x, r, p, ap, alpha)
            zvec = precond(r)
            rs_new, awz = kops.fused_rz_reduce(
                r, zvec, aw_flat if deflating else None
            )
            if deflating:
                mu = waw_inv @ awz.astype(waw_inv.dtype)
        beta = rs_new / jnp.where(rs == 0.0, 1.0, rs)

        p_new, _, _ = kops.fused_deflate_direction(zvec, p, beta, w_flat, mu)
        # Freeze p on breakdown too (not just inactivity): a poisoned
        # basis/preconditioner can make p_new non-finite through μ even
        # with a sanitized A·p.
        p = jnp.where(active & (~bad), p_new, p)

        rnorm_new = jnp.sqrt(rr)
        fail = jnp.where(
            (fail == 0) & active & (~jnp.isfinite(rnorm_new)),
            SolveStatus.BREAKDOWN_NONFINITE,
            fail,
        ).astype(jnp.int32)
        rnorm = jnp.where(active, rnorm_new, rnorm)
        if stag is not None:
            stag, fail = engine.stagnation_update(
                stag, rnorm_new, fail, active, stagnation_window
            )
        if trace is not None:
            # Frozen steps rewrite slot j+1 with its old value, keeping
            # the NaN tail of the trace untouched.
            old = trace[j + 1]
            trace = trace.at[j + 1].set(jnp.where(active, rnorm, old))
        j = j + active.astype(j.dtype)
        return (j, x, r, p, rs_new, rnorm, trace, fail, stag), (
            p_in, ap, alpha, beta,
        )

    fail0 = engine.initial_fail(rnorm0)
    stag0 = engine.stagnation_init(rnorm0, stagnation_window)
    state = (
        jnp.int32(0), x_flat, r_flat, p_flat, rs0, rnorm0, trace0,
        fail0, stag0,
    )

    state, rows = engine.run_recording_loop(
        step, active_fn, state, ell=ell
    )
    p_rows = ap_rows = a_rows = b_rows = None
    if rows is not None:
        p_rows, ap_rows, a_rows, b_rows = rows
    j, x, _, _, _, rnorm, trace, fail, _ = state

    converged = rnorm <= threshold
    info = SolveInfo(
        iterations=j,
        converged=converged,
        residual_norm=rnorm,
        matvecs=matvecs + j,
        residual_norms=trace,
        breakdown=fail > 0,
        status=engine.exit_status(converged, fail),
        guard_fired=guard_fired,
    )
    recycle = None
    if ell > 0:
        if flat_recycle:
            recycle = RecycleData(
                P=p_rows, AP=ap_rows, stored=jnp.minimum(j, ell),
                alpha=a_rows, beta=b_rows,
                aw_used=(
                    aw_flat
                    if (deflating and not exact_aw and stale_guard is not None)
                    else None
                ),
            )
        else:
            recycle = RecycleData(
                P=pt.unravel_basis(p_rows, unravel),
                AP=pt.unravel_basis(ap_rows, unravel),
                stored=jnp.minimum(j, ell),
                alpha=a_rows, beta=b_rows,
            )
    return CGResult(x=unravel(x), info=info, recycle=recycle)


# ---------------------------------------------------------------------------
# Dense baseline (paper Table 1's Cholesky column)
# ---------------------------------------------------------------------------


def cholesky_solve(mat: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Exact SPD solve via Cholesky — the paper's cubic-cost baseline."""
    return cho_solve(cho_factor(mat), b)


# ---------------------------------------------------------------------------
# Jitted entry points
# ---------------------------------------------------------------------------
#
# Solver arguments that select code paths are static; vectors/bases/operators
# are traced.  Operators registered as pytree nodes keep their matvec
# closures in aux_data — reusing the *same* closure object across calls (as
# the Laplace loop and RecycleManager do) makes these hit the jit cache, so
# a Newton sequence compiles each solver variant exactly once.

# ``M`` is a TRACED argument of the jitted entry points: preconditioners
# (``repro.core.preconditioners``) are registered pytree nodes whose data
# (diag, sketch basis) are children, so a Newton loop that rebuilds its
# Jacobi/Nyström preconditioner every system hits the jit cache instead of
# recompiling.  A bare closure is not traceable data; ``cg_jit`` keeps the
# pre-redesign behavior for those by routing them through a static-M jit
# (cached by closure identity — stable closures still cache-hit).

_cg_jit_traced_m = jax.jit(
    cg,
    static_argnames=("tol", "atol", "maxiter", "record_residuals", "stagnation_window"),
)
_cg_jit_static_m = jax.jit(
    cg,
    static_argnames=("tol", "atol", "maxiter", "M", "record_residuals", "stagnation_window"),
)


def cg_jit(*args, **kwargs):
    """Jitted :func:`cg`.  ``M`` may be None, a registered pytree node
    (traced — rebuild freely, one compilation), or a bare callable
    (static — falls back to hashing by identity, as before the
    SolveSpec redesign)."""
    M = kwargs.get("M")
    if M is not None and jax.tree_util.all_leaves([M]):
        return _cg_jit_static_m(*args, **kwargs)
    return _cg_jit_traced_m(*args, **kwargs)

defcg_jit = jax.jit(
    defcg,
    static_argnames=(
        "ell",
        "tol",
        "atol",
        "maxiter",
        "min_iters",
        "record_residuals",
        "waw_jitter",
        "exact_aw",
        "flat_recycle",
        "batch_axis",
        "stale_guard",
        "stagnation_window",
    ),
)
