"""Iterative SPD solvers: CG, preconditioned CG, and deflated CG.

This file implements the paper's Algorithm 1 (Saad et al.'s deflated
conjugate gradient) as a jit-able, pytree-native, shardable solver:

* vectors are arbitrary pytrees (``repro.core.pytree``);
* ``A`` is any matrix-free operator (``repro.core.operators``);
* the main iteration is a ``jax.lax.while_loop`` so the entire solve — and
  therefore an entire Hessian-free optimizer step that embeds it — lowers
  to a single XLA computation that pjit can shard across a pod;
* the first ``ell`` search directions and their ``A``-products are recorded
  into fixed-size ring buffers, which is all the harmonic-Ritz recycling
  step (``repro.core.recycle``) needs — zero extra matvecs, exactly the
  "readily available quantities" trick of the paper (§2.3, adapted: we
  store ``P``/``AP`` directly and form ``F``/``G`` by two tall-skinny GEMMs,
  which is MXU-friendly; see DESIGN.md §8).

Deflation (the lines that differ from textbook CG, cf. paper Alg. 1
lines 3 & 11):

    x0  = x_{-1} + W (WᵀAW)⁻¹ Wᵀ r_{-1}          # Wᵀ r0 = 0
    p0  = r0 − W μ0,        WᵀAW μ0 = WᵀA r0
    p_j = β p_{j-1} + r_j − W μ_j,  WᵀAW μ_j = WᵀA r_j

``WᵀA r`` is evaluated as ``(AW)ᵀ r`` (A symmetric), so the per-iteration
deflation overhead is two tall-skinny GEMVs + one k×k triangular solve —
O(nk) flops and *no* additional collectives beyond the two GEMV psums.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve

from repro.core import pytree as pt

Pytree = Any


class SolveInfo(NamedTuple):
    """Diagnostics of an iterative solve (all traced values)."""

    iterations: jax.Array  # int32: CG iterations executed
    converged: jax.Array  # bool
    residual_norm: jax.Array  # final ‖r‖
    matvecs: jax.Array  # total operator applications
    residual_norms: Optional[jax.Array] = None  # (maxiter+1,) trace or None
    breakdown: jax.Array | bool = False  # pᵀAp lost positivity


class RecycleData(NamedTuple):
    """Stored Krylov quantities for harmonic-Ritz extraction."""

    P: Pytree  # basis of ell search directions
    AP: Pytree  # their A-products
    stored: jax.Array  # int32: valid columns (may be < ell on early converge)


class CGResult(NamedTuple):
    x: Pytree
    info: SolveInfo
    recycle: Optional[RecycleData] = None


def _tolerances(b, tol, atol):
    bnorm = pt.tree_norm(b)
    return jnp.maximum(tol * bnorm, atol), bnorm


# ---------------------------------------------------------------------------
# Conjugate gradients (the paper's CG baseline)
# ---------------------------------------------------------------------------


def cg(
    A,
    b: Pytree,
    x0: Optional[Pytree] = None,
    *,
    tol: float = 1e-5,
    atol: float = 0.0,
    maxiter: int = 1000,
    M: Optional[Callable[[Pytree], Pytree]] = None,
    record_residuals: bool = False,
) -> CGResult:
    """(Preconditioned) conjugate gradients for SPD ``A``.

    ``M`` is an (SPD) preconditioner apply ``r ↦ M⁻¹ r``; ``None`` gives
    plain CG, matching the paper's baseline.
    """
    if x0 is None:
        x0 = pt.tree_zeros_like(b)
    precond = M if M is not None else (lambda v: v)

    r0 = pt.tree_sub(b, A(x0))
    z0 = precond(r0)
    p0 = z0
    rz0 = pt.tree_dot(r0, z0)
    rnorm0 = pt.tree_norm(r0)
    threshold, _ = _tolerances(b, tol, atol)

    if record_residuals:
        trace0 = jnp.full((maxiter + 1,), jnp.nan, dtype=rnorm0.dtype)
        trace0 = trace0.at[0].set(rnorm0)
    else:
        trace0 = None

    diverged_at = 1e8 * jnp.maximum(rnorm0, pt.tree_norm(b))

    def cond(state):
        j, _, _, _, _, rnorm, _, brk = state
        return (j < maxiter) & (rnorm > threshold) & (~brk)

    def body(state):
        j, x, r, z, p, rnorm, trace, brk = state
        ap = A(p)
        d = pt.tree_dot(p, ap)
        brk = (d <= 0.0) | (~jnp.isfinite(d)) | (rnorm > diverged_at)
        rz = pt.tree_dot(r, z)
        alpha = jnp.where(brk, 0.0, rz / jnp.where(brk, 1.0, d))
        x = pt.tree_axpy(alpha, p, x)
        r = pt.tree_axpy(-alpha, ap, r)
        z = precond(r)
        rz_new = pt.tree_dot(r, z)
        beta = rz_new / jnp.where(rz == 0.0, 1.0, rz)
        p = pt.tree_axpy(beta, p, z)
        rnorm = pt.tree_norm(r)
        if trace is not None:
            trace = trace.at[j + 1].set(rnorm)
        return (j + 1, x, r, z, p, rnorm, trace, brk)

    state = (jnp.int32(0), x0, r0, z0, p0, rnorm0, trace0, jnp.bool_(False))
    j, x, r, _, _, rnorm, trace, brk = jax.lax.while_loop(cond, body, state)
    del r, rz0
    info = SolveInfo(
        iterations=j,
        converged=rnorm <= threshold,
        residual_norm=rnorm,
        matvecs=j + 1,
        residual_norms=trace,
        breakdown=brk,
    )
    return CGResult(x=x, info=info)


# ---------------------------------------------------------------------------
# Deflated conjugate gradients — paper Algorithm 1
# ---------------------------------------------------------------------------


def deflated_initial_guess(x_prev, r_prev, W, AW, waw_cho):
    """Line 3 of Alg. 1: ``x0 = x_{-1} + W (WᵀAW)⁻¹ Wᵀ r_{-1}``.

    Returns ``(x0, r0)`` with ``r0`` updated via ``AW`` (no extra matvec):
    ``r0 = r_{-1} − AW c``.
    """
    c = cho_solve(waw_cho, pt.basis_dot(W, r_prev))
    x0 = pt.tree_add(x_prev, pt.basis_combine(W, c))
    r0 = pt.tree_sub(r_prev, pt.basis_combine(AW, c))
    return x0, r0


def defcg(
    A,
    b: Pytree,
    x0: Optional[Pytree] = None,
    W: Optional[Pytree] = None,
    AW: Optional[Pytree] = None,
    *,
    ell: int = 0,
    tol: float = 1e-5,
    atol: float = 0.0,
    maxiter: int = 1000,
    min_iters: int = 0,
    record_residuals: bool = False,
    waw_jitter: float = 0.0,
    exact_aw: bool = True,
) -> CGResult:
    """Deflated CG — ``def-CG(k, ell)`` of the paper (k = basis size of W).

    Args:
      A: SPD operator (callable on pytrees).
      b: right-hand side.
      x0: previous solution / warm start (``x_{-1}`` in Alg. 1).
      W: deflation basis (stacked pytree of k vectors) or None → plain CG
         that *still records* the first ``ell`` directions, which is how the
         first system of a sequence bootstraps recycling (paper Fig. 1).
      AW: ``A @ W``; computed here (k matvecs) when not supplied.
      ell: number of leading (p, Ap) pairs to record for Ritz extraction.
      min_iters: force at least this many iterations (useful to guarantee
         ``ell`` stored columns inside fully-jitted outer loops).
      waw_jitter: relative diagonal jitter for the k×k Cholesky.
      exact_aw: declare that ``AW`` is exactly ``A @ W``.  When False (a
         *stale* basis recycled across a drifted operator — the paper's
         cheap mode), the initial residual is recomputed with one true
         matvec instead of the ``r0 = r − AW c`` shortcut, keeping CG's
         convergence target exact while the deflation is approximate.

    Returns ``CGResult`` whose ``recycle`` field feeds
    :func:`repro.core.recycle.harmonic_ritz`.
    """
    if x0 is None:
        x0 = pt.tree_zeros_like(b)

    threshold, _ = _tolerances(b, tol, atol)
    matvecs = jnp.int32(0)

    deflating = W is not None
    if deflating:
        k = pt.basis_size(W)
        if AW is None:
            AW = pt.basis_map_vectors(A, W)
            matvecs = matvecs + k
        waw = pt.gram(W, AW)
        waw = 0.5 * (waw + waw.T)
        if waw_jitter:
            waw = waw + waw_jitter * (jnp.trace(waw) / k) * jnp.eye(
                k, dtype=waw.dtype
            )
        waw_cho = cho_factor(waw)

        r_init = pt.tree_sub(b, A(x0))
        matvecs = matvecs + 1
        x0, r0 = deflated_initial_guess(x0, r_init, W, AW, waw_cho)
        if not exact_aw:
            r0 = pt.tree_sub(b, A(x0))
            matvecs = matvecs + 1

        mu0 = cho_solve(waw_cho, pt.basis_dot(AW, r0))
        p0 = pt.tree_sub(r0, pt.basis_combine(W, mu0))
    else:
        r0 = pt.tree_sub(b, A(x0))
        matvecs = matvecs + 1
        p0 = r0

    rnorm0 = pt.tree_norm(r0)
    rs0 = pt.tree_dot(r0, r0)

    if record_residuals:
        trace0 = jnp.full((maxiter + 1,), jnp.nan, dtype=rnorm0.dtype)
        trace0 = trace0.at[0].set(rnorm0)
    else:
        trace0 = None

    if ell > 0:
        p_buf0 = pt.basis_zeros(b, ell)
        ap_buf0 = pt.basis_zeros(b, ell)
    else:
        p_buf0 = ap_buf0 = None

    diverged_at = 1e8 * jnp.maximum(rnorm0, pt.tree_norm(b))

    def cond(state):
        j = state[0]
        rnorm = state[5]
        brk = state[8]
        keep_going = (rnorm > threshold) | (j < min_iters)
        return (j < maxiter) & keep_going & (~brk)

    def body(state):
        j, x, r, p, rs, rnorm, trace, bufs, brk = state
        ap = A(p)
        d = pt.tree_dot(p, ap)
        brk = (d <= 0.0) | (~jnp.isfinite(d)) | (rnorm > diverged_at)
        alpha = jnp.where(brk, 0.0, rs / jnp.where(brk, 1.0, d))

        if bufs is not None:
            p_buf, ap_buf = bufs
            idx = jnp.minimum(j, ell - 1)
            write = j < ell
            p_sel = jax.tree_util.tree_map(
                lambda new, old: jnp.where(write, new, old),
                p,
                pt.basis_vector(p_buf, idx),
            )
            ap_sel = jax.tree_util.tree_map(
                lambda new, old: jnp.where(write, new, old),
                ap,
                pt.basis_vector(ap_buf, idx),
            )
            p_buf = pt.basis_set(p_buf, p_sel, idx)
            ap_buf = pt.basis_set(ap_buf, ap_sel, idx)
            bufs = (p_buf, ap_buf)

        x = pt.tree_axpy(alpha, p, x)
        r = pt.tree_axpy(-alpha, ap, r)
        rs_new = pt.tree_dot(r, r)
        beta = rs_new / jnp.where(rs == 0.0, 1.0, rs)

        if deflating:
            mu = cho_solve(waw_cho, pt.basis_dot(AW, r))
            p = pt.tree_axpy(
                beta, p, pt.tree_sub(r, pt.basis_combine(W, mu))
            )
        else:
            p = pt.tree_axpy(beta, p, r)

        rnorm = jnp.sqrt(rs_new)
        if trace is not None:
            trace = trace.at[j + 1].set(rnorm)
        return (j + 1, x, r, p, rs_new, rnorm, trace, bufs, brk)

    state = (
        jnp.int32(0),
        x0,
        r0,
        p0,
        rs0,
        rnorm0,
        trace0,
        (p_buf0, ap_buf0) if ell > 0 else None,
        jnp.bool_(False),
    )
    j, x, _, _, _, rnorm, trace, bufs, brk = jax.lax.while_loop(
        cond, body, state
    )

    info = SolveInfo(
        iterations=j,
        converged=rnorm <= threshold,
        residual_norm=rnorm,
        matvecs=matvecs + j,
        residual_norms=trace,
        breakdown=brk,
    )
    recycle = None
    if ell > 0:
        p_buf, ap_buf = bufs
        recycle = RecycleData(P=p_buf, AP=ap_buf, stored=jnp.minimum(j, ell))
    return CGResult(x=x, info=info, recycle=recycle)


# ---------------------------------------------------------------------------
# Dense baseline (paper Table 1's Cholesky column)
# ---------------------------------------------------------------------------


def cholesky_solve(mat: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Exact SPD solve via Cholesky — the paper's cubic-cost baseline."""
    return cho_solve(cho_factor(mat), b)


# ---------------------------------------------------------------------------
# Jitted entry points
# ---------------------------------------------------------------------------
#
# Solver arguments that select code paths are static; vectors/bases/operators
# are traced.  Operators registered as pytree nodes keep their matvec
# closures in aux_data — reusing the *same* closure object across calls (as
# the Laplace loop and RecycleManager do) makes these hit the jit cache, so
# a Newton sequence compiles each solver variant exactly once.

cg_jit = jax.jit(
    cg,
    static_argnames=("tol", "atol", "maxiter", "M", "record_residuals"),
)

defcg_jit = jax.jit(
    defcg,
    static_argnames=(
        "ell",
        "tol",
        "atol",
        "maxiter",
        "min_iters",
        "record_residuals",
        "waw_jitter",
        "exact_aw",
    ),
)
