"""One front door for every solve: ``SolveSpec`` + ``RecycleState``.

The paper's pitch is interpolating between a-priori low-rank approximations
(preconditioners) and exact solves (deflation/recycling) — Soodhalter et
al.'s recycling survey treats the two as one composable projection
framework.  Before this module, only plain ``cg`` accepted a
preconditioner, and five entry points each re-declared overlapping kwargs
with drifting defaults.  This module makes the combination declarative:

* :class:`SolveSpec` — a frozen, hashable description of *how* to solve
  (method axis ``cg``/``defcg``/``lsmr``/``deflsmr``, deflation sizes,
  tolerances, preconditioner strategy, least-squares shift).  It is
  the single source of truth for solver configuration: every default
  (``waw_jitter`` included) lives here or in the constant it re-exports,
  and the spec passes through ``jit`` as a static argument.
* :class:`RecycleState` (re-exported from :mod:`repro.core.recycle`) — the
  *what is carried between solves*: flat ``(k, n)`` recycled basis, its
  A-products, Ritz values, and a solve counter.  A registered pytree, so
  it checkpoints, shards, and vmaps over a leading tenant axis.

Front doors (everything else is a compatibility shim over these):

* :func:`solve` — one system.  ``solve(A, b, spec, state) -> SolveResult``
  runs (preconditioned) CG or def-CG, refreshes ``AW`` per the spec, and
  returns the next ``RecycleState``.  Fully traceable: no host syncs, so
  it jits (``solve_jit``), vmaps, and pjit-shards.
* :func:`solve_sequence` — N related systems as ONE ``lax.scan`` (the
  device-resident sequence engine), spec-driven and preconditionable;
  ``method="deflsmr"`` runs the same scan over regularized least-squares
  systems with normal-equations recycling geometry.
* :func:`solve_batch` — B independent tenants (systems or sequences)
  under one ``vmap``: one compiled program serves every tenant, each with
  its own ``RecycleState`` and convergence flag (``info.converged`` is
  the per-tenant mask).  This is the serving shape for many users'
  GP/Laplace problems at once.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import lsmr as lsmr_mod
from repro.core import preconditioners as precond_mod
from repro.core import pytree as pt
from repro.core import recycle as recycle_mod
from repro.core import solvers as solvers_mod
from repro.core.recycle import RecycleState, SequenceResult
from repro.core.solvers import DEFAULT_WAW_JITTER, SolveInfo
from repro.core.strategies import (
    HarmonicRitz,
    MGeometryHarmonic,
    RecycleStrategy,
    WindowedRecombine,
)

Pytree = Any

_METHODS = ("cg", "defcg", "lsmr", "deflsmr")
# The least-squares half of the method axis: plain and recycled LSMR on
# min ‖Ax − b‖² + lsq_shift·‖x‖² (rectangular A; see repro.core.lsmr).
_LSQ_METHODS = ("lsmr", "deflsmr")
_SELECTS = ("largest", "smallest")
_REFRESH_MODES = ("exact", "stale")
_PRECONDS = ("none", "jacobi", "nystrom", "custom")

# The vmap axis name solve_batch lifts tenants over; the def-CG recording
# scan reduces `active` across it so the whole batch stops paying matvecs
# the moment the LAST tenant converges (see solvers.defcg `batch_axis`).
_TENANT_AXIS = "repro_tenants"


@dataclasses.dataclass(frozen=True)
class SolveSpec:
    """Declarative solver configuration — the single source of truth.

    Frozen and hashable, so it rides through ``jit`` as ONE static
    argument instead of a dozen drifting kwargs.  Field semantics:

    Attributes:
      method: the solver axis (DESIGN.md §12).  ``"cg"`` (no deflation;
        ``k``/``ell`` ignored) or ``"defcg"`` (deflated CG with
        harmonic-Ritz recycling) for SPD systems; ``"lsmr"`` (plain) or
        ``"deflsmr"`` (recycled, deflated in the normal-equations
        geometry) for regularized least-squares ``min ‖Ax − b‖² +
        lsq_shift·‖x‖²`` with rectangular ``A`` — see
        :mod:`repro.core.lsmr`.  The least-squares methods converge on
        the normal residual ``‖Âᵀr̂‖``, take no preconditioner, use the
        default :class:`HarmonicRitz` extraction only, and ignore the
        recovery ladder (LSMR has no SPD breakdown modes; a non-finite
        solve retires the basis and re-bootstraps instead).
      k: recycled subspace size (rows of ``RecycleState.W``).
      ell: leading ``(p, Ap)`` pairs recorded per solve for extraction.
      tol, atol, maxiter: convergence controls — stop when
        ``‖r‖ ≤ max(tol·‖b‖, atol)``.
      select: which end of the spectrum the extraction keeps
        (``"largest"`` deflates the top — right for ``A = I + H½KH½``).
      waw_jitter: relative diagonal jitter for the k×k ``WᵀAW`` Cholesky.
        The one shared default is
        :data:`repro.core.solvers.DEFAULT_WAW_JITTER`; keep it small
        (≳1e-8 measurably destabilizes def-CG — see ``solvers.defcg``).
      refresh_aw: ``"exact"`` — recompute ``AW`` per system (k matvecs,
        one fused multi-RHS pass); ``"stale"`` — reuse extraction
        products (zero matvecs, the paper's cheap mode; exact only for an
        unchanged operator).  Consumed by the :class:`HarmonicRitz`
        strategy only; the other strategies own their refresh policy, so
        combining them with ``"stale"`` is rejected as contradictory.
      strategy: the :class:`repro.core.strategies.RecycleStrategy` owning
        the end-of-solve transition ``(window, state) → state`` and the
        per-system refresh policy: :class:`HarmonicRitz` (incumbent),
        :class:`WindowedRecombine` (zero-matvec windowed refresh with a
        drift guard — the paper's O(n²(ℓ+1)k) accounting), or
        :class:`MGeometryHarmonic` (extraction in the preconditioner's
        geometry; requires ``precond != "none"``).
      precond: preconditioner strategy — ``"none"``, ``"jacobi"``
        (diagonal), ``"nystrom"`` (randomized eigensketch), or
        ``"custom"`` (caller passes any SPD apply as ``M``).  Strategies
        other than ``"none"`` need operator data; build the apply with
        :func:`make_preconditioner` and pass it as ``M``.
      precond_rank: sketch rank for ``"nystrom"``.
      precond_sigma: bulk shift σ for the Nyström formula.
      recovery_rungs: how far the escalating recovery ladder may climb
        when a def-CG attempt ends broken (``SolveStatus`` ≥ 2) or
        unconverged with a carried basis: 1 = refresh ``AW = A·W`` and
        redo, 2 = + drop the basis (cold re-solve + re-seed), 3 = + plain
        CG against ``A + σI`` with the preconditioner disabled (last
        resort for a numerically indefinite operator).  0 disarms
        recovery entirely.  Every executed attempt's matvecs are charged
        to ``info.matvecs``; the rung taken is reported in
        ``result.report.rung``.  The ladder is one ``lax.while_loop``
        that runs zero iterations on a clean solve — clean-path iterates
        and matvec totals are untouched.
      recovery_shift: the σ of the rung-3 shift, relative to nothing —
        an absolute diagonal offset (the escalated-jitter analog at the
        operator level).  Keep it far below the operator's smallest
        eigenvalue of interest; it biases the rung-3 solution by
        ``O(σ‖x‖)``.
      stagnation_window: > 0 arms the stalled-residual detector: a solve
        whose best ‖r‖ fails to improve by 1% over this many consecutive
        iterations stops with STAGNATED status (and, with recovery
        armed, climbs the ladder) instead of burning the rest of
        ``maxiter``.  0 (default) adds no loop state and no checks.
      lsq_shift: the ridge λ ≥ 0 of the least-squares methods (static —
        it selects the augmented-block code path at trace time; 0 solves
        ordinary least squares).  Rejected for the SPD methods, whose
        operators carry their own shift.
    """

    method: str = "defcg"
    k: int = 8
    ell: int = 12
    tol: float = 1e-5
    atol: float = 0.0
    maxiter: int = 1000
    select: str = "largest"
    waw_jitter: float = DEFAULT_WAW_JITTER
    refresh_aw: str = "exact"
    precond: str = "none"
    precond_rank: int = 16
    precond_sigma: float = 1.0
    strategy: RecycleStrategy = HarmonicRitz()
    recovery_rungs: int = 3
    recovery_shift: float = 1e-6
    stagnation_window: int = 0
    lsq_shift: float = 0.0

    def __post_init__(self):
        if self.method not in _METHODS:
            raise ValueError(f"method must be one of {_METHODS}, got {self.method!r}")
        if self.select not in _SELECTS:
            raise ValueError(f"select must be one of {_SELECTS}, got {self.select!r}")
        if self.refresh_aw not in _REFRESH_MODES:
            raise ValueError(
                f"refresh_aw must be one of {_REFRESH_MODES}, got {self.refresh_aw!r}"
            )
        if self.precond not in _PRECONDS:
            raise ValueError(
                f"precond must be one of {_PRECONDS}, got {self.precond!r}"
            )
        if self.method in ("defcg", "deflsmr") and self.k < 1:
            raise ValueError(f"{self.method} needs k >= 1, got k={self.k}")
        if self.lsq_shift < 0:
            raise ValueError(
                f"lsq_shift must be >= 0, got {self.lsq_shift}"
            )
        if self.lsq_shift != 0.0 and self.method not in _LSQ_METHODS:
            raise ValueError(
                f"lsq_shift is the ridge λ of the least-squares methods "
                f"{_LSQ_METHODS}; method={self.method!r} ignores it — SPD "
                "operators carry their own shift"
            )
        if self.method in _LSQ_METHODS:
            if self.precond != "none":
                raise ValueError(
                    f"method={self.method!r} has no preconditioner path — "
                    "LSMR's geometry is fixed by the augmented operator; "
                    "use precond='none'"
                )
            if type(self.strategy) is not HarmonicRitz:
                raise ValueError(
                    f"method={self.method!r} extracts through the shared "
                    "harmonic-Ritz core only — custom strategies are "
                    "def-CG policies"
                )
        if self.ell < 0 or self.maxiter < 1 or self.precond_rank < 1:
            raise ValueError("ell >= 0, maxiter >= 1, precond_rank >= 1 required")
        if self.tol < 0 or self.atol < 0 or self.waw_jitter < 0:
            raise ValueError("tol, atol and waw_jitter must be non-negative")
        if not 0 <= self.recovery_rungs <= recycle_mod.MAX_RECOVERY_RUNGS:
            raise ValueError(
                f"recovery_rungs must be in [0, "
                f"{recycle_mod.MAX_RECOVERY_RUNGS}], got {self.recovery_rungs}"
            )
        if self.recovery_shift < 0 or self.stagnation_window < 0:
            raise ValueError(
                "recovery_shift and stagnation_window must be non-negative"
            )
        if not isinstance(self.strategy, RecycleStrategy):
            raise ValueError(
                "strategy must be a repro.core.strategies.RecycleStrategy "
                f"instance, got {self.strategy!r}"
            )
        if (
            self.refresh_aw == "stale"
            and not isinstance(self.strategy, HarmonicRitz)
        ):
            raise ValueError(
                f"refresh_aw='stale' conflicts with strategy="
                f"{type(self.strategy).__name__}: non-default strategies "
                "own their refresh policy (WindowedRecombine IS the "
                "guarded stale mode)"
            )
        if self.strategy.needs_preconditioner and self.precond == "none":
            raise ValueError(
                f"strategy={type(self.strategy).__name__} extracts in the "
                "preconditioner's geometry — it needs precond != 'none'"
            )
        if (
            isinstance(self.strategy, WindowedRecombine)
            and self.method == "defcg"
            and self.ell == 0
        ):
            # Without a recording window there is no transition: the
            # carried AW can never be re-derived from stored quantities
            # and the drift carry never updates, so every solve would
            # re-pay the in-solve refresh it exists to avoid.
            raise ValueError(
                "strategy=WindowedRecombine needs ell > 0 — its refresh "
                "recombines the recorded window"
            )


class SolveReport(NamedTuple):
    """Failure-handling diagnostics of a solve — one per front door.

    A small pytree of traced values (per-system / per-tenant stacked on
    the sequence and batch doors):

    Attributes:
      status: int32 :class:`repro.core.solvers.SolveStatus` code of the
        ADOPTED attempt (CONVERGED / MAXITER / BREAKDOWN_NONFINITE /
        BREAKDOWN_INDEFINITE / STAGNATED).
      rung: int32 highest recovery-ladder rung executed (0 = clean solve,
        ladder never fired; see ``SolveSpec.recovery_rungs``).
      guard_firings: int32 count of in-solve stale-guard ``AW`` refreshes.
      matvecs: honest total operator applications, including every failed
        ladder attempt and every guard/ladder refresh.
    """

    status: jax.Array
    rung: jax.Array
    guard_firings: jax.Array
    matvecs: jax.Array


def _make_report(info: SolveInfo, rung) -> SolveReport:
    return SolveReport(
        status=jnp.asarray(info.status, jnp.int32),
        rung=jnp.asarray(rung, jnp.int32),
        guard_firings=jnp.asarray(info.guard_fired, jnp.int32),
        matvecs=jnp.asarray(info.matvecs, jnp.int32),
    )


class SolveResult(NamedTuple):
    """What :func:`solve` returns: solution, diagnostics, next state."""

    x: Pytree
    info: SolveInfo
    state: Optional[RecycleState]
    report: Optional[SolveReport] = None


class SequenceSolveResult(NamedTuple):
    """Per-system stacked outputs of :func:`solve_sequence` + final state."""

    x: Pytree  # (num_systems, …) solutions
    info: SolveInfo  # stacked diagnostics
    theta: jnp.ndarray  # (num_systems, k) Ritz-value trace
    state: RecycleState  # final state, ready to seed the next call
    report: Optional[SolveReport] = None  # per-system failure diagnostics


class BatchSolveResult(NamedTuple):
    """Per-tenant stacked outputs of :func:`solve_batch` (leading axis B).

    ``info.converged`` is the per-tenant convergence mask;
    ``report.status`` is the per-tenant (or ``(B, N)`` per-system)
    failure status — a broken tenant is retired into its slot of this
    report instead of poisoning the batch.
    """

    x: Pytree
    info: SolveInfo
    state: Optional[RecycleState]
    report: Optional[SolveReport] = None


def make_preconditioner(
    A,
    spec: SolveSpec,
    template: Pytree,
    *,
    diag: Optional[Pytree] = None,
    key=None,
):
    """Build the ``M`` apply for ``spec.precond`` (None for ``"none"``).

    ``"jacobi"`` needs ``diag`` (the operator diagonal as a vector
    pytree); ``"nystrom"`` needs ``key`` and spends
    ``spec.precond_rank + 8`` matvecs on the sketch — an a-priori cost
    that amortizes across every solve that reuses the returned apply.
    The result is a registered pytree node, so the jitted front doors
    treat it as traced data (rebuilding it per system reuses one
    compiled solve).
    """
    if spec.precond == "none":
        return None
    if spec.precond == "jacobi":
        if diag is None:
            raise ValueError("precond='jacobi' needs diag=<operator diagonal>")
        return precond_mod.jacobi(diag)
    if spec.precond == "nystrom":
        if key is None:
            raise ValueError("precond='nystrom' needs key=<PRNG key>")
        U, lam = precond_mod.randomized_nystrom(
            A, template, rank=spec.precond_rank, key=key
        )
        return precond_mod.nystrom_preconditioner(U, lam, spec.precond_sigma)
    raise ValueError(
        "precond='custom' supplies its own apply — pass it as M instead"
    )


def _check_m(spec: SolveSpec, M) -> None:
    if spec.precond not in ("none",) and M is None:
        raise ValueError(
            f"spec.precond={spec.precond!r} but no M was passed — build one "
            "with repro.core.make_preconditioner(A, spec, template, ...)"
        )


# ---------------------------------------------------------------------------
# solve — one system
# ---------------------------------------------------------------------------


def solve(
    A,
    b: Pytree,
    spec: Optional[SolveSpec] = None,
    state: Optional[RecycleState] = None,
    *,
    x0: Optional[Pytree] = None,
    M=None,
    record_residuals: bool = False,
    batch_axis: Optional[str] = None,
    mesh=None,
) -> SolveResult:
    """Solve one SPD system ``A x = b`` per ``spec``, carrying ``state``.

    The single-system front door: (preconditioned) CG or def-CG on the
    flat engine.  For ``method="defcg"`` the returned ``state`` holds the
    harmonic-Ritz basis extracted from this solve — feed it back in for
    the next related system.  ``state=None`` bootstraps cold (an all-zero
    basis deflates as an exact no-op, so the first solve is plain CG plus
    recording).  Fully traceable — no host syncs — so this function jits
    (:data:`solve_jit`), vmaps (:func:`solve_batch`), and shards.

    ``M`` is the preconditioner apply for ``spec.precond`` (see
    :func:`make_preconditioner`); deflation composes with it through the
    split-preconditioned iteration of :func:`repro.core.solvers.defcg`.

    ``method="cg"`` and ``method="lsmr"`` neither consume nor update
    recycle state: a supplied ``state`` passes through UNTOUCHED (not
    validated, counter not bumped) so a mixed pipeline can thread one
    state through both.  The least-squares methods accept rectangular
    ``A`` (adjoint via ``rmatvec``; ``b`` lives in the range space, the
    solution in the domain) and solve ``min ‖Ax − b‖² +
    spec.lsq_shift·‖x‖²`` — ``info.residual_norm`` is then the normal
    residual ``‖Âᵀr̂‖``, the quantity LSMR converges on.

    Accounting: ``info.matvecs`` includes whatever refresh the spec's
    strategy spent (k operator applications for an exact refresh with a
    carried basis; zero on cold bootstraps, un-triggered guards, and
    stale mode), matching :func:`solve_sequence`.

    ``batch_axis`` names the ``vmap`` axis when this solve is lifted
    over tenants (``solve_batch`` sets it) — it arms the recording
    scan's cross-tenant matvec gate; leave ``None`` otherwise.

    ``mesh`` opts into the SPMD engine: pass a 1-D ``"solve"`` mesh
    (:func:`repro.launch.mesh.make_solve_mesh`) and the solve runs
    n-sharded across its devices through
    :func:`repro.core.sharded.solve_sharded` — one all-reduce per
    def-CG/CG iteration, operator data row-sharded.  ``mesh=None`` (the
    default) is the unchanged single-device path; the two differ only in
    the (documented) sharded-path restrictions — no preconditioner, no
    recovery ladder, ``cg``/``defcg``/``lsmr`` only.
    """
    spec = SolveSpec() if spec is None else spec
    if mesh is not None:
        if M is not None:
            raise ValueError(
                "the sharded engine has no preconditioner path — M must "
                "be None when mesh= is given"
            )
        if batch_axis is not None:
            raise ValueError(
                "mesh= and batch_axis= do not compose — shard one solve "
                "or vmap many, not both"
            )
        from repro.core import sharded as sharded_mod

        return sharded_mod.solve_sharded(
            A, b, spec, state, mesh=mesh, x0=x0,
            record_residuals=record_residuals,
        )
    _check_m(spec, M)

    if spec.method in _LSQ_METHODS:
        if M is not None:
            raise ValueError(
                f"method={spec.method!r} takes no preconditioner apply"
            )
        if spec.method == "lsmr":
            res = lsmr_mod.lsmr(
                A,
                b,
                x0,
                damp=spec.lsq_shift,
                tol=spec.tol,
                atol=spec.atol,
                maxiter=spec.maxiter,
                record_residuals=record_residuals,
                batch_axis=batch_axis,
                stagnation_window=spec.stagnation_window,
            )
            return SolveResult(
                x=res.x,
                info=res.info,
                state=state,
                report=_make_report(res.info, 0),
            )
        # deflsmr: the recycled basis lives in the DOMAIN space, whose
        # dimension a rectangular system's b cannot reveal — probe the
        # adjoint (zero cost) instead.
        x_tmpl = x0 if x0 is not None else lsmr_mod._domain_template(A, b)
        x_flat_t, unravel_x = pt.ravel_vector(x_tmpl)
        n = x_flat_t.shape[0]
        if state is None:
            state = RecycleState.zeros(spec.k, n, x_flat_t.dtype)
        if state.W.ndim != 2 or state.W.shape != (spec.k, n):
            raise ValueError(
                f"state.W has shape {state.W.shape}; spec(k={spec.k}) over "
                f"this system's domain needs ({spec.k}, {n}) — state and "
                "spec must agree"
            )
        x, info, w2, nw2, theta, rung = lsmr_mod._one_recycled_lsmr(
            A,
            b,
            x0,
            state.W,
            state.AW,
            unravel_x,
            k=spec.k,
            ell=spec.ell,
            damp=spec.lsq_shift,
            tol=spec.tol,
            atol=spec.atol,
            maxiter=spec.maxiter,
            select=spec.select,
            waw_jitter=spec.waw_jitter,
            refresh_aw=spec.refresh_aw,
            record_residuals=record_residuals,
            batch_axis=batch_axis,
            stagnation_window=spec.stagnation_window,
        )
        new_state = RecycleState(
            W=w2,
            AW=nw2,  # the AW slot carries NW = (AᵀA + λI)W for deflsmr
            theta=state.theta if theta is None else theta,
            systems_solved=state.systems_solved + 1,
            drift=state.drift,
        )
        return SolveResult(
            x=x, info=info, state=new_state,
            report=_make_report(info, rung),
        )

    if spec.method == "cg":
        res = solvers_mod.cg(
            A,
            b,
            x0,
            tol=spec.tol,
            atol=spec.atol,
            maxiter=spec.maxiter,
            M=M,
            record_residuals=record_residuals,
            stagnation_window=spec.stagnation_window,
        )
        return SolveResult(
            x=res.x,
            info=res.info,
            state=state,
            report=_make_report(res.info, 0),
        )

    b_flat, unravel = pt.ravel_vector(b)
    n = b_flat.shape[0]
    if state is None:
        state = RecycleState.zeros(spec.k, n, b_flat.dtype)
    if state.W.ndim != 2 or state.W.shape != (spec.k, n):
        raise ValueError(
            f"state.W has shape {state.W.shape}; spec(k={spec.k}) over this "
            f"system needs ({spec.k}, {n}) — state and spec must agree"
        )

    # Per-system semantics (refresh policy, accounting, strategy
    # transition, recovery ladder) are shared with solve_sequence's scan
    # body — ONE implementation, no drift.
    x, info, w2, aw2, theta, drift2, rung = recycle_mod._one_recycled_solve(
        A,
        b,
        x0,
        state.W,
        state.AW,
        state.drift,
        unravel,
        k=spec.k,
        ell=spec.ell,
        tol=spec.tol,
        atol=spec.atol,
        maxiter=spec.maxiter,
        select=spec.select,
        waw_jitter=spec.waw_jitter,
        refresh_aw=spec.refresh_aw,
        strategy=spec.strategy,
        M=M,
        record_residuals=record_residuals,
        batch_axis=batch_axis,
        recovery_rungs=spec.recovery_rungs,
        recovery_shift=spec.recovery_shift,
        stagnation_window=spec.stagnation_window,
    )
    new_state = RecycleState(
        W=w2,
        AW=aw2,
        # ell == 0 records nothing — carry the previous Ritz values.
        theta=state.theta if theta is None else theta,
        systems_solved=state.systems_solved + 1,
        drift=drift2.astype(state.drift.dtype),
    )
    return SolveResult(
        x=x, info=info, state=new_state, report=_make_report(info, rung)
    )


solve_jit = jax.jit(
    solve, static_argnames=("spec", "record_residuals", "batch_axis", "mesh")
)


# ---------------------------------------------------------------------------
# solve_sequence — N related systems, one lax.scan
# ---------------------------------------------------------------------------


def _solve_sequence_spec(
    systems: Any,
    b_seq: Pytree,
    spec: SolveSpec,
    state0: Optional[RecycleState],
    *,
    make_operator: Optional[Callable[[Any], Any]] = None,
    make_preconditioner: Optional[Callable[[Any], Any]] = None,
    carry_x: bool = False,
    divergence_fallback: bool = True,
    batch_axis: Optional[str] = None,
    x_prev0: Optional[jnp.ndarray] = None,
) -> SequenceSolveResult:
    if spec.method not in ("defcg", "deflsmr"):
        raise ValueError(
            "solve_sequence recycles a deflation basis — it needs "
            f"spec.method='defcg' or 'deflsmr', got {spec.method!r} (for "
            "plain CG/LSMR over independent systems use solve_batch)"
        )
    if spec.precond != "none" and make_preconditioner is None:
        raise ValueError(
            f"spec.precond={spec.precond!r} but no make_preconditioner was "
            "passed — the sequence path builds M per system, so supply a "
            "factory mapping each operator to its preconditioner apply"
        )
    if spec.method == "deflsmr":
        seq = lsmr_mod.solve_sequence_lsmr(
            systems,
            b_seq,
            state0.W if state0 is not None else None,
            state0.AW if state0 is not None else None,
            k=spec.k,
            ell=spec.ell,
            damp=spec.lsq_shift,
            make_operator=make_operator,
            tol=spec.tol,
            atol=spec.atol,
            maxiter=spec.maxiter,
            select=spec.select,
            waw_jitter=spec.waw_jitter,
            refresh_aw=spec.refresh_aw,
            carry_x=carry_x,
            batch_axis=batch_axis,
            stagnation_window=spec.stagnation_window,
            x_prev0=x_prev0,
        )
        return _finish_sequence(seq, spec, state0, b_seq)
    seq = recycle_mod.solve_sequence(
        systems,
        b_seq,
        state0.W if state0 is not None else None,
        state0.AW if state0 is not None else None,
        k=spec.k,
        ell=spec.ell,
        make_operator=make_operator,
        make_preconditioner=make_preconditioner,
        tol=spec.tol,
        atol=spec.atol,
        maxiter=spec.maxiter,
        select=spec.select,
        waw_jitter=spec.waw_jitter,
        refresh_aw=spec.refresh_aw,
        carry_x=carry_x,
        strategy=spec.strategy,
        drift0=state0.drift if state0 is not None else None,
        batch_axis=batch_axis,
        # divergence_fallback=False hard-disables recovery (the legacy
        # switch); otherwise the spec's ladder depth governs.
        recovery_rungs=(spec.recovery_rungs if divergence_fallback else 0),
        recovery_shift=spec.recovery_shift,
        stagnation_window=spec.stagnation_window,
        x_prev0=x_prev0,
    )
    return _finish_sequence(seq, spec, state0, b_seq)


def _finish_sequence(
    seq: SequenceResult,
    spec: SolveSpec,
    state0: Optional[RecycleState],
    b_seq: Pytree,
) -> SequenceSolveResult:
    """Fold an engine ``SequenceResult`` into the front door's return
    shape — shared by the def-CG and deflsmr sequence paths (for the
    latter, the ``AW`` slot carries the normal-operator products)."""
    num_systems = jax.tree_util.tree_leaves(b_seq)[0].shape[0]
    solved0 = (
        state0.systems_solved if state0 is not None else jnp.int32(0)
    )
    if seq.theta is not None:
        theta = seq.theta[-1]
    elif state0 is not None:
        # ell == 0 records nothing — carry the previous Ritz values.
        theta = state0.theta
    else:
        theta = jnp.zeros((spec.k,), seq.W.dtype)
    state = RecycleState(
        W=seq.W,
        AW=seq.AW,
        theta=theta,
        systems_solved=solved0 + num_systems,
        drift=seq.drift,
    )
    return SequenceSolveResult(
        x=seq.x,
        info=seq.info,
        theta=seq.theta,
        state=state,
        report=_make_report(seq.info, seq.rung),
    )


# The chunked driver's per-chunk engine call, jitted ONCE at module
# scope.  Calling the engine eagerly per chunk rebuilds the scan body
# closure every time, and jax's eager scan cache is keyed on the
# function object — so every chunk recompiled its scan (and a resumed
# run recompiled them all again).  Through this single jit the driver
# compiles at most two programs per run shape: the full-chunk program
# and one trailing partial chunk — the budget the trace audit
# (`repro.analysis.trace_audit`) pins.  All callables must be
# cache-stable (module-level factories, not per-call lambdas) to hit it.
_solve_sequence_spec_jit = jax.jit(
    _solve_sequence_spec,
    static_argnames=(
        "spec",
        "make_operator",
        "make_preconditioner",
        "carry_x",
        "divergence_fallback",
        "batch_axis",
    ),
)


def _solve_sequence_chunked(
    systems: Any,
    b_seq: Pytree,
    spec: SolveSpec,
    state0: Optional[RecycleState],
    *,
    make_operator: Optional[Callable[[Any], Any]],
    make_preconditioner: Optional[Callable[[Any], Any]],
    carry_x: bool,
    divergence_fallback: bool,
    checkpoint,
    checkpoint_every: int,
    resume: bool,
) -> SequenceSolveResult:
    """Crash-resumable sequence driver: chunked scans + checkpoints.

    Splits the N-system sequence into ``checkpoint_every``-sized chunks,
    runs each chunk as one engine scan (at most TWO compilations: the
    full-chunk program plus one trailing partial chunk), and saves the
    full resume image — accumulated per-system outputs, the carried
    :class:`RecycleState`, the warm-start carry, and ``next_index`` —
    after every chunk via ``checkpoint.save(..., blocking=True)``.

    With ``resume=True`` the newest restorable checkpoint is loaded and
    the loop continues from its ``next_index``.  Chunk boundaries are
    deterministic and the image is stored in full precision, so a
    killed-and-resumed run reproduces the uninterrupted run's iterates
    exactly.
    """
    num_systems = jax.tree_util.tree_leaves(b_seq)[0].shape[0]
    b0 = jax.tree_util.tree_map(lambda l: l[0], b_seq)
    if spec.method == "deflsmr":
        # Rectangular systems: the carried basis and solution live in
        # the DOMAIN space — probe the first operator's adjoint.
        make_op = (
            make_operator if make_operator is not None else (lambda s: s)
        )
        A0 = make_op(jax.tree_util.tree_map(lambda l: l[0], systems))
        x0_flat, unravel = pt.ravel_vector(
            lsmr_mod._domain_template(A0, b0)
        )
        n = x0_flat.shape[0]
        dtype = x0_flat.dtype
    else:
        b0_flat, unravel = pt.ravel_vector(b0)
        n = b0_flat.shape[0]
        dtype = b0_flat.dtype
    if state0 is None:
        state0 = RecycleState.zeros(spec.k, n, dtype)

    # The resume image: everything needed to continue mid-sequence.
    acc = {
        "x": jnp.zeros((num_systems, n), dtype),
        "theta": jnp.zeros((num_systems, spec.k), dtype),
        "iterations": jnp.zeros((num_systems,), jnp.int32),
        "converged": jnp.zeros((num_systems,), bool),
        "residual_norm": jnp.zeros((num_systems,), dtype),
        "matvecs": jnp.zeros((num_systems,), jnp.int32),
        "breakdown": jnp.zeros((num_systems,), bool),
        "status": jnp.zeros((num_systems,), jnp.int32),
        "guard_fired": jnp.zeros((num_systems,), bool),
        "rung": jnp.zeros((num_systems,), jnp.int32),
        "state": state0,
        "x_carry": jnp.zeros((n,), dtype),
    }
    start = 0
    if resume:
        restored = checkpoint.restore_latest(acc)
        if restored is not None:
            _, acc, extra = restored
            # repro-lint: disable=host-sync-in-trace — host resume path:
            # `extra` is the checkpoint's plain-dict metadata, never traced.
            start = int(extra["next_index"])

    ravel_each = jax.vmap(pt.ravel)
    while start < num_systems:
        stop = min(start + checkpoint_every, num_systems)
        sl = slice(start, stop)
        res = _solve_sequence_spec_jit(
            jax.tree_util.tree_map(lambda l: l[sl], systems),
            jax.tree_util.tree_map(lambda l: l[sl], b_seq),
            spec,
            acc["state"],
            make_operator=make_operator,
            make_preconditioner=make_preconditioner,
            carry_x=carry_x,
            divergence_fallback=divergence_fallback,
            x_prev0=acc["x_carry"] if carry_x else None,
        )
        x_flat = ravel_each(res.x)
        acc = dict(
            acc,
            x=acc["x"].at[sl].set(x_flat),
            iterations=acc["iterations"].at[sl].set(res.info.iterations),
            converged=acc["converged"].at[sl].set(res.info.converged),
            residual_norm=acc["residual_norm"]
            .at[sl]
            .set(res.info.residual_norm.astype(dtype)),
            matvecs=acc["matvecs"].at[sl].set(res.info.matvecs),
            breakdown=acc["breakdown"]
            .at[sl]
            .set(jnp.asarray(res.info.breakdown, bool)),
            status=acc["status"].at[sl].set(jnp.asarray(res.info.status)),
            guard_fired=acc["guard_fired"]
            .at[sl]
            .set(jnp.asarray(res.info.guard_fired, bool)),
            rung=acc["rung"].at[sl].set(res.report.rung),
            state=res.state,
            x_carry=x_flat[-1],
        )
        if res.theta is not None:
            acc["theta"] = acc["theta"].at[sl].set(res.theta)
        checkpoint.save(
            acc, step=stop, extra={"next_index": stop}, blocking=True
        )
        start = stop

    info = SolveInfo(
        iterations=acc["iterations"],
        converged=acc["converged"],
        residual_norm=acc["residual_norm"],
        matvecs=acc["matvecs"],
        breakdown=acc["breakdown"],
        status=acc["status"],
        guard_fired=acc["guard_fired"],
    )
    return SequenceSolveResult(
        x=jax.vmap(unravel)(acc["x"]),
        info=info,
        theta=acc["theta"] if spec.ell > 0 else None,
        state=acc["state"],
        report=_make_report(info, acc["rung"]),
    )


def solve_sequence(
    systems: Any,
    b_seq: Pytree,
    spec: Optional[SolveSpec] = None,
    state0: Optional[RecycleState] = None,
    *,
    make_operator: Optional[Callable[[Any], Any]] = None,
    make_preconditioner: Optional[Callable[[Any], Any]] = None,
    carry_x: bool = False,
    divergence_fallback: bool = True,
    checkpoint=None,
    checkpoint_every: int = 0,
    resume: bool = False,
):
    """Solve a sequence of related systems on-device, spec-driven.

    ``solve_sequence(systems, b_seq, spec, state0)`` is the front door:
    one ``lax.scan`` carries the :class:`RecycleState` across systems
    (zero host syncs; see :func:`repro.core.recycle.solve_sequence` for
    the engine internals), returns a :class:`SequenceSolveResult` whose
    ``state`` seeds the next call.  ``make_preconditioner`` maps each
    per-system operator to its ``M`` apply, so the whole scan runs
    Nyström/Jacobi-preconditioned def-CG.

    Crash resumability: pass ``checkpoint`` (a
    :class:`repro.checkpoint.CheckpointManager`) and ``checkpoint_every``
    (systems per chunk) to run the sequence as deterministic chunked
    scans, saving the full resume image after each chunk.  With
    ``resume=True`` the run continues from the newest restorable
    checkpoint; a killed-and-resumed run reproduces the uninterrupted
    run's iterates exactly.

    ``spec.method`` selects the engine: ``"defcg"`` (SPD systems) or
    ``"deflsmr"`` (regularized least-squares, normal-equations
    recycling geometry).  The PR-3-era positional ``(W0, AW0, k=…,
    ell=…)`` signature has been removed — seed the basis through
    ``state0=RecycleState(W=…, AW=…, …)`` instead.
    """
    if spec is not None and not isinstance(spec, SolveSpec):
        raise TypeError(
            "solve_sequence(systems, b, W0, AW0, k=..., ell=...) was "
            "removed; pass solve_sequence(systems, b, SolveSpec(k=..., "
            "ell=...), state0=RecycleState(W=..., AW=..., ...))"
        )
    if checkpoint is not None:
        if checkpoint_every < 1:
            raise ValueError(
                "checkpoint= needs checkpoint_every >= 1 (systems per "
                f"chunk), got {checkpoint_every}"
            )
        return _solve_sequence_chunked(
            systems,
            b_seq,
            SolveSpec() if spec is None else spec,
            state0,
            make_operator=make_operator,
            make_preconditioner=make_preconditioner,
            carry_x=carry_x,
            divergence_fallback=divergence_fallback,
            checkpoint=checkpoint,
            checkpoint_every=checkpoint_every,
            resume=resume,
        )
    if resume or checkpoint_every:
        raise ValueError(
            "resume=/checkpoint_every= need checkpoint=<CheckpointManager>"
        )
    return _solve_sequence_spec(
        systems,
        b_seq,
        SolveSpec() if spec is None else spec,
        state0,
        make_operator=make_operator,
        make_preconditioner=make_preconditioner,
        carry_x=carry_x,
        divergence_fallback=divergence_fallback,
    )


# ---------------------------------------------------------------------------
# solve_batch — B independent tenants, one vmap, one XLA computation
# ---------------------------------------------------------------------------


def solve_batch(
    systems: Any,
    b_batch: Pytree,
    spec: Optional[SolveSpec] = None,
    state: Optional[RecycleState] = None,
    *,
    make_operator: Optional[Callable[[Any], Any]] = None,
    make_preconditioner: Optional[Callable[[Any], Any]] = None,
    sequence: bool = False,
    carry_x: bool = False,
) -> BatchSolveResult:
    """Solve B independent systems (or sequences) in ONE compiled program.

    The multi-tenant serving shape: ``vmap`` lifts the flat def-CG engine
    over a leading tenant axis, so B users' GP/Laplace solves share one
    XLA computation — per-tenant ``RecycleState`` (leading axis B),
    per-tenant convergence masks (``info.converged``), no host syncs.
    Under ``vmap`` the while-loop runs until the *slowest* tenant
    converges; finished tenants' carries are masked frozen, so every
    tenant's answer matches its sequential :func:`solve` bit-for-bit.

    Args:
      systems: per-tenant operator data with a leading B axis on every
        traced leaf — a stacked operator pytree (e.g. one
        ``KernelSystemOperator`` whose ``sqrt_h`` is ``(B, n)``: B tenants
        sharing one kernel) consumed directly, or raw data mapped through
        ``make_operator``.  With ``sequence=True`` each leaf carries
        ``(B, N, …)``: B tenants × N systems each.
      b_batch: stacked right-hand sides, leading axis B (``(B, N, …)``
        with ``sequence=True``).
      state: batched :class:`RecycleState` (leading axis B on every
        leaf), e.g. a previous call's output.  ``None`` bootstraps every
        tenant cold.
      make_preconditioner: per-tenant operator → ``M`` apply factory
        (stable callable), as in :func:`solve_sequence`.
      sequence: treat each tenant as a *sequence* of N related systems
        (vmapped :func:`solve_sequence`) instead of a single system.
      carry_x: warm-start within each tenant's sequence
        (``sequence=True`` only).

    Returns a :class:`BatchSolveResult`; with ``sequence=True`` its
    ``x``/``info`` carry axes ``(B, N, …)`` and ``state`` is the B final
    per-tenant states.
    """
    spec = SolveSpec() if spec is None else spec
    make_op = make_operator if make_operator is not None else (lambda s: s)

    if sequence:
        if spec.method not in ("defcg", "deflsmr"):
            raise ValueError(
                "sequence=True requires spec.method='defcg' or 'deflsmr'"
            )

        def one_seq(sys_i, b_i, st_i):
            res = _solve_sequence_spec(
                sys_i,
                b_i,
                spec,
                st_i,
                make_operator=make_operator,
                make_preconditioner=make_preconditioner,
                carry_x=carry_x,
                batch_axis=_TENANT_AXIS,
            )
            return res.x, res.info, res.state, res.report

        if state is None:
            state = _batched_zero_state(
                b_batch, spec, axes=2,
                systems=systems, make_operator=make_operator,
            )
        x, info, state_out, report = jax.vmap(
            one_seq, axis_name=_TENANT_AXIS
        )(systems, b_batch, state)
        return BatchSolveResult(x=x, info=info, state=state_out, report=report)

    if spec.method in ("cg", "lsmr"):

        def one_cg(sys_i, b_i):
            A = make_op(sys_i)
            M = (
                make_preconditioner(A)
                if make_preconditioner is not None
                else None
            )
            res = solve(A, b_i, spec, None, M=M)
            return res.x, res.info, res.report

        # Plain CG/LSMR neither consume nor update recycle state — a
        # caller-supplied batched state passes through untouched (same
        # contract as solve()).
        x, info, report = jax.vmap(one_cg)(systems, b_batch)
        return BatchSolveResult(x=x, info=info, state=state, report=report)

    def one(sys_i, b_i, st_i):
        A = make_op(sys_i)
        M = (
            make_preconditioner(A)
            if make_preconditioner is not None
            else None
        )
        # batch_axis: the recording scan's matvec gate reduces `active`
        # across the tenant axis, so the batch stops paying operator
        # applications the moment its LAST tenant converges.
        res = solve(A, b_i, spec, st_i, M=M, batch_axis=_TENANT_AXIS)
        return res.x, res.info, res.state, res.report

    if state is None:
        state = _batched_zero_state(
            b_batch, spec, axes=1,
            systems=systems, make_operator=make_operator,
        )
    x, info, state_out, report = jax.vmap(one, axis_name=_TENANT_AXIS)(
        systems, b_batch, state
    )
    return BatchSolveResult(x=x, info=info, state=state_out, report=report)


def _batched_zero_state(
    b_batch: Pytree,
    spec: SolveSpec,
    axes: int,
    *,
    systems: Any = None,
    make_operator: Optional[Callable[[Any], Any]] = None,
) -> RecycleState:
    """Cold per-tenant states: leading B axis over RecycleState.zeros.

    For the least-squares methods the basis dimension is the DOMAIN
    size, which ``b`` (range space) cannot reveal — one tenant's
    operator adjoint is probed (``eval_shape``, zero cost) instead.
    """
    leaves = jax.tree_util.tree_leaves(b_batch)
    B = leaves[0].shape[0]
    b0 = jax.tree_util.tree_map(lambda l: l[(0,) * axes], b_batch)
    if spec.method in _LSQ_METHODS:
        make_op = (
            make_operator if make_operator is not None else (lambda s: s)
        )
        A0 = make_op(
            jax.tree_util.tree_map(lambda l: l[(0,) * axes], systems)
        )
        b0_flat, _ = pt.ravel_vector(
            lsmr_mod._domain_template(A0, b0)
        )
    else:
        b0_flat, _ = pt.ravel_vector(b0)
    n = b0_flat.shape[0]
    dtype = b0_flat.dtype
    return RecycleState(
        W=jnp.zeros((B, spec.k, n), dtype),
        AW=jnp.zeros((B, spec.k, n), dtype),
        theta=jnp.zeros((B, spec.k), dtype),
        systems_solved=jnp.zeros((B,), jnp.int32),
        drift=jnp.zeros((B,), dtype),
    )


solve_batch_jit = jax.jit(
    solve_batch,
    static_argnames=(
        "spec",
        "make_operator",
        "make_preconditioner",
        "sequence",
        "carry_x",
    ),
)


# ---------------------------------------------------------------------------
# solve_pool_step — one slot-masked serving step over a fixed slot pool
# ---------------------------------------------------------------------------


def _slot_bcast(active: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a ``(B,)`` slot mask against a ``(B, …)`` leaf."""
    return active.reshape(active.shape + (1,) * (leaf.ndim - 1))


def solve_pool_step(
    systems: Any,
    b_batch: Pytree,
    spec: Optional[SolveSpec],
    state: RecycleState,
    active: jnp.ndarray,
    *,
    make_operator: Optional[Callable[[Any], Any]] = None,
    make_preconditioner: Optional[Callable[[Any], Any]] = None,
) -> BatchSolveResult:
    """One batched serving step over a FIXED pool of B slots, mask-aware.

    The serving layer (:mod:`repro.serve`) keeps B device-resident
    :class:`RecycleState` slots and, each scheduler tick, serves whatever
    subset of slots has work with ONE :func:`solve_batch` call.  This
    entry point owns the masking semantics of that step:

    * ``active`` is the ``(B,)`` bool slot mask.  Inactive slots (empty,
      or resident tenants with no pending request this tick) are served a
      ZERO right-hand side: ``‖r₀‖ = 0 ≤ max(tol·0, atol)`` so they
      converge before iteration 1, their lanes freeze, and the
      cross-tenant matvec gate (``psum`` over the vmap axis) stops
      charging them the moment the last *active* tenant converges — an
      idle slot never stalls or poisons its neighbours.
    * Inactive slots' ``RecycleState`` passes through BIT-UNTOUCHED: the
      post-step merge restores their incoming state leaf-wise, so a
      resident-but-idle tenant's warm basis (and ``systems_solved``
      counter) survives any number of ticks it sits out.
    * Inactive slots' diagnostics are scrubbed: ``info``/``report``
      report 0 iterations / 0 matvecs / CONVERGED for them, so pool
      metrics can sum per-slot counters without first filtering (the k
      refresh matvecs an idle warm slot's lane *physically* rides along
      in the batched GEMM are not attributed to any tenant — they are
      pool overhead, visible only in wall-clock).

    Dispatch note: the B=1 degenerate case (exactly one active slot)
    should NOT come here — the vmapped while-loop lowering pays a masked
    select/broadcast tax that loses to plain :func:`solve` at B=1 (the
    ``batch/`` bench records it); :class:`repro.serve.SolveService`
    gathers the single slot and dispatches through :data:`solve_jit`
    instead.  This function stays total — it accepts any mask, including
    one-hot — so the fast path is an optimization, not a semantic fork.
    """
    spec = SolveSpec() if spec is None else spec
    if spec.method not in ("defcg", "deflsmr"):
        raise ValueError(
            "solve_pool_step carries per-slot RecycleState — it needs "
            f"spec.method='defcg' or 'deflsmr', got {spec.method!r}"
        )
    if state is None:
        state = _batched_zero_state(
            b_batch, spec, axes=1,
            systems=systems, make_operator=make_operator,
        )
    active = jnp.asarray(active, bool)
    b_masked = jax.tree_util.tree_map(
        lambda l: jnp.where(_slot_bcast(active, l), l, jnp.zeros_like(l)),
        b_batch,
    )
    res = solve_batch(
        systems,
        b_masked,
        spec,
        state,
        make_operator=make_operator,
        make_preconditioner=make_preconditioner,
    )
    state_out = jax.tree_util.tree_map(
        lambda new, old: jnp.where(_slot_bcast(active, new), new, old),
        res.state,
        state,
    )
    info = res.info
    zero = jnp.int32(0)
    masked_info = SolveInfo(
        iterations=jnp.where(active, info.iterations, zero),
        converged=jnp.where(active, info.converged, True),
        residual_norm=jnp.where(
            active, info.residual_norm, jnp.zeros_like(info.residual_norm)
        ),
        matvecs=jnp.where(active, info.matvecs, zero),
        residual_norms=info.residual_norms,
        breakdown=jnp.where(active, jnp.asarray(info.breakdown, bool), False),
        status=jnp.where(active, jnp.asarray(info.status, jnp.int32), zero),
        guard_fired=jnp.where(
            active, jnp.asarray(info.guard_fired, bool), False
        ),
    )
    report = SolveReport(
        status=masked_info.status,
        rung=jnp.where(active, res.report.rung, zero),
        guard_firings=jnp.asarray(masked_info.guard_fired, jnp.int32),
        matvecs=masked_info.matvecs,
    )
    x = jax.tree_util.tree_map(
        lambda l: jnp.where(_slot_bcast(active, l), l, jnp.zeros_like(l)),
        res.x,
    )
    return BatchSolveResult(x=x, info=masked_info, state=state_out, report=report)


solve_pool_step_jit = jax.jit(
    solve_pool_step,
    static_argnames=("spec", "make_operator", "make_preconditioner"),
)
