"""repro.core — the paper's contribution: recycled Krylov solvers for
sequences of related systems, pytree-native and pjit-shardable.

The public front doors are ``solve`` / ``solve_sequence`` / ``solve_batch``
driven by one ``SolveSpec`` and carrying a ``RecycleState`` (see
``core/api.py``).  The spec's method axis covers both workload families:
``cg``/``defcg`` for SPD systems and ``lsmr``/``deflsmr`` for regularized
least-squares (``core/lsmr.py``), all sharing the ``core/engine.py`` loop
harness.  The older entry points (``cg``, ``defcg``, ``RecycleManager``,
``recycled_solve_jit``) remain as host-side conveniences and
compatibility shims over the same engine.
"""

from repro.core.api import (
    BatchSolveResult,
    SequenceSolveResult,
    SolveReport,
    SolveResult,
    SolveSpec,
    make_preconditioner,
    solve,
    solve_batch,
    solve_batch_jit,
    solve_jit,
    solve_pool_step,
    solve_pool_step_jit,
    solve_sequence,
)
from repro.core.faults import FaultInjectingOperator, truncate_latest_checkpoint
from repro.core.lsmr import (
    lsmr,
    lsmr_jit,
    solve_sequence_lsmr,
    solve_sequence_lsmr_jit,
)
from repro.core.operators import (
    DenseMatrixOperator,
    GaussNewtonOperator,
    GGNOperator,
    KernelSystemOperator,
    LinearOperator,
    adjoint_matvec,
    apply_to_basis,
    from_callable,
    from_matrix,
    materialize,
)
from repro.core.preconditioners import (
    JacobiPreconditioner,
    NystromPreconditioner,
    WoodburyKernelPreconditioner,
    jacobi,
    kernel_nystrom_preconditioner,
    nystrom_preconditioner,
    randomized_nystrom,
)
from repro.core.recycle import (
    MAX_RECOVERY_RUNGS,
    RecycleManager,
    RecycleState,
    SequenceResult,
    harmonic_ritz,
    harmonic_ritz_flat,
    random_orthonormal_basis,
    recycled_solve_jit,
    solve_sequence_jit,
)
from repro.core.solvers import (
    DEFAULT_WAW_JITTER,
    CGResult,
    RecycleData,
    SolveInfo,
    SolveStatus,
    cg,
    cholesky_solve,
    defcg,
    deflated_initial_guess,
)
from repro.core.strategies import (
    HarmonicRitz,
    MGeometryHarmonic,
    RecycleStrategy,
    WindowedRecombine,
)

__all__ = [
    "BatchSolveResult",
    "SequenceSolveResult",
    "SolveReport",
    "SolveResult",
    "SolveSpec",
    "make_preconditioner",
    "solve",
    "solve_batch",
    "solve_batch_jit",
    "solve_jit",
    "solve_pool_step",
    "solve_pool_step_jit",
    "solve_sequence",
    "FaultInjectingOperator",
    "truncate_latest_checkpoint",
    "lsmr",
    "lsmr_jit",
    "solve_sequence_lsmr",
    "solve_sequence_lsmr_jit",
    "GaussNewtonOperator",
    "GGNOperator",
    "KernelSystemOperator",
    "DenseMatrixOperator",
    "LinearOperator",
    "adjoint_matvec",
    "apply_to_basis",
    "from_callable",
    "from_matrix",
    "materialize",
    "JacobiPreconditioner",
    "NystromPreconditioner",
    "WoodburyKernelPreconditioner",
    "jacobi",
    "kernel_nystrom_preconditioner",
    "nystrom_preconditioner",
    "randomized_nystrom",
    "MAX_RECOVERY_RUNGS",
    "RecycleManager",
    "RecycleState",
    "SequenceResult",
    "harmonic_ritz",
    "harmonic_ritz_flat",
    "random_orthonormal_basis",
    "recycled_solve_jit",
    "solve_sequence_jit",
    "DEFAULT_WAW_JITTER",
    "CGResult",
    "RecycleData",
    "SolveInfo",
    "SolveStatus",
    "cg",
    "cholesky_solve",
    "defcg",
    "deflated_initial_guess",
    "HarmonicRitz",
    "MGeometryHarmonic",
    "RecycleStrategy",
    "WindowedRecombine",
]
