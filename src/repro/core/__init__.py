"""repro.core — the paper's contribution: recycled Krylov solvers for
sequences of SPD systems, pytree-native and pjit-shardable."""

from repro.core.operators import (
    GGNOperator,
    KernelSystemOperator,
    LinearOperator,
    apply_to_basis,
    from_callable,
    from_matrix,
    materialize,
)
from repro.core.preconditioners import (
    jacobi,
    nystrom_preconditioner,
    randomized_nystrom,
)
from repro.core.recycle import (
    RecycleManager,
    SequenceResult,
    harmonic_ritz,
    harmonic_ritz_flat,
    random_orthonormal_basis,
    recycled_solve_jit,
    solve_sequence,
    solve_sequence_jit,
)
from repro.core.solvers import (
    CGResult,
    RecycleData,
    SolveInfo,
    cg,
    cholesky_solve,
    defcg,
    deflated_initial_guess,
)

__all__ = [
    "GGNOperator",
    "KernelSystemOperator",
    "LinearOperator",
    "apply_to_basis",
    "from_callable",
    "from_matrix",
    "materialize",
    "jacobi",
    "nystrom_preconditioner",
    "randomized_nystrom",
    "RecycleManager",
    "SequenceResult",
    "harmonic_ritz",
    "harmonic_ritz_flat",
    "random_orthonormal_basis",
    "recycled_solve_jit",
    "solve_sequence",
    "solve_sequence_jit",
    "CGResult",
    "RecycleData",
    "SolveInfo",
    "cg",
    "cholesky_solve",
    "defcg",
    "deflated_initial_guess",
]
