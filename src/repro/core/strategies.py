"""Recycle strategies: the end-of-solve transition, made a pluggable axis.

The paper fixes ONE policy for what survives a solve: harmonic-Ritz
extraction of ``k`` vectors from ``[W, P]`` followed by an exact
``A⁽ⁱ⁺¹⁾W`` refresh (k matvecs).  Related work treats both halves as free
design choices — POD-augmented selection (Carlberg et al.) and the
recycling-space taxonomy of the Soodhalter/de Sturler/Kilmer survey vary
*what* is kept and *in which inner product*.  This module makes that axis
explicit: a :class:`RecycleStrategy` owns the transition

    (recording window, old state)  →  (next W, next AW, θ, drift)

plus the pre-solve refresh policy, and is selected declaratively via
``SolveSpec.strategy``.

The window handoff contract
---------------------------

A strategy consumes only what the flat def-CG engine already recorded
(:class:`repro.core.solvers.RecycleData`): the first-ℓ search directions
``P`` and products ``AP`` written by the masked scan phase, the dynamic
``stored`` count, and the CG coefficients ``(α, β)`` of those iterations.
Everything is "readily available" in the paper's §2.3 sense — a
transition costs ZERO extra matvecs.  Whatever basis the strategy
returns, def-CG treats exact-zero rows as no-op deflation directions, so
clamped/degraded extractions never change shapes.

Concrete strategies
-------------------

* :class:`HarmonicRitz` — the incumbent: harmonic-Ritz extraction over
  ``Z = [W, P]`` in the Euclidean geometry, with the refresh policy taken
  from ``spec.refresh_aw`` (``"exact"`` spends k matvecs per system
  rebuilding ``AW``; ``"stale"`` reuses the extraction products).
* :class:`WindowedRecombine` — the paper-faithful O(n²(ℓ+1)k) accounting:
  BOTH ``W' = uᵀZ`` and ``AW' = uᵀAZ`` are rebuilt by recombining stored
  columns (one stacked two-block GEMM,
  :func:`repro.kernels.ops.recombine_blocks`) and the next solve runs on
  the stale products — zero refresh matvecs.  A per-system drift guard
  watches the asymmetry of the extraction gram ``F = (AZ)Zᵀ``: for exact
  data ``F`` is symmetric (A = Aᵀ), and under operator drift its W–P
  cross block is exactly ``Pᵀ(A⁽ⁱ⁾ − A_stale)W`` — a FREE measurement of
  ``‖AW − A·W‖`` projected on the Krylov window, read off a gram the
  extraction computes anyway.  When the measured drift exceeds
  ``guard``, the NEXT solve pays one full k-matvec refresh; below it, the
  sequence runs at the paper's accounting.  (The guard is retrospective —
  it reacts one system after drift appears; the sequence engine's
  divergence fallback covers the catastrophic case in the same pass.)
* :class:`MGeometryHarmonic` — harmonic extraction in the geometry of the
  preconditioner: with ``M⁻¹`` applied inside the grams, the extracted θ
  approximate eigenvalues of the EFFECTIVE operator ``M⁻¹A`` (the one the
  preconditioned iteration actually sees), so ``select`` targets the ends
  of the effective spectrum and deflation cleans up exactly what the
  preconditioner leaves behind.  Algebra: the split-preconditioned def-CG
  is plain def-CG on ``Ã = M^{-1/2} A M^{-1/2}`` with bases mapped by
  ``M^{1/2}``; harmonic Ritz of ``Ã`` over the mapped window needs
  ``G̃ = (AZ)ᵀ M⁻¹ (AZ)`` and ``F̃ = (AZ)ᵀZ`` — both computable with the
  preconditioner APPLY only (no square roots), and the recombination
  ``W' = Z U`` maps back for free.  Validated against a dense
  M^{1/2}-similarity reference in ``tests/test_strategies.py``.

Strategies are frozen dataclasses holding only static config: hashable
(they ride inside the jit-static ``SolveSpec``) and registered as pytree
nodes with zero children (they also pass through traced positions
untouched).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.solvers import DRIFT_NOISE_FLOOR_EPS, RecycleData
from repro.kernels import ops as kops

FlatApply = Callable[[jnp.ndarray], jnp.ndarray]


def _drift_threshold(guard: float, tol: float, dtype) -> jnp.ndarray:
    """``guard × tol`` floored at the working dtype's drift-noise level
    (:data:`repro.core.solvers.DRIFT_NOISE_FLOOR_EPS` × eps) — the one
    comparison scale shared by every guard layer."""
    return jnp.maximum(
        jnp.asarray(guard * tol, dtype),
        DRIFT_NOISE_FLOOR_EPS * jnp.finfo(dtype).eps,
    )


def _gated_basis_apply(apply_basis, pred, w, fallback, batch_axis):
    """``apply_basis(w)`` where ``pred``, else ``fallback`` — as a REAL
    branch even under ``vmap``.

    A per-lane predicate would lower ``lax.cond`` to a ``select`` under
    ``solve_batch``'s vmap, making every tenant pay the refresh GEMM
    every system; with the axis name the branch predicate becomes the
    cross-tenant any (unbatched), and the per-lane choice is a cheap
    ``where`` on the result — no tenant computes the operator unless
    SOME tenant's guard fired.
    """
    if batch_axis is None:
        # repro-lint: disable=cond-batched-pred — this is the explicitly
        # UNBATCHED branch; the vmapped path below reduces with psum.
        return jax.lax.cond(pred, apply_basis, lambda _: fallback, w)
    any_pred = jax.lax.psum(pred.astype(jnp.int32), batch_axis) > 0
    out = jax.lax.cond(any_pred, apply_basis, lambda _: fallback, w)
    return jnp.where(pred, out, fallback)


def _register_strategy(cls):
    """Register a strategy as a LEAF-less pytree node: all fields are
    static aux data, so a strategy is hashable jit-static config that can
    also sit inside traced containers without contributing leaves."""

    def flatten(s):
        return (), tuple(
            getattr(s, f.name) for f in dataclasses.fields(s)
        )

    def unflatten(aux, children):
        del children
        return cls(*aux)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


# ---------------------------------------------------------------------------
# The extraction core (flat, masked, optionally M-geometry)
# ---------------------------------------------------------------------------


def _select_positive_ritz(zeta, Wm, k: int, select: str):
    """Pick ``k`` Ritz pairs by θ = 1/ζ, clamped to the positive count.

    ζ ≤ 0 can only arise from rounding or masked/projected-out directions
    (A SPD ⇒ θ > 0) — never select it.  When fewer than ``k`` positive
    pairs survive the rank filter, the trailing slots are masked to exact
    zeros (θ = 0, zero eigenvector column) rather than argsorting the
    ``±inf`` sentinel keys into the selection, which manufactured ~1e300
    "Ritz values" normalized from near-zero vectors.

    Returns ``(w_sel, theta, slot_ok)`` with shapes ``(m, k), (k,), (k,)``.
    """
    npos = jnp.sum(zeta > 0)
    slot_ok = jnp.arange(k) < jnp.minimum(npos, k)
    if select == "largest":
        order = jnp.argsort(jnp.where(zeta > 0, zeta, jnp.inf))[:k]
    elif select == "smallest":
        order = jnp.argsort(jnp.where(zeta > 0, zeta, -jnp.inf))[::-1][:k]
    else:
        raise ValueError(f"unknown select={select!r}")
    w_sel = Wm[:, order] * slot_ok[None, :].astype(Wm.dtype)
    zeta_sel = jnp.where(slot_ok, zeta[order], 1.0)
    theta = jnp.where(slot_ok, 1.0 / zeta_sel, 0.0)
    return w_sel, theta, slot_ok


def harmonic_ritz_flat_core(
    Z: jnp.ndarray,
    AZ: jnp.ndarray,
    k: int,
    *,
    valid: Optional[jnp.ndarray] = None,
    select: str = "largest",
    jitter: float = 1e-10,
    m_apply: Optional[FlatApply] = None,
    psum_axis: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Masked flat harmonic-Ritz extraction; the strategies' shared math.

    Extends the device-resident extraction (see
    :func:`repro.core.recycle.harmonic_ritz_flat`, which wraps this) with
    two strategy-layer capabilities:

    * ``m_apply`` — an optional flat ``r ↦ M⁻¹r`` apply.  When given, the
      left gram becomes ``G = (AZ) M⁻¹ (AZ)ᵀ`` (one extra gram block in
      the SAME stacked self-gram GEMM over ``S = [Z; AZ; M⁻¹AZ]``) so the
      extracted pairs are harmonic Ritz of the preconditioned operator
      ``M⁻¹A`` mapped back to original coordinates — the M-geometry of
      :class:`MGeometryHarmonic`.
    * the fourth return ``fasym`` — the relative asymmetry
      ``‖F − Fᵀ‖_F / ‖F‖_F`` of the raw (equilibrated, pre-symmetrized)
      cross gram ``F = (AZ)Zᵀ``.  For exact data F is symmetric; with a
      stale ``AW`` block its W–P quadrant equals ``Pᵀ(A − A_stale)W``, so
      this scalar is a free ``‖AW − A·W‖`` proxy — the
      :class:`WindowedRecombine` drift guard.

    The recombination ``[W'; AW'] = [uᵀZ; uᵀAZ]`` is ONE stacked
    two-block GEMM (:func:`repro.kernels.ops.recombine_blocks`) — with a
    stale-mode strategy this is where the next basis AND its operator
    products come from, at zero matvecs.

    ``psum_axis`` names a mesh axis the length-n coordinate dimension is
    sharded over (the sharded engine's ``"solve"`` axis): the stacked
    self-gram and the column norms — the only n-reductions here — are
    computed per-shard and ``psum``-combined, everything downstream (the
    (2m, 2m) eigenproblems, the selection) is replicated arithmetic, and
    the recombination GEMM stays per-shard.  ``None`` (the default) is
    the unsharded path, bit-identical to before the axis existed.

    Returns ``(W, AW, theta, fasym)`` of shapes
    ``(k, n), (k, n), (k,), ()`` — n per-shard under ``psum_axis``.
    """
    m = Z.shape[0]
    if k > m:
        raise ValueError(f"cannot extract k={k} Ritz vectors from m={m} basis")
    if valid is not None:
        vz = valid.astype(Z.dtype)[:, None]
        Z = Z * vz
        AZ = AZ * vz

    S2 = jnp.concatenate([Z, AZ], axis=0)  # (2m, n): gram + recombination
    if m_apply is None:
        full = kops.self_gram(S2)  # (2m, 2m)
        if psum_axis is not None:
            # Per-shard gram over the local n-columns; ONE collective
            # replicates the full (2m, 2m) gram on every shard.
            full = jax.lax.psum(full, psum_axis)
        # Quadrants: ⎡ZZᵀ  ·⎤ — diag(ZZᵀ) are the column norms, the lower
        #            ⎣F    G⎦   blocks are the projection grams.
        zz = jnp.diag(full[:m, :m])
        F_raw = full[m:, :m]
        G = full[m:, m:]
    else:
        # M-geometry: one taller stack S = [Z; AZ; M⁻¹AZ] — the same
        # single self-gram GEMM now also contains G = (AZ)(M⁻¹AZ)ᵀ.
        MAZ = jax.vmap(m_apply)(AZ)
        full = kops.self_gram(jnp.concatenate([S2, MAZ], axis=0))
        if psum_axis is not None:
            full = jax.lax.psum(full, psum_axis)
        zz = jnp.diag(full[:m, :m])
        F_raw = full[m : 2 * m, :m]
        G = full[m : 2 * m, 2 * m :]
        G = 0.5 * (G + G.T)  # M⁻¹ symmetric ⇒ symmetric to rounding

    dz = jnp.where(zz > 0, jax.lax.rsqrt(zz), 0.0)
    G = G * dz[:, None] * dz[None, :]
    F = F_raw * dz[:, None] * dz[None, :]

    # Drift proxy BEFORE symmetrization throws the signal away: the
    # antisymmetric part of the (scale-equilibrated) F gram.
    fnorm = jnp.sqrt(jnp.sum(F * F))
    fasym = jnp.sqrt(jnp.sum((F - F.T) ** 2)) / jnp.maximum(
        fnorm, jnp.finfo(F.dtype).tiny
    )
    fasym = jnp.where(fnorm > 0, fasym, 0.0)
    F = 0.5 * (F + F.T)

    # Second-stage equilibration on ‖AZ_i‖ (M-geometry: ‖AZ_i‖_{M⁻¹}).
    d = jnp.where(jnp.diag(G) > 0, jnp.diag(G), 1.0) ** -0.5
    G = G * d[:, None] * d[None, :]
    F = F * d[:, None] * d[None, :]

    # Rank-revealing reduction of the generalized problem: eigendecompose
    # G and project out its near-null directions (masked rows and
    # near-dependent Krylov columns surface as λ ≈ 0).  Projected
    # directions get ζ = 0 exactly and the positivity filter excludes
    # them — shapes stay static.
    lam, qg = jnp.linalg.eigh(G)
    eps = jnp.finfo(G.dtype).eps
    rcond = jnp.maximum(jnp.asarray(jitter, G.dtype), 100.0 * eps) * m
    good = lam > rcond * lam[-1]
    s = jnp.where(good, 1.0 / jnp.sqrt(jnp.maximum(lam, 1e-300)), 0.0)
    M = s[:, None] * (qg.T @ F @ qg) * s[None, :]
    M = 0.5 * (M + M.T)
    zeta, Wm = jnp.linalg.eigh(M)

    w_sel, theta, slot_ok = _select_positive_ritz(zeta, Wm, k, select)

    # u folds the reduction and BOTH equilibrations, so it applies to the
    # raw (unnormalized) bases: u = D_z · D · Qg S w.
    u = qg @ (s[:, None] * w_sel)
    u = u * (d * dz)[:, None]
    u = u.astype(Z.dtype)

    # ONE pass over the stored bases rebuilds both blocks: W' = uᵀZ and
    # AW' = uᵀAZ — for a stale-mode strategy this GEMM IS the refresh.
    WA = kops.recombine_blocks(S2, u)  # (2k, n)
    W, AW = WA[:k], WA[k:]

    wsq = jnp.sum(W * W, axis=1)
    if psum_axis is not None:
        wsq = jax.lax.psum(wsq, psum_axis)
    wn = jnp.sqrt(jnp.maximum(wsq, jnp.finfo(u.dtype).tiny))
    col_scale = jnp.where(slot_ok, 1.0 / wn, 0.0).astype(W.dtype)
    W = W * col_scale[:, None]
    AW = AW * col_scale[:, None]
    return W, AW, theta, fasym


def extract_next_basis_core(
    w_flat: Optional[jnp.ndarray],
    aw_flat: Optional[jnp.ndarray],
    p_flat: jnp.ndarray,
    ap_flat: jnp.ndarray,
    stored,
    k: int,
    *,
    select: str = "largest",
    jitter: float = 1e-10,
    m_apply: Optional[FlatApply] = None,
    psum_axis: Optional[str] = None,
):
    """One cross-system extraction on the flat engine.

    ``Z = [W, P]`` with a traced validity mask: W rows are valid where
    nonzero (clamped slots are exact zeros), P rows where their index is
    below the dynamic ``stored`` count.  Shape-static throughout.
    ``psum_axis`` (see :func:`harmonic_ritz_flat_core`) marks the
    n-dimension as sharded — the W-row validity norms join the gram's
    cross-shard reductions.  Returns ``(W, AW, theta, fasym)``.
    """
    ell = p_flat.shape[0]
    p_valid = jnp.arange(ell) < stored
    if w_flat is None:
        Z, AZ, valid = p_flat, ap_flat, p_valid
    else:
        Z = jnp.concatenate([w_flat, p_flat], axis=0)
        AZ = jnp.concatenate([aw_flat, ap_flat], axis=0)
        wsq = jnp.sum(w_flat * w_flat, axis=1)
        if psum_axis is not None:
            wsq = jax.lax.psum(wsq, psum_axis)
        w_valid = wsq > 0
        valid = jnp.concatenate([w_valid, p_valid])
    return harmonic_ritz_flat_core(
        Z, AZ, k, valid=valid, select=select, jitter=jitter,
        m_apply=m_apply, psum_axis=psum_axis,
    )


# ---------------------------------------------------------------------------
# The strategy protocol
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecycleStrategy:
    """Owner of the per-system refresh policy and end-of-solve transition.

    Subclasses implement:

    * :meth:`prepare` — decide, BEFORE the solve, which ``AW`` deflates
      system i and what it costs:
      ``(aw_used, refresh_matvecs, exact_aw, stale_guard)``.
      ``exact_aw`` must be a *python* bool (it selects a static def-CG
      code path: whether the ``r₀ = r − AW·c`` shortcut is trusted or one
      true matvec re-derives the initial residual); ``stale_guard``
      (static float or None) arms def-CG's in-solve drift guard — the
      free ``‖(A·W − AW)c‖`` measurement in the stale setup that
      refreshes ``AW`` before a too-stale recurrence can diverge (see
      :func:`repro.core.solvers.defcg`).
    * :meth:`transition` — consume the recorded window
      (:class:`repro.core.solvers.RecycleData`) AFTER the solve and emit
      ``(W', AW', theta, drift)``.  ``drift`` is the strategy's own
      carried scalar (stored in ``RecycleState.drift``); strategies that
      do not guard return 0.
    * :meth:`manager_wants_refresh` — the host-side mirror of
      :meth:`prepare`'s refresh decision, for :class:`RecycleManager`.

    Instances are frozen, hashable, and leaf-less pytree nodes — valid
    both as jit-static config (inside ``SolveSpec``) and inside traced
    containers.
    """

    def prepare(
        self,
        apply_basis: FlatApply,
        w: jnp.ndarray,
        aw_carry: jnp.ndarray,
        drift: jnp.ndarray,
        *,
        k: int,
        refresh_aw: str,
        tol: float = 1e-5,
        batch_axis: Optional[str] = None,
    ):
        raise NotImplementedError

    def transition(
        self,
        w: Optional[jnp.ndarray],
        aw: Optional[jnp.ndarray],
        window: RecycleData,
        *,
        k: int,
        select: str = "largest",
        jitter: float = 1e-10,
        m_apply: Optional[FlatApply] = None,
    ):
        raise NotImplementedError

    def manager_wants_refresh(self, refresh_aw: str, drift, tol: float) -> bool:
        raise NotImplementedError

    def in_solve_guard(self, tol: float):
        """Static ``defcg(stale_guard=…)`` threshold, or None (no
        in-solve guard) — lets host-driven callers arm the same layer-2
        protection the device paths get from :meth:`prepare`."""
        del tol
        return None

    @property
    def needs_preconditioner(self) -> bool:
        """Whether the transition is meaningless without an ``M`` apply."""
        return False


def _zero_drift(ref: jnp.ndarray) -> jnp.ndarray:
    return jnp.zeros((), ref.dtype)


@_register_strategy
@dataclasses.dataclass(frozen=True)
class HarmonicRitz(RecycleStrategy):
    """The incumbent policy, expressed against the strategy interface.

    Transition: Euclidean harmonic-Ritz extraction over ``[W, P]``.
    Refresh: per ``spec.refresh_aw`` — ``"exact"`` recomputes ``AW`` with
    one multi-RHS pass (k matvecs, charged; skipped and uncharged on a
    cold all-zero basis), ``"stale"`` reuses the recombined products
    unconditionally (exact only for an unchanged operator).
    """

    def prepare(self, apply_basis, w, aw_carry, drift, *, k, refresh_aw,
                tol=1e-5, batch_axis=None):
        del drift, tol
        if refresh_aw == "stale":
            return aw_carry, jnp.int32(0), False, None
        # Cold bootstrap (all-zero W): A @ 0 = 0 — skip the k operator
        # passes and their accounting.
        has_w = jnp.any(w != 0)
        aw = _gated_basis_apply(
            apply_basis, has_w, w, jnp.zeros_like(w), batch_axis
        )
        return aw, k * has_w.astype(jnp.int32), True, None

    def transition(self, w, aw, window, *, k, select="largest",
                   jitter=1e-10, m_apply=None):
        del m_apply  # Euclidean geometry
        W, AW, theta, _ = extract_next_basis_core(
            w, aw, window.P, window.AP, window.stored, k,
            select=select, jitter=jitter,
        )
        return W, AW, theta, _zero_drift(W)

    def manager_wants_refresh(self, refresh_aw, drift, tol):
        del drift, tol
        return refresh_aw == "exact"


@_register_strategy
@dataclasses.dataclass(frozen=True)
class WindowedRecombine(RecycleStrategy):
    """Zero-matvec windowed refresh with a drift guard.

    The paper's §2.3 accounting made real: both ``W'`` and ``AW'`` come
    from recombining stored columns (one
    :func:`repro.kernels.ops.recombine_blocks` GEMM), the next solve
    deflates with the stale products, and one true matvec re-derives
    ``r₀`` — per-system cost ``iterations + 2`` matvecs, no k-matvec
    refresh.  The transition also measures drift for free (the
    antisymmetric part of the extraction gram ``F``, see
    :func:`harmonic_ritz_flat_core`); when the measured value exceeds
    ``guard`` the NEXT solve pays one full refresh, restoring exact
    deflation before the stale recurrence can destabilize.

    The guard is two-layered, both layers free of speculative matvecs:

    1. *pre-solve* — when the CARRIED drift measurement (the gram
       asymmetry recorded by the previous transition) already exceeds
       ``guard``, :meth:`prepare` refreshes up front with the fused
       multi-RHS pass (persistent-drift fast path);
    2. *in-solve* — ``defcg``'s ``stale_guard``: the stale setup's
       ``‖r_true − r_shortcut‖ = ‖(A·W − AW)c‖`` residual, measured on
       THIS system before the first iteration, triggers a refresh-and-
       redo of the deflated guess.  This is what actually protects a
       system hit by sudden drift — a retrospective signal cannot.

    ``guard`` is measured in units of the solve TOLERANCE: refresh when
    the observed staleness exceeds ``guard × tol``.  That scale is not
    arbitrary — the stale μ-recurrence reinjects un-deflated W-components
    every iteration and the deflated-out spectrum amplifies them
    geometrically (measured on the GP Newton family: staleness ≈ 10×tol
    diverges outright, ≈ tol converges at the exact path's iteration
    count), so "safe to skip the refresh" is exactly "stale error below
    the residual target", whatever the tolerance.  The default keeps a
    10× margin.  ``guard = inf`` never refreshes (the paper's pure cheap
    mode, correct for multiple-RHS sequences); ``guard = 0`` refreshes
    on ANY measured drift.  Both layers floor their thresholds at
    ~500·eps of the working dtype (see :meth:`in_solve_guard`): drift
    below rounding noise is indistinguishable from an unchanged
    operator — where stale products are exact and a refresh buys
    nothing — so even ``guard = 0`` skips the refresh there (and a
    freshly refreshed AW can never re-trigger a second refresh in the
    same solve), while any above-noise drift still pays exactly one
    k-matvec refresh per system.
    """

    guard: float = 0.1

    def in_solve_guard(self, tol: float) -> float:
        """The (static) threshold armed as ``defcg(stale_guard=…)``.

        def-CG additionally floors it at ~500·eps of the WORKING dtype
        (the drift measurement carries rounding-level terms even with an
        exact AW — ~1e-16 in f64, ~1e-7 in f32), so an already-refreshed
        AW can never re-trigger a second k-matvec refresh in the same
        solve — ``guard = 0`` then means "refresh every carried basis
        once", not twice, in either precision.
        """
        return self.guard * tol

    def prepare(self, apply_basis, w, aw_carry, drift, *, k, refresh_aw,
                tol=1e-5, batch_axis=None):
        del refresh_aw  # policy is the guard, not the spec flag
        # Same dtype-aware noise floor as the in-solve guard: the carried
        # gram-asymmetry measurement of an UNCHANGED operator is pure
        # rounding (~eps), and must not buy k-matvec refreshes.
        threshold = _drift_threshold(self.guard, tol, w.dtype)
        has_w = jnp.any(w != 0)
        refresh = has_w & (drift > threshold)
        aw = _gated_basis_apply(apply_basis, refresh, w, aw_carry, batch_axis)
        # exact_aw=False even when the guard just refreshed: the stale
        # branch needs the true-matvec r₀ re-derivation, and the branch
        # choice is traced — one uniformly-safe static code path.
        return aw, k * refresh.astype(jnp.int32), False, self.in_solve_guard(tol)

    def transition(self, w, aw, window, *, k, select="largest",
                   jitter=1e-10, m_apply=None):
        del m_apply
        W, AW, theta, fasym = extract_next_basis_core(
            w, aw, window.P, window.AP, window.stored, k,
            select=select, jitter=jitter,
        )
        return W, AW, theta, fasym.astype(W.dtype)

    def manager_wants_refresh(self, refresh_aw, drift, tol):
        del refresh_aw
        # The host-side mirror of prepare(): same tol-scaled threshold,
        # same dtype noise floor.
        d = jnp.asarray(drift)
        return bool(d > _drift_threshold(self.guard, tol, d.dtype))


@_register_strategy
@dataclasses.dataclass(frozen=True)
class MGeometryHarmonic(RecycleStrategy):
    """Harmonic extraction in the preconditioner's geometry.

    Identical refresh policy to exact :class:`HarmonicRitz` (the point is
    extraction geometry, not refresh accounting), but the transition
    passes the ``M⁻¹`` apply into the grams so θ approximate eigenvalues
    of the EFFECTIVE operator ``M⁻¹A`` — ``select`` then deliberately
    targets what the preconditioner leaves behind, instead of re-deflating
    spectrum the preconditioner already compressed.  Requires a
    preconditioned spec (``SolveSpec`` validation enforces it); with no
    ``M`` at transition time it degrades to the Euclidean extraction.
    """

    def prepare(self, apply_basis, w, aw_carry, drift, *, k, refresh_aw,
                tol=1e-5, batch_axis=None):
        del drift, refresh_aw, tol
        has_w = jnp.any(w != 0)
        aw = _gated_basis_apply(
            apply_basis, has_w, w, jnp.zeros_like(w), batch_axis
        )
        return aw, k * has_w.astype(jnp.int32), True, None

    def transition(self, w, aw, window, *, k, select="largest",
                   jitter=1e-10, m_apply=None):
        W, AW, theta, _ = extract_next_basis_core(
            w, aw, window.P, window.AP, window.stored, k,
            select=select, jitter=jitter, m_apply=m_apply,
        )
        return W, AW, theta, _zero_drift(W)

    def manager_wants_refresh(self, refresh_aw, drift, tol):
        del refresh_aw, drift, tol
        return True

    @property
    def needs_preconditioner(self) -> bool:
        return True
