"""Post-SPMD HLO inspection: while-corrected flops, traffic, collectives.

``compiled.cost_analysis()`` counts every ``while`` body ONCE, regardless
of trip count — for scan-over-layers models that undercounts flops and
collective bytes by ~n_layers×.  XLA leaves the trip count in the HLO
(``backend_config={"known_trip_count":{"n":"48"}}``), so we rebuild the
numbers properly:

  1. split the module into computations and build per-computation symbol
     tables (every def line carries its result shape);
  2. build call-graph multiplicities: ENTRY×1, while bodies × trip count,
     fusion/call/cond sub-computations × caller multiplicity;
  3. per computation, sum
       · dot flops      = 2 · |result| · contracted-dim size (from the
         lhs operand's shape + ``lhs_contracting_dims``),
       · HBM traffic    — SSA-value model over *executable* computations
         (entry + while bodies/conds; fusion bodies are register-internal):
         every materialized result is written once and read ~once
         (2 × result bytes), with in-place ops special-cased
         (dynamic-update-slice ↦ 2 × update-operand bytes, so a KV-cache
         append costs the token slice, not the cache),
       · collective bytes by op kind;
  4. total = Σ multiplicity × per-computation sums.

Post-partition HLO shapes are per-device, so everything here is the
per-chip view the roofline wants.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# ops with no real data movement of their own
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "iota", "partition-id",
}

_SHAPE_ELEM_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"^(?:\(.*?\)|\w+\[[0-9,]*\]\S*)\s+([\w\-]+)[\.\d]*\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
# Single-name callee attributes.  branch_computations={a, b} needs its own
# handling (a findall of this pattern would only surface the FIRST branch);
# true_computation= / false_computation= are the two-way conditional's
# spelling in older HLO text.
_CALLED_RE = re.compile(
    r"(?:body|condition|calls|to_apply|true_computation|false_computation)"
    r"=%?([\w\.\-]+)"
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")


def _callees(line: str) -> List[str]:
    """Every sub-computation a line references — all conditional
    branches included, not just the first."""
    names = _CALLED_RE.findall(line)
    for blk in _BRANCHES_RE.findall(line):
        names.extend(re.findall(r"%?([\w\.\-]+)", blk))
    return names


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_ELEM_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(text: str) -> List[int]:
    m = _SHAPE_ELEM_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        self.symtab: Dict[str, str] = {}  # instr name -> result shape text


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current = None
    entry_re = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
    for line in hlo.splitlines():
        if current is None:
            m = entry_re.match(line.strip())
            if m and line.rstrip().endswith("{"):
                name = m.group(2)
                if m.group(1):
                    name = "__entry__"
                current = Computation(name)
                comps[current.name] = current
            continue
        if line.strip() == "}":
            current = None
            continue
        current.lines.append(line)
        dm = _DEF_RE.match(line)
        if dm:
            name, rest = dm.groups()
            # result shape = leading "(...)" tuple or "dtype[dims]..." token
            if rest.startswith("("):
                depth = 0
                for i, ch in enumerate(rest):
                    depth += ch == "("
                    depth -= ch == ")"
                    if depth == 0:
                        current.symtab[name] = rest[: i + 1]
                        break
            else:
                tok = rest.split(" ", 1)[0]
                current.symtab[name] = tok
    return comps


def _fixpoint_mult(edges, comps) -> Dict[str, float]:
    mult = {name: 0.0 for name in comps}
    mult["__entry__"] = 1.0
    for _ in range(len(comps) + 2):
        nxt = {name: 0.0 for name in comps}
        nxt["__entry__"] = 1.0
        for caller, outs in edges.items():
            m = mult.get(caller, 0.0)
            if not m:
                continue
            for callee, f in outs:
                if callee in nxt:
                    nxt[callee] += m * f
        if nxt == mult:
            break
        mult = nxt
    return mult


def analyze(hlo: str) -> dict:
    """Full while-corrected per-device analysis (see module docstring)."""
    comps = _split_computations(hlo)
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    trips: Dict[str, float] = {}
    for c in comps.values():
        for line in c.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rest = dm.group(2)
            om = _OPNAME_RE.match(rest)
            op = om.group(1) if om else ""
            if op == "while":
                trip = 1.0
                tm = _TRIP_RE.search(line)
                if tm:
                    trip = float(tm.group(1))
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                cm = re.search(r"condition=%?([\w\.\-]+)", line)
                if bm:
                    edges[c.name].append((bm.group(1), trip))
                    trips[bm.group(1)] = trip
                if cm:
                    edges[c.name].append((cm.group(1), trip))
            else:
                # Conditional branches all get multiplicity 1 — a
                # worst-case upper bound (XLA executes one per visit);
                # previously only the first branch was even counted.
                for callee in _callees(line):
                    edges[c.name].append((callee, 1.0))
    mult = _fixpoint_mult(edges, comps)

    # Executable computations: entry + (transitively) while bodies/conds.
    # Everything else reached via calls=/to_apply= is a fusion/reducer body
    # whose intermediates never hit HBM.
    executable = {"__entry__"}
    frontier = ["__entry__"]
    while_edges: Dict[str, List[str]] = defaultdict(list)
    for c in comps.values():
        for line in c.lines:
            if " while(" in line:
                for pat in (r"body=%?([\w\.\-]+)", r"condition=%?([\w\.\-]+)"):
                    m2 = re.search(pat, line)
                    if m2:
                        while_edges[c.name].append(m2.group(1))
    while frontier:
        name = frontier.pop()
        for callee in while_edges.get(name, []):
            if callee not in executable:
                executable.add(callee)
                frontier.append(callee)

    flops = 0.0
    traffic = 0.0
    coll: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0.0, "bytes": 0.0}
    )
    coll_items: List[dict] = []
    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if not m:
            continue
        for line in c.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            iname, rest = dm.groups()
            om = _OPNAME_RE.match(rest)
            op = om.group(1) if om else ""
            rshape = c.symtab.get(iname, "")

            if op == "dot":
                # lhs operand: newer XLA prints the shape inline
                # (``dot(f32[64,64]{1,0} %x, ...)``); older text has only
                # ``%x`` and needs the symbol-table lookup.
                cd_m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
                args_m = re.search(r"\(([^)]*)\)", rest)
                lhs_text = args_m.group(1).split(", ")[0] if args_m else ""
                dims = _shape_dims(lhs_text)
                if not dims:
                    ref = _OPERANDS_RE.search(lhs_text)
                    if ref:
                        dims = _shape_dims(c.symtab.get(ref.group(1), ""))
                contract = 1
                if cd_m:
                    for idx in cd_m.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            contract *= dims[int(idx)]
                relems = 1
                for d in _shape_dims(rshape):
                    relems *= d
                flops += m * 2.0 * relems * contract

            op_base = op
            if op_base.endswith("-start"):
                op_base = op_base[: -len("-start")]
            if op_base in COLLECTIVE_OPS and not op.endswith("-done"):
                b = _shape_bytes(rshape)
                if op.endswith("-start") and rshape.startswith("("):
                    # An async start's result tuple aliases the operand
                    # next to the destination buffer — halve so the
                    # -start/-done pair is charged ONE payload.
                    b //= 2
                coll[op_base]["count"] += m
                coll[op_base]["bytes"] += m * b
                coll_items.append(
                    {
                        "op": op_base, "shape": rshape[:90], "mult": m,
                        "bytes": m * b, "comp": c.name[:40],
                        "meta": (
                            re.search(r'op_name="([^"]*)"', rest).group(1)[:110]
                            if 'op_name="' in rest else ""
                        ),
                    }
                )

            if (
                c.name in executable
                and op not in _NO_TRAFFIC
                and op != ""
            ):
                if op == "dynamic-update-slice":
                    # in-place: traffic = the update slice, not the buffer
                    arg_m = re.search(r"\(([^)]*)\)", rest)
                    refs = (
                        _OPERANDS_RE.findall(arg_m.group(1)) if arg_m else []
                    )
                    upd = (
                        _shape_bytes(c.symtab.get(refs[1], ""))
                        if len(refs) > 1 else 0
                    )
                    traffic += m * 2.0 * upd
                else:
                    traffic += m * 2.0 * _shape_bytes(rshape)

    coll_items.sort(key=lambda x: -x["bytes"])
    return {
        "flops": flops,
        "traffic_bytes": traffic,
        "collectives": {k: dict(v) for k, v in coll.items()},
        "top_collectives": coll_items[:12],
        "while_trips": trips,
        "n_computations": len(comps),
    }


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """While-corrected collective census (back-compat wrapper)."""
    return analyze(hlo_text)["collectives"]


def op_census(hlo_text: str, ops=("fusion", "custom-call", "while", "sort")):
    out = {}
    for op in ops:
        out[op] = len(re.findall(rf"=\s*[^=]*\b{op}[.\d]*\(", hlo_text))
    return out


# ---------------------------------------------------------------------------
# Static collective counting — the sharded engine's communication gates
# ---------------------------------------------------------------------------


def _line_collective(rest: str) -> str:
    """The collective base op a definition line holds, else ``""``.

    An async pair counts ONCE: the ``-start`` carries the payload and is
    counted; the matching ``-done`` is skipped.  (CPU HLO emits the plain
    sync form, GPU/TPU pipelines emit the async pair — both spell one
    collective.)
    """
    om = _OPNAME_RE.match(rest)
    if not om:
        return ""
    op = om.group(1)
    if op.endswith("-done"):
        return ""
    base = op[: -len("-start")] if op.endswith("-start") else op
    return base if base in COLLECTIVE_OPS else ""


def count_collectives(hlo: str) -> Dict[str, int]:
    """Static per-module collective instruction census.

    Counts each collective op kind across ALL computations of the module
    text — no multiplicity weighting (use :func:`analyze` for the
    while-corrected dynamic view).  One ``-start``/``-done`` async pair
    counts as ONE collective.
    """
    counts: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo.splitlines():
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        base = _line_collective(dm.group(2))
        if base:
            counts[base] += 1
    return counts


def while_body_collectives(hlo: str) -> Dict[str, Dict[str, int]]:
    """Per-while-body collective counts — the one-all-reduce-per-iteration
    gate of the sharded engine (DESIGN.md §5).

    For every ``while`` body in the module, counts the collectives the
    body executes per iteration, descending transitively through
    ``calls=``/``to_apply=`` and conditional branches (ALL branches — a
    worst-case per-iteration bound) but NOT into nested ``while`` bodies:
    a nested loop's per-iteration cost is its own row of the result.

    Returns ``{body_name: {op: count}}`` with async ``-start``/``-done``
    pairs counted once.  The sharded def-CG while body must show exactly
    ``{"all-reduce": 1}`` (plus the matvec's gather); the test suite
    pins it via :func:`repro.core.sharded.lower_sharded`.
    """
    comps = _split_computations(hlo)
    bodies: List[str] = []
    for c in comps.values():
        for line in c.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            om = _OPNAME_RE.match(dm.group(2))
            if om and om.group(1) == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", line)
                if bm:
                    bodies.append(bm.group(1))

    def count_comp(name: str, seen: set) -> Dict[str, int]:
        c = comps.get(name)
        totals: Dict[str, int] = defaultdict(int)
        if c is None:
            return totals
        for line in c.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rest = dm.group(2)
            om = _OPNAME_RE.match(rest)
            op = om.group(1) if om else ""
            if op == "while":
                continue  # nested loop: charged to its own body's row
            base = _line_collective(rest)
            if base:
                totals[base] += 1
            for callee in _callees(line):
                if callee in seen:
                    continue
                seen.add(callee)
                for k, v in count_comp(callee, seen).items():
                    totals[k] += v
        return totals

    return {name: dict(count_comp(name, {name})) for name in bodies}
