"""Production training driver: mesh + shardings + fault-tolerant loop.

Usage (CPU demo / real cluster):
  python -m repro.launch.train --arch qwen1.5-0.5b --preset smoke --steps 200
  python -m repro.launch.train --arch qwen3-8b --preset full \
      --mesh single --batch 256 --seq 4096          # on a real 256-chip pod

On ≥256 devices it builds the production mesh and shards params (TP +
ZeRO), batches (DP) and optimizer state exactly as the dry-run proves out;
on fewer devices it falls back to a 1×N data-parallel mesh so the same
code path runs anywhere.  The Trainer provides checkpoint/restart,
straggler tracking, and preemption handling (repro/runtime).
"""

from __future__ import annotations

import argparse
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import models
from repro.configs import get_config, get_smoke_config
from repro.data import TokenPipeline
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import sharding as shd_env
from repro.runtime import Trainer, TrainerConfig


def make_mesh_auto():
    n = len(jax.devices())
    if n >= 512:
        return mesh_lib.make_production_mesh(multi_pod=True)
    if n >= 256:
        return mesh_lib.make_production_mesh(multi_pod=False)
    return jax.make_mesh((n, 1), ("data", "model"))


def build(arch: str, preset: str, batch: int, seq: int, lr: float):
    cfg = get_config(arch) if preset == "full" else get_smoke_config(arch)
    mesh = make_mesh_auto()
    env = mesh_lib.axis_env_for(mesh, batch_shardable=True)
    shd_env.set_axis_env(env)
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)

    params = jax.jit(
        lambda k: models.init(k, cfg, tp=tp),
        out_shardings=mesh_lib.param_shardings(
            mesh,
            jax.eval_shape(
                lambda k: models.init(k, cfg, tp=tp),
                jax.ShapeDtypeStruct((2,), jnp.uint32),
            ),
            env,
        ),
    )(jax.random.PRNGKey(0))
    opt = steps_lib.init_opt_state(params)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, batch=batch, seq_len=seq)

    train_step = steps_lib.make_train_step(cfg, lr=lr)

    @jax.jit
    def step_fn(state, batch):
        params, opt = state
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = train_step(params, opt, batch)
        return (params, opt), metrics

    return cfg, mesh, (params, opt), pipe, step_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg, mesh, state, pipe, step_fn = build(
        args.arch, args.preset, args.batch, args.seq, args.lr
    )
    print(
        f"arch={cfg.name} devices={len(jax.devices())} "
        f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
        f"params={cfg.total_params()/1e6:.1f}M"
    )

    losses = []

    def logging_step(state, batch):
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if len(losses) % 20 == 0:
            first = np.mean(losses[:10])
            print(
                f"step {len(losses):5d} loss {losses[-1]:.4f} "
                f"(first10 {first:.4f})",
                flush=True,
            )
        return state, metrics

    trainer = Trainer(
        logging_step,
        pipe.make_batch,
        state,
        TrainerConfig(
            total_steps=args.steps,
            checkpoint_every=args.ckpt_every,
            checkpoint_dir=args.ckpt_dir,
        ),
    )
    out = trainer.run()
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(
        f"done: {out['final_step']} steps, loss {first:.4f} -> {last:.4f} "
        f"({'LEARNED' if last < first - 0.1 else 'no clear drop'}) "
        f"restarts={out['events'].restarts} stragglers={out['events'].stragglers}"
    )


if __name__ == "__main__":
    main()
