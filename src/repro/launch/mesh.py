"""Production mesh construction + sharding-rule binding.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single-pod: ``(16, 16) = ("data", "model")`` — 256
chips.  Multi-pod: ``(2, 16, 16) = ("pod", "data", "model")`` — 512 chips;
``pod`` is a second data-parallel axis (inter-pod gradient all-reduce over
DCI, intra-pod reduce-scatter over ICI).

Logical-axis bindings (see models/sharding.py):

* ``batch`` → ("pod", "data")   activations' batch dim
* ``model`` → "model"           tensor parallel
* ``fsdp``  → ("pod", "data")   ZeRO-3 parameter/optimizer sharding: every
  ≥2-D parameter shards one eligible dim across the DP axes; XLA SPMD
  inserts the per-layer all-gather (forward) and reduce-scatter (backward)
  — without this the 480B configs cannot fit 16 GB/chip (DESIGN.md §5)
* ``seq``   → "data"            sequence sharding for batch-1 long decode

`long_500k` (global_batch=1) rebinds ``batch → None`` and shards the
KV-cache/sequence dim over ``data`` instead.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import sharding as shd


def make_solve_mesh(n_devices: Optional[int] = None) -> Mesh:
    """The 1-D solver mesh: ``(n,) = ("solve",)`` over whatever exists.

    The axis every length-n dimension of the sharded Krylov engine
    (:mod:`repro.core.sharded`) shards over — solve vectors ``P("solve")``,
    ``(k, n)`` recycle bases ``P(None, "solve")``, operator data rows
    ``P("solve", ...)``.  Unlike :func:`make_production_mesh` there is no
    hard device-count requirement: ``n_devices=None`` takes every device
    jax sees (1 on a laptop CPU, 8 under
    ``xla_force_host_platform_device_count=8``, a full slice on TPU);
    an explicit count takes the first ``n_devices`` of them.
    """
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"n_devices={n_devices} out of range: this process has "
            f"{len(devices)} devices"
        )
    return jax.make_mesh((n,), ("solve",), devices=devices[:n])


def solve_state_shardings(mesh: Mesh) -> Any:
    """NamedSharding pytree for a :class:`repro.core.recycle.RecycleState`
    on the solve mesh — W/AW column-sharded along n, scalars replicated
    (the PartitionSpec rules live in :func:`repro.core.sharded.recycle_state_specs`)."""
    from repro.core import sharded as sharded_mod
    from repro.core.recycle import RecycleState

    s = sharded_mod.recycle_state_specs()
    # Explicit construction — PartitionSpec is a tuple subclass, so a
    # tree_map over a spec-valued pytree would descend into the specs.
    return RecycleState(
        W=NamedSharding(mesh, s.W),
        AW=NamedSharding(mesh, s.AW),
        theta=NamedSharding(mesh, s.theta),
        systems_solved=NamedSharding(mesh, s.systems_solved),
        drift=NamedSharding(mesh, s.drift),
    )


def solve_vector_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding of a flat length-n solve vector on the solve mesh."""
    from repro.core import sharded as sharded_mod

    return NamedSharding(mesh, sharded_mod.vector_spec())


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under dryrun.py (it sets xla_force_host_platform_device_count)"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def axis_env_for(mesh: Mesh, *, batch_shardable: bool = True) -> Dict[str, Any]:
    """Logical-name binding for a mesh (see module docstring)."""
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    env: Dict[str, Any] = {
        "model": "model",
        "fsdp": dp_axes,
        "seq": None,
        "batch": dp_axes if batch_shardable else None,
    }
    if not batch_shardable:
        env["seq"] = "data"
    return env


def bind(mesh: Mesh, *, batch_shardable: bool = True) -> Dict[str, Any]:
    env = axis_env_for(mesh, batch_shardable=batch_shardable)
    shd.set_axis_env(env)
    return env


# ---------------------------------------------------------------------------
# Parameter sharding with ZeRO (fsdp) augmentation
# ---------------------------------------------------------------------------


def _fsdp_augment(spec: P, shape, env, stacked: bool) -> P:
    """Shard the first un-sharded, divisible dim of a ≥2-D leaf over fsdp.

    The stacked periods axis (dim 0 of scan-stacked leaves) is excluded:
    sharding the scan axis would force a full-stack all-gather every scan
    step instead of a per-layer one.
    """
    fsdp = env.get("fsdp")
    if not fsdp or len(shape) < 2:
        return spec
    size = int(np.prod([_axis_len(a) for a in fsdp])) if fsdp else 1
    dims = list(spec) + [None] * (len(shape) - len(spec))
    start = 1 if stacked else 0
    for i in range(start, len(dims)):
        if dims[i] is None and shape[i] % size == 0 and shape[i] >= size:
            dims[i] = fsdp
            return P(*dims)
    return spec


_AXIS_SIZES: Dict[str, int] = {}


def _axis_len(name: str) -> int:
    return _AXIS_SIZES.get(name, 1)


def param_shardings(mesh: Mesh, params_shapes, env) -> Any:
    """NamedSharding tree for a (possibly abstract) parameter tree."""
    global _AXIS_SIZES
    _AXIS_SIZES = dict(zip(mesh.axis_names, mesh.devices.shape))

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            out = [walk(v, path + (str(i),)) for i, v in enumerate(tree)]
            return type(tree)(out)
        stacked = any(p == "periods" for p in path)
        name = path[-1]
        ndim = len(tree.shape)
        base = _resolve_spec(name, ndim, stacked, env)
        full = _fsdp_augment(base, tree.shape, env, stacked)
        return NamedSharding(mesh, full)

    return walk(params_shapes, ())


def _resolve_spec(name: str, ndim: int, stacked: bool, env) -> P:
    dims: tuple = ()
    for suffix, d in shd._SUFFIX_DIMS.items():
        if name.endswith(suffix):
            dims = d
            break
    pad = ndim - len(dims) - (1 if stacked else 0)
    full = ((None,) if stacked else ()) + (None,) * max(pad, 0) + dims
    return P(*[env.get(d) if d else None for d in full[:ndim]])


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def batch_shardings(mesh: Mesh, batch_shapes, env) -> Any:
    """Shard (B, ...) input batches over the DP axes (dim 0)."""

    def one(leaf):
        b = env.get("batch")
        if b and len(leaf.shape) >= 1:
            size = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in (b if isinstance(b, tuple) else (b,))]))
            if leaf.shape[0] % size == 0:
                return NamedSharding(mesh, P(b, *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(one, batch_shapes)


def decode_state_shardings(mesh: Mesh, state_shapes, env) -> Any:
    """NamedShardings for a DecodeState (KV caches / SSM states / memory).

    Rules (leaf path → spec), with batch = env["batch"], seq = env["seq"]:
      *.ssd     (np, B, H, P, N)  → (None, batch, model, None, None)
      *.conv    (np, B, K-1, C)   → (None, batch, None, model)
      memory.*  (np, B, Hkv, S, d)→ (None, batch, None, None, None)
      cache k/v (np, B, Hkv, S, d)→ (None, batch, None, seq, None)
      length                       → replicated
    """

    def rule(path, leaf):
        ks = jax.tree_util.keystr(path)
        ndim = len(leaf.shape)
        batch = env.get("batch")
        seq = env.get("seq")
        if ".length" in ks or ndim == 0:
            return NamedSharding(mesh, P())
        if ".ssd" in ks:
            spec = (None, batch, "model", None, None)
        elif ".conv" in ks:
            spec = (None, batch, None, "model")
        elif "memory" in ks:
            spec = (None, batch, None, None, None)
        else:  # KV cache k / v
            spec = (None, batch, None, seq, None)
        spec = spec[:ndim]
        # drop axes that don't divide evenly (e.g. B=1 long decode)
        fixed = []
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([sizes[a] for a in axes]))
            fixed.append(ax if dim % total == 0 else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(rule, state_shapes)
