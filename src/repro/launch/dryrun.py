import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.

For each cell we build abstract params/optimizer/batch/cache trees
(``jax.eval_shape`` — nothing is allocated), attach the production
shardings, ``jit(...).lower(...).compile()`` the step, and record:

* ``memory_analysis()``  — per-device bytes (proves the config fits);
* ``cost_analysis()``    — HLO FLOPs / bytes for §Roofline;
* collective op census + bytes parsed from the partitioned HLO;
* MODEL_FLOPS (6·N_active·D or 2·N_active·D) for the usefulness ratio.

Artifacts go to ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` and are
skipped when already present (incremental; delete to re-run).

Usage:
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh multi
"""

import argparse
import gc
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import models
from repro.configs.registry import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch import hlo_stats
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import sharding as shd_env

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")


def _use_mesh(mesh):
    try:
        return jax.sharding.use_mesh(mesh)
    except AttributeError:  # older jax
        return mesh


def _memory_dict(mem) -> dict:
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        if hasattr(mem, attr):
            try:
                out[attr] = int(getattr(mem, attr))
            except Exception:  # noqa: BLE001
                pass
    if not out:
        out["repr"] = str(mem)
    return out


def _cost_dict(cost) -> dict:
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    keep = ("flops", "bytes accessed", "transcendentals", "optimal_seconds")
    return {
        k: float(v)
        for k, v in dict(cost).items()
        if isinstance(v, (int, float)) and k in keep
    }


def run_gpc_cell(multi_pod: bool, outdir: str, force: bool = False,
                 replicate_x: bool = False) -> dict:
    """The paper's own workload (GPC def-CG iteration at n=2^20) as a cell."""
    from repro.configs.gpc_mnist import CONFIG as GPC
    from repro.launch import gpc_dryrun

    mesh_name = "multi" if multi_pod else "single"
    variant = "newton_1m_optx" if replicate_x else "newton_1m"
    tag = f"gpc-mnist__{variant}__{mesh_name}"
    path = os.path.join(outdir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    record = {
        "arch": "gpc-mnist", "shape": variant, "mesh": mesh_name,
        "chips": 512 if multi_pod else 256, "status": "pending",
        "note": "one def-CG(8) iteration; scale by measured iteration counts",
    }
    try:
        t0 = time.time()
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
        lowered = gpc_dryrun.lower_cell(GPC, mesh, replicate_x=replicate_x)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        record["lower_s"] = round(t_lower, 2)
        record["compile_s"] = round(time.time() - t0, 2)
        record["memory"] = _memory_dict(compiled.memory_analysis())
        record["cost"] = _cost_dict(compiled.cost_analysis())
        hlo = compiled.as_text()
        analysis = hlo_stats.analyze(hlo)
        record["hlo_flops_per_device"] = analysis["flops"]
        record["hlo_traffic_bytes_per_device"] = analysis["traffic_bytes"]
        record["collectives"] = analysis["collectives"]
        record["top_collectives"] = analysis["top_collectives"]
        record["while_trips"] = analysis["while_trips"]
        record["op_census"] = hlo_stats.op_census(hlo)
        record["model_flops"] = gpc_dryrun.model_flops(GPC)
        record["status"] = "ok"
        del hlo, compiled, lowered
    except Exception as exc:  # noqa: BLE001
        record["status"] = "error"
        record["error"] = f"{type(exc).__name__}: {exc}"
        record["traceback"] = traceback.format_exc()[-4000:]
    finally:
        gc.collect()
    _write(path, record)
    return record


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             force: bool = False) -> dict:
    if arch == "gpc-mnist":
        return run_gpc_cell(multi_pod, outdir, force)
    if arch == "gpc-mnist-optx":
        return run_gpc_cell(multi_pod, outdir, force, replicate_x=True)
    mesh_name = "multi" if multi_pod else "single"
    tag = f"{arch}__{shape_name}__{mesh_name}".replace("/", "_")
    path = os.path.join(outdir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": 512 if multi_pod else 256, "status": "pending",
    }
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        record.update(status="skipped", reason=why)
        _write(path, record)
        return record

    try:
        t0 = time.time()
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
        dp = 1
        for a, s in zip(mesh.axis_names, mesh.devices.shape):
            if a in ("pod", "data"):
                dp *= s
        tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        batch_ok = shape.global_batch % dp == 0
        env = mesh_lib.axis_env_for(mesh, batch_shardable=batch_ok)
        shd_env.set_axis_env(env)

        key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)
        params_s = jax.eval_shape(
            lambda k: models.init(k, cfg, tp=tp), key_s
        )
        p_shard = mesh_lib.param_shardings(mesh, params_s, env)
        batch_s = steps_lib.input_specs(cfg, shape)
        b_shard = mesh_lib.batch_shardings(mesh, batch_s, env)

        moment_dtype = jnp.bfloat16 if cfg.total_params() > 1e11 else jnp.float32
        record["moment_dtype"] = str(jnp.dtype(moment_dtype))

        with _use_mesh(mesh):
            if shape.kind == "train":
                opt_s = jax.eval_shape(
                    lambda p: steps_lib.init_opt_state(p, moment_dtype),
                    params_s,
                )
                opt_shard = type(opt_s)(
                    mu=p_shard, nu=p_shard,
                    count=mesh_lib.replicated(mesh),
                )
                step = steps_lib.make_train_step(cfg)
                jitted = jax.jit(
                    step,
                    in_shardings=(p_shard, opt_shard, b_shard),
                    out_shardings=(p_shard, opt_shard, None),
                    donate_argnums=(0, 1),
                )
                lowered = jitted.lower(params_s, opt_s, batch_s)
            elif shape.kind == "prefill":
                state_s = jax.eval_shape(
                    lambda: models.init_decode_state(
                        cfg, shape.global_batch, max_len=shape.seq_len
                    )
                )
                st_shard = mesh_lib.decode_state_shardings(mesh, state_s, env)
                step = steps_lib.make_prefill_step(cfg, shape.seq_len)
                jitted = jax.jit(
                    step,
                    in_shardings=(p_shard, b_shard, st_shard),
                    out_shardings=None,
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(params_s, batch_s, state_s)
            else:  # decode
                if cfg.is_encdec:
                    _, build_state = steps_lib.decode_state_specs(cfg, shape)
                    state_s = jax.eval_shape(build_state, params_s)
                else:
                    state_s, _ = steps_lib.decode_state_specs(cfg, shape)
                st_shard = mesh_lib.decode_state_shardings(mesh, state_s, env)
                step = steps_lib.make_serve_step(cfg)
                jitted = jax.jit(
                    step,
                    in_shardings=(p_shard, b_shard["tokens"], st_shard),
                    out_shardings=None,
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(
                    params_s, batch_s["tokens"], state_s
                )
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        record["lower_s"] = round(t_lower, 2)
        record["compile_s"] = round(t_compile, 2)
        record["memory"] = _memory_dict(compiled.memory_analysis())
        record["cost"] = _cost_dict(compiled.cost_analysis())

        hlo = compiled.as_text()
        analysis = hlo_stats.analyze(hlo)
        # per-device, while-trip-corrected (see hlo_stats docstring)
        record["hlo_flops_per_device"] = analysis["flops"]
        record["hlo_traffic_bytes_per_device"] = analysis["traffic_bytes"]
        record["collectives"] = analysis["collectives"]
        record["top_collectives"] = analysis["top_collectives"]
        record["while_trips"] = analysis["while_trips"]
        record["op_census"] = hlo_stats.op_census(hlo)
        record["hlo_bytes"] = len(hlo)
        del hlo, analysis, compiled, lowered, jitted

        record["model_flops"] = steps_lib.model_flops(cfg, shape)
        record["active_params"] = cfg.active_params()
        record["total_params"] = cfg.total_params()
        record["status"] = "ok"
    except Exception as exc:  # noqa: BLE001
        record["status"] = "error"
        record["error"] = f"{type(exc).__name__}: {exc}"
        record["traceback"] = traceback.format_exc()[-4000:]
    finally:
        shd_env.set_axis_env(None)
        gc.collect()

    _write(path, record)
    return record


def _write(path: str, record: dict):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--outdir", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    archs = (
        list(ARCH_IDS) + ["gpc-mnist"]
        if (args.all or args.arch is None)
        else [args.arch]
    )
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = (
        [False, True] if args.mesh == "both"
        else [args.mesh == "multi"]
    )

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape, multi, args.outdir, args.force)
                line = (
                    f"{rec['arch']:24s} {rec['shape']:12s} "
                    f"{rec['mesh']:6s} {rec['status']:7s}"
                )
                if rec["status"] == "ok":
                    line += (
                        f" flops={rec['cost'].get('flops', 0):.3e}"
                        f" compile={rec.get('compile_s', 0):.0f}s"
                    )
                elif rec["status"] == "error":
                    n_fail += 1
                    line += " " + rec.get("error", "")[:120]
                print(line, flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
