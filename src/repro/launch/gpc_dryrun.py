"""GPC (paper-workload) dry-run cell: one def-CG iteration at n = 2²⁰.

The paper's own system at pod scale: GP-classification Newton systems
``A = I + H½KH½`` with n = 1M data points.  The fused Gram matvec is
distributed by ``shard_map``: X rows live replicated (1M×784 f32 ≈ 3.3 GB,
fits HBM), the CG vectors are row-sharded across *all* 256/512 chips
(data × model axes flattened), and each chip computes its row-block of
``K·v`` against the full X with the same blocking as the Pallas kernel.
CG's inner products become single f32-scalar psums — the collective
pattern of distributed CG is two scalar all-reduces + one 4 MB
all-gather (of v) per iteration.

Because the def-CG while-loop has a *dynamic* trip count (convergence),
XLA cannot annotate ``known_trip_count`` — so we lower exactly ONE
deflated-CG iteration (matvec + deflation GEMVs + AXPYs) and the roofline
scales it by the measured iteration counts from the CPU benchmark
(EXPERIMENTS.md §Paper-validation).  Invoked from dryrun.py via
``--arch gpc-mnist``.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.gpc_mnist import GPCConfig


def row_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)  # rows sharded over every axis


def make_defcg_iteration(cfg: GPCConfig, mesh: Mesh,
                         replicate_x: bool = False):
    """One def-CG(k) iteration: Ap, α, x/r updates, μ-solve, p update.

    ``replicate_x``: §Perf iteration — X is loop-invariant, so gathering
    it per matvec (baseline: 3.3 GB all-gather/iteration) is pure waste;
    keeping X replicated (3.3 GB of HBM, fits v5e) removes the gather and
    leaves a single 4 MB v-gather + two scalar psums per iteration.
    """
    rows = row_axes(mesh)
    block = cfg.block
    x_spec = P(None, None) if replicate_x else P(rows, None)

    @functools.partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(x_spec, P(rows)),
        out_specs=P(rows),
    )
    def gram_matvec_local(x_in, v_local):
        # gather v (4 MB) once; row-block of exp-distances vs full X is
        # recomputed in VMEM-sized chunks — fused-Gram blocking (kernels/).
        v_full = jax.lax.all_gather(v_local, rows, tiled=True)
        if replicate_x:
            x_full = x_in
            n_dev = mesh.devices.size
            shard = x_in.shape[0] // n_dev
            idx = jax.lax.axis_index(rows) * shard
            x_local = jax.lax.dynamic_slice_in_dim(x_in, idx, shard, 0)
        else:
            x_full = jax.lax.all_gather(x_in, rows, tiled=True)
            x_local = x_in
        sq_l = jnp.sum(x_local * x_local, axis=1, keepdims=True)

        nb = x_full.shape[0] // block

        def body(acc, j):
            xb = jax.lax.dynamic_slice_in_dim(x_full, j * block, block, 0)
            vb = jax.lax.dynamic_slice_in_dim(v_full, j * block, block, 0)
            sq_b = jnp.sum(xb * xb, axis=1)[None, :]
            d2 = jnp.maximum(sq_l + sq_b - 2.0 * (x_local @ xb.T), 0.0)
            return acc + jnp.exp(-0.5 * d2) @ vb, None

        acc0 = v_local * 0.0  # varying-axes-correct zero under shard_map
        acc, _ = jax.lax.scan(body, acc0, jnp.arange(nb))
        return acc

    def a_matvec(x_data, sqrt_h, v):
        return v + sqrt_h * gram_matvec_local(x_data, sqrt_h * v)

    def defcg_iteration(x_data, sqrt_h, state):
        """state = (x, r, p, rs, W, AW, waw_inv) — one Alg.-1 iteration."""
        xv, r, p, rs, W, AW, waw_inv = state
        ap = a_matvec(x_data, sqrt_h, p)
        d = jnp.vdot(p, ap)  # psum under the hood
        alpha = rs / d
        xv = xv + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        beta = rs_new / rs
        mu = waw_inv @ (AW @ r)  # (k,n)@(n,) — deflation GEMV + k×k solve
        p = beta * p + r - W.T @ mu
        return (xv, r, p, rs_new, W, AW, waw_inv)

    return defcg_iteration


def input_specs(cfg: GPCConfig, mesh: Mesh):
    n, d, k = cfg.n, cfg.d, cfg.k
    f32 = jnp.float32 if cfg.dtype == "float32" else jnp.float64
    sds = jax.ShapeDtypeStruct
    x_data = sds((n, d), f32)
    sqrt_h = sds((n,), f32)
    state = (
        sds((n,), f32), sds((n,), f32), sds((n,), f32), sds((), f32),
        sds((k, n), f32), sds((k, n), f32), sds((k, k), f32),
    )
    return x_data, sqrt_h, state


def shardings(cfg: GPCConfig, mesh: Mesh, replicate_x: bool = False):
    rows = row_axes(mesh)
    rs = NamedSharding(mesh, P(rows))
    xs = NamedSharding(mesh, P(None, None) if replicate_x else P(rows, None))
    rep = NamedSharding(mesh, P())
    basis = NamedSharding(mesh, P(None, rows))
    state = (rs, rs, rs, rep, basis, basis, rep)
    return xs, rs, state


def lower_cell(cfg: GPCConfig, mesh: Mesh, replicate_x: bool = False):
    it = make_defcg_iteration(cfg, mesh, replicate_x=replicate_x)
    x_s, h_s, st_s = input_specs(cfg, mesh)
    x_sh, h_sh, st_sh = shardings(cfg, mesh, replicate_x=replicate_x)
    jitted = jax.jit(
        it,
        in_shardings=(x_sh, h_sh, st_sh),
        out_shardings=st_sh,
        donate_argnums=(2,),
    )
    return jitted.lower(x_s, h_s, st_s)


def model_flops(cfg: GPCConfig) -> float:
    """Useful flops of one def-CG iteration: the fused Gram matvec."""
    return 2.0 * cfg.n * cfg.n * cfg.d + 6.0 * cfg.n * cfg.n
