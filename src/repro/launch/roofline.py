"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell, three per-step time bounds on TPU v5e:

    t_compute = FLOPs_per_device            / 197e12        [bf16 MXU peak]
    t_memory  = HBM_bytes_per_device        / 819e9         [HBM bandwidth]
    t_coll    = Σ collective_bytes·α(op)    / (links·50e9)  [ICI]

FLOPs/traffic come from the while-trip-corrected HLO analysis
(`hlo_stats.analyze`) — per-device, post-SPMD shapes.  The collective
model: per-device op bytes ``s`` move α·s bytes over the slowest link,
α(all-reduce)=2 (reduce+broadcast phases), α(others)=1; `links`
conservatively 1 of the chip's ICI links is assumed serialized per op
(v5e has 4 links/chip; overlap credit is a hillclimb, not an assumption).

The memory term is reported twice: as measured from the compiled XLA-path
HLO, and with the **Pallas credit** — the flash-attention / fused-Gram
kernels keep block scores in VMEM, so their HBM traffic is removed when
estimating the deployed (kernel-enabled) bound.

Dominant term = bottleneck; MODEL_FLOPS / HLO_FLOPS is the useful-compute
ratio (catches remat + head-padding + capacity-factor waste).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 per chip, TPU v5e
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

ALPHA = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def load_artifacts(art_dir: str) -> List[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def roofline_terms(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["chips"]
    flops_dev = rec.get("hlo_flops_per_device", 0.0)
    traffic_dev = rec.get("hlo_traffic_bytes_per_device", 0.0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = traffic_dev / HBM_BW

    t_coll = 0.0
    coll_bytes = 0.0
    for op, st in rec.get("collectives", {}).items():
        t_coll += ALPHA.get(op, 1.0) * st["bytes"] / LINK_BW
        coll_bytes += st["bytes"]

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]

    model_flops_dev = rec.get("model_flops", 0.0) / chips
    useful_ratio = model_flops_dev / flops_dev if flops_dev else 0.0
    # roofline fraction: useful flops per chip over peak, at the bound time
    frac = model_flops_dev / PEAK_FLOPS / bound if bound > 0 else 0.0

    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops": rec.get("model_flops", 0.0),
        "hlo_flops_total": flops_dev * chips,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": frac,
        "collective_bytes_per_dev": coll_bytes,
        "moment_dtype": rec.get("moment_dtype"),
    }


def what_would_help(t: dict) -> str:
    if t["dominant"] == "compute":
        if t["useful_flops_ratio"] < 0.5:
            return (
                "compute-bound with low useful ratio — cut remat recompute "
                "/ head-padding / capacity-factor waste"
            )
        return "compute-bound — already near the right wall; larger per-chip tiles"
    if t["dominant"] == "memory":
        return (
            "memory-bound — enable Pallas kernels (scores stay in VMEM), "
            "raise arithmetic intensity (bigger blocks, fused ops, bf16 temps)"
        )
    return (
        "collective-bound — reshard to cut all-gathers (keep activations "
        "model-sharded through residual), overlap via async collectives"
    )


def table(art_dir: str, mesh: Optional[str] = "single") -> str:
    rows = []
    for rec in load_artifacts(art_dir):
        if mesh and rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                f"skipped — {rec['reason'][:48]} ||||||"
            )
            continue
        t = roofline_terms(rec)
        if t is None:
            rows.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
                f"ERROR {rec.get('error', '')[:48]} ||||||"
            )
            continue
        rows.append(
            "| {arch} | {shape} | {mesh} | {tc:.4f} | {tm:.4f} | {tl:.4f} "
            "| **{dom}** | {ur:.2f} | {rf:.1%} |".format(
                arch=t["arch"], shape=t["shape"], mesh=t["mesh"],
                tc=t["t_compute_s"], tm=t["t_memory_s"],
                tl=t["t_collective_s"], dom=t["dominant"],
                ur=t["useful_flops_ratio"], rf=t["roofline_fraction"],
            )
        )
    header = (
        "| arch | shape | mesh | t_compute [s] | t_memory [s] | "
        "t_collective [s] | bottleneck | useful-flops ratio | "
        "roofline fraction |\n|---|---|---|---|---|---|---|---|---|"
    )
    return header + "\n" + "\n".join(rows)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--artifacts",
        default=os.path.abspath(
            os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")
        ),
    )
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "all"])
    args = ap.parse_args()
    mesh = None if args.mesh == "all" else args.mesh
    print(table(args.artifacts, mesh))
    print()
    for rec in load_artifacts(args.artifacts):
        if mesh and rec.get("mesh") != mesh:
            continue
        t = roofline_terms(rec)
        if t:
            print(
                f"{t['arch']:24s} {t['shape']:12s} -> {what_would_help(t)}"
            )


if __name__ == "__main__":
    main()
