"""Step functions and abstract input specs for every (arch × shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, zero allocation — which is
what the dry-run lowers against.  ``make_*_step`` build the jit-able step
callables:

* ``train_step``  (train_4k)    — loss → grad → AdamW update;
* ``prefill_step``(prefill_32k) — prompt consumption with cache write-back;
* ``serve_step``  (decode_32k / long_500k) — one new token against a
  seq_len-deep KV cache / SSM state.

Modality-frontend stubs (per assignment): seamless feeds precomputed audio
frame embeddings ``(B, S_src, d_model)``; chameleon feeds VQ token ids
(its frontend emits ids into the shared vocab).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import models
from repro.configs.registry import ShapeSpec
from repro.models.config import ModelConfig
from repro.optim import adam_init, adam_update

Pytree = Any

I32 = jnp.int32


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract batch for one cell (see module docstring)."""
    b, s = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        if cfg.is_encdec:
            return {
                "src_embeds": sds((b, s, cfg.d_model), act),
                "tokens": sds((b, s), I32),
                "labels": sds((b, s), I32),
            }
        return {"tokens": sds((b, s), I32), "labels": sds((b, s), I32)}
    if shape.kind == "prefill":
        if cfg.is_encdec:
            return {
                "src_embeds": sds((b, s, cfg.d_model), act),
                "tokens": sds((b, max(cfg.source_len // 4, 64)), I32),
            }
        return {"tokens": sds((b, s), I32)}
    # decode: one new token; the cache depth comes from the decode state.
    return {"tokens": sds((b, 1), I32)}


def decode_state_specs(cfg: ModelConfig, shape: ShapeSpec):
    """Abstract DecodeState for a decode cell: caches filled to seq_len."""
    b, s = shape.global_batch, shape.seq_len

    def build():
        state = models.init_decode_state(cfg, b, max_len=s)
        if cfg.is_encdec:
            # cross-attention memory as produced by prefill
            src = jnp.zeros(
                (b, cfg.source_len, cfg.d_model), jnp.dtype(cfg.dtype)
            )
            mem = models.transformer._cross_memory(
                models.init(jax.random.PRNGKey(0), cfg), src, cfg
            )
            return state._replace(memory=mem)
        return state

    if cfg.is_encdec:
        # memory depends on params; build abstractly through prefill instead
        def build2(params):
            state = models.init_decode_state(cfg, b, max_len=s)
            src = jnp.zeros(
                (b, cfg.source_len, cfg.d_model), jnp.dtype(cfg.dtype)
            )
            mem = models.transformer._cross_memory(params, src, cfg)
            return state._replace(memory=mem, length=jnp.int32(0))

        return None, build2
    return jax.eval_shape(build), None


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, *, lr: float = 1e-4,
                    moment_dtype=jnp.float32):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            models.lm_loss, has_aux=True
        )(params, batch, cfg)
        new_params, new_opt = adam_update(
            grads, opt_state, params, lr=lr, weight_decay=0.1
        )
        out_metrics = {
            "loss": loss,
            "xent": metrics["xent"],
            "aux": metrics["aux"],
        }
        return new_params, new_opt, out_metrics

    return train_step


def init_opt_state(params, moment_dtype=jnp.float32):
    state = adam_init(params)
    if moment_dtype != jnp.float32:
        state = state._replace(
            mu=jax.tree_util.tree_map(
                lambda x: x.astype(moment_dtype), state.mu
            ),
            nu=jax.tree_util.tree_map(
                lambda x: x.astype(moment_dtype), state.nu
            ),
        )
    return state


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch, state):
        return models.prefill(params, batch, state, cfg)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, tokens, state):
        return models.decode_step(params, tokens, state, cfg)

    return serve_step


# ---------------------------------------------------------------------------
# MODEL_FLOPS accounting (roofline's "useful compute" numerator)
# ---------------------------------------------------------------------------


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6·N_active·tokens (train) or 2·N_active·tokens (inference)."""
    n_active = cfg.active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n_active * tokens
