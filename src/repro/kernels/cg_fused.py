"""Fused CG/def-CG iteration updates — the solver's non-matvec hot path.

One def-CG iteration on flat ``(n,)`` state does, besides the matvec:

    x  += α p                    r  -= α ap
    rr  = rᵀr                    awr = (AW)ᵀ r          (deflation GEMV)
    p   = β p + r − W μ          P[idx], AP[idx] = p, ap (recording)

Issued as separate ops these are ~8 HBM passes over n-sized data; in the
memory-bound regime the paper targets (cheap matvec, large n) they dominate
the iteration.  This module fuses them into two passes (DESIGN.md §8):

* :func:`fused_cg_update_pallas` — ``x/r`` AXPYs plus *both* reductions
  (``rᵀr`` and ``(AW)ᵀr``) in one read of ``x, r, p, ap, AW``;
* :func:`fused_deflate_direction_pallas` — the deflated direction update
  ``p ← βp + r − Wμ`` plus the guarded ring-buffer write of ``(p, Ap)``
  (a dynamic output row selected by scalar-prefetched ``idx``, buffers
  aliased in/out so untouched rows never move).

Layout: a flat vector of length n is viewed as ``(n/128, 128)`` and the
grid walks row-blocks; bases ``(k, n)`` become ``(k, n/128, 128)`` with the
k axis resident per block.  Scalars (α, β, μ) ride in SMEM; the reductions
accumulate in SMEM across the sequential grid.

The ``chunked`` twins are the pure-jnp same-math forms.  They deliberately
have *no* scan blocking: all operands are O(n), nothing materializes, and a
single jnp expression lets XLA fuse each group into one loop — that is the
CPU/GPU fast path the solver uses off-TPU.

Per the repo kernel contract: oracles live in ``ref.py``, dispatch in
``ops.py`` (pallas | interpret | reference | chunked | auto).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

_LANES = 128


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _acc(dtype):
    """Accumulation dtype (mirrors core.pytree): f64 stays, else ≥ f32."""
    if dtype == jnp.float64:
        return jnp.float64
    return jnp.promote_types(dtype, jnp.float32)


def _pad_rows(v: jnp.ndarray, n_pad: int) -> jnp.ndarray:
    """(n,) → (n_pad/128, 128), zero-padded (identity when n == n_pad)."""
    n = v.shape[-1]
    if v.ndim == 1:
        return jnp.pad(v, (0, n_pad - n)).reshape(-1, _LANES)
    return jnp.pad(v, ((0, 0), (0, n_pad - n))).reshape(
        v.shape[0], -1, _LANES
    )


# ---------------------------------------------------------------------------
# fused_cg_update: x += αp, r −= αap, rr = rᵀr, awr = AW·r — one pass
# ---------------------------------------------------------------------------


def _cg_update_kernel(
    alpha_ref, x_ref, r_ref, p_ref, ap_ref, xo_ref, ro_ref, rr_ref
):
    i = pl.program_id(0)
    alpha = alpha_ref[0, 0]
    rn = r_ref[...].astype(jnp.float32) - alpha * ap_ref[...].astype(
        jnp.float32
    )
    xo_ref[...] = (
        x_ref[...].astype(jnp.float32) + alpha * p_ref[...].astype(jnp.float32)
    ).astype(xo_ref.dtype)
    ro_ref[...] = rn.astype(ro_ref.dtype)

    @pl.when(i == 0)
    def _init():
        rr_ref[0, 0] = jnp.float32(0.0)

    rr_ref[0, 0] += jnp.sum(rn * rn)


def _cg_update_aw_kernel(
    alpha_ref, x_ref, r_ref, p_ref, ap_ref, aw_ref,
    xo_ref, ro_ref, rr_ref, awr_ref, *, k,
):
    i = pl.program_id(0)
    alpha = alpha_ref[0, 0]
    rn = r_ref[...].astype(jnp.float32) - alpha * ap_ref[...].astype(
        jnp.float32
    )
    xo_ref[...] = (
        x_ref[...].astype(jnp.float32) + alpha * p_ref[...].astype(jnp.float32)
    ).astype(xo_ref.dtype)
    ro_ref[...] = rn.astype(ro_ref.dtype)

    @pl.when(i == 0)
    def _init():
        rr_ref[0, 0] = jnp.float32(0.0)
        for ki in range(k):
            awr_ref[ki, 0] = jnp.float32(0.0)

    rr_ref[0, 0] += jnp.sum(rn * rn)
    awv = aw_ref[...].astype(jnp.float32)  # (k, rows, lanes)
    for ki in range(k):
        awr_ref[ki, 0] += jnp.sum(awv[ki] * rn)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_cg_update_pallas(
    x: jnp.ndarray,
    r: jnp.ndarray,
    p: jnp.ndarray,
    ap: jnp.ndarray,
    alpha,
    aw: Optional[jnp.ndarray] = None,
    *,
    block: int = 4096,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, Optional[jnp.ndarray]]:
    """One-pass CG state update over flat vectors (f32 accumulation).

    Returns ``(x + α·p, r − α·ap, ‖r_new‖², AW @ r_new | None)``.

    Shapes are padded to the (rows·128) tile internally; padded tails are
    zero so both reductions are exact, and outputs are sliced back to n.
    The pads are identity when n is already tile-aligned (the usual case
    for model shapes) — on TPU, misaligned n pays a pad/slice per call,
    so prefer aligned problem sizes (or a smaller ``block``) there.
    """
    n = x.shape[0]
    rows = max(8, block // _LANES)
    n_pad = _round_up(n, _LANES * rows)
    nrows = n_pad // _LANES
    grid = (nrows // rows,)

    x2, r2, p2, ap2 = (_pad_rows(v, n_pad) for v in (x, r, p, ap))
    alpha2 = jnp.asarray(alpha, jnp.float32).reshape(1, 1)

    vec_spec = pl.BlockSpec((rows, _LANES), lambda i: (i, 0))
    smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)
    in_specs = [smem((1, 1), lambda i: (0, 0))] + [vec_spec] * 4
    out_specs = [
        vec_spec,
        vec_spec,
        smem((1, 1), lambda i: (0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((nrows, _LANES), x.dtype),
        jax.ShapeDtypeStruct((nrows, _LANES), r.dtype),
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
    ]
    args = [alpha2, x2, r2, p2, ap2]

    if aw is not None:
        k = aw.shape[0]
        args.append(_pad_rows(aw, n_pad))
        in_specs.append(
            pl.BlockSpec((k, rows, _LANES), lambda i: (0, i, 0))
        )
        out_specs.append(smem((k, 1), lambda i: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((k, 1), jnp.float32))
        kernel = functools.partial(_cg_update_aw_kernel, k=k)
    else:
        kernel = _cg_update_kernel

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="fused_cg_update",
    )(*args)

    x_new = outs[0].reshape(n_pad)[:n]
    r_new = outs[1].reshape(n_pad)[:n]
    # Reductions accumulate in f32 on the TPU but are returned in the
    # accumulation dtype of the inputs, so solver loop carries keep a
    # consistent dtype across the pallas and chunked paths (x64 mode).
    rr = outs[2][0, 0].astype(_acc(r.dtype))
    awr = outs[3][:, 0].astype(_acc(r.dtype)) if aw is not None else None
    return x_new, r_new, rr, awr


def fused_cg_update_chunked(x, r, p, ap, alpha, aw=None):
    """Pure-jnp twin: same math, one fused XLA loop per output group."""
    acc = _acc(r.dtype)
    x_new = x + alpha * p
    r_new = r - alpha * ap
    ra = r_new.astype(acc)
    rr = jnp.sum(ra * ra)
    awr = aw.astype(acc) @ ra if aw is not None else None
    return x_new, r_new, rr, awr


# ---------------------------------------------------------------------------
# fused_rz_reduce: rᵀz and (AW)ᵀz — the preconditioned iteration's reductions
# ---------------------------------------------------------------------------
#
# Preconditioned def-CG applies z = M⁻¹r *after* the residual update, so the
# recurrence scalar rᵀz and the deflation GEMV (AW)ᵀz cannot ride in
# fused_cg_update's pass (which only sees r).  This second fused pass reads
# (r, z, AW) once and emits both reductions — the preconditioned iteration
# costs exactly one extra sweep over n-sized data beyond the unpreconditioned
# one, not three.


def _rz_reduce_kernel(r_ref, z_ref, rz_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        rz_ref[0, 0] = jnp.float32(0.0)

    rz_ref[0, 0] += jnp.sum(
        r_ref[...].astype(jnp.float32) * z_ref[...].astype(jnp.float32)
    )


def _rz_reduce_aw_kernel(r_ref, z_ref, aw_ref, rz_ref, awz_ref, *, k):
    i = pl.program_id(0)
    zv = z_ref[...].astype(jnp.float32)

    @pl.when(i == 0)
    def _init():
        rz_ref[0, 0] = jnp.float32(0.0)
        for ki in range(k):
            awz_ref[ki, 0] = jnp.float32(0.0)

    rz_ref[0, 0] += jnp.sum(r_ref[...].astype(jnp.float32) * zv)
    awv = aw_ref[...].astype(jnp.float32)  # (k, rows, lanes)
    for ki in range(k):
        awz_ref[ki, 0] += jnp.sum(awv[ki] * zv)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_rz_reduce_pallas(
    r: jnp.ndarray,
    z: jnp.ndarray,
    aw: Optional[jnp.ndarray] = None,
    *,
    block: int = 4096,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """``(rᵀz, AW @ z | None)`` in one read of ``r, z, AW`` (f32 accum)."""
    n = r.shape[0]
    rows = max(8, block // _LANES)
    n_pad = _round_up(n, _LANES * rows)
    nrows = n_pad // _LANES
    grid = (nrows // rows,)

    r2, z2 = _pad_rows(r, n_pad), _pad_rows(z, n_pad)
    vec_spec = pl.BlockSpec((rows, _LANES), lambda i: (i, 0))
    smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)

    in_specs = [vec_spec, vec_spec]
    out_specs = [smem((1, 1), lambda i: (0, 0))]
    out_shape = [jax.ShapeDtypeStruct((1, 1), jnp.float32)]
    args = [r2, z2]
    if aw is not None:
        k = aw.shape[0]
        args.append(_pad_rows(aw, n_pad))
        in_specs.append(pl.BlockSpec((k, rows, _LANES), lambda i: (0, i, 0)))
        out_specs.append(smem((k, 1), lambda i: (0, 0)))
        out_shape.append(jax.ShapeDtypeStruct((k, 1), jnp.float32))
        kernel = functools.partial(_rz_reduce_aw_kernel, k=k)
    else:
        kernel = _rz_reduce_kernel

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="fused_rz_reduce",
    )(*args)
    rz = outs[0][0, 0].astype(_acc(r.dtype))
    awz = outs[1][:, 0].astype(_acc(r.dtype)) if aw is not None else None
    return rz, awz


def fused_rz_reduce_chunked(r, z, aw=None):
    """Pure-jnp twin: one fused XLA reduction group in the acc dtype."""
    acc = _acc(r.dtype)
    za = z.astype(acc)
    rz = jnp.sum(r.astype(acc) * za)
    awz = aw.astype(acc) @ za if aw is not None else None
    return rz, awz


# ---------------------------------------------------------------------------
# lsmr_update: hbar ← h − c0·hbar, x ← x + c1·hbar, h ← v − c2·h — one pass
# ---------------------------------------------------------------------------
#
# One LSMR iteration's non-matvec vector work is three coupled AXPY-style
# recurrences over (x, hbar, h, v).  Issued separately they are three HBM
# sweeps (six reads, three writes); fused, every operand is read once and
# the shared intermediate hbar_new never round-trips through HBM.  The
# rotation scalars are pre-reduced by the solver (they come from the 2×2
# Givens recurrences, O(1) work) and ride in SMEM.


def _lsmr_update_kernel(c_ref, x_ref, hbar_ref, h_ref, v_ref,
                        xo_ref, hbo_ref, ho_ref):
    c0, c1, c2 = c_ref[0, 0], c_ref[1, 0], c_ref[2, 0]
    hv = h_ref[...].astype(jnp.float32)
    hb = hv - c0 * hbar_ref[...].astype(jnp.float32)
    xo_ref[...] = (
        x_ref[...].astype(jnp.float32) + c1 * hb
    ).astype(xo_ref.dtype)
    hbo_ref[...] = hb.astype(hbo_ref.dtype)
    ho_ref[...] = (
        v_ref[...].astype(jnp.float32) - c2 * hv
    ).astype(ho_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def lsmr_update_pallas(
    x: jnp.ndarray,
    hbar: jnp.ndarray,
    h: jnp.ndarray,
    v: jnp.ndarray,
    c0,
    c1,
    c2,
    *,
    block: int = 4096,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-pass LSMR vector update (f32 accumulation on the VPU).

    Returns ``(x + c1·(h − c0·hbar), h − c0·hbar, v − c2·h)`` — the
    ``(x, hbar, h)`` state after one iteration, with the scalars packed
    into SMEM and every n-sized operand read exactly once.
    """
    n = x.shape[0]
    rows = max(8, block // _LANES)
    n_pad = _round_up(n, _LANES * rows)
    nrows = n_pad // _LANES
    grid = (nrows // rows,)

    x2, hb2, h2, v2 = (_pad_rows(u, n_pad) for u in (x, hbar, h, v))
    c2_ = jnp.stack([
        jnp.asarray(c0, jnp.float32),
        jnp.asarray(c1, jnp.float32),
        jnp.asarray(c2, jnp.float32),
    ]).reshape(3, 1)

    vec_spec = pl.BlockSpec((rows, _LANES), lambda i: (i, 0))
    smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)
    outs = pl.pallas_call(
        _lsmr_update_kernel,
        grid=grid,
        in_specs=[smem((3, 1), lambda i: (0, 0))] + [vec_spec] * 4,
        out_specs=[vec_spec] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((nrows, _LANES), x.dtype),
            jax.ShapeDtypeStruct((nrows, _LANES), hbar.dtype),
            jax.ShapeDtypeStruct((nrows, _LANES), h.dtype),
        ],
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="lsmr_update",
    )(c2_, x2, hb2, h2, v2)
    return (
        outs[0].reshape(n_pad)[:n],
        outs[1].reshape(n_pad)[:n],
        outs[2].reshape(n_pad)[:n],
    )


def lsmr_update_chunked(x, hbar, h, v, c0, c1, c2):
    """Pure-jnp twin: same math, one fused XLA loop over the four vectors."""
    hbar_new = h - c0 * hbar
    x_new = x + c1 * hbar_new
    h_new = v - c2 * h
    return x_new, hbar_new, h_new


# ---------------------------------------------------------------------------
# fused_deflate_direction: p ← βp + r − Wμ, plus the (p, Ap) buffer write
# ---------------------------------------------------------------------------


def _deflate_buf_kernel(
    idx_ref, beta_ref, mu_ref, r_ref, p_ref, ap_ref, w_ref,
    pbi_ref, abi_ref, po_ref, pbo_ref, abo_ref, *, k,
):
    del idx_ref, pbi_ref, abi_ref  # routing only (index maps / aliasing)
    pv = p_ref[...].astype(jnp.float32)
    acc = r_ref[...].astype(jnp.float32) + beta_ref[0, 0] * pv
    for ki in range(k):
        acc -= mu_ref[ki, 0] * w_ref[ki].astype(jnp.float32)
    po_ref[...] = acc.astype(po_ref.dtype)
    pbo_ref[0] = p_ref[...].astype(pbo_ref.dtype)
    abo_ref[0] = ap_ref[...].astype(abo_ref.dtype)


def _deflate_kernel(beta_ref, mu_ref, r_ref, p_ref, w_ref, po_ref, *, k):
    pv = p_ref[...].astype(jnp.float32)
    acc = r_ref[...].astype(jnp.float32) + beta_ref[0, 0] * pv
    for ki in range(k):
        acc -= mu_ref[ki, 0] * w_ref[ki].astype(jnp.float32)
    po_ref[...] = acc.astype(po_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_deflate_direction_pallas(
    r: jnp.ndarray,
    p: jnp.ndarray,
    beta,
    w: jnp.ndarray,
    mu: jnp.ndarray,
    ap: Optional[jnp.ndarray] = None,
    idx=None,
    p_buf: Optional[jnp.ndarray] = None,
    ap_buf: Optional[jnp.ndarray] = None,
    *,
    block: int = 4096,
    interpret: bool = False,
):
    """Deflated direction update, optionally recording ``(p, ap)``.

    ``p_new = β·p + r − μᵀW``; when ``p_buf``/``ap_buf`` are given, the
    *incoming* ``p`` and ``ap`` are stored into buffer row ``idx`` in the
    same pass — callers guard the write by pointing ``idx`` at a spare
    row.  The buffers are aliased through the kernel (donated), so only
    the selected row moves; returns ``(p_new, p_buf, ap_buf)``.
    """
    n = r.shape[0]
    k = w.shape[0]
    rows = max(8, block // _LANES)
    n_pad = _round_up(n, _LANES * rows)
    nrows = n_pad // _LANES
    grid = (nrows // rows,)

    r2, p2 = _pad_rows(r, n_pad), _pad_rows(p, n_pad)
    w2 = _pad_rows(w, n_pad)
    beta2 = jnp.asarray(beta, jnp.float32).reshape(1, 1)
    mu2 = jnp.asarray(mu, jnp.float32).reshape(k, 1)

    have_buf = p_buf is not None
    smem = functools.partial(pl.BlockSpec, memory_space=pltpu.SMEM)

    if not have_buf:
        out = pl.pallas_call(
            functools.partial(_deflate_kernel, k=k),
            grid=grid,
            in_specs=[
                smem((1, 1), lambda i: (0, 0)),
                smem((k, 1), lambda i: (0, 0)),
                pl.BlockSpec((rows, _LANES), lambda i: (i, 0)),
                pl.BlockSpec((rows, _LANES), lambda i: (i, 0)),
                pl.BlockSpec((k, rows, _LANES), lambda i: (0, i, 0)),
            ],
            out_specs=pl.BlockSpec((rows, _LANES), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((nrows, _LANES), p.dtype),
            compiler_params=CompilerParams(
                dimension_semantics=("arbitrary",)
            ),
            interpret=interpret,
            name="fused_deflate_direction",
        )(beta2, mu2, r2, p2, w2)
        return out.reshape(n_pad)[:n], None, None

    m = p_buf.shape[0]
    ap2 = _pad_rows(ap, n_pad)
    pb2, ab2 = _pad_rows(p_buf, n_pad), _pad_rows(ap_buf, n_pad)
    idx2 = jnp.asarray(idx, jnp.int32).reshape(1)

    vec = lambda: pl.BlockSpec((rows, _LANES), lambda i, idx_ref: (i, 0))
    row = lambda: pl.BlockSpec(
        (1, rows, _LANES), lambda i, idx_ref: (idx_ref[0], i, 0)
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            smem((1, 1), lambda i, idx_ref: (0, 0)),  # beta
            smem((k, 1), lambda i, idx_ref: (0, 0)),  # mu
            vec(),  # r
            vec(),  # p
            vec(),  # ap
            pl.BlockSpec(
                (k, rows, _LANES), lambda i, idx_ref: (0, i, 0)
            ),  # w
            row(),  # p_buf (pass-through for aliasing)
            row(),  # ap_buf
        ],
        out_specs=[vec(), row(), row()],
    )
    # Alias the buffers in→out (inputs count the scalar-prefetch arg).
    outs = pl.pallas_call(
        functools.partial(_deflate_buf_kernel, k=k),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((nrows, _LANES), p.dtype),
            jax.ShapeDtypeStruct((m, nrows, _LANES), p_buf.dtype),
            jax.ShapeDtypeStruct((m, nrows, _LANES), ap_buf.dtype),
        ],
        input_output_aliases={7: 1, 8: 2},
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="fused_deflate_direction",
    )(idx2, beta2, mu2, r2, p2, ap2, w2, pb2, ab2)
    p_new = outs[0].reshape(n_pad)[:n]
    p_buf_new = outs[1].reshape(m, n_pad)[:, :n]
    ap_buf_new = outs[2].reshape(m, n_pad)[:, :n]
    return p_new, p_buf_new, ap_buf_new


# ---------------------------------------------------------------------------
# self_gram: S Sᵀ for a stacked flat basis — the extraction's single GEMM
# ---------------------------------------------------------------------------
#
# Harmonic-Ritz extraction needs G = (AZ)(AZ)ᵀ and F = (AZ)Zᵀ.  Stacking
# S = [Z; AZ] (2m, n) and forming S Sᵀ yields both as quadrants in ONE
# tall-skinny GEMM — one read of the basis data instead of three separate
# gram passes (ZZᵀ for column norms, then G, then F).


def _self_gram_kernel(s_ref, o_ref, acc_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sb = s_ref[...].astype(jnp.float32)  # (m_pad, bn)
    acc_ref[...] += jax.lax.dot_general(
        sb, sb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(j == pl.num_programs(0) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def self_gram_pallas(
    s: jnp.ndarray, *, block: int = 2048, interpret: bool = False
) -> jnp.ndarray:
    """``S Sᵀ`` for ``S`` of shape ``(m, n)``, blocked over ``n``.

    The grid walks n-blocks sequentially and accumulates the ``(m, m)``
    Gram tile in a VMEM scratch (f32); only the final step writes back.
    Zero-padding in both axes is exact (padded rows/cols contribute 0 and
    padded output rows are sliced off).
    """
    m, n = s.shape
    m_pad = _round_up(max(m, 8), 8)
    bn = min(_round_up(block, _LANES), _round_up(n, _LANES))
    n_pad = _round_up(n, bn)
    s_p = jnp.pad(s, ((0, m_pad - m), (0, n_pad - n)))

    out = pl.pallas_call(
        _self_gram_kernel,
        grid=(n_pad // bn,),
        in_specs=[pl.BlockSpec((m_pad, bn), lambda j: (0, j))],
        out_specs=pl.BlockSpec((m_pad, m_pad), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, m_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m_pad, m_pad), jnp.float32)],
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="self_gram",
    )(s_p)
    return out[:m, :m].astype(_acc(s.dtype))


def self_gram_chunked(s: jnp.ndarray, block: int = 8192) -> jnp.ndarray:
    """Pure-jnp twin: scan over n-blocks, accumulating in the acc dtype.

    A single GEMM when ``n ≤ block`` (the usual extraction size); the
    blocked scan bounds live memory for very long flat vectors.
    """
    acc = _acc(s.dtype)
    m, n = s.shape
    if n <= block:
        sa = s.astype(acc)
        return sa @ sa.T
    n_pad = _round_up(n, block)
    sp = jnp.pad(s, ((0, 0), (0, n_pad - n))).astype(acc)
    blocks = sp.reshape(m, n_pad // block, block).transpose(1, 0, 2)

    def body(g, sb):
        return g + sb @ sb.T, None

    g0 = jnp.zeros((m, m), acc)
    g, _ = jax.lax.scan(body, g0, blocks)
    return g


# ---------------------------------------------------------------------------
# recombine_blocks: [uᵀZ; uᵀAZ] from S = [Z; AZ] — the windowed refresh GEMM
# ---------------------------------------------------------------------------
#
# The paper's zero-extra-matvec refresh rebuilds BOTH the next recycled
# basis W' = uᵀZ and its operator products AW' = uᵀAZ from quantities the
# solve already stored.  Doing it as one kernel over the stacked S = [Z; AZ]
# (2m, n) reads the basis data once: each n-block loads the full (2m, bn)
# column slab, applies uᵀ to each half on the MXU, and writes the (2k, bn)
# output slab.  Output blocks are disjoint per grid step.


def _recombine_blocks_kernel(ut_ref, s_ref, o_ref, *, m_pad, k_pad):
    ut = ut_ref[...]  # (k_pad, m_pad) f32
    sb = s_ref[...].astype(jnp.float32)  # (2·m_pad, bn)
    top = jax.lax.dot_general(
        ut, sb[:m_pad], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    bot = jax.lax.dot_general(
        ut, sb[m_pad:], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[:k_pad] = top.astype(o_ref.dtype)
    o_ref[k_pad:] = bot.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def recombine_blocks_pallas(
    s: jnp.ndarray,
    u: jnp.ndarray,
    *,
    block: int = 2048,
    interpret: bool = False,
) -> jnp.ndarray:
    """``[uᵀ·S_top; uᵀ·S_bot]`` for ``S`` of shape ``(2m, n)``, ``u`` of
    ``(m, k)`` — blocked over ``n``, f32 accumulation on the MXU.

    Both halves are padded to an 8-row tile independently so the static
    half split survives padding; zero pad rows/cols contribute exact
    zeros and are sliced off the output.
    """
    m2, n = s.shape
    m = m2 // 2
    assert 2 * m == m2, "recombine_blocks needs an even (2m, n) stack"
    k = u.shape[1]
    m_pad = _round_up(max(m, 8), 8)
    k_pad = _round_up(max(k, 8), 8)
    bn = min(_round_up(block, _LANES), _round_up(n, _LANES))
    n_pad = _round_up(n, bn)

    s_p = jnp.concatenate(
        [
            jnp.pad(s[:m], ((0, m_pad - m), (0, n_pad - n))),
            jnp.pad(s[m:], ((0, m_pad - m), (0, n_pad - n))),
        ],
        axis=0,
    )
    ut_p = jnp.pad(
        u.astype(jnp.float32).T, ((0, k_pad - k), (0, m_pad - m))
    )

    out = pl.pallas_call(
        functools.partial(_recombine_blocks_kernel, m_pad=m_pad, k_pad=k_pad),
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((k_pad, m_pad), lambda j: (0, 0)),
            pl.BlockSpec((2 * m_pad, bn), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((2 * k_pad, bn), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((2 * k_pad, n_pad), s.dtype),
        compiler_params=CompilerParams(dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name="recombine_blocks",
    )(ut_p, s_p)
    return jnp.concatenate(
        [out[:k, :n], out[k_pad : k_pad + k, :n]], axis=0
    )


def recombine_blocks_chunked(
    s: jnp.ndarray, u: jnp.ndarray, block: int = 8192
) -> jnp.ndarray:
    """Pure-jnp twin: one fused two-block GEMM when ``n ≤ block``, else a
    scan over n-blocks with the kernel's blocking (bounded live memory)."""
    m2, n = s.shape
    m = m2 // 2
    acc = _acc(s.dtype)
    ut = u.astype(acc).T  # (k, m)
    if n <= block:
        sa = s.astype(acc)
        return jnp.concatenate([ut @ sa[:m], ut @ sa[m:]], axis=0).astype(
            s.dtype
        )
    n_pad = _round_up(n, block)
    sp = jnp.pad(s, ((0, 0), (0, n_pad - n))).astype(acc)
    blocks = sp.reshape(m2, n_pad // block, block).transpose(1, 0, 2)

    def body(_, sb):
        return None, jnp.concatenate([ut @ sb[:m], ut @ sb[m:]], axis=0)

    _, outs = jax.lax.scan(body, None, blocks)
    k = u.shape[1]
    return (
        outs.transpose(1, 0, 2).reshape(2 * k, n_pad)[:, :n].astype(s.dtype)
    )


def fused_deflate_direction_chunked(
    r, p, beta, w=None, mu=None, ap=None, idx=None, p_buf=None, ap_buf=None
):
    """Pure-jnp twin.  The buffer update is a single masked
    ``dynamic_update_slice`` (no read-modify-write of the old row); inside
    a ``while_loop`` the buffers are donated, so only row ``idx`` moves."""
    p_new = beta * p + r
    if w is not None:
        p_new = p_new - (
            mu.astype(_acc(w.dtype)) @ w.astype(_acc(w.dtype))
        ).astype(p.dtype)
    if p_buf is None:
        return p_new, None, None
    i = jnp.asarray(idx, jnp.int32)
    zero = jnp.int32(0)
    p_buf = jax.lax.dynamic_update_slice(
        p_buf, p[None].astype(p_buf.dtype), (i, zero)
    )
    ap_buf = jax.lax.dynamic_update_slice(
        ap_buf, ap[None].astype(ap_buf.dtype), (i, zero)
    )
    return p_new, p_buf, ap_buf
