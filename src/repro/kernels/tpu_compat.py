"""Version shims for the Pallas TPU API surface.

The compiler-params dataclass was renamed ``TPUCompilerParams`` →
``CompilerParams`` across JAX releases; resolve whichever this JAX ships
so the kernels import cleanly on both sides of the rename.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)

if CompilerParams is None:  # pragma: no cover - very old/new jax
    raise ImportError("no Pallas TPU CompilerParams class found in this jax")
