"""Fused RBF Gram-matrix matvec — the paper's per-iteration hot-spot.

Every CG / def-CG iteration on the GP-classification Newton system costs
one product with the kernel Gram matrix ``K(X, X)``.  Materializing ``K``
(n² entries) and streaming it from HBM makes the matvec memory-bound at
~0.5 flop/byte.  This kernel instead *fuses* Gram formation and the matvec:

    tile (i, j):   S  = ‖xi‖² + ‖xj‖ᵀ² − 2·Xi Xjᵀ        (MXU: bm×d @ d×bn)
                   Kb = exp(−S/2)                          (VPU)
                   Yi += Kb @ Vj                           (MXU: bm×bn @ bn×r)

so HBM traffic is O(n·d + n·r) per pass instead of O(n²), and arithmetic
intensity grows with the block size — the op becomes compute-bound, which
is the right regime for the MXU (DESIGN.md §3).

Parameter handling: the wrapper (ops.py) pre-scales ``X ← X/λ`` and
``V ← θ²·V``, so the kernel body is hyperparameter-free and never
recompiles during outer-loop kernel-hyperparameter optimization.

Multi-RHS (``V ∈ ℝ^{n×r}``) is native: recomputing ``A·W`` for a recycled
k-vector basis (the O(k·n²) overhead the paper accounts for in §2.2) is a
single fused pass with r = k instead of k separate matvecs.

Grid layout: ``(i, j)`` with j innermost ("arbitrary" semantics — the
output tile for row-block i is revisited across j and accumulated in VMEM;
only the final j writes back).  i is parallel across cores.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams


def _rbf_matvec_kernel(x_i_ref, x_j_ref, v_ref, o_ref, acc_ref):
    """One (bm × bn) tile of y += exp(−‖xi−xj‖²/2) @ v."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xi = x_i_ref[...].astype(jnp.float32)  # (bm, d)
    xj = x_j_ref[...].astype(jnp.float32)  # (bn, d)
    vj = v_ref[...].astype(jnp.float32)  # (bn, r)

    # Pairwise squared distances via one MXU matmul + rank-1 corrections.
    sq_i = jnp.sum(xi * xi, axis=1, keepdims=True)  # (bm, 1)
    sq_j = jnp.sum(xj * xj, axis=1, keepdims=True).T  # (1, bn)
    cross = jax.lax.dot_general(
        xi,
        xj,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (bm, bn)
    dist2 = jnp.maximum(sq_i + sq_j - 2.0 * cross, 0.0)
    kb = jnp.exp(-0.5 * dist2)

    acc_ref[...] += jax.lax.dot_general(
        kb, vj, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def rbf_matvec_pallas(
    x_scaled: jnp.ndarray,
    v_scaled: jnp.ndarray,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """``y = exp(−½‖x_i − x_j‖²) V`` over pre-scaled inputs.

    Args:
      x_scaled: (n, d) data, already divided by the lengthscale.
      v_scaled: (n, r) right-hand sides, already scaled by θ².
      block_m/block_n: VMEM tile rows/cols; multiples of 128 on real TPUs.
      interpret: run the kernel body in Python on CPU (validation mode).

    Shapes are padded internally: j-padding is exact because padded V rows
    are zero; padded i-rows are sliced off the output.
    """
    n, d = x_scaled.shape
    _, r = v_scaled.shape

    bm = min(block_m, max(_round_up(n, 8), 8))
    bn = min(block_n, max(_round_up(n, 8), 8))
    n_m = _round_up(n, bm)
    n_n = _round_up(n, bn)
    n_pad = max(n_m, n_n)
    d_pad = _round_up(d, 128)
    r_pad = _round_up(r, 8)

    x_p = jnp.pad(x_scaled, ((0, n_pad - n), (0, d_pad - d)))
    v_p = jnp.pad(v_scaled, ((0, n_pad - n), (0, r_pad - r)))

    grid = (n_pad // bm, n_pad // bn)
    out = pl.pallas_call(
        _rbf_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, r_pad), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, r_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, r_pad), v_scaled.dtype),
        scratch_shapes=[pltpu.VMEM((bm, r_pad), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="rbf_gram_matvec",
    )(x_p, x_p, v_p)
    return out[:n, :r]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "interpret")
)
def rbf_matvec_rect_pallas(
    x_rows: jnp.ndarray,
    x_cols: jnp.ndarray,
    v_scaled: jnp.ndarray,
    *,
    block_m: int = 256,
    block_n: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Rectangular Gram matvec ``y = exp(−½‖xr_i − xc_j‖²) V``.

    The sharded-operator building block: each shard holds a ROW block of
    the data (``x_rows``, its local (m, d) slice) and applies the full
    column set (``x_cols``, the all-gathered (n, d) data) to the gathered
    right-hand sides — the K-tile for (local rows × all columns) is
    formed and consumed in VMEM, never materialized.  The kernel body is
    :func:`_rbf_matvec_kernel` unchanged (the square wrapper just passes
    the same array for both row and column data); only the padding and
    grid differ.
    """
    m, d = x_rows.shape
    n, _ = x_cols.shape
    _, r = v_scaled.shape

    bm = min(block_m, max(_round_up(m, 8), 8))
    bn = min(block_n, max(_round_up(n, 8), 8))
    m_pad = _round_up(m, bm)
    n_pad = _round_up(n, bn)
    d_pad = _round_up(d, 128)
    r_pad = _round_up(r, 8)

    xr_p = jnp.pad(x_rows, ((0, m_pad - m), (0, d_pad - d)))
    xc_p = jnp.pad(x_cols, ((0, n_pad - n), (0, d_pad - d)))
    v_p = jnp.pad(v_scaled, ((0, n_pad - n), (0, r_pad - r)))

    grid = (m_pad // bm, n_pad // bn)
    out = pl.pallas_call(
        _rbf_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d_pad), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d_pad), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, r_pad), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, r_pad), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, r_pad), v_scaled.dtype),
        scratch_shapes=[pltpu.VMEM((bm, r_pad), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="rbf_gram_matvec_rect",
    )(xr_p, xc_p, v_p)
    return out[:m, :r]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
