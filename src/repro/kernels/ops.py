"""Public jit'd wrappers for the Pallas kernels, with implementation dispatch.

Every op takes ``impl ∈ {"auto", "pallas", "interpret", "reference",
"chunked"}``:

* ``pallas``     — the TPU kernel (real hardware target);
* ``interpret``  — the same kernel body, interpreted on CPU (validation);
* ``reference``  — the pure-jnp oracle from ``ref.py`` (materializes);
* ``chunked``    — a memory-efficient pure-jnp implementation with the same
  blocking structure as the kernel, built from ``lax.scan``.  This is what
  the multi-pod dry-run compiles (identical collective profile under pjit,
  linear memory, compiles on every backend) and what CPU end-to-end runs
  use;
* ``auto``       — ``pallas`` on TPU, ``chunked`` elsewhere.

Keeping the kernel and the scan implementation in one file per op — with a
single oracle — is the repo's kernel contract (see kernels/EXAMPLE.md).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cg_fused import (
    fused_cg_update_chunked,
    fused_cg_update_pallas,
    fused_deflate_direction_chunked,
    fused_deflate_direction_pallas,
    fused_rz_reduce_chunked,
    fused_rz_reduce_pallas,
    lsmr_update_chunked,
    lsmr_update_pallas,
    recombine_blocks_chunked,
    recombine_blocks_pallas,
    self_gram_chunked,
    self_gram_pallas,
)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rbf_matvec import rbf_matvec_pallas, rbf_matvec_rect_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

_NEG_INF = -1e30


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "chunked"
    return impl


# ---------------------------------------------------------------------------
# RBF Gram matvec
# ---------------------------------------------------------------------------


def rbf_matvec(
    x: jnp.ndarray,
    v: jnp.ndarray,
    theta: float,
    lengthscale: float,
    *,
    impl: str = "auto",
    block: int = 256,
) -> jnp.ndarray:
    """``K(X,X) @ v`` for the RBF kernel, no O(n²) memory (except reference).

    ``v`` may be ``(n,)`` or ``(n, r)`` (multi-RHS, e.g. refreshing ``A·W``
    for a k-vector recycled basis in one fused pass).
    """
    squeeze = v.ndim == 1
    v2 = v[:, None] if squeeze else v
    impl = _resolve(impl)
    if impl in ("pallas", "interpret"):
        out = rbf_matvec_pallas(
            x / lengthscale,
            (theta**2) * v2,
            block_m=block,
            block_n=block,
            interpret=(impl == "interpret"),
        )
    elif impl == "reference":
        out = ref.rbf_matvec(x, v2, theta, lengthscale)
    elif impl == "chunked":
        out = _rbf_matvec_chunked(x / lengthscale, (theta**2) * v2, block)
    else:
        raise ValueError(f"unknown impl={impl!r}")
    return out[:, 0] if squeeze else out


def _rbf_matvec_chunked(xs: jnp.ndarray, vs: jnp.ndarray, block: int):
    """Row-blocked Gram matvec: scan over i-blocks, full j per step.

    O(block · n) score memory.  Same math as the Pallas kernel (pre-scaled
    inputs), so dtype/rounding behaviour matches closely.
    """
    n, d = xs.shape
    nb = max(1, block)
    n_pad = ((n + nb - 1) // nb) * nb
    xp = jnp.pad(xs, ((0, n_pad - n), (0, 0)))
    sq_all = jnp.sum(xs * xs, axis=1)

    def body(_, xi):
        sq_i = jnp.sum(xi * xi, axis=1, keepdims=True)
        cross = xi @ xs.T
        d2 = jnp.maximum(sq_i + sq_all[None, :] - 2.0 * cross, 0.0)
        return None, jnp.exp(-0.5 * d2) @ vs

    _, ys = jax.lax.scan(body, None, xp.reshape(-1, nb, d))
    return ys.reshape(n_pad, vs.shape[1])[:n]


def rbf_matvec_rect(
    x_rows: jnp.ndarray,
    x_cols: jnp.ndarray,
    v: jnp.ndarray,
    theta: float,
    lengthscale: float,
    *,
    impl: str = "auto",
    block: int = 256,
) -> jnp.ndarray:
    """Rectangular Gram matvec ``K(X_rows, X_cols) @ v``, no O(m·n) memory.

    The sharded-operator primitive (DESIGN.md §5): each shard of the
    ``"solve"`` mesh keeps its local ROW block of the data and contracts
    it against the full (all-gathered) column set — one call per shard,
    K never materialized.  ``x_rows`` is ``(m, d)``, ``x_cols`` ``(n, d)``,
    ``v`` ``(n,)`` or ``(n, r)``; output ``(m,)`` / ``(m, r)``.  The
    square :func:`rbf_matvec` is the ``x_rows is x_cols`` special case.
    """
    squeeze = v.ndim == 1
    v2 = v[:, None] if squeeze else v
    impl = _resolve(impl)
    if impl in ("pallas", "interpret"):
        out = rbf_matvec_rect_pallas(
            x_rows / lengthscale,
            x_cols / lengthscale,
            (theta**2) * v2,
            block_m=block,
            block_n=block,
            interpret=(impl == "interpret"),
        )
    elif impl == "reference":
        out = ref.rbf_matvec_rect(x_rows, x_cols, v2, theta, lengthscale)
    elif impl == "chunked":
        out = _rbf_matvec_rect_chunked(
            x_rows / lengthscale, x_cols / lengthscale, (theta**2) * v2, block
        )
    else:
        raise ValueError(f"unknown impl={impl!r}")
    return out[:, 0] if squeeze else out


def _rbf_matvec_rect_chunked(
    xr: jnp.ndarray, xc: jnp.ndarray, vs: jnp.ndarray, block: int
):
    """Row-blocked rectangular Gram matvec — the chunked twin of
    :func:`_rbf_matvec_chunked` with distinct row/column data."""
    m, d = xr.shape
    nb = max(1, block)
    m_pad = ((m + nb - 1) // nb) * nb
    xp = jnp.pad(xr, ((0, m_pad - m), (0, 0)))
    sq_cols = jnp.sum(xc * xc, axis=1)

    def body(_, xi):
        sq_i = jnp.sum(xi * xi, axis=1, keepdims=True)
        cross = xi @ xc.T
        d2 = jnp.maximum(sq_i + sq_cols[None, :] - 2.0 * cross, 0.0)
        return None, jnp.exp(-0.5 * d2) @ vs

    _, ys = jax.lax.scan(body, None, xp.reshape(-1, nb, d))
    return ys.reshape(m_pad, vs.shape[1])[:m]


# ---------------------------------------------------------------------------
# Fused CG iteration updates (the def-CG inner-loop hot path)
# ---------------------------------------------------------------------------


def fused_cg_update(
    x: jnp.ndarray,
    r: jnp.ndarray,
    p: jnp.ndarray,
    ap: jnp.ndarray,
    alpha,
    aw: Optional[jnp.ndarray] = None,
    *,
    impl: str = "auto",
    block: int = 4096,
):
    """``(x + α p, r − α ap, ‖r_new‖², AW @ r_new | None)`` in one pass.

    The CG state update fused with both per-iteration reductions — the
    ``rᵀr`` recurrence scalar and the deflation GEMV ``(AW)ᵀ r`` (``aw``
    is the flat ``(k, n)`` basis; pass ``None`` when not deflating).
    """
    impl = _resolve(impl)
    if impl in ("pallas", "interpret"):
        return fused_cg_update_pallas(
            x, r, p, ap, alpha, aw,
            block=block, interpret=(impl == "interpret"),
        )
    if impl == "reference":
        return ref.fused_cg_update(x, r, p, ap, alpha, aw)
    if impl == "chunked":
        return fused_cg_update_chunked(x, r, p, ap, alpha, aw)
    raise ValueError(f"unknown impl={impl!r}")


def fused_rz_reduce(
    r: jnp.ndarray,
    z: jnp.ndarray,
    aw: Optional[jnp.ndarray] = None,
    *,
    impl: str = "auto",
    block: int = 4096,
):
    """``(rᵀz, AW @ z | None)`` in one pass over ``r, z, AW``.

    The preconditioned def-CG iteration's second fused sweep: the PCG
    recurrence scalar ``rᵀz`` (z = M⁻¹r is only available *after* the
    residual update, so it cannot ride in :func:`fused_cg_update`) plus
    the deflation GEMV taken in the preconditioned inner product.
    """
    impl = _resolve(impl)
    if impl in ("pallas", "interpret"):
        return fused_rz_reduce_pallas(
            r, z, aw, block=block, interpret=(impl == "interpret")
        )
    if impl == "reference":
        return ref.fused_rz_reduce(r, z, aw)
    if impl == "chunked":
        return fused_rz_reduce_chunked(r, z, aw)
    raise ValueError(f"unknown impl={impl!r}")


def fused_deflate_direction(
    r: jnp.ndarray,
    p: jnp.ndarray,
    beta,
    w: Optional[jnp.ndarray] = None,
    mu: Optional[jnp.ndarray] = None,
    ap: Optional[jnp.ndarray] = None,
    idx=None,
    p_buf: Optional[jnp.ndarray] = None,
    ap_buf: Optional[jnp.ndarray] = None,
    *,
    impl: str = "auto",
    block: int = 4096,
):
    """``p ← β p + r − μᵀ W`` fused with the guarded ring-buffer write.

    When ``p_buf``/``ap_buf`` are given the *incoming* ``(p, ap)`` is
    stored into row ``idx`` in the same pass (callers point ``idx`` at a
    spare row to suppress the write).  Returns ``(p_new, p_buf, ap_buf)``.

    The Pallas kernel serves the deflating combos; the plain-CG direction
    update (``w is None``) is two-operand elementwise work that XLA
    already fuses optimally, so it lowers to the chunked form everywhere.
    """
    impl = _resolve(impl)
    if impl in ("pallas", "interpret") and w is not None:
        return fused_deflate_direction_pallas(
            r, p, beta, w, mu, ap, idx, p_buf, ap_buf,
            block=block, interpret=(impl == "interpret"),
        )
    if impl == "reference":
        return ref.fused_deflate_direction(
            r, p, beta, w, mu, ap, idx, p_buf, ap_buf
        )
    if impl in ("chunked", "pallas", "interpret"):
        return fused_deflate_direction_chunked(
            r, p, beta, w, mu, ap, idx, p_buf, ap_buf
        )
    raise ValueError(f"unknown impl={impl!r}")


def lsmr_update(
    x: jnp.ndarray,
    hbar: jnp.ndarray,
    h: jnp.ndarray,
    v: jnp.ndarray,
    c0,
    c1,
    c2,
    *,
    impl: str = "auto",
    block: int = 4096,
):
    """``(x + c1·(h − c0·hbar), h − c0·hbar, v − c2·h)`` in one pass.

    The LSMR iteration's three coupled vector recurrences (see
    ``ref.lsmr_update`` for the semantic definition) fused into a single
    sweep over ``x, hbar, h, v`` — the least-squares analogue of
    :func:`fused_cg_update`.  The rotation scalars ``c0, c1, c2`` are the
    pre-reduced Givens quantities (O(1) host-free scalars).
    """
    impl = _resolve(impl)
    if impl in ("pallas", "interpret"):
        return lsmr_update_pallas(
            x, hbar, h, v, c0, c1, c2,
            block=block, interpret=(impl == "interpret"),
        )
    if impl == "reference":
        return ref.lsmr_update(x, hbar, h, v, c0, c1, c2)
    if impl == "chunked":
        return lsmr_update_chunked(x, hbar, h, v, c0, c1, c2)
    raise ValueError(f"unknown impl={impl!r}")


def self_gram(
    s: jnp.ndarray,
    *,
    impl: str = "auto",
    block: int = 8192,
) -> jnp.ndarray:
    """``S Sᵀ`` for a stacked flat basis ``S`` of shape ``(m, n)``.

    The harmonic-Ritz extraction stacks ``S = [Z; AZ]`` and reads its
    ``G``/``F`` gram blocks out of the quadrants of this one tall-skinny
    GEMM (one pass over the basis data).  Accumulates in f32 on the TPU
    kernel and in the acc dtype (f64-preserving) elsewhere.
    """
    impl = _resolve(impl)
    if impl in ("pallas", "interpret"):
        return self_gram_pallas(
            s, block=min(block, 2048), interpret=(impl == "interpret")
        )
    if impl == "reference":
        return ref.self_gram(s)
    if impl == "chunked":
        return self_gram_chunked(s, block)
    raise ValueError(f"unknown impl={impl!r}")


def recombine_blocks(
    s: jnp.ndarray,
    u: jnp.ndarray,
    *,
    impl: str = "auto",
    block: int = 8192,
) -> jnp.ndarray:
    """``[uᵀ·S_top; uᵀ·S_bot]`` — the stacked two-block recombination GEMM.

    ``s`` stacks two row-bases ``S = [Z; AZ]`` of shape ``(2m, n)``;
    ``u`` is the ``(m, k)`` recombination matrix from the extraction
    eigenproblem.  The result ``(2k, n)`` holds the next recycled basis
    ``W' = uᵀZ`` and its operator products ``AW' = uᵀAZ``, rebuilt from
    already-stored quantities in ONE pass over the basis data — the
    paper's zero-extra-matvec refresh (``core/strategies.py``).
    """
    impl = _resolve(impl)
    if impl in ("pallas", "interpret"):
        return recombine_blocks_pallas(
            s, u, block=min(block, 2048), interpret=(impl == "interpret")
        )
    if impl == "reference":
        return ref.recombine_blocks(s, u)
    if impl == "chunked":
        return recombine_blocks_chunked(s, u, block)
    raise ValueError(f"unknown impl={impl!r}")


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention(
    q: jnp.ndarray,  # (b, h, sq, dh)
    k: jnp.ndarray,  # (b, hkv, sk, dh)
    v: jnp.ndarray,
    *,
    causal: bool = False,
    scale: Optional[float] = None,
    q_offset: int = 0,
    impl: str = "auto",
    block_q: int = 512,
    block_k: int = 1024,
) -> jnp.ndarray:
    """GQA softmax attention; see ref.mha_attention for semantics."""
    impl = _resolve(impl)
    if impl in ("pallas", "interpret"):
        return flash_attention_pallas(
            q, k, v,
            causal=causal, scale=scale, q_offset=q_offset,
            block_q=min(block_q, 128), block_k=min(block_k, 128),
            interpret=(impl == "interpret"),
        )
    if impl == "reference":
        return ref.mha_attention(
            q, k, v, causal=causal, scale=scale, q_offset=q_offset
        )
    if impl == "chunked":
        return _attention_chunked(
            q, k, v,
            causal=causal, scale=scale, q_offset=q_offset,
            block_q=block_q, block_k=block_k,
        )
    raise ValueError(f"unknown impl={impl!r}")


def _attention_chunked(
    q, k, v, *, causal, scale, q_offset, block_q, block_k
):
    """Double-scan online-softmax attention: O(bq·bk) score memory.

    Outer scan over query blocks, inner scan over KV blocks with the
    flash-attention (m, l, acc) carry — the pure-jnp mirror of the Pallas
    kernel, compilable on CPU/GPU/TPU and linear-memory at 32k/512k.
    """
    b, h, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    group = h // hkv
    scale = dh**-0.5 if scale is None else scale

    bq = min(block_q, sq)
    bk = min(block_k, sk)
    sq_p = ((sq + bq - 1) // bq) * bq
    sk_p = ((sk + bk - 1) // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))

    kb = kp.reshape(b, hkv, sk_p // bk, bk, dh)
    vb = vp.reshape(b, hkv, sk_p // bk, bk, dh)

    def q_block(carry, inputs):
        qi, iq = inputs  # (b, h, bq, dh), block index

        def kv_block(state, kv_in):
            m_prev, l_prev, acc = state
            kj, vj, jk = kv_in  # (b, hkv, bk, dh), idx
            kjh = jnp.repeat(kj, group, axis=1)
            vjh = jnp.repeat(vj, group, axis=1)
            s = (
                jnp.einsum("bhqd,bhkd->bhqk", qi, kjh).astype(jnp.float32)
                * scale
            )
            kpos = jk * bk + jnp.arange(bk)[None, :]
            qpos = q_offset + iq * bq + jnp.arange(bq)[:, None]
            mask = kpos < sk
            if causal:
                mask = mask & (kpos <= qpos)
            s = jnp.where(mask[None, None], s, _NEG_INF)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new)
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * corr + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vj.dtype), vjh
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        init = (
            jnp.full((b, h, bq, 1), _NEG_INF, jnp.float32),
            jnp.zeros((b, h, bq, 1), jnp.float32),
            jnp.zeros((b, h, bq, dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            kv_block,
            init,
            (
                kb.transpose(2, 0, 1, 3, 4),
                vb.transpose(2, 0, 1, 3, 4),
                jnp.arange(sk_p // bk),
            ),
        )
        l = jnp.where(l == 0.0, 1.0, l)
        return carry, (acc / l).astype(q.dtype)

    _, ys = jax.lax.scan(
        q_block,
        None,
        (
            qp.reshape(b, h, sq_p // bq, bq, dh).transpose(2, 0, 1, 3, 4),
            jnp.arange(sq_p // bq),
        ),
    )
    out = ys.transpose(1, 2, 0, 3, 4).reshape(b, h, sq_p, dh)
    return out[:, :, :sq]


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------


def ssd(
    x: jnp.ndarray,  # (b, l, h, p)
    dt: jnp.ndarray,  # (b, l, h)
    a: jnp.ndarray,  # (h,)
    bmat: jnp.ndarray,  # (b, l, g, n)
    cmat: jnp.ndarray,  # (b, l, g, n)
    d: Optional[jnp.ndarray] = None,
    *,
    impl: str = "auto",
    chunk: int = 128,
    initial_state: Optional[jnp.ndarray] = None,  # (b, h, p, n)
    return_state: bool = False,
):
    """SSD scan; optionally seeded with / returning the (b,h,p,n) state —
    the prefill path (chunked scan + final state handoff to decode)."""
    impl = _resolve(impl)
    if impl in ("pallas", "interpret") and not return_state and initial_state is None:
        return ssd_scan_pallas(
            x, dt, a, bmat, cmat, d, chunk=chunk,
            interpret=(impl == "interpret"),
        )
    if impl == "reference" and not return_state and initial_state is None:
        return ref.ssd_reference(x, dt, a, bmat, cmat, d)
    if impl in ("chunked", "pallas", "interpret", "reference"):
        return _ssd_chunked(
            x, dt, a, bmat, cmat, d, chunk,
            initial_state=initial_state, return_state=return_state,
        )
    raise ValueError(f"unknown impl={impl!r}")


def _ssd_chunked(x, dt, a, bmat, cmat, d, chunk, *,
                 initial_state=None, return_state=False):
    """Pure-jnp chunked SSD — same blocking as the Pallas kernel, with the
    inter-chunk state carried by lax.scan.  O(l·c) score memory."""
    b, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hpg = h // g

    c = min(chunk, l)
    l_p = ((l + c - 1) // c) * c
    xp = jnp.pad(x, ((0, 0), (0, l_p - l), (0, 0), (0, 0)))
    dtp = jnp.pad(dt, ((0, 0), (0, l_p - l), (0, 0)))
    bp = jnp.pad(bmat, ((0, 0), (0, l_p - l), (0, 0), (0, 0)))
    cp = jnp.pad(cmat, ((0, 0), (0, l_p - l), (0, 0), (0, 0)))

    nc = l_p // c
    # (nc, b, c, h, p) etc.
    xc = xp.reshape(b, nc, c, h, p).transpose(1, 0, 2, 3, 4)
    dtc = dtp.reshape(b, nc, c, h).transpose(1, 0, 2, 3)
    bc = bp.reshape(b, nc, c, g, n).transpose(1, 0, 2, 3, 4)
    cc = cp.reshape(b, nc, c, g, n).transpose(1, 0, 2, 3, 4)

    def chunk_step(hstate, inputs):
        xi, dti, bi, ci = inputs
        adt = dti * a[None, None, :]  # (b, c, h)
        cs = jnp.cumsum(adt, axis=1)  # (b, c, h)
        cs_tot = cs[:, -1:, :]
        bih = jnp.repeat(bi, hpg, axis=2)  # (b, c, h, n)
        cih = jnp.repeat(ci, hpg, axis=2)

        gmat = jnp.einsum("bthn,bshn->bhts", cih, bih)  # (b, h, c, c)
        delta = cs[:, :, None, :] - cs[:, None, :, :]  # (b, t, s, h)
        tri = jnp.tril(jnp.ones((c, c), bool))
        m = jnp.where(
            tri[None, :, :, None],
            jnp.exp(jnp.where(tri[None, :, :, None], delta, 0.0))
            * dti[:, None, :, :],
            0.0,
        ).transpose(0, 3, 1, 2)  # (b, h, t, s)
        y = jnp.einsum("bhts,bshp->bthp", m * gmat, xi)

        y = y + jnp.exp(cs)[..., None] * jnp.einsum(
            "bthn,bhpn->bhtp", cih, hstate
        ).transpose(0, 2, 1, 3)

        bw = bih * (jnp.exp(cs_tot - cs) * dti)[..., None]  # (b, c, h, n)
        hnew = jnp.exp(cs_tot[:, 0, :])[:, :, None, None] * hstate + (
            jnp.einsum("bshp,bshn->bhpn", xi, bw)
        )
        return hnew, y

    h0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    h_final, yc = jax.lax.scan(chunk_step, h0, (xc, dtc, bc, cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, l_p, h, p)[:, :l]
    y = y.astype(x.dtype)
    if d is not None:
        y = y + x * d[None, None, :, None]
    if return_state:
        # NOTE: with l_p > l the padded tail has dt=0 ⇒ identity updates,
        # so h_final is exact for the true length.
        return y, h_final
    return y


def ssd_decode_step(
    hstate: jnp.ndarray,  # (b, h, p, n)
    x_t: jnp.ndarray,  # (b, h, p)
    dt_t: jnp.ndarray,  # (b, h)
    a: jnp.ndarray,  # (h,)
    b_t: jnp.ndarray,  # (b, g, n)
    c_t: jnp.ndarray,  # (b, g, n)
    d: Optional[jnp.ndarray] = None,
):
    """One SSD decode step: O(h·p·n), the SSM analogue of a KV-cache read.

    Returns ``(new_state, y_t)``.
    """
    h = x_t.shape[1]
    hpg = h // b_t.shape[1]
    decay = jnp.exp(a[None, :] * dt_t)  # (b, h)
    bth = jnp.repeat(b_t, hpg, axis=1)
    cth = jnp.repeat(c_t, hpg, axis=1)
    upd = jnp.einsum("bhp,bhn->bhpn", x_t * dt_t[..., None], bth)
    new = hstate * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new, cth)
    if d is not None:
        y = y + x_t * d[None, :, None]
    return new, y.astype(x_t.dtype)
