"""Mamba2 SSD (state-space duality) chunked-scan Pallas kernel.

The SSD recurrence  ``h_t = exp(a·dt_t)·h_{t−1} + dt_t·B_t x_tᵀ``,
``y_t = C_t h_t``  is evaluated with the chunked dual form (arXiv
2405.21060): the sequence is split into chunks of size ``c``; within a
chunk the contribution is a masked quadratic form (three MXU matmuls),
between chunks only the (p × n) state is carried:

    cs_t   = Σ_{u≤t} a·dt_u                       (log-decay cumsum, ≤ 0)
    G      = C_chunk B_chunkᵀ                     (c × c,   MXU)
    M[t,s] = exp(cs_t − cs_s)·dt_s·[s ≤ t]        (VPU)
    Y      = (M ⊙ G) X  +  exp(cs)·(C H0ᵀ)        (two MXU matmuls)
    H1     = exp(cs_c)·H0 + Xᵀ·(exp(cs_c − cs)·dt ⊙ B)

All decay exponents are ≤ 0 (a < 0, dt ≥ 0) so every ``exp`` is in (0, 1]
— numerically safe in f32.

Grid: ``(batch, heads, n_chunks)`` with chunks innermost and *sequential*
("arbitrary" semantics) — the (p × n) state lives in VMEM scratch across
chunk steps.  batch/head grid dims are parallel.  This is the TPU-native
replacement for the paper-adjacent GPU pattern of one threadblock per
(batch, head) scanning serially: on TPU the systolic MXU does the chunk
quadratics while the sequential grid carries the recurrence.

The wrapper folds ``a`` into precomputed ``a·dt`` so the kernel body has no
per-head scalar indexing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, adt_ref, b_ref, c_ref, y_ref, h_ref):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)  # (c, p)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (c, 1)
    adt = adt_ref[0, 0].astype(jnp.float32)  # (c, 1)
    bmat = b_ref[0, 0].astype(jnp.float32)  # (c, n)
    cmat = c_ref[0, 0].astype(jnp.float32)  # (c, n)

    cs = jnp.cumsum(adt, axis=0)  # (c, 1) inclusive, ≤ 0 decreasing
    cs_total = cs[-1:, :]  # (1, 1)

    # Intra-chunk masked quadratic.
    g = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (c, c): G[t, s] = C_t·B_s
    delta = cs - cs.T  # (c, c): cs_t − cs_s
    t_idx = jax.lax.broadcasted_iota(jnp.int32, g.shape, 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, g.shape, 1)
    mask = s_idx <= t_idx
    m = jnp.where(mask, jnp.exp(jnp.where(mask, delta, 0.0)) * dt.T, 0.0)
    y = jax.lax.dot_general(
        m * g, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (c, p)

    # Inter-chunk: contribution of the carried state.
    h0 = h_ref[...]  # (p, n)
    y += jnp.exp(cs) * jax.lax.dot_general(
        cmat, h0, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (c, p)

    # State update for the next chunk.
    bw = bmat * (jnp.exp(cs_total - cs) * dt)  # (c, n)
    h_ref[...] = jnp.exp(cs_total) * h0 + jax.lax.dot_general(
        x, bw, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (p, n)

    y_ref[0, 0] = y.astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def ssd_scan_pallas(
    x: jnp.ndarray,  # (b, l, h, p)
    dt: jnp.ndarray,  # (b, l, h)  (≥ 0, post-softplus)
    a: jnp.ndarray,  # (h,)        (< 0)
    bmat: jnp.ndarray,  # (b, l, g, n)
    cmat: jnp.ndarray,  # (b, l, g, n)
    d: jnp.ndarray | None = None,  # (h,) skip
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hpg = h // g

    c = min(chunk, _round_up(l, 8))
    l_p = _round_up(l, c)

    # Head-major layouts; fold a into a·dt; expand B/C across head groups.
    xh = jnp.pad(x.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, l_p - l), (0, 0)))
    dth = jnp.pad(dt.transpose(0, 2, 1), ((0, 0), (0, 0), (0, l_p - l)))[..., None]
    adth = dth * a[None, :, None, None]
    bh = jnp.repeat(bmat.transpose(0, 2, 1, 3), hpg, axis=1)
    ch = jnp.repeat(cmat.transpose(0, 2, 1, 3), hpg, axis=1)
    bh = jnp.pad(bh, ((0, 0), (0, 0), (0, l_p - l), (0, 0)))
    ch = jnp.pad(ch, ((0, 0), (0, 0), (0, l_p - l), (0, 0)))

    grid = (b, h, l_p // c)
    y = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, c, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, c, 1), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, c, 1), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, c, n), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, c, n), lambda bi, hi, ci: (bi, hi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, c, p), lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, l_p, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="ssd_chunked_scan",
    )(xh, dth, adth, bh, ch)

    y = y[:, :, :l, :].transpose(0, 2, 1, 3)  # (b, l, h, p)
    if d is not None:
        y = y + x * d[None, None, :, None]
    return y


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
