"""Flash-attention (online-softmax) Pallas kernel with GQA + causal masking.

TPU adaptation of the memory-efficient attention algorithm: the (sq × sk)
score matrix is never materialized in HBM.  Grid is
``(batch·q_heads, sq/bq, sk/bk)`` with the KV dimension innermost
("arbitrary" semantics); running max ``m``, normalizer ``l`` and the
unnormalized accumulator live in VMEM scratch and persist across KV steps.

Causal handling: KV blocks strictly above the diagonal are skipped with
``pl.when`` (no flops, no VMEM traffic for the masked region beyond the
pipelined fetch), diagonal blocks are masked elementwise.  For decode
(sq == 1 with a long KV cache) the same kernel is used with ``q_offset =
cache_len − 1``.

Block sizes default to MXU/VPU-aligned (128); the wrapper in ops.py pads
sq/sk as needed (padding keys are masked out via −inf logits).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tpu_compat import CompilerParams

_NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    q_offset: int,
    block_q: int,
    block_k: int,
    sk_valid: int,
):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = q_offset + iq * block_q
    k_start = jk * block_k

    # A KV block participates unless (causal and) it lies fully above the
    # diagonal of the *last* query row in this block.
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, dh)
        k = k_ref[0].astype(jnp.float32)  # (bk, dh)
        v = v_ref[0].astype(jnp.float32)  # (bk, dh)

        s = (
            jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            * scale
        )  # (bq, bk)

        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < sk_valid  # padding keys
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # (bq, bk)
        correction = jnp.exp(m_prev - m_new)
        l_new = l_prev * correction + jnp.sum(p, axis=1, keepdims=True)

        acc_ref[...] = acc_ref[...] * correction + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(jk == pl.num_programs(2) - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows → zeros
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "scale", "q_offset", "block_q", "block_k", "interpret"
    ),
)
def flash_attention_pallas(
    q: jnp.ndarray,  # (b, h, sq, dh)
    k: jnp.ndarray,  # (b, hkv, sk, dh)
    v: jnp.ndarray,  # (b, hkv, sk, dh)
    *,
    causal: bool = False,
    scale: float | None = None,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    group = h // hkv
    scale = dh**-0.5 if scale is None else scale

    bq = min(block_q, _round_up(sq, 8))
    bk = min(block_k, _round_up(sk, 8))
    sq_p = _round_up(sq, bq)
    sk_p = _round_up(sk, bk)
    dh_p = _round_up(dh, 128)

    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, dh_p - dh)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, dh_p - dh)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, dh_p - dh)))

    qp = qp.reshape(b * h, sq_p, dh_p)
    kp = kp.reshape(b * hkv, sk_p, dh_p)
    vp = vp.reshape(b * hkv, sk_p, dh_p)

    grid = (b * h, sq_p // bq, sk_p // bk)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        q_offset=q_offset,
        block_q=bq,
        block_k=bk,
        sk_valid=sk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh_p), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec(
                (1, bk, dh_p), lambda bh, i, j, g=group: (bh // g, j, 0)
            ),
            pl.BlockSpec(
                (1, bk, dh_p), lambda bh, i, j, g=group: (bh // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, bq, dh_p), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, dh_p), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh_p), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention_gqa",
    )(qp, kp, vp)
    return out.reshape(b, h, sq_p, dh_p)[:, :, :sq, :dh]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
