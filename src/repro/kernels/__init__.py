"""repro.kernels — Pallas TPU kernels for the perf-critical compute layers.

Contract per kernel: `<name>.py` holds the `pl.pallas_call` + BlockSpec
tiling, `ref.py` the pure-jnp oracle, `ops.py` the public jit'd wrapper
with impl dispatch (pallas | interpret | reference | chunked | auto).
"""

from repro.kernels import ops, ref
from repro.kernels.ops import (
    attention,
    fused_cg_update,
    fused_deflate_direction,
    rbf_matvec,
    ssd,
    ssd_decode_step,
)

__all__ = [
    "ops",
    "ref",
    "attention",
    "fused_cg_update",
    "fused_deflate_direction",
    "rbf_matvec",
    "ssd",
    "ssd_decode_step",
]
