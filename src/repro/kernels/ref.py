"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

These are the *semantic definitions*: simple, obviously-correct,
materialize-everything implementations that the kernels must match
(``tests/test_kernels.py`` sweeps shapes/dtypes with assert_allclose).
They are also what the CPU smoke tests run.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# RBF Gram matvec
# ---------------------------------------------------------------------------


def rbf_gram(x: jnp.ndarray, theta: float, lengthscale: float) -> jnp.ndarray:
    """Materialized RBF kernel Gram matrix K(X, X) — O(n²) memory."""
    d2 = (
        jnp.sum(x * x, 1)[:, None]
        + jnp.sum(x * x, 1)[None, :]
        - 2.0 * (x @ x.T)
    )
    d2 = jnp.maximum(d2, 0.0)
    return (theta**2) * jnp.exp(-0.5 * d2 / (lengthscale**2))


def rbf_matvec(
    x: jnp.ndarray, v: jnp.ndarray, theta: float, lengthscale: float
) -> jnp.ndarray:
    """``K(X,X) @ v`` by materializing K — oracle for the fused kernel."""
    k = rbf_gram(x, theta, lengthscale)
    return k @ v


def rbf_matvec_rect(
    x_rows: jnp.ndarray,
    x_cols: jnp.ndarray,
    v: jnp.ndarray,
    theta: float,
    lengthscale: float,
) -> jnp.ndarray:
    """``K(X_rows, X_cols) @ v`` by materializing the rectangular Gram
    block — oracle for the sharded-operator row-tile kernel."""
    xr = x_rows / lengthscale
    xc = x_cols / lengthscale
    d2 = (
        jnp.sum(xr * xr, 1)[:, None]
        + jnp.sum(xc * xc, 1)[None, :]
        - 2.0 * (xr @ xc.T)
    )
    d2 = jnp.maximum(d2, 0.0)
    return (theta**2) * jnp.exp(-0.5 * d2) @ v


# ---------------------------------------------------------------------------
# Fused CG iteration updates — oracles for cg_fused
# ---------------------------------------------------------------------------


def fused_cg_update(x, r, p, ap, alpha, aw=None):
    """Semantic definition of the fused CG state update.

    Returns ``(x + α p, r − α ap, ‖r_new‖², AW @ r_new | None)`` — the
    four quantities one def-CG iteration needs after the matvec.
    """
    x_new = x + alpha * p
    r_new = r - alpha * ap
    rr = jnp.vdot(r_new, r_new)
    awr = aw @ r_new if aw is not None else None
    return x_new, r_new, rr, awr


def fused_rz_reduce(r, z, aw=None):
    """Semantic definition of the preconditioned-iteration reductions.

    Returns ``(rᵀz, AW @ z | None)`` — the recurrence scalar of PCG and
    the deflation GEMV taken in the preconditioned inner product.
    """
    rz = jnp.vdot(r, z)
    awz = aw @ z if aw is not None else None
    return rz, awz


def fused_deflate_direction(
    r, p, beta, w=None, mu=None, ap=None, idx=None, p_buf=None, ap_buf=None
):
    """Semantic definition of the fused direction update + recording.

    ``p_new = β p + r − μᵀ W``; when buffers are given, the *incoming*
    ``(p, ap)`` pair is stored into row ``idx`` (callers guard the write
    by pointing ``idx`` at a spare row).  Returns ``(p_new, p_buf,
    ap_buf)``.
    """
    p_new = beta * p + r
    if w is not None:
        p_new = p_new - mu @ w
    if p_buf is not None:
        p_buf = p_buf.at[idx].set(p)
        ap_buf = ap_buf.at[idx].set(ap)
    return p_new, p_buf, ap_buf


def lsmr_update(x, hbar, h, v, c0, c1, c2):
    """Semantic definition of the fused LSMR iteration update.

    One LSMR iteration's three vector recurrences (Fong & Saunders 2011,
    with the rotation scalars pre-reduced by the caller):

        hbar_new = h − c0·hbar        (c0 = θ̄ρ / (ρ_old ρ̄_old))
        x_new    = x + c1·hbar_new    (c1 = ζ / (ρρ̄))
        h_new    = v − c2·h           (c2 = θ_new / ρ)

    Returns ``(x_new, hbar_new, h_new)``.
    """
    hbar_new = h - c0 * hbar
    x_new = x + c1 * hbar_new
    h_new = v - c2 * h
    return x_new, hbar_new, h_new


def recombine_blocks(s: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Semantic definition of the stacked two-block recombination GEMM.

    ``s`` is a ``(2m, n)`` stack of two row-bases ``[Z; AZ]`` and ``u`` an
    ``(m, k)`` recombination matrix; the result is the ``(2k, n)`` stack
    ``[uᵀ Z; uᵀ AZ]`` — both the next recycled basis ``W' = uᵀZ`` and its
    operator products ``AW' = uᵀAZ`` rebuilt from already-stored
    quantities in ONE pass over the basis data (the paper's zero-extra-
    matvec refresh; see ``core/strategies.py``).  Accumulates in at least
    f32 (f64-preserving).
    """
    m = u.shape[0]
    acc = (
        jnp.float64 if s.dtype == jnp.float64
        else jnp.promote_types(s.dtype, jnp.float32)
    )
    ua = u.astype(acc)
    sa = s.astype(acc)
    return jnp.concatenate([ua.T @ sa[:m], ua.T @ sa[m:]], axis=0).astype(
        s.dtype
    )


def self_gram(s: jnp.ndarray) -> jnp.ndarray:
    """Semantic definition of the stacked self-Gram ``S Sᵀ``.

    ``S`` is an ``(m, n)`` stacked flat basis (rows are vectors); the
    result is the ``(m, m)`` Gram matrix accumulated in at least f32 —
    the single tall-skinny GEMM the harmonic-Ritz extraction builds its
    ``G``/``F`` blocks from (stack ``[Z; AZ]`` and slice the quadrants).
    """
    acc = (
        jnp.float64 if s.dtype == jnp.float64
        else jnp.promote_types(s.dtype, jnp.float32)
    )
    sa = s.astype(acc)
    return sa @ sa.T


# ---------------------------------------------------------------------------
# Attention (GQA, optional causal) — oracle for flash_attention
# ---------------------------------------------------------------------------


def mha_attention(
    q: jnp.ndarray,  # (b, h, sq, dh)
    k: jnp.ndarray,  # (b, hkv, sk, dh)
    v: jnp.ndarray,  # (b, hkv, sk, dh)
    *,
    causal: bool = False,
    scale: float | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Reference softmax attention with grouped KV heads.

    ``q_offset`` positions the query block at absolute position
    ``q_offset + i`` for causal masking (decode: sq=1, q_offset=cache_len-1).
    """
    b, h, sq, dh = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = dh**-0.5 if scale is None else scale

    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    if causal:
        sk = k.shape[2]
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), vv)


# ---------------------------------------------------------------------------
# Mamba2 SSD — oracle: the exact sequential state-space recurrence
# ---------------------------------------------------------------------------


def ssd_reference(
    x: jnp.ndarray,  # (b, l, h, p)   inputs per head
    dt: jnp.ndarray,  # (b, l, h)     softplus-ed step sizes (>0)
    a: jnp.ndarray,  # (h,)           negative decay rates (a < 0)
    bmat: jnp.ndarray,  # (b, l, g, n)  input projections ("B")
    cmat: jnp.ndarray,  # (b, l, g, n)  output projections ("C")
    d: jnp.ndarray | None = None,  # (h,) skip connection
) -> jnp.ndarray:
    """Sequential SSD recurrence (state-space duality, arXiv 2405.21060):

        h_t = exp(a·dt_t) · h_{t-1} + dt_t · B_t x_tᵀ      (per head)
        y_t = C_t h_t (+ D x_t)

    with ``g`` B/C groups shared across ``h`` heads (h % g == 0).
    O(l·n·p) time — slow but exact; the chunked kernel must match it.
    """
    b, l, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    heads_per_group = h // g

    def step(state, inputs):
        xt, dtt, bt, ct = inputs  # (b,h,p), (b,h), (b,g,n), (b,g,n)
        decay = jnp.exp(a[None, :] * dtt)  # (b, h)
        bth = jnp.repeat(bt, heads_per_group, axis=1)  # (b, h, n)
        cth = jnp.repeat(ct, heads_per_group, axis=1)
        upd = (dtt * xt.transpose(2, 0, 1)).transpose(1, 2, 0)  # dt*x (b,h,p)
        new = state * decay[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", upd, bth
        )
        yt = jnp.einsum("bhpn,bhn->bhp", new, cth)
        return new, yt

    state0 = jnp.zeros((b, h, p, n), x.dtype)
    xs = (
        x.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
        bmat.transpose(1, 0, 2, 3),
        cmat.transpose(1, 0, 2, 3),
    )
    import jax

    _, ys = jax.lax.scan(step, state0, xs)
    y = ys.transpose(1, 0, 2, 3)  # (b, l, h, p)
    if d is not None:
        y = y + x * d[None, None, :, None]
    return y
