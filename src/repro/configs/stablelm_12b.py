"""stablelm-12b — dense GQA with partial rotary [hf:stabilityai].

40L, d_model 5120, 32H (kv=8), SwiGLU d_ff 13824, LayerNorm, 25% rotary,
vocab 100352.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    norm_type="layer",
    rope_pct=0.25,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="stablelm-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    dtype="float32",
)
