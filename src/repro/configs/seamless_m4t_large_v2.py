"""seamless-m4t-large-v2 — encoder-decoder, multimodal [arXiv:2308.11596].

24L encoder + 24L decoder, d_model 1024, 16H (kv=16), GELU d_ff 8192,
vocab 256206, sinusoidal positions (no RoPE), cross-attention.  The
speech frontend is a STUB: input_specs() feeds precomputed frame
embeddings (B, S_src, d_model) to the encoder.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    encoder_layers=24,
    cross_attention=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    mlp_type="gelu",
    norm_type="layer",
    rope=False,
    input_mode="embeddings",
    source_len=4096,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="seamless-smoke",
    n_layers=2,
    encoder_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    source_len=32,
    dtype="float32",
)
