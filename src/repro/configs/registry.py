"""Architecture registry: ``--arch <id>`` resolution + shape grid.

Every assigned architecture registers (full config, reduced smoke config).
The shape grid (train_4k / prefill_32k / decode_32k / long_500k) and the
per-arch skip rules (DESIGN.md §4) live here so the dry-run, benchmarks
and tests share one source of truth.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional, Tuple

from repro.models.config import ModelConfig

_ARCH_MODULES = {
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "arctic-480b": "repro.configs.arctic_480b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0_5b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
}

ARCH_IDS = tuple(_ARCH_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.SMOKE


def is_subquadratic(cfg: ModelConfig) -> bool:
    """SSM / hybrid stacks handle 512k decode; pure attention does not."""
    return cfg.family in ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Skip rules per spec: long_500k only for sub-quadratic mixers."""
    if shape.name == "long_500k" and not is_subquadratic(cfg):
        return False, "pure full-attention arch — 512k decode skipped (DESIGN.md §4)"
    return True, ""


def grid():
    """All (arch, shape) dry-run cells with skip annotations."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            yield arch, cfg, shape, ok, why
