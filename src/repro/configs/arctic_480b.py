"""arctic-480b — dense-MoE hybrid: 128e top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base].

35L, d_model 7168, 56H (kv=8), d_ff 4864 both for the dense residual
branch and per expert.  On the fixed 16-way TP mesh, 56 query heads pad
to 64 (DESIGN.md §4).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    experts_per_token=2,
    dense_residual=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="arctic-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    n_experts=8,
    experts_per_token=2,
    capacity_factor=8.0,  # dropless at smoke scale: decode == forward invariant
    dtype="float32",
)
