"""olmoe-1b-7b — 64-expert top-8 MoE [arXiv:2409.02060].

16L, d_model 2048, 16H (kv=16), expert d_ff 1024, vocab 50304.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    n_experts=64,
    experts_per_token=8,
    qk_norm=True,  # OLMoE uses QK-norm
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="olmoe-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=96,
    vocab_size=256,
    n_experts=8,
    experts_per_token=2,
    capacity_factor=8.0,  # dropless at smoke scale: decode == forward invariant
    dtype="float32",
)
