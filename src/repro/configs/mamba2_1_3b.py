"""mamba2-1.3b — SSD (state-space duality) stack [arXiv:2405.21060].

48L, d_model 2048, attention-free; d_inner = 2·2048 = 4096, headdim 64 →
64 SSD heads, state n=128, 1 B/C group, conv4.  Vocab 50280 (GPT-NeoX).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    rope=False,
    tie_embeddings=True,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
    ssm_conv=4,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="mamba2-smoke",
    n_layers=2,
    d_model=64,
    vocab_size=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=32,
    dtype="float32",
)
