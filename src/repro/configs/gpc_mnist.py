"""gpc-mnist — the paper's own workload as a distributed config.

Laplace-approximation GP classification on the (synthetic) infinite-digits
3-vs-5 task: n data points sharded row-wise over the mesh, the fused RBF
Gram matvec as the CG hot-spot, def-CG(k, ell) with harmonic-Ritz
recycling across the Newton sequence.  `n` here is paper-scale; the CPU
benchmarks shrink it via `replace(n=...)`.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class GPCConfig:
    name: str = "gpc-mnist"
    n: int = 1_048_576          # paper-scale row count (2^20)
    d: int = 784
    theta: float = 3.0
    lengthscale: float = 3.0
    solver: str = "defcg"
    k: int = 8                  # recycled subspace size — def-CG(8, 12)
    ell: int = 12
    tol: float = 1e-5
    maxiter: int = 200
    newton_tol: float = 1.0
    max_newton: int = 12
    dtype: str = "float32"
    block: int = 1024           # fused-matvec row block


CONFIG = GPCConfig()
SMOKE = GPCConfig(name="gpc-smoke", n=256, maxiter=400, dtype="float64")
