"""repro.configs — one module per assigned architecture (+ the paper's own
GPC workload).  Use `repro.configs.registry.get_config(arch_id)`."""

from repro.configs.registry import (
    ARCH_IDS,
    SHAPES,
    ShapeSpec,
    get_config,
    get_smoke_config,
    grid,
    shape_applicable,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ShapeSpec",
    "get_config",
    "get_smoke_config",
    "grid",
    "shape_applicable",
]
