"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 with MoE [arXiv:2403.19887].

32L, d_model 4096, 32H (kv=8) on the attention layers (1 per 8, at period
position 4), MoE 16e top-2 every other layer, SwiGLU d_ff 14336.  SSD
adaptation of Jamba's Mamba layers (DESIGN.md §8): d_inner 8192, headdim
64 → 128 heads, state 16.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    n_experts=16,
    experts_per_token=2,
    moe_every=2,
    attn_every=8,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
    ssm_conv=4,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="jamba-smoke",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    n_experts=4,
    experts_per_token=2,
    capacity_factor=8.0,  # dropless at smoke scale: decode == forward invariant
    ssm_state=8,
    ssm_head_dim=16,
    ssm_chunk=32,
    dtype="float32",
)
