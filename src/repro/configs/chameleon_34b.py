"""chameleon-34b — early-fusion VLM backbone [arXiv:2405.09818].

48L, d_model 8192, 64H (kv=8), SwiGLU d_ff 22016, vocab 65536 (text + VQ
image codes), QK-norm.  The image tokenizer is a modality-frontend STUB:
input_specs() feeds precomputed VQ token ids (the backbone is what we
build, per the assignment).
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="chameleon-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    dtype="float32",
)
