"""starcoder2-3b — dense GQA code model [arXiv:2402.19173].

30L, d_model 3072, 24H (kv=2), GELU MLP d_ff 12288, LayerNorm, RoPE,
QKV bias, vocab 49152.  24 query heads pad to 32 on 16-way TP.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    mlp_type="gelu",
    norm_type="layer",
    qkv_bias=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="starcoder2-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    dtype="float32",
)
