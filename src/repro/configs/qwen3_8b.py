"""qwen3-8b — dense GQA with QK-norm [hf:Qwen/Qwen3-8B].

36L, d_model 4096, 32H (kv=8), head_dim 128, SwiGLU d_ff 12288,
vocab 151936.
"""

import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="qwen3-smoke",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    dtype="float32",
)
