"""repro.runtime — fault-tolerant training loop."""

from repro.runtime.trainer import Trainer, TrainerConfig, TrainerEvents

__all__ = ["Trainer", "TrainerConfig", "TrainerEvents"]
