"""Fault-tolerant training runtime.

The loop a pod-scale deployment needs, in one class:

* **checkpoint/restart** — resumes from the newest valid checkpoint
  (params + optimizer state incl. the solver's ``RecycleState`` + data
  position); the data pipeline is content-addressed by step so the
  stream continues exactly, and the first post-restore solve deflates
  with the recovered basis;
* **failure handling** — any exception in a step (device loss, injected
  fault) triggers restore-from-checkpoint and replay; a bounded retry
  budget prevents crash loops;
* **straggler mitigation** — per-step deadline tracking against a rolling
  median; steps exceeding ``straggler_factor ×`` median are logged and
  counted (on real multi-host deployments the hook is where you'd trigger
  data re-balancing / hot-standby swap; in-process we record and continue,
  and tests inject artificial delays to exercise the path);
* **preemption** — SIGTERM-style stop flag checkpoints synchronously and
  exits cleanly;
* **elasticity** — on restart the restore path re-shards onto whatever
  mesh the trainer now holds (checkpoint/manager.py).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint.manager import CheckpointManager

Pytree = Any


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 10
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    max_restarts: int = 5
    straggler_factor: float = 3.0
    straggler_window: int = 20


@dataclasses.dataclass
class TrainerEvents:
    restarts: int = 0
    stragglers: int = 0
    step_times: List[float] = dataclasses.field(default_factory=list)
    log: List[str] = dataclasses.field(default_factory=list)


class Trainer:
    """Drives ``step_fn(state, batch) -> (state, metrics)`` with fault
    tolerance.  ``state`` is one pytree holding params + optimizer state
    (+ recycle basis); ``make_batch(step)`` must be deterministic."""

    def __init__(
        self,
        step_fn: Callable[[Pytree, Any], Any],
        make_batch: Callable[[int], Any],
        init_state: Pytree,
        config: TrainerConfig,
        *,
        state_shardings: Optional[Pytree] = None,
        fault_hook: Optional[Callable[[int], None]] = None,
        time_fn: Callable[[], float] = time.perf_counter,
    ):
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.config = config
        self.state_shardings = state_shardings
        self.fault_hook = fault_hook
        self.time_fn = time_fn  # injectable clock (deterministic tests)
        self.events = TrainerEvents()
        self.ckpt = CheckpointManager(
            config.checkpoint_dir, keep=config.keep_checkpoints
        )
        self._stop = False

        restored = self.ckpt.restore_latest(init_state, state_shardings)
        if restored is not None:
            self.start_step, self.state, _ = restored
            self.events.log.append(f"resumed from step {self.start_step}")
        else:
            self.start_step, self.state = 0, init_state

    def request_stop(self):  # preemption signal (SIGTERM handler target)
        self._stop = True

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        cfg = self.config
        step = self.start_step
        restarts = 0
        last_metrics: Dict[str, Any] = {}

        while step < cfg.total_steps:
            if self._stop:
                self._save(step, blocking=True)
                self.events.log.append(f"preempted at step {step}")
                break
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)  # may raise (injected failure)
                t0 = self.time_fn()
                batch = self.make_batch(step)
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(
                    jax.tree_util.tree_leaves(self.state)[0]
                )
                dt = self.time_fn() - t0
                self._track_straggler(step, dt)
                last_metrics = metrics
                step += 1
                if step % cfg.checkpoint_every == 0:
                    self._save(step, blocking=not cfg.async_checkpoint)
            except Exception as exc:  # noqa: BLE001 — any step failure
                restarts += 1
                self.events.restarts = restarts
                self.events.log.append(f"step {step} failed: {exc!r}")
                if restarts > cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={cfg.max_restarts}"
                    ) from exc
                restored = self.ckpt.restore_latest(
                    self.state, self.state_shardings
                )
                if restored is not None:
                    step, self.state, _ = restored
                    self.events.log.append(f"restored to step {step}")
                else:
                    step = 0
                    self.events.log.append("no checkpoint — restart from 0")

        self.ckpt.wait()
        self._save(step, blocking=True)
        return {
            "final_step": step,
            "state": self.state,
            "metrics": last_metrics,
            "events": self.events,
        }

    # ------------------------------------------------------------------
    def _save(self, step: int, blocking: bool):
        self.ckpt.save(
            self.state, step, extra={"step": step}, blocking=blocking
        )

    def _track_straggler(self, step: int, dt: float):
        times = self.events.step_times
        times.append(dt)
        w = self.config.straggler_window
        if len(times) >= 5:
            med = statistics.median(times[-w:])
            if dt > self.config.straggler_factor * med:
                self.events.stragglers += 1
                self.events.log.append(
                    f"straggler: step {step} took {dt:.3f}s "
                    f"(median {med:.3f}s) — mitigation hook fired"
                )
