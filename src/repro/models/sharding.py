"""Activation/parameter sharding environment.

Model code annotates activations with *logical* dimension names
(``shard(x, "batch", None, "model")``); the launcher binds those names to
physical mesh axes via :func:`set_axis_env`.  With no environment bound
(CPU unit tests), every annotation is a no-op — the same model code runs
on 1 device and on a 512-chip two-pod mesh.

Parameter shardings are produced structurally: every ``init`` function in
:mod:`repro.models` builds params as dicts whose leaf *names* carry the
sharding intent, and :func:`param_specs` maps names to ``PartitionSpec``s:

  leaf-name suffix        spec (logical)          physical (default env)
  ----------------------  ----------------------  ----------------------
  ``*_cs`` (column)       (None, "model")         TP column-parallel
  ``*_rs`` (row)          ("model", None)         TP row-parallel
  ``*_es`` (expert)       ("model", None, None)   expert-parallel
  ``*_vs`` (vocab-major)  ("model", None)         vocab-sharded embedding
  ``*_hs`` (head-vector)  ("model",)              per-head vectors
  anything else           fully replicated

Stacked period params (leading scan axis) get the spec shifted right by
one ``None``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Pytree = Any

# Logical-name → mesh-axis (or tuple of axes) binding.  None → no-op.
_ENV: Optional[Dict[str, Union[str, Tuple[str, ...], None]]] = None


def set_axis_env(env: Optional[Dict[str, Any]]) -> None:
    """Bind logical dimension names to physical mesh axes (None to clear).

    The production binding (launch/mesh.py) is
    ``{"batch": ("pod", "data"), "model": "model", "seq": "data"}``.
    """
    global _ENV
    _ENV = env


def get_axis_env():
    return _ENV


def axis_size(name: str) -> int:
    """Product of mesh-axis sizes bound to a logical name (1 if unbound)."""
    if _ENV is None or _ENV.get(name) is None:
        return 1
    axes = _ENV[name]
    axes = (axes,) if isinstance(axes, str) else axes
    import numpy as np

    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    return int(np.prod([dict(zip(mesh.axis_names, mesh.shape))[a] for a in axes]))


def logical_to_spec(dims: Sequence[Optional[str]]) -> P:
    assert _ENV is not None
    return P(*[_ENV.get(d) if d else None for d in dims])


def shard(x: jax.Array, *dims: Optional[str]) -> jax.Array:
    """Constrain activation sharding by logical dim names (no-op unbound)."""
    if _ENV is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_to_spec(dims))


# ---------------------------------------------------------------------------
# Parameter specs from leaf-name suffixes
# ---------------------------------------------------------------------------

_SUFFIX_DIMS = {
    "_cs": (None, "model"),
    "_rs": ("model", None),
    "_es": ("model", None, None),
    "_vs": ("model", None),
    "_hs": ("model",),
}


def spec_for_leaf(name: str, ndim: int, stacked: bool) -> P:
    dims: Tuple[Optional[str], ...] = ()
    for suffix, d in _SUFFIX_DIMS.items():
        if name.endswith(suffix):
            dims = d
            break
    pad = ndim - len(dims) - (1 if stacked else 0)
    full = ((None,) if stacked else ()) + (None,) * max(pad, 0) + dims
    if _ENV is None:
        return P(*full[:ndim])
    return P(*[_ENV.get(d) if d else None for d in full[:ndim]])


def param_specs(params: Pytree, stacked_prefix: str = "periods") -> Pytree:
    """PartitionSpec tree mirroring ``params`` (see module docstring)."""

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            out = [walk(v, path + (str(i),)) for i, v in enumerate(tree)]
            return type(tree)(out)
        stacked = any(p == stacked_prefix for p in path)
        return spec_for_leaf(path[-1], tree.ndim, stacked)

    return walk(params, ())
