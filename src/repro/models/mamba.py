"""Mamba2 (SSD) mixer block: in-proj → causal conv → SSD → gated norm → out.

Follows the Mamba2 block layout (arXiv 2405.21060): a single input
projection produces [z (gate), x (heads·headdim), B, C (groups·state),
dt (heads)]; x/B/C pass through a short causal depthwise conv; the SSD
scan (Pallas kernel on TPU, chunked-jnp elsewhere — repro/kernels) runs
the state-space mixing; output is RMS-gated by silu(z) and projected back.

Head sharding: the ``d_inner`` feature dim (heads·headdim) is
column-sharded over the ``model`` axis; B/C groups are small (g=1 for the
assigned configs) and stay replicated — the TPU-native layout for SSD
(heads are embarrassingly parallel; only the out-proj row-reduces).

Decode state = (conv tail (K−1 inputs), SSD state (h, p, n)) — the SSM
analogue of a KV cache, O(1) in sequence length.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.models.sharding import shard


class SSMState(NamedTuple):
    conv: jnp.ndarray  # (B, K-1, conv_dim) rolling input tail
    ssd: jnp.ndarray  # (B, H, P, N)


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    h = cfg.n_ssm_heads
    p = cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    conv_dim = di + 2 * g * n
    return di, h, p, g, n, conv_dim


def mamba_init(key, cfg: ModelConfig):
    pd = jnp.dtype(cfg.param_dtype)
    di, h, p, g, n, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * di + 2 * g * n + h
    return {
        "in_proj_cs": dense_init(ks[0], cfg.d_model, d_in_proj, pd),
        "conv_w_rs": jax.random.normal(ks[1], (conv_dim, cfg.ssm_conv), pd)
        * jnp.asarray(cfg.ssm_conv**-0.5, pd),
        "conv_b_hs": jnp.zeros((conv_dim,), pd),
        "a_log_hs": jnp.log(
            jax.random.uniform(ks[2], (h,), pd, minval=1.0, maxval=16.0)
        ),
        "dt_bias_hs": jnp.log(
            jnp.expm1(
                jax.random.uniform(ks[3], (h,), pd, minval=1e-3, maxval=0.1)
            )
        ),
        "d_skip_hs": jnp.ones((h,), pd),
        "gate_norm_hs": jnp.ones((di,), pd),
        "out_proj_rs": dense_init(ks[4], di, cfg.d_model, pd),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    di, h, p, g, n, _ = _dims(cfg)
    z, xin, bmat, cmat, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * n, 2 * di + 2 * g * n], axis=-1
    )
    return z, xin, bmat, cmat, dt


def _causal_conv(seq, w, b):
    """Depthwise causal conv over (B, S, C) with kernel (C, K)."""
    k = w.shape[-1]
    pad = jnp.pad(seq, ((0, 0), (k - 1, 0), (0, 0)))
    stacked = jnp.stack(
        [pad[:, i : i + seq.shape[1], :] for i in range(k)], axis=-1
    )  # (B, S, C, K)
    return jnp.einsum("bsck,ck->bsc", stacked, w.astype(seq.dtype)) + b.astype(
        seq.dtype
    )


def mamba_apply(
    params,
    xres: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    *,
    state: Optional[SSMState] = None,
) -> Tuple[jnp.ndarray, Optional[SSMState]]:
    """Full-sequence scan (state=None) or stateful stepping (decode).

    Decode calls with S small (typically 1) update the conv tail and SSD
    state and return them.
    """
    dt_ = xres.dtype
    b, s, _ = xres.shape
    di, h, p, g, n, conv_dim = _dims(cfg)

    zxbcdt = xres @ params["in_proj_cs"].astype(dt_)
    z, xin, bmat, cmat, dtraw = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)  # (B,S,conv_dim)

    new_state = None
    if state is None:
        conv_out = _causal_conv(
            conv_in, params["conv_w_rs"], params["conv_b_hs"]
        )
    else:
        ktail = cfg.ssm_conv - 1
        hist = jnp.concatenate([state.conv, conv_in], axis=1)
        conv_out = _causal_conv(
            hist, params["conv_w_rs"], params["conv_b_hs"]
        )[:, ktail:]
        new_conv = jax.lax.dynamic_slice_in_dim(
            hist, hist.shape[1] - ktail, ktail, axis=1
        )

    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(dt_)
    xc, bc, cc = jnp.split(conv_out, [di, di + g * n], axis=-1)

    xh = xc.reshape(b, s, h, p)
    xh = shard(xh, "batch", None, "model", None)
    bh = bc.reshape(b, s, g, n)
    ch = cc.reshape(b, s, g, n)
    dt_act = jax.nn.softplus(
        dtraw.astype(jnp.float32) + params["dt_bias_hs"].astype(jnp.float32)
    )
    a = -jnp.exp(params["a_log_hs"].astype(jnp.float32))
    d_skip = params["d_skip_hs"].astype(jnp.float32)

    impl = (
        cfg.attn_impl
        if cfg.attn_impl in ("pallas", "interpret")
        else "chunked"
    )
    if state is None:
        y = kops.ssd(
            xh, dt_act.astype(jnp.float32), a, bh, ch, d_skip,
            impl=impl, chunk=cfg.ssm_chunk,
        )
    elif s > 1:
        # Prefill: chunked scan seeded with the carried state; hand the
        # final state to decode.  (Perf iteration #1: the naive path ran
        # the O(1)-decode step S times — 32k sequential state r/w's.)
        y, ssd_state = kops.ssd(
            xh, dt_act.astype(jnp.float32), a, bh, ch, d_skip,
            impl="chunked", chunk=cfg.ssm_chunk,
            initial_state=state.ssd, return_state=True,
        )
        new_state = SSMState(conv=new_conv, ssd=ssd_state)
    else:
        def step1(carry, inp):
            xt, dtt, bt, ct = inp
            new, yt = kops.ssd_decode_step(carry, xt, dtt, a, bt, ct, d_skip)
            return new, yt

        ssd_state, ys = jax.lax.scan(
            step1,
            state.ssd,
            (
                xh.transpose(1, 0, 2, 3).astype(jnp.float32),
                dt_act.transpose(1, 0, 2),
                bh.transpose(1, 0, 2, 3).astype(jnp.float32),
                ch.transpose(1, 0, 2, 3).astype(jnp.float32),
            ),
        )
        y = ys.transpose(1, 0, 2, 3)
        new_state = SSMState(conv=new_conv, ssd=ssd_state)

    y = y.reshape(b, s, di).astype(dt_)

    # Gated RMS norm (Mamba2's norm-before-out-proj).
    zf = jax.nn.silu(z.astype(jnp.float32))
    yf = y.astype(jnp.float32) * zf
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (
        yf * jax.lax.rsqrt(ms + cfg.norm_eps) * params["gate_norm_hs"].astype(jnp.float32)
    ).astype(dt_)

    out = y @ params["out_proj_rs"].astype(dt_)
    return out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=None) -> SSMState:
    dtype = dtype or jnp.dtype(cfg.dtype)
    di, h, p, g, n, conv_dim = _dims(cfg)
    return SSMState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        ssd=jnp.zeros((batch, h, p, n), jnp.float32),
    )
