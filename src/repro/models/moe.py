"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Design (token-dropping, sort-based dispatch — the cost-realistic layout):

  1. router logits → softmax → top-k (gates, expert ids) per token;
  2. assignments sorted by expert (stable ⇒ token-major priority), each
     assignment gets a position-in-expert; positions ≥ capacity are dropped
     (capacity C = ceil(T·k/E · capacity_factor));
  3. gather the kept tokens into an (E, C, d) buffer, run all experts as
     one batched einsum pair (MXU-friendly, expert dim shardable), and
     scatter-add the gate-weighted outputs back.

Expert parallelism: expert-stacked weights carry the ``_es`` suffix →
``P("model", None, None)``.  Under pjit the gather/scatter lower to
collectives chosen by SPMD (baseline); an explicit shard_map all-to-all
dispatch is a hillclimb option (EXPERIMENTS.md §Perf).

The auxiliary load-balancing loss (Shazeer-style fraction·probability
product) is returned alongside so training can regularize routing.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init
from repro.models.sharding import shard


def moe_init(key, cfg: ModelConfig):
    pd = jnp.dtype(cfg.param_dtype)
    e = cfg.n_experts
    d_ff = cfg.moe_d_ff or cfg.d_ff
    ks = jax.random.split(key, 4)

    def expert_stack(k, d_in, d_out):
        return (
            jax.random.normal(k, (e, d_in, d_out), pd)
            * jnp.asarray((1.0 / d_in) ** 0.5, pd)
        )

    p = {"router": dense_init(ks[0], cfg.d_model, e, pd)}
    if cfg.mlp_type == "swiglu":
        p["gate_es"] = expert_stack(ks[1], cfg.d_model, d_ff)
        p["up_es"] = expert_stack(ks[2], cfg.d_model, d_ff)
        p["down_es"] = expert_stack(ks[3], d_ff, cfg.d_model)
    else:
        p["up_es"] = expert_stack(ks[1], cfg.d_model, d_ff)
        p["down_es"] = expert_stack(ks[2], d_ff, cfg.d_model)
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(
        n_tokens * cfg.experts_per_token / cfg.n_experts * cfg.capacity_factor
    )
    return max(c, cfg.experts_per_token)


def moe_apply(
    params, x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Apply the MoE FFN to (B, S, D); returns (output, aux_loss).

    Dispatches with the configured strategy: ``grouped`` (default — each
    batch row is its own dispatch group, GShard/Switch-style, so token
    gathers never cross the data axis; §Perf arctic hillclimb) or
    ``global`` (single global capacity pool — simpler, but XLA must
    resolve token gathers across the DP axes with pod-scale collectives).
    """
    if cfg.moe_dispatch == "grouped":
        return moe_apply_grouped(params, x, cfg)
    return moe_apply_global(params, x, cfg)


def moe_apply_grouped(
    params, x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Group-local dispatch: capacity per (batch row, expert).

    All indexing stays within each batch row, so under a batch-sharded
    layout every gather/scatter is data-axis-local; the only cross-device
    MoE collective left is the inherent expert-combine psum over the
    ``model`` axis.
    """
    b, s, d = x.shape
    k = cfg.experts_per_token
    e = cfg.n_experts
    cap = max(
        int(s * k / e * cfg.capacity_factor), k
    )
    dt = x.dtype

    logits = (x @ params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (B, S, E)
    gates, eidx = jax.lax.top_k(probs, k)  # (B, S, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    a_n = s * k  # assignments per row
    flat_e = eidx.reshape(b, a_n).astype(jnp.int32)
    flat_g = gates.reshape(b, a_n).astype(dt)
    # token-major order: token t's k assignments are at [t·k, t·k+k)
    flat_tok = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)[None, :].repeat(
        b, axis=0
    )

    # Gather-only dispatch: sort assignments by expert within each row;
    # scatters with constructed index arrays defeat SPMD (they replicate
    # the operand — measured in §Perf), batched sorts + take_along_axis
    # stay sharded.
    order = jnp.argsort(flat_e, axis=1, stable=True)
    rank = jnp.argsort(order, axis=1)  # inverse permutation
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    sorted_tok = jnp.take_along_axis(flat_tok, order, axis=1)

    oh = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (B, A, E)
    counts = jnp.sum(oh, axis=1)  # (B, E)
    starts = jnp.cumsum(counts, axis=1) - counts

    frac = counts.astype(jnp.float32) / a_n
    aux = e * jnp.mean(jnp.sum(frac * jnp.mean(probs, axis=1), axis=-1))

    # expert buffers: slot c of expert e_i reads sorted stream position
    # starts[e_i] + c (rows beyond counts are masked)
    slot_iota = jnp.arange(cap, dtype=jnp.int32)
    gidx = starts[..., None] + slot_iota[None, None, :]  # (B, E, C)
    valid = slot_iota[None, None, :] < jnp.minimum(counts[..., None], cap)
    gclip = jnp.clip(gidx, 0, a_n - 1).reshape(b, e * cap)
    tok_buf = jnp.where(
        valid,
        jnp.take_along_axis(sorted_tok, gclip, axis=1).reshape(b, e, cap),
        0,
    )

    xg = jnp.take_along_axis(
        x[:, None, :, :], tok_buf[..., None], axis=2
    )  # (B, E, C, d) — row-local gather
    xg = jnp.where(valid[..., None], xg, 0)
    xg = shard(xg, "batch", "model", None, None)

    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("becd,edf->becf", xg, params["gate_es"].astype(dt))
        u = jnp.einsum("becd,edf->becf", xg, params["up_es"].astype(dt))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    else:
        h = jnp.einsum("becd,edf->becf", xg, params["up_es"].astype(dt))
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(dt)
    yo = jnp.einsum("becf,efd->becd", h, params["down_es"].astype(dt))

    # Gather-based combine: each assignment reads back its expert slot.
    slot_sorted = (
        jnp.arange(a_n, dtype=jnp.int32)[None, :]
        - jnp.take_along_axis(starts, sorted_e, axis=1)
    )  # (B, A) position-in-expert, sorted order
    pos = jnp.take_along_axis(slot_sorted, rank, axis=1)  # token-major
    keep = pos < cap
    aidx = jnp.clip(flat_e * cap + pos, 0, e * cap - 1)
    vals = jnp.take_along_axis(
        yo.reshape(b, e * cap, d), aidx[..., None], axis=1
    )  # (B, A, d)
    vals = vals * (flat_g * keep.astype(dt))[..., None]
    y = vals.reshape(b, s, k, d).sum(axis=2)
    y = shard(y, "batch", None, None)
    return y, aux.astype(jnp.float32)


def moe_apply_global(
    params, x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Global-capacity dispatch (the baseline layout; see moe_apply)."""
    b, s, d = x.shape
    t = b * s
    k = cfg.experts_per_token
    e = cfg.n_experts
    cap = capacity(cfg, t)
    dt = x.dtype

    x2 = x.reshape(t, d)
    logits = (x2 @ params["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gates, eidx = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.maximum(
        jnp.sum(gates, axis=-1, keepdims=True), 1e-9
    )  # renormalized top-k mixture (olmoe/mixtral convention)

    # ---- sort-based position-in-expert (token-major priority) ----------
    flat_e = eidx.reshape(-1).astype(jnp.int32)  # (T·k,)
    flat_g = gates.reshape(-1).astype(dt)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e).astype(jnp.int32)  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_sorted < cap

    # Aux load-balancing loss: E · Σ_e fraction_e · mean-prob_e (the
    # fraction term is discrete — no gradient — as in Shazeer et al.).
    frac = counts.astype(jnp.float32) / (t * k)
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0))

    # Scatter (expert, position) → source token / gate.  Dropped entries
    # are routed to expert index `e` (out of bounds) and discarded by the
    # scatter's mode="drop" — no write collisions with real slots.
    tok_buf = jnp.zeros((e, cap), jnp.int32)
    gate_buf = jnp.zeros((e, cap), dt)
    se = jnp.where(keep, sorted_e, e)
    sp = jnp.where(keep, pos_sorted, 0)
    tok_buf = tok_buf.at[se, sp].set(flat_tok[order], mode="drop")
    gate_buf = gate_buf.at[se, sp].set(flat_g[order], mode="drop")

    xg = jnp.take(x2, tok_buf.reshape(-1), axis=0).reshape(e, cap, d)
    xg = shard(xg, "model", None, None)

    # ---- batched expert MLP --------------------------------------------
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xg, params["gate_es"].astype(dt))
        u = jnp.einsum("ecd,edf->ecf", xg, params["up_es"].astype(dt))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    else:
        h = jnp.einsum("ecd,edf->ecf", xg, params["up_es"].astype(dt))
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(dt)
    yo = jnp.einsum("ecf,efd->ecd", h, params["down_es"].astype(dt))
    yo = yo * gate_buf[..., None]

    # ---- combine --------------------------------------------------------
    y2 = jnp.zeros((t, d), dt).at[tok_buf.reshape(-1)].add(
        yo.reshape(-1, d), mode="drop"
    )
    return y2.reshape(b, s, d), aux.astype(jnp.float32)
