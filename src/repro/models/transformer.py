"""Model assembly: decoder-only LMs, hybrid stacks, and encoder–decoder.

Layers are grouped into the smallest repeating *period* of (mixer, ffn)
kinds (``ModelConfig.period``): parameters are stacked across periods and
the stack is driven by ``lax.scan``, so HLO size — and therefore 512-device
compile time — is O(period), not O(depth).  Dense/MoE/SSM stacks have
period 1; Jamba's 1-in-8-attention + every-other-MoE layout has period 8;
Seamless scans encoder and decoder stacks separately.

Three execution modes share the block code:

* ``forward``      — full-sequence (train / prefill), no cache;
* ``prefill``      — full-sequence with cache write-back (serving);
* ``decode_step``  — one token against carried caches (KV or SSM state).

The vocab-sharded cross-entropy (`lm_loss`) streams sequence chunks so the
(B, S, V) logits tensor is never materialized — with V up to 152k and S up
to 4k·batch this is the difference between fitting HBM and not.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as ssm
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    embed_apply,
    embed_init,
    lm_head_weights,
    mlp_apply,
    mlp_init,
    norm_apply,
    norm_init,
    padded_vocab,
    sinusoidal_positions,
)
from repro.models.sharding import shard

Pytree = Any


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block_init(key, cfg: ModelConfig, mixer: str, ffn: str, tp: int,
                cross: bool = False) -> Dict:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"mixer_norm": norm_init(cfg)}
    if mixer == "attn":
        p["attn"] = attn.attn_init(ks[0], cfg, tp)
    else:
        p["ssm"] = ssm.mamba_init(ks[0], cfg)
    if cross:
        p["cross_norm"] = norm_init(cfg)
        p["cross_attn"] = attn.attn_init(ks[1], cfg, tp)
    if ffn != "none":
        p["ffn_norm"] = norm_init(cfg)
    if ffn in ("mlp", "moe+mlp"):
        p["mlp"] = mlp_init(ks[2], cfg)
    if ffn in ("moe", "moe+mlp"):
        p["moe"] = moe_mod.moe_init(ks[3], cfg)
    return p


def _block_apply(
    params,
    x,
    cfg: ModelConfig,
    mixer: str,
    ffn: str,
    *,
    causal: bool = True,
    cache=None,
    memory=None,
    positions=None,
):
    """One residual block; returns (x, new_cache, aux_loss)."""
    h = norm_apply(params["mixer_norm"], x, cfg)
    if mixer == "attn":
        out, new_cache = attn.attn_apply(
            params["attn"], h, cfg,
            causal=causal, cache=cache, positions=positions,
        )
    else:
        out, new_cache = ssm.mamba_apply(params["ssm"], h, cfg, state=cache)
    x = x + out
    x = shard(x, "batch", None, None)

    if "cross_attn" in params:
        h = norm_apply(params["cross_norm"], x, cfg)
        out, _ = attn.attn_apply(
            params["cross_attn"], h, cfg, causal=False, memory=memory
        )
        x = x + out

    aux = jnp.float32(0.0)
    if ffn != "none":
        h = norm_apply(params["ffn_norm"], x, cfg)
        y = 0.0
        if "moe" in params:
            ym, aux = moe_mod.moe_apply(params["moe"], h, cfg)
            y = y + ym
        if "mlp" in params:
            y = y + mlp_apply(params["mlp"], h, cfg)
        x = x + y
        x = shard(x, "batch", None, None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stacks (scan over periods)
# ---------------------------------------------------------------------------


def _stack_init(key, cfg: ModelConfig, kinds, ffns, tp, cross=False,
                n_total=None):
    period = len(kinds)
    n_total = n_total or cfg.n_layers

    def one_period(k):
        ks = jax.random.split(k, period)
        return {
            "blocks": [
                _block_init(ks[i], cfg, kinds[i], ffns[i], tp, cross)
                for i in range(period)
            ]
        }

    keys = jax.random.split(key, n_total // period)
    return jax.vmap(one_period)(keys)


def _stack_apply(
    stack_params,
    x,
    cfg: ModelConfig,
    kinds,
    ffns,
    *,
    causal=True,
    caches=None,
    memory=None,
    positions=None,
):
    """Scan the period stack; returns (x, new_caches | None, aux_sum).

    ``caches``/``memory`` (both optional) are pytrees whose leaves carry a
    leading n_periods axis matching ``stack_params``; they join the scan's
    xs as dict entries so one body serves all execution modes.
    """
    period = len(kinds)
    has_caches = caches is not None
    has_memory = memory is not None

    xs: Dict[str, Any] = {"params": stack_params}
    if has_caches:
        xs["caches"] = caches
    if has_memory:
        xs["memory"] = memory

    def body(carry, xs_t):
        xc = carry
        pparams = xs_t["params"]
        pcaches = xs_t["caches"] if has_caches else [None] * period
        pmemory = xs_t["memory"] if has_memory else [None] * period
        new_caches = []
        aux_sum = jnp.float32(0.0)
        for i in range(period):
            xc, nc, aux = _block_apply(
                pparams["blocks"][i], xc, cfg, kinds[i], ffns[i],
                causal=causal, cache=pcaches[i], memory=pmemory[i],
                positions=positions,
            )
            new_caches.append(nc if nc is not None else 0)
            aux_sum = aux_sum + aux
        return xc, (new_caches, aux_sum)

    if cfg.remat and not has_caches:  # decode paths don't backprop
        body = jax.checkpoint(body, prevent_cse=False)

    x, (new_caches, auxs) = jax.lax.scan(body, x, xs)
    return x, (new_caches if has_caches else None), jnp.sum(auxs)


# ---------------------------------------------------------------------------
# Top-level models
# ---------------------------------------------------------------------------


def init(key, cfg: ModelConfig, tp: int = 1) -> Pytree:
    """Initialize the full parameter tree for any assigned architecture."""
    ks = jax.random.split(key, 4)
    kinds = cfg.layer_kinds()
    ffns = cfg.ffn_kinds()
    period = cfg.period()
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg),
        "periods": _stack_init(
            key=ks[1], cfg=cfg,
            kinds=kinds[:period], ffns=ffns[:period], tp=tp,
            cross=cfg.cross_attention,
        ),
        "final_norm": norm_init(cfg),
    }
    if cfg.is_encdec:
        params["encoder"] = {
            "periods": _stack_init(
                key=ks[2], cfg=cfg,
                kinds=("attn",), ffns=("mlp",), tp=tp, cross=False,
                n_total=cfg.encoder_layers,
            ),
            "final_norm": norm_init(cfg),
        }
    return params


def _decoder_inputs(params, batch, cfg: ModelConfig):
    """Token ids or precomputed embeddings (modality-stub archs)."""
    if cfg.input_mode == "embeddings" and "embeds" in batch:
        return batch["embeds"].astype(jnp.dtype(cfg.dtype))
    return embed_apply(params["embed"], batch["tokens"], cfg)


def _encode(params, batch, cfg: ModelConfig):
    """Run the encoder stack over source embeddings/tokens (enc-dec)."""
    if cfg.input_mode == "embeddings":
        x = batch["src_embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_apply(params["embed"], batch["src_tokens"], cfg)
    x = x + sinusoidal_positions(x.shape[1], x.shape[2], x.dtype)[None]
    x, _, _ = _stack_apply(
        params["encoder"]["periods"], x, cfg, ("attn",), ("mlp",),
        causal=False,
    )
    return norm_apply(params["encoder"]["final_norm"], x, cfg)


def _cross_memory(params, enc_out, cfg: ModelConfig):
    """Per-decoder-layer cross-attention K/V, stacked over periods."""

    def one_period(pparams):
        return [
            attn.encode_memory(bp["cross_attn"], enc_out, cfg)
            for bp in pparams["blocks"]
        ]

    return jax.vmap(one_period, in_axes=0)(params["periods"])


def forward_hidden(params, batch, cfg: ModelConfig):
    """Full-sequence decoder forward; returns (hidden (B,S,D), aux_loss)."""
    period = cfg.period()
    kinds = cfg.layer_kinds()[:period]
    ffns = cfg.ffn_kinds()[:period]
    x = _decoder_inputs(params, batch, cfg)
    memory = None
    if cfg.is_encdec:
        enc_out = _encode(params, batch, cfg)
        memory = _cross_memory(params, enc_out, cfg)
    if not cfg.rope and not cfg.is_encdec:
        x = x + sinusoidal_positions(x.shape[1], x.shape[2], x.dtype)[None]
    x, _, aux = _stack_apply(
        params["periods"], x, cfg, kinds, ffns, causal=True, memory=memory
    )
    return norm_apply(params["final_norm"], x, cfg), aux


def lm_loss(params, batch, cfg: ModelConfig):
    """Causal-LM loss: chunked, vocab-sharded cross-entropy + MoE aux.

    ``batch`` needs ``tokens``/``embeds`` (+ ``src_*`` for enc-dec) and
    ``labels`` (int32, −1 = masked).  Returns (loss, metrics).
    """
    hidden, aux = forward_hidden(params, batch, cfg)
    w = lm_head_weights(params["embed"], cfg)
    labels = batch["labels"]
    xent, n_tok = _chunked_xent(hidden, w, labels, cfg)
    loss = xent + cfg.router_aux_coef * aux
    return loss, {"xent": xent, "aux": aux, "tokens": n_tok}


def _chunked_xent(hidden, w, labels, cfg: ModelConfig):
    """Σ softmax-xent over sequence chunks; never materializes (B,S,V)."""
    b, s, d = hidden.shape
    v = w.shape[1]
    chunk = min(cfg.logits_chunk, s)
    n_chunks = (s + chunk - 1) // chunk
    s_pad = n_chunks * chunk
    hidden = jnp.pad(hidden, ((0, 0), (0, s_pad - s), (0, 0)))
    labels = jnp.pad(labels, ((0, 0), (0, s_pad - s)), constant_values=-1)
    hc = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    vocab_mask = jnp.arange(v) < cfg.vocab_size  # mask padded vocab rows

    def body(carry, xs):
        tot, cnt = carry
        h, lab = xs
        logits = (h @ w).astype(jnp.float32)  # (B, chunk, V)
        logits = shard(logits, "batch", None, "model")
        logits = jnp.where(vocab_mask[None, None, :], logits, -1e30)
        m = jnp.max(logits, axis=-1, keepdims=True)
        lse = m[..., 0] + jnp.log(
            jnp.sum(jnp.exp(logits - m), axis=-1)
        )
        ll = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1
        )[..., 0]
        valid = lab >= 0
        tot = tot + jnp.sum(jnp.where(valid, lse - ll, 0.0)).astype(jnp.float32)
        cnt = cnt + jnp.sum(valid).astype(jnp.int32)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.int32(0)), (hc, lc)
    )
    return tot / jnp.maximum(cnt, 1), cnt


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    caches: Pytree  # stacked per-period list of KVCache/SSMState
    memory: Optional[Pytree]  # cross-attention K/V (enc-dec only)
    length: jnp.ndarray


def init_decode_state(
    cfg: ModelConfig, batch: int, max_len: int, tp: int = 1
) -> DecodeState:
    period = cfg.period()
    kinds = cfg.layer_kinds()[:period]
    n_periods = cfg.n_layers // period

    def one(_):
        slots = []
        for kind in kinds:
            if kind == "attn":
                slots.append(attn.init_cache(cfg, batch, max_len, tp))
            else:
                slots.append(ssm.init_ssm_state(cfg, batch))
        return slots

    caches = jax.vmap(one)(jnp.arange(n_periods))
    return DecodeState(caches=caches, memory=None, length=jnp.int32(0))


def prefill(params, batch, state: DecodeState, cfg: ModelConfig):
    """Consume the prompt, filling caches; returns (state, last_logits)."""
    period = cfg.period()
    kinds = cfg.layer_kinds()[:period]
    ffns = cfg.ffn_kinds()[:period]
    x = _decoder_inputs(params, batch, cfg)
    memory = state.memory
    if cfg.is_encdec:
        enc_out = _encode(params, batch, cfg)
        memory = _cross_memory(params, enc_out, cfg)
    if not cfg.rope and not cfg.is_encdec:
        x = x + sinusoidal_positions(x.shape[1], x.shape[2], x.dtype)[None]
    x, caches, _ = _stack_apply(
        params["periods"], x, cfg, kinds, ffns,
        causal=True, caches=state.caches, memory=memory,
    )
    h = norm_apply(params["final_norm"], x[:, -1:, :], cfg)
    logits = (h @ lm_head_weights(params["embed"], cfg)).astype(jnp.float32)
    new_state = DecodeState(
        caches=caches, memory=memory, length=state.length + x.shape[1]
    )
    return new_state, logits


def decode_step(params, tokens, state: DecodeState, cfg: ModelConfig):
    """One serving step: new token(s) (B, s) → logits; caches advance."""
    period = cfg.period()
    kinds = cfg.layer_kinds()[:period]
    ffns = cfg.ffn_kinds()[:period]
    x = embed_apply(params["embed"], tokens, cfg)
    if not cfg.rope and not cfg.is_encdec:
        pos = sinusoidal_positions(2**17, x.shape[2], x.dtype)
        x = x + jax.lax.dynamic_slice_in_dim(
            pos, state.length, x.shape[1], axis=0
        )[None]
    x, caches, _ = _stack_apply(
        params["periods"], x, cfg, kinds, ffns,
        causal=True, caches=state.caches, memory=state.memory,
    )
    h = norm_apply(params["final_norm"], x, cfg)
    logits = (h @ lm_head_weights(params["embed"], cfg)).astype(jnp.float32)
    new_state = DecodeState(
        caches=caches, memory=state.memory, length=state.length + x.shape[1]
    )
    return logits, new_state
