"""GQA attention layer: projections, RoPE, qk-norm, KV cache, TP padding.

Tensor-parallel head padding: on a fixed 16-way ``model`` axis, query
heads are padded up to a multiple of the TP degree (arctic 56→64,
starcoder2 24→32, stablelm 40→48 — the standard fixed-mesh deployment
trade; the padded heads have zero output rows so they are functionally
inert).  KV heads are *replicated* across TP when ``n_kv_heads < tp``
(Megatron rule) — their projections stay unsharded and every device reads
the full (small) KV cache.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.config import ModelConfig
from repro.models.layers import dense_init, rms_head_norm, rope_apply, round_up
from repro.models.sharding import shard


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, Hkv, S, dh)
    v: jnp.ndarray
    length: jnp.ndarray  # int32 scalar — valid prefix


def padded_q_heads(cfg: ModelConfig, tp: int) -> int:
    return round_up(cfg.n_heads, max(tp, 1))


def kv_sharded(cfg: ModelConfig, tp: int) -> bool:
    return tp > 1 and cfg.n_kv_heads % tp == 0


def attn_init(key, cfg: ModelConfig, tp: int = 1):
    pd = jnp.dtype(cfg.param_dtype)
    dh = cfg.head_dim
    hq = padded_q_heads(cfg, tp)
    hkv = cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    kv_sfx = "_cs" if kv_sharded(cfg, tp) else ""
    p = {
        "wq_cs": dense_init(ks[0], cfg.d_model, hq * dh, pd),
        f"wk{kv_sfx}": dense_init(ks[1], cfg.d_model, hkv * dh, pd),
        f"wv{kv_sfx}": dense_init(ks[2], cfg.d_model, hkv * dh, pd),
        "wo_rs": dense_init(ks[3], hq * dh, cfg.d_model, pd),
    }
    if cfg.qkv_bias:
        p["bq_hs"] = jnp.zeros((hq * dh,), pd)
        p[f"bk{kv_sfx and '_hs'}"] = jnp.zeros((hkv * dh,), pd)
        p[f"bv{kv_sfx and '_hs'}"] = jnp.zeros((hkv * dh,), pd)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), pd)
        p["k_norm"] = jnp.ones((dh,), pd)
    return p


def _project_q(params, x, cfg: ModelConfig, positions):
    dt = x.dtype
    b, s, _ = x.shape
    dh = cfg.head_dim
    hq = params["wq_cs"].shape[1] // dh
    q = x @ params["wq_cs"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq_hs"].astype(dt)
    q = q.reshape(b, s, hq, dh)
    if cfg.qk_norm:
        q = rms_head_norm(params["q_norm"], q, cfg.norm_eps)
    if cfg.rope:
        q = rope_apply(q, positions, cfg.rope_theta, cfg.rope_pct)
    return q


def _project_kv(params, x, cfg: ModelConfig, positions):
    dt = x.dtype
    b, s, _ = x.shape
    dh = cfg.head_dim
    wk = params.get("wk_cs", params.get("wk"))
    wv = params.get("wv_cs", params.get("wv"))
    hkv = wk.shape[1] // dh
    k = x @ wk.astype(dt)
    v = x @ wv.astype(dt)
    if cfg.qkv_bias:
        k = k + params.get("bk_hs", params.get("bk")).astype(dt)
        v = v + params.get("bv_hs", params.get("bv")).astype(dt)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        k = rms_head_norm(params["k_norm"], k, cfg.norm_eps)
    if cfg.rope:
        k = rope_apply(k, positions, cfg.rope_theta, cfg.rope_pct)
    return k, v


def attn_apply(
    params,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    cache: Optional[KVCache] = None,
    memory: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """Self- or cross-attention with optional KV cache.

    * prefill/train: ``cache=None`` → attends within ``x`` (causal opt.);
    * decode: ``cache`` holds (B, Hkv, S_max, dh); ``x`` is the new token(s)
      written at ``cache.length``;
    * cross-attention: ``memory=(k, v)`` precomputed from the encoder.
    """
    b, s, _ = x.shape
    dt = x.dtype
    if positions is None:
        base = cache.length if cache is not None else 0
        positions = base + jnp.arange(s)[None, :]

    q = _project_q(params, x, cfg, positions)
    q_bhsd = q.transpose(0, 2, 1, 3)
    q_bhsd = shard(q_bhsd, "batch", "model", None, None)

    new_cache = None
    if memory is not None:
        k_full, v_full = memory  # (B, Hkv, S_mem, dh)
        ctx = kops.attention(
            q_bhsd, k_full, v_full,
            causal=False, impl=cfg.attn_impl,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        )
    elif cache is not None:
        k, v = _project_kv(params, x, cfg, positions)
        k_new = k.transpose(0, 2, 1, 3)
        v_new = v.transpose(0, 2, 1, 3)
        zero = jnp.int32(0)
        kc = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype),
            (zero, zero, cache.length, zero),
        )
        vc = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype),
            (zero, zero, cache.length, zero),
        )
        new_cache = KVCache(k=kc, v=vc, length=cache.length + s)
        if s > 1:
            # Prefill: flash/chunked attention within the prompt (fresh
            # caches start at length 0, so causal-within-x is exact).
            # Perf iteration #1: the naive path ran the decode read with
            # s_new = 32k, materializing (B, H, 32k, 32k) scores.
            ctx = kops.attention(
                q_bhsd,
                k_new.astype(q_bhsd.dtype),
                v_new.astype(q_bhsd.dtype),
                causal=True, impl=cfg.attn_impl,
                block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
            )
        else:
            # Decode: masked read of the valid cache prefix.
            ctx = _decode_attention(q_bhsd, kc, vc, cache.length, s, cfg)
    else:
        k, v = _project_kv(params, x, cfg, positions)
        k_bhsd = shard(k.transpose(0, 2, 1, 3), "batch", None, None, None)
        v_bhsd = shard(v.transpose(0, 2, 1, 3), "batch", None, None, None)
        ctx = kops.attention(
            q_bhsd, k_bhsd, v_bhsd,
            causal=causal, impl=cfg.attn_impl,
            block_q=cfg.attn_block_q, block_k=cfg.attn_block_k,
        )

    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, -1)
    out = ctx @ params["wo_rs"].astype(dt)
    return out, new_cache


def _decode_attention(q, kc, vc, length, s_new, cfg: ModelConfig):
    """Masked attention of `s_new` fresh queries against a cache prefix.

    Memory-light reference path (scores are (B,H,s_new,S_max), fine for
    decode where s_new is 1) with explicit length masking; large caches
    (512k) stream through the chunked impl when configured.
    """
    b, h, _, dh = q.shape
    hkv = kc.shape[1]
    group = h // hkv
    s_max = kc.shape[2]
    scale = dh**-0.5

    # Grouped einsum — never materializes the repeated KV (512k caches).
    qg = q.reshape(b, hkv, group, s_new, dh)
    logits = (
        jnp.einsum("bhgqd,bhkd->bhgqk", qg, kc).astype(jnp.float32) * scale
    )
    kpos = jnp.arange(s_max)[None, None, None, None, :]
    qpos = (length + jnp.arange(s_new))[None, None, None, :, None]
    logits = jnp.where(kpos <= qpos, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vc)
    return ctx.reshape(b, h, s_new, dh)


def init_cache(
    cfg: ModelConfig, batch: int, max_len: int, tp: int = 1, dtype=None
) -> KVCache:
    dtype = dtype or jnp.dtype(cfg.dtype)
    shape = (batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        length=jnp.int32(0),
    )


def encode_memory(params, enc_out, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (B, S, D)."""
    dt = enc_out.dtype
    b, s, _ = enc_out.shape
    dh = cfg.head_dim
    wk = params.get("wk_cs", params.get("wk"))
    wv = params.get("wv_cs", params.get("wv"))
    hkv = wk.shape[1] // dh
    k = (enc_out @ wk.astype(dt)).reshape(b, s, hkv, dh)
    v = (enc_out @ wv.astype(dt)).reshape(b, s, hkv, dh)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
