"""Shared building blocks: norms, RoPE, MLPs, embeddings.

All modules follow the same convention: ``<name>_init(key, cfg, ...) ->
params`` (a dict whose leaf names carry sharding suffixes, see
models/sharding.py) and ``<name>_apply(params, x, ...) -> y`` (pure,
jit/scan/vmap-friendly).  Compute happens in ``cfg.dtype`` (bf16 by
default) with f32 accumulation where it matters; params are stored in
``cfg.param_dtype``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.sharding import shard


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    return jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(
        scale, dtype
    )


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), _pdtype(cfg))}
    if cfg.norm_type == "layer":
        p["bias"] = jnp.zeros((d,), _pdtype(cfg))
    return p


def norm_apply(params, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layer":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * params["scale"].astype(jnp.float32) + params[
            "bias"
        ].astype(jnp.float32)
    else:  # rms
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        out = out * params["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_head_norm(scale, x, eps):
    """RMS norm over the trailing (head) dim — qk_norm (qwen3/chameleon)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_apply(x: jnp.ndarray, positions: jnp.ndarray, theta: float, pct: float):
    """Rotary embedding on (..., seq, n_heads, head_dim); partial if pct<1."""
    dh = x.shape[-1]
    rot = int(dh * pct) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = xr[..., :half], xr[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot < dh else out


def sinusoidal_positions(seq: int, d: int, dtype) -> jnp.ndarray:
    """Absolute sinusoidal position table (seamless encoder)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    half = d // 2
    freqs = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    pd = _pdtype(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "gate_cs": dense_init(ks[0], cfg.d_model, d_ff, pd),
            "up_cs": dense_init(ks[1], cfg.d_model, d_ff, pd),
            "down_rs": dense_init(ks[2], d_ff, cfg.d_model, pd),
        }
    return {
        "up_cs": dense_init(ks[0], cfg.d_model, d_ff, pd),
        "up_bias_hs": jnp.zeros((d_ff,), pd),
        "down_rs": dense_init(ks[1], d_ff, cfg.d_model, pd),
        "down_bias": jnp.zeros((cfg.d_model,), pd),
    }


def mlp_apply(params, x, cfg: ModelConfig):
    dt = _dtype(cfg)
    if cfg.mlp_type == "swiglu":
        g = x @ params["gate_cs"].astype(dt)
        u = x @ params["up_cs"].astype(dt)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
        h = shard(h, "batch", None, "model")
        return h @ params["down_rs"].astype(dt)
    h = x @ params["up_cs"].astype(dt) + params["up_bias_hs"].astype(dt)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(dt)
    h = shard(h, "batch", None, "model")
    return h @ params["down_rs"].astype(dt) + params["down_bias"].astype(dt)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab rounded to 256 for clean 16-way sharding (Megatron-style)."""
    return round_up(cfg.vocab_size, 256)


def embed_init(key, cfg: ModelConfig):
    pd = _pdtype(cfg)
    v = padded_vocab(cfg)
    p = {"table_vs": jax.random.normal(key, (v, cfg.d_model), pd) * 0.02}
    if not cfg.tie_embeddings:
        p["lm_head_cs"] = dense_init(
            jax.random.fold_in(key, 1), cfg.d_model, v, pd
        )
    return p


def embed_apply(params, tokens, cfg: ModelConfig):
    table = params["table_vs"].astype(_dtype(cfg))
    out = jnp.take(table, tokens, axis=0)
    return shard(out, "batch", None, None)


def lm_head_weights(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["table_vs"].T.astype(_dtype(cfg))
    return params["lm_head_cs"].astype(_dtype(cfg))
