"""Architecture configuration schema covering all 10 assigned families.

One frozen dataclass describes every architecture the framework can build:
dense GQA transformers, MoE (with optional dense-residual branch), Mamba2
SSD stacks, hybrid interleaves (Jamba), early-fusion VLM backbones
(Chameleon), and encoder–decoder (Seamless).  `repro/configs/<arch>.py`
instantiates one of these per assigned architecture plus a reduced smoke
variant of the same family.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio

    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0  # 0 → d_model // n_heads
    d_ff: int = 0
    vocab_size: int = 0

    # -- attention features --------------------------------------------------
    rope: bool = True
    rope_theta: float = 10000.0
    rope_pct: float = 1.0  # stablelm-2: 0.25 (partial rotary)
    qk_norm: bool = False  # qwen3, chameleon
    qkv_bias: bool = False  # qwen1.5, starcoder2
    norm_type: str = "rms"  # rms | layer
    norm_eps: float = 1e-5
    mlp_type: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False

    # -- MoE -----------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # 0 → d_ff
    moe_every: int = 1  # apply MoE every k-th layer (jamba: 2)
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_dispatch: str = "grouped"  # grouped (data-axis-local) | global

    # -- SSM (Mamba2/SSD) ----------------------------------------------------
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0  # hybrid: one attention layer every k layers (jamba: 8)

    # -- encoder-decoder -----------------------------------------------------
    encoder_layers: int = 0  # > 0 → enc-dec (seamless)
    cross_attention: bool = False
    source_len: int = 0  # default encoder source length for serve shapes

    # -- modality frontend stubs ----------------------------------------------
    input_mode: str = "tokens"  # tokens | embeddings (audio stub feeds frames)

    # -- numerics / execution -------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    attn_impl: str = "chunked"
    attn_block_q: int = 512
    attn_block_k: int = 1024
    logits_chunk: int = 512  # seq chunking for the vocab-sharded loss

    # ------------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or (self.d_inner // self.ssm_head_dim)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Mixer kind per decoder layer: 'attn' or 'ssm'."""
        if self.family == "ssm":
            return tuple("ssm" for _ in range(self.n_layers))
        if self.attn_every > 0:  # hybrid: attention at position k-1 of period
            return tuple(
                "attn" if (i % self.attn_every) == self.attn_every // 2 else "ssm"
                for i in range(self.n_layers)
            )
        return tuple("attn" for _ in range(self.n_layers))

    def ffn_kinds(self) -> Tuple[str, ...]:
        """FFN kind per decoder layer: 'mlp', 'moe', or 'moe+mlp'."""
        kinds = []
        for i in range(self.n_layers):
            if self.n_experts > 0 and (i % self.moe_every) == (self.moe_every - 1):
                kinds.append("moe+mlp" if self.dense_residual else "moe")
            else:
                kinds.append("mlp" if self.d_ff > 0 else "none")
        return tuple(kinds)

    def period(self) -> int:
        """Smallest repeating block of (mixer, ffn) kinds — the scan unit.

        HLO size is O(period); n_layers/period periods are lax.scan-ed, so
        deep stacks compile in O(1) depth (compile-time discipline for the
        512-device dry-run; DESIGN.md §6).
        """
        mixers, ffns = self.layer_kinds(), self.ffn_kinds()
        n = self.n_layers
        for p in range(1, n + 1):
            if n % p:
                continue
            if all(
                mixers[i] == mixers[i % p] and ffns[i] == ffns[i % p]
                for i in range(n)
            ):
                return p
        return n

    def active_params(self) -> float:
        """Active parameters per token (MoE counts top-k experts only)."""
        return _param_count(self, active_only=True)

    def total_params(self) -> float:
        return _param_count(self, active_only=False)


def _ffn_params(cfg: ModelConfig, d_ff: int) -> float:
    mult = 3 if cfg.mlp_type == "swiglu" else 2
    return mult * cfg.d_model * d_ff


def _param_count(cfg: ModelConfig, active_only: bool) -> float:
    d, hd = cfg.d_model, cfg.head_dim
    attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
    ssm = 0.0
    if cfg.family in ("ssm", "hybrid"):
        di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
        h = cfg.n_ssm_heads
        in_proj = d * (2 * di + 2 * g * n + h)
        ssm = in_proj + (di + 2 * g * n) * cfg.ssm_conv + di * d + 2 * h + di

    total = 0.0
    for mixer, ffn in zip(cfg.layer_kinds(), cfg.ffn_kinds()):
        total += attn if mixer == "attn" else ssm
        moe_ff = cfg.moe_d_ff or cfg.d_ff
        if ffn == "mlp":
            total += _ffn_params(cfg, cfg.d_ff)
        elif ffn in ("moe", "moe+mlp"):
            experts = (
                cfg.experts_per_token if active_only else cfg.n_experts
            )
            total += experts * _ffn_params(cfg, moe_ff) + d * cfg.n_experts
            if ffn == "moe+mlp":
                total += _ffn_params(cfg, cfg.d_ff)
        total += 2 * d  # norms
    if cfg.encoder_layers:
        enc = attn + _ffn_params(cfg, cfg.d_ff) + 2 * d
        dec_cross = attn + d  # cross-attention per decoder layer
        total += cfg.encoder_layers * enc + cfg.n_layers * dec_cross
    total += cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    return total
