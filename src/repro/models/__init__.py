"""repro.models — the architecture zoo (pure-JAX, scan-over-periods)."""

from repro.models.config import ModelConfig
from repro.models.transformer import (
    DecodeState,
    decode_step,
    forward_hidden,
    init,
    init_decode_state,
    lm_loss,
    prefill,
)

__all__ = [
    "ModelConfig",
    "DecodeState",
    "decode_step",
    "forward_hidden",
    "init",
    "init_decode_state",
    "lm_loss",
    "prefill",
]
