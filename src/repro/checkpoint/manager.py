"""Fault-tolerant checkpointing: atomic, versioned, mesh-elastic.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json, committed by writing
into ``step_<n>.tmp`` and ``os.replace``-ing into place (atomic on POSIX) —
a host dying mid-write can only ever leave a ``.tmp`` turd, never a
half-valid checkpoint.  ``restore_latest`` walks checkpoints newest-first
and skips unreadable/incomplete ones (corrupt-tail tolerance).

Elasticity: arrays are stored mesh-agnostically (plain host numpy).  On
restore, pass ``shardings`` built from the *current* mesh and every array
is ``device_put`` with its new layout — restoring a 256-chip checkpoint
onto 512 chips (or onto 1 CPU) is the same call.  The solver's
``repro.core.RecycleState`` (optimizer state) rides along like any other
registered pytree — its stable key names survive the name-manifest check
— so def-CG's "computational transfer learning" state survives
preemption too: the first post-restore solve deflates with the recovered
basis (round-trip tested in ``tests/test_api.py``).

A background-thread async mode overlaps serialization with the next train
step (``save(..., blocking=False)``); ``wait()`` joins before the next
save to bound memory.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any

_SEP = "|"


def _flatten_with_names(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_pytree(tree: Pytree, directory: str, step: int, extra: Optional[dict] = None):
    """Atomically write one checkpoint; returns its final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    names, leaves, _ = _flatten_with_names(tree)
    arrays = {}
    dtypes = {}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        dtypes[f"a{i}"] = str(arr.dtype)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)  # widen non-npz dtypes losslessly
        arrays[f"a{i}"] = arr
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "names": names,
        "count": len(names),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def restore_pytree(
    template: Pytree,
    path: str,
    shardings: Optional[Pytree] = None,
) -> Pytree:
    """Restore into the structure of ``template``; optionally re-shard every
    leaf onto the current mesh (elastic restore)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    names, t_leaves, treedef = _flatten_with_names(template)
    if manifest["names"] != names:
        raise ValueError(
            "checkpoint/template structure mismatch: "
            f"{len(manifest['names'])} vs {len(names)} leaves"
        )
    leaves = []
    s_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        if shardings is not None
        else [None] * len(names)
    )
    for i, (tmpl, shd) in enumerate(zip(t_leaves, s_leaves)):
        arr = data[f"a{i}"]
        if hasattr(tmpl, "dtype"):
            import ml_dtypes  # noqa: F401 — registers bf16 numpy casts

            arr = arr.astype(np.dtype(tmpl.dtype))
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Versioned checkpoints with retention, resume, and async writes."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- writing ----------------------------------------------------------
    def save(self, tree: Pytree, step: int, *, extra: Optional[dict] = None,
             blocking: bool = True):
        tree = jax.device_get(tree)  # snapshot before the next step mutates

        def work():
            save_pytree(tree, self.directory, step, extra)
            self._gc()

        if blocking:
            work()
        else:
            self.wait()
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- reading ----------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def restore_latest(
        self, template: Pytree, shardings: Optional[Pytree] = None
    ):
        """Newest restorable checkpoint (corrupt tails skipped) or None."""
        self.wait()
        for step in reversed(self.steps()):
            path = os.path.join(self.directory, f"step_{step:08d}")
            try:
                tree = restore_pytree(template, path, shardings)
                with open(os.path.join(path, "manifest.json")) as f:
                    extra = json.load(f).get("extra", {})
                return step, tree, extra
            except Exception:
                continue  # corrupt/incomplete — try the previous one
        return None

    def _gc(self):
        steps = self.steps()
        for step in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{step:08d}"),
                ignore_errors=True,
            )
