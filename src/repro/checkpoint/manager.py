"""Fault-tolerant checkpointing: atomic, versioned, mesh-elastic.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json, committed by writing
into ``step_<n>.tmp`` and ``os.replace``-ing into place (atomic on POSIX) —
a host dying mid-write can only ever leave a ``.tmp`` turd, never a
half-valid checkpoint.  ``restore_latest`` walks checkpoints newest-first
and skips unreadable/incomplete ones (corrupt-tail tolerance).

Elasticity: arrays are stored mesh-agnostically (plain host numpy).  On
restore, pass ``shardings`` built from the *current* mesh and every array
is ``device_put`` with its new layout — restoring a 256-chip checkpoint
onto 512 chips (or onto 1 CPU) is the same call.  The solver's
``repro.core.RecycleState`` (optimizer state) rides along like any other
registered pytree — its stable key names survive the name-manifest check
— so def-CG's "computational transfer learning" state survives
preemption too: the first post-restore solve deflates with the recovered
basis (round-trip tested in ``tests/test_api.py``).

A background-thread async mode overlaps serialization with the next train
step (``save(..., blocking=False)``); ``wait()`` joins before the next
save to bound memory.
"""

from __future__ import annotations

import json
import logging
import os
import shutil
import threading
import warnings
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any

_SEP = "|"

logger = logging.getLogger(__name__)

# Manifest schema history:
#   1 (implicit — pre-"schema_version" manifests): exact name-list match
#     required on restore.
#   2: adds "schema_version"; restore matches leaves BY NAME, defaulting
#     template leaves absent from the checkpoint (forward migration for
#     state pytrees that grew fields — e.g. RecycleState gaining `drift`).
#
# Bumping this: restore matches BY NAME, so the checkpoint-visible leaf
# names live in src/repro/analysis/schema_manifest.json — when a bump
# renames/removes a RecycleState leaf (or changes SolveSpec defaults),
# add the restore migration here, then regenerate the manifest with
# `python -m repro.analysis --update-schema` (tests/test_schema_manifest.py
# and the CI lint job diff it against live code).
SCHEMA_VERSION = 2


def _flatten_with_names(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_pytree(tree: Pytree, directory: str, step: int, extra: Optional[dict] = None):
    """Atomically write one checkpoint; returns its final path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    names, leaves, _ = _flatten_with_names(tree)
    arrays = {}
    dtypes = {}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        dtypes[f"a{i}"] = str(arr.dtype)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)  # widen non-npz dtypes losslessly
        arrays[f"a{i}"] = arr
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "names": names,
        "count": len(names),
        "extra": extra or {},
        "schema_version": SCHEMA_VERSION,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def restore_pytree(
    template: Pytree,
    path: str,
    shardings: Optional[Pytree] = None,
) -> Pytree:
    """Restore into the structure of ``template``; optionally re-shard every
    leaf onto the current mesh (elastic restore).

    Leaves are matched BY NAME (the keystr path recorded in the
    manifest), not by position.  A template leaf *missing* from the
    checkpoint keeps its template value — with a warning — so a state
    pytree that grew a field since the checkpoint was written (schema
    migration, e.g. ``RecycleState.drift`` added in a later version)
    restores instead of being rejected as corrupt.  A checkpoint leaf
    with no home in the template is still a hard ``ValueError``: dropping
    saved state silently is never safe.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    names, t_leaves, treedef = _flatten_with_names(template)
    saved_index = {name: i for i, name in enumerate(manifest["names"])}
    unknown = [n for n in manifest["names"] if n not in set(names)]
    if unknown:
        raise ValueError(
            "checkpoint/template structure mismatch: checkpoint leaves "
            f"{unknown[:5]} have no home in the template "
            f"({len(manifest['names'])} saved vs {len(names)} template leaves)"
        )
    missing = [n for n in names if n not in saved_index]
    if missing:
        warnings.warn(
            f"checkpoint at {path} (schema_version="
            f"{manifest.get('schema_version', 1)}) lacks "
            f"{len(missing)} template leaves {missing[:5]} — defaulting "
            "them from the template (schema migration)",
            stacklevel=2,
        )
    leaves = []
    s_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )
        if shardings is not None
        else [None] * len(names)
    )
    for name, tmpl, shd in zip(names, t_leaves, s_leaves):
        if name not in saved_index:
            leaves.append(tmpl)  # grown-field default: the template value
            continue
        arr = data[f"a{saved_index[name]}"]
        if hasattr(tmpl, "dtype"):
            import ml_dtypes  # noqa: F401 — registers bf16 numpy casts

            arr = arr.astype(np.dtype(tmpl.dtype))
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    """Versioned checkpoints with retention, resume, and async writes.

    Failure-handling contract:

    * an exception inside a background ``save(..., blocking=False)``
      thread does NOT vanish — it is captured and re-raised from the next
      :meth:`wait` or :meth:`save`, so a failed write cannot masquerade
      as a committed checkpoint;
    * :meth:`restore_latest` records every checkpoint it had to skip as
      corrupt/incomplete in :attr:`last_skipped` (a ``[(step, reason)]``
      list, also logged) — corrupt-tail recovery is visible, not silent;
    * retention GC is equally observable: every step :meth:`save`'s
      garbage collection deletes is recorded in :attr:`last_deleted`
      (the most recent GC pass) and counted in :attr:`deleted_total`, so
      a high-frequency writer (e.g. the serving layer's per-tenant LRU
      spills) can see exactly what its ``keep_last`` budget discarded.

    ``keep_last`` is the retention budget: only the newest ``keep_last``
    committed steps survive a save (``keep`` is the original name for
    the same knob and remains accepted; ``keep_last`` wins when both are
    given).  ``keep_last=None``/``keep=None`` disables GC — unbounded
    retention, the caller owns cleanup.
    """

    def __init__(
        self,
        directory: str,
        keep: Optional[int] = 3,
        *,
        keep_last: Optional[int] = None,
    ):
        self.directory = directory
        self.keep = keep_last if keep_last is not None else keep
        if self.keep is not None and self.keep < 1:
            raise ValueError(
                f"keep_last must be >= 1 (or None for unbounded), "
                f"got {self.keep}"
            )
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._async_error: Optional[BaseException] = None
        # (step, reason) for every checkpoint the last restore_latest
        # call skipped as unreadable, newest first.
        self.last_skipped: list = []
        # Steps the most recent GC pass deleted (oldest first), and the
        # lifetime total — the `last_skipped`-style observability of the
        # retention policy.
        self.last_deleted: list = []
        self.deleted_total: int = 0

    # -- writing ----------------------------------------------------------
    def save(self, tree: Pytree, step: int, *, extra: Optional[dict] = None,
             blocking: bool = True):
        tree = jax.device_get(tree)  # snapshot before the next step mutates

        def work():
            try:
                save_pytree(tree, self.directory, step, extra)
                self._gc()
            except BaseException as exc:  # surfaced by the next wait()/save()
                self._async_error = exc

        if blocking:
            self._raise_pending()
            save_pytree(tree, self.directory, step, extra)
            self._gc()
        else:
            self.wait()  # joins the previous write AND raises its failure
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self):
        if self._async_error is not None:
            exc, self._async_error = self._async_error, None
            raise RuntimeError(
                "async checkpoint save failed (the checkpoint was NOT "
                "committed)"
            ) from exc

    # -- reading ----------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def restore_latest(
        self, template: Pytree, shardings: Optional[Pytree] = None
    ):
        """Newest restorable checkpoint (corrupt tails skipped) or None.

        Every skipped checkpoint is recorded in ``self.last_skipped`` as a
        ``(step, reason)`` pair (newest first) and logged, so a corrupt
        tail is observable rather than silently walked past.
        """
        self.wait()
        self.last_skipped = []
        for step in reversed(self.steps()):
            path = os.path.join(self.directory, f"step_{step:08d}")
            try:
                tree = restore_pytree(template, path, shardings)
                with open(os.path.join(path, "manifest.json")) as f:
                    extra = json.load(f).get("extra", {})
                return step, tree, extra
            except Exception as exc:  # corrupt/incomplete — try the previous one
                reason = f"{type(exc).__name__}: {exc}"
                self.last_skipped.append((step, reason))
                logger.warning(
                    "skipping unreadable checkpoint step %d at %s (%s)",
                    step, path, reason,
                )
                continue
        return None

    def _gc(self):
        if self.keep is None:
            return
        steps = self.steps()
        deleted = []
        for step in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{step:08d}"),
                ignore_errors=True,
            )
            deleted.append(step)
        if deleted:
            self.last_deleted = deleted
            self.deleted_total += len(deleted)
            logger.info(
                "checkpoint GC at %s deleted %d step(s) %s (keep_last=%d)",
                self.directory, len(deleted), deleted, self.keep,
            )
