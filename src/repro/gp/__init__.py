"""repro.gp — GP-classification substrate (the paper's experiment)."""

from repro.gp.inducing import InducingResult, subset_gpc
from repro.gp.kernels import RBFKernel
from repro.gp.laplace import (
    LaplaceResult,
    NewtonTrace,
    laplace_gpc,
    logistic_quantities,
    predict_latent,
)

__all__ = [
    "InducingResult",
    "subset_gpc",
    "RBFKernel",
    "LaplaceResult",
    "NewtonTrace",
    "laplace_gpc",
    "logistic_quantities",
    "predict_latent",
]
