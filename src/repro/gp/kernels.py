"""GP kernel functions and matrix-free Gram operators."""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class RBFKernel:
    """Gaussian/RBF kernel  k(x, x') = θ² exp(−‖x−x'‖² / 2λ²)  (paper §3)."""

    theta: float = 1.0
    lengthscale: float = 1.0

    def gram(self, x: jnp.ndarray) -> jnp.ndarray:
        """Materialized K(X, X) — only for the Cholesky baseline / small n."""
        return kref.rbf_gram(x, self.theta, self.lengthscale)

    def cross(self, xa: jnp.ndarray, xb: jnp.ndarray) -> jnp.ndarray:
        d2 = (
            jnp.sum(xa * xa, 1)[:, None]
            + jnp.sum(xb * xb, 1)[None, :]
            - 2.0 * (xa @ xb.T)
        )
        return (self.theta**2) * jnp.exp(
            -0.5 * jnp.maximum(d2, 0.0) / self.lengthscale**2
        )

    def matvec_fn(
        self, x: jnp.ndarray, *, impl: str = "auto", block: int = 256
    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
        """Matrix-free ``v ↦ K v`` over the fused kernel (K never built)."""

        def mv(v: jnp.ndarray) -> jnp.ndarray:
            return kops.rbf_matvec(
                x, v, self.theta, self.lengthscale, impl=impl, block=block
            )

        return mv

    def matvec_cost_flops(self, n: int, d: int) -> float:
        """Flops of one fused Gram matvec (distance matmul dominates)."""
        return 2.0 * n * n * d + 6.0 * n * n
