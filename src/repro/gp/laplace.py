"""Laplace-approximation GP classification — the paper's experiment (§3).

Newton's method on the latent posterior Ψ(f) = log p(y|f) − ½ fᵀK⁻¹f,
with the Kuss–Rasmussen numerically-stable restructuring: each Newton
iteration solves the SPD system (paper Eq. 9–10)

    A⁽ⁱ⁾ = I + H½ K H½,       b⁽ⁱ⁾ = H½ K (H f + ∇ log p(y|f)),

whose eigenvalues lie in [1, n·max(K)/4].  The solver is pluggable —
``cholesky`` (exact, the paper's cubic baseline), ``cg``, or ``defcg``
with a :class:`repro.core.RecycleManager` carrying the deflation basis
across Newton iterations (the paper's contribution).  Since the operator
changes every Newton step (H½ moves with f), the manager recomputes
``A⁽ⁱ⁾W`` each iteration — via ``KernelSystemOperator.basis_matvec``
this is ONE fused multi-RHS Gram pass (each K-tile formed once for all k
recycled vectors), not k sequential matvecs; both the matrix-free kernel
matvec and the dense ``K @ V`` path batch natively.

The logistic likelihood p(y_i|f_i) = σ(y_i f_i) with y ∈ {−1, +1}.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp

from repro.core import (
    KernelSystemOperator,
    RecycleManager,
    SolveSpec,
    cholesky_solve,
    jacobi,
    kernel_nystrom_preconditioner,
    randomized_nystrom,
)
from repro.core.api import solve_jit
from repro.core.solvers import cg_jit
from repro.gp.kernels import RBFKernel


def log_sigmoid(z):
    return -jnp.logaddexp(0.0, -z)


def logistic_quantities(f: jnp.ndarray, y: jnp.ndarray):
    """Returns (log p(y|f), ∇ log p, H diag) for the logistic likelihood."""
    pi = jax.nn.sigmoid(f)
    logp = jnp.sum(log_sigmoid(y * f))
    grad = (y + 1.0) / 2.0 - pi
    hdiag = pi * (1.0 - pi)  # = −∇∇ log p (positive)
    return logp, grad, hdiag


@dataclasses.dataclass
class NewtonTrace:
    """Per-Newton-iteration record (mirrors the columns of paper Table 1)."""

    logp: List[float] = dataclasses.field(default_factory=list)
    psi: List[float] = dataclasses.field(default_factory=list)
    solver_iterations: List[int] = dataclasses.field(default_factory=list)
    solver_matvecs: List[int] = dataclasses.field(default_factory=list)
    cumulative_time: List[float] = dataclasses.field(default_factory=list)
    residual_traces: List = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class LaplaceResult:
    f: jnp.ndarray
    psi: float
    logp: float
    trace: NewtonTrace
    converged: bool


def laplace_gpc(
    x: jnp.ndarray,
    y: jnp.ndarray,
    kernel: RBFKernel,
    *,
    solver: str = "defcg",
    solver_tol: float = 1e-5,
    solver_maxiter: int = 2000,
    recycle: Optional[RecycleManager] = None,
    spec: Optional[SolveSpec] = None,
    precond_key=None,
    newton_tol: float = 1.0,
    max_newton: int = 30,
    impl: str = "auto",
    block: int = 256,
    record_residuals: bool = False,
    k_dense: Optional[jnp.ndarray] = None,
    dense_matvec: bool = False,
) -> LaplaceResult:
    """Find the Laplace mode f̂ of GP classification by Newton's method.

    Args:
      solver: "cholesky" | "cg" | "defcg" (ignored when ``spec`` given).
      recycle: RecycleManager for solver="defcg" (created if None).
      spec: a :class:`repro.core.SolveSpec` — the front-door path: every
        Newton system is solved by ``repro.core.solve`` with a
        :class:`RecycleState` carried across iterations (one jitted
        computation per solve, no host-driven manager) and the spec's
        preconditioner strategy applied.  ``precond="jacobi"`` builds
        ``diag(A) = 1 + h·k(x,x)`` per iteration; ``precond="nystrom"``
        sketches the INVARIANT kernel ``K ≈ UΛUᵀ`` once
        (``precond_rank + 8`` kernel matvecs, charged to the first
        system's matvec count) and rebinds it to each system's drifting
        ``H½`` by a rank-r Woodbury solve
        (:func:`repro.core.kernel_nystrom_preconditioner`) — zero
        operator matvecs per system, exact under drift.  The spec's
        ``strategy`` rides along: ``WindowedRecombine`` runs the Newton
        sequence at the paper's zero-refresh-matvec accounting (the drift
        guard pays k matvecs only on the early, fast-moving Newton
        steps), and ``MGeometryHarmonic`` + a preconditioner extracts in
        the effective ``M⁻¹A`` geometry.
      precond_key: PRNG key for ``spec.precond="nystrom"``.
      newton_tol: stop when ΔΨ < newton_tol (paper used ΔΨ < 1).
      k_dense: pre-materialized K.  Required by the Cholesky path (built
        here if absent).  If ``dense_matvec=True`` the iterative solvers
        also use it (2n² flops/matvec — the paper's own setup, where K is
        formed once per hyperparameter setting); otherwise they use the
        fused matrix-free Gram matvec (O(n·d) memory, the TPU-scale path).
      dense_matvec: see above.

    The returned trace contains per-iteration log p(y|f), Ψ, solver
    iteration/matvec counts and cumulative wall time spent in the linear
    solver — everything paper Table 1 / Figs 2–3 report.
    """
    n = x.shape[0]
    f = jnp.zeros(n, x.dtype)
    if spec is not None:
        if spec.precond == "custom":
            raise ValueError(
                "laplace_gpc builds the preconditioner itself and has no M "
                "parameter — use spec.precond='jacobi'/'nystrom'/'none', or "
                "drive repro.core.solve directly for a custom M"
            )
        solver = "spec"
    if (solver == "cholesky" or dense_matvec) and k_dense is None:
        k_dense = kernel.gram(x)
    if dense_matvec:
        k_mv = lambda v: k_dense @ v  # noqa: E731 — stable closure for jit
    else:
        k_mv = kernel.matvec_fn(x, impl=impl, block=block)
    if solver == "defcg" and recycle is None:
        recycle = RecycleManager(k=8, ell=12, tol=solver_tol, maxiter=solver_maxiter)
    solve_state = None  # RecycleState carried across Newton systems
    k_sketch = None  # once-per-call Nyström sketch (U, lam) of K
    sketch_matvecs = 0

    trace = NewtonTrace()
    psi_prev = -jnp.inf
    x_prev = None
    solve_time = 0.0
    converged = False

    for it in range(max_newton):
        logp, grad, hdiag = logistic_quantities(f, y)
        sqrt_h = jnp.sqrt(hdiag)
        bg = hdiag * f + grad
        b = sqrt_h * k_mv(bg)

        t0 = time.perf_counter()
        if solver == "cholesky":
            amat = (
                jnp.eye(n, dtype=x.dtype)
                + sqrt_h[:, None] * k_dense * sqrt_h[None, :]
            )
            xsol = cholesky_solve(amat, b)
            info = None
        else:
            a_op = KernelSystemOperator(k_mv, sqrt_h)
            if solver == "spec":
                M = None
                if spec.precond == "jacobi":
                    # diag(A) = 1 + h_i k(x_i, x_i) — exact, host-free.
                    diag_k = (
                        jnp.diag(k_dense)
                        if dense_matvec
                        else jnp.full(n, kernel.theta**2, x.dtype)
                    )
                    M = jacobi(1.0 + hdiag * diag_k)
                elif spec.precond == "nystrom":
                    if k_sketch is None:
                        key = (
                            precond_key
                            if precond_key is not None
                            else jax.random.PRNGKey(0)
                        )
                        k_sketch = randomized_nystrom(
                            k_mv,
                            jnp.zeros(n, x.dtype),
                            rank=spec.precond_rank,
                            key=key,
                        )
                        sketch_matvecs = spec.precond_rank + 8
                    M = kernel_nystrom_preconditioner(
                        k_sketch[0], k_sketch[1], sqrt_h
                    )
                res = solve_jit(
                    a_op, b, spec, solve_state, x0=x_prev, M=M,
                    record_residuals=record_residuals,
                )
                solve_state = res.state
            elif solver == "cg":
                res = cg_jit(
                    a_op, b, x_prev,
                    tol=solver_tol, maxiter=solver_maxiter,
                    record_residuals=record_residuals,
                )
            elif solver == "defcg":
                res = recycle.solve(
                    a_op, b, x_prev,
                    tol=solver_tol, maxiter=solver_maxiter,
                    record_residuals=record_residuals,
                )
            else:
                raise ValueError(f"unknown solver={solver!r}")
            xsol, info = res.x, res.info
        jax.block_until_ready(xsol)
        solve_time += time.perf_counter() - t0

        a_vec = bg - sqrt_h * xsol
        f = k_mv(a_vec)
        x_prev = xsol

        logp_new, _, _ = logistic_quantities(f, y)
        psi = logp_new - 0.5 * jnp.dot(a_vec, f)

        trace.logp.append(float(logp_new))
        trace.psi.append(float(psi))
        trace.cumulative_time.append(solve_time)
        if info is not None:
            trace.solver_iterations.append(int(info.iterations))
            # The one-off Nyström sketch cost is charged to the system
            # that built it — honest a-priori-subspace accounting.
            trace.solver_matvecs.append(int(info.matvecs) + sketch_matvecs)
            sketch_matvecs = 0
            if record_residuals and info.residual_norms is not None:
                trace.residual_traces.append(
                    jnp.asarray(info.residual_norms)
                )
        else:
            trace.solver_iterations.append(n)  # direct solve ≙ full rank
            trace.solver_matvecs.append(0)

        if jnp.abs(psi - psi_prev) < newton_tol:
            converged = True
            break
        psi_prev = psi

    logp_final, _, _ = logistic_quantities(f, y)
    return LaplaceResult(
        f=f, psi=float(psi), logp=float(logp_final),
        trace=trace, converged=converged,
    )


def predict_latent(
    x_train: jnp.ndarray,
    y_train: jnp.ndarray,
    f_hat: jnp.ndarray,
    x_test: jnp.ndarray,
    kernel: RBFKernel,
) -> jnp.ndarray:
    """Posterior-mean latent at test points: k(X*, X) ∇log p(y|f̂)."""
    _, grad, _ = logistic_quantities(f_hat, y_train)
    return kernel.cross(x_test, x_train) @ grad
