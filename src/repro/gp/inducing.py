"""Inducing-point / subset-of-data baseline (paper §3.1).

The paper compares recycled iterative solvers against the ML-standard
*a-priori low-rank* route: pick m ≪ n representer points X_m, run the full
Laplace optimization on the m-point subproblem (O(m³)), and induce the
remaining latents through the conditional mean

    E[f_{n−m} | f_m] = K_{(n−m)m} K_mm⁻¹ f_m .

The training-set objective log p(y | f) is then evaluated with the induced
latents over the *full* set — that is the accuracy axis of paper Fig. 4;
the cost axis is the (linear-in-n) wall time of the subset solve.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.gp.kernels import RBFKernel
from repro.gp.laplace import LaplaceResult, laplace_gpc, logistic_quantities


@dataclasses.dataclass
class InducingResult:
    logp_full: float  # log p(y|f) with induced latents on the full set
    subset_result: LaplaceResult
    m: int
    seconds: float


def subset_gpc(
    x: jnp.ndarray,
    y: jnp.ndarray,
    kernel: RBFKernel,
    m: int,
    *,
    key=None,
    newton_tol: float = 1.0,
    max_newton: int = 30,
    jitter: float = 1e-6,
) -> InducingResult:
    """Randomly-selected subset-of-data GPC (the paper's Fig. 4 baseline)."""
    n = x.shape[0]
    key = key if key is not None else jax.random.PRNGKey(0)
    idx = jax.random.permutation(key, n)[:m]
    xm, ym = x[idx], y[idx]

    t0 = time.perf_counter()
    sub = laplace_gpc(
        xm, ym, kernel,
        solver="cholesky", newton_tol=newton_tol, max_newton=max_newton,
    )

    # Induce the full latent vector through the conditional mean.
    kmm = kernel.gram(xm) + jitter * jnp.eye(m, dtype=x.dtype)
    knm = kernel.cross(x, xm)  # (n, m)
    alpha = jnp.linalg.solve(kmm, sub.f)
    f_full = knm @ alpha
    # Keep the subset's own (exact) latents at the subset points.
    f_full = f_full.at[idx].set(sub.f)
    jax.block_until_ready(f_full)
    seconds = time.perf_counter() - t0

    logp_full, _, _ = logistic_quantities(f_full, y)
    return InducingResult(
        logp_full=float(logp_full), subset_result=sub, m=m, seconds=seconds
    )
