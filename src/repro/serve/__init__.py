"""repro.serve — the multi-tenant solve service over the solver front doors.

The paper frames recycling as transfer learning of a low-rank
approximation across a time-series of numerical tasks; this package is
that framing as a *serving* system.  Each tenant (one user's GP /
Laplace / Newton sequence) carries an evolving
:class:`repro.core.RecycleState`; the service keeps B of them resident
on device in a :class:`StatePool`, serves every resident tenant's next
system with ONE slot-masked :func:`repro.core.solve_pool_step` per tick
(continuous batching), spills LRU-cold tenants through
:class:`repro.checkpoint.CheckpointManager` so their warm bases survive
eviction, and exposes per-tenant + pool telemetry as plain dicts.

Layering (each module's docstring carries its contract):

* :mod:`repro.serve.pool`      — device-resident slots + the spill store
* :mod:`repro.serve.scheduler` — admission/eviction/serve event loop
* :mod:`repro.serve.session`   — the tenant-facing handle
* :mod:`repro.serve.metrics`   — per-tenant and pool-level counters
"""

from repro.serve.metrics import ServeMetrics, TenantMetrics
from repro.serve.pool import (
    PoolFullError,
    StatePool,
    TenantStateStore,
)
from repro.serve.scheduler import (
    ServedResult,
    SolveService,
    Ticket,
)
from repro.serve.session import Session

__all__ = [
    "PoolFullError",
    "ServeMetrics",
    "ServedResult",
    "Session",
    "SolveService",
    "StatePool",
    "TenantMetrics",
    "TenantStateStore",
    "Ticket",
]
