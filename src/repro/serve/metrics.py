"""Per-tenant and pool-level serving telemetry — plain-dict snapshots.

Everything here is host-side bookkeeping: the scheduler feeds it concrete
Python ints pulled off the device ONCE per tick (after the batched step
has already synchronized), so recording costs no extra device round
trips.  ``snapshot()`` returns a nested plain dict (json-safe scalars
only) — the contract the serve bench records and any external scraper
can consume without importing jax.

Two levels:

* :class:`TenantMetrics` — one per tenant key, counting what THAT
  tenant consumed: systems served, iterations/matvecs (honest per-tenant
  accounting from the masked :class:`repro.core.SolveReport`, so an idle
  neighbour's refresh overhead is never charged here), guard/rung
  firings, breakdowns, queue wait, evictions and warm restores.
* :class:`ServeMetrics` — the pool: ticks (busy/idle), batched vs
  single-dispatch steps, slot occupancy integrals (slot-ticks occupied /
  actively serving, from which the snapshot derives mean occupancy),
  admission/eviction/restore totals, peak queue depth, and checkpoint-GC
  deletions reported by the spill store.
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class TenantMetrics:
    """Counters for one tenant key (all plain Python ints)."""

    submitted: int = 0
    served: int = 0
    iterations: int = 0
    matvecs: int = 0
    guard_firings: int = 0
    rung_retries: int = 0  # sum of adopted recovery-ladder rungs
    breakdowns: int = 0  # served systems with status >= BREAKDOWN
    queue_wait_ticks: int = 0  # ticks requests spent waiting pre-service
    evictions: int = 0
    restores: int = 0  # warm re-admissions from a spilled state
    last_status: int = 0
    last_served_tick: int = -1

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ServeMetrics:
    """Pool-level counters plus the per-tenant registry."""

    slots: int = 0
    ticks: int = 0
    idle_ticks: int = 0
    batched_steps: int = 0
    single_steps: int = 0  # B=1 fast-path dispatches through plain solve
    served_total: int = 0
    admissions: int = 0
    evictions: int = 0
    restores: int = 0
    occupied_slot_ticks: int = 0  # sum over ticks of resident tenants
    serving_slot_ticks: int = 0  # sum over ticks of actively served slots
    queue_depth_peak: int = 0
    spill_gc_deleted: int = 0  # checkpoint steps GC'd by the spill store
    tenants: Dict[str, TenantMetrics] = dataclasses.field(
        default_factory=dict
    )

    def tenant(self, key: str) -> TenantMetrics:
        if key not in self.tenants:
            self.tenants[key] = TenantMetrics()
        return self.tenants[key]

    # -- recording hooks (called by the scheduler) -------------------------
    def record_tick(self, occupied: int, serving: int) -> None:
        self.ticks += 1
        self.occupied_slot_ticks += occupied
        self.serving_slot_ticks += serving
        if serving == 0:
            self.idle_ticks += 1

    def record_queue_depth(self, depth: int) -> None:
        self.queue_depth_peak = max(self.queue_depth_peak, depth)

    def record_served(
        self,
        key: str,
        *,
        iterations: int,
        matvecs: int,
        guard_firings: int,
        rung: int,
        status: int,
        waited_ticks: int,
        tick: int,
    ) -> None:
        t = self.tenant(key)
        t.served += 1
        t.iterations += iterations
        t.matvecs += matvecs
        t.guard_firings += guard_firings
        t.rung_retries += rung
        if status >= 2:  # SolveStatus.BREAKDOWN_NONFINITE and above
            t.breakdowns += 1
        t.queue_wait_ticks += waited_ticks
        t.last_status = status
        t.last_served_tick = tick
        self.served_total += 1

    def record_admission(self, key: str, *, restored: bool) -> None:
        self.admissions += 1
        if restored:
            self.restores += 1
            self.tenant(key).restores += 1

    def record_eviction(self, key: str) -> None:
        self.evictions += 1
        self.tenant(key).evictions += 1

    def record_spill_gc(self, deleted_steps: int) -> None:
        self.spill_gc_deleted += deleted_steps

    # -- reading -----------------------------------------------------------
    def snapshot(self) -> dict:
        """The whole registry as one nested plain dict (json-safe)."""
        busy = max(self.ticks - self.idle_ticks, 1)
        return {
            "pool": {
                "slots": self.slots,
                "ticks": self.ticks,
                "idle_ticks": self.idle_ticks,
                "batched_steps": self.batched_steps,
                "single_steps": self.single_steps,
                "served_total": self.served_total,
                "admissions": self.admissions,
                "evictions": self.evictions,
                "restores": self.restores,
                "occupied_slot_ticks": self.occupied_slot_ticks,
                "serving_slot_ticks": self.serving_slot_ticks,
                "mean_occupancy": self.occupied_slot_ticks
                / max(self.ticks * max(self.slots, 1), 1),
                "mean_serving_occupancy": self.serving_slot_ticks
                / (busy * max(self.slots, 1)),
                "queue_depth_peak": self.queue_depth_peak,
                "spill_gc_deleted": self.spill_gc_deleted,
            },
            "tenants": {
                key: t.snapshot() for key, t in sorted(self.tenants.items())
            },
        }
