"""Host-side continuous-batching scheduler over the slot pool.

One :class:`SolveService` = one admission queue + one :class:`StatePool`
+ one :class:`TenantStateStore` + one :class:`ServeMetrics` registry.
The event loop is deliberately synchronous and deterministic — a *tick*
is one call to :meth:`SolveService.tick`:

1. **Admit**: waiting tenants (pending work, not resident) bind to free
   slots in arrival order.  When no slot is free, the least-recently-
   served *idle* resident (no pending request) is evicted — its
   ``RecycleState`` spills through the store so its warm basis survives
   — and the newcomer takes the slot.  Busy residents are never evicted,
   so admitted work always completes.  A tenant that was evicted earlier
   re-admits from its spilled state (bit-for-bit), not cold.
2. **Serve**: every resident tenant with pending work contributes its
   next request.  With two or more active slots the whole pool runs ONE
   :func:`repro.core.solve_pool_step` (idle/empty slots masked inactive
   — zero rhs, state passed through untouched); with exactly one active
   slot the scheduler gathers that slot and dispatches through plain
   :data:`repro.core.solve_jit` instead, fencing the known B=1 vmap
   regression (masked while-loop lowering tax, see the ``batch/`` bench).
3. **Scatter**: per-tenant solutions and masked
   :class:`repro.core.SolveReport` diagnostics land in the ticket table
   (:meth:`result` collects them), slot last-served ticks and the
   metrics registry update.

Nothing here blocks on a background thread: "continuous batching" is a
property of the admission/eviction policy, not of concurrency — drive
the loop with ``tick()`` / ``run_until_idle()`` / ``result(drive=True)``
and every run is exactly reproducible (the pool-lifecycle tests depend
on this).

Batching contract: all tenants of one service must share one operator
*family* — identical pytree treedef and identical static aux (e.g. one
kernel-matvec callable for every tenant of a shared-kernel GP service).
The treedef is checked per tick with a targeted error; a fresh callable
per request would silently retrace the batched step every tick, so keep
operator closures module-stable exactly as with the plain front doors.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque
from typing import Any, Deque, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SolveReport,
    SolveSpec,
    solve_jit,
    solve_pool_step_jit,
)
from repro.core import pytree as pt
from repro.serve.metrics import ServeMetrics
from repro.serve.pool import PoolFullError, StatePool, TenantStateStore

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Ticket:
    """Claim check for one submitted system (tenant key + sequence no)."""

    tenant: str
    seq: int


@dataclasses.dataclass(frozen=True)
class ServedResult:
    """What a ticket redeems for: solution + per-tenant diagnostics."""

    tenant: str
    seq: int
    x: Pytree
    iterations: int
    matvecs: int
    converged: bool
    residual_norm: float
    status: int
    rung: int
    guard_firings: int
    tick: int
    queue_wait_ticks: int
    report: SolveReport

    @property
    def ok(self) -> bool:
        return self.converged and self.status == 0


@dataclasses.dataclass
class _Request:
    ticket: Ticket
    A: Any
    b: Pytree
    submitted_tick: int


class SolveService:
    """Multi-tenant solve service: submit systems, drive ticks, redeem
    tickets.  See the module docstring for the tick protocol.

    Args:
      spec: the one :class:`SolveSpec` every tenant is served under
        (``method='defcg'`` — the pool carries recycle state).
      slots: pool size B (slots, not tenants — tenants beyond B rotate
        through eviction).
      checkpoint_dir: where evicted tenants' states spill.  ``None``
        keeps host-RAM copies (non-durable); a directory spills through
        :class:`repro.checkpoint.CheckpointManager` with ``keep_last``
        retention GC per tenant key.
      keep_last: spilled-checkpoint retention budget per tenant.
      max_drive_ticks: safety bound for ``result(drive=True)`` /
        ``run_until_idle`` loops.
    """

    def __init__(
        self,
        spec: Optional[SolveSpec] = None,
        *,
        slots: int = 8,
        checkpoint_dir: Optional[str] = None,
        keep_last: int = 4,
        max_drive_ticks: int = 100_000,
    ):
        spec = SolveSpec() if spec is None else spec
        if spec.method != "defcg":
            raise ValueError(
                "SolveService carries per-tenant RecycleState — it needs "
                f"spec.method='defcg', got {spec.method!r}"
            )
        self.spec = spec
        self.pool = StatePool(slots, spec)
        self.store = TenantStateStore(checkpoint_dir, keep_last=keep_last)
        self.metrics = ServeMetrics(slots=slots)
        self.max_drive_ticks = max_drive_ticks
        self.tick_count = 0
        # Tenant -> FIFO of unserved requests; OrderedDict so admission
        # considers waiting tenants in arrival order (first submit wins).
        self._pending: "OrderedDict[str, Deque[_Request]]" = OrderedDict()
        self._results: Dict[Tuple[str, int], ServedResult] = {}
        self._seq: Dict[str, int] = {}

    # -- tenant-facing API -------------------------------------------------
    def session(self, tenant: str):
        """A :class:`repro.serve.Session` handle bound to ``tenant``."""
        from repro.serve.session import Session

        return Session(self, tenant)

    def submit(self, tenant: str, A: Any, b: Pytree) -> Ticket:
        """Enqueue one system for ``tenant``; returns its ticket."""
        tenant = str(tenant)
        seq = self._seq.get(tenant, 0)
        self._seq[tenant] = seq + 1
        ticket = Ticket(tenant=tenant, seq=seq)
        if tenant not in self._pending:
            self._pending[tenant] = deque()
        self._pending[tenant].append(
            _Request(ticket=ticket, A=A, b=b, submitted_tick=self.tick_count)
        )
        self.metrics.tenant(tenant).submitted += 1
        return ticket

    def poll(self, ticket: Ticket) -> Optional[ServedResult]:
        """The ticket's result if served, else None (does not tick)."""
        return self._results.get((ticket.tenant, ticket.seq))

    def result(self, ticket: Ticket, *, drive: bool = True) -> ServedResult:
        """Redeem a ticket, driving ticks until it resolves.

        With ``drive=False`` the ticket must already be served (KeyError
        otherwise) — the mode for an external loop that owns ticking.
        """
        key = (ticket.tenant, ticket.seq)
        if key in self._results:
            return self._results.pop(key)
        if not drive:
            raise KeyError(
                f"ticket {ticket} not served yet (drive=False does not tick)"
            )
        for _ in range(self.max_drive_ticks):
            self.tick()
            if key in self._results:
                return self._results.pop(key)
        raise RuntimeError(
            f"ticket {ticket} unresolved after {self.max_drive_ticks} ticks "
            "— was it submitted to this service?"
        )

    def close(self, tenant: str, *, spill: bool = True) -> None:
        """Depart: free the tenant's slot (spilling its warm state so a
        later session can resume) and forget its empty queue.

        Refuses to close a tenant with unserved requests — drain or
        redeem them first (dropping queued work silently would turn a
        scheduling bug into a hang at ``result``).
        """
        tenant = str(tenant)
        q = self._pending.get(tenant)
        if q:
            raise RuntimeError(
                f"tenant {tenant!r} still has {len(q)} unserved request(s) "
                "— drive them to completion before close()"
            )
        self._pending.pop(tenant, None)
        if self.pool.resident(tenant):
            state = self.pool.release(tenant)
            if spill:
                self.store.spill(tenant, state)

    # -- the event loop ----------------------------------------------------
    def run_until_idle(self) -> int:
        """Tick until no request is pending; returns systems served."""
        served = 0
        for _ in range(self.max_drive_ticks):
            if not any(self._pending.values()):
                return served
            served += self.tick()
        raise RuntimeError(
            f"work still pending after {self.max_drive_ticks} ticks"
        )

    def tick(self) -> int:
        """One scheduler step: admit, serve, scatter.  Returns the number
        of systems served this tick (0 = idle tick)."""
        self.tick_count += 1
        tick = self.tick_count
        self._admit(tick)

        serving = []  # (slot, request)
        for tenant, q in self._pending.items():
            if not q:
                continue
            slot = self.pool.slot_of(tenant)
            if slot is not None:
                serving.append((slot, q.popleft()))
        self.metrics.record_tick(self.pool.occupancy, len(serving))
        self.metrics.record_queue_depth(
            sum(len(q) for q in self._pending.values()) + len(serving)
        )
        if not serving:
            return 0

        if len(serving) == 1:
            # B=1 fence: one active slot loses under the vmapped masked
            # while-loop — gather the slot and run the plain front door.
            slot, req = serving[0]
            res = solve_jit(
                req.A, req.b, self.spec, self.pool.slot_state(slot)
            )
            self.pool.write_slot(slot, res.state)
            self.metrics.single_steps += 1
            self._scatter(req, res.x, res.info, res.report, tick)
        else:
            systems, b_batch, active = self._build_batch(serving)
            res = solve_pool_step_jit(
                systems, b_batch, self.spec, self.pool.state, active
            )
            self.pool.state = res.state
            self.metrics.batched_steps += 1
            info = jax.device_get(res.info._replace(residual_norms=None))
            report = jax.device_get(res.report)
            for slot, req in serving:
                self._scatter(
                    req,
                    jax.tree_util.tree_map(lambda l: l[slot], res.x),
                    jax.tree_util.tree_map(lambda l: l[slot], info),
                    jax.tree_util.tree_map(lambda l: l[slot], report),
                    tick,
                )
        self.pool.touch([slot for slot, _ in serving], tick)
        return len(serving)

    # -- internals ---------------------------------------------------------
    def _admit(self, tick: int) -> None:
        for tenant in list(self._pending):
            if not self._pending[tenant] or self.pool.resident(tenant):
                continue
            busy = {t for t, q in self._pending.items() if q}
            if not self.pool.free_slots():
                victim = self.pool.lru_tenant(exclude=busy)
                if victim is None:
                    # Every resident has pending work; the newcomer waits
                    # (queue_wait_ticks accrues until a slot drains).
                    continue
                self.store.spill(victim, self.pool.release(victim))
                self.metrics.record_eviction(victim)
            req = self._pending[tenant][0]
            n, dtype = self._problem_shape(req.b)
            self.pool.ensure_allocated(n, dtype)
            restored = self.store.restore(
                tenant, self.pool.zero_slot_state()
            )
            try:
                self.pool.admit(tenant, restored, n=n, dtype=dtype, tick=tick)
            except PoolFullError:  # pragma: no cover — guarded above
                continue
            self.metrics.record_admission(
                tenant, restored=restored is not None
            )

    @staticmethod
    def _problem_shape(b: Pytree):
        flat, _ = pt.ravel_vector(b)
        return flat.shape[0], flat.dtype

    def _build_batch(self, serving):
        B = self.pool.slots
        fill_req = serving[0][1]
        treedef0 = jax.tree_util.tree_structure(fill_req.A)
        for slot, req in serving[1:]:
            td = jax.tree_util.tree_structure(req.A)
            if td != treedef0:
                raise ValueError(
                    "all tenants of one service must share one operator "
                    f"family: tenant {req.ticket.tenant!r} submitted "
                    f"{td} but the tick's first operator is {treedef0} "
                    "(same pytree structure AND same static aux required "
                    "to stack into one batched step)"
                )
        zero_b = jax.tree_util.tree_map(jnp.zeros_like, fill_req.b)
        ops = [fill_req.A] * B
        bs = [zero_b] * B
        active = np.zeros(B, bool)
        for slot, req in serving:
            ops[slot] = req.A
            bs[slot] = req.b
            active[slot] = True
        systems = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *ops)
        b_batch = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *bs)
        return systems, b_batch, jnp.asarray(active)

    def _scatter(self, req: _Request, x, info, report, tick: int) -> None:
        waited = max(tick - 1 - req.submitted_tick, 0)
        served = ServedResult(
            tenant=req.ticket.tenant,
            seq=req.ticket.seq,
            x=x,
            iterations=int(info.iterations),
            matvecs=int(info.matvecs),
            converged=bool(info.converged),
            residual_norm=float(info.residual_norm),
            status=int(info.status),
            rung=int(report.rung),
            guard_firings=int(report.guard_firings),
            tick=tick,
            queue_wait_ticks=waited,
            report=SolveReport(
                status=np.int32(info.status),
                rung=np.int32(report.rung),
                guard_firings=np.int32(report.guard_firings),
                matvecs=np.int32(info.matvecs),
            ),
        )
        self._results[(req.ticket.tenant, req.ticket.seq)] = served
        self.metrics.record_served(
            req.ticket.tenant,
            iterations=served.iterations,
            matvecs=served.matvecs,
            guard_firings=served.guard_firings,
            rung=served.rung,
            status=served.status,
            waited_ticks=waited,
            tick=tick,
        )

    # -- telemetry ---------------------------------------------------------
    def metrics_snapshot(self) -> dict:
        """Pool + per-tenant counters as one nested plain dict."""
        self.metrics.spill_gc_deleted = self.store.gc_deleted_total
        return self.metrics.snapshot()
