"""Device-resident ``RecycleState`` slot pool + the tenant spill store.

The serving substrate the ROADMAP's millions-of-users story needs: B
fixed slots hold one stacked :class:`repro.core.RecycleState` pytree
(leading axis B, resident on device for the whole service lifetime) plus
host-side per-slot metadata — bound tenant key, last-served tick.  A
tenant's "computational transfer learning" state (the paper's recycled
subspace) lives in its slot between requests; the scheduler serves every
resident tenant's next system with ONE :func:`repro.core.solve_pool_step`
call, so admitting a tenant never costs a new compilation and an idle or
poisoned slot never stalls its neighbours (masking semantics live in the
step entry, per-slot breakdown retirement in the PR 6 runtime).

Two classes:

* :class:`StatePool` — the slots.  ``admit`` binds a tenant to a free
  slot (writing its state — cold zeros or a restored basis — into the
  stacked pytree with one ``.at[slot].set``), ``release`` reads the
  tenant's state back out and zeroes the slot.  The pool is policy-free:
  WHO to evict is the scheduler's call (:meth:`lru_tenant` just answers
  the least-recently-served question).
* :class:`TenantStateStore` — where evicted states go.  With a directory
  it spills through :class:`repro.checkpoint.CheckpointManager` (one
  manager per tenant key, ``keep_last`` retention GC, atomic writes —
  an evicted tenant's warm basis survives a process death); without one
  it keeps host-RAM copies (same interface, no durability).  Either way
  re-admission restores the exact bytes that were evicted: the round
  trip is bit-for-bit (full-precision npz / host copy), which is the
  transfer-learning payoff — a returning tenant's first solve deflates
  with the basis it left behind.
"""

from __future__ import annotations

import os
import re
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import RecycleState, SolveSpec

Pytree = Any


class PoolFullError(RuntimeError):
    """Raised by ``admit`` when no slot is free (scheduler evicts + retries)."""


def _tenant_dirname(key: str) -> str:
    """Filesystem-safe per-tenant directory name (collision-disambiguated)."""
    safe = re.sub(r"[^A-Za-z0-9._-]", "_", str(key))[:64]
    if safe != str(key):
        import hashlib

        safe += "-" + hashlib.sha256(str(key).encode()).hexdigest()[:8]
    return f"tenant_{safe}"


class TenantStateStore:
    """Spill/restore per-tenant ``RecycleState`` by tenant key.

    ``directory=None`` keeps host-RAM copies (fast, non-durable);
    otherwise each tenant key owns a :class:`CheckpointManager` under
    ``<directory>/tenant_<key>/`` with ``keep_last`` retention — every
    eviction writes a NEW step (monotonic per tenant), old steps are
    GC'd, and :attr:`gc_deleted_total` aggregates the managers'
    ``deleted_total`` observability for pool metrics.
    """

    def __init__(
        self, directory: Optional[str] = None, *, keep_last: int = 4
    ):
        self.directory = directory
        self.keep_last = keep_last
        self._managers: Dict[str, CheckpointManager] = {}
        self._memory: Dict[str, RecycleState] = {}
        self._steps: Dict[str, int] = {}

    def _manager(self, key: str) -> CheckpointManager:
        if key not in self._managers:
            self._managers[key] = CheckpointManager(
                os.path.join(self.directory, _tenant_dirname(key)),
                keep_last=self.keep_last,
            )
            existing = self._managers[key].steps()
            self._steps[key] = max(existing) if existing else 0
        return self._managers[key]

    @property
    def gc_deleted_total(self) -> int:
        return sum(m.deleted_total for m in self._managers.values())

    def spill(self, key: str, state: RecycleState) -> None:
        """Persist ``state`` for ``key`` (a new step; old steps GC'd)."""
        if self.directory is None:
            self._memory[key] = jax.device_get(state)
            return
        mgr = self._manager(key)
        self._steps[key] += 1
        mgr.save(
            state,
            step=self._steps[key],
            extra={"tenant": str(key)},
            blocking=True,
        )

    def restore(
        self, key: str, template: RecycleState
    ) -> Optional[RecycleState]:
        """The newest spilled state for ``key``, or None if never spilled."""
        if self.directory is None:
            got = self._memory.get(key)
            if got is None:
                return None
            return jax.tree_util.tree_map(jnp.asarray, got)
        mgr = self._manager(key)
        restored = mgr.restore_latest(template)
        if restored is None:
            return None
        _, state, _ = restored
        return state

    def has(self, key: str) -> bool:
        if self.directory is None:
            return key in self._memory
        return bool(self._manager(key).steps())


class StatePool:
    """B fixed device-resident ``RecycleState`` slots + host metadata.

    The stacked state (leading axis B on every leaf) is allocated lazily
    on the first :meth:`admit` — the pool learns ``n`` and the dtype from
    the first tenant — and then NEVER reallocated: serving shape is
    fixed, so every tick reuses one compiled batched step.
    """

    def __init__(
        self,
        slots: int,
        spec: Optional[SolveSpec] = None,
        *,
        n: Optional[int] = None,
        dtype=None,
    ):
        if slots < 1:
            raise ValueError(f"a pool needs slots >= 1, got {slots}")
        self.slots = slots
        self.spec = SolveSpec() if spec is None else spec
        self.state: Optional[RecycleState] = None
        self.tenants: List[Optional[str]] = [None] * slots
        self.last_served = np.zeros(slots, np.int64)
        self._slot_of: Dict[str, int] = {}
        if n is not None:
            self.ensure_allocated(n, dtype if dtype is not None else jnp.float64)

    # -- allocation --------------------------------------------------------
    @property
    def n(self) -> Optional[int]:
        return None if self.state is None else self.state.W.shape[-1]

    @property
    def dtype(self):
        return None if self.state is None else self.state.W.dtype

    def ensure_allocated(self, n: int, dtype) -> None:
        if self.state is None:
            zero = RecycleState.zeros(self.spec.k, n, dtype)
            self.state = jax.tree_util.tree_map(
                lambda l: jnp.zeros((self.slots,) + jnp.shape(l), l.dtype),
                zero,
            )
        elif self.n != n:
            raise ValueError(
                f"pool is allocated for n={self.n}; a tenant with n={n} "
                "needs its own pool (serving shape is fixed per pool)"
            )

    def zero_slot_state(self) -> RecycleState:
        """A cold single-slot state template (pool must be allocated)."""
        if self.state is None:
            raise RuntimeError("pool not allocated yet — admit a tenant first")
        return RecycleState.zeros(self.spec.k, self.n, self.dtype)

    # -- membership --------------------------------------------------------
    @property
    def occupancy(self) -> int:
        return len(self._slot_of)

    def free_slots(self) -> List[int]:
        return [i for i, t in enumerate(self.tenants) if t is None]

    def slot_of(self, key: str) -> Optional[int]:
        return self._slot_of.get(key)

    def resident(self, key: str) -> bool:
        return key in self._slot_of

    def lru_tenant(self, exclude=()) -> Optional[str]:
        """Least-recently-served resident tenant not in ``exclude``."""
        best_key, best_tick = None, None
        for slot, key in enumerate(self.tenants):
            if key is None or key in exclude:
                continue
            if best_tick is None or self.last_served[slot] < best_tick:
                best_key, best_tick = key, self.last_served[slot]
        return best_key

    # -- admit / release ---------------------------------------------------
    def admit(
        self,
        key: str,
        state: Optional[RecycleState] = None,
        *,
        n: Optional[int] = None,
        dtype=None,
        tick: int = 0,
    ) -> int:
        """Bind ``key`` to a free slot; write its state (or stay cold).

        Raises :class:`PoolFullError` when no slot is free — the
        scheduler owns the eviction policy, so it catches this, spills a
        victim, and retries.
        """
        if key in self._slot_of:
            raise ValueError(f"tenant {key!r} is already resident")
        free = self.free_slots()
        if not free:
            raise PoolFullError(
                f"all {self.slots} slots are bound; evict a tenant first"
            )
        if state is not None:
            leaf = state.W
            self.ensure_allocated(leaf.shape[-1], leaf.dtype)
        elif n is not None:
            self.ensure_allocated(
                n, dtype if dtype is not None else jnp.float64
            )
        if self.state is None:
            raise RuntimeError(
                "cold admission into an unallocated pool needs n= (and "
                "optionally dtype=) to size the slots"
            )
        slot = free[0]
        self.tenants[slot] = key
        self._slot_of[key] = slot
        self.last_served[slot] = tick
        if state is not None:
            self.write_slot(slot, state)
        # A freed slot is zeroed on release, so a cold admit is genuinely
        # cold without another device write.
        return slot

    def release(self, key: str) -> RecycleState:
        """Unbind ``key``; return its slot state and zero the slot."""
        slot = self._slot_of.pop(key, None)
        if slot is None:
            raise KeyError(f"tenant {key!r} is not resident")
        state = self.slot_state(slot)
        self.tenants[slot] = None
        self.last_served[slot] = 0
        self.state = jax.tree_util.tree_map(
            lambda buf: buf.at[slot].set(jnp.zeros_like(buf[slot])),
            self.state,
        )
        return state

    # -- slot state I/O ----------------------------------------------------
    def slot_state(self, slot: int) -> RecycleState:
        return jax.tree_util.tree_map(lambda buf: buf[slot], self.state)

    def write_slot(self, slot: int, state: RecycleState) -> None:
        self.state = jax.tree_util.tree_map(
            lambda buf, s: buf.at[slot].set(jnp.asarray(s, buf.dtype)),
            self.state,
            state,
        )

    def touch(self, slots, tick: int) -> None:
        for slot in slots:
            self.last_served[slot] = tick

    # -- introspection -----------------------------------------------------
    def slot_table(self) -> List[dict]:
        """Host-side per-slot metadata snapshot (one dict per slot)."""
        solved = (
            np.asarray(self.state.systems_solved)
            if self.state is not None
            else np.zeros(self.slots, np.int32)
        )
        return [
            {
                "slot": i,
                "tenant": self.tenants[i],
                "active": self.tenants[i] is not None,
                "last_served_tick": int(self.last_served[i]),
                "systems_solved": int(solved[i]),
            }
            for i in range(self.slots)
        ]
