"""The tenant-facing handle: submit systems, redeem tickets, depart.

A :class:`Session` is a thin, stateless-on-device view over one tenant
key of a :class:`repro.serve.SolveService` — all solver state lives in
the service's pool/store, so sessions are free to create, drop, and
re-create: a re-created session for the same key resumes the same warm
``RecycleState`` (from its slot if still resident, from the spill store
if it was evicted).

Deterministic synchronous mode is the default: ``result()`` drives the
service's tick loop until the ticket resolves, so single-threaded tests
and scripts get exact reproducibility with no extra plumbing.  A host
event loop that owns ticking itself passes ``drive=False`` and polls.

    with service.session("alice") as s:
        t = s.submit(A0, b0)
        r = s.result(t)          # drives ticks; r.x, r.report, r.ok
        x1 = s.solve(A1, b1).x   # submit + result in one call
    # __exit__ -> close(): slot freed, warm basis spilled for next time
"""

from __future__ import annotations

from typing import Any, Optional

from repro.serve.scheduler import ServedResult, SolveService, Ticket

Pytree = Any


class Session:
    """One tenant's handle on a :class:`SolveService` (see module doc)."""

    def __init__(self, service: SolveService, tenant: str):
        self.service = service
        self.tenant = str(tenant)
        self._last_ticket: Optional[Ticket] = None
        self._closed = False

    # -- submitting --------------------------------------------------------
    def submit(self, A: Any, b: Pytree) -> Ticket:
        """Enqueue ``A x = b`` for this tenant; returns the ticket."""
        self._check_open()
        self._last_ticket = self.service.submit(self.tenant, A, b)
        return self._last_ticket

    # -- redeeming ---------------------------------------------------------
    def result(
        self, ticket: Optional[Ticket] = None, *, drive: bool = True
    ) -> ServedResult:
        """Redeem ``ticket`` (default: the most recent submit)."""
        self._check_open()
        ticket = self._last_ticket if ticket is None else ticket
        if ticket is None:
            raise ValueError("nothing submitted yet — no ticket to redeem")
        if ticket.tenant != self.tenant:
            raise ValueError(
                f"ticket belongs to tenant {ticket.tenant!r}, "
                f"not {self.tenant!r}"
            )
        return self.service.result(ticket, drive=drive)

    def poll(self, ticket: Optional[Ticket] = None) -> Optional[ServedResult]:
        """Non-driving probe: the result if served, else None."""
        ticket = self._last_ticket if ticket is None else ticket
        return None if ticket is None else self.service.poll(ticket)

    def solve(self, A: Any, b: Pytree) -> ServedResult:
        """Submit and drive to completion in one call."""
        return self.result(self.submit(A, b))

    # -- telemetry ---------------------------------------------------------
    def metrics(self) -> dict:
        """This tenant's counter snapshot (plain dict)."""
        return self.service.metrics.tenant(self.tenant).snapshot()

    # -- departing ---------------------------------------------------------
    def close(self, *, spill: bool = True) -> None:
        """Depart: free the slot; ``spill=True`` keeps the warm basis in
        the store so a future session for this key resumes it."""
        if not self._closed:
            self.service.close(self.tenant, spill=spill)
            self._closed = True

    def _check_open(self) -> None:
        if self._closed:
            raise RuntimeError(
                f"session for tenant {self.tenant!r} is closed"
            )

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # Don't mask an in-flight exception with the unserved-work guard.
        if exc_type is None:
            self.close()
