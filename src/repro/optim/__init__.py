"""repro.optim — first- and second-order optimizers + gradient compression."""

from repro.optim.adam import AdamState, adam_init, adam_update
from repro.optim.grad_compress import (
    PowerSGDState,
    compress,
    compress_decompress,
    decompress,
    powersgd_init,
)
from repro.optim.hessian_free import (
    HFConfig,
    HFState,
    hf_init,
    hf_step,
    softmax_xent_hvp,
    squared_loss_hvp,
)

__all__ = [
    "AdamState", "adam_init", "adam_update",
    "PowerSGDState", "compress", "compress_decompress", "decompress",
    "powersgd_init",
    "HFConfig", "HFState", "hf_init", "hf_step",
    "softmax_xent_hvp", "squared_loss_hvp",
]
