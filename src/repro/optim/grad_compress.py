"""PowerSGD gradient compression with *recycled* power-iteration bases.

Beyond-paper feature, same core idea as the paper: a low-rank subspace
learned from one step's computation is transferred to the next.  PowerSGD
(Vogels et al.) compresses each ≥2-D gradient M (m×n) to rank r by one
power iteration  P = M Q,  Q' = orth(Mᵀ P)  — reusing the previous step's
Q as the starting basis is exactly "subspace recycling for gradients": as
training settles, consecutive gradients share their dominant subspace, so
one recycled iteration tracks it (the same drift argument as paper §3).

Error feedback (e ← M − P Q'ᵀ, added to the next gradient) keeps the
compression unbiased in the long run.  At scale, only P and Q (m·r + n·r
values instead of m·n) cross the DP/pod axis — an ~(m·n)/(r·(m+n))×
reduction in gradient all-reduce bytes; the all-reduce itself is applied
by the caller between :func:`compress` and :func:`decompress` (the train
step psums P and Q like any other tensor).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class PowerSGDState(NamedTuple):
    q: Pytree  # per-leaf (n, r) recycled basis (None-like zeros for 1-D)
    error: Pytree  # error-feedback memory, same shapes as grads


def _as_matrix(x: jnp.ndarray):
    if x.ndim == 1:
        return None
    return x.reshape(x.shape[0], -1)


def powersgd_init(params: Pytree, rank: int, key) -> PowerSGDState:
    leaves, treedef = jax.tree_util.tree_flatten(params)
    keys = jax.random.split(key, len(leaves))

    def mk_q(p, k):
        m = _as_matrix(p)
        if m is None:
            return jnp.zeros((0,), jnp.float32)
        n = m.shape[1]
        q, _ = jnp.linalg.qr(jax.random.normal(k, (n, rank), jnp.float32))
        return q

    qs = [mk_q(p, k) for p, k in zip(leaves, keys)]
    return PowerSGDState(
        q=jax.tree_util.tree_unflatten(treedef, qs),
        error=jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params
        ),
    )


def _orthonormalize(m: jnp.ndarray) -> jnp.ndarray:
    q, _ = jnp.linalg.qr(m)
    return q


def compress(
    grads: Pytree, state: PowerSGDState
) -> Tuple[Pytree, Pytree, Pytree]:
    """Returns (P tree, Q' tree, low-rank-input tree).  P/Q' are what a
    data-parallel caller all-reduces (means) before :func:`decompress`."""

    def one(g, q, e):
        m = _as_matrix(g)
        if m is None:
            return g.astype(jnp.float32), q, g.astype(jnp.float32)
        mf = m.astype(jnp.float32) + e.reshape(m.shape)
        p = mf @ q  # (m, r)
        p = _orthonormalize(p)
        q_new = mf.T @ p  # (n, r) — recycled basis for next step
        return p, q_new, mf

    trees = jax.tree_util.tree_map(one, grads, state.q, state.error)
    p_tree = jax.tree_util.tree_map(
        lambda t: t[0], trees, is_leaf=lambda t: isinstance(t, tuple)
    )
    q_tree = jax.tree_util.tree_map(
        lambda t: t[1], trees, is_leaf=lambda t: isinstance(t, tuple)
    )
    m_tree = jax.tree_util.tree_map(
        lambda t: t[2], trees, is_leaf=lambda t: isinstance(t, tuple)
    )
    return p_tree, q_tree, m_tree


def decompress(
    grads: Pytree,
    p_tree: Pytree,
    q_tree: Pytree,
    m_tree: Pytree,
) -> Tuple[Pytree, PowerSGDState]:
    """Rebuild M̂ = P Q'ᵀ, update error feedback, return (ĝ, new state)."""

    def one(g, p, q, mf):
        if g.ndim == 1:
            return g.astype(jnp.float32), q, jnp.zeros_like(g, jnp.float32)
        approx = p @ q.T  # (m, n)
        err = mf - approx
        return approx.reshape(g.shape), q, err.reshape(g.shape)

    trees = jax.tree_util.tree_map(one, grads, p_tree, q_tree, m_tree)
    ghat = jax.tree_util.tree_map(
        lambda t: t[0], trees, is_leaf=lambda t: isinstance(t, tuple)
    )
    q_new = jax.tree_util.tree_map(
        lambda t: t[1], trees, is_leaf=lambda t: isinstance(t, tuple)
    )
    err = jax.tree_util.tree_map(
        lambda t: t[2], trees, is_leaf=lambda t: isinstance(t, tuple)
    )
    return ghat, PowerSGDState(q=q_new, error=err)


def compress_decompress(
    grads: Pytree, state: PowerSGDState
) -> Tuple[Pytree, PowerSGDState, dict]:
    """Single-process convenience (tests / single-host): compress +
    decompress without a collective in between; returns compression
    metrics (bytes ratio)."""
    p_tree, q_tree, m_tree = compress(grads, state)
    ghat, new_state = decompress(grads, p_tree, q_tree, m_tree)

    def nbytes(t):
        return sum(x.size for x in jax.tree_util.tree_leaves(t))

    dense = nbytes(grads)
    compressed = nbytes(p_tree) + nbytes(q_tree)
    return ghat, new_state, {"compression_ratio": dense / max(compressed, 1)}
