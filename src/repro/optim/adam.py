"""AdamW — the first-order baseline optimizer (pytree-native)."""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class AdamState(NamedTuple):
    mu: Pytree
    nu: Pytree
    count: jnp.ndarray


def adam_init(params: Pytree) -> AdamState:
    zeros = lambda p: jax.tree_util.tree_map(  # noqa: E731
        lambda x: jnp.zeros_like(x, dtype=jnp.float32), p
    )
    return AdamState(mu=zeros(params), nu=zeros(params), count=jnp.int32(0))


def adam_update(
    grads: Pytree,
    state: AdamState,
    params: Pytree,
    *,
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
):
    """One AdamW step; returns (new_params, new_state)."""
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1**cf
    bc2 = 1.0 - b2**cf

    mu = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1.0 - b1) * g.astype(jnp.float32),
        state.mu, grads,
    )
    nu = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1.0 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads,
    )

    def step(p, m, v):
        s = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if weight_decay:
            s = s + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * s).astype(p.dtype)

    new_params = jax.tree_util.tree_map(step, params, mu, nu)
    return new_params, AdamState(mu=mu, nu=nu, count=count)
