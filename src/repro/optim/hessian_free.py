"""Hessian-free (Gauss-Newton) optimizer with Krylov subspace recycling.

This carries the paper's technique to LM-scale training (cf. the paper's
Martens-2010 citation): every outer step solves the damped GGN system

    (Jᵀ H_L J + λ I) δ = −∇L

with **def-CG(k, ell)** — the deflation basis W is extracted from each
solve's Krylov data (harmonic Ritz) and *recycled into the next step's
solve*, exactly the paper's sequence-of-related-SPD-systems setting: as the
optimizer converges, consecutive GGN operators drift less and recycling
buys more (paper §3, "the iterates change less and less").

``HFConfig(solver="gauss_newton")`` is the TRUE Gauss-Newton variant for
residual models: instead of squaring the Jacobian into the SPD normal
operator, each step solves the damped least-squares problem

    min_δ ‖J δ + r‖² + λ ‖δ‖²

with **(def)LSMR** on the rectangular :class:`~repro.core.GaussNewtonOperator`
(one ``jvp``/``vjp`` per iteration, conditioning κ(J) instead of κ(J)²).
The LM-adapted damping λ is a traced value while ``SolveSpec.lsq_shift``
is static, so the step folds λ into the operator — LSMR runs on
``J/√λ`` with unit shift, which has the identical minimizer — and the
same ``RecycleState`` recycles the normal-equations-geometry basis
across outer steps.

Everything (def-CG loop included) is shape-static and jit-compatible, so
``hf_step`` pjit-shards across a pod like any train step.  The inner
solve+extract is one step of the device-resident sequence engine behind
the ``repro.core.solve`` front door: the GGN is linearized once for the
whole multi-RHS ``AW`` refresh, and the harmonic-Ritz extraction is the
masked flat form — no ``min_iters`` floor, so early-converging solves
stop early.  Damping follows the Levenberg-Marquardt reduction-ratio
rule.  The :class:`repro.core.RecycleState` and the previous step
direction (used as the warm start, Alg. 1's ``x_{-1}``) are part of the
optimizer state — and therefore of checkpoints.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import (
    GaussNewtonOperator,
    GGNOperator,
    HarmonicRitz,
    LinearOperator,
    RecycleState,
    RecycleStrategy,
    SolveSpec,
    solve,
)
from repro.core import pytree as pt
from repro.core.recycle import random_orthonormal_basis

Pytree = Any


@dataclasses.dataclass(frozen=True)
class HFConfig:
    k: int = 8  # recycled subspace size  — def-CG(k, ell)
    ell: int = 12  # stored Krylov directions
    cg_tol: float = 1e-4
    cg_maxiter: int = 50
    lr: float = 1.0
    init_damping: float = 1.0
    min_damping: float = 1e-6
    max_damping: float = 1e6
    recycle: bool = True  # False → plain CG/LSMR baseline (paper comparison)
    # "ggn": damped normal-equations system via GGNOperator + (def-)CG.
    # "gauss_newton": TRUE GN step via GaussNewtonOperator + (def)LSMR on
    # min ‖Jδ + r‖² + λ‖δ‖² — needs hf_step(residual_fn=...).
    solver: str = "ggn"
    # Recycle strategy for the Newton sequence of GGN systems.  The GGN
    # matvec is ~3 forward passes, so WindowedRecombine's zero-matvec
    # refresh (k model linearizations saved per step, drift-guarded) is
    # the natural choice once damping stabilizes; HarmonicRitz is the
    # conservative default.
    strategy: RecycleStrategy = HarmonicRitz()

    def __post_init__(self):
        if self.solver not in ("ggn", "gauss_newton"):
            raise ValueError(
                f"HFConfig.solver must be 'ggn' or 'gauss_newton', "
                f"got {self.solver!r}"
            )

    def solve_spec(self) -> SolveSpec:
        """The inner solver's configuration as the shared SolveSpec."""
        if self.solver == "gauss_newton":
            # lsq_shift=1.0: the traced LM damping is folded into the
            # operator (J/√λ), so the spec-level shift stays static.
            return SolveSpec(
                method="deflsmr" if self.recycle else "lsmr",
                k=self.k,
                ell=self.ell if self.recycle else 0,
                tol=self.cg_tol,
                maxiter=self.cg_maxiter,
                lsq_shift=1.0,
            )
        return SolveSpec(
            method="defcg",
            k=self.k,
            ell=self.ell if self.recycle else 0,
            tol=self.cg_tol,
            maxiter=self.cg_maxiter,
            strategy=self.strategy,
        )


class HFState(NamedTuple):
    recycle: RecycleState  # recycled deflation state (flat (k, n) basis)
    delta_prev: Pytree  # previous step direction (warm start)
    damping: jnp.ndarray
    step: jnp.ndarray
    last_cg_iters: jnp.ndarray


def hf_init(params: Pytree, cfg: HFConfig, key) -> HFState:
    # Bootstrap with a random orthonormal basis — a valid (merely
    # unhelpful) deflation space; its AW placeholder is zeros, which the
    # exact per-step refresh overwrites before it is ever used.
    w_flat = pt.ravel_basis(random_orthonormal_basis(key, params, cfg.k))
    return HFState(
        recycle=RecycleState(
            W=w_flat,
            AW=jnp.zeros_like(w_flat),
            theta=jnp.zeros((cfg.k,), w_flat.dtype),
            systems_solved=jnp.int32(0),
            drift=jnp.zeros((), w_flat.dtype),
        ),
        delta_prev=pt.tree_zeros_like(params),
        damping=jnp.float32(cfg.init_damping),
        step=jnp.int32(0),
        last_cg_iters=jnp.int32(0),
    )


def softmax_xent_hvp(logits: jnp.ndarray, tangent: jnp.ndarray) -> jnp.ndarray:
    """Gauss-Newton Hessian of mean softmax cross-entropy wrt logits:
    ``(diag(p) − p pᵀ)/N`` applied to a tangent — PSD, as def-CG needs."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    tf = tangent.astype(jnp.float32)
    inner = jnp.sum(p * tf, axis=-1, keepdims=True)
    n = logits.size // logits.shape[-1]
    return (p * (tf - inner) / n).astype(tangent.dtype)


def squared_loss_hvp(outputs, tangent):
    n = outputs.size
    return 2.0 * tangent / n


def hf_step(
    params: Pytree,
    state: HFState,
    batch: Any,
    *,
    model_fn: Optional[Callable[[Pytree, Any], jnp.ndarray]] = None,
    loss_fn: Optional[Callable[[jnp.ndarray, Any], jnp.ndarray]] = None,
    loss_hvp: Callable = softmax_xent_hvp,
    residual_fn: Optional[Callable[[Pytree, Any], Pytree]] = None,
    cfg: HFConfig = HFConfig(),
) -> Tuple[Pytree, HFState, dict]:
    """One Hessian-free step.  ``model_fn(params, batch) -> outputs``,
    ``loss_fn(outputs, batch) -> scalar``.  Fully traceable.

    With ``cfg.solver == "gauss_newton"`` pass ``residual_fn(params,
    batch) -> residual pytree`` instead; the step minimizes the damped
    least-squares model of ``loss = ½‖r‖²`` with (def)LSMR on the
    Jacobian itself.
    """
    if cfg.solver == "gauss_newton":
        if residual_fn is None:
            raise ValueError(
                "HFConfig(solver='gauss_newton') needs "
                "hf_step(residual_fn=...)"
            )
        gn = GaussNewtonOperator(
            residual_fn=lambda p: residual_fn(p, batch), params=params
        )

        def total_loss(p):
            rr = residual_fn(p, batch)
            return 0.5 * pt.tree_dot(rr, rr)

        r = gn.residuals()
        loss = 0.5 * pt.tree_dot(r, r)
        grads = gn.rmatvec(r)
        # Fold the traced λ into the operator: LSMR on (J/√λ, −r/√λ)
        # with unit shift minimizes λ⁻¹(‖Jδ + r‖² + λ‖δ‖²) — the same
        # δ — while SolveSpec.lsq_shift stays a static 1.0.
        s = jax.lax.rsqrt(state.damping.astype(pt.ravel(r).dtype))
        op = LinearOperator(
            matvec=lambda v: pt.tree_scale(s, gn.matvec(v)),
            rmatvec=lambda u: pt.tree_scale(s, gn.rmatvec(u)),
        )
        res = solve(
            op,
            pt.tree_scale(-s, r),
            cfg.solve_spec(),
            state.recycle if cfg.recycle else None,
            x0=state.delta_prev,
        )
        delta, result = res.x, res
        recycle_next = res.state if cfg.recycle else state.recycle
        jdelta = gn.matvec(delta)
        curvature = pt.tree_dot(jdelta, jdelta) + state.damping * pt.tree_dot(
            delta, delta
        )
    else:
        if model_fn is None or loss_fn is None:
            raise ValueError(
                "HFConfig(solver='ggn') needs hf_step(model_fn=..., "
                "loss_fn=...)"
            )

        def total_loss(p):
            return loss_fn(model_fn(p, batch), batch)

        loss, grads = jax.value_and_grad(total_loss)(params)

        op = GGNOperator(
            model_fn=lambda p: model_fn(p, batch),
            loss_hvp=lambda out, t: loss_hvp(out, t),
            params=params,
            damping=state.damping,
        )
        neg_grad = pt.tree_scale(-1.0, grads)

        if cfg.recycle:
            # One front-door step: exact AW refresh (GGN linearized
            # once), flat def-CG, masked harmonic-Ritz extraction into
            # the next state.  Plain solve (not solve_jit): the
            # GGNOperator's closures are rebuilt per step, so an inner
            # jit would cache-miss every call — hf_step is designed to
            # be jit-wrapped as a whole by the caller (as
            # examples/hessian_free_lm.py does), like any train step.
            res = solve(op, neg_grad, cfg.solve_spec(), state.recycle,
                        x0=state.delta_prev)
            delta, result, recycle_next = res.x, res, res.state
        else:
            from repro.core import defcg

            result = defcg(
                op, neg_grad, state.delta_prev,
                ell=0, tol=cfg.cg_tol, maxiter=cfg.cg_maxiter,
            )
            delta, recycle_next = result.x, state.recycle
        curvature = pt.tree_dot(delta, op.matvec(delta))

    new_params = pt.tree_axpy(cfg.lr, delta, params)

    # Levenberg–Marquardt damping from the reduction ratio ρ.
    new_loss = total_loss(new_params)
    quad_decrease = -(pt.tree_dot(grads, delta) + 0.5 * curvature)
    rho = (loss - new_loss) / jnp.maximum(quad_decrease, 1e-30)
    damping = jnp.where(rho > 0.75, state.damping * (2.0 / 3.0), state.damping)
    damping = jnp.where(rho < 0.25, damping * 1.5, damping)
    damping = jnp.clip(damping, cfg.min_damping, cfg.max_damping)

    # Reject steps that increase the loss (keep params, keep basis).
    accept = new_loss < loss
    new_params = jax.tree_util.tree_map(
        lambda a, b: jnp.where(accept, a, b), new_params, params
    )
    delta_kept = jax.tree_util.tree_map(
        lambda a, b: jnp.where(accept, a, b), delta, pt.tree_zeros_like(delta)
    )

    new_state = HFState(
        recycle=recycle_next,
        delta_prev=delta_kept,
        damping=damping,
        step=state.step + 1,
        last_cg_iters=result.info.iterations,
    )
    metrics = {
        "loss": loss,
        "new_loss": new_loss,
        "rho": rho,
        "damping": damping,
        "cg_iterations": result.info.iterations,
        "cg_matvecs": result.info.matvecs,
        "cg_residual": result.info.residual_norm,
        "accepted": accept,
    }
    return new_params, new_state, metrics
