"""Launch-layer tests: sharding rules, HLO stats, roofline math, and a
real 512-device dry-run integration test (subprocess)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_stats, roofline

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class TestHLOStats:
    def test_while_trip_correction(self):
        def step(params, x):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, params)
            return y.sum()

        params = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        compiled = jax.jit(step).lower(params, x).compile()
        a = hlo_stats.analyze(compiled.as_text())
        assert a["flops"] == pytest.approx(6 * 2 * 64**3, rel=1e-6)
        assert 6.0 in a["while_trips"].values()
        # SSA traffic model: bounded by a few × the value sizes per step
        assert a["traffic_bytes"] < 50e6

    def test_collective_parse(self):
        hlo = """
ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  ROOT %ar.1 = f32[16,16]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
}
"""
        st = hlo_stats.collective_stats(hlo)
        assert st["all-reduce"]["count"] == 1
        assert st["all-reduce"]["bytes"] == 16 * 16 * 4


class TestRoofline:
    def _rec(self, **kw):
        rec = {
            "status": "ok", "arch": "x", "shape": "train_4k",
            "mesh": "single", "chips": 256,
            "hlo_flops_per_device": 1.97e13,  # exactly 0.1 s of compute
            "hlo_traffic_bytes_per_device": 81.9e9,  # exactly 0.1 s of HBM
            "collectives": {"all-reduce": {"count": 1, "bytes": 2.5e9}},
            "model_flops": 1.97e13 * 256,  # useful ratio 1.0
        }
        rec.update(kw)
        return rec

    def test_terms(self):
        t = roofline.roofline_terms(self._rec())
        assert t["t_compute_s"] == pytest.approx(0.1)
        assert t["t_memory_s"] == pytest.approx(0.1)
        assert t["t_collective_s"] == pytest.approx(2 * 2.5e9 / 50e9)
        assert t["useful_flops_ratio"] == pytest.approx(1.0)
        assert t["dominant"] in ("compute", "memory", "collective")

    def test_roofline_fraction_at_peak(self):
        # pure-compute cell with ratio 1 → fraction 1
        rec = self._rec(
            hlo_traffic_bytes_per_device=0.0, collectives={},
        )
        t = roofline.roofline_terms(rec)
        assert t["roofline_fraction"] == pytest.approx(1.0)

    def test_skip_and_error_rows(self):
        assert roofline.roofline_terms({"status": "skipped"}) is None


class TestShardingRules:
    def test_param_specs_cover_tree(self):
        from repro import models
        from repro.configs import get_smoke_config
        from repro.launch import mesh as mesh_lib

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        env = mesh_lib.axis_env_for(mesh)
        cfg = get_smoke_config("jamba-v0.1-52b")  # richest param tree
        shapes = jax.eval_shape(
            lambda k: models.init(k, cfg, tp=1),
            jax.ShapeDtypeStruct((2,), jnp.uint32),
        )
        shardings = mesh_lib.param_shardings(mesh, shapes, env)
        assert jax.tree_util.tree_structure(
            shapes
        ) == jax.tree_util.tree_structure(shardings)
        # every leaf got a NamedSharding
        for s in jax.tree_util.tree_leaves(
            shardings,
            is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding),
        ):
            assert isinstance(s, jax.sharding.NamedSharding)


@pytest.mark.slow
class TestDryRunIntegration:
    def test_one_cell_end_to_end(self, tmp_path):
        """Real 512-host-device dry-run of the cheapest cell (subprocess —
        the device count must be set before jax initializes)."""
        out = subprocess.run(
            [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", "qwen1.5-0.5b", "--shape", "decode_32k",
                "--mesh", "single", "--force", "--outdir", str(tmp_path),
            ],
            env={**os.environ, "PYTHONPATH": SRC},
            capture_output=True, text=True, timeout=520,
        )
        assert out.returncode == 0, out.stdout + out.stderr
        rec = json.load(
            open(tmp_path / "qwen1.5-0.5b__decode_32k__single.json")
        )
        assert rec["status"] == "ok"
        assert rec["hlo_flops_per_device"] > 0
        t = roofline.roofline_terms(rec)
        assert t["bound_s"] > 0
