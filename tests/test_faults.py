"""Chaos tests for the fault-tolerant solve runtime (ISSUE 6).

Every claim of the robustness layer is exercised against an ACTUAL
injected fault, in the fast CI tier:

  1. breakdown detection + recovery ladder — a NaN matvec mid-solve is
     detected, classified, recovered from (transient) or retired
     (persistent) with a finite solution and an honest
     ``SolveReport``;
  2. typed statuses — indefinite operators and stalled residuals get
     BREAKDOWN_INDEFINITE / STAGNATED, not a silent MAXITER;
  3. zero clean-path overhead — arming the ladder changes nothing on a
     healthy sequence (same iterates, same matvecs, rung 0 everywhere);
  4. crash-resumable sequences — chunked checkpointed runs match the
     uninterrupted scan exactly, survive a mid-run kill, fall back past
     a truncated checkpoint, and migrate old-schema state pytrees.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.core import (
    FaultInjectingOperator,
    SolveSpec,
    SolveStatus,
    from_matrix,
    solve,
    solve_sequence,
    truncate_latest_checkpoint,
)
from tests.conftest import make_spd


def _spd(n=32, cond=1e2, seed=0):
    rng = np.random.default_rng(seed)
    mat, _, _ = make_spd(n, cond, rng)
    b = rng.standard_normal(n)
    return jnp.asarray(mat), jnp.asarray(b)


def _drifting_sequence(n=40, num=5, seed=0):
    """Stacked drifting SPD systems + rhs, raw-data pytree for the engine."""
    rng = np.random.default_rng(seed)
    base, _, _ = make_spd(n, 1e2, rng)
    mats = jnp.stack(
        [jnp.asarray(base + (1.0 + 0.05 * i) * np.eye(n)) for i in range(num)]
    )
    bs = jnp.asarray(rng.standard_normal((num, n)))
    return mats, bs


SPEC = SolveSpec(k=4, ell=8, tol=1e-8, maxiter=400)


class TestBreakdownAndLadder:
    def test_transient_nan_matvec_recovers(self):
        """A NaN on one executed matvec mid-solve: the ladder redoes the
        solve and converges, with the failed attempt charged."""
        mat, b = _spd()
        clean = solve(from_matrix(mat), b, SPEC)
        assert int(clean.report.status) == SolveStatus.CONVERGED
        assert int(clean.report.rung) == 0

        op = FaultInjectingOperator(from_matrix(mat), at_matvec=3)
        res = solve(op, b, SPEC)
        assert bool(res.info.converged)
        assert int(res.report.status) == SolveStatus.CONVERGED
        assert int(res.report.rung) >= 1
        # honest accounting: the broken attempt's matvecs are charged
        assert int(res.report.matvecs) > int(clean.report.matvecs)
        np.testing.assert_allclose(
            np.asarray(res.x),
            np.linalg.solve(np.asarray(mat), np.asarray(b)),
            rtol=1e-5, atol=1e-7,
        )

    def test_persistent_corruption_retires_finitely(self):
        """Every matvec poisoned: the full ladder fails, yet the front
        door returns FINITE coordinates, a truthful status, and a
        zeroed (retired) recycle state."""
        mat, b = _spd(seed=1)
        op = FaultInjectingOperator(from_matrix(mat), poison=jnp.nan)
        res = solve(op, b, SPEC)
        assert not bool(res.info.converged)
        assert int(res.report.status) == SolveStatus.BREAKDOWN_NONFINITE
        assert int(res.report.rung) == 3
        assert bool(jnp.all(jnp.isfinite(res.x)))
        # retirement: the next solve must bootstrap cold, not deflate
        # with a poisoned basis
        assert bool(jnp.all(res.state.W == 0))
        assert bool(jnp.all(res.state.AW == 0))

    def test_indefinite_operator_is_classified(self):
        """pᵀAp < 0 on an indefinite operator reads
        BREAKDOWN_INDEFINITE, not MAXITER."""
        n = 16
        diag = jnp.ones(n).at[-1].set(-1.0)
        b = jnp.zeros(n).at[-1].set(1.0)
        res = solve(
            from_matrix(jnp.diag(diag)),
            b,
            SolveSpec(method="cg", tol=1e-10, maxiter=50),
        )
        assert not bool(res.info.converged)
        assert int(res.report.status) == SolveStatus.BREAKDOWN_INDEFINITE
        assert SolveStatus.describe(res.report.status) == (
            "BREAKDOWN_INDEFINITE"
        )

    def test_stagnation_detector_stops_early(self):
        """A bounded perturbation floors the residual; the armed
        detector stops with STAGNATED instead of burning maxiter."""
        mat, b = _spd(seed=2)
        op = FaultInjectingOperator(from_matrix(mat), poison=1e-3)
        res = solve(
            op,
            b,
            SolveSpec(
                method="cg",
                tol=1e-12,
                maxiter=400,
                stagnation_window=10,
                recovery_rungs=0,
            ),
        )
        assert int(res.report.status) == SolveStatus.STAGNATED
        assert int(res.info.iterations) < 400

    def test_sequence_broken_system_is_isolated(self):
        """One persistently-broken system inside a sequence: it is
        retired with a truthful per-system status while its neighbors
        (before AND after) still converge — the poison does not travel
        through the recycled basis."""
        mats, bs = _drifting_sequence()
        poison = jnp.zeros(mats.shape[0]).at[2].set(jnp.nan)
        systems = {"mat": mats, "poison": poison}

        def make_op(s):
            return FaultInjectingOperator(from_matrix(s["mat"]), s["poison"])

        res = solve_sequence(systems, bs, SPEC, make_operator=make_op)
        conv = np.asarray(res.info.converged)
        status = np.asarray(res.report.status)
        assert not conv[2]
        assert status[2] == SolveStatus.BREAKDOWN_NONFINITE
        assert int(res.report.rung[2]) == 3
        healthy = [0, 1, 3, 4]
        assert conv[healthy].all()
        assert (status[healthy] == SolveStatus.CONVERGED).all()
        assert bool(jnp.all(jnp.isfinite(res.x)))
        # the broken system was charged for its failed attempts
        mv = np.asarray(res.report.matvecs)
        it = np.asarray(res.info.iterations)
        assert mv[2] >= it[2] + 2

    def test_clean_path_pays_nothing(self):
        """Acceptance: arming the ladder must not change a healthy
        sequence's iterates or matvec totals (fig2/table1 unchanged)."""
        mats, bs = _drifting_sequence(seed=3)
        systems = {"mat": mats}
        mk = lambda s: from_matrix(s["mat"])  # noqa: E731
        armed = solve_sequence(systems, bs, SPEC, make_operator=mk)
        disarmed = solve_sequence(
            systems, bs, SPEC, make_operator=mk, divergence_fallback=False
        )
        np.testing.assert_array_equal(
            np.asarray(armed.info.iterations),
            np.asarray(disarmed.info.iterations),
        )
        np.testing.assert_array_equal(
            np.asarray(armed.info.matvecs),
            np.asarray(disarmed.info.matvecs),
        )
        assert (np.asarray(armed.report.rung) == 0).all()
        np.testing.assert_allclose(
            np.asarray(armed.x), np.asarray(disarmed.x), rtol=0, atol=0
        )


class _DyingManager(CheckpointManager):
    """Kills the process (KeyboardInterrupt) after N successful saves."""

    def __init__(self, directory, die_after):
        super().__init__(directory)
        self.saves = 0
        self.die_after = die_after

    def save(self, tree, step, **kw):
        super().save(tree, step, **kw)
        self.saves += 1
        if self.saves >= self.die_after:
            raise KeyboardInterrupt("simulated preemption")


class TestResumableSequences:
    def _run(self, mgr=None, resume=False, **kw):
        mats, bs = _drifting_sequence()
        systems = {"mat": mats}
        mk = lambda s: from_matrix(s["mat"])  # noqa: E731
        return solve_sequence(
            systems,
            bs,
            SPEC,
            make_operator=mk,
            checkpoint=mgr,
            checkpoint_every=2 if mgr is not None else 0,
            resume=resume,
            **kw,
        )

    def test_chunked_matches_unchunked(self, tmp_path):
        whole = self._run()
        chunked = self._run(CheckpointManager(str(tmp_path)))
        np.testing.assert_allclose(
            np.asarray(chunked.x), np.asarray(whole.x), rtol=0, atol=0
        )
        np.testing.assert_array_equal(
            np.asarray(chunked.info.iterations),
            np.asarray(whole.info.iterations),
        )
        np.testing.assert_array_equal(
            np.asarray(chunked.info.matvecs), np.asarray(whole.info.matvecs)
        )
        np.testing.assert_allclose(
            np.asarray(chunked.state.W), np.asarray(whole.state.W),
            rtol=0, atol=0,
        )

    def test_kill_and_resume_reproduces_iterates(self, tmp_path):
        """Killed after the first chunk's checkpoint, resumed in a fresh
        manager: bit-identical to the uninterrupted run."""
        whole = self._run(CheckpointManager(str(tmp_path / "ref")))
        with pytest.raises(KeyboardInterrupt):
            self._run(_DyingManager(str(tmp_path / "ckpt"), die_after=1))
        resumed = self._run(
            CheckpointManager(str(tmp_path / "ckpt")), resume=True
        )
        np.testing.assert_allclose(
            np.asarray(resumed.x), np.asarray(whole.x), rtol=0, atol=0
        )
        np.testing.assert_array_equal(
            np.asarray(resumed.info.iterations),
            np.asarray(whole.info.iterations),
        )
        np.testing.assert_array_equal(
            np.asarray(resumed.report.status),
            np.asarray(whole.report.status),
        )
        np.testing.assert_allclose(
            np.asarray(resumed.state.W), np.asarray(whole.state.W),
            rtol=0, atol=0,
        )

    def test_resume_past_truncated_checkpoint(self, tmp_path):
        """A torn-disk checkpoint (manifest intact, payload garbage) is
        skipped WITH a recorded reason, and the run still completes."""
        mgr = _DyingManager(str(tmp_path), die_after=2)
        with pytest.raises(KeyboardInterrupt):
            self._run(mgr)
        step = truncate_latest_checkpoint(str(tmp_path))
        assert step is not None
        fresh = CheckpointManager(str(tmp_path))
        resumed = self._run(fresh, resume=True)
        whole = self._run()
        np.testing.assert_allclose(
            np.asarray(resumed.x), np.asarray(whole.x), rtol=0, atol=0
        )
        # the skip was observable, not a silent `except: continue`
        assert fresh.last_skipped
        assert fresh.last_skipped[0][0] == step


class TestCheckpointSatellites:
    def test_schema_migration_defaults_grown_leaf(self, tmp_path):
        """A template that grew a field since the checkpoint was written
        (the documented pre-PR-4 RecycleState.drift break) restores with
        a warning instead of being rejected."""
        old = {"w": jnp.arange(4.0)}
        save_pytree(old, str(tmp_path), step=0)
        template = {"w": jnp.zeros(4), "drift": jnp.float64(7.5)}
        with pytest.warns(UserWarning, match="schema migration"):
            out = restore_pytree(
                template, str(tmp_path / "step_00000000")
            )
        np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(4.0))
        assert float(out["drift"]) == 7.5  # template default kept

    def test_unknown_checkpoint_leaf_still_rejected(self, tmp_path):
        """Dropping SAVED state silently is never safe — a checkpoint
        leaf with no home in the template stays a hard error."""
        save_pytree({"w": jnp.zeros(3), "extra": jnp.ones(2)},
                    str(tmp_path), step=0)
        with pytest.raises(ValueError, match="no home"):
            restore_pytree({"w": jnp.zeros(3)},
                           str(tmp_path / "step_00000000"))

    def test_async_save_error_reraises(self, tmp_path, monkeypatch):
        """A failed background write surfaces on the next wait()/save()
        instead of masquerading as a committed checkpoint."""
        from repro.checkpoint import manager as manager_mod

        mgr = CheckpointManager(str(tmp_path))

        def boom(*a, **k):
            raise OSError("disk full")

        monkeypatch.setattr(manager_mod, "save_pytree", boom)
        mgr.save({"w": jnp.zeros(2)}, step=0, blocking=False)
        with pytest.raises(RuntimeError, match="NOT committed"):
            mgr.wait()
        # the error is raised ONCE, then cleared
        mgr.wait()

    def test_resume_kwargs_need_checkpoint(self):
        mats, bs = _drifting_sequence(num=2)
        with pytest.raises(ValueError, match="CheckpointManager"):
            solve_sequence(
                {"mat": mats}, bs, SPEC,
                make_operator=lambda s: from_matrix(s["mat"]),
                checkpoint_every=2,
            )


class TestFaultOperatorUnit:
    def test_poison_arithmetic(self):
        mat, _ = _spd(n=8)
        v = jnp.ones(8)
        op = FaultInjectingOperator(from_matrix(mat), poison=0.5)
        np.testing.assert_allclose(
            np.asarray(op(v)), np.asarray(mat @ v + 0.5), rtol=1e-12
        )

    def test_is_a_pytree_with_traced_poison(self):
        mat, _ = _spd(n=8)
        op = FaultInjectingOperator(from_matrix(mat), poison=jnp.float64(0.0))
        leaves = jax.tree_util.tree_leaves(op)
        assert any(np.asarray(l).shape == () for l in leaves)

    def test_host_counter_counts(self):
        mat, _ = _spd(n=8)
        op = FaultInjectingOperator(from_matrix(mat), at_matvec=1)
        v = jnp.ones(8)
        out0 = op(v)
        out1 = op(v)  # poisoned
        out2 = op(v)
        assert op.executed_matvecs == 3
        assert bool(jnp.all(jnp.isfinite(out0)))
        assert not bool(jnp.all(jnp.isfinite(out1)))
        assert bool(jnp.all(jnp.isfinite(out2)))
        op.reset()
        assert op.executed_matvecs == 0
