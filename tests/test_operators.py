"""Adjoint contract of every LinearOperator flavour.

The LSMR engine (``core/lsmr.py``) touches operators only through the
``matvec``/``rmatvec`` pair resolved by ``operators.adjoint_matvec``;
its correctness rests entirely on the adjoint identity

    ⟨A v, w⟩ = ⟨v, Aᵀ w⟩   for all v ∈ domain, w ∈ range.

These tests check that identity to 1e-10 on random rectangular shapes
for every operator class in the repo — including the implicitly
symmetric ones, whose adjoint is their own matvec by contract.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pytree as pt
from repro.core.faults import FaultInjectingOperator
from repro.core.operators import (
    DenseMatrixOperator,
    GaussNewtonOperator,
    GGNOperator,
    KernelSystemOperator,
    LinearOperator,
    adjoint_matvec,
    from_callable,
    from_matrix,
)

ADJ_TOL = 1e-10

# A spread of genuinely rectangular shapes (tall, wide, square) so a
# transposition bug cannot hide behind m == n.
RECT_SHAPES = [(7, 4), (4, 7), (23, 11), (11, 23), (16, 16)]


def _adjoint_gap(op, v, w):
    """|⟨Av, w⟩ − ⟨v, Aᵀw⟩| scaled to the magnitudes involved."""
    av = op.matvec(v)
    atw = adjoint_matvec(op)(w)
    lhs = pt.tree_dot(av, w)
    rhs = pt.tree_dot(v, atw)
    scale = max(1.0, abs(float(lhs)), abs(float(rhs)))
    return abs(float(lhs - rhs)) / scale


class TestRectangularAdjoints:
    @pytest.mark.parametrize("m,n", RECT_SHAPES)
    def test_dense_matrix_operator(self, m, n):
        rng = np.random.default_rng(m * 100 + n)
        op = DenseMatrixOperator(jnp.asarray(rng.standard_normal((m, n))))
        v = jnp.asarray(rng.standard_normal(n))
        w = jnp.asarray(rng.standard_normal(m))
        assert _adjoint_gap(op, v, w) < ADJ_TOL

    @pytest.mark.parametrize("m,n", RECT_SHAPES)
    def test_dense_matrix_operator_T_roundtrip(self, m, n):
        rng = np.random.default_rng(m * 100 + n + 1)
        A = jnp.asarray(rng.standard_normal((m, n)))
        op = DenseMatrixOperator(A)
        v = jnp.asarray(rng.standard_normal(n))
        w = jnp.asarray(rng.standard_normal(m))
        # .T is itself a DenseMatrixOperator whose adjoint is the original
        np.testing.assert_allclose(
            np.asarray(op.T.matvec(w)), np.asarray(A.T @ w), atol=1e-12
        )
        assert _adjoint_gap(op.T, w, v) < ADJ_TOL
        np.testing.assert_array_equal(
            np.asarray(op.T.T.mat), np.asarray(A)
        )

    @pytest.mark.parametrize("m,n", RECT_SHAPES)
    def test_linear_operator_with_rmatvec(self, m, n):
        rng = np.random.default_rng(m * 100 + n + 2)
        A = jnp.asarray(rng.standard_normal((m, n)))
        op = LinearOperator(
            matvec=lambda v: A @ v, rmatvec=lambda u: A.T @ u
        )
        v = jnp.asarray(rng.standard_normal(n))
        w = jnp.asarray(rng.standard_normal(m))
        assert _adjoint_gap(op, v, w) < ADJ_TOL
        # T swaps the closures and T.T round-trips
        assert _adjoint_gap(op.T, w, v) < ADJ_TOL
        np.testing.assert_allclose(
            np.asarray(op.T.T.matvec(v)), np.asarray(A @ v), atol=1e-12
        )

    @pytest.mark.parametrize("m,n", RECT_SHAPES)
    def test_gauss_newton_operator(self, m, n):
        """J of a nonlinear residual map: jvp vs vjp must be adjoint."""
        rng = np.random.default_rng(m * 100 + n + 3)
        X = jnp.asarray(rng.standard_normal((m, n)))
        y = jnp.asarray(rng.standard_normal(m))
        op = GaussNewtonOperator(
            residual_fn=lambda p: jnp.tanh(X @ p) - y,
            params=jnp.asarray(rng.standard_normal(n)),
        )
        v = jnp.asarray(rng.standard_normal(n))
        w = jnp.asarray(rng.standard_normal(m))
        assert _adjoint_gap(op, v, w) < ADJ_TOL
        # .T exposes the swapped pair as a LinearOperator
        assert _adjoint_gap(op.T, w, v) < ADJ_TOL

    def test_gauss_newton_operator_pytree_domain(self):
        """Params and residuals may both be pytrees — the adjoint holds
        in the raveled inner product."""
        rng = np.random.default_rng(7)
        X = jnp.asarray(rng.standard_normal((9, 5)))

        def residual_fn(p):
            h = jnp.tanh(X @ p["w"] + p["b"])
            return {"r1": h[:4], "r2": 2.0 * h[4:]}

        params = {
            "w": jnp.asarray(rng.standard_normal(5)),
            "b": jnp.asarray(rng.standard_normal(())),
        }
        op = GaussNewtonOperator(residual_fn=residual_fn, params=params)
        v = {
            "w": jnp.asarray(rng.standard_normal(5)),
            "b": jnp.asarray(rng.standard_normal(())),
        }
        w = {
            "r1": jnp.asarray(rng.standard_normal(4)),
            "r2": jnp.asarray(rng.standard_normal(5)),
        }
        assert _adjoint_gap(op, v, w) < ADJ_TOL

    @pytest.mark.parametrize("m,n", RECT_SHAPES)
    def test_scaled_and_sum_preserve_adjoint(self, m, n):
        rng = np.random.default_rng(m * 100 + n + 4)
        A = jnp.asarray(rng.standard_normal((m, n)))
        B = jnp.asarray(rng.standard_normal((m, n)))
        opA = LinearOperator(lambda v: A @ v, rmatvec=lambda u: A.T @ u)
        opB = LinearOperator(lambda v: B @ v, rmatvec=lambda u: B.T @ u)
        v = jnp.asarray(rng.standard_normal(n))
        w = jnp.asarray(rng.standard_normal(m))
        assert _adjoint_gap(opA.scaled(-1.7), v, w) < ADJ_TOL
        assert _adjoint_gap(opA + opB, v, w) < ADJ_TOL

    def test_shifted_preserves_adjoint_square(self):
        rng = np.random.default_rng(11)
        A = jnp.asarray(rng.standard_normal((13, 13)))
        op = LinearOperator(lambda v: A @ v, rmatvec=lambda u: A.T @ u)
        v = jnp.asarray(rng.standard_normal(13))
        w = jnp.asarray(rng.standard_normal(13))
        assert _adjoint_gap(op.shifted(0.37), v, w) < ADJ_TOL


class TestSymmetricByContract:
    """Operators without an ``rmatvec`` declare themselves symmetric:
    ``adjoint_matvec`` resolves to their own matvec, and the adjoint
    identity must hold with that resolution (i.e. they really ARE
    symmetric — a non-symmetric operator sneaking through the implicit
    contract is exactly the bug this guards against)."""

    def test_from_callable_symmetric(self):
        rng = np.random.default_rng(21)
        A = rng.standard_normal((12, 12))
        S = jnp.asarray(A + A.T)
        op = from_callable(lambda v: S @ v)
        v = jnp.asarray(rng.standard_normal(12))
        w = jnp.asarray(rng.standard_normal(12))
        assert adjoint_matvec(op) is op.matvec
        assert _adjoint_gap(op, v, w) < ADJ_TOL

    def test_from_matrix_spd(self):
        rng = np.random.default_rng(22)
        A = rng.standard_normal((10, 10))
        op = from_matrix(jnp.asarray(A @ A.T + 10 * np.eye(10)))
        v = jnp.asarray(rng.standard_normal(10))
        w = jnp.asarray(rng.standard_normal(10))
        assert _adjoint_gap(op, v, w) < ADJ_TOL

    def test_kernel_system_operator(self):
        rng = np.random.default_rng(23)
        G = rng.standard_normal((14, 14))
        K = jnp.asarray(G @ G.T)
        op = KernelSystemOperator(
            kernel_matvec=lambda u: K @ u,
            sqrt_h=jnp.asarray(rng.uniform(0.1, 1.0, 14)),
        )
        v = jnp.asarray(rng.standard_normal(14))
        w = jnp.asarray(rng.standard_normal(14))
        assert _adjoint_gap(op, v, w) < ADJ_TOL

    def test_ggn_operator(self):
        rng = np.random.default_rng(24)
        X = jnp.asarray(rng.standard_normal((20, 6)))
        op = GGNOperator(
            model_fn=lambda p: jnp.tanh(X @ p),
            loss_hvp=lambda out, t: 2.0 * t / out.size,
            params=jnp.asarray(rng.standard_normal(6)),
            damping=jnp.asarray(0.3),
        )
        v = jnp.asarray(rng.standard_normal(6))
        w = jnp.asarray(rng.standard_normal(6))
        assert _adjoint_gap(op, v, w) < ADJ_TOL

    def test_fault_injecting_wrapper_with_zero_poison(self):
        """poison=0.0 is a bit-exact no-op, so the wrapper inherits the
        base operator's (symmetric) adjoint."""
        rng = np.random.default_rng(25)
        A = rng.standard_normal((9, 9))
        base = from_matrix(jnp.asarray(A @ A.T + 9 * np.eye(9)))
        op = FaultInjectingOperator(base=base, poison=jnp.asarray(0.0))
        v = jnp.asarray(rng.standard_normal(9))
        w = jnp.asarray(rng.standard_normal(9))
        av = op(v)
        atw = adjoint_matvec(base)(w)
        gap = abs(float(pt.tree_dot(av, w) - pt.tree_dot(v, atw)))
        assert gap < ADJ_TOL


class TestAdjointResolution:
    def test_adjoint_matvec_prefers_rmatvec(self):
        rng = np.random.default_rng(31)
        A = jnp.asarray(rng.standard_normal((5, 3)))
        op = DenseMatrixOperator(A)
        u = jnp.asarray(rng.standard_normal(5))
        np.testing.assert_allclose(
            np.asarray(adjoint_matvec(op)(u)), np.asarray(A.T @ u),
            atol=1e-12,
        )

    def test_adjoint_matvec_bare_callable(self):
        f = lambda v: 2.0 * v  # noqa: E731
        assert adjoint_matvec(f) is f

    def test_adjoint_under_jit_and_vmap(self):
        """The pair survives jit+vmap — the shape LSMR actually runs in
        (batched tenants under one compiled program)."""
        rng = np.random.default_rng(32)
        mats = jnp.asarray(rng.standard_normal((4, 8, 5)))
        vs = jnp.asarray(rng.standard_normal((4, 5)))
        ws = jnp.asarray(rng.standard_normal((4, 8)))

        @jax.jit
        @jax.vmap
        def gaps(mat, v, w):
            op = DenseMatrixOperator(mat)
            return pt.tree_dot(op.matvec(v), w) - pt.tree_dot(
                v, adjoint_matvec(op)(w)
            )

        assert float(jnp.max(jnp.abs(gaps(mats, vs, ws)))) < ADJ_TOL
