"""Optimizer substrate tests: AdamW, Hessian-free w/ recycling, PowerSGD."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pytree as pt
from repro.optim import (
    HFConfig,
    adam_init,
    adam_update,
    compress_decompress,
    hf_init,
    hf_step,
    powersgd_init,
    squared_loss_hvp,
)


class TestAdam:
    def test_converges_on_quadratic(self):
        target = {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([[0.5, -0.5]])}
        params = jax.tree_util.tree_map(jnp.zeros_like, target)
        state = adam_init(params)

        def loss(p):
            return pt.tree_dot(
                pt.tree_sub(p, target), pt.tree_sub(p, target)
            )

        for _ in range(400):
            g = jax.grad(loss)(params)
            params, state = adam_update(g, state, params, lr=3e-2)
        assert float(loss(params)) < 1e-3


class TestHessianFree:
    def _problem(self, seed=0):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((64, 8)))
        w_true = jnp.asarray(rng.standard_normal((8, 3)))
        y = jnp.tanh(x @ w_true)

        def model_fn(params, batch):
            return jnp.tanh(batch["x"] @ params["w"])

        def loss_fn(outputs, batch):
            return jnp.mean(jnp.square(outputs - batch["y"]))

        batch = {"x": x, "y": y}
        params = {"w": jnp.asarray(rng.standard_normal((8, 3))) * 0.1}
        return model_fn, loss_fn, batch, params

    def test_hf_reduces_loss(self):
        model_fn, loss_fn, batch, params = self._problem()
        cfg = HFConfig(k=4, ell=8, cg_maxiter=30, init_damping=0.1)
        state = hf_init(params, cfg, jax.random.PRNGKey(0))
        losses = []
        for _ in range(12):
            params, state, m = hf_step(
                params, state, batch,
                model_fn=model_fn, loss_fn=loss_fn,
                loss_hvp=squared_loss_hvp, cfg=cfg,
            )
            losses.append(float(m["loss"]))
        assert losses[-1] < 0.05 * losses[0]

    def test_hf_beats_gd_per_step(self):
        # Second-order steps should beat plain gradient steps in 12 its.
        model_fn, loss_fn, batch, params0 = self._problem(seed=3)
        cfg = HFConfig(k=4, ell=8, cg_maxiter=30, init_damping=0.1)
        params = jax.tree_util.tree_map(lambda x: x, params0)
        state = hf_init(params, cfg, jax.random.PRNGKey(0))
        for _ in range(12):
            params, state, m = hf_step(
                params, state, batch,
                model_fn=model_fn, loss_fn=loss_fn,
                loss_hvp=squared_loss_hvp, cfg=cfg,
            )
        hf_loss = float(m["new_loss"])

        def loss(p):
            return loss_fn(model_fn(p, batch), batch)

        params = params0
        for _ in range(12):
            params = pt.tree_axpy(-0.5, jax.grad(loss)(params), params)
        gd_loss = float(loss(params))
        assert hf_loss < gd_loss

    def test_recycling_reduces_cg_iterations(self):
        """Later HF steps should need fewer CG iterations with recycling
        than the no-recycle baseline — the paper's claim, on a GGN
        sequence instead of a GP Newton sequence."""
        model_fn, loss_fn, batch, params = self._problem(seed=5)
        totals = {}
        for recycle in (True, False):
            p = jax.tree_util.tree_map(lambda x: x, params)
            cfg = HFConfig(
                k=4, ell=8, cg_maxiter=200, cg_tol=1e-6,
                init_damping=0.1, recycle=recycle,
            )
            st = hf_init(p, cfg, jax.random.PRNGKey(1))
            iters = []
            for _ in range(10):
                p, st, m = hf_step(
                    p, st, batch,
                    model_fn=model_fn, loss_fn=loss_fn,
                    loss_hvp=squared_loss_hvp, cfg=cfg,
                )
                iters.append(int(m["cg_iterations"]))
            totals[recycle] = sum(iters[2:])
        assert totals[True] <= totals[False]


class TestPowerSGD:
    def test_compression_and_error_feedback(self):
        rng = np.random.default_rng(0)
        grads = {
            "w": jnp.asarray(rng.standard_normal((64, 32))),
            "b": jnp.asarray(rng.standard_normal(32)),
        }
        state = powersgd_init(grads, rank=4, key=jax.random.PRNGKey(0))
        ghat, state, metrics = compress_decompress(grads, state)
        assert metrics["compression_ratio"] > 4
        # 1-D params pass through exactly
        np.testing.assert_allclose(np.asarray(ghat["b"]), np.asarray(grads["b"]))
        # error feedback: memory holds the residual
        resid = np.asarray(grads["w"]) - np.asarray(ghat["w"])
        np.testing.assert_allclose(
            np.asarray(state.error["w"]), resid, rtol=1e-4, atol=1e-5
        )

    def test_recycled_basis_tracks_static_subspace(self):
        """With a fixed low-rank gradient, the recycled basis converges and
        compression becomes near-exact — subspace transfer across steps."""
        rng = np.random.default_rng(1)
        u = rng.standard_normal((64, 4))
        v = rng.standard_normal((32, 4))
        g = {"w": jnp.asarray(u @ v.T)}
        state = powersgd_init(g, rank=4, key=jax.random.PRNGKey(0))
        errs = []
        for _ in range(5):
            ghat, state, _ = compress_decompress(g, state)
            errs.append(
                float(jnp.linalg.norm(g["w"] - ghat["w"]))
                / float(jnp.linalg.norm(g["w"]))
            )
        assert errs[-1] < 1e-4
        assert errs[-1] <= errs[0] + 1e-6  # no degradation across steps
