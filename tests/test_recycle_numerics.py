"""Regression tests for the rank-revealing harmonic-Ritz extraction and
the prefill/forward consistency invariant (EXPERIMENTS §Paper-validation
numerics finding + §Perf cell C)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import RecycleManager, cg, defcg, from_matrix, harmonic_ritz
from repro.core import pytree as pt


class TestRitzNumerics:
    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(64, 200),
        k=st.integers(2, 8),
        span=st.floats(2.0, 5.0),
        seed=st.integers(0, 2**16),
    )
    def test_theta_positive_and_outliers_found(self, n, k, span, seed):
        """Extraction from a long recording window must return strictly
        positive Ritz values approximating the top eigenvalues — the
        mixed-column-scale rounding regression (see core/recycle.py)."""
        rng = np.random.default_rng(seed)
        q, _ = np.linalg.qr(rng.standard_normal((n, n)))
        eigs = np.concatenate(
            [np.linspace(1.0, 10.0, n - k), np.logspace(3, 3 + span, k)]
        )
        A = jnp.asarray((q * eigs) @ q.T)
        b = jnp.asarray(rng.standard_normal(n))

        res = defcg(from_matrix(A), b, tol=1e-10, maxiter=20 * n, ell=3 * k)
        m = int(res.recycle.stored)
        Z = pt.basis_slice(res.recycle.P, m)
        AZ = pt.basis_slice(res.recycle.AP, m)
        W, AW, theta = harmonic_ritz(Z, AZ, k)
        th = np.sort(np.asarray(theta))[::-1]
        assert (th > 0).all()
        # top Ritz value ≈ top eigenvalue
        np.testing.assert_allclose(th[0], eigs[-1], rtol=0.05)

    def test_recycled_solve_meets_kappa_eff_bound(self):
        """After the numerics fix the *recycled* (Ritz-W) solve obeys the
        κ_eff iteration bound, not just the exact-eigenvector one."""
        rng = np.random.default_rng(3)
        n, k = 256, 8
        q, _ = np.linalg.qr(rng.standard_normal((n, n)))
        eigs = np.concatenate(
            [np.linspace(1.0, 10.0, n - k), np.logspace(3, 5, k)]
        )
        A = jnp.asarray((q * eigs) @ q.T)
        mgr = RecycleManager(k=k, ell=3 * k, tol=1e-5, maxiter=10000)
        mgr.solve(from_matrix(A), jnp.asarray(rng.standard_normal(n)))
        b2 = jnp.asarray(rng.standard_normal(n))
        rec = mgr.solve(from_matrix(A), b2, reuse_aw=True)
        fresh = cg(from_matrix(A), b2, tol=1e-5, maxiter=10000)
        bound = 1.5 * 0.5 * np.sqrt(10.0) * np.log(2.0 / 1e-5)
        assert int(rec.info.iterations) <= bound
        assert int(rec.info.iterations) < 0.5 * int(fresh.info.iterations)
        np.testing.assert_allclose(
            np.asarray(A @ rec.x), np.asarray(b2),
            atol=1e-4 * float(jnp.linalg.norm(b2)),
        )


class TestPrefillConsistency:
    @pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-1.3b"])
    def test_prefill_then_decode_matches_forward(self, arch):
        """prefill(prompt) + decode(next) must equal the full forward on
        [prompt; next] — the §Perf cell-C fix must stay semantics-exact."""
        from repro import models
        from repro.configs import get_smoke_config
        from repro.models.layers import lm_head_weights

        cfg = get_smoke_config(arch)
        b, s = 2, 24
        params = models.init(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(
            jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size
        )

        hidden, _ = models.forward_hidden(params, {"tokens": tokens}, cfg)
        full_logits = hidden @ lm_head_weights(params["embed"], cfg)

        state = models.init_decode_state(cfg, b, max_len=s)
        state, pre_logits = models.prefill(
            params, {"tokens": tokens[:, : s - 1]}, state, cfg
        )
        # prefill's last-position logits == forward logits at position s-2
        np.testing.assert_allclose(
            np.asarray(pre_logits[:, 0], np.float32),
            np.asarray(full_logits[:, s - 2], np.float32),
            rtol=2e-2, atol=2e-2,
        )
        dec_logits, state = models.decode_step(
            params, tokens[:, s - 1 :], state, cfg
        )
        np.testing.assert_allclose(
            np.asarray(dec_logits[:, 0], np.float32),
            np.asarray(full_logits[:, s - 1], np.float32),
            rtol=2e-2, atol=2e-2,
        )
