"""Public-API snapshot: the config surface must not silently fork again.

ISSUE 3 exists because five entry points each grew overlapping kwargs
with drifting defaults.  This test pins (a) ``repro.core.__all__`` and
(b) the exact ``SolveSpec`` field set + defaults, so any future PR that
adds a parallel config path (or quietly changes a shared default) fails
here and has to update the snapshot EXPLICITLY — with a reviewable diff.
"""

import dataclasses

import repro.core as core
from repro.core import HarmonicRitz, SolveSpec
from repro.core.solvers import DEFAULT_WAW_JITTER

# Alphabetical snapshot of the public surface.  Additions are fine (update
# deliberately); removals/renames are API breaks.
EXPECTED_CORE_ALL = sorted(
    [
        # front doors (core/api.py)
        "BatchSolveResult",
        "SequenceSolveResult",
        "SolveReport",
        "SolveResult",
        "SolveSpec",
        "make_preconditioner",
        "solve",
        "solve_batch",
        "solve_batch_jit",
        "solve_jit",
        "solve_pool_step",
        "solve_pool_step_jit",
        "solve_sequence",
        # fault injection (ISSUE 6: chaos instrumentation)
        "FaultInjectingOperator",
        "truncate_latest_checkpoint",
        # operators
        "GaussNewtonOperator",
        "GGNOperator",
        "KernelSystemOperator",
        "DenseMatrixOperator",
        "LinearOperator",
        "adjoint_matvec",
        "apply_to_basis",
        "from_callable",
        "from_matrix",
        "materialize",
        # least-squares engine (ISSUE 9: the method axis)
        "lsmr",
        "lsmr_jit",
        "solve_sequence_lsmr",
        "solve_sequence_lsmr_jit",
        # preconditioners
        "JacobiPreconditioner",
        "NystromPreconditioner",
        "WoodburyKernelPreconditioner",
        "jacobi",
        "kernel_nystrom_preconditioner",
        "nystrom_preconditioner",
        "randomized_nystrom",
        # recycling
        "MAX_RECOVERY_RUNGS",
        "RecycleManager",
        "RecycleState",
        "SequenceResult",
        "harmonic_ritz",
        "harmonic_ritz_flat",
        "random_orthonormal_basis",
        "recycled_solve_jit",
        "solve_sequence_jit",
        # solvers
        "DEFAULT_WAW_JITTER",
        "CGResult",
        "RecycleData",
        "SolveInfo",
        "SolveStatus",
        "cg",
        "cholesky_solve",
        "defcg",
        "deflated_initial_guess",
        # recycle strategies (ISSUE 5: the extraction/refresh axis)
        "HarmonicRitz",
        "MGeometryHarmonic",
        "RecycleStrategy",
        "WindowedRecombine",
    ]
)

# The ONE solver-configuration schema.  Field name -> default.
EXPECTED_SOLVESPEC_FIELDS = {
    "method": "defcg",
    "k": 8,
    "ell": 12,
    "tol": 1e-5,
    "atol": 0.0,
    "maxiter": 1000,
    "select": "largest",
    "waw_jitter": DEFAULT_WAW_JITTER,
    "refresh_aw": "exact",
    "precond": "none",
    "precond_rank": 16,
    "precond_sigma": 1.0,
    "strategy": HarmonicRitz(),
    # ISSUE 6: the fault-tolerance knobs
    "recovery_rungs": 3,
    "recovery_shift": 1e-6,
    "stagnation_window": 0,
    # ISSUE 9: regularization shift λ for the least-squares methods
    "lsq_shift": 0.0,
}

# Failure-handling diagnostics returned by every front door.
EXPECTED_SOLVEREPORT_FIELDS = ("status", "rung", "guard_firings", "matvecs")


def test_solvereport_field_schema():
    from repro.core import SolveReport

    assert SolveReport._fields == EXPECTED_SOLVEREPORT_FIELDS


def test_core_all_snapshot():
    assert sorted(core.__all__) == EXPECTED_CORE_ALL


def test_core_all_resolves():
    for name in core.__all__:
        assert getattr(core, name) is not None, name


def test_solvespec_field_schema():
    fields = {f.name: f.default for f in dataclasses.fields(SolveSpec)}
    assert fields == EXPECTED_SOLVESPEC_FIELDS


def test_solvespec_frozen_and_hashable():
    spec = SolveSpec()
    assert hash(spec) == hash(SolveSpec())
    try:
        spec.k = 5  # type: ignore[misc]
    except dataclasses.FrozenInstanceError:
        pass
    else:  # pragma: no cover
        raise AssertionError("SolveSpec must be frozen")


def test_waw_jitter_never_forks():
    """The unified default is exactly 1e-12 everywhere it surfaces."""
    import inspect

    from repro.core import RecycleManager, defcg
    from repro.core import recycle as recycle_mod

    assert DEFAULT_WAW_JITTER == 1e-12
    assert SolveSpec().waw_jitter == DEFAULT_WAW_JITTER
    assert (
        inspect.signature(defcg).parameters["waw_jitter"].default
        == DEFAULT_WAW_JITTER
    )
    assert (
        inspect.signature(recycle_mod.solve_sequence)
        .parameters["waw_jitter"]
        .default
        == DEFAULT_WAW_JITTER
    )
    assert RecycleManager(k=2, ell=4).waw_jitter == DEFAULT_WAW_JITTER
