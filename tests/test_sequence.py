"""Device-resident sequence engine tests (ISSUE 2 tentpole).

Four layers of checks:

  1. flat/pytree parity: ``harmonic_ritz_flat`` must reproduce the pytree
     ``harmonic_ritz`` (the semantic oracle) at 1e-10, including with a
     traced validity mask standing in for the oracle's static slice;
  2. ``solve_sequence``: a drifting-operator sequence run as ONE jitted
     scan must show falling def-CG iteration counts (paper Fig. 2
     qualitative check), correct solutions, and honest matvec accounting;
  3. host-sync freedom: the whole N-system sequence must trace (no
     ``int()``/``.item()`` on traced state in the per-system path);
  4. multi-RHS refresh: ``apply_to_basis`` must equal the k-matvec sweep
     for every concrete operator (one fused pass ≡ k applications).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    GGNOperator,
    KernelSystemOperator,
    apply_to_basis,
    defcg,
    from_matrix,
    harmonic_ritz,
    harmonic_ritz_flat,
    solve_sequence_jit,
)
from repro.core import pytree as pt
from tests.conftest import make_spd


def _recorded_basis(n=120, k=6, ell=14, seed=0):
    """Run one recording def-CG solve; return its (P, AP, stored)."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    eigs = np.concatenate(
        [np.linspace(1.0, 5.0, n - k), np.logspace(3, 4.5, k)]
    )
    A = jnp.asarray((q * eigs) @ q.T)
    b = jnp.asarray(rng.standard_normal(n))
    res = defcg(from_matrix(A), b, tol=1e-12, maxiter=20 * n, ell=ell)
    return res.recycle, A, b


class TestFlatPytreeParity:
    def test_full_window_parity(self):
        """Flat extraction == pytree oracle at 1e-10 on a full window."""
        rec, _, _ = _recorded_basis()
        k, ell = 6, 14
        m = int(rec.stored)
        assert m == ell  # sanity: the window filled
        Wp, AWp, thp = harmonic_ritz(rec.P, rec.AP, k)
        Wf, AWf, thf = harmonic_ritz_flat(rec.P, rec.AP, k)
        np.testing.assert_allclose(
            np.asarray(thf), np.asarray(thp), rtol=1e-10
        )
        # Ritz vectors match up to per-column sign (eigh convention).
        Wp_flat = pt.ravel_basis(Wp)
        signs = jnp.sign(jnp.sum(Wp_flat * Wf, axis=1))
        np.testing.assert_allclose(
            np.asarray(Wf * signs[:, None]), np.asarray(Wp_flat),
            rtol=1e-8, atol=1e-10,
        )
        np.testing.assert_allclose(
            np.asarray(AWf * signs[:, None]), np.asarray(pt.ravel_basis(AWp)),
            rtol=1e-8, atol=1e-8,
        )

    def test_masked_window_matches_static_slice(self):
        """A traced validity mask must equal the oracle's static slice —
        the host-sync-free replacement for ``int(stored)`` + ``[:m]``."""
        rec, _, _ = _recorded_basis(n=90, k=4, ell=20, seed=3)
        stored = 11  # pretend the solve stopped mid-window
        P_sl = pt.basis_slice(rec.P, stored)
        AP_sl = pt.basis_slice(rec.AP, stored)
        Wp, _, thp = harmonic_ritz(P_sl, AP_sl, 4)
        _, _, thf = harmonic_ritz_flat(
            rec.P, rec.AP, 4, valid=jnp.arange(20) < jnp.int32(stored)
        )
        np.testing.assert_allclose(
            np.asarray(thf), np.asarray(thp), rtol=1e-10
        )

    def test_extracted_flat_basis_deflates(self):
        """End-to-end: the flat-extracted basis speeds up a second solve."""
        rec, A, _ = _recorded_basis(seed=5)
        W, AW, _ = harmonic_ritz_flat(rec.P, rec.AP, 6)
        rng = np.random.default_rng(99)
        b2 = jnp.asarray(rng.standard_normal(A.shape[0]))
        fresh = defcg(from_matrix(A), b2, tol=1e-8, maxiter=3000, ell=0)
        defl = defcg(from_matrix(A), b2, W=W, AW=AW, tol=1e-8, maxiter=3000)
        assert int(defl.info.iterations) < int(fresh.info.iterations)
        np.testing.assert_allclose(
            np.asarray(A @ defl.x), np.asarray(b2),
            atol=1e-6 * float(jnp.linalg.norm(b2)),
        )


def _drifting_sequence(n=96, k=8, num=5, seed=11, drift=0.01):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    eigs = np.concatenate(
        [np.linspace(1.0, 5.0, n - k), np.logspace(3.0, 4.5, k)]
    )
    base = (q * eigs) @ q.T
    mats, bs = [], []
    for _ in range(num):
        pert = rng.standard_normal((n, n)) * drift
        mats.append(base + pert @ pert.T)  # SPD drift
        bs.append(rng.standard_normal(n))
    return jnp.asarray(np.stack(mats)), jnp.asarray(np.stack(bs))


class TestSolveSequence:
    def test_drifting_sequence_iterations_fall(self):
        """Paper Fig. 2: recycling must cut iterations after system 1."""
        mats, bs = _drifting_sequence()
        seq = solve_sequence_jit(
            mats, bs, k=8, ell=12, make_operator=from_matrix,
            tol=1e-8, maxiter=5000,
        )
        iters = np.asarray(seq.info.iterations)
        cg_iters = [
            int(
                defcg(
                    from_matrix(mats[i]), bs[i], tol=1e-8, maxiter=5000, ell=0
                ).info.iterations
            )
            for i in range(mats.shape[0])
        ]
        # every recycled system after the first clearly beats fresh CG
        assert all(iters[i] < 0.6 * cg_iters[i] for i in range(1, len(iters)))
        assert np.sum(iters[1:]) < 0.85 * np.sum(cg_iters[1:])
        for i in range(mats.shape[0]):
            np.testing.assert_allclose(
                np.asarray(mats[i] @ seq.x[i]), np.asarray(bs[i]),
                atol=1e-6 * float(jnp.linalg.norm(bs[i])),
            )

    def test_matvec_accounting_includes_refresh(self):
        """exact refresh ⇒ matvecs = iterations + 1 (r₀) + k (refresh) —
        except the cold bootstrap system, whose all-zero basis needs (and
        is charged) no refresh."""
        mats, bs = _drifting_sequence(num=3)
        seq = solve_sequence_jit(
            mats, bs, k=8, ell=12, make_operator=from_matrix,
            tol=1e-8, maxiter=5000,
        )
        np.testing.assert_array_equal(
            np.asarray(seq.info.matvecs),
            np.asarray(seq.info.iterations) + 1 + np.array([0, 8, 8]),
        )

    def test_stale_seeding_requires_aw(self):
        """W0 without AW0 in stale mode would deflate against AW = 0 and
        report a silently wrong 'converged' solution — must be rejected."""
        mats, bs = _drifting_sequence(num=2)
        from repro.core import recycle as recycle_mod

        W0 = jnp.asarray(np.random.default_rng(0).standard_normal((4, 96)))
        with pytest.raises(ValueError, match="stale"):
            recycle_mod.solve_sequence(
                mats, bs, W0, None, k=4, ell=8, make_operator=from_matrix,
                refresh_aw="stale",
            )

    def test_stale_mode_solves_correctly(self):
        """Stale AW (zero refresh matvecs) over an UNCHANGED operator —
        the multiple-RHS setting, where the stale products are exact:
        solutions meet tolerance, recycling cuts iterations, and
        matvecs = iterations + 2 (r₀ shortcut + one true-matvec rederive).

        (Under operator drift, stale deflation can destabilize the
        conjugacy recurrence outright — RecycleManager's breakdown
        fallback covers that host-side; see its docstring.)"""
        mats, bs = _drifting_sequence(num=4, seed=29, drift=0.0)
        seq = solve_sequence_jit(
            mats, bs, k=8, ell=12, make_operator=from_matrix,
            tol=1e-8, maxiter=5000, refresh_aw="stale",
        )
        for i in range(mats.shape[0]):
            np.testing.assert_allclose(
                np.asarray(mats[i] @ seq.x[i]), np.asarray(bs[i]),
                atol=1e-6 * float(jnp.linalg.norm(bs[i])),
            )
        np.testing.assert_array_equal(
            np.asarray(seq.info.matvecs),
            np.asarray(seq.info.iterations) + 2,
        )
        iters = np.asarray(seq.info.iterations)
        assert iters[-1] < iters[0]

    def test_traces_without_host_sync(self):
        """The whole N-system sequence must be traceable: any int()/.item()
        on traced per-system state would raise a ConcretizationTypeError
        here.  This is the acceptance criterion made executable — extended
        to the batched multi-tenant front door, which must likewise lower
        to ONE XLA computation (single jaxpr, no host round-trips)."""
        from repro.core import recycle as recycle_mod

        mats, bs = _drifting_sequence(num=3)

        def run(mats, bs):
            seq = recycle_mod.solve_sequence(
                mats, bs, k=4, ell=8, make_operator=from_matrix,
                tol=1e-6, maxiter=200,
            )
            return seq.info.iterations, seq.W

        jaxpr = jax.make_jaxpr(run)(mats, bs)
        assert jaxpr is not None

        from repro.core import SolveSpec, solve_batch

        spec = SolveSpec(k=4, ell=8, tol=1e-6, maxiter=200)

        def run_batch(mats, bs):
            out = solve_batch(mats, bs, spec, make_operator=from_matrix)
            return out.x, out.info.converged, out.state.W

        # B tenants (reusing the drifting mats as independent systems)
        jaxpr_b = jax.make_jaxpr(run_batch)(mats, bs)
        assert jaxpr_b is not None

        def run_batch_seq(mats, bs):
            out = solve_batch(
                mats[None], bs[None], spec,
                make_operator=from_matrix, sequence=True,
            )
            return out.info.iterations, out.state.W

        jaxpr_bs = jax.make_jaxpr(run_batch_seq)(mats, bs)
        assert jaxpr_bs is not None

    def test_warm_start_carry(self):
        """carry_x: re-solving the same system is near-free."""
        n = 64
        rng = np.random.default_rng(7)
        A, _, _ = make_spd(n, 1e4, rng)
        b = rng.standard_normal(n)
        mats = jnp.asarray(np.stack([A] * 3))
        bs = jnp.asarray(np.stack([b] * 3))
        seq = solve_sequence_jit(
            mats, bs, k=6, ell=12, make_operator=from_matrix,
            tol=1e-8, maxiter=2000, carry_x=True,
        )
        iters = np.asarray(seq.info.iterations)
        assert iters[1] <= 2 and iters[2] <= 2

    def test_seeding_from_previous_result(self):
        """The returned (W, AW) seeds a follow-up call (sequence resume)."""
        mats, bs = _drifting_sequence(num=4, seed=41)
        first = solve_sequence_jit(
            mats[:2], bs[:2], k=8, ell=12, make_operator=from_matrix,
            tol=1e-8, maxiter=5000,
        )
        resumed = solve_sequence_jit(
            mats[2:], bs[2:], first.W, first.AW,
            k=8, ell=12, make_operator=from_matrix, tol=1e-8, maxiter=5000,
        )
        cold = solve_sequence_jit(
            mats[2:], bs[2:], k=8, ell=12, make_operator=from_matrix,
            tol=1e-8, maxiter=5000,
        )
        # the seeded run's FIRST system already benefits from recycling
        assert int(resumed.info.iterations[0]) < int(cold.info.iterations[0])


class TestMultiRHSRefresh:
    def test_dense_operator_matmat(self):
        rng = np.random.default_rng(0)
        A, _, _ = make_spd(48, 1e3, rng)
        op = from_matrix(jnp.asarray(A))
        W = jnp.asarray(rng.standard_normal((5, 48)))
        np.testing.assert_allclose(
            np.asarray(apply_to_basis(op, W)),
            np.asarray(pt.basis_map_vectors(op, W)),
            rtol=1e-12,
        )

    def test_kernel_system_operator_multi_rhs(self):
        from repro.kernels import ref as kref

        rng = np.random.default_rng(1)
        n, d, m = 80, 4, 6
        xs = jnp.asarray(rng.standard_normal((n, d)))
        kmat = kref.rbf_gram(xs, 1.5, 1.2)
        sqrt_h = jnp.asarray(rng.uniform(0.05, 0.5, n))
        op = KernelSystemOperator(lambda v: kmat @ v, sqrt_h)
        W = jnp.asarray(rng.standard_normal((m, n)))
        np.testing.assert_allclose(
            np.asarray(apply_to_basis(op, W)),
            np.asarray(pt.basis_map_vectors(op, W)),
            rtol=1e-10,
        )

    def test_ggn_operator_linearize_once(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((16, 3)))

        def model(params):
            return jnp.tanh(x @ params["w"]) @ params["v"]

        params = {
            "w": jnp.asarray(rng.standard_normal((3, 4))) * 0.3,
            "v": jnp.asarray(rng.standard_normal((4, 2))) * 0.3,
        }
        op = GGNOperator(
            model, lambda out, t: 2.0 * t, params, damping=jnp.float64(0.1)
        )
        W = pt.basis_from_vectors(
            [pt.tree_random_like(jax.random.PRNGKey(i), params) for i in range(3)]
        )
        got = apply_to_basis(op, W)
        want = pt.basis_map_vectors(op.matvec, W)
        for g, w in zip(
            jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(want)
        ):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), rtol=1e-10, atol=1e-12
            )


class TestRitzClampRegression:
    def test_fewer_positive_than_k_is_masked(self):
        """Rank-deficient window with k > surviving positive Ritz count:
        the trailing slots must be exact zeros — not 1e300 'Ritz values'
        normalized out of near-null vectors (the +inf argsort bug)."""
        rng = np.random.default_rng(4)
        n = 64
        A, _, _ = make_spd(n, 1e3, rng)
        A = jnp.asarray(A)
        z1 = jnp.asarray(rng.standard_normal(n))
        z2 = jnp.asarray(rng.standard_normal(n))
        # duplicated columns → rank-2 basis, ask for k=4
        Z = jnp.stack([z1, z2, z1, z2])
        AZ = Z @ A
        for extract in (harmonic_ritz, harmonic_ritz_flat):
            W, AW, theta = extract(Z, AZ, 4)
            th = np.asarray(theta)
            assert np.all(np.isfinite(th))
            assert np.all(th < 1e10), th  # no 1e300 garbage
            assert np.sum(th > 0) == 2
            np.testing.assert_array_equal(th[2:], 0.0)
            Wf = pt.ravel_basis(W)
            np.testing.assert_array_equal(np.asarray(Wf)[2:], 0.0)

    def test_clamped_basis_still_deflates_safely(self):
        """def-CG with a clamped (zero-padded) basis: the zero columns are
        an exact deflation no-op under the jitter floor — the solve must
        converge to the true solution."""
        rng = np.random.default_rng(8)
        n = 64
        A, _, _ = make_spd(n, 1e3, rng)
        A = jnp.asarray(A)
        z1 = jnp.asarray(rng.standard_normal(n))
        z2 = jnp.asarray(rng.standard_normal(n))
        Z = jnp.stack([z1, z2, z1, z2])
        W, AW, _ = harmonic_ritz_flat(Z, Z @ A, 4)
        b = jnp.asarray(rng.standard_normal(n))
        # no explicit waw_jitter: zero columns must be regularized away
        # unconditionally (any jitter setting, including the 0.0 default)
        for jitter in (0.0, 1e-12):
            res = defcg(
                from_matrix(A), b, W=W, AW=AW,
                tol=1e-10, maxiter=2000, waw_jitter=jitter,
            )
            assert bool(res.info.converged)
            np.testing.assert_allclose(
                np.asarray(A @ res.x), np.asarray(b),
                atol=1e-7 * float(jnp.linalg.norm(b)),
            )
