"""Snapshot test for the checked-in leaf/field schema manifest.

The PR 4 incident: renaming a ``RecycleState`` leaf silently orphaned
every existing checkpoint, because ``restore_pytree`` matches leaves BY
NAME.  The manifest (``src/repro/analysis/schema_manifest.json``) pins
the names; this test pins the manifest.  If it fails you changed a
checkpoint/jit contract — bump ``SCHEMA_VERSION`` in
``repro/checkpoint/manager.py``, add a restore migration, and regenerate
with ``python -m repro.analysis --update-schema``.
"""

import json

import pytest

from repro.analysis import schema
from repro.core import RecycleState, SolveReport, SolveSpec


class TestManifestMatchesLiveCode:
    def test_checked_in_manifest_matches(self):
        violations = schema.check_manifest()
        assert violations == [], "\n".join(v.message for v in violations)

    def test_recycle_state_leaf_names_snapshot(self):
        live = schema.compute_manifest()
        assert [l["key"] for l in live["RecycleState"]["leaves"]] == [
            "W", "AW", "theta", "systems_solved", "drift",
        ]

    def test_solve_report_field_order_snapshot(self):
        assert SolveReport._fields == (
            "status", "rung", "guard_firings", "matvecs",
        )

    def test_solve_spec_field_names_snapshot(self):
        live = schema.compute_manifest()
        assert [f["name"] for f in live["SolveSpec"]["fields"]] == [
            "method", "k", "ell", "tol", "atol", "maxiter", "select",
            "waw_jitter", "refresh_aw", "precond", "precond_rank",
            "precond_sigma", "strategy", "recovery_rungs",
            "recovery_shift", "stagnation_window", "lsq_shift",
        ]

    def test_manifest_version_matches_checkpoint_manager(self):
        from repro.checkpoint import manager

        with open(schema.default_manifest_path()) as f:
            stored = json.load(f)
        assert stored["checkpoint_schema_version"] == manager.SCHEMA_VERSION


class TestManifestCatchesDrift:
    def test_leaf_rename_is_detected(self, tmp_path):
        # Simulate the PR 4 break: the manifest remembers leaf `W` under
        # another name → check_manifest must flag it.
        stored = schema.compute_manifest()
        stored["RecycleState"]["leaves"][0]["key"] = "basis"
        p = tmp_path / "schema_manifest.json"
        p.write_text(json.dumps(stored))
        violations = schema.check_manifest(str(p))
        assert any("RecycleState.leaves" in v.message for v in violations)
        assert any("SCHEMA_VERSION" in v.message for v in violations)

    def test_spec_default_drift_is_detected(self, tmp_path):
        stored = schema.compute_manifest()
        for f in stored["SolveSpec"]["fields"]:
            if f["name"] == "tol":
                f["default"] = "0.001"
        p = tmp_path / "schema_manifest.json"
        p.write_text(json.dumps(stored))
        violations = schema.check_manifest(str(p))
        assert any("SolveSpec.fields" in v.message for v in violations)

    def test_missing_manifest_is_flagged(self, tmp_path):
        violations = schema.check_manifest(str(tmp_path / "nope.json"))
        assert len(violations) == 1
        assert "--update-schema" in violations[0].message

    def test_roundtrip_regeneration_is_stable(self, tmp_path):
        p = tmp_path / "schema_manifest.json"
        schema.write_manifest(str(p))
        assert schema.check_manifest(str(p)) == []


def test_state_template_roundtrips_by_name():
    """End-to-end: the manifest's leaf names are the names the
    checkpoint layer actually restores by."""
    import jax

    state = RecycleState.zeros(2, 4)
    leaves, _ = jax.tree_util.tree_flatten_with_path(state)
    names = {
        getattr(path[0], "name", None) for path, _ in leaves
    }
    assert names == {"W", "AW", "theta", "systems_solved", "drift"}


def test_spec_is_hashable_static_arg():
    # The manifest documents SolveSpec as the static jit cache key; it
    # must therefore stay hashable and equality-stable.
    assert hash(SolveSpec()) == hash(SolveSpec())
    assert SolveSpec() == SolveSpec()
    with pytest.raises(Exception):
        object.__setattr__  # appease linters: attribute write below
        SolveSpec().__setattr__("k", 9)
