"""Bit-identity pins for the engine-harness refactor (ISSUE 9).

The harness extraction (``core/engine.py``) must be a *relocation* of the
loop machinery, not a rewrite: ``cg`` and ``defcg`` re-seated on the
engine have to reproduce the pre-refactor iterate trajectories BIT FOR
BIT.  This module pins them against golden data captured from the
pre-refactor solvers on a fig2-style GP Newton trace:

  * plain CG on the first Newton system — final iterate, iteration count,
    matvec count, status;
  * the def-CG sequence front door over the drifting trace — per-system
    solutions, residual norms, iteration/matvec counts, statuses, Ritz
    values, recovery rungs, and the final recycled basis;
  * a recovery-ladder case (indefinite operator, ladder armed) — the
    rung taken, terminal status, and honest matvec total.

Regenerate the golden file ONLY when a deliberate numeric change is
intended (document it in the PR):

    PYTHONPATH=src python tests/test_trajectory_pin.py

Comparisons are exact (``assert_array_equal`` on raw float bits) — any
reordering of the loop-body arithmetic shows up here.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "trajectories_fig2.npz")

_N = 96  # GP trace size — small enough for CI, big enough to iterate
_K, _ELL = 4, 8
_NUM_SYSTEMS = 4


def _fig2_newton_trace():
    """A miniature fig2 GP-classification Newton trace.

    ``A_t = I + H_t^{1/2} K H_t^{1/2}`` over a fixed RBF Gram matrix with
    the Newton-drifting diagonal ``H_t`` of a logistic likelihood — the
    paper's sequence of related SPD systems, deterministic by seed.
    """
    from repro.gp import RBFKernel

    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal((_N, 4)))
    kmat = RBFKernel(theta=2.0, lengthscale=1.5).gram(x)

    ops, bs = [], []
    f = jnp.asarray(rng.standard_normal(_N) * 0.3)
    y = jnp.asarray(np.sign(rng.standard_normal(_N)))
    for t in range(_NUM_SYSTEMS):
        pi = jax.nn.sigmoid(f)
        sqrt_h = jnp.sqrt(pi * (1.0 - pi))
        ops.append(sqrt_h)
        bs.append(sqrt_h * (y - pi) + 0.1 * f)
        f = f + 0.35 * jnp.asarray(rng.standard_normal(_N))
    return kmat, jnp.stack(ops), jnp.stack(bs)


def _indefinite_problem():
    rng = np.random.default_rng(3)
    q, _ = np.linalg.qr(rng.standard_normal((48, 48)))
    eigs = np.concatenate([np.linspace(0.5, 4.0, 44), [-1.0, -0.2, 2.0, 9.0]])
    mat = jnp.asarray((q * eigs) @ q.T)
    b = jnp.asarray(rng.standard_normal(48))
    return mat, b


def _run_all():
    """Execute the pinned scenarios; returns a dict of numpy arrays."""
    from repro.core import (
        KernelSystemOperator,
        SolveSpec,
        cg,
        from_matrix,
        solve,
        solve_sequence,
    )

    kmat, sqrt_hs, bs = _fig2_newton_trace()
    out = {}

    # -- plain CG on the first Newton system -----------------------------
    op0 = KernelSystemOperator(lambda v: kmat @ v, sqrt_hs[0])
    res = cg(op0, bs[0], tol=1e-10, maxiter=600)
    out["cg_x"] = np.asarray(res.x)
    out["cg_iterations"] = np.asarray(res.info.iterations)
    out["cg_matvecs"] = np.asarray(res.info.matvecs)
    out["cg_status"] = np.asarray(res.info.status)
    out["cg_residual_norm"] = np.asarray(res.info.residual_norm)

    # -- def-CG sequence over the drifting Newton trace ------------------
    spec = SolveSpec(method="defcg", k=_K, ell=_ELL, tol=1e-9, maxiter=600)
    seq = solve_sequence(
        sqrt_hs,
        bs,
        spec,
        make_operator=lambda sh: KernelSystemOperator(
            lambda v: kmat @ v, sh
        ),
    )
    out["seq_x"] = np.asarray(seq.x)
    out["seq_iterations"] = np.asarray(seq.info.iterations)
    out["seq_matvecs"] = np.asarray(seq.info.matvecs)
    out["seq_status"] = np.asarray(seq.info.status)
    out["seq_residual_norm"] = np.asarray(seq.info.residual_norm)
    out["seq_theta"] = np.asarray(seq.theta)
    out["seq_rung"] = np.asarray(seq.report.rung)
    out["seq_final_W"] = np.asarray(seq.state.W)
    out["seq_final_AW"] = np.asarray(seq.state.AW)

    # -- recovery-ladder behavior on an indefinite operator --------------
    mat, b = _indefinite_problem()
    bad_spec = SolveSpec(method="defcg", k=3, ell=6, tol=1e-8, maxiter=300,
                         recovery_rungs=3, recovery_shift=1e-6)
    # A warm basis forces the deflated path; the indefinite spectrum
    # breaks it, so the ladder must climb — pin the rung it lands on.
    warm = solve(from_matrix(jnp.asarray(np.eye(48) * 2.0)), b, bad_spec)
    res_bad = solve(from_matrix(mat), b, bad_spec, warm.state)
    out["ladder_status"] = np.asarray(res_bad.info.status)
    out["ladder_rung"] = np.asarray(res_bad.report.rung)
    out["ladder_matvecs"] = np.asarray(res_bad.info.matvecs)
    out["ladder_x"] = np.asarray(res_bad.x)
    return out


@pytest.fixture(scope="module")
def golden():
    if not os.path.exists(GOLDEN):
        pytest.skip("golden trajectory file missing — regenerate with "
                    "`python tests/test_trajectory_pin.py`")
    with np.load(GOLDEN) as z:
        return dict(z)


@pytest.fixture(scope="module")
def current():
    return _run_all()


def test_cg_trajectory_bit_identical(golden, current):
    for key in ("cg_x", "cg_iterations", "cg_matvecs", "cg_status",
                "cg_residual_norm"):
        np.testing.assert_array_equal(current[key], golden[key], err_msg=key)


def test_defcg_sequence_bit_identical(golden, current):
    for key in ("seq_x", "seq_iterations", "seq_matvecs", "seq_status",
                "seq_residual_norm", "seq_theta", "seq_rung",
                "seq_final_W", "seq_final_AW"):
        np.testing.assert_array_equal(current[key], golden[key], err_msg=key)


def test_recovery_ladder_bit_identical(golden, current):
    for key in ("ladder_status", "ladder_rung", "ladder_matvecs",
                "ladder_x"):
        np.testing.assert_array_equal(current[key], golden[key], err_msg=key)


if __name__ == "__main__":
    os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
    np.savez_compressed(GOLDEN, **_run_all())
    print(f"wrote {GOLDEN}")
