"""repro.serve (ISSUE 8 tentpole): the multi-tenant solve service.

Five layers of checks, all in the non-slow tier (small dense/GP-shaped
problems, n ≤ 96):

  1. ``solve_pool_step`` masking semantics: inactive slots' RecycleState
     passes through BIT-untouched, their diagnostics are scrubbed to
     zero/CONVERGED, and active slots match a plain ``solve_batch``;
  2. pool lifecycle: admit → serve → evict → re-admit restores the same
     ``RecycleState`` bit-for-bit (through the CheckpointManager spill
     store), and the re-admitted tenant solves warm (fewer iterations
     than its own cold start);
  3. parity: a pool serving T tenants matches T sequential
     ``solve_sequence`` runs — per-system iterations AND matvec
     accounting — because every layer shares ``_one_recycled_solve``;
  4. fault isolation: a poisoned tenant (PR 6's ``FaultInjectingOperator``)
     is retired into its own slot's report; its neighbours converge and
     its own next (healthy) request recovers from a zeroed basis;
  5. the end-to-end acceptance scenario: tenants arrive/depart
     asynchronously over drifting GP Newton sequences with eviction
     pressure, per-tenant reports + pool metrics come back, and the
     evicted-then-readmitted tenant beats a cold tenant.

Plus the ISSUE 8 satellites: CheckpointManager ``keep_last`` retention
GC with ``last_deleted`` observability, and the B=1 single-dispatch
fence (metrics prove the pool bypassed the vmapped path).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import (
    DenseMatrixOperator,
    FaultInjectingOperator,
    RecycleState,
    SolveSpec,
    SolveStatus,
    solve_batch,
    solve_jit,
    solve_pool_step,
    solve_sequence,
)
from repro.serve import (
    PoolFullError,
    Session,
    SolveService,
    StatePool,
    TenantStateStore,
)

SPEC = SolveSpec(k=6, ell=10, tol=1e-8, maxiter=2000)


def _spd_family(n=64, k=6, seed=0):
    """A base SPD matrix with a deflatable tail (test_api's recipe)."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    eigs = np.concatenate(
        [np.linspace(1.0, 5.0, n - k), np.logspace(3.0, 4.0, k)]
    )
    return (q * eigs) @ q.T


def _newton_trace(base, seed, num=3, drift=0.01):
    """A drifting sequence of (operator, rhs) pairs for one tenant."""
    n = base.shape[0]
    rng = np.random.default_rng(seed)
    mats, bs = [], []
    for _ in range(num):
        pert = rng.standard_normal((n, n)) * drift
        mats.append(jnp.asarray(base + pert @ pert.T))
        bs.append(jnp.asarray(rng.standard_normal(n)))
    return mats, bs


def _leaves_equal(a, b):
    return all(
        bool(jnp.array_equal(x, y))
        for x, y in zip(
            jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
        )
    )


BASE = _spd_family()


# ---------------------------------------------------------------------------
# 1. solve_pool_step masking semantics
# ---------------------------------------------------------------------------


class TestSolvePoolStep:
    def _warm_batched_state(self, mats, bs):
        """A (B, k, n) state with genuinely nonzero bases in every slot."""
        res = solve_batch(
            jnp.stack(mats), jnp.stack(bs), SPEC, make_operator=DenseMatrixOperator
        )
        return res.state

    def test_inactive_state_bit_untouched(self):
        mats, bs = _newton_trace(BASE, seed=1, num=3)
        state = self._warm_batched_state(mats, bs)
        active = jnp.asarray([True, False, True])
        res = solve_pool_step(
            DenseMatrixOperator(jnp.stack(mats)),
            jnp.stack(bs),
            SPEC,
            state,
            active,
        )
        before = jax.tree_util.tree_map(lambda l: l[1], state)
        after = jax.tree_util.tree_map(lambda l: l[1], res.state)
        assert _leaves_equal(before, after)
        # ... including the counter: the idle slot did NOT solve a system.
        assert int(res.state.systems_solved[1]) == int(
            state.systems_solved[1]
        )
        assert int(res.state.systems_solved[0]) == int(
            state.systems_solved[0]
        ) + 1

    def test_inactive_diagnostics_scrubbed(self):
        mats, bs = _newton_trace(BASE, seed=2, num=3)
        state = self._warm_batched_state(mats, bs)
        active = jnp.asarray([True, False, True])
        res = solve_pool_step(
            DenseMatrixOperator(jnp.stack(mats)),
            jnp.stack(bs),
            SPEC,
            state,
            active,
        )
        assert int(res.info.iterations[1]) == 0
        assert int(res.info.matvecs[1]) == 0
        assert int(res.report.matvecs[1]) == 0
        assert int(res.report.rung[1]) == 0
        assert int(res.report.status[1]) == SolveStatus.CONVERGED
        assert bool(res.info.converged[1])
        assert float(jnp.abs(res.x[1]).max()) == 0.0

    def test_active_slots_match_solve_batch(self):
        """With all slots active the step IS solve_batch (plus a no-op
        merge): solutions, counts, and outgoing states must agree."""
        mats, bs = _newton_trace(BASE, seed=3, num=3)
        state = self._warm_batched_state(mats, bs)
        plain = solve_batch(
            DenseMatrixOperator(jnp.stack(mats)), jnp.stack(bs), SPEC, state
        )
        masked = solve_pool_step(
            DenseMatrixOperator(jnp.stack(mats)),
            jnp.stack(bs),
            SPEC,
            state,
            jnp.asarray([True, True, True]),
        )
        np.testing.assert_array_equal(
            np.asarray(plain.info.iterations), np.asarray(masked.info.iterations)
        )
        np.testing.assert_array_equal(
            np.asarray(plain.info.matvecs), np.asarray(masked.info.matvecs)
        )
        assert _leaves_equal(plain.state, masked.state)
        np.testing.assert_array_equal(np.asarray(plain.x), np.asarray(masked.x))

    def test_rejects_plain_cg(self):
        mats, bs = _newton_trace(BASE, seed=4, num=2)
        with pytest.raises(ValueError, match="defcg"):
            solve_pool_step(
                DenseMatrixOperator(jnp.stack(mats[:1])),
                jnp.stack(bs[:1]),
                SolveSpec(method="cg"),
                None,
                jnp.asarray([True]),
            )


# ---------------------------------------------------------------------------
# 2. StatePool + TenantStateStore lifecycle
# ---------------------------------------------------------------------------


class TestStatePool:
    def test_admit_release_zeroes_slot(self):
        pool = StatePool(2, SPEC, n=16, dtype=jnp.float64)
        warm = RecycleState(
            W=jnp.ones((SPEC.k, 16)),
            AW=2.0 * jnp.ones((SPEC.k, 16)),
            theta=jnp.ones((SPEC.k,)),
            systems_solved=jnp.int32(5),
            drift=jnp.float64(0.25),
        )
        slot = pool.admit("a", warm, tick=3)
        assert pool.slot_of("a") == slot
        assert _leaves_equal(pool.slot_state(slot), warm)
        back = pool.release("a")
        assert _leaves_equal(back, warm)
        # The freed slot is genuinely cold again.
        assert float(jnp.abs(pool.slot_state(slot).W).max()) == 0.0
        assert not pool.resident("a")

    def test_pool_full_and_lru(self):
        pool = StatePool(2, SPEC, n=8, dtype=jnp.float64)
        pool.admit("a", tick=1)
        pool.admit("b", tick=2)
        with pytest.raises(PoolFullError):
            pool.admit("c", n=8)
        assert pool.lru_tenant() == "a"
        pool.touch([pool.slot_of("a")], tick=9)
        assert pool.lru_tenant() == "b"
        assert pool.lru_tenant(exclude={"b"}) == "a"
        assert pool.lru_tenant(exclude={"a", "b"}) is None

    def test_fixed_n_enforced(self):
        pool = StatePool(2, SPEC, n=8, dtype=jnp.float64)
        with pytest.raises(ValueError, match="allocated for n=8"):
            pool.admit("a", n=16)

    def test_slot_table(self):
        pool = StatePool(2, SPEC, n=8, dtype=jnp.float64)
        pool.admit("a", tick=4)
        table = pool.slot_table()
        assert table[0]["tenant"] == "a" and table[0]["active"]
        assert table[0]["last_served_tick"] == 4
        assert table[1]["tenant"] is None and not table[1]["active"]

    def test_store_roundtrip_bit_for_bit(self, tmp_path):
        store = TenantStateStore(str(tmp_path), keep_last=2)
        state = RecycleState(
            W=jnp.asarray(np.random.default_rng(0).standard_normal((6, 16))),
            AW=jnp.asarray(np.random.default_rng(1).standard_normal((6, 16))),
            theta=jnp.asarray(np.random.default_rng(2).standard_normal(6)),
            systems_solved=jnp.int32(7),
            drift=jnp.float64(1e-9),
        )
        assert not store.has("t")
        store.spill("t", state)
        assert store.has("t")
        back = store.restore(
            "t", jax.tree_util.tree_map(jnp.zeros_like, state)
        )
        assert _leaves_equal(state, back)

    def test_store_memory_mode(self):
        store = TenantStateStore(None)
        state = RecycleState.zeros(4, 8)
        assert store.restore("t", state) is None
        store.spill("t", state)
        assert store.has("t") and _leaves_equal(store.restore("t", state), state)

    def test_store_retention_gc_observable(self, tmp_path):
        store = TenantStateStore(str(tmp_path), keep_last=2)
        state = RecycleState.zeros(4, 8)
        for _ in range(5):
            store.spill("t", state)
        mgr = store._manager("t")
        assert mgr.steps() == [4, 5]
        assert mgr.deleted_total == 3
        assert mgr.last_deleted == [3]
        assert store.gc_deleted_total == 3


class TestCheckpointRetention:
    """Satellite: keep_last GC + last_skipped-style delete observability."""

    def test_keep_last_wins_over_keep(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=10, keep_last=2)
        tree = {"x": jnp.arange(3.0)}
        for step in range(1, 6):
            mgr.save(tree, step=step)
        assert mgr.steps() == [4, 5]
        assert mgr.deleted_total == 3

    def test_unbounded_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=None)
        tree = {"x": jnp.arange(3.0)}
        for step in range(1, 6):
            mgr.save(tree, step=step)
        assert mgr.steps() == [1, 2, 3, 4, 5]
        assert mgr.deleted_total == 0 and mgr.last_deleted == []

    def test_invalid_keep_rejected(self, tmp_path):
        with pytest.raises(ValueError, match="keep_last"):
            CheckpointManager(str(tmp_path), keep_last=0)


# ---------------------------------------------------------------------------
# 3. Service lifecycle + parity
# ---------------------------------------------------------------------------


class TestServiceLifecycle:
    def test_evict_readmit_restores_state_bit_for_bit(self, tmp_path):
        svc = SolveService(SPEC, slots=2, checkpoint_dir=str(tmp_path))
        traces = {t: _newton_trace(BASE, seed=i + 10, num=2)
                  for i, t in enumerate(("a", "b", "c"))}

        def serve_one(t, j):
            mats, bs = traces[t]
            return svc.session(t).solve(DenseMatrixOperator(mats[j]), bs[j])

        serve_one("a", 0)
        serve_one("b", 0)
        state_a = svc.pool.slot_state(svc.pool.slot_of("a"))
        serve_one("c", 0)  # pool full -> evicts LRU idle (a)
        assert not svc.pool.resident("a")
        assert svc.store.has("a")
        restored = svc.store.restore("a", svc.pool.zero_slot_state())
        assert _leaves_equal(state_a, restored)

        r_warm = serve_one("a", 1)  # re-admission from the spilled state
        snap = svc.metrics_snapshot()
        assert snap["tenants"]["a"]["evictions"] == 1
        assert snap["tenants"]["a"]["restores"] == 1
        assert snap["pool"]["evictions"] == 2  # a's and the one a forced
        # The restored basis is warm: far fewer iterations than a's cold
        # first system over the same drifting family.
        r_cold_iters = snap["tenants"]["c"]["iterations"]
        assert r_warm.iterations < 0.6 * r_cold_iters

    def test_pool_parity_with_sequential_solve_sequence(self):
        """T pooled tenants == T sequential solve_sequence runs: same
        per-system iterations and matvec accounting, same solutions."""
        T, num = 3, 3
        svc = SolveService(SPEC, slots=T)
        traces = {f"t{i}": _newton_trace(BASE, seed=20 + i, num=num)
                  for i in range(T)}
        tickets = {t: [] for t in traces}
        sessions = {t: svc.session(t) for t in traces}
        for j in range(num):
            for t in traces:
                mats, bs = traces[t]
                tickets[t].append(
                    sessions[t].submit(DenseMatrixOperator(mats[j]), bs[j])
                )
        served = svc.run_until_idle()
        assert served == T * num
        # Every tick batched all T tenants (continuous batching, no
        # single-dispatch fallback in this saturated scenario).
        assert svc.metrics.batched_steps == num
        assert svc.metrics.single_steps == 0

        for t in traces:
            mats, bs = traces[t]
            seq = solve_sequence(
                jnp.stack(mats), jnp.stack(bs), SPEC,
                make_operator=DenseMatrixOperator,
            )
            for j, tk in enumerate(tickets[t]):
                r = svc.result(tk, drive=False)
                assert r.iterations == int(seq.info.iterations[j]), (t, j)
                assert r.matvecs == int(seq.info.matvecs[j]), (t, j)
                assert r.converged and r.status == SolveStatus.CONVERGED
                np.testing.assert_allclose(
                    np.asarray(r.x), np.asarray(seq.x[j]),
                    rtol=1e-9, atol=1e-9,
                )

    def test_single_tenant_uses_plain_solve_dispatch(self):
        """B=1 fence: one active slot bypasses the vmapped step and must
        bit-match the plain solve front door."""
        svc = SolveService(SPEC, slots=4)
        mats, bs = _newton_trace(BASE, seed=30, num=2)
        s = svc.session("only")
        r0 = s.solve(DenseMatrixOperator(mats[0]), bs[0])
        r1 = s.solve(DenseMatrixOperator(mats[1]), bs[1])
        assert svc.metrics.single_steps == 2
        assert svc.metrics.batched_steps == 0
        state = None
        for j, r in enumerate((r0, r1)):
            ref = solve_jit(DenseMatrixOperator(mats[j]), bs[j], SPEC, state)
            state = ref.state
            assert r.iterations == int(ref.info.iterations)
            assert r.matvecs == int(ref.info.matvecs)
            np.testing.assert_array_equal(np.asarray(r.x), np.asarray(ref.x))

    def test_busy_residents_never_evicted(self):
        """With every slot holding pending work, a newcomer waits (and
        its queue_wait_ticks accrue) instead of evicting a busy tenant."""
        svc = SolveService(SPEC, slots=2)
        traces = {t: _newton_trace(BASE, seed=40 + i, num=2)
                  for i, t in enumerate(("a", "b", "c"))}
        tickets = []
        for t, (mats, bs) in traces.items():
            s = svc.session(t)
            for m, b in zip(mats, bs):
                tickets.append(s.submit(DenseMatrixOperator(m), b))
        svc.run_until_idle()
        results = [svc.result(tk, drive=False) for tk in tickets]
        assert all(r.converged for r in results)
        snap = svc.metrics_snapshot()
        # c could only be admitted after a or b drained (2 ticks each).
        assert snap["tenants"]["c"]["queue_wait_ticks"] > 0
        assert snap["pool"]["queue_depth_peak"] == 6

    def test_close_with_pending_refuses(self):
        svc = SolveService(SPEC, slots=2)
        mats, bs = _newton_trace(BASE, seed=50, num=1)
        s = svc.session("a")
        s.submit(DenseMatrixOperator(mats[0]), bs[0])
        with pytest.raises(RuntimeError, match="unserved"):
            s.close()
        s.result()
        s.close()
        with pytest.raises(RuntimeError, match="closed"):
            s.submit(DenseMatrixOperator(mats[0]), bs[0])

    def test_mixed_operator_family_rejected(self):
        svc = SolveService(SPEC, slots=2)
        mats, bs = _newton_trace(BASE, seed=60, num=2)
        sa, sb = svc.session("a"), svc.session("b")
        sa.submit(DenseMatrixOperator(mats[0]), bs[0])
        sb.submit(
            FaultInjectingOperator(DenseMatrixOperator(mats[1]), 0.0), bs[1]
        )
        with pytest.raises(ValueError, match="operator family"):
            svc.tick()

    def test_service_requires_defcg(self):
        with pytest.raises(ValueError, match="defcg"):
            SolveService(SolveSpec(method="cg"))


# ---------------------------------------------------------------------------
# 4. Fault isolation under the pool (PR 6 injectors reused)
# ---------------------------------------------------------------------------


class TestPoisonedTenantIsolation:
    def test_neighbours_unharmed_and_tenant_recovers(self):
        svc = SolveService(SPEC, slots=3)
        traces = {t: _newton_trace(BASE, seed=70 + i, num=2)
                  for i, t in enumerate(("good1", "bad", "good2"))}
        sessions = {t: svc.session(t) for t in traces}
        tickets = {}
        for t in traces:
            mats, bs = traces[t]
            poison = jnp.nan if t == "bad" else 0.0
            tickets[t] = sessions[t].submit(
                FaultInjectingOperator(DenseMatrixOperator(mats[0]), poison),
                bs[0],
            )
        svc.run_until_idle()
        r_bad = svc.result(tickets["bad"], drive=False)
        assert r_bad.status >= SolveStatus.BREAKDOWN_NONFINITE
        assert not r_bad.converged
        assert np.isfinite(np.asarray(r_bad.x)).all()  # retired, not NaN
        for t in ("good1", "good2"):
            r = svc.result(tickets[t], drive=False)
            assert r.converged and r.status == SolveStatus.CONVERGED
            mats, bs = traces[t]
            np.testing.assert_allclose(
                np.asarray(mats[0] @ r.x), np.asarray(bs[0]),
                atol=1e-6 * float(jnp.linalg.norm(bs[0])),
            )
        # The poisoned slot's outgoing basis was zeroed by retirement, so
        # the tenant's next HEALTHY request bootstraps cold and converges.
        mats, bs = traces["bad"]
        r_next = sessions["bad"].solve(
            FaultInjectingOperator(DenseMatrixOperator(mats[1]), 0.0), bs[1]
        )
        assert r_next.converged
        snap = svc.metrics_snapshot()
        assert snap["tenants"]["bad"]["breakdowns"] == 1
        assert snap["tenants"]["good1"]["breakdowns"] == 0


# ---------------------------------------------------------------------------
# 5. End-to-end acceptance scenario (GP Newton shape, eviction pressure)
# ---------------------------------------------------------------------------


class TestEndToEndScenario:
    def test_async_arrivals_departures_eviction_and_warm_resume(self, tmp_path):
        """ISSUE 8 acceptance: tenants arrive/depart asynchronously over
        drifting GP Newton sequences (A = I + H½KH½), pool smaller than
        the tenant population, evicted-then-readmitted tenants resume
        warm, and reports + metrics come back for everyone."""
        n, T, slots = 80, 5, 2
        rng = np.random.default_rng(99)
        q, _ = np.linalg.qr(rng.standard_normal((n, n)))
        kmat = jnp.asarray((q * np.logspace(1.5, -2, n)) @ q.T)  # PSD "gram"
        k_mv = lambda v: kmat @ v  # noqa: E731 — one stable kernel closure

        from repro.core import KernelSystemOperator

        def tenant_systems(i, num):
            r = np.random.default_rng(200 + i)
            f = r.standard_normal(n) * 0.5
            out = []
            for _ in range(num):
                pi = 1.0 / (1.0 + np.exp(-f))
                out.append((
                    KernelSystemOperator(
                        k_mv, jnp.asarray(np.sqrt(pi * (1 - pi)))
                    ),
                    jnp.asarray(r.standard_normal(n)),
                ))
                f = f + 0.05 * r.standard_normal(n)
            return out

        spec = SolveSpec(k=6, ell=10, tol=1e-7, maxiter=1000)
        svc = SolveService(spec, slots=slots, checkpoint_dir=str(tmp_path))

        # Phase 1: tenants 0/1 each serve two systems, then DEPART
        # (sessions close, warm bases spill).
        first_iters = {}
        for i in (0, 1):
            with svc.session(f"u{i}") as s:
                sys_i = tenant_systems(i, 2)
                r0 = s.solve(*sys_i[0])
                r1 = s.solve(*sys_i[1])
                first_iters[i] = (r0.iterations, r1.iterations)
                assert r0.converged and r1.converged
                assert r1.iterations < r0.iterations  # recycling works
        assert svc.pool.occupancy == 0

        # Phase 2: three NEW tenants churn through the 2-slot pool
        # (eviction pressure among themselves), interleaved arrivals.
        sessions = {i: svc.session(f"u{i}") for i in (2, 3, 4)}
        tickets = {i: [] for i in (2, 3, 4)}
        systems = {i: tenant_systems(i, 2) for i in (2, 3, 4)}
        for j in range(2):
            for i in (2, 3, 4):
                tickets[i].append(sessions[i].submit(*systems[i][j]))
            svc.tick()
        svc.run_until_idle()
        for i in (2, 3, 4):
            for tk in tickets[i]:
                assert svc.result(tk, drive=False).converged

        # Phase 3: tenant 0 RETURNS (was evicted to disk at close).  Its
        # restored basis must beat the cold starts of phase-2 tenants.
        with svc.session("u0") as s0:
            r_back = s0.solve(*tenant_systems(0, 3)[2])
        assert r_back.converged
        snap = svc.metrics_snapshot()
        assert snap["tenants"]["u0"]["restores"] == 1
        cold_iters = [
            svc.metrics.tenants[f"u{i}"].iterations for i in (2, 3, 4)
        ]
        # Cold tenants' FIRST systems dominate their totals; the warm
        # return must undercut every cold first-solve.
        assert r_back.iterations < first_iters[0][0]
        assert all(r_back.iterations < c for c in cold_iters)

        # Telemetry contract: one plain-dict snapshot, json-serializable.
        import json

        payload = json.dumps(snap)
        assert "u0" in payload and snap["pool"]["slots"] == slots
        assert snap["pool"]["served_total"] == 11
        assert snap["pool"]["evictions"] >= 2
        assert 0.0 < snap["pool"]["mean_occupancy"] <= 1.0


# ---------------------------------------------------------------------------
# Public surface
# ---------------------------------------------------------------------------


def test_serve_all_resolves():
    import repro.serve as serve

    for name in serve.__all__:
        assert getattr(serve, name) is not None, name
    assert serve.Session is Session


def test_served_result_is_frozen():
    fields = {f.name for f in dataclasses.fields(
        __import__("repro.serve.scheduler", fromlist=["ServedResult"]).ServedResult
    )}
    assert {"x", "iterations", "matvecs", "report", "tick",
            "queue_wait_ticks"} <= fields
