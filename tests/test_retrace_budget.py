"""Compile-budget regression tests: spec-identical repeat calls must hit
the jit cache.

These pin the two retrace bugs the trace audit caught when it first ran
over the repo (and the PR 6 chunked-sequence claim):

* ``from_matrix`` used to wrap the matrix in a closure stored as pytree
  AUX data — part of the static jit cache key — so ``solve_jit``
  retraced for every new system.  ``DenseMatrixOperator`` carries the
  matrix as a traced leaf; the budget here is ≤1 trace across systems.
* The chunked (crash-resumable) ``solve_sequence`` ran its engine scan
  eagerly per chunk; jax's eager-scan cache keys on the body function
  OBJECT, and the body was rebuilt per call, so every chunk (and every
  resumed run) recompiled.  Through the module-level
  ``_solve_sequence_spec_jit`` the budget is ≤2 programs per run shape
  (full chunk + trailing partial) and 0 recompilations on an identical
  re-run.

Budgets are measured on FRESH ``jax.jit`` wrappers via ``_cache_size()``
(so other tests' caches can't mask a regression) and, for the chunked
host loop, by capturing ``jax.log_compiles()`` events.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import trace_audit
from repro.checkpoint import CheckpointManager
from repro.core import RecycleState, SolveSpec, from_matrix
from repro.core import api as api_mod

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _problem(num=5, n=24, seed=0):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (n, n)) / jnp.sqrt(n)
    base = q @ q.T + jnp.eye(n)
    shifts = 0.05 * jnp.arange(num, dtype=base.dtype)
    mats = base[None] + shifts[:, None, None] * jnp.eye(n)[None]
    bs = jax.random.normal(jax.random.fold_in(key, 1), (num, n))
    return mats, bs


SPEC = SolveSpec(k=3, ell=4, tol=1e-6, maxiter=40)


class TestSingleSolveBudget:
    def test_solve_retraces_at_most_once_across_systems(self):
        mats, bs = _problem()
        state = RecycleState.zeros(SPEC.k, bs.shape[-1], bs.dtype)
        f = trace_audit.fresh_jit(
            api_mod.solve,
            static_argnames=("spec", "record_residuals", "batch_axis"),
        )
        for i in range(3):
            res = f(from_matrix(mats[i]), bs[i], SPEC, state)
            state = res.state
        assert f._cache_size() == 1

    def test_dense_operator_matrix_is_a_leaf(self):
        # The root cause of the old per-system retrace: the matrix must
        # be traced pytree data, not static aux.
        op = from_matrix(jnp.eye(4))
        leaves = jax.tree_util.tree_leaves(op)
        assert len(leaves) == 1 and leaves[0].shape == (4, 4)

    def test_distinct_specs_do_retrace(self):
        # Sanity for the measurement itself: the cache key DOES see spec.
        mats, bs = _problem()
        state = RecycleState.zeros(3, bs.shape[-1], bs.dtype)
        f = trace_audit.fresh_jit(
            api_mod.solve,
            static_argnames=("spec", "record_residuals", "batch_axis"),
        )
        f(from_matrix(mats[0]), bs[0], SPEC, state)
        f(from_matrix(mats[0]), bs[0],
          SolveSpec(k=3, ell=4, tol=1e-6, maxiter=41), state)
        assert f._cache_size() == 2


class TestSequenceAndBatchBudget:
    def test_solve_sequence_retraces_at_most_once(self):
        mats, bs = _problem()
        state = RecycleState.zeros(SPEC.k, bs.shape[-1], bs.dtype)
        f = jax.jit(
            lambda ms, vs, st: api_mod.solve_sequence(
                ms, vs, SPEC, st, make_operator=from_matrix
            )
        )
        r1 = f(mats, bs, state)
        f(mats + 0.01, bs + 1.0, r1.state)
        assert f._cache_size() == 1

    def test_solve_batch_retraces_at_most_once(self):
        mats, bs = _problem()
        state = RecycleState.zeros(SPEC.k, bs.shape[-1], bs.dtype)
        bstate = jax.tree_util.tree_map(lambda l: jnp.stack([l, l]), state)
        f = trace_audit.fresh_jit(
            api_mod.solve_batch,
            static_argnames=(
                "spec", "make_operator", "make_preconditioner",
                "sequence", "carry_x",
            ),
        )
        f(mats[:2], bs[:2], SPEC, bstate, make_operator=from_matrix)
        f(mats[1:3], bs[1:3], SPEC, bstate, make_operator=from_matrix)
        assert f._cache_size() == 1


class TestChunkedSequenceBudget:
    def _run(self, directory, mats, bs):
        return api_mod.solve_sequence(
            mats, bs, SPEC, None,
            make_operator=from_matrix,
            checkpoint=CheckpointManager(directory),
            checkpoint_every=2,
        )

    def test_chunked_compiles_at_most_two_programs(self, tmp_path):
        # N=5, chunk=2 → chunks of 2, 2, 1: the full-chunk program plus
        # one trailing partial — never one program per chunk.
        mats, bs = _problem(num=5, n=20, seed=3)
        with trace_audit.count_compiles() as cap:
            self._run(str(tmp_path / "a"), mats, bs)
        chunk_programs = [
            n for n in cap.names if n == "scan" or "solve_sequence" in n
        ]
        assert len(chunk_programs) <= 2, cap.names

        # A spec/shape-identical re-run recompiles NOTHING (the PR 6
        # resume story: a crash-resumed run must not pay compiles again).
        with trace_audit.count_compiles() as cap2:
            self._run(str(tmp_path / "b"), mats, bs)
        assert cap2.names == [], cap2.names


class TestAuditEntryPoints:
    """The executable audits themselves stay green (what CI's lint tier
    runs); failures here reproduce with
    `python -m repro.analysis --trace-audit`."""

    def test_retrace_budget_audit_clean(self):
        assert trace_audit.audit_retrace_budgets() == []

    def test_forbidden_primitive_audit_clean(self):
        assert trace_audit.audit_forbidden_primitives() == []

    def test_chunked_audit_clean(self):
        assert trace_audit.audit_chunked_sequence() == []
