"""Sharded Krylov engine: parity, collective counts, scaling.

The conftest forces ``xla_force_host_platform_device_count=8``, so the
"solve" mesh here is 8 real (host) devices — shard_map runs genuinely
SPMD and the compiled HLO carries the real collectives.  Three gates:

1. PARITY — sharded cg/defcg/lsmr match the unsharded engine's iterates
   (x to 1e-10, identical iteration/matvec counts, matching RecycleState
   up to per-row sign) at mesh sizes 1, 4 and 8, and the recycled
   warm-start win survives sharding.
2. COMMUNICATION — the def-CG/CG while body contains EXACTLY ONE
   all-reduce per iteration (LSMR its inherent two), asserted from
   compiled HLO via repro.launch.hlo_stats.while_body_collectives.
3. SCALE — the sharded RBF operator solves an n = 1e5 GP system without
   materializing the n×n Gram matrix (slow tier).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sharded
from repro.core.api import SolveSpec, solve, solve_jit
from repro.core.operators import DenseMatrixOperator, RBFKernelSystemOperator
from repro.core.recycle import RecycleState
from repro.launch import hlo_stats
from repro.launch.mesh import (
    make_solve_mesh,
    solve_state_shardings,
    solve_vector_sharding,
)

from conftest import make_spd


def _system(n=64, cond=50.0, seed=0):
    rng = np.random.default_rng(seed)
    a_np, _, _ = make_spd(n, cond=cond, rng=rng)
    A = DenseMatrixOperator(mat=jnp.asarray(a_np))
    b = jnp.asarray(rng.standard_normal(n))
    return A, b, rng


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------


class TestSolveMesh:
    def test_eight_forced_host_devices(self):
        assert jax.device_count() == 8

    def test_default_takes_all_devices(self):
        mesh = make_solve_mesh()
        assert mesh.axis_names == ("solve",)
        assert mesh.shape["solve"] == 8

    def test_explicit_count(self):
        for n in (1, 4, 8):
            assert make_solve_mesh(n).shape["solve"] == n

    def test_out_of_range_count_raises(self):
        with pytest.raises(ValueError, match="out of range"):
            make_solve_mesh(9)
        with pytest.raises(ValueError, match="out of range"):
            make_solve_mesh(0)

    def test_state_shardings_match_spec_rules(self):
        mesh = make_solve_mesh(8)
        sh = solve_state_shardings(mesh)
        assert sh.W.spec == sharded.basis_spec()
        assert sh.AW.spec == sharded.basis_spec()
        assert sh.theta.spec == jax.sharding.PartitionSpec()
        assert solve_vector_sharding(mesh).spec == sharded.vector_spec()

    def test_shard_recycle_state_places_leaves(self):
        mesh = make_solve_mesh(8)
        st = sharded.shard_recycle_state(
            RecycleState.zeros(4, 64, jnp.float64), mesh
        )
        assert st.W.sharding.spec == sharded.basis_spec()
        assert st.theta.sharding.spec == jax.sharding.PartitionSpec()


# ---------------------------------------------------------------------------
# parity with the unsharded engine
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_devices", [1, 4, 8])
@pytest.mark.parametrize("method", ["cg", "defcg", "lsmr"])
def test_sharded_matches_unsharded(method, n_devices):
    """x matches to 1e-10 at every mesh size.  CG/def-CG iteration and
    matvec counts may differ by AT MOST one: the sharded stopping test
    rides the one-step ``‖r₊‖²`` recurrence (the price of one all-reduce
    per iteration), which can cross the threshold one step before/after
    the unsharded fresh reduction when the crossing is within rounding.
    LSMR's coupled Golub–Kahan recurrences accumulate association
    differences over the run, so its counts get a small slack — the
    iterates themselves still pin at 1e-10."""
    A, b, _ = _system()
    spec = SolveSpec(method=method, k=4, ell=6, tol=1e-12, maxiter=300)
    st = RecycleState.zeros(4, 64, jnp.float64)
    ref = solve(A, b, spec, st)
    got = solve(A, b, spec, st, mesh=make_solve_mesh(n_devices))

    slack = 5 if method == "lsmr" else 1
    np.testing.assert_allclose(got.x, ref.x, rtol=0, atol=1e-10)
    assert abs(int(got.info.iterations) - int(ref.info.iterations)) <= slack
    assert abs(int(got.info.matvecs) - int(ref.info.matvecs)) <= 2 * slack
    assert bool(got.info.converged) and bool(ref.info.converged)
    assert int(got.info.status) == int(ref.info.status)


def test_sharded_defcg_state_matches_up_to_row_sign():
    A, b, _ = _system()
    spec = SolveSpec(method="defcg", k=4, ell=6, tol=1e-10, maxiter=200)
    st = RecycleState.zeros(4, 64, jnp.float64)
    ref = solve(A, b, spec, st)
    got = solve(A, b, spec, st, mesh=make_solve_mesh(8))

    # Harmonic-Ritz vectors are sign-ambiguous per row; align then compare.
    w_r, w_g = np.asarray(ref.state.W), np.asarray(got.state.W)
    signs = np.sign(np.sum(w_r * w_g, axis=1))
    np.testing.assert_allclose(w_g * signs[:, None], w_r, atol=1e-10)
    np.testing.assert_allclose(
        np.asarray(got.state.AW) * signs[:, None],
        np.asarray(ref.state.AW),
        atol=1e-10,
    )
    np.testing.assert_allclose(got.state.theta, ref.state.theta, atol=1e-10)
    assert int(got.state.systems_solved) == int(ref.state.systems_solved) == 1


def test_recycling_win_survives_sharding():
    """The paper's claim under SPMD: a recycled second solve beats the
    cold first one by the same margin as the unsharded engine."""
    A, b, rng = _system()
    b2 = jnp.asarray(rng.standard_normal(64))
    spec = SolveSpec(method="defcg", k=4, ell=8, tol=1e-8, maxiter=200)
    st0 = RecycleState.zeros(4, 64, jnp.float64)
    mesh = make_solve_mesh(8)

    ref1 = solve(A, b, spec, st0)
    ref2 = solve(A, b2, spec, ref1.state)
    got1 = solve(A, b, spec, st0, mesh=mesh)
    got2 = solve(A, b2, spec, got1.state, mesh=mesh)

    assert int(ref2.info.iterations) < int(ref1.info.iterations)
    assert int(got2.info.iterations) < int(got1.info.iterations)
    assert abs(int(got1.info.iterations) - int(ref1.info.iterations)) <= 1
    assert abs(int(got2.info.iterations) - int(ref2.info.iterations)) <= 1
    np.testing.assert_allclose(got2.x, ref2.x, rtol=0, atol=1e-10)


def test_state_reshards_across_mesh_sizes():
    """A state produced on one mesh is a legal warm start on another:
    _prepare re-commits every traced input onto the target mesh, so a
    mesh-8 state feeds a mesh-1 (or unsharded) solve instead of dying
    on a cross-device jit error — and the answers agree."""
    A, b, rng = _system()
    b2 = jnp.asarray(rng.standard_normal(64))
    spec = SolveSpec(method="defcg", k=4, ell=8, tol=1e-8, maxiter=200)
    st0 = RecycleState.zeros(4, 64, jnp.float64)

    got1 = solve(A, b, spec, st0, mesh=make_solve_mesh(8))
    r_m1 = solve(A, b2, spec, got1.state, mesh=make_solve_mesh(1))
    r_m8 = solve(A, b2, spec, got1.state, mesh=make_solve_mesh(8))
    r_un = solve(A, b2, spec, got1.state)
    np.testing.assert_allclose(r_m1.x, r_m8.x, rtol=0, atol=1e-10)
    np.testing.assert_allclose(r_un.x, r_m8.x, rtol=0, atol=1e-10)
    assert abs(int(r_m1.info.iterations) - int(r_m8.info.iterations)) <= 1


def test_sharded_lsmr_damped_parity():
    A, b, _ = _system()
    spec = SolveSpec(
        method="lsmr", tol=1e-10, maxiter=300, lsq_shift=1e-2
    )
    ref = solve(A, b, spec)
    got = solve(A, b, spec, mesh=make_solve_mesh(8))
    np.testing.assert_allclose(got.x, ref.x, rtol=0, atol=1e-10)
    assert abs(int(got.info.iterations) - int(ref.info.iterations)) <= 5
    assert bool(got.info.converged) and bool(ref.info.converged)


def test_sharded_x0_and_trace_parity():
    """Warm start threads through, and the recorded residual trace
    follows the unsharded trajectory (a tol=1e-8 stop leaves x at the
    ~1e-8 convergence level, so the x pin here is commensurate; the
    tight 1e-10 trajectory pin lives in test_sharded_matches_unsharded
    at tol=1e-12)."""
    A, b, rng = _system()
    x0 = jnp.asarray(rng.standard_normal(64))
    spec = SolveSpec(method="defcg", k=4, ell=6, tol=1e-8, maxiter=200)
    st = RecycleState.zeros(4, 64, jnp.float64)
    ref = solve(A, b, spec, st, x0=x0, record_residuals=True)
    got = solve(
        A, b, spec, st, x0=x0, record_residuals=True,
        mesh=make_solve_mesh(8),
    )
    np.testing.assert_allclose(got.x, ref.x, rtol=0, atol=1e-6)
    # Early trace entries are bitwise-close; deep into the solve the
    # association-level beta differences amplify through the conjugacy
    # recurrences (both runs still converge to the same x), so pin the
    # prefix and the endpoint rather than the full tail.
    j = min(int(ref.info.iterations), int(got.info.iterations))
    prefix = min(j, 25)
    np.testing.assert_allclose(
        got.info.residual_norms[:prefix],
        ref.info.residual_norms[:prefix],
        rtol=1e-6,
    )
    assert bool(got.info.converged) and bool(ref.info.converged)


def test_solve_jit_with_static_mesh():
    """``mesh`` is a static argname of solve_jit — jitting the front
    door with a mesh reproduces the eager sharded solve exactly."""
    A, b, _ = _system()
    mesh = make_solve_mesh(8)
    spec = SolveSpec(method="cg", tol=1e-8, maxiter=200)
    eager = solve(A, b, spec, mesh=mesh)
    jitted = solve_jit(A, b, spec, mesh=mesh)
    np.testing.assert_allclose(jitted.x, eager.x, rtol=0, atol=1e-12)
    assert int(jitted.info.iterations) == int(eager.info.iterations)


def test_rbf_operator_sharded_parity():
    rng = np.random.default_rng(1)
    n = 256
    X = jnp.asarray(rng.standard_normal((n, 3)))
    sqrt_h = jnp.asarray(0.5 + rng.random(n))
    A = RBFKernelSystemOperator(
        x=X, sqrt_h=sqrt_h, theta=1.3, lengthscale=1.1,
        impl="chunked", block=64,
    )
    b = jnp.asarray(rng.standard_normal(n))
    spec = SolveSpec(method="defcg", k=4, ell=6, tol=1e-9, maxiter=400)
    st = RecycleState.zeros(4, n, jnp.float64)
    ref = solve(A, b, spec, st)
    got = solve(A, b, spec, st, mesh=make_solve_mesh(8))
    np.testing.assert_allclose(got.x, ref.x, rtol=0, atol=1e-10)
    assert abs(int(got.info.iterations) - int(ref.info.iterations)) <= 1
    assert abs(int(got.info.matvecs) - int(ref.info.matvecs)) <= 1


# ---------------------------------------------------------------------------
# front-door contract
# ---------------------------------------------------------------------------


class TestFrontDoor:
    def test_unsupported_method_raises(self):
        A, b, _ = _system()
        with pytest.raises(NotImplementedError, match="no sharded path"):
            solve(
                A, b, SolveSpec(method="deflsmr"), mesh=make_solve_mesh(8)
            )

    def test_preconditioner_rejected(self):
        A, b, _ = _system()
        with pytest.raises(ValueError, match="no preconditioner"):
            solve(A, b, SolveSpec(method="cg"), M=lambda r: r,
                  mesh=make_solve_mesh(8))

    def test_batch_axis_rejected(self):
        A, b, _ = _system()
        with pytest.raises(ValueError, match="do not compose"):
            solve(A, b, SolveSpec(method="cg"), batch_axis="tenant",
                  mesh=make_solve_mesh(8))

    def test_indivisible_n_raises(self):
        A, b, _ = _system(n=60)  # 60 % 8 != 0
        with pytest.raises(ValueError, match="not divisible"):
            solve(A, b, SolveSpec(method="cg"), mesh=make_solve_mesh(8))

    def test_wrong_mesh_axis_raises(self):
        A, b, _ = _system()
        bad = jax.make_mesh((8,), ("data",))
        with pytest.raises(ValueError, match="'solve' axis"):
            solve(A, b, SolveSpec(method="cg"), mesh=bad)

    def test_unsupported_operator_raises(self):
        b = jnp.ones(64)
        with pytest.raises(TypeError, match="shards the operator"):
            sharded.solve_sharded(
                lambda v: v, b, SolveSpec(method="cg"),
                mesh=make_solve_mesh(8),
            )

    def test_no_mesh_is_the_unsharded_path(self):
        A, b, _ = _system()
        res = solve(A, b, SolveSpec(method="cg", tol=1e-8))
        assert bool(res.info.converged)


# ---------------------------------------------------------------------------
# communication: collective counts pinned from compiled HLO
# ---------------------------------------------------------------------------


def _while_body_allreduce_counts(method, **spec_kw):
    A, b, _ = _system()
    st = RecycleState.zeros(4, 64, jnp.float64)
    spec = SolveSpec(method=method, k=4, ell=6, maxiter=200, **spec_kw)
    low = sharded.lower_sharded(A, b, spec, st, mesh=make_solve_mesh(8))
    hlo = low.compile().as_text()
    per_body = hlo_stats.while_body_collectives(hlo)
    assert per_body, "no while loop found in compiled sharded solve"
    return per_body


def test_defcg_one_allreduce_per_iteration():
    """THE tentpole contract: every def-CG iteration — recording scan
    phase and while phase both lower to HLO while loops — performs
    exactly ONE all-reduce (the merged psum) and one all-gather (the
    matvec input)."""
    for name, counts in _while_body_allreduce_counts("defcg").items():
        assert counts.get("all-reduce", 0) == 1, (name, counts)
        assert counts.get("all-gather", 0) == 1, (name, counts)
        assert counts.get("reduce-scatter", 0) == 0, (name, counts)


def test_cg_one_allreduce_per_iteration():
    for name, counts in _while_body_allreduce_counts("cg").items():
        assert counts.get("all-reduce", 0) == 1, (name, counts)


def test_lsmr_two_allreduces_per_iteration():
    """LSMR's β/α normalizations are serially dependent — two is its
    floor, and the sharded body must not exceed it."""
    for name, counts in _while_body_allreduce_counts("lsmr").items():
        assert counts.get("all-reduce", 0) == 2, (name, counts)


# ---------------------------------------------------------------------------
# hlo_stats counting helpers (unit level, synthetic HLO)
# ---------------------------------------------------------------------------

_SYNTH_ASYNC = """\
HloModule synth

%body.1 (p.0: (f32[2])) -> (f32[2]) {
  %p.0 = (f32[2]) parameter(0)
  %g.0 = f32[2] get-tuple-element((f32[2]) %p.0), index=0
  %ars = (f32[2], f32[2]) all-reduce-start(f32[2] %g.0), to_apply=%add
  %ard = f32[2] all-reduce-done((f32[2], f32[2]) %ars)
  ROOT %t.0 = (f32[2]) tuple(f32[2] %ard)
}

%cond.1 (p.1: (f32[2])) -> pred[] {
  %p.1 = (f32[2]) parameter(0)
  ROOT %c.0 = pred[] constant(true)
}

ENTRY %main (a.0: f32[2]) -> (f32[2]) {
  %a.0 = f32[2] parameter(0)
  %t.1 = (f32[2]) tuple(f32[2] %a.0)
  ROOT %w.0 = (f32[2]) while((f32[2]) %t.1), condition=%cond.1, body=%body.1
}
"""


class TestHloStatsCounting:
    def test_async_pair_counts_once(self):
        census = hlo_stats.count_collectives(_SYNTH_ASYNC)
        assert census["all-reduce"] == 1

    def test_async_pair_counts_once_in_while_body(self):
        per_body = hlo_stats.while_body_collectives(_SYNTH_ASYNC)
        assert per_body == {"body.1": {"all-reduce": 1}}

    def test_sync_form_counts(self):
        hlo = _SYNTH_ASYNC.replace(
            "%ars = (f32[2], f32[2]) all-reduce-start(f32[2] %g.0), "
            "to_apply=%add",
            "%ars2 = f32[2] all-reduce(f32[2] %g.0), to_apply=%add",
        ).replace(
            "%ard = f32[2] all-reduce-done((f32[2], f32[2]) %ars)",
            "%ard = f32[2] all-gather(f32[2] %ars2), dimensions={0}",
        )
        census = hlo_stats.count_collectives(hlo)
        assert census["all-reduce"] == 1
        assert census["all-gather"] == 1

    def test_nested_while_not_charged_to_outer_body(self):
        hlo = """\
HloModule nested

%inner_body (q.0: (f32[2])) -> (f32[2]) {
  %q.0 = (f32[2]) parameter(0)
  %gi = f32[2] get-tuple-element((f32[2]) %q.0), index=0
  %ari = f32[2] all-reduce(f32[2] %gi), to_apply=%add
  ROOT %ti = (f32[2]) tuple(f32[2] %ari)
}

%inner_cond (q.1: (f32[2])) -> pred[] {
  %q.1 = (f32[2]) parameter(0)
  ROOT %ci = pred[] constant(true)
}

%outer_body (p.0: (f32[2])) -> (f32[2]) {
  %p.0 = (f32[2]) parameter(0)
  %g.0 = f32[2] get-tuple-element((f32[2]) %p.0), index=0
  %ag = f32[4] all-gather(f32[2] %g.0), dimensions={0}
  %sl = f32[2] slice(f32[4] %ag), slice={[0:2]}
  %tn = (f32[2]) tuple(f32[2] %sl)
  %wi = (f32[2]) while((f32[2]) %tn), condition=%inner_cond, body=%inner_body
  %gw = f32[2] get-tuple-element((f32[2]) %wi), index=0
  ROOT %t.0 = (f32[2]) tuple(f32[2] %gw)
}

%outer_cond (p.1: (f32[2])) -> pred[] {
  %p.1 = (f32[2]) parameter(0)
  ROOT %c.0 = pred[] constant(true)
}

ENTRY %main (a.0: f32[2]) -> (f32[2]) {
  %a.0 = f32[2] parameter(0)
  %t.1 = (f32[2]) tuple(f32[2] %a.0)
  ROOT %w.0 = (f32[2]) while((f32[2]) %t.1), condition=%outer_cond, body=%outer_body
}
"""
        per_body = hlo_stats.while_body_collectives(hlo)
        assert per_body["outer_body"] == {"all-gather": 1}
        assert per_body["inner_body"] == {"all-reduce": 1}

    def test_conditional_branches_are_worst_case(self):
        hlo = """\
HloModule branchy

%yes (y.0: f32[2]) -> f32[2] {
  %y.0 = f32[2] parameter(0)
  ROOT %ay = f32[2] all-reduce(f32[2] %y.0), to_apply=%add
}

%no (n.0: f32[2]) -> f32[2] {
  %n.0 = f32[2] parameter(0)
  ROOT %an = f32[2] all-reduce(f32[2] %n.0), to_apply=%add
}

%body.1 (p.0: (pred[], f32[2])) -> (pred[], f32[2]) {
  %p.0 = (pred[], f32[2]) parameter(0)
  %pr = pred[] get-tuple-element((pred[], f32[2]) %p.0), index=0
  %g.0 = f32[2] get-tuple-element((pred[], f32[2]) %p.0), index=1
  %cd = f32[2] conditional(pred[] %pr, f32[2] %g.0, f32[2] %g.0), true_computation=%yes, false_computation=%no
  ROOT %t.0 = (pred[], f32[2]) tuple(pred[] %pr, f32[2] %cd)
}

%cond.1 (p.1: (pred[], f32[2])) -> pred[] {
  %p.1 = (pred[], f32[2]) parameter(0)
  ROOT %c.0 = pred[] constant(true)
}

ENTRY %main (a.0: pred[], b.0: f32[2]) -> (pred[], f32[2]) {
  %a.0 = pred[] parameter(0)
  %b.0 = f32[2] parameter(1)
  %t.1 = (pred[], f32[2]) tuple(pred[] %a.0, f32[2] %b.0)
  ROOT %w.0 = (pred[], f32[2]) while((pred[], f32[2]) %t.1), condition=%cond.1, body=%body.1
}
"""
        per_body = hlo_stats.while_body_collectives(hlo)
        # Both branches are counted — an upper bound per iteration.
        assert per_body["body.1"] == {"all-reduce": 2}

    def test_count_collectives_on_real_lowering(self):
        A, b, _ = _system()
        low = sharded.lower_sharded(
            A, b, SolveSpec(method="cg", maxiter=50),
            mesh=make_solve_mesh(8),
        )
        census = hlo_stats.count_collectives(low.compile().as_text())
        assert census["all-reduce"] >= 1
        assert census["all-gather"] >= 1


# ---------------------------------------------------------------------------
# scale: n = 1e5 GP solve without materializing K (slow tier)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_rbf_gp_solve_n_1e5_never_materializes_gram():
    """An n = 1e5 RBF GP system (75 GB dense Gram in f64 — far beyond
    materializing) solves through the sharded operator: row-blocks of X
    local per shard, K-tiles formed and consumed on the fly.  maxiter is
    tiny (each matvec is ~n² work on this 1-core CPU box) — the gate is
    completion + CONSISTENCY: the recurrence-tracked ‖r₁‖ must match the
    true ‖b − A x₁‖ recomputed with one more chunked matvec.  (A strict
    per-step decrease is NOT a valid gate: plain-CG residual 2-norms are
    non-monotone, and on this near-singular Gram the first step
    overshoots ‖r‖ by ~50× in exact arithmetic.)"""
    rng = np.random.default_rng(7)
    n = 100_000
    X = jnp.asarray(rng.standard_normal((n, 2)), dtype=jnp.float32)
    sqrt_h = jnp.asarray(0.5 + rng.random(n), dtype=jnp.float32)
    A = RBFKernelSystemOperator(
        x=X, sqrt_h=sqrt_h, theta=1.0, lengthscale=2.0,
        impl="chunked", block=512,
    )
    b = jnp.asarray(rng.standard_normal(n), dtype=jnp.float32)
    spec = SolveSpec(method="defcg", k=4, ell=0, tol=1e-8, maxiter=1)
    res = solve(
        A, b, spec, RecycleState.zeros(4, n, jnp.float32),
        record_residuals=True, mesh=make_solve_mesh(8),
    )
    assert np.all(np.isfinite(np.asarray(res.x)))
    assert float(jnp.linalg.norm(res.x)) > 0.0
    trace = np.asarray(res.info.residual_norms)
    assert np.isfinite(trace[0]) and np.isfinite(trace[1])
    np.testing.assert_allclose(
        trace[0], np.linalg.norm(np.asarray(b)), rtol=1e-4
    )
    true_r = float(jnp.linalg.norm(b - A.matvec(res.x)))
    np.testing.assert_allclose(trace[1], true_r, rtol=5e-2)
