"""Checkpoint + fault-tolerant runtime tests.

The headline invariant: a training run killed at an arbitrary step and
restarted must produce bit-identical final state to an uninterrupted run
(deterministic data + checkpointed state ⇒ exact replay).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_pytree, save_pytree
from repro.data import TokenPipeline
from repro.runtime import Trainer, TrainerConfig

# Multi-run trainer replays (each run recompiles the step): slow tier.
pytestmark = pytest.mark.slow


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "scalar": jnp.int32(7),
        }
        path = save_pytree(tree, str(tmp_path), step=3)
        out = restore_pytree(tree, path)
        for a, b in zip(
            jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(out)
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert a.dtype == b.dtype

    def test_manager_retention_and_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"x": jnp.zeros(3)}
        for s in (1, 2, 3, 4):
            mgr.save({"x": jnp.full(3, float(s))}, s)
        assert mgr.steps() == [3, 4]
        step, out, _ = mgr.restore_latest(tree)
        assert step == 4
        np.testing.assert_allclose(np.asarray(out["x"]), 4.0)

    def test_corrupt_tail_falls_back(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        tree = {"x": jnp.zeros(3)}
        mgr.save({"x": jnp.full(3, 1.0)}, 1)
        mgr.save({"x": jnp.full(3, 2.0)}, 2)
        # corrupt the newest checkpoint
        victim = os.path.join(str(tmp_path), "step_00000002", "arrays.npz")
        with open(victim, "wb") as f:
            f.write(b"garbage")
        step, out, _ = mgr.restore_latest(tree)
        assert step == 1
        np.testing.assert_allclose(np.asarray(out["x"]), 1.0)

    def test_structure_mismatch_rejected(self, tmp_path):
        path = save_pytree({"x": jnp.zeros(3)}, str(tmp_path), step=1)
        with pytest.raises(ValueError):
            restore_pytree({"y": jnp.zeros(3)}, path)


def _toy_step(state, batch):
    params, count = state
    grad = jax.tree_util.tree_map(
        lambda p: p - jnp.float32(batch["tokens"].sum() % 7), params
    )
    params = jax.tree_util.tree_map(lambda p, g: p - 0.01 * g, params, grad)
    return (params, count + 1), {"count": count + 1}


class TestTrainer:
    def _pipeline(self):
        return TokenPipeline(vocab_size=97, batch=2, seq_len=16, seed=0)

    def test_uninterrupted_run(self, tmp_path):
        pipe = self._pipeline()
        cfg = TrainerConfig(
            total_steps=12, checkpoint_every=4,
            checkpoint_dir=str(tmp_path), async_checkpoint=False,
        )
        state0 = ({"w": jnp.ones(4)}, jnp.int32(0))
        t = Trainer(_toy_step, pipe.make_batch, state0, cfg)
        out = t.run()
        assert out["final_step"] == 12

    def test_crash_replay_is_exact(self, tmp_path):
        pipe = self._pipeline()
        state0 = ({"w": jnp.ones(4)}, jnp.int32(0))

        # Reference: uninterrupted.
        ref_cfg = TrainerConfig(
            total_steps=12, checkpoint_every=3,
            checkpoint_dir=str(tmp_path / "ref"), async_checkpoint=False,
        )
        ref = Trainer(_toy_step, pipe.make_batch, state0, ref_cfg).run()

        # Faulty: dies at steps 5 and 8, must recover and match exactly.
        fails = {5: True, 8: True}

        def fault_hook(step):
            if fails.pop(step, False):
                raise RuntimeError("injected device failure")

        cfg = TrainerConfig(
            total_steps=12, checkpoint_every=3,
            checkpoint_dir=str(tmp_path / "faulty"), async_checkpoint=False,
        )
        out = Trainer(
            _toy_step, pipe.make_batch, state0, cfg, fault_hook=fault_hook
        ).run()
        assert out["events"].restarts == 2
        np.testing.assert_array_equal(
            np.asarray(out["state"][0]["w"]), np.asarray(ref["state"][0]["w"])
        )
        assert int(out["state"][1]) == int(ref["state"][1])

    def test_resume_after_preemption(self, tmp_path):
        pipe = self._pipeline()
        state0 = ({"w": jnp.ones(4)}, jnp.int32(0))
        cfg = TrainerConfig(
            total_steps=12, checkpoint_every=2,
            checkpoint_dir=str(tmp_path), async_checkpoint=False,
        )
        # First process: preempt after step 6.
        t1 = Trainer(_toy_step, pipe.make_batch, state0, cfg)

        orig = t1.step_fn

        def stopping_step(state, batch):
            out = orig(state, batch)
            if int(out[0][1]) >= 6:
                t1.request_stop()
            return out

        t1.step_fn = stopping_step
        t1.run()

        # Second process: picks up where the first left off, finishes.
        t2 = Trainer(_toy_step, pipe.make_batch, state0, cfg)
        assert t2.start_step >= 6
        out = t2.run()
        assert out["final_step"] == 12

    def test_straggler_detection(self, tmp_path):
        """Deterministic via an injected fake clock: every step 'takes'
        0.01s except step 9, which 'takes' 1.0s (100× the median)."""
        pipe = self._pipeline()
        state0 = ({"w": jnp.ones(4)}, jnp.int32(0))

        fake = {"t": 0.0, "step": 0, "phase": 0}

        def fake_clock():
            # called twice per step: start and end
            if fake["phase"] == 0:
                fake["phase"] = 1
            else:
                fake["phase"] = 0
                fake["t"] += 1.0 if fake["step"] == 9 else 0.01
                fake["step"] += 1
            return fake["t"]

        cfg = TrainerConfig(
            total_steps=12, checkpoint_every=100,
            checkpoint_dir=str(tmp_path), async_checkpoint=False,
            straggler_factor=3.0,
        )
        out = Trainer(
            _toy_step, pipe.make_batch, state0, cfg, time_fn=fake_clock
        ).run()
        assert out["events"].stragglers >= 1
        assert any("straggler" in line for line in out["events"].log)

    def test_data_pipeline_deterministic(self):
        pipe = self._pipeline()
        b1 = pipe.make_batch(7)
        b2 = pipe.make_batch(7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = pipe.make_batch(8)
        assert not np.array_equal(b1["tokens"], b3["tokens"])
