"""Front-door API tests (ISSUE 3 tentpole): SolveSpec + RecycleState,
preconditioned def-CG, and the batched multi-tenant entry point.

Five layers of checks:

  1. preconditioned def-CG parity: ``defcg(…, M)`` (Jacobi and Nyström)
     must reproduce an explicitly split-preconditioned reference solve —
     plain def-CG on ``E A E`` with the transformed basis ``E⁻¹W``,
     ``E = M^{-1/2}`` — to 1e-10 (trajectory parity at a fixed iteration
     count below convergence, where rounding noise cannot accumulate);
  2. the ``solve`` front door: state carry, refresh accounting, and
     round-tripping ``RecycleState`` through the checkpoint layer;
  3. ``solve_batch``: B vmapped tenants bit-match B sequential ``solve``
     calls (per-tenant masks freeze finished lanes), and the whole batch
     traces to one XLA computation with no host syncs;
  4. seed-time validation of ``RecycleManager.seed`` (host-side error
     instead of a mid-solve XLA shape failure);
  5. the paper-level claim: Nyström-preconditioned def-CG (invariant-K
     sketch + per-system Woodbury) beats unpreconditioned def-CG in
     matvecs on the GP Laplace Newton sequence.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_pytree, save_pytree
from repro.core import (
    DEFAULT_WAW_JITTER,
    RecycleManager,
    RecycleState,
    SolveSpec,
    cg,
    defcg,
    from_matrix,
    jacobi,
    nystrom_preconditioner,
    randomized_nystrom,
    solve,
    solve_batch,
    solve_jit,
    solve_sequence,
)
from repro.core import pytree as pt
from tests.conftest import make_spd


def _spd_problem(n=64, cond=1e3, seed=1, row_scale=0.8):
    rng = np.random.default_rng(seed)
    A0, _, _ = make_spd(n, cond, rng)
    s = np.logspace(0, row_scale, n)
    A = jnp.asarray(A0 * np.outer(s, s))
    b = jnp.asarray(rng.standard_normal(n))
    return A, b, rng


class TestSolveSpec:
    def test_waw_jitter_single_default(self):
        """Satellite: ONE waw_jitter default, carried by the spec and
        shared by defcg / the manager / the sequence engine."""
        import inspect

        assert SolveSpec().waw_jitter == DEFAULT_WAW_JITTER == 1e-12
        assert (
            inspect.signature(defcg).parameters["waw_jitter"].default
            == DEFAULT_WAW_JITTER
        )
        assert RecycleManager(k=4, ell=8).waw_jitter == DEFAULT_WAW_JITTER

    def test_validation(self):
        with pytest.raises(ValueError, match="method"):
            SolveSpec(method="gmres")
        with pytest.raises(ValueError, match="refresh_aw"):
            SolveSpec(refresh_aw="sometimes")
        with pytest.raises(ValueError, match="precond"):
            SolveSpec(precond="ilu")
        with pytest.raises(ValueError, match="k >= 1"):
            SolveSpec(k=0)

    def test_hashable_static_jit_arg(self):
        """Two equal specs must be one jit cache entry."""
        assert SolveSpec(k=4) == SolveSpec(k=4)
        assert hash(SolveSpec(k=4)) == hash(SolveSpec(k=4))
        assert SolveSpec(k=4) != SolveSpec(k=5)


class TestPreconditionedDefCGParity:
    """defcg(M) ≡ split-preconditioned plain def-CG, at 1e-10."""

    def _parity_case(self, M_dense_inv, M_apply, n=64, k=4, iters=15):
        A, b, rng = _spd_problem(n=n)
        # E = M^{-1/2} (symmetric): def-PCG on (A, b, M) must equal plain
        # def-CG on (EAE, Eb) with basis W̃ = E⁻¹W, mapped back by E.
        lam, q = np.linalg.eigh(np.asarray(M_dense_inv))
        E = (q * np.sqrt(lam)) @ q.T
        At = jnp.asarray(E @ np.asarray(A) @ E)
        bt = jnp.asarray(E @ np.asarray(b))
        W = jnp.asarray(np.linalg.qr(rng.standard_normal((n, k)))[0].T)
        Wt = jnp.asarray(np.asarray(W) @ np.linalg.inv(E))

        # Fixed iteration count below convergence: exact trajectory parity
        # (post-convergence steps wander in rounding noise by design).
        ref = defcg(
            from_matrix(At), bt, W=Wt, tol=0.0, maxiter=iters, waw_jitter=0.0
        )
        got = defcg(
            from_matrix(A), b, W=W, tol=0.0, maxiter=iters, waw_jitter=0.0,
            M=M_apply,
        )
        assert int(ref.info.iterations) == int(got.info.iterations) == iters
        x_ref = jnp.asarray(E @ np.asarray(ref.x))
        np.testing.assert_allclose(
            np.asarray(got.x), np.asarray(x_ref), rtol=1e-10, atol=1e-10
        )
        # and run to convergence: the preconditioned solve hits the TRUE
        # residual tolerance of the untransformed system
        conv = defcg(from_matrix(A), b, W=W, tol=1e-10, maxiter=5000, M=M_apply)
        assert bool(conv.info.converged)
        np.testing.assert_allclose(
            np.asarray(A @ conv.x), np.asarray(b),
            atol=1e-8 * float(jnp.linalg.norm(b)),
        )

    def test_jacobi_parity(self):
        A, _, _ = _spd_problem()
        d = jnp.diag(A)
        self._parity_case(np.diag(1.0 / np.asarray(d)), jacobi(d))

    def test_nystrom_parity(self):
        A, _, _ = _spd_problem()
        n = A.shape[0]
        U, lam = randomized_nystrom(
            from_matrix(A), jnp.zeros(n), rank=10, key=jax.random.PRNGKey(0)
        )
        M = nystrom_preconditioner(U, lam, sigma=1.0)
        M_dense = np.stack(
            [np.asarray(M(jnp.eye(n, dtype=A.dtype)[i])) for i in range(n)]
        ).T
        self._parity_case(M_dense, M)

    def test_pcg_defcg_no_basis_matches_cg(self):
        """defcg(M) without a basis is exactly preconditioned CG."""
        A, b, _ = _spd_problem()
        M = jacobi(jnp.diag(A))
        r_cg = cg(from_matrix(A), b, tol=1e-10, maxiter=2000, M=M)
        r_def = defcg(from_matrix(A), b, tol=1e-10, maxiter=2000, ell=0, M=M)
        assert int(r_cg.info.iterations) == int(r_def.info.iterations)
        np.testing.assert_allclose(
            np.asarray(r_cg.x), np.asarray(r_def.x), rtol=1e-9, atol=1e-10
        )


def _solve_args(n=64, cond=1e3, seed=2):
    rng = np.random.default_rng(seed)
    A0, _, _ = make_spd(n, cond, rng)
    s = np.logspace(0, 1.5, n)
    return (
        jnp.asarray(A0 * np.outer(s, s)),
        jnp.asarray(rng.standard_normal(n)),
        rng,
    )


def _drifting_mats(n=96, k=8, num=4, seed=11, drift=0.01):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    eigs = np.concatenate(
        [np.linspace(1.0, 5.0, n - k), np.logspace(3.0, 4.5, k)]
    )
    base = (q * eigs) @ q.T
    mats, bs = [], []
    for _ in range(num):
        pert = rng.standard_normal((n, n)) * drift
        mats.append(base + pert @ pert.T)
        bs.append(rng.standard_normal(n))
    return jnp.asarray(np.stack(mats)), jnp.asarray(np.stack(bs))


class TestSolveFrontDoor:
    SPEC = SolveSpec(k=8, ell=12, tol=1e-8, maxiter=5000)

    def test_state_carry_cuts_iterations(self):
        mats, bs = _drifting_mats()
        state = None
        iters = []
        for i in range(mats.shape[0]):
            res = solve_jit(from_matrix(mats[i]), bs[i], self.SPEC, state)
            state = res.state
            iters.append(int(res.info.iterations))
            np.testing.assert_allclose(
                np.asarray(mats[i] @ res.x), np.asarray(bs[i]),
                atol=1e-6 * float(jnp.linalg.norm(bs[i])),
            )
        assert int(state.systems_solved) == mats.shape[0]
        assert all(it < 0.6 * iters[0] for it in iters[1:])

    def test_matches_sequence_engine(self):
        """solve() iterated == solve_sequence(): same engine, same counts."""
        mats, bs = _drifting_mats(num=3, seed=5)
        seq = solve_sequence(
            mats, bs, self.SPEC, make_operator=from_matrix
        )
        state = None
        for i in range(3):
            res = solve(from_matrix(mats[i]), bs[i], self.SPEC, state)
            state = res.state
            assert int(res.info.iterations) == int(seq.info.iterations[i])
            assert int(res.info.matvecs) == int(seq.info.matvecs[i])
        np.testing.assert_allclose(
            np.asarray(state.W), np.asarray(seq.state.W), rtol=1e-9, atol=1e-9
        )
        assert int(seq.state.systems_solved) == 3

    def test_refresh_accounting(self):
        """matvecs = iterations + 1 (r₀) + k (refresh) after bootstrap."""
        mats, bs = _drifting_mats(num=2, seed=9)
        r1 = solve(from_matrix(mats[0]), bs[0], self.SPEC)
        assert int(r1.info.matvecs) == int(r1.info.iterations) + 1  # cold
        r2 = solve(from_matrix(mats[1]), bs[1], self.SPEC, r1.state)
        assert int(r2.info.matvecs) == int(r2.info.iterations) + 1 + 8

    def test_state_spec_mismatch_rejected(self):
        mats, bs = _drifting_mats(num=1)
        bad = RecycleState.zeros(4, bs.shape[1], bs.dtype)  # k=4 vs spec k=8
        with pytest.raises(ValueError, match="state and spec must agree"):
            solve(from_matrix(mats[0]), bs[0], self.SPEC, bad)

    def test_precond_strategy_requires_m(self):
        mats, bs = _drifting_mats(num=1)
        spec = dataclasses.replace(self.SPEC, precond="nystrom")
        with pytest.raises(ValueError, match="make_preconditioner"):
            solve(from_matrix(mats[0]), bs[0], spec)

    def test_sequence_precond_strategy_requires_factory(self):
        """A declared preconditioner strategy must not silently run
        unpreconditioned through the sequence front door."""
        mats, bs = _drifting_mats(num=2)
        spec = dataclasses.replace(self.SPEC, precond="jacobi")
        with pytest.raises(ValueError, match="factory"):
            solve_sequence(mats, bs, spec, make_operator=from_matrix)

    def test_atol_respected_by_sequence_paths(self):
        """SolveSpec.atol reaches the sequence engine (it was only honored
        by the single-system path)."""
        mats, bs = _drifting_mats(num=2)
        loose = SolveSpec(k=4, ell=8, tol=0.0, atol=1e-2, maxiter=3000)
        seq = solve_sequence(mats, bs, loose, make_operator=from_matrix)
        assert np.asarray(seq.info.converged).all()
        # tol=0, atol=0 would run every system to maxiter
        assert (np.asarray(seq.info.iterations) < 3000).all()

    def test_sequence_ell_zero_carries_state(self):
        """ell=0 (no recording) is a valid spec — the sequence runs,
        solves correctly, and carries the incoming basis/theta through."""
        mats, bs = _drifting_mats(num=2)
        spec = SolveSpec(k=4, ell=0, tol=1e-8, maxiter=5000)
        seq = solve_sequence(mats, bs, spec, make_operator=from_matrix)
        assert np.asarray(seq.info.converged).all()
        assert seq.state.theta.shape == (4,)
        assert int(seq.state.systems_solved) == 2

    def test_cg_jit_accepts_closure_and_pytree_preconditioners(self):
        """cg_jit keeps working with a bare-closure M (static fallback)
        AND with registered pytree-node preconditioners (traced)."""
        from repro.core import jacobi
        from repro.core.solvers import cg_jit

        A, b, _ = _solve_args()
        d = jnp.diag(A)
        closure = lambda r: r / d  # noqa: E731
        r1 = cg_jit(from_matrix(A), b, tol=1e-10, maxiter=2000, M=closure)
        r2 = cg_jit(from_matrix(A), b, tol=1e-10, maxiter=2000, M=jacobi(d))
        assert bool(r1.info.converged) and bool(r2.info.converged)
        # static path constant-folds d; traced path streams it — same
        # math, last-bit rounding may shift the stop by one iteration
        assert abs(int(r1.info.iterations) - int(r2.info.iterations)) <= 1
        np.testing.assert_allclose(
            np.asarray(r1.x), np.asarray(r2.x), rtol=1e-7, atol=1e-9
        )

    def test_legacy_w0_signature_removed(self):
        """The PR-3-era solve_sequence(systems, b, W0, AW0, k=…) shim is
        gone: positional arrays in the spec slot raise, keywords raise,
        and the supported replacement — state0=RecycleState — works."""
        mats, bs = _drifting_mats(num=3)
        first = solve_sequence(mats[:1], bs[:1], self.SPEC,
                               make_operator=from_matrix)
        with pytest.raises(TypeError, match="removed"):
            solve_sequence(mats[1:], bs[1:], first.state.W, first.state.AW,
                           make_operator=from_matrix)
        with pytest.raises(TypeError):
            solve_sequence(mats[1:], bs[1:], self.SPEC,
                           W0=first.state.W, AW0=first.state.AW,
                           make_operator=from_matrix)
        seq = solve_sequence(mats[1:], bs[1:], self.SPEC, first.state,
                             make_operator=from_matrix)
        assert np.asarray(seq.info.converged).all()

    def test_recycle_state_checkpoint_roundtrip(self, tmp_path):
        """RecycleState must survive checkpoint/manager.py unchanged —
        restoring a checkpoint resumes the recycling sequence."""
        mats, bs = _drifting_mats(num=1)
        res = solve(from_matrix(mats[0]), bs[0], self.SPEC)
        train_state = {"params": jnp.ones(3), "recycle": res.state}
        path = save_pytree(train_state, str(tmp_path), step=1)
        out = restore_pytree(train_state, path)
        assert isinstance(out["recycle"], RecycleState)
        for a, b in zip(
            jax.tree_util.tree_leaves(train_state["recycle"]),
            jax.tree_util.tree_leaves(out["recycle"]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # ... and the restored state keeps working as a warm start
        res2 = solve(from_matrix(mats[0]), bs[0], self.SPEC, out["recycle"])
        assert int(res2.info.iterations) < int(res.info.iterations)


class TestSolveBatch:
    SPEC = SolveSpec(k=6, ell=10, tol=1e-8, maxiter=3000)

    def test_vmap_parity_with_sequential_solves(self):
        """B batched tenants must match B sequential solve() calls —
        identical iterates (masked lanes freeze), counts and states."""
        B = 5
        rng = np.random.default_rng(17)
        mats, bs = [], []
        for i in range(B):
            A0, _, _ = make_spd(48, 10.0 ** (2 + i % 3), rng)
            mats.append(A0)
            bs.append(rng.standard_normal(48))
        mats = jnp.asarray(np.stack(mats))
        bs = jnp.asarray(np.stack(bs))

        batch = solve_batch(mats, bs, self.SPEC, make_operator=from_matrix)
        assert np.asarray(batch.info.converged).all()
        for i in range(B):
            single = solve(from_matrix(mats[i]), bs[i], self.SPEC)
            assert int(batch.info.iterations[i]) == int(
                single.info.iterations
            ), i
            assert int(batch.info.matvecs[i]) == int(single.info.matvecs), i
            np.testing.assert_allclose(
                np.asarray(batch.x[i]), np.asarray(single.x),
                rtol=1e-12, atol=1e-12,
            )
            np.testing.assert_allclose(
                np.asarray(batch.state.W[i]), np.asarray(single.state.W),
                rtol=1e-9, atol=1e-9,
            )

    def test_batched_states_feed_back(self):
        """A second batched round consumes the first round's states."""
        B, n = 3, 64
        rng = np.random.default_rng(23)
        q, _ = np.linalg.qr(rng.standard_normal((n, n)))
        eigs = np.concatenate(
            [np.linspace(1.0, 5.0, n - 6), np.logspace(3.0, 4.5, 6)]
        )
        A0 = (q * eigs) @ q.T
        mats = jnp.asarray(
            np.stack([A0 + 0.01 * np.eye(n) * i for i in range(B)])
        )
        bs = jnp.asarray(rng.standard_normal((B, n)))
        first = solve_batch(mats, bs, self.SPEC, make_operator=from_matrix)
        bs2 = jnp.asarray(rng.standard_normal((B, n)))
        second = solve_batch(
            mats, bs2, self.SPEC, first.state, make_operator=from_matrix
        )
        assert np.asarray(second.info.converged).all()
        assert (
            np.asarray(second.info.iterations)
            < 0.7 * np.asarray(first.info.iterations)
        ).all()
        np.testing.assert_array_equal(
            np.asarray(second.state.systems_solved), 2
        )

    def test_batched_sequences(self):
        """sequence=True: B tenants × N systems each, one computation."""
        B, N, n = 3, 3, 64
        rng = np.random.default_rng(29)
        A0, _, _ = make_spd(n, 1e4, rng)
        mats = np.empty((B, N, n, n))
        bs = np.empty((B, N, n))
        for t in range(B):
            for i in range(N):
                pert = rng.standard_normal((n, n)) * 0.01
                mats[t, i] = A0 * (1.0 + 0.1 * t) + pert @ pert.T
                bs[t, i] = rng.standard_normal(n)
        mats, bs = jnp.asarray(mats), jnp.asarray(bs)
        batch = solve_batch(
            mats, bs, self.SPEC, make_operator=from_matrix, sequence=True
        )
        assert batch.x.shape == (B, N, n)
        for t in range(B):
            seq = solve_sequence(
                mats[t], bs[t], self.SPEC, make_operator=from_matrix
            )
            # Batched eigh (the extraction's reduction) rounds differently
            # from the single-problem LAPACK path, and across a sequence
            # the extracted basis feeds the NEXT solve — so cross-system
            # counts may drift by ±1 iteration.  Solutions still meet the
            # same residual tolerance.
            np.testing.assert_allclose(
                np.asarray(batch.info.iterations[t]),
                np.asarray(seq.info.iterations),
                atol=2,
            )
            for i in range(N):
                np.testing.assert_allclose(
                    np.asarray(mats[t, i] @ batch.x[t, i]),
                    np.asarray(bs[t, i]),
                    atol=1e-6 * float(jnp.linalg.norm(bs[t, i])),
                )

    def test_cg_batch_passes_state_through(self):
        """method='cg' neither consumes nor updates recycle state — a
        supplied batched state must come back untouched, not be dropped."""
        mats, bs = _drifting_mats(num=2)
        prev = solve_batch(mats, bs, self.SPEC, make_operator=from_matrix)
        cg_spec = SolveSpec(method="cg", tol=1e-8, maxiter=3000)
        out = solve_batch(
            mats, bs, cg_spec, prev.state, make_operator=from_matrix
        )
        assert out.state is prev.state
        assert np.asarray(out.info.converged).all()

    def test_per_tenant_convergence_mask(self):
        """A hard tenant must not corrupt an easy tenant's answer."""
        n = 48
        rng = np.random.default_rng(31)
        easy, _, _ = make_spd(n, 10.0, rng)
        hard, _, _ = make_spd(n, 1e6, rng)
        mats = jnp.asarray(np.stack([easy, hard]))
        bs = jnp.asarray(rng.standard_normal((2, n)))
        spec = SolveSpec(k=4, ell=8, tol=1e-12, maxiter=40)  # hard one fails
        batch = solve_batch(mats, bs, spec, make_operator=from_matrix)
        conv = np.asarray(batch.info.converged)
        assert conv[0] and not conv[1]
        single = solve(from_matrix(mats[0]), bs[0], spec)
        np.testing.assert_allclose(
            np.asarray(batch.x[0]), np.asarray(single.x),
            rtol=1e-12, atol=1e-12,
        )


class TestSeedValidation:
    def test_seed_too_many_vectors_rejected(self):
        mgr = RecycleManager(k=4, ell=8)
        W = jnp.asarray(np.random.default_rng(0).standard_normal((6, 32)))
        with pytest.raises(ValueError, match="between 1 and 4"):
            mgr.seed(W)

    def test_seed_mismatched_aw_rejected(self):
        mgr = RecycleManager(k=4, ell=8)
        rng = np.random.default_rng(0)
        W = jnp.asarray(rng.standard_normal((3, 32)))
        with pytest.raises(ValueError, match="does not match W"):
            mgr.seed(W, jnp.asarray(rng.standard_normal((3, 16))))
        with pytest.raises(ValueError, match="structure"):
            mgr.seed(W, {"a": jnp.asarray(rng.standard_normal((3, 32)))})

    def test_valid_seed_still_works(self):
        rng = np.random.default_rng(3)
        A, _, q = make_spd(64, 1e4, rng)
        A = jnp.asarray(A)
        W = jnp.asarray(q[:, -4:].T)
        mgr = RecycleManager(k=4, ell=8, tol=1e-8, maxiter=3000)
        mgr.seed(W)
        res = mgr.solve(from_matrix(A), jnp.asarray(rng.standard_normal(64)))
        assert bool(res.info.converged)
        assert mgr.AW is not None


class TestLaplaceNystromPrecondition:
    @pytest.fixture(scope="class")
    def gp_runs(self):
        """The GP Laplace Newton sequence, plain vs Nyström def-CG."""
        from repro.data import make_infinite_digits
        from repro.gp import RBFKernel, laplace_gpc

        x, y = make_infinite_digits(260, seed=7)
        x = jnp.asarray(x, jnp.float64)
        y = jnp.asarray(y, jnp.float64)
        kernel = RBFKernel(theta=30.0, lengthscale=32.0)
        base = SolveSpec(k=8, ell=12, tol=1e-10, maxiter=4000)
        nys = dataclasses.replace(base, precond="nystrom", precond_rank=40)
        plain = laplace_gpc(x, y, kernel, spec=base, newton_tol=1e-4)
        pre = laplace_gpc(
            x, y, kernel, spec=nys,
            precond_key=jax.random.PRNGKey(0), newton_tol=1e-4,
        )
        return plain, pre

    def test_nystrom_defcg_needs_measurably_fewer_matvecs(self, gp_runs):
        """Acceptance criterion: Nyström-preconditioned def-CG beats
        unpreconditioned def-CG on the GP Laplace sequence — per-system
        solver iterations AND total matvecs (sketch cost INCLUDED; the
        invariant-K sketch amortizes across the Newton sequence)."""
        plain, pre = gp_runs
        it_plain = plain.trace.solver_iterations
        it_pre = pre.trace.solver_iterations
        assert len(it_plain) == len(it_pre)
        assert all(p < q for p, q in zip(it_pre, it_plain))
        assert sum(it_pre) < 0.6 * sum(it_plain)
        # total operator applications, one-off sketch charged to system 1
        assert sum(pre.trace.solver_matvecs) < 0.95 * sum(
            plain.trace.solver_matvecs
        )

    def test_same_mode_found(self, gp_runs):
        plain, pre = gp_runs
        assert abs(pre.logp - plain.logp) / abs(plain.logp) < 1e-6
        np.testing.assert_allclose(
            np.asarray(pre.f), np.asarray(plain.f), atol=5e-4
        )
