"""Shared pytest fixtures.

x64 is enabled globally for the test session: solver correctness tests
need double precision, and all model code passes explicit dtypes so this
does not perturb the (bf16/f32) smoke tests.

The test process forces EIGHT host platform devices (before jax is first
imported — the flag is read at backend initialization): the sharded
Krylov engine's parity and collective-count suite
(tests/test_sharded_engine.py) needs a real multi-device mesh, and every
single-device test is oblivious to the extra devices because jax places
un-annotated computations on device 0.  Only `repro/launch/dryrun.py` (a
separate process) requests more (512).

``hypothesis`` is optional: CI boxes without it still collect and run the
full deterministic suite — a stub module is installed so the
``from hypothesis import given, ...`` imports in test files resolve, and
every ``@given``-decorated property test is skipped.
"""

import os
import sys
import types

if "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()

import jax
import numpy as np
import pytest

try:
    import hypothesis

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def _given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def _settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    _settings.register_profile = lambda *a, **k: None
    _settings.load_profile = lambda *a, **k: None

    def _strategy(*_args, **_kwargs):
        return None

    _strategies = types.ModuleType("hypothesis.strategies")
    for _name in (
        "integers",
        "floats",
        "booleans",
        "sampled_from",
        "lists",
        "text",
        "tuples",
        "one_of",
        "just",
    ):
        setattr(_strategies, _name, _strategy)

    hypothesis = types.ModuleType("hypothesis")
    hypothesis.given = _given
    hypothesis.settings = _settings
    hypothesis.strategies = _strategies
    sys.modules["hypothesis"] = hypothesis
    sys.modules["hypothesis.strategies"] = _strategies

jax.config.update("jax_enable_x64", True)

if HAVE_HYPOTHESIS:
    # Deterministic property tests (shared CI boxes; examples replay exactly).
    hypothesis.settings.register_profile(
        "ci", derandomize=True, deadline=None, max_examples=15
    )
    hypothesis.settings.load_profile("ci")


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Free compiled-executable memory between test modules — the full
    suite compiles hundreds of programs in one process (1-core CPU box)."""
    yield
    jax.clear_caches()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_spd(n, cond=1e3, rng=None, dtype=np.float64):
    """Random SPD matrix with a controlled, log-spaced spectrum."""
    rng = rng or np.random.default_rng(0)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    eigs = np.logspace(0, np.log10(cond), n)
    return (q * eigs) @ q.T.astype(dtype), eigs, q
