"""Self-check for the static analyzer: every rule has fixture snippets
covering the positive, suppressed, and (where applicable) allowlisted
cases, plus engine-level suppression/baseline mechanics.

The fixtures are tiny synthetic trees written under ``tmp_path`` with
the directory names the rules key on (``core/``, ``kernels/``), so the
tests exercise the same path-scoping logic the real ``src/`` scan uses.
Non-slow tier: pure AST work, no jax imports in the hot path.
"""

import json
import textwrap

import pytest

from repro.analysis import (
    LintConfig,
    RULE_NAMES,
    load_baseline,
    run_lint,
    write_baseline,
)
from repro.analysis.engine import parse_suppressions


def _lint_snippet(tmp_path, relpath, code, config=None):
    """Write ``code`` at ``tmp_path/relpath`` and lint the whole tree."""
    target = tmp_path / relpath
    target.parent.mkdir(parents=True, exist_ok=True)
    target.write_text(textwrap.dedent(code))
    return run_lint([str(tmp_path)], config=config)


def _rules_hit(result):
    return sorted({v.rule for v in result.violations})


# ---------------------------------------------------------------------------
# host-sync-in-trace
# ---------------------------------------------------------------------------


class TestHostSyncInTrace:
    POSITIVE = """
        import jax
        import numpy as np

        @jax.jit
        def traced(x):
            n = int(x)            # host sync on traced data
            y = np.abs(x)         # host numpy in traced code
            z = x.item()          # device->host transfer
            return n + y + z
    """

    def test_positive(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", self.POSITIVE)
        rules = [v.rule for v in result.violations]
        assert rules.count("host-sync-in-trace") == 3

    def test_transitive_reachability(self, tmp_path):
        # int() lives in a helper that a scanned function calls: still hit.
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax

            def helper(x):
                return int(x)

            def step(c, x):
                return c + helper(x), c

            def run(xs):
                return jax.lax.scan(step, 0.0, xs)
        """)
        assert _rules_hit(result) == ["host-sync-in-trace"]

    def test_suppressed(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax

            @jax.jit
            def traced(x, k):
                # repro-lint: disable=host-sync-in-trace — k is static config
                n = int(k)
                return x * n
        """)
        assert result.violations == []
        assert result.suppressed == 1

    def test_multiline_justification_suppresses(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax

            @jax.jit
            def traced(x, k):
                # repro-lint: disable=host-sync-in-trace — k is static
                # config threaded from the spec, never traced data.
                n = int(k)
                return x * n
        """)
        assert result.violations == []
        assert result.suppressed == 1

    def test_allowlisted_file(self, tmp_path):
        # faults.py is genuinely host-side (io_callback instrumentation).
        result = _lint_snippet(tmp_path, "core/faults.py", self.POSITIVE)
        assert result.violations == []
        assert result.suppressed == 0

    def test_untraced_function_not_flagged(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            def host_only(x):
                return int(x)
        """)
        assert result.violations == []

    def test_static_shape_casts_not_flagged(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax

            @jax.jit
            def traced(x):
                n = int(x.shape[0])
                m = int(len(x.shape))
                return x * (n + m)
        """)
        assert result.violations == []

    def test_outside_traced_packages_not_flagged(self, tmp_path):
        result = _lint_snippet(tmp_path, "bench/mod.py", self.POSITIVE)
        assert result.violations == []


# ---------------------------------------------------------------------------
# kernel-contract
# ---------------------------------------------------------------------------


class TestKernelContract:
    REF = """
        def good_op(x):
            return x
    """
    TESTS = """
        def test_good_op_parity():
            assert good_op is not None
    """

    def _tree(self, tmp_path, ops_code):
        (tmp_path / "kernels").mkdir(parents=True, exist_ok=True)
        (tmp_path / "kernels" / "ref.py").write_text(
            textwrap.dedent(self.REF))
        (tmp_path / "tests").mkdir(exist_ok=True)
        (tmp_path / "tests" / "test_parity.py").write_text(
            textwrap.dedent(self.TESTS))
        return _lint_snippet(tmp_path, "kernels/ops.py", ops_code)

    def test_compliant_op_passes(self, tmp_path):
        result = self._tree(tmp_path, """
            from repro.kernels import ref

            def good_op(x, *, impl="auto"):
                if impl == "pallas":
                    return x
                if impl == "interpret":
                    return x
                if impl == "chunked":
                    return x
                if impl == "reference":
                    return ref.good_op(x)
                return ref.good_op(x)
        """)
        assert result.violations == []

    def test_missing_impl_and_oracle_and_test(self, tmp_path):
        result = self._tree(tmp_path, """
            def bad_op(x, *, impl="auto"):
                if impl == "pallas":
                    return x
                return x
        """)
        msgs = [v.message for v in result.violations]
        assert all(v.rule == "kernel-contract" for v in result.violations)
        assert any("does not dispatch" in m for m in msgs)  # impls missing
        assert any("never references" in m for m in msgs)  # no oracle
        assert any("no parity test" in m for m in msgs)  # not in tests/

    def test_oracle_must_exist_in_ref(self, tmp_path):
        result = self._tree(tmp_path, """
            from repro.kernels import ref

            def good_op(x, *, impl="auto"):
                for impl in ("pallas", "interpret", "reference", "chunked"):
                    pass
                return ref.phantom_op(x)
        """)
        assert any(
            "not defined in ref.py" in v.message for v in result.violations
        )

    def test_non_contract_function_ignored(self, tmp_path):
        # No `impl` kwarg (e.g. decode steps) and private helpers: exempt.
        result = self._tree(tmp_path, """
            def decode_step(x):
                return x

            def _helper(x, *, impl="auto"):
                return x
        """)
        assert result.violations == []


# ---------------------------------------------------------------------------
# pytree-schema (AST half)
# ---------------------------------------------------------------------------


class TestPytreeSchema:
    def test_missing_unflatten(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax

            @jax.tree_util.register_pytree_node_class
            class Broken:
                def tree_flatten(self):
                    return (), None
        """)
        assert _rules_hit(result) == ["pytree-schema"]
        assert "tree_unflatten" in result.violations[0].message

    def test_dynamic_key_name(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax
            from jax.tree_util import GetAttrKey

            @jax.tree_util.register_pytree_with_keys_class
            class Shifty:
                def tree_flatten_with_keys(self):
                    name = "W" + "x"
                    return [(GetAttrKey(name), 1)], None

                @classmethod
                def tree_unflatten(cls, aux, children):
                    return cls()
        """)
        assert _rules_hit(result) == ["pytree-schema"]
        assert "non-literal" in result.violations[0].message

    def test_good_registration_passes(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax
            from jax.tree_util import GetAttrKey

            @jax.tree_util.register_pytree_with_keys_class
            class Stable:
                def tree_flatten_with_keys(self):
                    return [(GetAttrKey("W"), 1)], None

                @classmethod
                def tree_unflatten(cls, aux, children):
                    return cls()
        """)
        assert result.violations == []


# ---------------------------------------------------------------------------
# static-spec-frozen
# ---------------------------------------------------------------------------


class TestStaticSpecFrozen:
    def test_unfrozen_spec(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import dataclasses

            @dataclasses.dataclass
            class TunerSpec:
                k: int = 8
        """)
        assert _rules_hit(result) == ["static-spec-frozen"]

    def test_array_leaf_in_spec(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import dataclasses
            import jax.numpy as jnp

            @dataclasses.dataclass(frozen=True)
            class SketchSpec:
                k: int = 8
                weights: jnp.ndarray = None
        """)
        assert _rules_hit(result) == ["static-spec-frozen"]
        assert "leaf-less" in result.violations[0].message

    def test_frozen_scalar_spec_passes(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import dataclasses

            @dataclasses.dataclass(frozen=True)
            class CleanSpec:
                k: int = 8
                tol: float = 1e-5
        """)
        assert result.violations == []

    def test_non_spec_dataclass_ignored(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import dataclasses

            @dataclasses.dataclass
            class MutableScratch:
                count: int = 0
        """)
        assert result.violations == []


# ---------------------------------------------------------------------------
# cond-batched-pred
# ---------------------------------------------------------------------------


class TestCondBatchedPred:
    def test_unreduced_pred(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax

            def gate(pred, x):
                return jax.lax.cond(pred, lambda v: v, lambda v: -v, x)
        """)
        assert _rules_hit(result) == ["cond-batched-pred"]

    def test_psum_reduced_pred_passes(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax
            import jax.numpy as jnp

            def gate(pred, x, axis):
                any_pred = jax.lax.psum(pred.astype(jnp.int32), axis) > 0
                return jax.lax.cond(any_pred, lambda v: v, lambda v: -v, x)
        """)
        assert result.violations == []

    def test_chained_assignment_reduction_passes(self, tmp_path):
        # The reduction is two assignments upstream of the predicate.
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax
            import jax.numpy as jnp

            def gate(active, x, axis):
                total = jax.lax.psum(active.astype(jnp.int32), axis)
                run = total > 0
                return jax.lax.cond(run, lambda v: v, lambda v: -v, x)
        """)
        assert result.violations == []

    def test_suppressed(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax

            def gate(pred, x):
                # repro-lint: disable=cond-batched-pred — never vmapped
                return jax.lax.cond(pred, lambda v: v, lambda v: -v, x)
        """)
        assert result.violations == []
        assert result.suppressed == 1


# ---------------------------------------------------------------------------
# bare-except / swallowed-thread-exc
# ---------------------------------------------------------------------------


class TestExceptionRules:
    def test_bare_except(self, tmp_path):
        result = _lint_snippet(tmp_path, "util/mod.py", """
            def f():
                try:
                    g()
                except:
                    pass
        """)
        assert "bare-except" in _rules_hit(result)

    def test_typed_except_passes(self, tmp_path):
        result = _lint_snippet(tmp_path, "util/mod.py", """
            def f():
                try:
                    g()
                except ValueError:
                    raise
        """)
        assert result.violations == []

    def test_swallowed_thread_exc(self, tmp_path):
        result = _lint_snippet(tmp_path, "util/mod.py", """
            import threading

            def spawn():
                def work():
                    try:
                        risky()
                    except Exception:
                        pass
                t = threading.Thread(target=work, daemon=True)
                t.start()
        """)
        assert "swallowed-thread-exc" in _rules_hit(result)

    def test_stored_exception_passes(self, tmp_path):
        # The checkpoint-manager idiom: stash for the joiner to re-raise.
        result = _lint_snippet(tmp_path, "util/mod.py", """
            import threading

            class Saver:
                def spawn(self):
                    def work():
                        try:
                            risky()
                        except BaseException as exc:
                            self._async_error = exc
                    self._thread = threading.Thread(target=work)
                    self._thread.start()
        """)
        assert result.violations == []


# ---------------------------------------------------------------------------
# engine mechanics: suppressions, baseline, fingerprints
# ---------------------------------------------------------------------------


class TestEngine:
    def test_every_rule_name_is_documented(self):
        # The catalogue the fixtures above cover, pinned so a new rule
        # without fixture coverage fails here first.
        assert RULE_NAMES == [
            "host-sync-in-trace",
            "kernel-contract",
            "pytree-schema",
            "static-spec-frozen",
            "cond-batched-pred",
            "bare-except",
            "swallowed-thread-exc",
        ]

    def test_disable_file(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            # repro-lint: disable-file=host-sync-in-trace — eager debug module
            import jax

            @jax.jit
            def traced(x):
                return int(x)
        """)
        assert result.violations == []
        assert result.suppressed == 1

    def test_unrelated_rule_not_suppressed(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax

            @jax.jit
            def traced(x):
                # repro-lint: disable=bare-except — wrong rule name
                return int(x)
        """)
        assert _rules_hit(result) == ["host-sync-in-trace"]

    def test_baseline_grandfathers_by_content(self, tmp_path):
        code = """
            import jax

            @jax.jit
            def traced(x):
                return int(x)
        """
        result = _lint_snippet(tmp_path, "core/mod.py", code)
        assert len(result.violations) == 1
        bl_path = tmp_path / "baseline.json"
        write_baseline(str(bl_path), result.violations)
        baseline = load_baseline(str(bl_path))

        # Same finding, shifted by unrelated edits above: still baselined.
        shifted = "# a new comment line\n# another\n" + textwrap.dedent(code)
        (tmp_path / "core" / "mod.py").write_text(shifted)
        result2 = run_lint([str(tmp_path)], baseline=baseline)
        assert result2.violations == []
        assert len(result2.baselined) == 1

    def test_baseline_does_not_hide_new_findings(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", """
            import jax

            @jax.jit
            def traced(x):
                return int(x)
        """)
        bl_path = tmp_path / "baseline.json"
        write_baseline(str(bl_path), result.violations)
        baseline = load_baseline(str(bl_path))
        (tmp_path / "core" / "mod.py").write_text(textwrap.dedent("""
            import jax

            @jax.jit
            def traced(x):
                return int(x)

            @jax.jit
            def traced2(y):
                return float(y)
        """))
        result2 = run_lint([str(tmp_path)], baseline=baseline)
        assert len(result2.baselined) == 1  # the int() finding
        assert len(result2.violations) == 1  # the new float() finding

    def test_parse_error_is_a_violation(self, tmp_path):
        result = _lint_snippet(tmp_path, "core/mod.py", "def broken(:\n")
        assert [v.rule for v in result.violations] == ["parse-error"]

    def test_suppression_parser(self):
        sup = parse_suppressions(
            "x = 1\n"
            "# repro-lint: disable=rule-a, rule-b — because reasons\n"
            "y = 2\n"
        )
        assert sup.matches("rule-a", 2)
        assert sup.matches("rule-b", 3)  # line after the directive
        assert not sup.matches("rule-c", 3)
        assert not sup.matches("rule-a", 1)


# ---------------------------------------------------------------------------
# the shipped tree is clean
# ---------------------------------------------------------------------------


def test_shipped_src_tree_is_clean():
    """`python -m repro.analysis src/` exits 0 on the repo as shipped —
    every finding fixed or suppressed with a justification."""
    import pathlib

    src = pathlib.Path(__file__).resolve().parent.parent / "src"
    result = run_lint([str(src)])
    assert result.violations == [], "\n".join(
        v.format() for v in result.violations
    )


def test_baseline_file_parses_if_present():
    import pathlib

    bl = (
        pathlib.Path(__file__).resolve().parent.parent
        / "analysis" / "baseline.json"
    )
    if not bl.exists():
        pytest.skip("no baseline file (clean tree)")
    data = json.loads(bl.read_text())
    assert isinstance(data.get("violations"), list)
