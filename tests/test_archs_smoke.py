"""Per-architecture smoke tests (reduced configs, CPU, 1 device).

For every assigned arch: instantiate the reduced config of the same
family, run one forward + loss + grad step, one prefill + decode step,
and assert output shapes and finiteness.  The FULL configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.layers import padded_vocab

# Full-zoo end-to-end compiles: the dominant share of tier-1 wall-clock.
# The quick CI tier (-m "not slow") skips these; run them locally / nightly.
pytestmark = pytest.mark.slow

# Shape-insensitive assertions (finiteness, xent ≈ log V, cache equality
# at matching positions) — the smallest batch/seq the decode loop still
# exercises meaningfully keeps the per-arch compile+run cost down.
B, S = 2, 24


def _batch(cfg, key):
    ks = jax.random.split(key, 4)
    batch = {}
    if cfg.is_encdec:
        batch["src_embeds"] = jax.random.normal(
            ks[0], (B, cfg.source_len, cfg.d_model), jnp.float32
        )
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_and_loss(self, arch):
        cfg = get_smoke_config(arch)
        key = jax.random.PRNGKey(0)
        params = models.init(key, cfg)
        batch = _batch(cfg, jax.random.PRNGKey(1))

        hidden, aux = models.forward_hidden(params, batch, cfg)
        assert hidden.shape == (B, S, cfg.d_model)
        assert bool(jnp.all(jnp.isfinite(hidden)))

        loss, metrics = models.lm_loss(params, batch, cfg)
        assert np.isfinite(float(loss))
        # untrained model ⇒ near-uniform prediction ⇒ xent ≈ log V
        assert float(metrics["xent"]) < np.log(padded_vocab(cfg)) + 2.0

    def test_grad_step(self, arch):
        cfg = get_smoke_config(arch)
        params = models.init(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg, jax.random.PRNGKey(1))

        def loss_fn(p):
            return models.lm_loss(p, batch, cfg)[0]

        loss, grads = jax.value_and_grad(loss_fn)(params)
        assert np.isfinite(float(loss))
        flat = jax.tree_util.tree_leaves(grads)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
        # something must receive nonzero gradient
        assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat)

    def test_prefill_decode(self, arch):
        cfg = get_smoke_config(arch)
        params = models.init(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg, jax.random.PRNGKey(1))
        state = models.init_decode_state(cfg, B, max_len=S + 8)

        state, logits = models.prefill(params, batch, state, cfg)
        assert logits.shape == (B, 1, padded_vocab(cfg))
        assert bool(jnp.all(jnp.isfinite(logits)))

        tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)
        logits2, state = models.decode_step(params, tok[:, None], state, cfg)
        assert logits2.shape == (B, 1, padded_vocab(cfg))
        assert bool(jnp.all(jnp.isfinite(logits2)))
        assert int(state.length) == S + 1

    def test_decode_matches_forward(self, arch):
        """Teacher-forced decode must reproduce the full forward logits —
        the KV-cache/SSM-state correctness invariant."""
        cfg = get_smoke_config(arch)
        if cfg.is_encdec:
            pytest.skip("enc-dec covered by prefill path")
        params = models.init(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg, jax.random.PRNGKey(1))
        hidden, _ = models.forward_hidden(params, batch, cfg)
        from repro.models.layers import lm_head_weights

        full_logits = hidden @ lm_head_weights(params["embed"], cfg)

        state = models.init_decode_state(cfg, B, max_len=S)
        outs = []
        for t in range(S):
            lg, state = models.decode_step(
                params, batch["tokens"][:, t : t + 1], state, cfg
            )
            outs.append(lg[:, 0])
        dec_logits = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(dec_logits, np.float32),
            np.asarray(full_logits, np.float32),
            rtol=2e-2, atol=2e-2,
        )
