"""Unit + property tests for repro.core solvers (CG / def-CG / recycling).

These encode the paper's mathematical claims as executable checks:
  * def-CG keeps residuals orthogonal to the deflation space (Eq. 5);
  * deflating the top-k eigenvectors yields the κ_eff = λ_n/λ_{k+1}
    convergence improvement (§2.1) — checked as an iteration-count drop;
  * harmonic Ritz values approximate extremal eigenvalues (§2.3);
  * recycling across a drifting sequence of systems reduces iterations
    (the paper's central empirical claim, Table 1 / Fig 2).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    RecycleManager,
    cg,
    cholesky_solve,
    defcg,
    from_matrix,
    harmonic_ritz,
    materialize,
    random_orthonormal_basis,
    randomized_nystrom,
    nystrom_preconditioner,
)
from repro.core import pytree as pt
from tests.conftest import make_spd


def _solve_setup(n=64, cond=1e4, seed=0):
    rng = np.random.default_rng(seed)
    A, eigs, q = make_spd(n, cond, rng)
    b = rng.standard_normal(n)
    return jnp.asarray(A), jnp.asarray(b), eigs, q


class TestCG:
    def test_converges_to_direct_solution(self):
        A, b, _, _ = _solve_setup()
        res = cg(from_matrix(A), b, tol=1e-12, maxiter=500)
        x_direct = jnp.linalg.solve(A, b)
        np.testing.assert_allclose(res.x, x_direct, rtol=1e-8, atol=1e-8)
        assert bool(res.info.converged)

    def test_exact_in_n_iterations(self):
        # Krylov finite-termination: CG reaches machine precision in ≤ n its.
        A, b, _, _ = _solve_setup(n=24, cond=1e2)
        res = cg(from_matrix(A), b, tol=1e-13, maxiter=200)
        assert int(res.info.iterations) <= 40  # n + numerics slack

    def test_clustered_spectrum_converges_fast(self):
        # k distinct eigenvalues → ≤ k iterations (exact arithmetic).
        rng = np.random.default_rng(1)
        q, _ = np.linalg.qr(rng.standard_normal((50, 50)))
        eigs = np.repeat([1.0, 10.0, 100.0], [20, 20, 10])
        A = jnp.asarray((q * eigs) @ q.T)
        b = jnp.asarray(rng.standard_normal(50))
        res = cg(from_matrix(A), b, tol=1e-10, maxiter=100)
        assert int(res.info.iterations) <= 6

    def test_pytree_vectors(self):
        # CG over a dict-structured unknown (the LM/GGN use case).
        rng = np.random.default_rng(2)
        A, _, _ = make_spd(12, 50.0, rng)
        A = jnp.asarray(A)

        def matvec(tree):
            flat = jnp.concatenate([tree["a"].ravel(), tree["b"].ravel()])
            out = A @ flat
            return {"a": out[:8].reshape(2, 4), "b": out[8:]}

        b_tree = {
            "a": jnp.asarray(rng.standard_normal((2, 4))),
            "b": jnp.asarray(rng.standard_normal(4)),
        }
        res = cg(matvec, b_tree, tol=1e-12, maxiter=100)
        flat_x = jnp.concatenate([res.x["a"].ravel(), res.x["b"].ravel()])
        flat_b = jnp.concatenate([b_tree["a"].ravel(), b_tree["b"].ravel()])
        np.testing.assert_allclose(A @ flat_x, flat_b, rtol=1e-8, atol=1e-8)

    def test_jacobi_pcg_reduces_iterations(self):
        # Badly row-scaled SPD: Jacobi preconditioning must win.
        rng = np.random.default_rng(3)
        n = 80
        A0, _, _ = make_spd(n, 10.0, rng)
        s = np.logspace(0, 3, n)
        A = jnp.asarray(A0 * np.outer(s, s))
        b = jnp.asarray(rng.standard_normal(n))
        plain = cg(from_matrix(A), b, tol=1e-10, maxiter=2000)
        from repro.core import jacobi

        pre = cg(
            from_matrix(A), b, tol=1e-10, maxiter=2000, M=jacobi(jnp.diag(A))
        )
        assert int(pre.info.iterations) < int(plain.info.iterations)
        x_direct = jnp.linalg.solve(A, b)
        np.testing.assert_allclose(pre.x, x_direct, rtol=1e-6, atol=1e-6)


class TestDefCG:
    def test_matches_cg_without_deflation(self):
        A, b, _, _ = _solve_setup()
        r1 = cg(from_matrix(A), b, tol=1e-10, maxiter=500)
        r2 = defcg(from_matrix(A), b, tol=1e-10, maxiter=500, ell=0)
        np.testing.assert_allclose(r1.x, r2.x, rtol=1e-9, atol=1e-10)
        assert int(r1.info.iterations) == int(r2.info.iterations)

    def test_residual_orthogonal_to_W(self):
        # Eq. (5): every def-CG residual ⟂ span{W}.  Check the final one.
        A, b, eigs, q = _solve_setup(n=48, cond=1e5)
        W = pt.basis_from_vectors([jnp.asarray(q[:, -i]) for i in (1, 2, 3)])
        res = defcg(from_matrix(A), b, W=W, tol=1e-8, maxiter=200, ell=0)
        r = b - A @ res.x
        np.testing.assert_allclose(
            np.asarray(pt.basis_dot(W, r)), 0.0, atol=1e-6 * float(pt.tree_norm(b))
        )

    def test_exact_topk_deflation_hits_kappa_eff(self):
        # §2.1: deflating the top-k eigenvectors → κ_eff = λ_{n-k}/λ_1.
        # CG iteration count scales ~ sqrt(κ); expect a clear drop.
        n, k = 96, 8
        rng = np.random.default_rng(7)
        q, _ = np.linalg.qr(rng.standard_normal((n, n)))
        eigs = np.concatenate([np.linspace(1.0, 10.0, n - k), np.logspace(3, 5, k)])
        A = jnp.asarray((q * eigs) @ q.T)
        b = jnp.asarray(rng.standard_normal(n))

        plain = cg(from_matrix(A), b, tol=1e-10, maxiter=3000)
        W = pt.basis_from_vectors([jnp.asarray(q[:, n - k + i]) for i in range(k)])
        defl = defcg(from_matrix(A), b, W=W, tol=1e-10, maxiter=3000)

        x_direct = jnp.linalg.solve(A, b)
        np.testing.assert_allclose(defl.x, x_direct, rtol=1e-5, atol=1e-6)
        # κ drops 1e5/1 → 10; iterations should drop by at least 2x.
        assert int(defl.info.iterations) * 2 < int(plain.info.iterations)

    def test_warm_start_projection(self):
        # Line 3 of Alg 1: x0 correction zeroes Wᵀr0 (checked indirectly:
        # solving the same system twice with recycling is near-free).
        A, b, _, _ = _solve_setup(n=64, cond=1e4)
        mgr = RecycleManager(k=8, ell=16, tol=1e-10, maxiter=1000)
        first = mgr.solve(from_matrix(A), b)
        second = mgr.solve(from_matrix(A), b, x0=first.x)
        assert int(second.info.iterations) <= 2

    def test_seeded_basis_without_aw_reuse_aw(self):
        """Regression: seed(W) with no AW + reuse_aw=True on the first
        solve must compute AW (nothing to reuse yet), not crash raveling
        None in the refresh."""
        A, b, eigs, q = _solve_setup(n=96, cond=1e5, seed=17)
        k = 8
        W = pt.basis_from_vectors(
            [jnp.asarray(q[:, -(i + 1)]) for i in range(k)]
        )
        mgr = RecycleManager(k=k, ell=12, tol=1e-8, maxiter=3000)
        mgr.seed(W)  # a-priori seeding, no A-products
        res = mgr.solve(from_matrix(A), b, reuse_aw=True)
        assert bool(res.info.converged)
        assert mgr.AW is not None
        # exact top-k deflation: clearly beats fresh CG, and the k AW
        # matvecs are charged
        fresh = cg(from_matrix(A), b, tol=1e-8, maxiter=3000)
        assert int(res.info.iterations) < int(fresh.info.iterations)
        assert int(res.info.matvecs) == int(res.info.iterations) + 1 + k

    def test_zero_iteration_solve_keeps_basis_state(self):
        """Regression: a 0-iteration solve (exact x0) records nothing and
        must leave the manager's basis untouched — in particular a None
        basis must not become a phantom all-zero basis that gets charged
        k refresh matvecs on every later system."""
        A, b, _, _ = _solve_setup(n=48, cond=1e2)
        x_exact = jnp.linalg.solve(A, b)
        mgr = RecycleManager(k=4, ell=8, tol=1e-6, maxiter=500)
        res = mgr.solve(from_matrix(A), b, x0=x_exact)
        assert int(res.info.iterations) == 0
        assert mgr.W is None
        # the next solve runs as a plain first system: no refresh charge
        res2 = mgr.solve(from_matrix(A), b)
        plain = defcg(from_matrix(A), b, ell=8, tol=1e-6, maxiter=500)
        assert int(res2.info.matvecs) == int(plain.info.matvecs)
        assert mgr.W is not None  # and recycling is bootstrapped now

    def test_recycling_drifting_sequence(self):
        # The paper's setting: a slowly drifting SPD sequence — recycling
        # must reduce iterations vs fresh CG on the later systems.
        n, k, ell = 96, 8, 12
        rng = np.random.default_rng(11)
        q, _ = np.linalg.qr(rng.standard_normal((n, n)))
        eigs = np.concatenate(
            [np.linspace(1.0, 5.0, n - k), np.logspace(3.0, 4.5, k)]
        )
        base = (q * eigs) @ q.T
        mgr = RecycleManager(k=k, ell=ell, tol=1e-8, maxiter=5000)
        cg_iters, defcg_iters = [], []
        x_prev = None
        for i in range(5):
            pert = rng.standard_normal((n, n)) * 0.01
            Ai = jnp.asarray(base + pert @ pert.T)  # SPD drift
            bi = jnp.asarray(rng.standard_normal(n))
            cg_iters.append(int(cg(from_matrix(Ai), bi, tol=1e-8, maxiter=5000).info.iterations))
            res = mgr.solve(from_matrix(Ai), bi, x0=x_prev)
            x_prev = res.x
            defcg_iters.append(int(res.info.iterations))
            np.testing.assert_allclose(
                Ai @ res.x, bi, rtol=0, atol=1e-7 * np.linalg.norm(bi)
            )
        # After the first system, recycling should clearly win (paper ~25%).
        assert sum(defcg_iters[1:]) < 0.85 * sum(cg_iters[1:])

    def test_breakdown_flag_on_indefinite(self):
        A = jnp.diag(jnp.array([1.0, -1.0, 2.0]))
        b = jnp.array([1.0, 1.0, 1.0])
        res = cg(from_matrix(A), b, tol=1e-12, maxiter=50)
        assert bool(res.info.breakdown) or not bool(res.info.converged)

    def test_fallback_matvec_accounting(self):
        """Regression: when a poisoned basis forces the clean re-solve,
        the reported matvecs must be the TRUE total — refresh + failed
        attempt + fallback — not just the fallback with the discarded
        basis's refresh cost stapled on."""
        A, b, _, _ = _solve_setup(n=64, cond=1e4)
        k, ell, maxiter = 4, 8, 6  # maxiter too small to converge
        W = random_orthonormal_basis(jax.random.PRNGKey(0), b, k)

        mgr = RecycleManager(k=k, ell=ell, tol=1e-10, maxiter=maxiter)
        mgr.seed(W)
        res = mgr.solve(from_matrix(A), b)
        assert not bool(res.info.converged)  # both attempts hit maxiter
        assert mgr.W is not None  # fallback still re-bootstrapped a basis

        # Reference costs of the two attempts, run in isolation.
        AW = pt.basis_map_vectors(from_matrix(A), W)
        failed = defcg(
            from_matrix(A), b, W=W, AW=AW, ell=ell,
            tol=1e-10, maxiter=maxiter, waw_jitter=mgr.waw_jitter,
        )
        fallback = defcg(
            from_matrix(A), b, ell=ell, tol=1e-10, maxiter=maxiter
        )
        expected = (
            k  # refresh of the (discarded) basis — it was still computed
            + int(failed.info.matvecs)
            + int(fallback.info.matvecs)
        )
        assert int(res.info.matvecs) == expected


class TestHarmonicRitz:
    def test_ritz_values_approximate_extremal_eigs(self):
        n, ell, k = 128, 24, 4
        A, b, eigs, _ = _solve_setup(n=n, cond=1e4, seed=13)
        res = defcg(from_matrix(A), b, tol=1e-12, maxiter=500, ell=ell)
        m = int(res.recycle.stored)
        Z = pt.basis_slice(res.recycle.P, m)
        AZ = pt.basis_slice(res.recycle.AP, m)
        _, _, theta = harmonic_ritz(Z, AZ, k, select="largest")
        # Largest harmonic Ritz value should approach λ_max within a few %.
        assert np.max(np.asarray(theta)) > 0.5 * eigs[-1]

    def test_extracted_basis_deflates(self):
        # End-to-end: Ritz basis from run 1 must speed up run 2 (same A).
        A, b, _, _ = _solve_setup(n=96, cond=1e5, seed=17)
        first = defcg(from_matrix(A), b, tol=1e-8, maxiter=3000, ell=16)
        m = int(first.recycle.stored)
        Z = pt.basis_slice(first.recycle.P, m)
        AZ = pt.basis_slice(first.recycle.AP, m)
        W, AW, _ = harmonic_ritz(Z, AZ, 8)
        rng = np.random.default_rng(23)
        b2 = jnp.asarray(rng.standard_normal(96))
        fresh = cg(from_matrix(A), b2, tol=1e-8, maxiter=3000)
        defl = defcg(from_matrix(A), b2, W=W, AW=AW, tol=1e-8, maxiter=3000)
        assert int(defl.info.iterations) < int(fresh.info.iterations)
        np.testing.assert_allclose(
            A @ defl.x, b2, rtol=0, atol=1e-6 * np.linalg.norm(b2)
        )


class TestNystrom:
    def test_sketch_finds_top_eigenspace(self):
        A, _, eigs, q = _solve_setup(n=64, cond=1e4, seed=29)
        U, lam = randomized_nystrom(
            from_matrix(A), jnp.zeros(64), rank=6, key=jax.random.PRNGKey(0)
        )
        np.testing.assert_allclose(lam[0], eigs[-1], rtol=0.05)

    def test_nystrom_pcg(self):
        A, b, eigs, _ = _solve_setup(n=96, cond=1e5, seed=31)
        U, lam = randomized_nystrom(
            from_matrix(A), jnp.zeros(96), rank=10, key=jax.random.PRNGKey(1)
        )
        M = nystrom_preconditioner(U, lam, sigma=1.0)
        plain = cg(from_matrix(A), b, tol=1e-8, maxiter=3000)
        pre = cg(from_matrix(A), b, tol=1e-8, maxiter=3000, M=M)
        assert int(pre.info.iterations) < int(plain.info.iterations)
        np.testing.assert_allclose(
            A @ pre.x, b, rtol=0, atol=1e-6 * np.linalg.norm(b)
        )


class TestProperties:
    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(8, 48),
        cond=st.floats(1e1, 1e6),
        seed=st.integers(0, 2**16),
    )
    def test_cg_solves_any_spd(self, n, cond, seed):
        rng = np.random.default_rng(seed)
        A, _, _ = make_spd(n, cond, rng)
        b = rng.standard_normal(n)
        res = cg(from_matrix(jnp.asarray(A)), jnp.asarray(b), tol=1e-10, maxiter=20 * n)
        np.testing.assert_allclose(
            A @ np.asarray(res.x), b, atol=1e-7 * max(1.0, np.linalg.norm(b))
        )

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(12, 40),
        k=st.integers(1, 6),
        seed=st.integers(0, 2**16),
    )
    def test_defcg_invariants(self, n, k, seed):
        """def-CG with a random-orthonormal W still solves the system and
        keeps Wᵀr ≈ 0 — deflation is *correct* for any full-rank W."""
        rng = np.random.default_rng(seed)
        A, _, _ = make_spd(n, 1e4, rng)
        b = rng.standard_normal(n)
        W = random_orthonormal_basis(
            jax.random.PRNGKey(seed % 97), jnp.zeros(n), k
        )
        res = defcg(
            from_matrix(jnp.asarray(A)), jnp.asarray(b), W=W, tol=1e-10, maxiter=20 * n
        )
        x = np.asarray(res.x)
        np.testing.assert_allclose(
            A @ x, b, atol=1e-6 * max(1.0, np.linalg.norm(b))
        )
        r = jnp.asarray(b - A @ x)
        np.testing.assert_allclose(
            np.asarray(pt.basis_dot(W, r)), 0.0, atol=1e-6 * max(1.0, np.linalg.norm(b))
        )

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16))
    def test_materialize_ggn_is_symmetric(self, seed):
        """GGN operator must be symmetric PSD (+damping) — def-CG's precondition."""
        from repro.core import GGNOperator

        rng = np.random.default_rng(seed)
        Wm = jnp.asarray(rng.standard_normal((5, 3)))
        x = jnp.asarray(rng.standard_normal((7, 3)))

        def model(params):
            return x @ (params["w"].T @ Wm.T @ Wm @ params["w"])  # nonlinear in params

        def loss_hvp(outputs, t):
            return 2.0 * t  # squared loss Hessian = 2I

        params = {"w": jnp.asarray(rng.standard_normal((3, 3)))}
        op = GGNOperator(model, loss_hvp, params, damping=jnp.float64(0.1))
        dense = materialize(op, params)
        np.testing.assert_allclose(dense, dense.T, atol=1e-8)
        eigs = np.linalg.eigvalsh(np.asarray(dense))
        assert eigs.min() >= 0.0999  # PSD + damping
