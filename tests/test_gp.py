"""GP-classification substrate tests — the paper's workload in miniature.

Validates the three Table-1 columns agree (Cholesky is exact; CG/def-CG
track it to solver tolerance), that def-CG recycling reduces iterations
across the Newton sequence (the paper's headline claim), and that the
inducing-point baseline shows the cost/precision gap of Fig. 4.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RecycleManager
from repro.data import make_infinite_digits
from repro.gp import RBFKernel, laplace_gpc, subset_gpc


N = 220
KERNEL = RBFKernel(theta=3.0, lengthscale=3.0)


@pytest.fixture(scope="module")
def digits():
    x, y = make_infinite_digits(N, seed=7)
    return jnp.asarray(x, jnp.float64), jnp.asarray(y, jnp.float64)


@pytest.fixture(scope="module")
def solutions(digits):
    x, y = digits
    chol = laplace_gpc(x, y, KERNEL, solver="cholesky", newton_tol=1e-2)
    cg_r = laplace_gpc(x, y, KERNEL, solver="cg", solver_tol=1e-6, newton_tol=1e-2)
    mgr = RecycleManager(k=8, ell=12, tol=1e-6, maxiter=2000)
    def_r = laplace_gpc(
        x, y, KERNEL, solver="defcg", recycle=mgr,
        solver_tol=1e-6, newton_tol=1e-2,
    )
    return chol, cg_r, def_r


class TestLaplaceGPC:
    def test_newton_monotone(self, solutions):
        chol, _, _ = solutions
        psi = chol.trace.psi
        assert all(b >= a - 1e-6 for a, b in zip(psi, psi[1:]))

    def test_iterative_matches_cholesky(self, solutions):
        chol, cg_r, def_r = solutions
        # Table-1 agreement: same final log p(y|f) to solver tolerance.
        assert abs(cg_r.logp - chol.logp) / abs(chol.logp) < 1e-4
        assert abs(def_r.logp - chol.logp) / abs(chol.logp) < 1e-4
        np.testing.assert_allclose(
            np.asarray(def_r.f), np.asarray(chol.f), rtol=0, atol=5e-3
        )

    def test_defcg_saves_iterations(self, solutions):
        # Paper Fig 2: after the first system, def-CG uses fewer CG
        # iterations than plain CG.
        _, cg_r, def_r = solutions
        cg_total = sum(cg_r.trace.solver_iterations[1:])
        def_total = sum(def_r.trace.solver_iterations[1:])
        assert def_total < cg_total

    def test_training_accuracy(self, digits, solutions):
        x, y = digits
        chol, _, _ = solutions
        acc = float(jnp.mean((jnp.sign(chol.f) == y)))
        assert acc > 0.95

    def test_classes_separate(self, digits, solutions):
        x, y = digits
        chol, _, _ = solutions
        mean_pos = float(jnp.mean(chol.f[y > 0]))
        mean_neg = float(jnp.mean(chol.f[y < 0]))
        assert mean_pos > 0 > mean_neg


class TestInducingBaseline:
    def test_subset_worse_than_full(self, digits, solutions):
        # Fig 4: a small subset is fast but leaves a persistent logp gap.
        x, y = digits
        chol, _, _ = solutions
        import jax

        sub = subset_gpc(x, y, KERNEL, m=N // 8, key=jax.random.PRNGKey(0))
        rel_err = abs(sub.logp_full - chol.logp) / abs(chol.logp)
        assert rel_err > 1e-4  # finite, uncorrected approximation error
        # and bigger subsets should shrink the gap
        sub2 = subset_gpc(x, y, KERNEL, m=N // 2, key=jax.random.PRNGKey(0))
        rel_err2 = abs(sub2.logp_full - chol.logp) / abs(chol.logp)
        assert rel_err2 < rel_err
