"""Fused CG iteration kernels (cg_fused) + flat-engine solver equivalence.

Three layers of checks:

  1. oracle parity: ``fused_cg_update`` / ``fused_deflate_direction`` in
     interpret and chunked mode vs the pure-jnp oracles in ``ref.py``, at
     tile-aligned and non-multiple-of-block shapes (the acceptance bar);
  2. flat-engine equivalence: ``defcg`` (flat inner loop) vs a direct
     transcription of the seed's pytree def-CG loop, to 1e-10 on an RBF
     GP Newton system, including the recorded ``(P, AP)`` Krylov data and
     the harmonic-Ritz extraction it feeds;
  3. structure invariance: the same system solved with a flat ``(n,)``
     vector and with a dict-structured pytree must give the same numbers
     (the pack/unpack shim is exact).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.scipy.linalg import cho_factor, cho_solve

from repro.core import KernelSystemOperator, defcg, from_matrix, harmonic_ritz
from repro.core import pytree as pt
from repro.kernels import ops, ref
from tests.conftest import make_spd

F32 = jnp.float32


# ---------------------------------------------------------------------------
# 1. oracle parity
# ---------------------------------------------------------------------------

# (n, k, block): default shape, non-multiple-of-block n, tiny n, k=1 edge
PARITY_CASES = [
    (4096, 8, 4096),
    (1000, 5, 1024),
    (130, 3, 4096),
    (257, 1, 1024),
]


class TestFusedCGUpdate:
    @pytest.mark.parametrize("impl", ["interpret", "chunked"])
    @pytest.mark.parametrize("case", PARITY_CASES)
    def test_matches_oracle(self, impl, case):
        n, k, block = case
        rng = np.random.default_rng(n + k)
        x, r, p, ap = (
            jnp.asarray(rng.standard_normal(n), F32) for _ in range(4)
        )
        aw = jnp.asarray(rng.standard_normal((k, n)), F32)
        alpha = 0.37
        want = ref.fused_cg_update(x, r, p, ap, alpha, aw)
        got = ops.fused_cg_update(
            x, r, p, ap, alpha, aw, impl=impl, block=block
        )
        for g, w, name in zip(got, want, ("x", "r", "rr", "awr")):
            scale = max(1.0, float(jnp.max(jnp.abs(w))))
            np.testing.assert_allclose(
                np.asarray(g) / scale,
                np.asarray(w) / scale,
                rtol=2e-4,
                atol=2e-4,
                err_msg=f"{impl} {name} n={n} k={k}",
            )

    @pytest.mark.parametrize("impl", ["interpret", "chunked"])
    def test_no_deflation_variant(self, impl):
        rng = np.random.default_rng(3)
        n = 513  # not a multiple of anything relevant
        x, r, p, ap = (
            jnp.asarray(rng.standard_normal(n), F32) for _ in range(4)
        )
        want = ref.fused_cg_update(x, r, p, ap, -1.25)
        got = ops.fused_cg_update(x, r, p, ap, -1.25, impl=impl, block=1024)
        assert got[3] is None
        np.testing.assert_allclose(
            np.asarray(got[1]), np.asarray(want[1]), rtol=2e-4, atol=2e-4
        )
        np.testing.assert_allclose(
            float(got[2]), float(want[2]), rtol=2e-4
        )


class TestFusedRzReduce:
    """Oracle parity for the preconditioned-iteration reduction pass."""

    @pytest.mark.parametrize("impl", ["interpret", "chunked"])
    @pytest.mark.parametrize("case", PARITY_CASES)
    def test_matches_oracle(self, impl, case):
        n, k, block = case
        rng = np.random.default_rng(2 * n + k)
        r, z = (jnp.asarray(rng.standard_normal(n), F32) for _ in range(2))
        aw = jnp.asarray(rng.standard_normal((k, n)), F32)
        want = ref.fused_rz_reduce(r, z, aw)
        got = ops.fused_rz_reduce(r, z, aw, impl=impl, block=block)
        np.testing.assert_allclose(
            float(got[0]), float(want[0]), rtol=2e-4,
            err_msg=f"{impl} rz n={n}",
        )
        scale = max(1.0, float(jnp.max(jnp.abs(want[1]))))
        np.testing.assert_allclose(
            np.asarray(got[1]) / scale, np.asarray(want[1]) / scale,
            rtol=2e-4, atol=2e-4, err_msg=f"{impl} awz n={n} k={k}",
        )

    @pytest.mark.parametrize("impl", ["interpret", "chunked"])
    def test_no_deflation_variant(self, impl):
        rng = np.random.default_rng(5)
        n = 513
        r, z = (jnp.asarray(rng.standard_normal(n), F32) for _ in range(2))
        want = ref.fused_rz_reduce(r, z)
        got = ops.fused_rz_reduce(r, z, impl=impl, block=1024)
        assert got[1] is None
        np.testing.assert_allclose(float(got[0]), float(want[0]), rtol=2e-4)


class TestFusedDeflateDirection:
    @pytest.mark.parametrize("impl", ["interpret", "chunked"])
    @pytest.mark.parametrize("case", PARITY_CASES)
    def test_matches_oracle_with_buffers(self, impl, case):
        n, k, block = case
        m = 2 * k + 1
        rng = np.random.default_rng(n - k)
        r, p, ap = (jnp.asarray(rng.standard_normal(n), F32) for _ in range(3))
        w = jnp.asarray(rng.standard_normal((k, n)), F32)
        mu = jnp.asarray(rng.standard_normal(k), F32)
        p_buf = jnp.zeros((m, n), F32)
        ap_buf = jnp.full((m, n), -1.0, F32)
        idx = jnp.int32(k)  # interior row
        want = ref.fused_deflate_direction(
            r, p, 0.9, w, mu, ap, idx, p_buf, ap_buf
        )
        got = ops.fused_deflate_direction(
            r, p, 0.9, w, mu, ap, idx, p_buf, ap_buf, impl=impl, block=block
        )
        for g, w_, name in zip(got, want, ("p", "p_buf", "ap_buf")):
            np.testing.assert_allclose(
                np.asarray(g),
                np.asarray(w_),
                rtol=2e-4,
                atol=2e-4,
                err_msg=f"{impl} {name} n={n} k={k}",
            )

    @pytest.mark.parametrize("impl", ["interpret", "chunked"])
    def test_no_buffer_variant(self, impl):
        rng = np.random.default_rng(9)
        n, k = 777, 4
        r, p = (jnp.asarray(rng.standard_normal(n), F32) for _ in range(2))
        w = jnp.asarray(rng.standard_normal((k, n)), F32)
        mu = jnp.asarray(rng.standard_normal(k), F32)
        want = ref.fused_deflate_direction(r, p, 0.3, w, mu)
        got = ops.fused_deflate_direction(
            r, p, 0.3, w, mu, impl=impl, block=1024
        )
        np.testing.assert_allclose(
            np.asarray(got[0]), np.asarray(want[0]), rtol=2e-4, atol=2e-4
        )
        assert got[1] is None and got[2] is None


class TestSelfGram:
    """Oracle parity for the stacked-gram pass (harmonic Ritz's one GEMM)."""

    # (m, n, block): aligned, ragged-n, tiny, m not multiple of 8
    CASES = [(16, 4096, 2048), (24, 1000, 512), (6, 130, 2048), (13, 257, 128)]

    @pytest.mark.parametrize("impl", ["interpret", "chunked"])
    @pytest.mark.parametrize("case", CASES)
    def test_matches_oracle(self, impl, case):
        m, n, block = case
        rng = np.random.default_rng(m * n)
        s = jnp.asarray(rng.standard_normal((m, n)), F32)
        want = ref.self_gram(s)
        got = ops.self_gram(s, impl=impl, block=block)
        scale = max(1.0, float(jnp.max(jnp.abs(want))))
        np.testing.assert_allclose(
            np.asarray(got) / scale, np.asarray(want) / scale,
            rtol=2e-4, atol=2e-4, err_msg=f"{impl} m={m} n={n}",
        )

    def test_chunked_f64_is_exact_blocked_sum(self):
        """The chunked path must keep f64 accumulation (the extraction's
        1e-10 parity depends on it) — compare against the single GEMM."""
        rng = np.random.default_rng(7)
        s = jnp.asarray(rng.standard_normal((10, 5000)))
        got = ops.self_gram(s, impl="chunked", block=512)
        want = ref.self_gram(s)
        assert got.dtype == jnp.float64
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-13, atol=1e-13
        )


class TestRecombineBlocks:
    """Oracle parity for the stacked two-block recombination GEMM
    (``[uᵀZ; uᵀAZ]`` — the strategies' zero-matvec windowed refresh)."""

    # (m, k, n, block): aligned, ragged everything, k > m pad edge, n < block
    CASES = [(16, 8, 4096, 2048), (20, 6, 1000, 512), (5, 3, 130, 2048),
             (13, 13, 257, 128)]

    @pytest.mark.parametrize("impl", ["interpret", "chunked"])
    @pytest.mark.parametrize("case", CASES)
    def test_matches_oracle(self, impl, case):
        m, k, n, block = case
        rng = np.random.default_rng(m * n + k)
        s = jnp.asarray(rng.standard_normal((2 * m, n)), F32)
        u = jnp.asarray(rng.standard_normal((m, k)), F32)
        want = ref.recombine_blocks(s, u)
        got = ops.recombine_blocks(s, u, impl=impl, block=block)
        assert got.shape == (2 * k, n)
        scale = max(1.0, float(jnp.max(jnp.abs(want))))
        np.testing.assert_allclose(
            np.asarray(got) / scale, np.asarray(want) / scale,
            rtol=2e-4, atol=2e-4, err_msg=f"{impl} m={m} k={k} n={n}",
        )

    def test_chunked_f64_is_exact(self):
        """Chunked must keep f64 accumulation (extraction parity at 1e-10
        rides on W' = uᵀZ being exact in x64 mode)."""
        rng = np.random.default_rng(11)
        s = jnp.asarray(rng.standard_normal((24, 5000)))
        u = jnp.asarray(rng.standard_normal((12, 5)))
        got = ops.recombine_blocks(s, u, impl="chunked", block=512)
        want = ref.recombine_blocks(s, u)
        assert got.dtype == jnp.float64
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-12
        )


# ---------------------------------------------------------------------------
# 2. flat engine vs the seed pytree loop, on an RBF GP Newton system
# ---------------------------------------------------------------------------


def _seed_defcg(A, b, W, AW, *, ell, tol, maxiter):
    """Direct transcription of the seed's pytree def-CG loop (Alg. 1 with
    ring-buffer recording) — the reference the flat engine must match."""
    k = pt.basis_size(W)
    waw = pt.gram(W, AW)
    waw = 0.5 * (waw + waw.T)
    waw_cho = cho_factor(waw)
    waw_inv = cho_solve(waw_cho, jnp.eye(k, dtype=waw.dtype))

    x = pt.tree_zeros_like(b)
    r = pt.tree_sub(b, A(x))
    c = cho_solve(waw_cho, pt.basis_dot(W, r))
    x = pt.tree_add(x, pt.basis_combine(W, c))
    r = pt.tree_sub(r, pt.basis_combine(AW, c))
    mu = cho_solve(waw_cho, pt.basis_dot(AW, r))
    p = pt.tree_sub(r, pt.basis_combine(W, mu))

    threshold = tol * float(pt.tree_norm(b))
    p_buf = pt.basis_zeros(b, ell)
    ap_buf = pt.basis_zeros(b, ell)
    rs = pt.tree_dot(r, r)
    j = 0
    while j < maxiter and float(pt.tree_norm(r)) > threshold:
        ap = A(p)
        d = pt.tree_dot(p, ap)
        alpha = rs / d
        if j < ell:
            p_buf = pt.basis_set(p_buf, p, j)
            ap_buf = pt.basis_set(ap_buf, ap, j)
        x = pt.tree_axpy(alpha, p, x)
        r = pt.tree_axpy(-alpha, ap, r)
        rs_new = pt.tree_dot(r, r)
        beta = rs_new / rs
        mu = waw_inv @ pt.basis_dot(AW, r)
        p = pt.tree_axpy(beta, p, pt.tree_sub(r, pt.basis_combine(W, mu)))
        rs = rs_new
        j += 1
    return x, p_buf, ap_buf, j


def _gp_newton_system(n=120, d=4, seed=0):
    """A = I + H½ K H½ for an RBF Gram matrix — the paper's Eq. 10."""
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.standard_normal((n, d)))
    kmat = ref.rbf_gram(xs, 1.5, 1.2)
    sqrt_h = jnp.asarray(rng.uniform(0.05, 0.5, n))
    a_op = KernelSystemOperator(lambda v: kmat @ v, sqrt_h)
    b = jnp.asarray(rng.standard_normal(n))
    return a_op, b, kmat, sqrt_h


class TestFlatEngineEquivalence:
    def test_matches_seed_pytree_loop_to_1e10(self):
        n, k, ell = 120, 6, 12
        a_op, b, _, _ = _gp_newton_system(n=n)
        W = jnp.asarray(
            np.linalg.qr(
                np.random.default_rng(7).standard_normal((n, k))
            )[0].T
        )
        AW = pt.basis_map_vectors(a_op, W)

        want_x, want_p, want_ap, want_j = _seed_defcg(
            a_op, b, W, AW, ell=ell, tol=1e-12, maxiter=400
        )
        # waw_jitter=0.0 explicitly: the seed loop factorizes WᵀAW without
        # jitter, and this test is a strict transcription-equivalence check
        # (the shared production default is DEFAULT_WAW_JITTER = 1e-12).
        res = defcg(
            a_op, b, W=W, AW=AW, ell=ell, tol=1e-12, maxiter=400,
            waw_jitter=0.0,
        )

        assert int(res.info.iterations) == want_j
        np.testing.assert_allclose(
            np.asarray(res.x), np.asarray(want_x), rtol=1e-10, atol=1e-10
        )
        np.testing.assert_allclose(
            np.asarray(res.recycle.P), np.asarray(want_p),
            rtol=1e-10, atol=1e-10,
        )
        np.testing.assert_allclose(
            np.asarray(res.recycle.AP), np.asarray(want_ap),
            rtol=1e-10, atol=1e-10,
        )
        assert int(res.recycle.stored) == min(want_j, ell)

        # ... and the recycled harmonic-Ritz extraction agrees too.
        m = int(res.recycle.stored)
        _, _, theta_flat = harmonic_ritz(
            pt.basis_slice(res.recycle.P, m),
            pt.basis_slice(res.recycle.AP, m),
            k,
        )
        _, _, theta_seed = harmonic_ritz(
            pt.basis_slice(want_p, m), pt.basis_slice(want_ap, m), k
        )
        np.testing.assert_allclose(
            np.sort(np.asarray(theta_flat)),
            np.sort(np.asarray(theta_seed)),
            rtol=1e-8,
        )

    def test_structure_invariance(self):
        """Flat (n,) and dict-pytree runs of the same system must agree."""
        # Fixed iteration count (tol=0) so both runs execute identical
        # steps: the inner loop is structure-blind, and the only noise is
        # the pytree-space *setup* (gram, μ0), which reduces per leaf.
        n, k, ell, iters = 96, 5, 10, 40
        rng = np.random.default_rng(23)
        amat, _, _ = make_spd(n, 1e2, rng)
        amat = jnp.asarray(amat)
        b = jnp.asarray(rng.standard_normal(n))
        wq = jnp.asarray(np.linalg.qr(rng.standard_normal((n, k)))[0].T)

        flat = defcg(
            from_matrix(amat), b, W=wq, ell=ell, tol=0.0, maxiter=iters
        )

        h = n // 2

        def tree_matvec(tree):
            v = jnp.concatenate([tree["a"].ravel(), tree["b"]])
            out = amat @ v
            return {"a": out[:h].reshape(2, -1), "b": out[h:]}

        b_tree = {"a": b[:h].reshape(2, -1), "b": b[h:]}
        w_tree = {"a": wq[:, :h].reshape(k, 2, -1), "b": wq[:, h:]}
        tree = defcg(
            tree_matvec, b_tree, W=w_tree, ell=ell, tol=0.0, maxiter=iters
        )

        assert int(flat.info.iterations) == int(tree.info.iterations) == iters
        x_tree_flat = jnp.concatenate(
            [tree.x["a"].ravel(), tree.x["b"]]
        )
        np.testing.assert_allclose(
            np.asarray(flat.x), np.asarray(x_tree_flat), rtol=1e-10, atol=1e-10
        )
        # recycle bases carry the vector's structure, values identical
        assert tree.recycle.P["a"].shape == (ell,) + b_tree["a"].shape
        p_tree_flat = jnp.concatenate(
            [
                tree.recycle.P["a"].reshape(ell, -1),
                tree.recycle.P["b"],
            ],
            axis=1,
        )
        np.testing.assert_allclose(
            np.asarray(flat.recycle.P),
            np.asarray(p_tree_flat),
            rtol=1e-10,
            atol=1e-10,
        )

    def test_recording_window_semantics(self):
        """stored == min(iterations, ell); rows past convergence stay 0."""
        a_op, b, _, _ = _gp_newton_system(n=60)
        res = defcg(a_op, b, tol=1e-13, maxiter=300, ell=50)
        j = int(res.info.iterations)
        stored = int(res.recycle.stored)
        assert stored == min(j, 50)
        tail = np.asarray(res.recycle.P)[stored:]
        np.testing.assert_array_equal(tail, 0.0)

    def test_maxiter_shorter_than_window(self):
        a_op, b, _, _ = _gp_newton_system(n=60)
        res = defcg(a_op, b, tol=0.0, maxiter=4, ell=8)
        assert int(res.info.iterations) == 4
        assert int(res.recycle.stored) == 4
        assert np.all(np.asarray(res.recycle.P)[:4].any(axis=1))
        np.testing.assert_array_equal(np.asarray(res.recycle.P)[4:], 0.0)
