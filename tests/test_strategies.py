"""Strategy-layer tests (ISSUE 5 tentpole).

Five layers of checks:

  1. transition parity: the ``HarmonicRitz`` strategy (recombination GEMM
     included) must reproduce the pytree ``harmonic_ritz`` oracle at
     1e-10 — the refactor moved the extraction, it must not move the
     numbers;
  2. window handoff: the recorded ``(P, AP, α, β, stored)`` must satisfy
     the CG recurrences exactly (the solver→strategy contract is data,
     not vibes), and ``aw_used`` must surface exactly when the in-solve
     guard is armed;
  3. ``WindowedRecombine``: the paper's O(n²(ℓ+1)k) matvec accounting on
     the fig2/table1 GP Newton sequence — ``matvecs = iterations + 2``
     plus ``k`` ONLY on guard-triggered refreshes, per-system iterations
     within ±1 of the ``HarmonicRitz`` path — and the pure zero-refresh
     accounting on a multiple-RHS (no-drift) sequence;
  4. ``MGeometryHarmonic``: extraction in the M⁻¹ geometry validated
     against a dense M^{1/2}-similarity reference (plain harmonic Ritz of
     ``M^{-1/2} A M^{-1/2}`` on transformed bases, mapped back);
  5. the sequence divergence guard: a deliberately poisoned stale seed
     basis must yield correct solutions (fallback re-solve, honest matvec
     totals) instead of the silent garbage the device path used to
     return.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HarmonicRitz,
    KernelSystemOperator,
    MGeometryHarmonic,
    SolveSpec,
    WindowedRecombine,
    cholesky_solve,
    defcg,
    from_matrix,
    harmonic_ritz,
    jacobi,
    solve,
    solve_batch,
    solve_sequence,
)
from repro.core import pytree as pt
from repro.core.strategies import extract_next_basis_core
from tests.conftest import make_spd


@functools.lru_cache(maxsize=1)
def _gp_newton_sequence(n=160, num=6):
    """A genuine fig2-style GP Newton sequence: per-iteration ``(H½, b)``
    from Newton's method on the Laplace mode (exact inner solves), plus
    the dense K for building operators.  Cached — several tests share it.
    """
    from repro.data import make_infinite_digits
    from repro.gp import RBFKernel
    from repro.gp.laplace import logistic_quantities

    x, y = make_infinite_digits(n, seed=0, noise=0.1)
    x = jnp.asarray(x, jnp.float64)
    y = jnp.asarray(y, jnp.float64)
    kernel = RBFKernel(theta=3.0, lengthscale=3.0)
    kd = jnp.asarray(kernel.gram(x))
    k_mv = lambda v: kd @ v  # noqa: E731 — stable closure

    f = jnp.zeros(n)
    shs, bs = [], []
    for _ in range(num):
        _, grad, hdiag = logistic_quantities(f, y)
        sh = jnp.sqrt(hdiag)
        bg = hdiag * f + grad
        b = sh * k_mv(bg)
        shs.append(sh)
        bs.append(b)
        amat = jnp.eye(n) + sh[:, None] * kd * sh[None, :]
        xsol = cholesky_solve(amat, b)
        f = k_mv(bg - sh * xsol)
    return k_mv, jnp.stack(shs), jnp.stack(bs)


def _seq_residuals(k_mv, shs, bs, xs):
    """Relative residuals of stacked solutions under A = I + H½KH½."""
    out = []
    for i in range(bs.shape[0]):
        ax = xs[i] + shs[i] * k_mv(shs[i] * xs[i])
        out.append(
            float(jnp.linalg.norm(bs[i] - ax) / jnp.linalg.norm(bs[i]))
        )
    return out


def _recorded_window(n=120, k=6, ell=14, seed=0):
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    eigs = np.concatenate(
        [np.linspace(1.0, 5.0, n - k), np.logspace(3, 4.5, k)]
    )
    A = jnp.asarray((q * eigs) @ q.T)
    b = jnp.asarray(rng.standard_normal(n))
    res = defcg(
        from_matrix(A), b, tol=1e-12, maxiter=20 * n, ell=ell,
        flat_recycle=True,
    )
    return res, A, b


class TestTransitionParity:
    def test_harmonic_strategy_matches_pytree_oracle(self):
        """HarmonicRitz().transition == the pytree oracle at 1e-10 —
        recombination-GEMM extraction must not move the numbers."""
        res, _, _ = _recorded_window()
        k = 6
        rec = res.recycle
        W_s, AW_s, th_s, drift = HarmonicRitz().transition(
            None, None, rec, k=k
        )
        Wp, AWp, thp = harmonic_ritz(rec.P, rec.AP, k)
        np.testing.assert_allclose(
            np.asarray(th_s), np.asarray(thp), rtol=1e-10
        )
        Wp_flat = pt.ravel_basis(Wp)
        signs = jnp.sign(jnp.sum(Wp_flat * W_s, axis=1))
        np.testing.assert_allclose(
            np.asarray(W_s * signs[:, None]), np.asarray(Wp_flat),
            rtol=1e-8, atol=1e-10,
        )
        np.testing.assert_allclose(
            np.asarray(AW_s * signs[:, None]),
            np.asarray(pt.ravel_basis(AWp)),
            rtol=1e-8, atol=1e-8,
        )
        assert float(drift) == 0.0  # HarmonicRitz does not guard

    def test_exact_transition_gram_is_symmetric(self):
        """The drift proxy on EXACT window data is rounding-level — the
        baseline the WindowedRecombine guard discriminates against."""
        res, _, _ = _recorded_window(seed=3)
        rec = res.recycle
        _, _, _, fasym = extract_next_basis_core(
            None, None, rec.P, rec.AP, rec.stored, 6
        )
        assert float(fasym) < 1e-12

    def test_stale_transition_gram_asymmetry_measures_drift(self):
        """With a stale AW block mixed into the window, the F-gram
        asymmetry is a genuine ‖AW − A·W‖ signal (orders above the exact
        baseline), read off a gram the extraction computes anyway."""
        res, A, _ = _recorded_window(seed=5)
        rec = res.recycle
        W, AW, _, _ = extract_next_basis_core(
            None, None, rec.P, rec.AP, rec.stored, 6
        )
        rng = np.random.default_rng(0)
        pert = jnp.asarray(rng.standard_normal(A.shape)) * 0.05
        A2 = A + pert @ pert.T
        res2 = defcg(
            from_matrix(A2), jnp.asarray(rng.standard_normal(A.shape[0])),
            W=W, AW=(W @ A2),  # exact products under A2: clean window
            tol=1e-8, maxiter=3000, ell=14, flat_recycle=True,
        )
        # window under A2, but pair it with the STALE products A¹W:
        _, _, _, fasym = extract_next_basis_core(
            W, AW, res2.recycle.P, res2.recycle.AP, res2.recycle.stored, 6
        )
        assert float(fasym) > 1e-6


class TestWindowHandoff:
    def test_alpha_beta_satisfy_cg_recurrences(self):
        """(P, AP, α, β) must reconstruct the CG iterates exactly:
        r_{j+1} = r_j − α_j AP_j and P_{j+1} = r_{j+1} + β_j P_j."""
        rng = np.random.default_rng(0)
        n = 80
        q, _ = np.linalg.qr(rng.standard_normal((n, n)))
        A = jnp.asarray((q * np.linspace(1, 50, n)) @ q.T)
        b = jnp.asarray(rng.standard_normal(n))
        res = defcg(
            from_matrix(A), b, tol=1e-10, maxiter=500, ell=30,
            flat_recycle=True,
        )
        rec = res.recycle
        m = int(rec.stored)
        assert m > 5
        P, AP = np.asarray(rec.P), np.asarray(rec.AP)
        al, be = np.asarray(rec.alpha), np.asarray(rec.beta)
        r = np.asarray(b)
        np.testing.assert_allclose(P[0], r, atol=1e-12)
        for j in range(m - 1):
            r = r - al[j] * AP[j]
            np.testing.assert_allclose(
                r + be[j] * P[j], P[j + 1], rtol=1e-10, atol=1e-12
            )
        # rows past the stored count are zero, coefficients included
        np.testing.assert_array_equal(al[m:], 0.0)
        np.testing.assert_array_equal(be[m:], 0.0)

    def test_aw_used_surfaces_only_under_stale_guard(self):
        res, A, b = _recorded_window(seed=7)
        W, AW, _, _ = extract_next_basis_core(
            None, None, res.recycle.P, res.recycle.AP,
            res.recycle.stored, 6,
        )
        plain = defcg(
            from_matrix(A), b, W=W, AW=AW, tol=1e-8, maxiter=3000,
            ell=8, flat_recycle=True,
        )
        assert plain.recycle.aw_used is None
        guarded = defcg(
            from_matrix(A), b, W=W, AW=AW, tol=1e-8, maxiter=3000,
            ell=8, flat_recycle=True, exact_aw=False, stale_guard=1e-6,
        )
        assert guarded.recycle.aw_used is not None
        assert guarded.recycle.aw_used.shape == AW.shape


class TestWindowedRecombine:
    def test_paper_accounting_on_gp_newton_sequence(self):
        """The acceptance criterion: on the fig2/table1 GP Newton
        sequence, matvecs = iterations + 2 (+k only on guard-triggered
        refreshes) and per-system iterations within ±1 of HarmonicRitz."""
        k_mv, shs, bs = _gp_newton_sequence()
        ops = KernelSystemOperator(k_mv, shs)
        k = 8
        base = solve_sequence(
            ops, bs, SolveSpec(k=k, ell=12, tol=1e-5, maxiter=2000)
        )
        win = solve_sequence(
            ops, bs,
            SolveSpec(k=k, ell=12, tol=1e-5, maxiter=2000,
                      strategy=WindowedRecombine()),
        )
        it_b = np.asarray(base.info.iterations)
        it_w = np.asarray(win.info.iterations)
        mv_w = np.asarray(win.info.matvecs)
        # solutions correct
        assert max(_seq_residuals(k_mv, shs, bs, win.x)) < 1e-4
        # iterations within ±1 of the exact-refresh path, per system
        assert np.max(np.abs(it_w - it_b)) <= 1, (it_w, it_b)
        # the paper's accounting: iters + 2 setup matvecs, plus k ONLY
        # where the guard bought a refresh — nothing else (in particular
        # no silent re-solve: that would show up as extra iterations).
        overhead = mv_w - it_w - 2
        assert set(np.unique(overhead)).issubset({0, k}), overhead
        # recycling still cuts iterations across the sequence
        assert it_w[-1] < it_w[0]

    def test_zero_refresh_accounting_on_multiple_rhs(self):
        """No drift (one operator, many right-hand sides): the guard must
        never trigger — matvecs = iterations + 2 exactly, k matvecs per
        system cheaper than the exact-refresh HarmonicRitz path."""
        k_mv, shs, bs = _gp_newton_sequence()
        num, k = 5, 8
        ops = KernelSystemOperator(k_mv, jnp.stack([shs[-1]] * num))
        rng = np.random.default_rng(1)
        bs_same = jnp.asarray(rng.standard_normal((num, bs.shape[1])))
        spec = SolveSpec(k=k, ell=12, tol=1e-5, maxiter=2000,
                         strategy=WindowedRecombine())
        seq = solve_sequence(ops, bs_same, spec)
        it_ = np.asarray(seq.info.iterations)
        mv = np.asarray(seq.info.matvecs)
        np.testing.assert_array_equal(mv, it_ + 2)
        assert it_[-1] < it_[0]  # recycling works
        base = solve_sequence(
            ops, bs_same, SolveSpec(k=k, ell=12, tol=1e-5, maxiter=2000)
        )
        # same-or-cheaper per system from system 2 on (no k-matvec refresh)
        assert np.all(mv[1:] <= np.asarray(base.info.matvecs)[1:] - k + 1)

    def test_guard_zero_reduces_to_exact_refresh(self):
        """guard=0 refreshes every carried basis — iteration counts must
        match the HarmonicRitz exact path on the drifting sequence."""
        k_mv, shs, bs = _gp_newton_sequence()
        ops = KernelSystemOperator(k_mv, shs)
        base = solve_sequence(
            ops, bs, SolveSpec(k=8, ell=12, tol=1e-5, maxiter=2000)
        )
        win0 = solve_sequence(
            ops, bs,
            SolveSpec(k=8, ell=12, tol=1e-5, maxiter=2000,
                      strategy=WindowedRecombine(guard=0.0)),
        )
        np.testing.assert_array_equal(
            np.asarray(win0.info.iterations),
            np.asarray(base.info.iterations),
        )
        # ... and refreshes exactly ONCE per carried basis: iters + 2
        # setup matvecs + k (systems 2+) — the in-solve guard must not
        # re-trigger on the freshly refreshed AW's rounding-level drift.
        it0 = np.asarray(win0.info.iterations)
        mv0 = np.asarray(win0.info.matvecs)
        np.testing.assert_array_equal(mv0[0], it0[0] + 2)  # cold
        np.testing.assert_array_equal(mv0[1:], it0[1:] + 2 + 8)

    def test_state_carries_finite_drift(self):
        k_mv, shs, bs = _gp_newton_sequence()
        ops = KernelSystemOperator(k_mv, shs)
        seq = solve_sequence(
            ops, bs,
            SolveSpec(k=8, ell=12, tol=1e-5, maxiter=2000,
                      strategy=WindowedRecombine()),
        )
        assert np.isfinite(float(seq.state.drift))

    def test_single_solve_front_door_accounting(self):
        """solve() carries the WindowedRecombine state too: second solve
        against the SAME operator costs iterations + 2, no refresh."""
        rng = np.random.default_rng(2)
        A0, _, _ = make_spd(96, 1e3, rng)
        A = jnp.asarray(A0)
        spec = SolveSpec(k=6, ell=12, tol=1e-6, maxiter=2000,
                         strategy=WindowedRecombine())
        r1 = solve(from_matrix(A), jnp.asarray(rng.standard_normal(96)), spec)
        r2 = solve(
            from_matrix(A), jnp.asarray(rng.standard_normal(96)), spec,
            r1.state,
        )
        assert int(r2.info.matvecs) == int(r2.info.iterations) + 2
        assert int(r2.info.iterations) < int(r1.info.iterations)


class TestMGeometryHarmonic:
    def _preconditioned_window(self, n=96, k=5, ell=16, seed=4):
        rng = np.random.default_rng(seed)
        A0, _, _ = make_spd(n, 1e4, rng)
        s = np.logspace(0, 1.5, n)  # strong diagonal scaling → M matters
        A = jnp.asarray(A0 * np.outer(s, s))
        mdiag = jnp.asarray(np.diag(np.asarray(A)))
        M = jacobi(mdiag)
        b = jnp.asarray(rng.standard_normal(n))
        res = defcg(
            from_matrix(A), b, tol=1e-12, maxiter=20 * n, ell=ell,
            flat_recycle=True, M=M,
        )
        return A, mdiag, res.recycle

    def test_matches_dense_m_half_similarity_reference(self):
        """θ and the recycled subspace must match plain harmonic Ritz of
        the dense similarity transform Ã = M^{-1/2} A M^{-1/2} applied to
        the transformed window, mapped back — the semantic definition of
        M-geometry extraction."""
        k = 5
        A, mdiag, rec = self._preconditioned_window(k=k)
        m = int(rec.stored)
        Z = rec.P[:m]
        AZ = rec.AP[:m]
        m_apply = lambda v: v / mdiag  # noqa: E731

        W_g, AW_g, th_g, _ = extract_next_basis_core(
            None, None, rec.P, rec.AP, rec.stored, k, m_apply=m_apply
        )

        # Dense reference: z̃ = M½z, Ãz̃ = M^{-1/2}(Az); harmonic Ritz of
        # Ã over span(Z̃); map the selected vectors back by M^{-1/2}.
        m_half = jnp.sqrt(mdiag)
        Z_t = Z * m_half[None, :]
        AZ_t = AZ / m_half[None, :]
        W_t, _, th_ref = harmonic_ritz(Z_t, AZ_t, k)
        W_ref = pt.ravel_basis(W_t) / m_half[None, :]

        np.testing.assert_allclose(
            np.asarray(th_g), np.asarray(th_ref), rtol=1e-8
        )
        # same subspace, vector by vector (up to sign and normalization:
        # the reference normalizes in the transformed space)
        wr = W_ref / jnp.linalg.norm(W_ref, axis=1, keepdims=True)
        for i in range(k):
            dot = float(jnp.abs(jnp.sum(wr[i] * W_g[i])))
            assert dot > 1.0 - 1e-8, (i, dot)

    def test_mgeometry_targets_effective_spectrum(self):
        """M-geometry θ approximate eig(M⁻¹A), not eig(A): against a
        Jacobi M the two extractions must disagree on this scaled
        problem (same window, different geometry ⇒ different targets)."""
        k = 5
        A, mdiag, rec = self._preconditioned_window(k=k)
        _, _, th_e, _ = extract_next_basis_core(
            None, None, rec.P, rec.AP, rec.stored, k
        )
        m_apply = lambda v: v / mdiag  # noqa: E731
        _, _, th_g, _ = extract_next_basis_core(
            None, None, rec.P, rec.AP, rec.stored, k, m_apply=m_apply
        )
        # effective spectrum of M⁻¹A is near-1-clustered: θ_M ≪ θ_E here
        assert float(th_g[0]) < 0.1 * float(th_e[0])
        # and the M-geometry values approximate eig(M⁻¹A)'s top end
        eff = np.linalg.eigvalsh(
            np.diag(1.0 / np.sqrt(np.asarray(mdiag)))
            @ np.asarray(A)
            @ np.diag(1.0 / np.sqrt(np.asarray(mdiag)))
        )
        np.testing.assert_allclose(float(th_g[0]), eff[-1], rtol=0.1)

    def test_spec_requires_preconditioner(self):
        with pytest.raises(ValueError, match="precond"):
            SolveSpec(strategy=MGeometryHarmonic())

    def test_end_to_end_preconditioned_sequence(self):
        """solve_sequence with MGeometryHarmonic + Jacobi: correct
        solutions and recycling still cuts iterations."""
        k_mv, shs, bs = _gp_newton_sequence()
        n = bs.shape[1]
        ops = KernelSystemOperator(k_mv, shs)
        diag_k = k_mv(jnp.eye(n))  # dense K diag via one pass
        kd = jnp.diag(diag_k)
        make_prec = lambda op: jacobi(1.0 + op.sqrt_h**2 * kd)  # noqa: E731
        spec = SolveSpec(
            k=8, ell=12, tol=1e-5, maxiter=2000, precond="jacobi",
            strategy=MGeometryHarmonic(),
        )
        seq = solve_sequence(
            ops, bs, spec, make_preconditioner=make_prec
        )
        assert max(_seq_residuals(k_mv, shs, bs, seq.x)) < 1e-4
        it_ = np.asarray(seq.info.iterations)
        assert it_[-1] < it_[0]


class TestSpecValidation:
    def test_stale_refresh_conflicts_with_owned_policy(self):
        with pytest.raises(ValueError, match="stale"):
            SolveSpec(refresh_aw="stale", strategy=WindowedRecombine())

    def test_strategy_must_be_instance(self):
        with pytest.raises(ValueError, match="strategy"):
            SolveSpec(strategy="windowed")

    def test_spec_with_strategy_is_hashable_static(self):
        s1 = SolveSpec(strategy=WindowedRecombine(guard=0.2))
        s2 = SolveSpec(strategy=WindowedRecombine(guard=0.2))
        assert hash(s1) == hash(s2) and s1 == s2
        assert s1 != SolveSpec(strategy=WindowedRecombine(guard=0.3))

    def test_hf_config_plumbs_strategy(self):
        from repro.optim.hessian_free import HFConfig

        cfg = HFConfig(strategy=WindowedRecombine())
        assert cfg.solve_spec().strategy == WindowedRecombine()


class TestSequenceDivergenceGuard:
    """Satellite: the device path's residual guard against a poisoned
    deflation basis (the manager had a fallback; the scan did not)."""

    def _poisoned_seed(self):
        k_mv, shs, bs = _gp_newton_sequence()
        n = bs.shape[1]
        rng = np.random.default_rng(9)
        W0 = jnp.asarray(rng.standard_normal((4, n)))
        W0 = W0 / jnp.linalg.norm(W0, axis=1, keepdims=True)
        A0 = KernelSystemOperator(k_mv, shs[0])
        # sign-flipped products: a maximally poisoned "stale" AW
        AW0 = -A0.basis_matvec(W0)
        return KernelSystemOperator(k_mv, shs), bs, W0, AW0, k_mv, shs

    def test_stale_poisoned_seed_recovers_with_fallback(self):
        from repro.core import recycle as recycle_mod

        ops, bs, W0, AW0, k_mv, shs = self._poisoned_seed()
        seq = recycle_mod.solve_sequence(
            ops, bs, W0, AW0, k=4, ell=12, tol=1e-5, maxiter=300,
            refresh_aw="stale", divergence_fallback=True,
        )
        assert max(_seq_residuals(k_mv, shs, bs, seq.x)) < 1e-4
        assert bool(np.asarray(seq.info.converged).all())
        # the failed attempt was charged: system 1's total exceeds the
        # clean-solve cost alone
        mv = np.asarray(seq.info.matvecs)
        it_ = np.asarray(seq.info.iterations)
        assert mv[0] > it_[0] + 2

    def test_without_fallback_poisoned_seed_fails(self):
        """The guard exists for a reason: same seed, fallback off, the
        first system must NOT converge (this is the pre-refactor device
        path's silent failure mode)."""
        from repro.core import recycle as recycle_mod

        ops, bs, W0, AW0, _, _ = self._poisoned_seed()
        seq = recycle_mod.solve_sequence(
            ops, bs, W0, AW0, k=4, ell=12, tol=1e-5, maxiter=300,
            refresh_aw="stale", divergence_fallback=False,
        )
        assert not bool(np.asarray(seq.info.converged)[0])


class TestBatchEarlyExit:
    """Satellite: the cross-tenant matvec gate must not change answers —
    warm-state tenants exercise the gated recording window."""

    def test_warm_batch_parity_with_sequential(self):
        rng = np.random.default_rng(3)
        n, B = 72, 3
        mats, states, bvecs = [], [], []
        spec = SolveSpec(k=4, ell=10, tol=1e-8, maxiter=2000)
        for i in range(B):
            A0, _, _ = make_spd(n, 1e3, rng)
            A = jnp.asarray(A0)
            b1 = jnp.asarray(rng.standard_normal(n))
            r = solve(from_matrix(A), b1, spec)
            mats.append(A)
            states.append(r.state)
            bvecs.append(jnp.asarray(rng.standard_normal(n)))
        batched_state = jax.tree_util.tree_map(
            lambda *ls: jnp.stack(ls), *states
        )
        out = solve_batch(
            jnp.stack(mats), jnp.stack(bvecs), spec, batched_state,
            make_operator=from_matrix,
        )
        for i in range(B):
            ref = solve(from_matrix(mats[i]), bvecs[i], spec, states[i])
            assert int(out.info.iterations[i]) == int(ref.info.iterations)
            # batched (n, B) GEMMs reorder reductions vs the sequential
            # GEMVs — trajectories agree to rounding, not bit-for-bit
            np.testing.assert_allclose(
                np.asarray(out.x[i]), np.asarray(ref.x), rtol=1e-8,
                atol=1e-10,
            )
