"""Per-kernel validation: Pallas (interpret=True) and chunked impls vs the
pure-jnp oracles in kernels/ref.py, swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

F32 = jnp.float32
BF16 = jnp.bfloat16


def _tol(dtype, scale=1.0):
    return dict(
        rtol=scale * (2e-2 if dtype == BF16 else 2e-4),
        atol=scale * (5e-2 if dtype == BF16 else 5e-4),
    )


# ---------------------------------------------------------------------------
# rbf_matvec
# ---------------------------------------------------------------------------


class TestRBFMatvec:
    @pytest.mark.parametrize("impl", ["interpret", "chunked"])
    @pytest.mark.parametrize(
        "n,d,r", [(64, 3, 1), (200, 17, 4), (257, 784, 8), (8, 1, 1)]
    )
    def test_matches_oracle(self, impl, n, d, r):
        rng = np.random.default_rng(n + d + r)
        x = jnp.asarray(rng.standard_normal((n, d)), F32)
        v = jnp.asarray(rng.standard_normal((n, r)), F32)
        theta, ls = 1.3, 2.1
        # Oracle in float64 — the kernels' f32 distance expansion is the
        # thing under test.
        want = np.asarray(
            ref.rbf_matvec(x.astype(jnp.float64), v.astype(jnp.float64), theta, ls)
        )
        got = np.asarray(
            ops.rbf_matvec(x, v, theta, ls, impl=impl, block=64)
        )
        scale = max(1.0, np.abs(want).max())
        np.testing.assert_allclose(got / scale, want / scale, **_tol(F32))

    def test_single_vector_shape(self):
        x = jnp.ones((10, 2), F32)
        v = jnp.ones((10,), F32)
        y = ops.rbf_matvec(x, v, 1.0, 1.0, impl="chunked")
        assert y.shape == (10,)

    @settings(max_examples=12, deadline=None)
    @given(
        n=st.integers(4, 150),
        d=st.integers(1, 40),
        r=st.integers(1, 5),
        block=st.sampled_from([16, 32, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_property_chunked_any_shape(self, n, d, r, block, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((n, d)), F32)
        v = jnp.asarray(rng.standard_normal((n, r)), F32)
        want = np.asarray(ref.rbf_matvec(x, v, 0.9, 1.4))
        got = np.asarray(ops.rbf_matvec(x, v, 0.9, 1.4, impl="chunked", block=block))
        scale = max(1.0, np.abs(want).max())
        np.testing.assert_allclose(got / scale, want / scale, **_tol(F32))

    def test_multirhs_equals_stacked_single(self):
        # multi-RHS fused pass (the A·W refresh path) == k single matvecs
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.standard_normal((40, 6)), F32)
        V = jnp.asarray(rng.standard_normal((40, 3)), F32)
        multi = ops.rbf_matvec(x, V, 1.1, 0.8, impl="interpret", block=32)
        singles = jnp.stack(
            [
                ops.rbf_matvec(x, V[:, i], 1.1, 0.8, impl="interpret", block=32)
                for i in range(3)
            ],
            axis=1,
        )
        np.testing.assert_allclose(multi, singles, rtol=1e-5, atol=1e-5)


class TestRBFMatvecRect:
    """Rectangular Gram matvec ``K(X_rows, X_cols) @ v`` — the sharded
    operator's per-shard primitive (DESIGN.md §5)."""

    @pytest.mark.parametrize("impl", ["interpret", "chunked"])
    @pytest.mark.parametrize("m,n,d,r", [(48, 96, 3, 1), (33, 200, 11, 4)])
    def test_matches_oracle(self, impl, m, n, d, r):
        rng = np.random.default_rng(m + n + d)
        xr = jnp.asarray(rng.standard_normal((m, d)), F32)
        xc = jnp.asarray(rng.standard_normal((n, d)), F32)
        v = jnp.asarray(rng.standard_normal((n, r)), F32)
        theta, ls = 1.3, 2.1
        want = np.asarray(
            ref.rbf_matvec_rect(
                xr.astype(jnp.float64),
                xc.astype(jnp.float64),
                v.astype(jnp.float64),
                theta,
                ls,
            )
        )
        got = np.asarray(
            ops.rbf_matvec_rect(xr, xc, v, theta, ls, impl=impl, block=32)
        )
        assert got.shape == (m, r)
        scale = max(1.0, np.abs(want).max())
        np.testing.assert_allclose(got / scale, want / scale, **_tol(F32))

    def test_square_case_equals_rbf_matvec(self):
        rng = np.random.default_rng(9)
        x = jnp.asarray(rng.standard_normal((70, 4)), F32)
        v = jnp.asarray(rng.standard_normal((70,)), F32)
        sq = ops.rbf_matvec(x, v, 0.9, 1.4, impl="chunked", block=32)
        rect = ops.rbf_matvec_rect(x, x, v, 0.9, 1.4, impl="chunked", block=32)
        assert rect.shape == (70,)
        np.testing.assert_allclose(rect, sq, rtol=1e-5, atol=1e-5)

    def test_row_blocks_concatenate_to_full_matvec(self):
        # The sharding identity the mesh operator relies on: every shard
        # computes K(X_local, X_full) @ v and the concatenation of the
        # row-block outputs IS the full square matvec.
        rng = np.random.default_rng(11)
        n, d, shards = 96, 5, 4
        x = jnp.asarray(rng.standard_normal((n, d)), F32)
        v = jnp.asarray(rng.standard_normal((n,)), F32)
        full = ops.rbf_matvec(x, v, 1.1, 0.8, impl="chunked", block=16)
        blocks = [
            ops.rbf_matvec_rect(
                x[i * (n // shards):(i + 1) * (n // shards)],
                x, v, 1.1, 0.8, impl="chunked", block=16,
            )
            for i in range(shards)
        ]
        np.testing.assert_allclose(
            jnp.concatenate(blocks), full, rtol=1e-5, atol=1e-5
        )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


ATTN_CASES = [
    # b, h, hkv, sq, sk, dh, causal, q_offset
    (2, 4, 2, 64, 64, 32, False, 0),
    (1, 8, 2, 96, 96, 64, True, 0),
    (2, 4, 4, 1, 133, 64, True, 132),   # decode
    (1, 2, 1, 40, 200, 16, False, 0),   # cross-attention shape
    (1, 16, 2, 33, 33, 128, True, 0),   # ragged blocks
]


class TestAttention:
    @pytest.mark.parametrize("impl", ["interpret", "chunked"])
    @pytest.mark.parametrize("case", ATTN_CASES)
    @pytest.mark.parametrize("dtype", [F32, BF16])
    def test_matches_oracle(self, impl, case, dtype):
        b, h, hkv, sq, sk, dh, causal, q_offset = case
        rng = np.random.default_rng(abs(hash(case)) % 2**31)
        q = jnp.asarray(rng.standard_normal((b, h, sq, dh)), dtype)
        k = jnp.asarray(rng.standard_normal((b, hkv, sk, dh)), dtype)
        v = jnp.asarray(rng.standard_normal((b, hkv, sk, dh)), dtype)
        want = np.asarray(
            ref.mha_attention(
                q.astype(F32), k.astype(F32), v.astype(F32),
                causal=causal, q_offset=q_offset,
            )
        )
        got = np.asarray(
            ops.attention(
                q, k, v, causal=causal, q_offset=q_offset,
                impl=impl, block_q=32, block_k=32,
            )
        ).astype(np.float32)
        np.testing.assert_allclose(got, want, **_tol(dtype))

    @settings(max_examples=10, deadline=None)
    @given(
        sq=st.integers(1, 70),
        sk=st.integers(1, 70),
        dh=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
        seed=st.integers(0, 2**16),
    )
    def test_property_chunked(self, sq, sk, dh, causal, seed):
        if causal and sq > sk:
            sq = sk  # causal requires q positions within the cache
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.standard_normal((1, 2, sq, dh)), F32)
        k = jnp.asarray(rng.standard_normal((1, 2, sk, dh)), F32)
        v = jnp.asarray(rng.standard_normal((1, 2, sk, dh)), F32)
        off = sk - sq if causal else 0
        want = np.asarray(
            ref.mha_attention(q, k, v, causal=causal, q_offset=off)
        )
        got = np.asarray(
            ops.attention(
                q, k, v, causal=causal, q_offset=off,
                impl="chunked", block_q=16, block_k=16,
            )
        )
        np.testing.assert_allclose(got, want, **_tol(F32))


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


SSD_CASES = [
    # b, l, h, p, g, n, chunk
    (1, 64, 2, 16, 1, 16, 32),
    (2, 100, 4, 8, 2, 24, 32),
    (1, 37, 2, 4, 2, 8, 16),     # ragged chunk
    (2, 128, 8, 32, 1, 64, 64),
]


class TestSSD:
    @pytest.mark.parametrize("impl", ["interpret", "chunked"])
    @pytest.mark.parametrize("case", SSD_CASES)
    def test_matches_sequential_oracle(self, impl, case):
        b, l, h, p, g, n, chunk = case
        rng = np.random.default_rng(abs(hash(case)) % 2**31)
        x = jnp.asarray(rng.standard_normal((b, l, h, p)), F32)
        dt = jnp.asarray(rng.uniform(0.01, 0.4, (b, l, h)), F32)
        a = jnp.asarray(-rng.uniform(0.3, 2.0, (h,)), F32)
        B = jnp.asarray(rng.standard_normal((b, l, g, n)), F32)
        C = jnp.asarray(rng.standard_normal((b, l, g, n)), F32)
        D = jnp.asarray(rng.standard_normal((h,)), F32)
        want = np.asarray(ref.ssd_reference(x, dt, a, B, C, D))
        got = np.asarray(
            ops.ssd(x, dt, a, B, C, D, impl=impl, chunk=chunk)
        )
        scale = max(1.0, np.abs(want).max())
        np.testing.assert_allclose(got / scale, want / scale, **_tol(F32, 2.0))

    def test_decode_step_matches_scan(self):
        """Feeding tokens one-by-one through ssd_decode_step must equal the
        full-sequence scan — the serve-path invariant."""
        b, l, h, p, g, n = 2, 20, 2, 8, 1, 8
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((b, l, h, p)), F32)
        dt = jnp.asarray(rng.uniform(0.05, 0.3, (b, l, h)), F32)
        a = jnp.asarray(-rng.uniform(0.5, 1.5, (h,)), F32)
        B = jnp.asarray(rng.standard_normal((b, l, g, n)), F32)
        C = jnp.asarray(rng.standard_normal((b, l, g, n)), F32)
        full = ref.ssd_reference(x, dt, a, B, C)
        state = jnp.zeros((b, h, p, n), F32)
        outs = []
        for t in range(l):
            state, y = ops.ssd_decode_step(
                state, x[:, t], dt[:, t], a, B[:, t], C[:, t]
            )
            outs.append(y)
        got = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(full), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=8, deadline=None)
    @given(
        l=st.integers(2, 80),
        chunk=st.sampled_from([8, 16, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_property_chunk_invariance(self, l, chunk, seed):
        """Output must be independent of the chunk size (pure blocking)."""
        rng = np.random.default_rng(seed)
        b, h, p, g, n = 1, 2, 4, 1, 8
        x = jnp.asarray(rng.standard_normal((b, l, h, p)), F32)
        dt = jnp.asarray(rng.uniform(0.01, 0.4, (b, l, h)), F32)
        a = jnp.asarray(-rng.uniform(0.3, 2.0, (h,)), F32)
        B = jnp.asarray(rng.standard_normal((b, l, g, n)), F32)
        C = jnp.asarray(rng.standard_normal((b, l, g, n)), F32)
        y1 = ops.ssd(x, dt, a, B, C, impl="chunked", chunk=chunk)
        y2 = ops.ssd(x, dt, a, B, C, impl="chunked", chunk=2 * chunk)
        np.testing.assert_allclose(
            np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4
        )
